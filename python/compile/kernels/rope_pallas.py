"""Pallas RoPE kernels (L1) — the paper's §4.5 non-contiguous kernel, TPU-style.

The paper's Triton kernel avoids PyTorch's materialised gather of per-head
cos/sin subsets by indexing inside the kernel.  On TPU the equivalent design
(DESIGN.md §Hardware-Adaptation) precomputes, once per pruning plan, a tiny
``theta_sel [H, m]`` table containing the angular frequencies of exactly the
retained pairs of each head.  The kernel then

  1. streams one (batch, head) activation block [S_tile, 2m] HBM->VMEM,
  2. keeps the [m] theta row VMEM-resident across the whole S loop,
  3. computes cos/sin *in-kernel* (VPU work) and applies the 2x2 rotations
     with two fused multiply-adds per pair,
  4. streams the rotated block back.

No full-D cos/sin table ever exists, and no gather is performed: the
"non-contiguity" was resolved at plan time.  This is why RAP's kernel cost is
*below* the contiguous baseline (it touches 2m <= D lanes), mirroring the
paper's Figure 16 / Table 11 result.

Everything here runs under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); block shapes are still chosen as they would be on real TPU so
the VMEM estimates in DESIGN.md are faithful.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# S tile used when the sequence axis is long enough to be worth tiling.
# [S_TILE, 2m] fp32 at m=64 is 64 KiB — comfortably VMEM-resident together
# with the [m] theta row and double-buffering headroom.
S_TILE = 128


def _latent_kernel(pos_ref, x_ref, theta_ref, o_ref):
    """Rotate one (b, h, s-tile) latent block.

    Block shapes: x_ref [1, 1, S_t, 2m], theta_ref [1, m], pos_ref [S_t].
    """
    m = theta_ref.shape[-1]
    pos = pos_ref[...].astype(jnp.float32)  # [S_t]
    theta = theta_ref[0]  # [m], VMEM-resident per-head retained freqs
    ang = pos[:, None] * theta[None, :]  # [S_t, m]
    cos = jnp.cos(ang)
    sin = jnp.sin(ang)
    x = x_ref[0, 0]  # [S_t, 2m]
    a = x[:, :m]
    b = x[:, m:]
    o_ref[0, 0, :, :m] = a * cos - b * sin
    o_ref[0, 0, :, m:] = a * sin + b * cos


@functools.partial(jax.jit, static_argnames=("interpret",))
def rope_latent_pallas(
    x: jnp.ndarray,
    pos: jnp.ndarray,
    theta_sel: jnp.ndarray,
    interpret: bool = True,
) -> jnp.ndarray:
    """Index-aware RoPE on a latent tensor.

    x: [B, H, S, 2m] canonical half layout; pos: [S] int32;
    theta_sel: [H, m].  Returns the rotated tensor, same shape.
    """
    bsz, h, s, two_m = x.shape
    m = two_m // 2
    s_t = S_TILE if s % S_TILE == 0 and s > S_TILE else s
    grid = (bsz, h, s // s_t)
    return pl.pallas_call(
        _latent_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s_t,), lambda b, i, j: (j,)),  # pos tile
            pl.BlockSpec((1, 1, s_t, two_m), lambda b, i, j: (b, i, j, 0)),
            pl.BlockSpec((1, m), lambda b, i, j: (i, 0)),  # per-head thetas
        ],
        out_specs=pl.BlockSpec((1, 1, s_t, two_m), lambda b, i, j: (b, i, j, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(pos, x, theta_sel)


def _full_kernel_half(pos_ref, x_ref, theta_ref, o_ref):
    """Contiguous baseline, half pairing.

    Block shapes: x_ref [1, 1, S_t, D], theta_ref [D/2], pos_ref [S_t].
    """
    p = theta_ref.shape[0]
    pos = pos_ref[...].astype(jnp.float32)
    ang = pos[:, None] * theta_ref[...][None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x = x_ref[0, 0]
    a = x[:, :p]
    b = x[:, p:]
    o_ref[0, 0, :, :p] = a * cos - b * sin
    o_ref[0, 0, :, p:] = a * sin + b * cos


def _full_kernel_interleaved(pos_ref, x_ref, theta_ref, o_ref):
    """Contiguous baseline, interleaved pairing: pre-permute to half layout
    in VMEM (free), rotate, permute back."""
    pos = pos_ref[...].astype(jnp.float32)
    ang = pos[:, None] * theta_ref[...][None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x = x_ref[0, 0]
    a = x[:, 0::2]
    b = x[:, 1::2]
    ra = a * cos - b * sin
    rb = a * sin + b * cos
    o_ref[0, 0] = jnp.stack([ra, rb], axis=-1).reshape(x.shape)


@functools.partial(
    jax.jit, static_argnames=("base", "pairing", "interpret")
)
def rope_full_pallas(
    x: jnp.ndarray,
    pos: jnp.ndarray,
    base: float,
    pairing: str = "half",
    interpret: bool = True,
) -> jnp.ndarray:
    """Contiguous-baseline RoPE as a Pallas kernel.

    x: [B, H, S, D]; pos: [S].  The theta table [D/2] is shared by all heads
    (classic broadcastable case the paper's §4.5 calls "standard").
    """
    bsz, h, s, d = x.shape
    p = d // 2
    theta = (base ** (-2.0 * jnp.arange(p, dtype=jnp.float32) / d)).reshape(p)
    kern = _full_kernel_half if pairing == "half" else _full_kernel_interleaved
    s_t = S_TILE if s % S_TILE == 0 and s > S_TILE else s
    grid = (bsz, h, s // s_t)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s_t,), lambda b, i, j: (j,)),
            pl.BlockSpec((1, 1, s_t, d), lambda b, i, j: (b, i, j, 0)),
            pl.BlockSpec((p,), lambda b, i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, s_t, d), lambda b, i, j: (b, i, j, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(pos, x, theta)
