"""Fused latent-KV decode attention Pallas kernel (L1).

One decode step over a (possibly RAP/SVD/PaLU-compressed) latent KV cache:
for each query head, score against its GQA group's latent K cache, softmax
with a position mask, and contract with the latent V cache.  The latent
widths kr/vr are the per-layer values the pruning plan produced — the kernel
is width-generic, which is exactly what makes RAP "drop-in" (§4.5): the
computation graph is unchanged, only dimensions shrink.

TPU mapping: grid over (batch, q-head); each step keeps the [Smax, kr] K
block and [Smax, vr] V block of the head's KV group in VMEM (Smax=640,
kr,vr<=64 -> <=320 KiB), computes the masked softmax on the VPU and the two
contractions on the MXU.  The S axis could be tiled with an online-softmax
accumulator for longer contexts; at our Smax a single block is optimal
(fewer HBM round-trips).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_kernel(scale, pos_ref, q_ref, k_ref, v_ref, o_ref):
    """Block shapes: q [1,1,kr], k [1,1,Smax,kr], v [1,1,Smax,vr], pos [1]
    (this batch element's position) -> o [1,1,vr]."""
    smax = k_ref.shape[-2]
    q = q_ref[0, 0]  # [kr]
    k = k_ref[0, 0]  # [Smax, kr]
    v = v_ref[0, 0]  # [Smax, vr]
    pos = pos_ref[0]
    s = jnp.dot(k, q) * scale  # [Smax]
    mask = jax.lax.iota(jnp.int32, smax) <= pos
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s)
    w = jnp.exp(s - m)
    w = w / jnp.sum(w)
    o_ref[0, 0] = jnp.dot(w, v)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def attn_decode_pallas(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,
    scale: float,
    interpret: bool = True,
) -> jnp.ndarray:
    """Single-step decode attention.

    q: [B, H, kr]; k_cache: [B, Hkv, Smax, kr]; v_cache: [B, Hkv, Smax, vr];
    pos: scalar int32 or [B] int32 (per-sequence positions — continuous
    batching mixes sequences at different offsets).  Returns [B, H, vr].
    Query head h attends to KV head h // (H / Hkv).
    """
    bsz, h, kr = q.shape
    hkv, smax = k_cache.shape[1], k_cache.shape[2]
    vr = v_cache.shape[3]
    group = h // hkv
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (bsz,))
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale),
        grid=(bsz, h),
        in_specs=[
            pl.BlockSpec((1,), lambda b, i: (b,)),
            pl.BlockSpec((1, 1, kr), lambda b, i: (b, i, 0)),
            # K/V blocks of the head's GQA group stay VMEM-resident.
            pl.BlockSpec((1, 1, smax, kr), lambda b, i: (b, i // group, 0, 0)),
            pl.BlockSpec((1, 1, smax, vr), lambda b, i: (b, i // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, vr), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, vr), q.dtype),
        interpret=interpret,
    )(pos_arr, q, k_cache, v_cache)
