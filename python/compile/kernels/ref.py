"""Pure-jnp oracles for the L1 Pallas kernels.

Every Pallas kernel in this package is validated against these references in
``python/tests`` (including hypothesis sweeps over shapes).  The rust engine
(`rust/src/rope`, `rust/src/model`) implements the same math and is
cross-checked against PJRT executions of these graphs.

Layout conventions
------------------
*Full* (uncompressed) K/Q tensors use the model's native pairing strategy
("half": pair (j, j+D/2); "interleaved": pair (2j, 2j+1)).

*Latent* (RAP-pruned) tensors use the canonical **half layout**: a width-2m
row is ``[a_0..a_{m-1}, b_0..b_{m-1}]`` where (a_i, b_i) is the i-th retained
RoPE pair, ordered by ascending original pair index.  The per-head angular
frequencies of exactly the retained pairs are precomputed into a small
``theta_sel [H, m]`` table — the TPU adaptation of the paper's
non-contiguous Triton kernel (DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import jax.numpy as jnp


def thetas(n_pairs: int, head_dim: int, base: float) -> jnp.ndarray:
    """Angular frequency per RoPE pair j: base^(-2j/D)."""
    j = jnp.arange(n_pairs, dtype=jnp.float32)
    return base ** (-2.0 * j / head_dim)


def rope_full_ref(x: jnp.ndarray, pos: jnp.ndarray, base: float, pairing: str) -> jnp.ndarray:
    """Standard RoPE on a full-dimension tensor.

    x: [..., S, D] with D even; pos: [S] int32 positions.
    """
    d = x.shape[-1]
    p = d // 2
    th = thetas(p, d, base)
    ang = pos.astype(jnp.float32)[:, None] * th[None, :]  # [S, p]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if pairing == "half":
        a, b = x[..., :p], x[..., p:]
        return jnp.concatenate([a * cos - b * sin, a * sin + b * cos], axis=-1)
    if pairing == "interleaved":
        a, b = x[..., 0::2], x[..., 1::2]
        ra, rb = a * cos - b * sin, a * sin + b * cos
        out = jnp.stack([ra, rb], axis=-1)
        return out.reshape(x.shape)
    raise ValueError(pairing)


def rope_latent_ref(x: jnp.ndarray, pos: jnp.ndarray, theta_sel: jnp.ndarray) -> jnp.ndarray:
    """Index-aware RoPE on a latent tensor (canonical half layout).

    x: [B, H, S, 2m]; pos: [S]; theta_sel: [H, m] — per-head frequencies of
    the retained pairs (original indices baked in at plan time).
    """
    m = theta_sel.shape[-1]
    ang = pos.astype(jnp.float32)[None, :, None] * theta_sel[:, None, :]  # [H, S, m]
    cos, sin = jnp.cos(ang)[None], jnp.sin(ang)[None]  # [1, H, S, m]
    a, b = x[..., :m], x[..., m:]
    return jnp.concatenate([a * cos - b * sin, a * sin + b * cos], axis=-1)


def rope_gather_ref(
    x: jnp.ndarray,
    pos: jnp.ndarray,
    base: float,
    head_dim: int,
    pair_idx: jnp.ndarray,
) -> jnp.ndarray:
    """The "PyTorch" variant the paper criticises (§4.5): materialise the full
    cos/sin tables, then gather per-head retained columns.  Numerically equal
    to ``rope_latent_ref`` when ``theta_sel = thetas(...)[pair_idx]``; only the
    memory behaviour differs.

    x: [B, H, S, 2m]; pair_idx: [H, m] int32 original pair indices.
    """
    p = head_dim // 2
    th = thetas(p, head_dim, base)  # [p]
    ang = pos.astype(jnp.float32)[:, None] * th[None, :]  # [S, p]
    cos_full, sin_full = jnp.cos(ang), jnp.sin(ang)  # [S, p]
    # Materialising gather: one [H, S, m] buffer per table.
    cos = jnp.take(cos_full, pair_idx, axis=1).transpose(1, 0, 2)  # [H, S, m]
    sin = jnp.take(sin_full, pair_idx, axis=1).transpose(1, 0, 2)
    m = pair_idx.shape[-1]
    a, b = x[..., :m], x[..., m:]
    return jnp.concatenate(
        [a * cos[None] - b * sin[None], a * sin[None] + b * cos[None]], axis=-1
    )


def attn_decode_ref(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,
    scale: float,
) -> jnp.ndarray:
    """Single-step decode attention over a (latent) KV cache.

    q: [B, H, kr]; k_cache: [B, Hkv, Smax, kr]; v_cache: [B, Hkv, Smax, vr];
    pos: scalar int32 or [B] int32 — the index of each sequence's current
    token; entries at s > pos are masked out.  Returns [B, H, vr].
    """
    b, h, kr = q.shape
    hkv, smax = k_cache.shape[1], k_cache.shape[2]
    group = h // hkv
    kx = jnp.repeat(k_cache, group, axis=1)  # [B, H, Smax, kr]
    vx = jnp.repeat(v_cache, group, axis=1)
    s = jnp.einsum("bhk,bhsk->bhs", q, kx) * scale
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    mask = jnp.arange(smax)[None, None, :] <= posb[:, None, None]
    s = jnp.where(mask, s, -1e30)
    w = jnp.exp(s - s.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhs,bhsv->bhv", w, vx)
