"""L1 — Pallas kernels for RAP's compute hot-spots.

``rope_pallas``: contiguous-baseline and index-aware (non-contiguous) RoPE.
``attn_pallas``: fused latent-KV decode attention.
``ref``: pure-jnp oracles used by pytest and by the L2 training path.

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; real-TPU performance is estimated from VMEM
footprint + BlockSpec structure in DESIGN.md.
"""

from . import ref  # noqa: F401
from .rope_pallas import rope_full_pallas, rope_latent_pallas  # noqa: F401
from .attn_pallas import attn_decode_pallas  # noqa: F401
