"""L2 — JAX decoder-only transformer with compressed-attention variants.

One forward implementation serves every method the paper evaluates:

- ``baseline``  : full K/V, standard RoPE, full cache.
- ``svd``       : per-head truncated SVD of W_k and W_v (Eq. 1).  The cache
                  stores pre-RoPE latents; **both** K and V are reconstructed
                  to full dimension at attention time (the Figure-1 overhead).
- ``palu``      : whitened SVD; B_v absorbed into W_o, so only K is
                  reconstructed.
- ``rap``       : RoPE-aligned pair pruning of W_k with B_k absorbed into W_q
                  (Eq. 9–10) + whitened-SVD V with B_v absorbed into W_o
                  (the paper's default hybrid pipeline, §4.5).  Nothing is
                  reconstructed: attention runs directly in latent widths.

The per-layer latent widths come from a :class:`compile.config.VariantSpec`;
the corresponding weights are produced by ``compile.rap``.  The Pallas
kernels are used on the AOT/serving path (``use_pallas=True``); training and
Fisher estimation use the pure-jnp path (identical numerics, asserted by
``python/tests``).
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, VariantSpec
from .kernels import ref
from .kernels.attn_pallas import attn_decode_pallas
from .kernels.rope_pallas import rope_full_pallas, rope_latent_pallas

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Weight initialisation (baseline model)
# --------------------------------------------------------------------------


def init_weights(cfg: ModelConfig, seed: int = 42) -> Dict:
    """He-style init for the baseline model.  Embedding is tied to the
    output head (standard for small LMs; keeps the parameter budget in the
    attention/MLP stack where compression acts)."""
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, cfg.n_layers * 7 + 1)
    d, q, kv, m = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.mlp_hidden

    def dense(k, fan_in, shape):
        return (jax.random.normal(k, shape) / np.sqrt(fan_in)).astype(jnp.float32)

    layers = []
    ki = iter(keys[:-1])
    for _ in range(cfg.n_layers):
        layers.append(
            {
                "attn_norm": jnp.ones((d,), jnp.float32),
                "wq": dense(next(ki), d, (d, q)),
                "wk": dense(next(ki), d, (d, kv)),
                "wv": dense(next(ki), d, (d, kv)),
                "wo": dense(next(ki), q, (q, d)),
                "mlp_norm": jnp.ones((d,), jnp.float32),
                "w_gate": dense(next(ki), d, (d, m)),
                "w_up": dense(next(ki), d, (d, m)),
                "w_down": dense(next(ki), m, (m, d)),
            }
        )
    return {
        "tok_emb": (jax.random.normal(keys[-1], (cfg.vocab, d)) * 0.02).astype(
            jnp.float32
        ),
        "layers": layers,
        "final_norm": jnp.ones((d,), jnp.float32),
    }


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def swiglu(h: jnp.ndarray, lw: Dict) -> jnp.ndarray:
    g = h @ lw["w_gate"]
    return (jax.nn.silu(g) * (h @ lw["w_up"])) @ lw["w_down"]


# --------------------------------------------------------------------------
# Per-method attention projections
# --------------------------------------------------------------------------


def _split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[B, S, H*w] -> [B, H, S, w]."""
    b, s, hw = x.shape
    return x.reshape(b, s, n_heads, hw // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    """[B, H, S, w] -> [B, S, H*w]."""
    b, h, s, w = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * w)


def _rope_q_full(cfg, q, pos, use_pallas):
    if use_pallas:
        return rope_full_pallas(q, pos, cfg.rope_theta, cfg.pairing)
    return ref.rope_full_ref(q, pos, cfg.rope_theta, cfg.pairing)


def _rope_latent(x, pos, theta_sel, use_pallas):
    if use_pallas:
        return rope_latent_pallas(x, pos, theta_sel)
    return ref.rope_latent_ref(x, pos, theta_sel)


def project_qkv(
    cfg: ModelConfig,
    spec: VariantSpec,
    lw: Dict,
    h: jnp.ndarray,
    pos: jnp.ndarray,
    layer: int,
    use_pallas: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Project a normed hidden state into (q, k_cacheable, v_cacheable).

    Returns q [B, H, S, qw], k [B, Hkv, S, kr], v [B, Hkv, S, vr] where
    k/v are exactly what goes into the KV cache for this method:
      baseline: post-RoPE K, full V;
      svd/palu: pre-RoPE latent K, latent V;
      rap:      post-index-aware-RoPE latent K, latent V.
    """
    method = spec.method
    if method == "baseline":
        q = _split_heads(h @ lw["wq"], cfg.n_heads)
        k = _split_heads(h @ lw["wk"], cfg.n_kv_heads)
        v = _split_heads(h @ lw["wv"], cfg.n_kv_heads)
        q = _rope_q_full(cfg, q, pos, use_pallas)
        k = _rope_q_full(cfg, k, pos, use_pallas)
        return q, k, v
    if method in ("svd", "palu"):
        q = _split_heads(h @ lw["wq"], cfg.n_heads)
        q = _rope_q_full(cfg, q, pos, use_pallas)
        k_lat = _split_heads(h @ lw["a_k"], cfg.n_kv_heads)
        v_lat = _split_heads(h @ lw["a_v"], cfg.n_kv_heads)
        return q, k_lat, v_lat
    if method == "rap":
        # Absorbed query projection: width 2m per query head (Eq. 10).
        q_lat = _split_heads(h @ lw["wq_t"], cfg.n_heads)
        k_lat = _split_heads(h @ lw["a_k"], cfg.n_kv_heads)
        theta_kv = lw["theta_sel"]  # [Hkv, m]
        theta_q = jnp.repeat(theta_kv, cfg.group_size, axis=0)  # [H, m]
        q_lat = _rope_latent(q_lat, pos, theta_q, use_pallas)
        k_lat = _rope_latent(k_lat, pos, theta_kv, use_pallas)
        v_lat = _split_heads(h @ lw["a_v"], cfg.n_kv_heads)
        return q_lat, k_lat, v_lat
    raise ValueError(method)


def _project_qkv_norope(cfg: ModelConfig, spec: VariantSpec, lw: Dict, h: jnp.ndarray):
    """Projections only (no positional rotation) — the decode step applies
    RoPE per batch element afterwards.  Returns (q, k_cacheable_unrotated,
    v_cacheable) with the same shapes as :func:`project_qkv`."""
    if spec.method == "baseline":
        return (
            _split_heads(h @ lw["wq"], cfg.n_heads),
            _split_heads(h @ lw["wk"], cfg.n_kv_heads),
            _split_heads(h @ lw["wv"], cfg.n_kv_heads),
        )
    if spec.method in ("svd", "palu"):
        return (
            _split_heads(h @ lw["wq"], cfg.n_heads),
            _split_heads(h @ lw["a_k"], cfg.n_kv_heads),
            _split_heads(h @ lw["a_v"], cfg.n_kv_heads),
        )
    if spec.method == "rap":
        return (
            _split_heads(h @ lw["wq_t"], cfg.n_heads),
            _split_heads(h @ lw["a_k"], cfg.n_kv_heads),
            _split_heads(h @ lw["a_v"], cfg.n_kv_heads),
        )
    raise ValueError(spec.method)


def attention_scores_inputs(
    cfg: ModelConfig, spec: VariantSpec, lw: Dict, k_cache: jnp.ndarray, pos_kv: jnp.ndarray
) -> jnp.ndarray:
    """Turn the cached K into whatever Q is dotted against.

    baseline/rap: identity (this is RAP's entire point — Eq. 10 holds and
    the cache participates in attention directly).
    svd/palu: reconstruct K = RoPE((X A_k) B_k) to full head dim — the
    per-step overhead the paper eliminates.
    """
    if spec.method in ("baseline", "rap"):
        return k_cache
    # k_cache: [B, Hkv, S, rk]; b_k: [Hkv, rk, dh]
    k_full = jnp.einsum("bhsr,hrd->bhsd", k_cache, lw["b_k"])
    return ref.rope_full_ref(k_full, pos_kv, cfg.rope_theta, cfg.pairing)


def values_inputs(spec: VariantSpec, lw: Dict, v_cache: jnp.ndarray) -> jnp.ndarray:
    """svd reconstructs V; palu/rap consume latent V (B_v absorbed in W_o)."""
    if spec.method == "svd":
        return jnp.einsum("bhsr,hrd->bhsd", v_cache, lw["b_v"])
    return v_cache


def output_proj(spec: VariantSpec, lw: Dict, attn: jnp.ndarray) -> jnp.ndarray:
    """attn: [B, H, S, vw] -> [B, S, D] through the (possibly absorbed) W_o."""
    merged = _merge_heads(attn)
    if spec.method in ("palu", "rap"):
        return merged @ lw["wo_t"]
    return merged @ lw["wo"]


# --------------------------------------------------------------------------
# Full-sequence forward (training / PPL / prefill)
# --------------------------------------------------------------------------


def _causal_attend(cfg: ModelConfig, q, k, v) -> jnp.ndarray:
    """q: [B,H,S,kw], k: [B,Hkv,S,kw], v: [B,Hkv,S,vw] -> [B,H,S,vw]."""
    s = q.shape[2]
    kx = jnp.repeat(k, cfg.group_size, axis=1)
    vx = jnp.repeat(v, cfg.group_size, axis=1)
    # The paper keeps the original 1/sqrt(D) scale (§3 Eq. 3); pruned dims
    # simply contribute nothing to the dot product.
    scores = jnp.einsum("bhqk,bhsk->bhqs", q, kx) / np.sqrt(cfg.head_dim)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqs,bhsv->bhqv", w, vx)


def forward_full(
    cfg: ModelConfig,
    spec: VariantSpec,
    weights: Dict,
    tokens: jnp.ndarray,
    use_pallas: bool = False,
    return_hiddens: bool = False,
):
    """Full-sequence forward.  tokens: [B, S] int32 -> logits [B, S, V].

    With ``return_hiddens=True`` also returns the per-layer *normed* inputs
    to the attention projections (used for whitening covariance in
    ``compile.rap.palu``)."""
    b, s = tokens.shape
    pos = jnp.arange(s, dtype=jnp.int32)
    x = weights["tok_emb"][tokens]
    hiddens: List[jnp.ndarray] = []
    for layer, lw in enumerate(weights["layers"]):
        h = rms_norm(x, lw["attn_norm"], cfg.norm_eps)
        if return_hiddens:
            hiddens.append(h)
        q, kc, vc = project_qkv(cfg, spec, lw, h, pos, layer, use_pallas)
        k = attention_scores_inputs(cfg, spec, lw, kc, pos)
        v = values_inputs(spec, lw, vc)
        attn = _causal_attend(cfg, q, k, v)
        x = x + output_proj(spec, lw, attn)
        x = x + swiglu(rms_norm(x, lw["mlp_norm"], cfg.norm_eps), lw)
    x = rms_norm(x, weights["final_norm"], cfg.norm_eps)
    logits = x @ weights["tok_emb"].T
    if return_hiddens:
        return logits, hiddens
    return logits


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


def loss_fn(cfg, spec, weights, tokens, targets, use_pallas: bool = False):
    return cross_entropy(forward_full(cfg, spec, weights, tokens, use_pallas), targets)


# --------------------------------------------------------------------------
# Prefill with cache + single-token decode (the serving graphs)
# --------------------------------------------------------------------------


def prefill_with_cache(
    cfg: ModelConfig,
    spec: VariantSpec,
    weights: Dict,
    tokens: jnp.ndarray,
    s_max: int,
    use_pallas: bool = True,
):
    """Prefill: run the prompt, return last-position logits and the KV cache
    padded to ``s_max``.  Cache layout per layer: k [B, Hkv, Smax, kr],
    v [B, Hkv, Smax, vr] — *latent* widths for the compressed methods."""
    b, s = tokens.shape
    pos = jnp.arange(s, dtype=jnp.int32)
    x = weights["tok_emb"][tokens]
    k_caches, v_caches = [], []
    for layer, lw in enumerate(weights["layers"]):
        h = rms_norm(x, lw["attn_norm"], cfg.norm_eps)
        q, kc, vc = project_qkv(cfg, spec, lw, h, pos, layer, use_pallas)
        k = attention_scores_inputs(cfg, spec, lw, kc, pos)
        v = values_inputs(spec, lw, vc)
        attn = _causal_attend(cfg, q, k, v)
        x = x + output_proj(spec, lw, attn)
        x = x + swiglu(rms_norm(x, lw["mlp_norm"], cfg.norm_eps), lw)
        pad = [(0, 0), (0, 0), (0, s_max - s), (0, 0)]
        k_caches.append(jnp.pad(kc, pad))
        v_caches.append(jnp.pad(vc, pad))
    x = rms_norm(x, weights["final_norm"], cfg.norm_eps)
    logits = x[:, -1, :] @ weights["tok_emb"].T
    return logits, k_caches, v_caches


def _rope_batched_positions(cfg, spec, lw, x, pos_b, use_pallas, is_query):
    """RoPE a decode-step tensor [B, H, 1, w] where batch element b sits at
    position pos_b[b].  Folds the batch axis into the per-row position axis
    (RoPE is row-wise), so the same kernels serve continuous batching."""
    bsz, h, _, w = x.shape
    xt = jnp.transpose(x[:, :, 0, :], (1, 0, 2))[None]  # [1, H, B, w]
    if spec.method == "rap":
        theta = lw["theta_sel"]
        if is_query:
            theta = jnp.repeat(theta, cfg.group_size, axis=0)
        rot = _rope_latent(xt, pos_b, theta, use_pallas)
    else:
        rot = _rope_q_full(cfg, xt, pos_b, use_pallas)
    return jnp.transpose(rot[0], (1, 0, 2))[:, :, None, :]  # [B, H, 1, w]


def decode_step(
    cfg: ModelConfig,
    spec: VariantSpec,
    weights: Dict,
    token: jnp.ndarray,
    pos: jnp.ndarray,
    k_caches: List[jnp.ndarray],
    v_caches: List[jnp.ndarray],
    use_pallas: bool = True,
):
    """One decode step.  token: [B] int32; pos: scalar int32 or [B] int32 —
    each sequence's current position (continuous batching mixes offsets).
    Returns (logits [B, V], updated caches).

    For svd/palu this reconstructs the **entire** cached K (and V for svd)
    to full dimension every step — faithfully reproducing the Figure-1
    reconstruction overhead that RAP's absorbed graphs do not contain.
    """
    b = token.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    x = weights["tok_emb"][token][:, None, :]  # [B, 1, D]
    new_k, new_v = [], []
    s_max = k_caches[0].shape[2]
    # One-hot position masks for the per-sequence cache scatter.
    onehot = (jnp.arange(s_max, dtype=jnp.int32)[None, :] == pos_b[:, None])
    oh = onehot[:, None, :, None]  # [B, 1, Smax, 1]
    for layer, lw in enumerate(weights["layers"]):
        h = rms_norm(x, lw["attn_norm"], cfg.norm_eps)
        # Project WITHOUT rope (pos handled per batch element below).
        q, kc, vc = _project_qkv_norope(cfg, spec, lw, h)
        if spec.method in ("baseline", "rap"):
            q = _rope_batched_positions(cfg, spec, lw, q, pos_b, use_pallas, True)
            kc = _rope_batched_positions(cfg, spec, lw, kc, pos_b, use_pallas, False)
        elif spec.method in ("svd", "palu"):
            q = _rope_batched_positions(cfg, spec, lw, q, pos_b, use_pallas, True)
        # Scatter this step's K/V at each sequence's position.
        k_cache = jnp.where(oh, kc, k_caches[layer])
        v_cache = jnp.where(oh, vc, v_caches[layer])
        new_k.append(k_cache)
        new_v.append(v_cache)
        pos_kv = jnp.arange(s_max, dtype=jnp.int32)
        k_all = attention_scores_inputs(cfg, spec, lw, k_cache, pos_kv)
        v_all = values_inputs(spec, lw, v_cache)
        scale = 1.0 / np.sqrt(cfg.head_dim)
        if use_pallas and spec.method in ("baseline", "rap"):
            # Fused latent decode-attention kernel on the no-reconstruction
            # path (the hot path RAP optimises).
            attn = attn_decode_pallas(q[:, :, 0, :], k_all, v_all, pos_b, scale)
        else:
            attn = ref.attn_decode_ref(q[:, :, 0, :], k_all, v_all, pos_b, scale)
        x = x + output_proj(spec, lw, attn[:, :, None, :])
        x = x + swiglu(rms_norm(x, lw["mlp_norm"], cfg.norm_eps), lw)
    x = rms_norm(x, weights["final_norm"], cfg.norm_eps)
    logits = x[:, 0, :] @ weights["tok_emb"].T
    return logits, new_k, new_v


# --------------------------------------------------------------------------
# Weight flattening (interchange with rust)
# --------------------------------------------------------------------------

_BASE_KEYS = ["attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down"]
_METHOD_KEYS = {
    "baseline": ["attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down"],
    "svd": ["attn_norm", "wq", "a_k", "b_k", "a_v", "b_v", "wo", "mlp_norm", "w_gate", "w_up", "w_down"],
    "palu": ["attn_norm", "wq", "a_k", "b_k", "a_v", "wo_t", "mlp_norm", "w_gate", "w_up", "w_down"],
    "rap": ["attn_norm", "wq_t", "a_k", "theta_sel", "a_v", "wo_t", "mlp_norm", "w_gate", "w_up", "w_down"],
}


def flatten_weights(spec: VariantSpec, weights: Dict) -> List[Tuple[str, np.ndarray]]:
    """Deterministic (name, array) list — the order rust reads them in and
    the order the AOT executables take them as leading parameters."""
    out = [("tok_emb", np.asarray(weights["tok_emb"]))]
    keys = _METHOD_KEYS[spec.method]
    for i, lw in enumerate(weights["layers"]):
        for k in keys:
            out.append((f"layers.{i}.{k}", np.asarray(lw[k])))
    out.append(("final_norm", np.asarray(weights["final_norm"])))
    return out


def unflatten_weights(spec: VariantSpec, n_layers: int, named: Dict[str, np.ndarray]) -> Dict:
    keys = _METHOD_KEYS[spec.method]
    return {
        "tok_emb": jnp.asarray(named["tok_emb"]),
        "layers": [
            {k: jnp.asarray(named[f"layers.{i}.{k}"]) for k in keys}
            for i in range(n_layers)
        ],
        "final_norm": jnp.asarray(named["final_norm"]),
    }
