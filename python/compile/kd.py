"""Knowledge-distillation recovery with LoRA (paper §4.4, Eq. 11–13).

The pruned student's attention weights are frozen; low-rank adapters are
trained on a combined CE + KL loss against the unpruned teacher
(alpha_CE=0.4, alpha_KD=0.6, T=2.0 — Table 15), then merged back into the
base weights so deployment carries zero adapter overhead (Alg. 1 line 11).

Adapters attach to the method's actual attention matrices: for RAP that is
the *absorbed* wq_t / a_k / a_v / wo_t, for PaLU wq / a_k / a_v / wo_t —
i.e. KD happens in the compressed geometry, exactly as a practitioner would
run it.
"""

from __future__ import annotations

import time
from typing import Dict, Iterable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import KDConfig, ModelConfig, VariantSpec, baseline_spec
from .model import cross_entropy, forward_full
from .train import adamw_init, adamw_update, clip_by_global_norm

# Which per-layer matrices receive adapters, by method.
LORA_TARGETS = {
    "rap": ["wq_t", "a_k", "a_v", "wo_t"],
    "palu": ["wq", "a_k", "a_v", "wo_t"],
    "svd": ["wq", "a_k", "a_v", "wo"],
    "baseline": ["wq", "wk", "wv", "wo"],
}


def lora_init(
    cfg: ModelConfig, spec: VariantSpec, weights: Dict, kcfg: KDConfig
) -> List[Dict]:
    """Per-layer {name: (down [din,r], up [r,dout])} adapters."""
    key = jax.random.PRNGKey(kcfg.seed)
    targets = LORA_TARGETS[spec.method]
    adapters = []
    for lw in weights["layers"]:
        layer_ad = {}
        for name in targets:
            w = lw[name]
            if w.ndim != 2:
                continue
            din, dout = w.shape
            key, sub = jax.random.split(key)
            down = (jax.random.normal(sub, (din, kcfg.lora_rank)) / np.sqrt(din)).astype(jnp.float32)
            up = jnp.zeros((kcfg.lora_rank, dout), jnp.float32)
            layer_ad[name] = {"down": down, "up": up}
        adapters.append(layer_ad)
    return adapters


def merge_lora(
    weights: Dict, adapters: List[Dict], scale: float
) -> Dict:
    """W' = W + scale * down @ up (Eq. 11), returning merged weights."""
    layers = []
    for lw, ad in zip(weights["layers"], adapters):
        new = dict(lw)
        for name, a in ad.items():
            new[name] = lw[name] + scale * (a["down"] @ a["up"])
        layers.append(new)
    return {**weights, "layers": layers}


def lora_param_fraction(adapters: List[Dict], weights: Dict) -> float:
    n_ad = sum(
        int(a["down"].size + a["up"].size)
        for layer in adapters
        for a in layer.values()
    )
    n_w = sum(int(np.asarray(x).size) for x in jax.tree_util.tree_leaves(weights))
    return n_ad / max(n_w, 1)


def kd_loss(
    cfg: ModelConfig,
    spec: VariantSpec,
    kcfg: KDConfig,
    student_logits: jnp.ndarray,
    teacher_logits: jnp.ndarray,
    targets: jnp.ndarray,
) -> jnp.ndarray:
    """alpha_CE * CE(student, y) + alpha_KD * T^2 * KL(teacher || student)."""
    ce = cross_entropy(student_logits, targets)
    t = kcfg.temperature
    p_t = jax.nn.softmax(teacher_logits / t, axis=-1)
    logp_s = jax.nn.log_softmax(student_logits / t, axis=-1)
    logp_t = jax.nn.log_softmax(teacher_logits / t, axis=-1)
    kl = jnp.sum(p_t * (logp_t - logp_s), axis=-1).mean() * (t * t)
    return kcfg.alpha_ce * ce + kcfg.alpha_kd * kl


def distill(
    cfg: ModelConfig,
    spec: VariantSpec,
    student: Dict,
    teacher: Dict,
    kcfg: KDConfig,
    batch_iter: Iterable[Tuple[np.ndarray, np.ndarray]],
    eval_fn=None,
    eval_every: int = 10,
) -> Tuple[Dict, List[Dict]]:
    """Run KD; returns (merged student weights, curve log).

    ``eval_fn(weights) -> ppl`` is called every ``eval_every`` steps to
    record the Fig. 15 recovery curve.
    """
    base_spec = baseline_spec(cfg)
    scale = kcfg.lora_alpha / kcfg.lora_rank
    adapters = lora_init(cfg, spec, student, kcfg)

    @jax.jit
    def teacher_fwd(x):
        return forward_full(cfg, base_spec, teacher, x)

    @jax.jit
    def step_fn(ad, opt, x, y, t_logits):
        def lf(ad_):
            merged = merge_lora(student, ad_, scale)
            s_logits = forward_full(cfg, spec, merged, x)
            return kd_loss(cfg, spec, kcfg, s_logits, t_logits, y)

        loss, grads = jax.value_and_grad(lf)(ad)
        grads, _ = clip_by_global_norm(grads, 1.0)
        ad, opt = adamw_update(ad, grads, opt, kcfg.lr, 0.0)
        return ad, opt, loss

    opt = adamw_init(adapters)
    log: List[Dict] = []
    t0 = time.time()
    for step, (x, y) in enumerate(batch_iter):
        xj, yj = jnp.asarray(x), jnp.asarray(y)
        t_logits = teacher_fwd(xj)
        adapters, opt, loss = step_fn(adapters, opt, xj, yj, t_logits)
        if step % eval_every == 0 or step == kcfg.steps - 1:
            entry = {"step": step, "loss": float(loss), "sec": time.time() - t0}
            if eval_fn is not None:
                entry["ppl"] = float(eval_fn(merge_lora(student, adapters, scale)))
            log.append(entry)
            print(
                f"[kd {cfg.name}/{spec.key}] step {step:3d} "
                f"loss {float(loss):.4f}"
                + (f" ppl {entry['ppl']:.3f}" if "ppl" in entry else ""),
                flush=True,
            )
    merged = merge_lora(student, adapters, scale)
    merged_frac = lora_param_fraction(adapters, student)
    log.append({"lora_param_fraction": merged_frac})
    return merged, log
