"""SVD-based factorization baselines (paper Eq. 1, §6.1 "SVD").

Naive per-head truncated SVD of W_k and W_v: W ≈ A B with
A = U_r Σ_r^{1/2}, B = Σ_r^{1/2} V_r^T.  No whitening, no adaptive budget,
no RoPE absorption — the cache stores X A (pre-RoPE) and **both** K and V
are reconstructed at attention time, exactly the configuration the paper
evaluates as "SVD".

Also provides the whitened variant used by PaLU and by RAP's hybrid V side:
truncate S^T W where C = X^T X = S S^T (Cholesky), which minimises
||X(W - Ŵ)||_F instead of ||W - Ŵ||_F.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def truncated_svd_per_head(
    w: np.ndarray, n_heads: int, rank: int
) -> Tuple[np.ndarray, np.ndarray]:
    """w: [D, H*dh] -> (A [D, H*rank], B [H, rank, dh])."""
    d, hd = w.shape
    dh = hd // n_heads
    a_heads, b_heads = [], []
    for h in range(n_heads):
        wh = w[:, h * dh : (h + 1) * dh].astype(np.float64)
        u, s, vt = np.linalg.svd(wh, full_matrices=False)
        sq = np.sqrt(s[:rank])
        a_heads.append(u[:, :rank] * sq[None, :])
        b_heads.append(sq[:, None] * vt[:rank])
    a = np.concatenate(a_heads, axis=1).astype(np.float32)  # [D, H*rank]
    b = np.stack(b_heads).astype(np.float32)  # [H, rank, dh]
    return a, b


def whitened_svd_per_head(
    w: np.ndarray, cov: np.ndarray, n_heads: int, rank: int, damp: float = 1e-4
) -> Tuple[np.ndarray, np.ndarray]:
    """Data-whitened truncated SVD (SVD-LLM / PaLU style).

    cov: [D, D] accumulated X^T X of the layer's (normed) inputs.
    Factor S^T W with C = S S^T; A = S^{-T} U_r Σ_r, B = V_r^T, so that
    X A B ≈ X W with error measured in the activation geometry.
    """
    d, hd = w.shape
    dh = hd // n_heads
    c = cov.astype(np.float64)
    # Damping keeps the Cholesky well-posed for near-singular activations.
    c = c + damp * np.trace(c) / d * np.eye(d)
    s_mat = np.linalg.cholesky(c)  # lower triangular, C = S S^T
    a_heads, b_heads = [], []
    for h in range(n_heads):
        wh = w[:, h * dh : (h + 1) * dh].astype(np.float64)
        wp = s_mat.T @ wh
        u, s, vt = np.linalg.svd(wp, full_matrices=False)
        ur = u[:, :rank] * s[:rank][None, :]
        # A = S^{-T} (U_r Σ_r): solve S^T A = U_r Σ_r.
        a_h = np.linalg.solve(s_mat.T, ur)
        a_heads.append(a_h)
        b_heads.append(vt[:rank])
    a = np.concatenate(a_heads, axis=1).astype(np.float32)
    b = np.stack(b_heads).astype(np.float32)
    return a, b


def build_svd_variant(cfg, weights, rank_k, rank_v, ratio: float, tag: str = ""):
    """Assemble the naive per-head truncated-SVD variant (§6.1 "SVD"):
    uniform ranks, no whitening, both K and V reconstructed at runtime."""
    from ..config import VariantSpec

    layers = []
    for lw in weights["layers"]:
        a_k, b_k = truncated_svd_per_head(
            np.asarray(lw["wk"]), cfg.n_kv_heads, rank_k
        )
        a_v, b_v = truncated_svd_per_head(
            np.asarray(lw["wv"]), cfg.n_kv_heads, rank_v
        )
        layers.append(
            {
                "attn_norm": lw["attn_norm"],
                "wq": lw["wq"],
                "a_k": a_k,
                "b_k": b_k,
                "a_v": a_v,
                "b_v": b_v,
                "wo": lw["wo"],
                "mlp_norm": lw["mlp_norm"],
                "w_gate": lw["w_gate"],
                "w_up": lw["w_up"],
                "w_down": lw["w_down"],
            }
        )
    spec = VariantSpec(
        method="svd",
        ratio=ratio,
        model=cfg.name,
        tag=tag,
        k_rank=[rank_k] * cfg.n_layers,
        v_rank=[rank_v] * cfg.n_layers,
    )
    return {
        "spec": spec,
        "weights": {
            "tok_emb": weights["tok_emb"],
            "layers": layers,
            "final_norm": weights["final_norm"],
        },
    }


def reconstruction_error(
    w: np.ndarray, a: np.ndarray, b: np.ndarray, n_heads: int
) -> float:
    """||W - A B||_F / ||W||_F, reassembling per-head blocks."""
    d, hd = w.shape
    dh = hd // n_heads
    rank = a.shape[1] // n_heads
    err = 0.0
    base = float(np.linalg.norm(w) ** 2)
    for h in range(n_heads):
        wh = w[:, h * dh : (h + 1) * dh]
        ah = a[:, h * rank : (h + 1) * rank]
        err += float(np.linalg.norm(wh - ah @ b[h]) ** 2)
    return float(np.sqrt(err / base))
