"""PaLU baseline: whitened per-head SVD + B_v absorption into W_o.

Per the paper's §6.1 configuration: on top of SVD, PaLU (i) whitens with the
calibration activation covariance and (ii) absorbs B_v into W_o so V is
served from its latent without reconstruction; K still carries its B_k and
is reconstructed (then RoPE'd) every attention call — the residual overhead
RAP removes.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from ..config import ModelConfig, VariantSpec
from .svd import whitened_svd_per_head


def absorb_bv_into_wo(
    cfg: ModelConfig, wo: np.ndarray, b_v: np.ndarray
) -> np.ndarray:
    """W_o: [H*dh, D]; b_v: [Hkv, rv, dh] -> W_o~: [H*rv, D].

    Query head h consumes KV head g(h) = h // group's latent V, so its W_o
    row block [dh, D] is left-multiplied by B_v[g(h)] (GQA-aware absorption).
    """
    d = wo.shape[1]
    dh = cfg.head_dim
    rv = b_v.shape[1]
    rows = []
    for h in range(cfg.n_heads):
        g = h // cfg.group_size
        rows.append(b_v[g] @ wo[h * dh : (h + 1) * dh, :])  # [rv, D]
    return np.concatenate(rows, axis=0).astype(np.float32)  # [H*rv, D]


def build_palu_variant(
    cfg: ModelConfig,
    weights: Dict,
    covs: List[np.ndarray],
    rank_k: List[int],
    rank_v: List[int],
    ratio: float,
    tag: str = "",
) -> Dict:
    """Assemble a PaLU variant's weights + spec.

    covs: per-layer activation covariance of the attention-norm output.
    rank_k/rank_v: per-layer retained ranks per KV head.
    """
    layers = []
    for li, lw in enumerate(weights["layers"]):
        wk = np.asarray(lw["wk"])
        wv = np.asarray(lw["wv"])
        a_k, b_k = whitened_svd_per_head(wk, covs[li], cfg.n_kv_heads, rank_k[li])
        a_v, b_v = whitened_svd_per_head(wv, covs[li], cfg.n_kv_heads, rank_v[li])
        wo_t = absorb_bv_into_wo(cfg, np.asarray(lw["wo"]), b_v)
        layers.append(
            {
                "attn_norm": lw["attn_norm"],
                "wq": lw["wq"],
                "a_k": a_k,
                "b_k": b_k,
                "a_v": a_v,
                "wo_t": wo_t,
                "mlp_norm": lw["mlp_norm"],
                "w_gate": lw["w_gate"],
                "w_up": lw["w_up"],
                "w_down": lw["w_down"],
            }
        )
    spec = VariantSpec(
        method="palu",
        ratio=ratio,
        model=cfg.name,
        tag=tag,
        k_rank=list(map(int, rank_k)),
        v_rank=list(map(int, rank_v)),
    )
    return {
        "spec": spec,
        "weights": {
            "tok_emb": weights["tok_emb"],
            "layers": layers,
            "final_norm": weights["final_norm"],
        },
    }
