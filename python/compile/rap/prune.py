"""RAP construction (paper §4.3, Algorithm 1 lines 4–9).

Given pair scores and per-layer budgets:

1. select the top-m RoPE pairs per KV head (head-uniform m),
2. gather the retained columns of W_k into A_k (canonical half layout:
   first components then second components, ascending original pair index),
3. build the binary expansion B_k implicitly as an index map (Eq. 8) and
   absorb B_k^T into W_q (Eq. 9–10) — the gather of W_q's columns,
4. precompute the per-head theta_sel table for the index-aware RoPE kernel,
5. compress the V side with whitened SVD and absorb B_v into W_o (the
   paper's default hybrid pipeline, §4.5).

``build_rap_variant`` also supports uniform budgets and magnitude scores for
the Fig.-13 ablation arms, and single-layer pruning for Fig. 4.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from ..config import ModelConfig, VariantSpec, rope_pairs
from .palu import absorb_bv_into_wo
from .svd import whitened_svd_per_head


def select_pairs(scores: np.ndarray, m: int) -> np.ndarray:
    """Top-m pair indices per head, ascending order.  scores: [Hkv, P]."""
    hkv, p = scores.shape
    sel = np.argsort(-scores, axis=1, kind="stable")[:, :m]
    return np.sort(sel, axis=1)


def gather_pair_columns(
    cfg: ModelConfig, w: np.ndarray, n_heads: int, pair_idx: np.ndarray
) -> np.ndarray:
    """Gather retained RoPE-pair columns into canonical half layout.

    w: [D, H*dh]; pair_idx: [H, m] -> [D, H*2m].  For head h the output
    block is [cols of first pair components | cols of second components].
    """
    pairs = rope_pairs(cfg)
    d = w.shape[0]
    dh = cfg.head_dim
    m = pair_idx.shape[1]
    out = np.empty((d, n_heads * 2 * m), dtype=w.dtype)
    for h in range(n_heads):
        wh = w[:, h * dh : (h + 1) * dh]
        first = [pairs[j][0] for j in pair_idx[h]]
        second = [pairs[j][1] for j in pair_idx[h]]
        out[:, h * 2 * m : h * 2 * m + m] = wh[:, first]
        out[:, h * 2 * m + m : (h + 1) * 2 * m] = wh[:, second]
    return out


def absorb_bk_into_wq(
    cfg: ModelConfig, wq: np.ndarray, pair_idx: np.ndarray
) -> np.ndarray:
    """W_q B_k^T (Eq. 10): since B is a binary expansion (Eq. 8), absorption
    is the gather of W_q's columns at the *KV group's* retained pairs.

    wq: [D, H*dh]; pair_idx: [Hkv, m] -> [D, H*2m].
    """
    q_idx = np.repeat(pair_idx, cfg.group_size, axis=0)  # [H, m]
    return gather_pair_columns(cfg, wq, cfg.n_heads, q_idx)


def theta_sel_table(cfg: ModelConfig, pair_idx: np.ndarray) -> np.ndarray:
    """Per-head retained-pair frequencies [Hkv, m] — the VMEM table of the
    index-aware kernel (DESIGN.md §Hardware-Adaptation)."""
    p = cfg.n_pairs
    j = np.arange(p, dtype=np.float64)
    th = cfg.rope_theta ** (-2.0 * j / cfg.head_dim)
    return th[pair_idx].astype(np.float32)


def expansion_matrix(cfg: ModelConfig, pair_idx_h: np.ndarray) -> np.ndarray:
    """The explicit binary B of Eq. 8 for one head, [2m, dh].  Only used by
    tests (commutativity / reconstruction identities); the runtime never
    materialises it — that is the point of the absorption."""
    pairs = rope_pairs(cfg)
    m = len(pair_idx_h)
    b = np.zeros((2 * m, cfg.head_dim), np.float32)
    for i, j in enumerate(pair_idx_h):
        b[i, pairs[j][0]] = 1.0
        b[m + i, pairs[j][1]] = 1.0
    return b


def build_rap_variant(
    cfg: ModelConfig,
    weights: Dict,
    scores: List[Dict[str, np.ndarray]],
    covs: List[np.ndarray],
    m_pairs: List[int],
    v_ranks: List[int],
    ratio: float,
    tag: str = "",
) -> Dict:
    """Assemble a RAP variant (hybrid: RAP-K + whitened-SVD-V, §4.5)."""
    layers = []
    k_pairs_all = []
    for li, lw in enumerate(weights["layers"]):
        pair_idx = select_pairs(scores[li]["k_pairs"], m_pairs[li])  # [Hkv, m]
        k_pairs_all.append(pair_idx.tolist())
        a_k = gather_pair_columns(
            cfg, np.asarray(lw["wk"]), cfg.n_kv_heads, pair_idx
        )
        wq_t = absorb_bk_into_wq(cfg, np.asarray(lw["wq"]), pair_idx)
        a_v, b_v = whitened_svd_per_head(
            np.asarray(lw["wv"]), covs[li], cfg.n_kv_heads, v_ranks[li]
        )
        wo_t = absorb_bv_into_wo(cfg, np.asarray(lw["wo"]), b_v)
        layers.append(
            {
                "attn_norm": lw["attn_norm"],
                "wq_t": wq_t,
                "a_k": a_k,
                "theta_sel": theta_sel_table(cfg, pair_idx),
                "a_v": a_v,
                "wo_t": wo_t,
                "mlp_norm": lw["mlp_norm"],
                "w_gate": lw["w_gate"],
                "w_up": lw["w_up"],
                "w_down": lw["w_down"],
            }
        )
    spec = VariantSpec(
        method="rap",
        ratio=ratio,
        model=cfg.name,
        tag=tag,
        k_rank=[2 * m for m in m_pairs],
        v_rank=list(map(int, v_ranks)),
        k_pairs=k_pairs_all,
    )
    return {
        "spec": spec,
        "weights": {
            "tok_emb": weights["tok_emb"],
            "layers": layers,
            "final_norm": weights["final_norm"],
        },
    }


def build_single_layer_variant(
    cfg: ModelConfig,
    weights: Dict,
    scores: List[Dict[str, np.ndarray]],
    covs: List[np.ndarray],
    layer: int,
    rho: float,
) -> Dict:
    """Fig. 4: prune only ``layer`` at ratio rho, leave the rest untouched.

    Implemented as a RAP variant whose other layers keep all pairs/full
    V-rank (a full-width whitened SVD is exact up to float error)."""
    m = [cfg.n_pairs] * cfg.n_layers
    rv = [cfg.head_dim] * cfg.n_layers
    m[layer] = max(1, int(round((1.0 - rho) * cfg.n_pairs)))
    rv[layer] = max(1, int(round((1.0 - rho) * cfg.head_dim)))
    return build_rap_variant(
        cfg, weights, scores, covs, m, rv, rho, tag=f"layer{layer}"
    )
