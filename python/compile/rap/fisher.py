"""Fisher-information scoring of RoPE pairs and V columns (paper Eq. 6–7).

F(W) = E[(dL/dW)^2] accumulated over a small calibration set; the score of a
RoPE pair (j, j') of a K projection is the sum of the squared-gradient mass
of both columns (Eq. 7).  V projections have no pair structure; their
per-column scores feed the V side of the adaptive budget and the whitened-SVD
rank split.

Shapes: for each layer we return
  k_pair_scores [Hkv, P]   (P = head_dim / 2 RoPE pairs)
  v_col_scores  [Hkv, D_h]
"""

from __future__ import annotations

from typing import Dict, Iterable, List

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig, baseline_spec, rope_pairs
from ..model import loss_fn


def _zeros_like_kv(cfg: ModelConfig):
    return [
        {
            "wk": np.zeros((cfg.d_model, cfg.kv_dim), np.float64),
            "wv": np.zeros((cfg.d_model, cfg.kv_dim), np.float64),
        }
        for _ in range(cfg.n_layers)
    ]


def accumulate_fisher(
    cfg: ModelConfig,
    weights: Dict,
    calib_batches: Iterable,
) -> List[Dict[str, np.ndarray]]:
    """Accumulate squared gradients of the K/V projections over calibration
    batches.  Returns per-layer {"wk": [D, Hkv*dh], "wv": ...} float64."""
    spec = baseline_spec(cfg)

    def kv_loss(kv_params, weights, x, y):
        w = dict(weights)
        w["layers"] = [
            {**lw, "wk": kvp["wk"], "wv": kvp["wv"]}
            for lw, kvp in zip(weights["layers"], kv_params)
        ]
        return loss_fn(cfg, spec, w, x, y)

    grad_fn = jax.jit(jax.grad(kv_loss))
    acc = _zeros_like_kv(cfg)
    n = 0
    for x, y in calib_batches:
        kv_params = [
            {"wk": lw["wk"], "wv": lw["wv"]} for lw in weights["layers"]
        ]
        g = grad_fn(kv_params, weights, jnp.asarray(x), jnp.asarray(y))
        for layer in range(cfg.n_layers):
            acc[layer]["wk"] += np.square(np.asarray(g[layer]["wk"], np.float64))
            acc[layer]["wv"] += np.square(np.asarray(g[layer]["wv"], np.float64))
        n += 1
    for layer in range(cfg.n_layers):
        acc[layer]["wk"] /= max(n, 1)
        acc[layer]["wv"] /= max(n, 1)
    return acc


def _per_head(cfg: ModelConfig, mat: np.ndarray) -> np.ndarray:
    """[D, Hkv*dh] -> [Hkv, D, dh]."""
    d = cfg.d_model
    return mat.reshape(d, cfg.n_kv_heads, cfg.head_dim).transpose(1, 0, 2)


def pair_scores_from_fisher(
    cfg: ModelConfig, fisher: List[Dict[str, np.ndarray]]
) -> List[Dict[str, np.ndarray]]:
    """Aggregate Fisher mass into pair scores (K) and column scores (V)."""
    pairs = rope_pairs(cfg)
    out = []
    for layer in range(cfg.n_layers):
        fk = _per_head(cfg, fisher[layer]["wk"])  # [Hkv, D, dh]
        fv = _per_head(cfg, fisher[layer]["wv"])
        col_k = fk.sum(axis=1)  # [Hkv, dh]
        k_pair = np.stack(
            [col_k[:, j] + col_k[:, jp] for (j, jp) in pairs], axis=1
        )  # [Hkv, P]
        out.append({"k_pairs": k_pair, "v_cols": fv.sum(axis=1)})
    return out


def magnitude_scores(
    cfg: ModelConfig, weights: Dict
) -> List[Dict[str, np.ndarray]]:
    """The Fig.-13 "Magnitude" ablation: squared-L2 column mass of W itself
    instead of its squared gradient."""
    pairs = rope_pairs(cfg)
    out = []
    for lw in weights["layers"]:
        wk = _per_head(cfg, np.asarray(lw["wk"], np.float64) ** 2)
        wv = _per_head(cfg, np.asarray(lw["wv"], np.float64) ** 2)
        col_k = wk.sum(axis=1)
        k_pair = np.stack(
            [col_k[:, j] + col_k[:, jp] for (j, jp) in pairs], axis=1
        )
        out.append({"k_pairs": k_pair, "v_cols": wv.sum(axis=1)})
    return out


def scores_to_json(scores: List[Dict[str, np.ndarray]]) -> list:
    return [
        {"k_pairs": s["k_pairs"].tolist(), "v_cols": s["v_cols"].tolist()}
        for s in scores
    ]
