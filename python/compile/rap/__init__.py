"""The offline RAP pipeline (paper §4, Algorithms 1 & 2).

``fisher``  — Fisher-information pair/column scoring (Eq. 6–7) + the
              magnitude-scoring ablation.
``budget``  — adaptive budget allocation across (layer, K/V) groups (Alg. 2).
``prune``   — RoPE-pair selection, A/B construction (Eq. 8), absorption of
              B_k into W_q (Eq. 9–10); assembles full RAP variants.
``svd``     — per-head truncated SVD baseline (Eq. 1).
``palu``    — whitened SVD with B_v absorbed into W_o.
"""

from . import budget, fisher, palu, prune, svd  # noqa: F401
