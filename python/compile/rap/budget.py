"""Adaptive budget allocation (paper Algorithm 2).

Groups are (layer, K) and (layer, V) — 2L groups for an L-layer model (the
paper's "64 groups for a 32-layer model").  Each group's raw compression
ratio is anti-proportional to its aggregated Fisher mass, normalised so the
mean equals the global target rho, then clamped to [0, 1] and re-projected
onto mean rho by iterative water-filling.  Within a group the retained
dimension is uniform across heads (efficient batched GEMM — §4.2 point 2).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from ..config import ModelConfig


def allocate(
    scores: List[Dict[str, np.ndarray]],
    rho: float,
    max_iter: int = 100,
) -> Tuple[np.ndarray, np.ndarray]:
    """Run Algorithm 2.  Returns (rho_k [L], rho_v [L]) group ratios."""
    sig = []
    for s in scores:
        sig.append(float(np.sum(s["k_pairs"])))
        sig.append(float(np.sum(s["v_cols"])))
    sig = np.asarray(sig, np.float64)
    n = len(sig)
    sc = sig.sum()
    if sc <= 0 or n <= 1:
        flat = np.full(n, rho)
    else:
        # Alg. 2 line 6: rho_i = rho * (1 - sigma_i/SC) / (1 - 1/N)
        flat = rho * (1.0 - sig / sc) / (1.0 - 1.0 / n)
    flat = np.clip(flat, 0.0, 1.0)
    flat = project_mean(flat, rho, max_iter=max_iter)
    rho_k = flat[0::2]
    rho_v = flat[1::2]
    return rho_k, rho_v


def project_mean(x: np.ndarray, target_mean: float, max_iter: int = 100) -> np.ndarray:
    """Project x onto {y in [0,1]^n : mean(y) = target_mean} (Alg. 2 line 9).

    Water-filling: repeatedly shift the unclamped coordinates by the residual
    and re-clip.  Converges in O(n) iterations; exact when feasible
    (0 <= target_mean <= 1)."""
    target_mean = float(np.clip(target_mean, 0.0, 1.0))
    y = np.clip(np.asarray(x, np.float64), 0.0, 1.0)
    for _ in range(max_iter):
        resid = target_mean - y.mean()
        if abs(resid) < 1e-12:
            break
        if resid > 0:
            free = y < 1.0
        else:
            free = y > 0.0
        if not free.any():
            break
        y[free] = y[free] + resid * len(y) / free.sum()
        y = np.clip(y, 0.0, 1.0)
    return y


def ranks_from_ratios(
    cfg: ModelConfig, rho_k: np.ndarray, rho_v: np.ndarray
) -> Tuple[List[int], List[int]]:
    """Integerise group ratios into per-layer retained widths.

    K: m_l retained *pairs* (latent width 2 m_l), at least one pair.
    V: retained rank r_l, at least 1.
    After rounding, greedily nudge the least-off layers so the global
    achieved KV ratio matches the target as closely as integer widths allow.
    """
    p = cfg.n_pairs
    dh = cfg.head_dim
    m = [max(1, int(round((1.0 - r) * p))) for r in rho_k]
    rv = [max(1, int(round((1.0 - r) * dh))) for r in rho_v]

    target_keep = (1.0 - (np.concatenate([rho_k, rho_v]).mean())) * (
        2 * dh * cfg.n_layers
    )

    def total(mm, rr):
        return sum(2 * x for x in mm) + sum(rr)

    # Greedy adjustment: move the width whose fractional error is largest.
    for _ in range(4 * cfg.n_layers):
        t = total(m, rv)
        diff = target_keep - t
        if abs(diff) < 1.0:
            break
        if diff > 0:
            # add capacity where rounding cut the most
            cand = [
                ("k", i, (1.0 - rho_k[i]) * p - m[i])
                for i in range(cfg.n_layers)
                if m[i] < p
            ] + [
                ("v", i, (1.0 - rho_v[i]) * dh - rv[i])
                for i in range(cfg.n_layers)
                if rv[i] < dh
            ]
            if not cand:
                break
            kind, i, _ = max(cand, key=lambda c: c[2])
            if kind == "k":
                m[i] += 1
            else:
                rv[i] += 1
        else:
            cand = [
                ("k", i, m[i] - (1.0 - rho_k[i]) * p)
                for i in range(cfg.n_layers)
                if m[i] > 1
            ] + [
                ("v", i, rv[i] - (1.0 - rho_v[i]) * dh)
                for i in range(cfg.n_layers)
                if rv[i] > 1
            ]
            if not cand:
                break
            kind, i, _ = max(cand, key=lambda c: c[2])
            if kind == "k":
                m[i] -= 1
            else:
                rv[i] -= 1
    return m, rv


def uniform_ranks(cfg: ModelConfig, rho: float) -> Tuple[List[int], List[int]]:
    """The "Uniform" ablation arm (Fig. 13): same ratio everywhere."""
    m = max(1, int(round((1.0 - rho) * cfg.n_pairs)))
    rv = max(1, int(round((1.0 - rho) * cfg.head_dim)))
    return [m] * cfg.n_layers, [rv] * cfg.n_layers


def achieved_kv_ratio(cfg: ModelConfig, m: List[int], rv: List[int]) -> float:
    """Fraction of baseline KV-cache retained by widths (m, rv)."""
    kept = sum(2 * x for x in m) + sum(rv)
    return kept / (2.0 * cfg.head_dim * cfg.n_layers)
