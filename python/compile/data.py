"""Synthetic Markov-Zipf byte corpus — the WikiText-2 stand-in.

The paper calibrates Fisher scores and runs KD on a few thousand WikiText-2
tokens and reports WikiText-2 perplexity.  We have no network access, so we
generate a *structured* corpus with the statistics that make those
measurements meaningful:

- a Zipf-distributed vocabulary of pseudo-words (so frequent vs. rare-token
  behaviour diverges, which the probe tasks measure),
- a first-order Markov chain over words (so context actually lowers PPL),
- sentence and paragraph structure with punctuation,
- named "entities" that repeat far apart (long-range recall signal for the
  LongBench-analog tasks).

Byte-level tokenisation (vocab 256) keeps the tokenizer trivial and
identical between python and rust.
"""

from __future__ import annotations

import numpy as np

CORPUS_SEED = 42
N_WORDS = 800
N_ENTITIES = 24
ALPHA = 1.2  # Zipf exponent


def _make_words(rng: np.random.Generator, n: int) -> list:
    letters = "abcdefghijklmnopqrstuvwxyz"
    words = set()
    while len(words) < n:
        ln = int(rng.integers(2, 9))
        words.add("".join(letters[i] for i in rng.integers(0, 26, ln)))
    return sorted(words)


def _make_entities(rng: np.random.Generator, n: int) -> list:
    consonants = "bcdfghjklmnprstvwz"
    vowels = "aeiou"
    out = []
    for _ in range(n):
        syll = int(rng.integers(2, 4))
        name = ""
        for _ in range(syll):
            name += consonants[rng.integers(0, len(consonants))]
            name += vowels[rng.integers(0, len(vowels))]
        out.append(name.capitalize())
    return out


def generate_corpus(n_bytes: int = 1 << 21, seed: int = CORPUS_SEED) -> bytes:
    """Deterministically generate ``n_bytes`` of structured text."""
    rng = np.random.default_rng(seed)
    words = _make_words(rng, N_WORDS)
    entities = _make_entities(rng, N_ENTITIES)

    # Zipf unigram distribution over words.
    ranks = np.arange(1, N_WORDS + 1, dtype=np.float64)
    unigram = ranks ** (-ALPHA)
    unigram /= unigram.sum()

    # Sparse first-order Markov chain: each word prefers ~12 successors.
    n_succ = 12
    succ = rng.integers(0, N_WORDS, size=(N_WORDS, n_succ))
    succ_w = rng.dirichlet(np.ones(n_succ) * 0.6, size=N_WORDS)

    out = bytearray()
    w = int(rng.choice(N_WORDS, p=unigram))
    sent_len = 0
    para_len = 0
    entity = entities[int(rng.integers(0, N_ENTITIES))]
    while len(out) < n_bytes:
        # 4% of tokens are the current paragraph's entity (long-range repeat).
        if rng.random() < 0.04:
            token = entity
        else:
            if rng.random() < 0.75:
                w = int(succ[w, rng.choice(n_succ, p=succ_w[w])])
            else:
                w = int(rng.choice(N_WORDS, p=unigram))
            token = words[w]
        out += token.encode()
        sent_len += 1
        if sent_len >= int(rng.integers(6, 16)):
            out += b". " if rng.random() < 0.8 else b"? "
            sent_len = 0
            para_len += 1
            if para_len >= int(rng.integers(4, 9)):
                out += b"\n\n"
                para_len = 0
                entity = entities[int(rng.integers(0, N_ENTITIES))]
        else:
            out += b" "
    return bytes(out[:n_bytes])


def train_eval_split(corpus: bytes, eval_frac: float = 0.1):
    cut = int(len(corpus) * (1.0 - eval_frac))
    return corpus[:cut], corpus[cut:]


def batches(data: bytes, batch: int, seq: int, steps: int, seed: int):
    """Yield (inputs, targets) uint8 arrays of shape [batch, seq]."""
    arr = np.frombuffer(data, dtype=np.uint8)
    rng = np.random.default_rng(seed)
    hi = len(arr) - seq - 1
    for _ in range(steps):
        idx = rng.integers(0, hi, size=batch)
        x = np.stack([arr[i : i + seq] for i in idx])
        y = np.stack([arr[i + 1 : i + seq + 1] for i in idx])
        yield x.astype(np.int32), y.astype(np.int32)


def eval_windows(data: bytes, seq: int, max_windows: int = 64):
    """Contiguous non-overlapping eval windows for PPL."""
    arr = np.frombuffer(data, dtype=np.uint8)
    n = min(max_windows, (len(arr) - 1) // seq)
    xs = np.stack([arr[i * seq : i * seq + seq] for i in range(n)])
    ys = np.stack([arr[i * seq + 1 : i * seq + seq + 1] for i in range(n)])
    return xs.astype(np.int32), ys.astype(np.int32)
