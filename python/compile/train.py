"""Tiny-model pre-training (build-time only).

Hand-rolled AdamW + cosine schedule (no optax in this environment).  The
trained baseline checkpoint is the "teacher" for KD and the substrate every
compression method operates on; its quality determines whether Fisher
scores, layer sensitivity (Fig. 4) and KD recovery carry real signal.
"""

from __future__ import annotations

import time
from typing import Dict, Iterable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, TrainConfig, baseline_spec
from .model import loss_fn


def adamw_init(params) -> Dict:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": zeros, "t": 0}


def adamw_update(params, grads, state, lr, wd, b1=0.9, b2=0.95, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads
    )
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads
    )
    mhat_scale = 1.0 / (1.0 - b1**t)
    vhat_scale = 1.0 / (1.0 - b2**t)

    def upd(p, m_, v_):
        step = lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps)
        return p - step - lr * wd * p

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def cosine_lr(step: int, cfg: TrainConfig) -> float:
    if step < cfg.warmup:
        return cfg.lr * (step + 1) / cfg.warmup
    p = (step - cfg.warmup) / max(1, cfg.steps - cfg.warmup)
    return cfg.lr * 0.5 * (1.0 + np.cos(np.pi * p))


def train(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    weights: Dict,
    batch_iter: Iterable[Tuple[np.ndarray, np.ndarray]],
    log_every: int = 25,
) -> Tuple[Dict, list]:
    """Train in place; returns (weights, loss_log)."""
    spec = baseline_spec(cfg)

    @jax.jit
    def step_fn(params, opt, x, y, lr):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, spec, p, x, y)
        )(params)
        grads, gn = clip_by_global_norm(grads, tcfg.grad_clip)
        params, opt = adamw_update(params, grads, opt, lr, tcfg.weight_decay)
        return params, opt, loss, gn

    opt = adamw_init(weights)
    log = []
    t0 = time.time()
    for step, (x, y) in enumerate(batch_iter):
        lr = cosine_lr(step, tcfg)
        weights, opt, loss, gn = step_fn(
            weights, opt, jnp.asarray(x), jnp.asarray(y), jnp.float32(lr)
        )
        if step % log_every == 0 or step == tcfg.steps - 1:
            loss_f = float(loss)
            log.append({"step": step, "loss": loss_f, "lr": lr})
            print(
                f"[train {cfg.name}] step {step:4d} loss {loss_f:.4f} "
                f"lr {lr:.2e} ({time.time() - t0:.1f}s)",
                flush=True,
            )
    return weights, log


def eval_ppl(
    cfg: ModelConfig, spec, weights: Dict, xs: np.ndarray, ys: np.ndarray, batch: int = 8
) -> float:
    """Perplexity over contiguous eval windows (matches rust eval::ppl)."""
    total, count = 0.0, 0

    @jax.jit
    def nll(w, x, y):
        return loss_fn(cfg, spec, w, x, y)

    for i in range(0, len(xs), batch):
        x = jnp.asarray(xs[i : i + batch])
        y = jnp.asarray(ys[i : i + batch])
        total += float(nll(weights, x, y)) * x.shape[0] * x.shape[1]
        count += x.shape[0] * x.shape[1]
    return float(np.exp(total / max(count, 1)))
