"""Model and pipeline configuration for the RAP reproduction.

Two tiny decoder-only models stand in for LLaMA-3-8B and Mistral-7B (see
DESIGN.md §Substitutions): ``tinyllama`` (GQA 8q/4kv, "half" RoPE pairing,
LLaMA-style) and ``tinymistral`` (GQA 8q/2kv, interleaved pairing,
different width/MLP ratio).  All of RAP's structural machinery — RoPE-pair
grouping, Fisher scoring, adaptive budgets, B-absorption — is
dimension-generic, so a trained tiny model exercises every code path the
paper's 7–8B models do while remaining tractable on one CPU core.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

PAIRING_HALF = "half"  # (j, j + D/2)  — LLaMA/HF style
PAIRING_INTERLEAVED = "interleaved"  # (2j, 2j+1) — original RoFormer style

METHODS = ("baseline", "svd", "palu", "rap")

# Compression ratios evaluated in the paper (rho = 1 - r).
RATIOS = (0.10, 0.20, 0.30, 0.40, 0.50)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters of a decoder-only transformer."""

    name: str
    vocab: int = 256  # byte-level
    d_model: int = 192
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 4
    head_dim: int = 24
    mlp_hidden: int = 512
    max_seq: int = 640
    rope_theta: float = 10000.0
    pairing: str = PAIRING_HALF
    norm_eps: float = 1e-5

    @property
    def n_pairs(self) -> int:
        assert self.head_dim % 2 == 0, "RoPE requires an even head dim"
        return self.head_dim // 2

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def group_size(self) -> int:
        """Query heads per KV head (GQA group size)."""
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        d, m = self.d_model, self.mlp_hidden
        per_layer = (
            d * self.q_dim  # wq
            + 2 * d * self.kv_dim  # wk, wv
            + self.q_dim * d  # wo
            + 3 * d * m  # gate, up, down
            + 2 * d  # norms
        )
        return self.vocab * d + self.n_layers * per_layer + d

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


@dataclass(frozen=True)
class TrainConfig:
    """Pre-training configuration for the tiny models."""

    steps: int = 400
    batch: int = 8
    seq: int = 192
    lr: float = 3e-3
    warmup: int = 40
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    seed: int = 42


@dataclass(frozen=True)
class KDConfig:
    """Knowledge-distillation recovery (paper §4.4, Table 15)."""

    steps: int = 60
    batch: int = 8
    seq: int = 192
    lr: float = 1e-4
    lora_rank: int = 8
    lora_alpha: float = 16.0
    alpha_ce: float = 0.4
    alpha_kd: float = 0.6
    temperature: float = 2.0
    seed: int = 42


@dataclass(frozen=True)
class FisherConfig:
    """Fisher-information calibration (paper §6.1: N=32 windows of L=2048;
    scaled to the tiny corpus: 32 windows of 192 tokens)."""

    windows: int = 32
    seq: int = 192
    batch: int = 8
    seed: int = 42


TINYLLAMA = ModelConfig(
    name="tinyllama",
    d_model=192,
    n_layers=4,
    n_heads=8,
    n_kv_heads=4,
    head_dim=24,
    mlp_hidden=512,
    pairing=PAIRING_HALF,
)

TINYMISTRAL = ModelConfig(
    name="tinymistral",
    d_model=160,
    n_layers=4,
    n_heads=8,
    n_kv_heads=2,
    head_dim=20,
    mlp_hidden=448,
    pairing=PAIRING_INTERLEAVED,
)

MODELS: Dict[str, ModelConfig] = {m.name: m for m in (TINYLLAMA, TINYMISTRAL)}


def rope_pairs(cfg: ModelConfig) -> List[tuple]:
    """Column-index pairs (j, j') rotated together, per pairing strategy."""
    p = cfg.n_pairs
    if cfg.pairing == PAIRING_HALF:
        return [(j, j + p) for j in range(p)]
    if cfg.pairing == PAIRING_INTERLEAVED:
        return [(2 * j, 2 * j + 1) for j in range(p)]
    raise ValueError(f"unknown pairing strategy {cfg.pairing!r}")


@dataclass
class VariantSpec:
    """A compressed model variant: method + per-layer latent widths.

    ``k_rank[l]``: latent K width per kv head at layer l (2m for RAP — the
    retained pairs are stored pre-expanded — or the SVD rank for
    SVD/PaLU).  ``v_rank[l]``: latent V width per kv head.
    ``k_pairs[l]``: for RAP, retained pair indices per kv head,
    shape [n_kv_heads, m]; empty for other methods.
    """

    method: str
    ratio: float
    model: str
    tag: str = ""  # distinguishes ablation variants, e.g. "MU", "noKD"
    k_rank: List[int] = field(default_factory=list)
    v_rank: List[int] = field(default_factory=list)
    k_pairs: List[List[List[int]]] = field(default_factory=list)

    @property
    def key(self) -> str:
        base = f"{self.method}_r{int(round(self.ratio * 100)):02d}"
        return f"{base}_{self.tag}" if self.tag else base

    def to_json(self) -> Dict:
        return {
            "method": self.method,
            "ratio": self.ratio,
            "model": self.model,
            "tag": self.tag,
            "key": self.key,
            "k_rank": self.k_rank,
            "v_rank": self.v_rank,
            "k_pairs": self.k_pairs,
        }

    @staticmethod
    def from_json(d: Dict) -> "VariantSpec":
        return VariantSpec(
            method=d["method"],
            ratio=d["ratio"],
            model=d["model"],
            tag=d.get("tag", ""),
            k_rank=d["k_rank"],
            v_rank=d["v_rank"],
            k_pairs=d.get("k_pairs", []),
        )


def baseline_spec(cfg: ModelConfig) -> VariantSpec:
    return VariantSpec(
        method="baseline",
        ratio=0.0,
        model=cfg.name,
        k_rank=[cfg.head_dim] * cfg.n_layers,
        v_rank=[cfg.head_dim] * cfg.n_layers,
        k_pairs=[
            [list(range(cfg.n_pairs)) for _ in range(cfg.n_kv_heads)]
            for _ in range(cfg.n_layers)
        ],
    )


def dump_json(path, obj) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, sort_keys=True)
