"""AOT pipeline: train → score → prune → distill → export (make artifacts).

Python runs ONCE here and never on the request path.  Outputs under
``artifacts/``:

  corpus.bin                      — synthetic corpus (eval split is the tail)
  manifest.json                   — configs, variant specs, tensor index,
                                    HLO signatures, python-side PPL log
  weights/<model>/<variant>.bin   — flat little-endian f32 tensors
  hlo/<model>/<variant>_{prefill<S>,decode_b<B>}.hlo.txt
  hlo/ropebench/*.hlo.txt         — Fig. 16 kernel microbench graphs
  logs/*.json                     — train/KD curves (Fig. 14/15, Table 5)
  cache/*.npz                     — stage caches (idempotent re-runs)

Interchange is HLO **text** (xla_extension 0.5.1 rejects jax>=0.5's 64-bit
instruction-id protos; the text parser reassigns ids — see
/opt/xla-example/README.md).

Kernel policy for the serving graphs: baseline and RAP lower the L1 Pallas
RoPE kernels (interpret=True) into their HLO — RoPE is the paper's kernel
contribution (§4.5) — while attention itself uses the jnp path for all four
methods so the latency comparison isolates exactly what the paper varies
(latent widths and reconstruction matmuls).  A dedicated ``pallas_full``
decode artifact additionally runs the fused Pallas decode-attention kernel
end-to-end to prove the whole L1→L2→L3 path composes.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import data as data_mod
from compile.config import (
    FisherConfig,
    KDConfig,
    MODELS,
    ModelConfig,
    RATIOS,
    TrainConfig,
    VariantSpec,
    baseline_spec,
)
from compile.kd import distill
from compile.model import (
    decode_step,
    flatten_weights,
    forward_full,
    init_weights,
    prefill_with_cache,
    unflatten_weights,
)
from compile.rap import budget as budget_mod
from compile.rap import fisher as fisher_mod
from compile.rap.palu import build_palu_variant
from compile.rap.prune import build_rap_variant, build_single_layer_variant
from compile.rap.svd import build_svd_variant
from compile.train import eval_ppl, train

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))), "artifacts")

# Serving-graph export matrix (per DESIGN.md: the rust engine covers the
# dense ratio sweeps; PJRT covers the serving path).
PREFILL_BUCKETS = (32, 128)
DECODE_BATCHES = (1, 4)
S_MAX = 384
HLO_RATIOS = {"tinyllama": (0.10, 0.30, 0.50), "tinymistral": (0.30,)}
KD_MODELS = ("tinyllama", "tinymistral")


def _ensure_dirs():
    for d in ("", "weights", "hlo", "hlo/ropebench", "logs", "cache",
              "weights/tinyllama", "weights/tinymistral",
              "hlo/tinyllama", "hlo/tinymistral"):
        os.makedirs(os.path.join(ART, d), exist_ok=True)


# ---------------------------------------------------------------- caching

def _cache_path(name: str) -> str:
    return os.path.join(ART, "cache", name)


def save_tree(path: str, spec: VariantSpec, weights: Dict):
    flat = flatten_weights(spec, weights)
    np.savez(path, **{n: a for n, a in flat})


def load_tree(path: str, spec: VariantSpec, n_layers: int) -> Dict:
    z = np.load(path)
    return unflatten_weights(spec, n_layers, {k: z[k] for k in z.files})


# ------------------------------------------------------------- HLO export

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_prefill(cfg, spec, weights, s, batch, use_pallas, out_path) -> Dict:
    flat = flatten_weights(spec, weights)
    names = [n for n, _ in flat]
    arrs = [a for _, a in flat]
    nw = len(arrs)

    def fn(*args):
        ws = unflatten_weights(spec, cfg.n_layers, dict(zip(names, args[:nw])))
        logits, kc, vc = prefill_with_cache(cfg, spec, ws, args[nw], S_MAX, use_pallas)
        return (logits, *kc, *vc)

    in_specs = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for a in arrs]
    in_specs.append(jax.ShapeDtypeStruct((batch, s), jnp.int32))
    text = to_hlo_text(jax.jit(fn).lower(*in_specs))
    with open(out_path, "w") as f:
        f.write(text)
    return {
        "kind": "prefill", "seq": s, "batch": batch, "s_max": S_MAX,
        "n_weights": nw, "weight_names": names,
        "k_rank": spec.k_rank, "v_rank": spec.v_rank,
        "path": os.path.relpath(out_path, ART),
    }


def export_decode(cfg, spec, weights, batch, use_pallas, out_path) -> Dict:
    flat = flatten_weights(spec, weights)
    names = [n for n, _ in flat]
    arrs = [a for _, a in flat]
    nw = len(arrs)
    kr, vr = spec.k_rank, spec.v_rank

    def fn(*args):
        ws = unflatten_weights(spec, cfg.n_layers, dict(zip(names, args[:nw])))
        token = args[nw]
        pos = args[nw + 1]
        kc = list(args[nw + 2 : nw + 2 + cfg.n_layers])
        vc = list(args[nw + 2 + cfg.n_layers : nw + 2 + 2 * cfg.n_layers])
        logits, kc2, vc2 = decode_step(cfg, spec, ws, token, pos, kc, vc, use_pallas)
        return (logits, *kc2, *vc2)

    in_specs = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for a in arrs]
    in_specs.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
    # per-sequence positions: the coordinator's continuous batcher mixes
    # sequences at different offsets in one decode step.
    in_specs.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
    for r in kr:
        in_specs.append(jax.ShapeDtypeStruct((batch, cfg.n_kv_heads, S_MAX, r), jnp.float32))
    for r in vr:
        in_specs.append(jax.ShapeDtypeStruct((batch, cfg.n_kv_heads, S_MAX, r), jnp.float32))
    text = to_hlo_text(jax.jit(fn).lower(*in_specs))
    with open(out_path, "w") as f:
        f.write(text)
    return {
        "kind": "decode", "batch": batch, "s_max": S_MAX,
        "n_weights": nw, "weight_names": names,
        "k_rank": kr, "v_rank": vr,
        "path": os.path.relpath(out_path, ART),
    }


def export_rope_bench(cfg: ModelConfig) -> List[Dict]:
    """Fig. 16 / Tables 8 & 11 microbench graphs: three RoPE implementations
    lowered as standalone HLO, swept over (batch, seq, ratio)."""
    from compile.kernels import ref
    from compile.kernels.rope_pallas import rope_full_pallas, rope_latent_pallas

    h = cfg.n_heads
    dh = cfg.head_dim
    p = cfg.n_pairs
    entries = []
    rng = np.random.default_rng(7)

    def lower(fn, in_specs, path):
        text = to_hlo_text(jax.jit(fn).lower(*in_specs))
        with open(os.path.join(ART, "hlo", "ropebench", path), "w") as f:
            f.write(text)

    shapes = [(b, s) for b in (1, 2, 4) for s in (1, 128, 512, 2048)]
    for (b, s) in shapes:
        for ratio in (0.0, 0.1, 0.2, 0.3, 0.4, 0.5):
            m = p if ratio == 0.0 else max(1, int(round((1.0 - ratio) * p)))
            tag = f"b{b}_s{s}_r{int(ratio*100):02d}"
            pair_idx = np.stack(
                [np.sort(rng.choice(p, size=m, replace=False)) for _ in range(h)]
            ).astype(np.int32)
            th = np.asarray(ref.thetas(p, dh, cfg.rope_theta))
            theta_sel = jnp.asarray(th[pair_idx])
            xs = jax.ShapeDtypeStruct((b, h, s, 2 * m), jnp.float32)
            ps = jax.ShapeDtypeStruct((s,), jnp.int32)

            if ratio == 0.0:
                # contiguous baseline (pallas, full dim)
                lower(
                    lambda x, pos: (rope_full_pallas(x, pos, cfg.rope_theta, cfg.pairing),),
                    [jax.ShapeDtypeStruct((b, h, s, dh), jnp.float32), ps],
                    f"contig_{tag}.hlo.txt",
                )
                entries.append({"impl": "contig", "batch": b, "seq": s, "ratio": 0.0,
                                "m": p, "path": f"hlo/ropebench/contig_{tag}.hlo.txt"})
                continue

            # fused index-aware pallas kernel (theta table baked as constant)
            lower(
                lambda x, pos, ts=theta_sel: (rope_latent_pallas(x, pos, ts),),
                [xs, ps], f"fused_{tag}.hlo.txt",
            )
            entries.append({"impl": "fused", "batch": b, "seq": s, "ratio": ratio,
                            "m": m, "path": f"hlo/ropebench/fused_{tag}.hlo.txt"})
            # materialising gather ("PyTorch") variant
            pi = jnp.asarray(pair_idx)
            lower(
                lambda x, pos, pi=pi: (ref.rope_gather_ref(x, pos, cfg.rope_theta, dh, pi),),
                [xs, ps], f"gather_{tag}.hlo.txt",
            )
            entries.append({"impl": "gather", "batch": b, "seq": s, "ratio": ratio,
                            "m": m, "path": f"hlo/ropebench/gather_{tag}.hlo.txt"})
    return entries


# --------------------------------------------------------------- pipeline

def write_weights_bin(model_name: str, spec: VariantSpec, weights: Dict) -> Dict:
    flat = flatten_weights(spec, weights)
    rel = f"weights/{model_name}/{spec.key}.bin"
    path = os.path.join(ART, rel)
    tensors = []
    off = 0
    with open(path, "wb") as f:
        for name, arr in flat:
            a = np.ascontiguousarray(arr, dtype=np.float32)
            f.write(a.tobytes())
            tensors.append({"name": name, "shape": list(a.shape), "offset": off})
            off += a.nbytes
    return {"path": rel, "bytes": off, "tensors": tensors}


class Pipeline:
    def __init__(self, cfg: ModelConfig, corpus: bytes, force: bool = False):
        self.cfg = cfg
        self.force = force
        self.train_data, self.eval_data = data_mod.train_eval_split(corpus)
        self.eval_x, self.eval_y = data_mod.eval_windows(self.eval_data, 192, 32)
        self.manifest_variants: Dict[str, Dict] = {}
        self.logs: Dict[str, object] = {}

    # -- stage 1: teacher -------------------------------------------------
    def teacher(self) -> Dict:
        cpath = _cache_path(f"{self.cfg.name}_teacher.npz")
        spec = baseline_spec(self.cfg)
        if os.path.exists(cpath) and not self.force:
            return load_tree(cpath, spec, self.cfg.n_layers)
        tcfg = TrainConfig()
        w = init_weights(self.cfg, seed=tcfg.seed)
        batches = data_mod.batches(self.train_data, tcfg.batch, tcfg.seq, tcfg.steps, tcfg.seed)
        w, log = train(self.cfg, tcfg, w, batches)
        save_tree(cpath, spec, w)
        self.logs["train"] = log
        return w

    # -- stage 2: calibration ---------------------------------------------
    def calibration(self, teacher: Dict):
        fpath = _cache_path(f"{self.cfg.name}_fisher.npz")
        cpath = _cache_path(f"{self.cfg.name}_covs.npz")
        fcfg = FisherConfig()
        if not (os.path.exists(fpath) and os.path.exists(cpath)) or self.force:
            n_batches = max(1, fcfg.windows // fcfg.batch)
            calib = list(
                data_mod.batches(self.train_data, fcfg.batch, fcfg.seq, n_batches, fcfg.seed + 1)
            )
            fisher = fisher_mod.accumulate_fisher(self.cfg, teacher, calib)
            np.savez(
                fpath,
                **{f"k{i}": f["wk"] for i, f in enumerate(fisher)},
                **{f"v{i}": f["wv"] for i, f in enumerate(fisher)},
            )
            covs = self._covariances(teacher, calib)
            np.savez(cpath, **{f"c{i}": c for i, c in enumerate(covs)})
        zf = np.load(fpath)
        fisher = [
            {"wk": zf[f"k{i}"], "wv": zf[f"v{i}"]} for i in range(self.cfg.n_layers)
        ]
        zc = np.load(cpath)
        covs = [zc[f"c{i}"] for i in range(self.cfg.n_layers)]
        scores = fisher_mod.pair_scores_from_fisher(self.cfg, fisher)
        return scores, covs

    def _covariances(self, weights: Dict, calib) -> List[np.ndarray]:
        spec = baseline_spec(self.cfg)

        @jax.jit
        def hidden_fn(w, x):
            _, hiddens = forward_full(self.cfg, spec, w, x, return_hiddens=True)
            return hiddens

        covs = [np.zeros((self.cfg.d_model, self.cfg.d_model), np.float64)
                for _ in range(self.cfg.n_layers)]
        n = 0
        for x, _ in calib:
            hs = hidden_fn(weights, jnp.asarray(x))
            for i, h in enumerate(hs):
                hm = np.asarray(h, np.float64).reshape(-1, self.cfg.d_model)
                covs[i] += hm.T @ hm
                n += 0  # covariance is a sum; scale is irrelevant to Cholesky whitening direction
            n += x.shape[0] * x.shape[1]
        return [c / max(n, 1) for c in covs]

    # -- stage 3: variants --------------------------------------------------
    def _register(self, built: Dict, ppl: float):
        spec: VariantSpec = built["spec"]
        info = write_weights_bin(self.cfg.name, spec, built["weights"])
        self.manifest_variants[spec.key] = {
            "spec": spec.to_json(),
            "weights": info,
            "ppl_python": ppl,
        }
        print(f"[variant {self.cfg.name}/{spec.key}] ppl={ppl:.3f}", flush=True)

    def _ppl(self, spec, weights) -> float:
        return eval_ppl(self.cfg, spec, weights, self.eval_x, self.eval_y)

    def build_variants(self, teacher, scores, covs):
        cfg = self.cfg
        cache = _cache_path(f"{cfg.name}_variants_done.json")
        base_spec_ = baseline_spec(cfg)
        self._register({"spec": base_spec_, "weights": teacher}, self._ppl(base_spec_, teacher))
        kd_logs = {}

        for rho in RATIOS:
            rank = max(1, int(round((1.0 - rho) * cfg.head_dim)))
            sv = build_svd_variant(cfg, teacher, rank, rank, rho)
            self._register(sv, self._ppl(sv["spec"], sv["weights"]))

            pl_ = build_palu_variant(cfg, teacher, covs, [rank] * cfg.n_layers,
                                     [rank] * cfg.n_layers, rho)
            self._register(pl_, self._ppl(pl_["spec"], pl_["weights"]))

            rho_k, rho_v = budget_mod.allocate(scores, rho)
            m, rv = budget_mod.ranks_from_ratios(cfg, rho_k, rho_v)
            rap = build_rap_variant(cfg, teacher, scores, covs, m, rv, rho)
            pre_ppl = self._ppl(rap["spec"], rap["weights"])
            # pre-KD snapshot (Fig. 14 / Table 5 "w/o KD")
            nokd_spec = VariantSpec.from_json({**rap["spec"].to_json(), "tag": "noKD"})
            self._register({"spec": nokd_spec, "weights": rap["weights"]}, pre_ppl)

            if cfg.name in KD_MODELS:
                kcfg = KDConfig()
                kd_batches = data_mod.batches(self.train_data, kcfg.batch, kcfg.seq,
                                              kcfg.steps, kcfg.seed + int(rho * 100))
                merged, log = distill(
                    cfg, rap["spec"], rap["weights"], teacher, kcfg, kd_batches,
                    eval_fn=lambda w, s=rap["spec"]: self._ppl(s, w),
                )
                kd_logs[f"rap_r{int(rho*100):02d}"] = {
                    "pre_ppl": pre_ppl, "curve": log,
                }
                self._register({"spec": rap["spec"], "weights": merged},
                               self._ppl(rap["spec"], merged))
            else:
                self._register(rap, pre_ppl)

        # PaLU + KD at rho=30% (Table 7)
        rank30 = max(1, int(round(0.7 * cfg.head_dim)))
        pl30 = build_palu_variant(cfg, teacher, covs, [rank30] * cfg.n_layers,
                                  [rank30] * cfg.n_layers, 0.30, tag="kd")
        kcfg = KDConfig(steps=40)
        merged, log = distill(
            cfg, pl30["spec"], pl30["weights"], teacher, kcfg,
            data_mod.batches(self.train_data, kcfg.batch, kcfg.seq, kcfg.steps, 777),
            eval_fn=lambda w, s=pl30["spec"]: self._ppl(s, w),
        )
        kd_logs["palu_r30"] = {"curve": log}
        self._register({"spec": pl30["spec"], "weights": merged},
                       self._ppl(pl30["spec"], merged))

        # Fig. 13 ablation arms at rho=30% (tinyllama only)
        if cfg.name == "tinyllama":
            self._ablation_arms(teacher, scores, covs)
            self._fig4_layers(teacher, scores, covs)

        self.logs["kd"] = kd_logs
        with open(cache, "w") as f:
            json.dump({"done": True}, f)

    def _ablation_arms(self, teacher, scores, covs):
        cfg = self.cfg
        mag_scores = fisher_mod.magnitude_scores(cfg, teacher)
        rho = 0.30
        arms = {
            "FU": (scores, *budget_mod.uniform_ranks(cfg, rho)),
            "MA": (mag_scores, *budget_mod.ranks_from_ratios(
                cfg, *budget_mod.allocate(mag_scores, rho))),
            "MU": (mag_scores, *budget_mod.uniform_ranks(cfg, rho)),
        }
        for tag, (sc, m, rv) in arms.items():
            v = build_rap_variant(cfg, teacher, sc, covs, m, rv, rho, tag=tag)
            self._register(v, self._ppl(v["spec"], v["weights"]))

    def _fig4_layers(self, teacher, scores, covs):
        for layer in range(self.cfg.n_layers):
            v = build_single_layer_variant(self.cfg, teacher, scores, covs, layer, 0.30)
            self._register(v, self._ppl(v["spec"], v["weights"]))

    # -- stage 4: HLO exports ----------------------------------------------
    def export_hlos(self) -> Dict[str, Dict]:
        cfg = self.cfg
        out: Dict[str, Dict] = {}
        keys = ["baseline_r00"]
        for rho in HLO_RATIOS[cfg.name]:
            for meth in ("svd", "palu", "rap"):
                keys.append(f"{meth}_r{int(rho*100):02d}")
        for key in keys:
            if key not in self.manifest_variants:
                continue
            ventry = self.manifest_variants[key]
            spec = VariantSpec.from_json(ventry["spec"])
            weights = self._load_variant(spec, ventry)
            graphs = {}
            use_pallas = spec.method in ("baseline", "rap")
            for s in PREFILL_BUCKETS:
                p = os.path.join(ART, "hlo", cfg.name, f"{key}_prefill{s}.hlo.txt")
                graphs[f"prefill{s}"] = export_prefill(cfg, spec, weights, s, 1, use_pallas, p)
            for b in DECODE_BATCHES:
                p = os.path.join(ART, "hlo", cfg.name, f"{key}_decode_b{b}.hlo.txt")
                graphs[f"decode_b{b}"] = export_decode(cfg, spec, weights, b, use_pallas, p)
            out[key] = graphs
            print(f"[hlo {cfg.name}/{key}] exported {len(graphs)} graphs", flush=True)
        # Full-pallas decode proof artifact (L1 attention kernel e2e).
        key = f"rap_r30"
        if key in self.manifest_variants:
            ventry = self.manifest_variants[key]
            spec = VariantSpec.from_json(ventry["spec"])
            weights = self._load_variant(spec, ventry)
            p = os.path.join(ART, "hlo", cfg.name, f"{key}_decode_pallas_full.hlo.txt")
            # decode_step uses attn_decode_pallas when use_pallas and method rap
            out.setdefault(key, {})["decode_pallas_full"] = export_decode(
                cfg, spec, weights, 1, True, p
            )
        return out

    def _load_variant(self, spec: VariantSpec, ventry: Dict) -> Dict:
        path = os.path.join(ART, ventry["weights"]["path"])
        raw = np.fromfile(path, dtype=np.float32)
        named = {}
        for t in ventry["weights"]["tensors"]:
            n = int(np.prod(t["shape"]))
            o = t["offset"] // 4
            named[t["name"]] = raw[o : o + n].reshape(t["shape"])
        return unflatten_weights(spec, self.cfg.n_layers, named)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="ignored; kept for Makefile compat")
    ap.add_argument("--models", default="tinyllama,tinymistral")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-hlo", action="store_true")
    args = ap.parse_args()

    _ensure_dirs()
    t0 = time.time()
    corpus_path = os.path.join(ART, "corpus.bin")
    if not os.path.exists(corpus_path):
        corpus = data_mod.generate_corpus()
        with open(corpus_path, "wb") as f:
            f.write(corpus)
    else:
        corpus = open(corpus_path, "rb").read()

    manifest = {
        "corpus": "corpus.bin",
        "eval": {"seq": 192, "windows": 32, "eval_frac": 0.1},
        "s_max": S_MAX,
        "models": {},
        "hlo": {},
        "rope_bench": [],
    }
    mpath = os.path.join(ART, "manifest.json")
    for name in args.models.split(","):
        cfg = MODELS[name]
        pipe = Pipeline(cfg, corpus, force=args.force)
        teacher = pipe.teacher()
        scores, covs = pipe.calibration(teacher)
        pipe.build_variants(teacher, scores, covs)
        manifest["models"][name] = {
            "config": cfg.to_json(),
            "variants": pipe.manifest_variants,
        }
        with open(os.path.join(ART, "logs", f"{name}_logs.json"), "w") as f:
            json.dump(pipe.logs, f, indent=1)
        if not args.skip_hlo:
            manifest["hlo"][name] = pipe.export_hlos()
        print(f"[aot] {name} done at {time.time()-t0:.0f}s", flush=True)

    if not args.skip_hlo:
        manifest["rope_bench"] = export_rope_bench(MODELS["tinyllama"])

    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    # Sentinel for make's dependency tracking.
    with open(os.path.join(ART, ".stamp"), "w") as f:
        f.write(str(time.time()))
    print(f"[aot] all done in {time.time()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
