"""Fisher scoring semantics + AOT export plumbing."""

import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile.config import ModelConfig, baseline_spec
from compile.model import init_weights
from compile.rap import fisher as fisher_mod
from compile.rap.prune import select_pairs
import compile.aot as aot


class TestFisher:
    def test_scores_nonnegative(self, micro_cfg, micro_scores):
        for s in micro_scores:
            assert (s["k_pairs"] >= 0).all()
            assert (s["v_cols"] >= 0).all()
            assert s["k_pairs"].shape == (micro_cfg.n_kv_heads, micro_cfg.n_pairs)
            assert s["v_cols"].shape == (micro_cfg.n_kv_heads, micro_cfg.head_dim)

    def test_deterministic(self, micro_cfg, micro_weights, micro_calib):
        f1 = fisher_mod.accumulate_fisher(micro_cfg, micro_weights, micro_calib)
        f2 = fisher_mod.accumulate_fisher(micro_cfg, micro_weights, micro_calib)
        np.testing.assert_allclose(f1[0]["wk"], f2[0]["wk"], rtol=1e-6)

    def test_pair_aggregation_sums_both_columns(self, micro_cfg):
        """Pair score = column j mass + column j' mass (Eq. 7)."""
        from compile.config import rope_pairs
        cfg = micro_cfg
        fake = []
        for _ in range(cfg.n_layers):
            wk = np.zeros((cfg.d_model, cfg.kv_dim))
            fake.append({"wk": wk, "wv": np.zeros_like(wk)})
        # put known mass in head 0, pair 2's two columns
        pairs = rope_pairs(cfg)
        j, jp = pairs[2]
        fake[0]["wk"][:, j] = 3.0
        fake[0]["wk"][:, jp] = 2.0
        scores = fisher_mod.pair_scores_from_fisher(cfg, fake)
        expected = 3.0 * cfg.d_model + 2.0 * cfg.d_model
        assert np.isclose(scores[0]["k_pairs"][0, 2], expected)
        assert scores[0]["k_pairs"][0, 0] == 0.0

    def test_magnitude_scores_shapes(self, micro_cfg, micro_weights):
        s = fisher_mod.magnitude_scores(micro_cfg, micro_weights)
        assert len(s) == micro_cfg.n_layers
        assert s[0]["k_pairs"].shape == (micro_cfg.n_kv_heads, micro_cfg.n_pairs)

    def test_select_pairs_top_m_sorted(self):
        scores = np.asarray([[5.0, 1.0, 9.0, 2.0], [0.1, 0.4, 0.2, 0.3]])
        idx = select_pairs(scores, 2)
        np.testing.assert_array_equal(idx[0], [0, 2])
        np.testing.assert_array_equal(idx[1], [1, 3])


class TestAotExport:
    def test_prefill_decode_hlo_text(self, micro_cfg, micro_weights, tmp_path):
        spec = baseline_spec(micro_cfg)
        p1 = str(tmp_path / "p.hlo.txt")
        info = aot.export_prefill(micro_cfg, spec, micro_weights, 8, 1, False, p1)
        assert info["kind"] == "prefill" and os.path.getsize(p1) > 1000
        text = open(p1).read()
        assert text.startswith("HloModule")
        p2 = str(tmp_path / "d.hlo.txt")
        info = aot.export_decode(micro_cfg, spec, micro_weights, 1, False, p2)
        assert info["kind"] == "decode" and os.path.getsize(p2) > 1000
        # the parameter count matches weights + token + pos + 2L caches
        assert info["n_weights"] == len(info["weight_names"])

    def test_rap_decode_hlo_contains_no_reconstruction(
        self, micro_cfg, micro_rap, tmp_path
    ):
        """The absorbed RAP graph must not contain a [rk, dh] reconstruction
        contraction; the SVD graph must.  We check a necessary condition:
        graph size — the SVD decode graph strictly larger than RAP's at the
        same ratio (it contains the extra einsum)."""
        from compile.rap.svd import build_svd_variant
        cfg = micro_cfg
        sv = build_svd_variant(cfg, {
            "tok_emb": micro_rap["weights"]["tok_emb"],
            "layers": [
                {k: v for k, v in zip(
                    ["attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down"],
                    [lw.get("attn_norm"), np.zeros((cfg.d_model, cfg.q_dim), np.float32),
                     np.zeros((cfg.d_model, cfg.kv_dim), np.float32),
                     np.zeros((cfg.d_model, cfg.kv_dim), np.float32),
                     np.zeros((cfg.q_dim, cfg.d_model), np.float32),
                     lw.get("mlp_norm"), lw.get("w_gate"), lw.get("w_up"), lw.get("w_down")])}
                for lw in micro_rap["weights"]["layers"]
            ],
            "final_norm": micro_rap["weights"]["final_norm"],
        }, 11, 11, 0.3)
        p_rap = str(tmp_path / "rap.hlo.txt")
        p_svd = str(tmp_path / "svd.hlo.txt")
        aot.export_decode(cfg, micro_rap["spec"], micro_rap["weights"], 1, False, p_rap)
        aot.export_decode(cfg, sv["spec"], sv["weights"], 1, False, p_svd)
        rap_text = open(p_rap).read()
        svd_text = open(p_svd).read()
        # SVD decode reconstructs K and V: strictly more dot ops.
        assert svd_text.count(" dot(") > rap_text.count(" dot(")

    def test_weights_bin_roundtrip(self, micro_cfg, micro_rap, tmp_path, monkeypatch):
        monkeypatch.setattr(aot, "ART", str(tmp_path))
        os.makedirs(tmp_path / "weights" / micro_cfg.name, exist_ok=True)
        info = aot.write_weights_bin(micro_cfg.name, micro_rap["spec"], micro_rap["weights"])
        raw = np.fromfile(tmp_path / info["path"], dtype=np.float32)
        assert raw.nbytes == info["bytes"]
        t0 = info["tensors"][0]
        assert t0["name"] == "tok_emb" and t0["offset"] == 0
        n0 = int(np.prod(t0["shape"]))
        np.testing.assert_allclose(
            raw[:n0].reshape(t0["shape"]),
            np.asarray(micro_rap["weights"]["tok_emb"]),
        )
