"""Corpus generator, batching, KD/LoRA machinery."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import data as data_mod
from compile.config import KDConfig, baseline_spec
from compile.kd import lora_init, merge_lora, lora_param_fraction, kd_loss, distill
from compile.model import forward_full


class TestData:
    def test_deterministic(self):
        a = data_mod.generate_corpus(1 << 14, seed=1)
        b = data_mod.generate_corpus(1 << 14, seed=1)
        assert a == b

    def test_seed_changes_output(self):
        a = data_mod.generate_corpus(1 << 14, seed=1)
        b = data_mod.generate_corpus(1 << 14, seed=2)
        assert a != b

    def test_structure(self):
        c = data_mod.generate_corpus(1 << 16)
        assert len(c) == 1 << 16
        text = c.decode()
        assert ". " in text and "\n\n" in text
        # byte-level vocab constraint
        assert max(c) < 256

    def test_zipf_like_distribution(self):
        """Frequent words should dominate: top-10 words cover far more mass
        than a uniform distribution would."""
        c = data_mod.generate_corpus(1 << 17).decode()
        words = [w.strip(".?") for w in c.split() if w.strip(".?")]
        from collections import Counter
        counts = Counter(words)
        top = sum(v for _, v in counts.most_common(10))
        assert top / len(words) > 0.15

    def test_batches_shapes_and_shift(self):
        c = data_mod.generate_corpus(1 << 14)
        tr, ev = data_mod.train_eval_split(c)
        assert len(ev) == (1 << 14) - int((1 << 14) * 0.9)
        for x, y in data_mod.batches(tr, 3, 32, 2, 0):
            assert x.shape == (3, 32) and y.shape == (3, 32)
            # y is x shifted by one
            assert (x[:, 1:] == y[:, :-1]).all()

    def test_eval_windows_nonoverlapping(self):
        c = data_mod.generate_corpus(1 << 14)
        xs, ys = data_mod.eval_windows(c, 64, 8)
        assert xs.shape == (8, 64)
        flat = np.frombuffer(c, np.uint8)
        np.testing.assert_array_equal(xs[1], flat[64:128])


class TestKD:
    def test_zero_up_merge_is_identity(self, micro_cfg, micro_rap):
        spec, w = micro_rap["spec"], micro_rap["weights"]
        ad = lora_init(micro_cfg, spec, w, KDConfig())
        merged = merge_lora(w, ad, 2.0)
        t = jnp.asarray(np.arange(8, dtype=np.int32)[None])
        np.testing.assert_allclose(
            forward_full(micro_cfg, spec, w, t),
            forward_full(micro_cfg, spec, merged, t),
            atol=1e-6,
        )

    def test_lora_param_fraction_small(self, micro_cfg, micro_rap):
        ad = lora_init(micro_cfg, micro_rap["spec"], micro_rap["weights"], KDConfig(lora_rank=2))
        frac = lora_param_fraction(ad, micro_rap["weights"])
        assert 0 < frac < 0.1

    def test_kd_loss_zero_when_matched(self):
        logits = jnp.asarray(np.random.default_rng(0).normal(size=(2, 4, 16)).astype(np.float32))
        y = jnp.zeros((2, 4), jnp.int32)
        kcfg = KDConfig(alpha_ce=0.0, alpha_kd=1.0)
        val = float(kd_loss(None, None, kcfg, logits, logits, y))
        assert abs(val) < 1e-5

    def test_distill_reduces_kd_loss(self, micro_cfg, micro_weights, micro_rap, micro_corpus):
        tr, _ = data_mod.train_eval_split(micro_corpus)
        kcfg = KDConfig(steps=6, batch=2, seq=48, lr=3e-3)
        batches = data_mod.batches(tr, kcfg.batch, kcfg.seq, kcfg.steps, 3)
        merged, log = distill(
            micro_cfg, micro_rap["spec"], micro_rap["weights"], micro_weights,
            kcfg, batches, eval_fn=None, eval_every=100,
        )
        losses = [e["loss"] for e in log if "loss" in e]
        assert losses[-1] <= losses[0] + 0.05
