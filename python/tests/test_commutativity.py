"""The paper's central structural claims (Definition 1.1, Eq. 5, Eq. 9–10).

These tests prove, numerically, the three facts the whole method rests on:

1. RAP's pair-preserving binary expansion B commutes with (index-aware)
   RoPE: RoPE(XA)B == RoPE(XAB).
2. Arbitrary (non-pair-aligned) column pruning does NOT commute — the
   negative control that motivates RAP over plain structured pruning.
3. After absorbing B_k into W_q, the attention scores over retained pairs
   equal the full model's scores restricted to those pairs (Eq. 9–10), and
   a no-op prune (keep everything) reproduces the baseline exactly.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.config import ModelConfig, rope_pairs
from compile.kernels import ref
from compile.rap.prune import (
    absorb_bk_into_wq,
    expansion_matrix,
    gather_pair_columns,
    select_pairs,
    theta_sel_table,
)

RNG = np.random.default_rng(7)


def _index_aware_rope_full(x_full, pos, cfg):
    """RoPE on a full-D tensor using the model's native pairing."""
    return ref.rope_full_ref(x_full, pos, cfg.rope_theta, cfg.pairing)


def _cfg(pairing, head_dim=16):
    return ModelConfig(
        name="t", d_model=32, n_layers=1, n_heads=2, n_kv_heads=2,
        head_dim=head_dim, mlp_hidden=32, pairing=pairing,
    )


@pytest.mark.parametrize("pairing", ["half", "interleaved"])
class TestCommutativity:
    def test_rope_commutes_with_pair_expansion(self, pairing):
        """RoPE(XA) B == RoPE(X A B) for pair-preserving B (Eq. 5)."""
        cfg = _cfg(pairing)
        p = cfg.n_pairs
        m = 5
        pair_idx_h = np.sort(RNG.choice(p, m, replace=False))
        b_mat = expansion_matrix(cfg, pair_idx_h)  # [2m, dh]
        s = 9
        xa = RNG.normal(size=(1, 1, s, 2 * m)).astype(np.float32)  # latent
        pos = jnp.arange(s, dtype=jnp.int32)

        theta_sel = theta_sel_table(cfg, pair_idx_h[None, :])  # [1, m]
        # left side: index-aware RoPE in latent space, then expand
        left = np.asarray(ref.rope_latent_ref(jnp.asarray(xa), pos, jnp.asarray(theta_sel)))
        left = left @ b_mat  # [1,1,S,dh]
        # right side: expand first, then full RoPE
        right = np.asarray(
            _index_aware_rope_full(jnp.asarray(xa @ b_mat), pos, cfg)
        )
        np.testing.assert_allclose(left, right, rtol=1e-5, atol=1e-5)

    def test_arbitrary_column_pruning_does_not_commute(self, pairing):
        """Negative control: breaking a rotation pair breaks commutativity."""
        cfg = _cfg(pairing)
        pairs = rope_pairs(cfg)
        dh = cfg.head_dim
        # Keep 2m arbitrary columns that split at least one pair:
        # the two halves of pair 0 land in different 'pair slots'.
        j0, j0p = pairs[0]
        j1, j1p = pairs[1]
        cols = [j0, j1]  # mixes components of two different pairs
        b_bad = np.zeros((2, dh), np.float32)
        b_bad[0, cols[0]] = 1.0
        b_bad[1, cols[1]] = 1.0
        s = 7
        xa = RNG.normal(size=(1, 1, s, 2)).astype(np.float32)
        pos = jnp.arange(s, dtype=jnp.int32)
        # Treat the two kept columns as if they were one RoPE 'pair' — the
        # only latent rotation available — and compare to the true result.
        theta_fake = np.asarray([[ref.thetas(dh // 2, dh, cfg.rope_theta)[0]]], np.float32)
        left = np.asarray(ref.rope_latent_ref(jnp.asarray(xa), pos, jnp.asarray(theta_fake)))
        left = left @ b_bad
        right = np.asarray(_index_aware_rope_full(jnp.asarray(xa @ b_bad), pos, cfg))
        assert not np.allclose(left, right, rtol=1e-3, atol=1e-3)

    def test_expansion_matrix_is_orthonormal_selector(self, pairing):
        cfg = _cfg(pairing)
        m = 4
        pair_idx_h = np.sort(RNG.choice(cfg.n_pairs, m, replace=False))
        b = expansion_matrix(cfg, pair_idx_h)
        np.testing.assert_allclose(b @ b.T, np.eye(2 * m), atol=1e-7)
        # every row has exactly one 1 (binary expansion, Eq. 8)
        assert (b.sum(axis=1) == 1).all()
        assert ((b == 0) | (b == 1)).all()

    def test_gather_is_w_times_bt(self, pairing):
        """A = W B^T: gathering pair columns equals multiplying by B^T."""
        cfg = _cfg(pairing)
        w = RNG.normal(size=(cfg.d_model, cfg.kv_dim)).astype(np.float32)
        m = 3
        pair_idx = np.stack(
            [np.sort(RNG.choice(cfg.n_pairs, m, replace=False))
             for _ in range(cfg.n_kv_heads)]
        )
        a = gather_pair_columns(cfg, w, cfg.n_kv_heads, pair_idx)
        dh = cfg.head_dim
        for h in range(cfg.n_kv_heads):
            b = expansion_matrix(cfg, pair_idx[h])
            wh = w[:, h * dh : (h + 1) * dh]
            np.testing.assert_allclose(
                a[:, h * 2 * m : (h + 1) * 2 * m], wh @ b.T, atol=1e-6
            )


@pytest.mark.parametrize("pairing", ["half", "interleaved"])
def test_absorbed_scores_equal_restricted_full_scores(pairing):
    """Eq. 9–10: RoPE(X W_q B^T) RoPE(X A_k)^T equals the full-dimension
    scores computed with only the retained pairs' contributions."""
    cfg = _cfg(pairing)
    d, dh = cfg.d_model, cfg.head_dim
    wq = RNG.normal(size=(d, cfg.q_dim)).astype(np.float32)
    wk = RNG.normal(size=(d, cfg.kv_dim)).astype(np.float32)
    m = 5
    pair_idx = np.stack(
        [np.sort(RNG.choice(cfg.n_pairs, m, replace=False))
         for _ in range(cfg.n_kv_heads)]
    )
    s = 6
    x = RNG.normal(size=(1, s, d)).astype(np.float32)
    pos = jnp.arange(s, dtype=jnp.int32)

    a_k = gather_pair_columns(cfg, wk, cfg.n_kv_heads, pair_idx)
    wq_t = absorb_bk_into_wq(cfg, wq, pair_idx)
    theta = theta_sel_table(cfg, pair_idx)

    def split(t, n_heads):
        return t.reshape(1, s, n_heads, -1).transpose(0, 2, 1, 3)

    q_lat = ref.rope_latent_ref(
        jnp.asarray(split(x @ wq_t, cfg.n_heads)), pos,
        jnp.asarray(np.repeat(theta, cfg.group_size, axis=0)))
    k_lat = ref.rope_latent_ref(
        jnp.asarray(split(x @ a_k, cfg.n_kv_heads)), pos, jnp.asarray(theta))
    scores_lat = np.einsum("bhqk,bhsk->bhqs", np.asarray(q_lat), np.asarray(k_lat))

    # full path, then zero out removed pairs' contributions
    q_full = ref.rope_full_ref(jnp.asarray(split(x @ wq, cfg.n_heads)), pos,
                               cfg.rope_theta, cfg.pairing)
    k_full = ref.rope_full_ref(jnp.asarray(split(x @ wk, cfg.n_kv_heads)), pos,
                               cfg.rope_theta, cfg.pairing)
    pairs = rope_pairs(cfg)
    keep_mask = np.zeros((cfg.n_kv_heads, dh), np.float32)
    for h in range(cfg.n_kv_heads):
        for j in pair_idx[h]:
            keep_mask[h, pairs[j][0]] = 1.0
            keep_mask[h, pairs[j][1]] = 1.0
    k_masked = np.asarray(k_full) * keep_mask[None, :, None, :]
    scores_full = np.einsum("bhqk,bhsk->bhqs", np.asarray(q_full), k_masked)
    np.testing.assert_allclose(scores_lat, scores_full, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    pairing=st.sampled_from(["half", "interleaved"]),
    head_dim=st.sampled_from([8, 12, 16, 20]),
    data=st.data(),
)
def test_commutativity_hypothesis(pairing, head_dim, data):
    cfg = _cfg(pairing, head_dim=head_dim)
    p = cfg.n_pairs
    m = data.draw(st.integers(1, p))
    pair_idx_h = np.sort(RNG.choice(p, m, replace=False))
    b_mat = expansion_matrix(cfg, pair_idx_h)
    s = data.draw(st.integers(1, 12))
    xa = RNG.normal(size=(1, 1, s, 2 * m)).astype(np.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    theta_sel = theta_sel_table(cfg, pair_idx_h[None, :])
    left = np.asarray(ref.rope_latent_ref(jnp.asarray(xa), pos, jnp.asarray(theta_sel))) @ b_mat
    right = np.asarray(ref.rope_full_ref(jnp.asarray(xa @ b_mat), pos, cfg.rope_theta, cfg.pairing))
    np.testing.assert_allclose(left, right, rtol=1e-4, atol=1e-4)
