"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

Includes hypothesis sweeps over shapes so the kernels are exercised across
tile boundaries, odd head counts, GQA group sizes, and ratio-dependent
latent widths.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.rope_pallas import rope_full_pallas, rope_latent_pallas, S_TILE
from compile.kernels.attn_pallas import attn_decode_pallas

RNG = np.random.default_rng(1234)
TOL = dict(rtol=1e-5, atol=1e-5)


def rand(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


class TestRopeLatent:
    def test_matches_ref_basic(self):
        x = rand(2, 4, 16, 12)
        pos = jnp.arange(16, dtype=jnp.int32)
        theta = jnp.asarray(RNG.uniform(0.01, 1, (4, 6)).astype(np.float32))
        np.testing.assert_allclose(
            rope_latent_pallas(x, pos, theta),
            ref.rope_latent_ref(x, pos, theta),
            **TOL,
        )

    def test_tiled_path(self):
        s = 2 * S_TILE
        x = rand(1, 2, s, 8)
        pos = jnp.arange(s, dtype=jnp.int32)
        theta = jnp.asarray(RNG.uniform(0.01, 1, (2, 4)).astype(np.float32))
        np.testing.assert_allclose(
            rope_latent_pallas(x, pos, theta),
            ref.rope_latent_ref(x, pos, theta),
            **TOL,
        )

    def test_offset_positions(self):
        """Decode-style: a single token at arbitrary position."""
        x = rand(3, 4, 1, 10)
        theta = jnp.asarray(RNG.uniform(0.01, 1, (4, 5)).astype(np.float32))
        for p in (0, 7, 123):
            pos = jnp.asarray([p], dtype=jnp.int32)
            np.testing.assert_allclose(
                rope_latent_pallas(x, pos, theta),
                ref.rope_latent_ref(x, pos, theta),
                **TOL,
            )

    def test_norm_preserving(self):
        """RoPE is orthogonal per pair: row norms are invariant."""
        x = rand(1, 2, 8, 12)
        pos = jnp.arange(8, dtype=jnp.int32)
        theta = jnp.asarray(RNG.uniform(0.01, 1, (2, 6)).astype(np.float32))
        y = rope_latent_pallas(x, pos, theta)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1),
            rtol=1e-5, atol=1e-5,
        )

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 6),
        s=st.integers(1, 40),
        m=st.integers(1, 16),
    )
    def test_hypothesis_shapes(self, b, h, s, m):
        x = rand(b, h, s, 2 * m)
        pos = jnp.arange(s, dtype=jnp.int32)
        theta = jnp.asarray(RNG.uniform(0.001, 1, (h, m)).astype(np.float32))
        np.testing.assert_allclose(
            rope_latent_pallas(x, pos, theta),
            ref.rope_latent_ref(x, pos, theta),
            **TOL,
        )


class TestRopeFull:
    @pytest.mark.parametrize("pairing", ["half", "interleaved"])
    def test_matches_ref(self, pairing):
        x = rand(2, 3, 24, 16)
        pos = jnp.arange(24, dtype=jnp.int32)
        np.testing.assert_allclose(
            rope_full_pallas(x, pos, 10000.0, pairing),
            ref.rope_full_ref(x, pos, 10000.0, pairing),
            **TOL,
        )

    @pytest.mark.parametrize("pairing", ["half", "interleaved"])
    def test_relative_position_property(self, pairing):
        """RoPE's defining property: <R_i q, R_j k> depends only on i - j."""
        d = 8
        q = rand(1, 1, 1, d)
        k = rand(1, 1, 1, d)
        def score(i, j):
            qi = ref.rope_full_ref(q, jnp.asarray([i], jnp.int32), 100.0, pairing)
            kj = ref.rope_full_ref(k, jnp.asarray([j], jnp.int32), 100.0, pairing)
            return float(jnp.sum(qi * kj))
        assert np.isclose(score(3, 1), score(10, 8), rtol=1e-4, atol=1e-5)
        assert np.isclose(score(0, 0), score(25, 25), rtol=1e-4, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(1, 2), h=st.integers(1, 4),
        s=st.integers(1, 33), p=st.integers(1, 12),
        pairing=st.sampled_from(["half", "interleaved"]),
    )
    def test_hypothesis_shapes(self, b, h, s, p, pairing):
        x = rand(b, h, s, 2 * p)
        pos = jnp.arange(s, dtype=jnp.int32)
        np.testing.assert_allclose(
            rope_full_pallas(x, pos, 10000.0, pairing),
            ref.rope_full_ref(x, pos, 10000.0, pairing),
            **TOL,
        )


class TestGatherVariant:
    def test_gather_equals_latent(self):
        """The 'PyTorch' materialising-gather path is numerically identical
        to the fused kernel — only memory behaviour differs (§4.5)."""
        h, m, dh = 4, 5, 16
        p = dh // 2
        x = rand(2, h, 12, 2 * m)
        pos = jnp.arange(12, dtype=jnp.int32)
        pair_idx = np.stack(
            [np.sort(RNG.choice(p, m, replace=False)) for _ in range(h)]
        ).astype(np.int32)
        th = np.asarray(ref.thetas(p, dh, 10000.0))
        g = ref.rope_gather_ref(x, pos, 10000.0, dh, jnp.asarray(pair_idx))
        l = ref.rope_latent_ref(x, pos, jnp.asarray(th[pair_idx]))
        f = rope_latent_pallas(x, pos, jnp.asarray(th[pair_idx]))
        np.testing.assert_allclose(g, l, **TOL)
        np.testing.assert_allclose(g, f, **TOL)


class TestAttnDecode:
    def test_matches_ref(self):
        q = rand(2, 4, 12)
        kc = rand(2, 2, 32, 12)
        vc = rand(2, 2, 32, 10)
        for pos in (0, 5, 31):
            np.testing.assert_allclose(
                attn_decode_pallas(q, kc, vc, jnp.int32(pos), 0.25),
                ref.attn_decode_ref(q, kc, vc, jnp.int32(pos), 0.25),
                **TOL,
            )

    def test_mask_excludes_future(self):
        """Garbage beyond pos must not affect the output."""
        q = rand(1, 2, 8)
        kc = np.asarray(rand(1, 1, 16, 8))
        vc = np.asarray(rand(1, 1, 16, 8))
        kc2, vc2 = kc.copy(), vc.copy()
        kc2[:, :, 6:] = 1e3
        vc2[:, :, 6:] = -1e3
        a = attn_decode_pallas(jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc), jnp.int32(5), 0.3)
        b = attn_decode_pallas(jnp.asarray(q), jnp.asarray(kc2), jnp.asarray(vc2), jnp.int32(5), 0.3)
        np.testing.assert_allclose(a, b, **TOL)

    def test_pos_zero_is_single_token(self):
        q = rand(1, 2, 6)
        kc = rand(1, 2, 8, 6)
        vc = rand(1, 2, 8, 4)
        out = attn_decode_pallas(q, kc, vc, jnp.int32(0), 1.0)
        # softmax over one element == that element's V row
        np.testing.assert_allclose(out, np.asarray(vc)[:, :, 0, :], **TOL)

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 3),
        hkv=st.integers(1, 4),
        group=st.integers(1, 3),
        smax=st.integers(4, 48),
        kr=st.integers(1, 16),
        vr=st.integers(1, 16),
    )
    def test_hypothesis_shapes(self, b, hkv, group, smax, kr, vr):
        h = hkv * group
        q = rand(b, h, kr)
        kc = rand(b, hkv, smax, kr)
        vc = rand(b, hkv, smax, vr)
        pos = jnp.int32(smax // 2)
        np.testing.assert_allclose(
            attn_decode_pallas(q, kc, vc, pos, 0.5),
            ref.attn_decode_ref(q, kc, vc, pos, 0.5),
            rtol=1e-4, atol=1e-4,
        )
