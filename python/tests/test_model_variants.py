"""L2 model: shapes, method equivalences, decode/prefill consistency, and
the paper's key runtime identities."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.config import ModelConfig, baseline_spec, VariantSpec
from compile.model import (
    decode_step,
    flatten_weights,
    forward_full,
    init_weights,
    loss_fn,
    prefill_with_cache,
    unflatten_weights,
)
from compile.rap import budget as budget_mod
from compile.rap.prune import build_rap_variant
from compile.rap.svd import build_svd_variant, reconstruction_error, truncated_svd_per_head
from compile.rap.palu import build_palu_variant

RNG = np.random.default_rng(99)


def toks(b, s, vocab=256):
    return jnp.asarray(RNG.integers(0, vocab, (b, s)).astype(np.int32))


class TestForward:
    def test_logits_shape(self, micro_cfg, micro_weights):
        spec = baseline_spec(micro_cfg)
        t = toks(2, 12)
        out = forward_full(micro_cfg, spec, micro_weights, t)
        assert out.shape == (2, 12, micro_cfg.vocab)

    def test_causality(self, micro_cfg, micro_weights):
        """Changing a future token must not affect earlier logits."""
        spec = baseline_spec(micro_cfg)
        t = np.asarray(toks(1, 10))
        t2 = t.copy()
        t2[0, -1] = (t2[0, -1] + 7) % 256
        a = forward_full(micro_cfg, spec, micro_weights, jnp.asarray(t))
        b = forward_full(micro_cfg, spec, micro_weights, jnp.asarray(t2))
        np.testing.assert_allclose(a[:, :-1], b[:, :-1], rtol=1e-5, atol=1e-5)
        assert not np.allclose(a[:, -1], b[:, -1])

    def test_noop_rap_equals_baseline(self, micro_cfg, micro_weights, micro_scores, micro_covs):
        """Keeping all pairs and full V-rank must reproduce the baseline
        (the binary expansion is a permutation; whitened full-rank SVD is
        exact)."""
        cfg = micro_cfg
        m = [cfg.n_pairs] * cfg.n_layers
        rv = [cfg.head_dim] * cfg.n_layers
        v = build_rap_variant(cfg, micro_weights, micro_scores, micro_covs, m, rv, 0.0)
        t = toks(1, 16)
        a = forward_full(cfg, baseline_spec(cfg), micro_weights, t)
        b = forward_full(cfg, v["spec"], v["weights"], t)
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3)

    def test_full_rank_svd_equals_baseline(self, micro_cfg, micro_weights):
        cfg = micro_cfg
        v = build_svd_variant(cfg, micro_weights, cfg.head_dim, cfg.head_dim, 0.0)
        t = toks(1, 16)
        a = forward_full(cfg, baseline_spec(cfg), micro_weights, t)
        b = forward_full(cfg, v["spec"], v["weights"], t)
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3)

    def test_interleaved_model_runs(self, micro_cfg_interleaved):
        cfg = micro_cfg_interleaved
        w = init_weights(cfg, 1)
        out = forward_full(cfg, baseline_spec(cfg), w, toks(1, 8))
        assert np.isfinite(np.asarray(out)).all()


class TestDecodeConsistency:
    @pytest.mark.parametrize("method", ["baseline", "svd", "palu", "rap"])
    def test_prefill_plus_decode_matches_full(
        self, method, micro_cfg, micro_weights, micro_scores, micro_covs, micro_rap
    ):
        cfg = micro_cfg
        if method == "baseline":
            spec, w = baseline_spec(cfg), micro_weights
        elif method == "rap":
            spec, w = micro_rap["spec"], micro_rap["weights"]
        elif method == "svd":
            v = build_svd_variant(cfg, micro_weights, 11, 11, 0.3)
            spec, w = v["spec"], v["weights"]
        else:
            v = build_palu_variant(cfg, micro_weights, micro_covs, [11] * cfg.n_layers,
                                   [11] * cfg.n_layers, 0.3)
            spec, w = v["spec"], v["weights"]
        t = toks(1, 12)
        full = forward_full(cfg, spec, w, t)
        logits, kc, vc = prefill_with_cache(cfg, spec, w, t[:, :8], 24, use_pallas=False)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, 7]), rtol=2e-4, atol=2e-4
        )
        for i in range(8, 12):
            logits, kc, vc = decode_step(
                cfg, spec, w, t[:, i], jnp.int32(i), kc, vc, use_pallas=False
            )
            np.testing.assert_allclose(
                np.asarray(logits), np.asarray(full[:, i]), rtol=5e-4, atol=5e-4
            )

    def test_pallas_serving_path_matches_jnp(self, micro_cfg, micro_rap):
        cfg, spec, w = micro_cfg, micro_rap["spec"], micro_rap["weights"]
        t = toks(2, 9)
        l1, kc1, vc1 = prefill_with_cache(cfg, spec, w, t, 16, use_pallas=False)
        l2, kc2, vc2 = prefill_with_cache(cfg, spec, w, t, 16, use_pallas=True)
        np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)
        nt = toks(2, 1)[:, 0]
        d1, _, _ = decode_step(cfg, spec, w, nt, jnp.int32(9), kc1, vc1, use_pallas=False)
        d2, _, _ = decode_step(cfg, spec, w, nt, jnp.int32(9), kc2, vc2, use_pallas=True)
        np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-4)

    def test_cache_shapes_are_latent(self, micro_cfg, micro_rap):
        """The cache must store the *compressed* widths — that is the claim."""
        cfg, spec, w = micro_cfg, micro_rap["spec"], micro_rap["weights"]
        _, kc, vc = prefill_with_cache(cfg, spec, w, toks(1, 8), 16, use_pallas=False)
        for l in range(cfg.n_layers):
            assert kc[l].shape == (1, cfg.n_kv_heads, 16, spec.k_rank[l])
            assert vc[l].shape == (1, cfg.n_kv_heads, 16, spec.v_rank[l])
            assert spec.k_rank[l] < cfg.head_dim  # actually compressed


class TestSVD:
    def test_error_decreases_with_rank(self, micro_cfg, micro_weights):
        w = np.asarray(micro_weights["layers"][0]["wk"])
        errs = []
        for rank in (2, 4, 8, 16):
            a, b = truncated_svd_per_head(w, micro_cfg.n_kv_heads, rank)
            errs.append(reconstruction_error(w, a, b, micro_cfg.n_kv_heads))
        assert errs == sorted(errs, reverse=True)
        assert errs[-1] < 1e-5  # full rank is exact

    def test_whitened_beats_plain_in_activation_norm(
        self, micro_cfg, micro_weights, micro_covs, micro_calib
    ):
        """Whitening minimises ||X(W-Ŵ)||_F, so it should win in that norm."""
        from compile.rap.svd import whitened_svd_per_head
        cfg = micro_cfg
        w = np.asarray(micro_weights["layers"][0]["wk"])
        cov = micro_covs[0]
        rank = 6
        a1, b1 = truncated_svd_per_head(w, cfg.n_kv_heads, rank)
        a2, b2 = whitened_svd_per_head(w, cov, cfg.n_kv_heads, rank)
        # compare activation-space error via the covariance quadratic form
        def act_err(a, b):
            dh = cfg.head_dim
            r = a.shape[1] // cfg.n_kv_heads
            err = 0.0
            for h in range(cfg.n_kv_heads):
                wh = w[:, h * dh : (h + 1) * dh]
                ah = a[:, h * r : (h + 1) * r]
                dw = wh - ah @ b[h]
                err += float(np.trace(dw.T @ cov @ dw))
            return err
        assert act_err(a2, b2) <= act_err(a1, b1) * 1.001


class TestFlatten:
    @pytest.mark.parametrize("method", ["baseline", "rap"])
    def test_roundtrip(self, method, micro_cfg, micro_weights, micro_rap):
        cfg = micro_cfg
        if method == "baseline":
            spec, w = baseline_spec(cfg), micro_weights
        else:
            spec, w = micro_rap["spec"], micro_rap["weights"]
        flat = flatten_weights(spec, w)
        names = {n: a for n, a in flat}
        w2 = unflatten_weights(spec, cfg.n_layers, names)
        t = toks(1, 6)
        np.testing.assert_allclose(
            forward_full(cfg, spec, w, t), forward_full(cfg, spec, w2, t),
            atol=1e-6,
        )

    def test_deterministic_order(self, micro_cfg, micro_rap):
        f1 = [n for n, _ in flatten_weights(micro_rap["spec"], micro_rap["weights"])]
        f2 = [n for n, _ in flatten_weights(micro_rap["spec"], micro_rap["weights"])]
        assert f1 == f2
        assert f1[0] == "tok_emb" and f1[-1] == "final_norm"


class TestCompressionQuality:
    def test_rap_loss_reasonable_after_prune(
        self, micro_cfg, micro_weights, micro_rap, micro_calib
    ):
        """On an untrained micro model the pruned loss should stay within a
        modest factor of baseline (scores are still informative)."""
        x, y = micro_calib[0]
        base = float(loss_fn(micro_cfg, baseline_spec(micro_cfg), micro_weights,
                             jnp.asarray(x), jnp.asarray(y)))
        pruned = float(loss_fn(micro_cfg, micro_rap["spec"], micro_rap["weights"],
                               jnp.asarray(x), jnp.asarray(y)))
        assert pruned < base * 1.5
