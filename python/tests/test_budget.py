"""Algorithm 2 invariants: valid ratios, exact mean, sensitivity ordering."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.config import ModelConfig
from compile.rap import budget


def _scores(l, hkv, p, dh, rng):
    return [
        {
            "k_pairs": rng.uniform(0.1, 10, (hkv, p)),
            "v_cols": rng.uniform(0.1, 10, (hkv, dh)),
        }
        for _ in range(l)
    ]


CFG = ModelConfig(name="t", d_model=64, n_layers=4, n_heads=4, n_kv_heads=2,
                  head_dim=16, mlp_hidden=64)


class TestAllocate:
    def test_mean_equals_rho(self):
        rng = np.random.default_rng(0)
        s = _scores(4, 2, 8, 16, rng)
        for rho in (0.1, 0.3, 0.5, 0.9):
            rk, rv = budget.allocate(s, rho)
            flat = np.concatenate([rk, rv])
            assert abs(flat.mean() - rho) < 1e-9
            assert (flat >= 0).all() and (flat <= 1).all()

    def test_sensitive_groups_pruned_less(self):
        """A group with higher Fisher mass gets a lower compression ratio."""
        rng = np.random.default_rng(1)
        s = _scores(4, 2, 8, 16, rng)
        # Make layer 0's K group vastly more sensitive than layer 3's.
        s[0]["k_pairs"][:] = 100.0
        s[3]["k_pairs"][:] = 0.001
        rk, _ = budget.allocate(s, 0.3)
        assert rk[0] < rk[3]

    def test_equal_scores_give_uniform(self):
        s = [
            {"k_pairs": np.ones((2, 8)), "v_cols": np.ones((2, 16)) * 0.5}
            for _ in range(4)
        ]
        # make all group totals identical
        for e in s:
            e["v_cols"] = np.ones((2, 16)) * (8 * 2 / (16 * 2))
        rk, rv = budget.allocate(s, 0.25)
        np.testing.assert_allclose(rk, 0.25, atol=1e-9)
        np.testing.assert_allclose(rv, 0.25, atol=1e-9)

    @settings(max_examples=25, deadline=None)
    @given(
        rho=st.floats(0.05, 0.95),
        l=st.integers(1, 8),
        seed=st.integers(0, 1000),
    )
    def test_hypothesis_valid(self, rho, l, seed):
        rng = np.random.default_rng(seed)
        s = _scores(l, 2, 8, 16, rng)
        rk, rv = budget.allocate(s, rho)
        flat = np.concatenate([rk, rv])
        assert (flat >= -1e-12).all() and (flat <= 1 + 1e-12).all()
        assert abs(flat.mean() - rho) < 1e-6


class TestProjectMean:
    def test_already_feasible_fixed_point(self):
        x = np.array([0.2, 0.4])
        y = budget.project_mean(x, 0.3)
        np.testing.assert_allclose(y.mean(), 0.3)

    def test_clipping_redistributes(self):
        x = np.array([2.0, 0.0, 0.0, 0.0])  # clips to [1,0,0,0], mean .25
        y = budget.project_mean(x, 0.5)
        assert abs(y.mean() - 0.5) < 1e-9
        assert y[0] == 1.0

    @settings(max_examples=50, deadline=None)
    @given(
        n=st.integers(1, 30),
        target=st.floats(0.0, 1.0),
        seed=st.integers(0, 10_000),
    )
    def test_hypothesis(self, n, target, seed):
        rng = np.random.default_rng(seed)
        x = rng.uniform(-1, 2, n)
        y = budget.project_mean(x, target)
        assert (y >= -1e-12).all() and (y <= 1 + 1e-12).all()
        assert abs(y.mean() - target) < 1e-6


class TestRanks:
    def test_ranks_bounds(self):
        rng = np.random.default_rng(2)
        s = _scores(CFG.n_layers, CFG.n_kv_heads, CFG.n_pairs, CFG.head_dim, rng)
        for rho in (0.1, 0.3, 0.5, 0.8):
            rk, rv = budget.allocate(s, rho)
            m, rvv = budget.ranks_from_ratios(CFG, rk, rv)
            assert all(1 <= x <= CFG.n_pairs for x in m)
            assert all(1 <= x <= CFG.head_dim for x in rvv)

    def test_achieved_ratio_close_to_target(self):
        rng = np.random.default_rng(3)
        s = _scores(CFG.n_layers, CFG.n_kv_heads, CFG.n_pairs, CFG.head_dim, rng)
        for rho in (0.2, 0.3, 0.4):
            rk, rv = budget.allocate(s, rho)
            m, rvv = budget.ranks_from_ratios(CFG, rk, rv)
            achieved = budget.achieved_kv_ratio(CFG, m, rvv)
            assert abs(achieved - (1 - rho)) < 0.05

    def test_uniform_ranks(self):
        m, rv = budget.uniform_ranks(CFG, 0.5)
        assert m == [CFG.n_pairs // 2] * CFG.n_layers
        assert rv == [CFG.head_dim // 2] * CFG.n_layers

    def test_zero_rho_keeps_everything(self):
        m, rv = budget.uniform_ranks(CFG, 0.0)
        assert m == [CFG.n_pairs] * CFG.n_layers
        assert rv == [CFG.head_dim] * CFG.n_layers
