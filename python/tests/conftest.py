"""Shared fixtures: a micro model + calibration products, built once."""

import sys, os

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
import jax.numpy as jnp
import pytest

from compile.config import ModelConfig, baseline_spec
from compile import data as data_mod
from compile.model import init_weights, forward_full
from compile.rap import fisher as fisher_mod, budget as budget_mod
from compile.rap.prune import build_rap_variant


@pytest.fixture(scope="session")
def micro_cfg():
    return ModelConfig(
        name="micro", d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
        head_dim=16, mlp_hidden=96, max_seq=128,
    )


@pytest.fixture(scope="session")
def micro_cfg_interleaved():
    return ModelConfig(
        name="micro_il", d_model=48, n_layers=2, n_heads=4, n_kv_heads=4,
        head_dim=12, mlp_hidden=64, max_seq=128, pairing="interleaved",
    )


@pytest.fixture(scope="session")
def micro_weights(micro_cfg):
    return init_weights(micro_cfg, seed=0)


@pytest.fixture(scope="session")
def micro_corpus():
    return data_mod.generate_corpus(1 << 16)


@pytest.fixture(scope="session")
def micro_calib(micro_corpus):
    tr, _ = data_mod.train_eval_split(micro_corpus)
    return list(data_mod.batches(tr, 2, 64, 2, 0))


@pytest.fixture(scope="session")
def micro_scores(micro_cfg, micro_weights, micro_calib):
    f = fisher_mod.accumulate_fisher(micro_cfg, micro_weights, micro_calib)
    return fisher_mod.pair_scores_from_fisher(micro_cfg, f)


@pytest.fixture(scope="session")
def micro_covs(micro_cfg, micro_weights, micro_calib):
    spec = baseline_spec(micro_cfg)
    x, _ = micro_calib[0]
    _, hid = forward_full(micro_cfg, spec, micro_weights, jnp.asarray(x), return_hiddens=True)
    covs = []
    for h in hid:
        hm = np.asarray(h, np.float64).reshape(-1, micro_cfg.d_model)
        covs.append(hm.T @ hm)
    return covs


@pytest.fixture(scope="session")
def micro_rap(micro_cfg, micro_weights, micro_scores, micro_covs):
    rho = 0.3
    rk, rv_ = budget_mod.allocate(micro_scores, rho)
    m, rv = budget_mod.ranks_from_ratios(micro_cfg, rk, rv_)
    return build_rap_variant(
        micro_cfg, micro_weights, micro_scores, micro_covs, m, rv, rho
    )
