//! Error-bound oracles for the selectable kernel paths (ISSUE 7).
//!
//! The Scalar path stays bit-identical to the preserved seed oracles —
//! that contract lives untouched in `tests/paged.rs` / `tests/prefill.rs`,
//! whose propchecks now dispatch both sides through the engine's kernel
//! path and therefore hold under any forced `RAP_KERNEL_PATH`.  This file
//! holds the *relaxed* contracts the ROADMAP sanctions for the non-scalar
//! paths:
//!
//! * Wide (8-lane f32) logits match Scalar within a per-logit abs
//!   tolerance, and greedy (temperature-0) argmax agrees wherever the
//!   scalar top-2 gap is not a near-tie;
//! * FusedInt4 over packed blocks is **bitwise** the same arithmetic as
//!   f32 storage + `quantize_kv` round-trips (prefill, any chunk
//!   partition) — the fused q4 kernels dequantize in-register to exactly
//!   the values the round-trip materializes;
//! * FusedInt4 vs plain f32 stays within the int4 quantization error
//!   budget with temperature-0 argmax agreement outside near-ties;
//! * packed storage really packs: more blocks per byte budget, and
//!   reconstruction-needing methods are rejected.

use rap::config::Method;
use rap::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Request};
use rap::kvcache::{CacheShape, KvLayerView, KvStorageMode, PagedKvCache};
use rap::model::backend::{BackendConfig, RustBackend};
use rap::model::synth::synth_engine;
use rap::model::{argmax, BatchWorkspace, Engine, PrefillWorkspace};
use rap::tensor::simd::KernelPath;

const METHODS: [Method; 4] = [Method::Baseline, Method::Svd, Method::Palu, Method::Rap];

/// Methods whose attention never reconstructs K/V — the ones packed-int4
/// storage supports.
const PACKABLE: [Method; 2] = [Method::Baseline, Method::Rap];

fn prompt(n: usize) -> Vec<u8> {
    (0..n).map(|i| (i * 7 % 251) as u8).collect()
}

/// Prefill `prompt`, then teacher-force `n_steps` fixed tokens through the
/// dense decode path; returns the logits at every step (prefill last-token
/// logits first).  Teacher forcing keeps both kernel paths on the same
/// token sequence even where a near-tie would flip greedy sampling.
fn forced_dense_logits(engine: &Engine, prompt: &[u8], n_steps: usize) -> Vec<Vec<f32>> {
    let mut cache = engine.new_cache(prompt.len() + n_steps + 1);
    let mut out = vec![engine.prefill(prompt, &mut cache)];
    for i in 0..n_steps {
        let t = (i * 13 % 251) as u8;
        out.push(engine.step_reuse(t, prompt.len() + i, &mut cache).to_vec());
    }
    out
}

/// Per-logit abs-tol comparison plus temperature-0 argmax agreement with a
/// near-tie escape: where the reference's top-2 gap is below `2 * tol` a
/// bounded perturbation may legitimately flip the argmax.
fn assert_error_bound(reference: &[Vec<f32>], got: &[Vec<f32>], tol: f32, label: &str) {
    assert_eq!(reference.len(), got.len());
    for (step, (r, g)) in reference.iter().zip(got).enumerate() {
        assert_eq!(r.len(), g.len());
        for (t, (&rv, &gv)) in r.iter().zip(g).enumerate() {
            assert!(
                (rv - gv).abs() <= tol,
                "{label}: step {step} logit {t}: {rv} vs {gv} (tol {tol})"
            );
        }
        let top = argmax(r);
        let gap = r
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != top)
            .map(|(_, &v)| r[top] - v)
            .fold(f32::INFINITY, f32::min);
        if gap > 2.0 * tol {
            assert_eq!(
                argmax(g),
                top,
                "{label}: step {step}: temperature-0 argmax must agree (gap {gap})"
            );
        }
    }
}

#[test]
fn wide_path_matches_scalar_within_tolerance_on_all_methods() {
    for method in METHODS {
        let mut engine = synth_engine(method, 7);
        engine.set_kernel_path(KernelPath::Scalar);
        let scalar = forced_dense_logits(&engine, &prompt(48), 8);
        engine.set_kernel_path(KernelPath::Wide);
        let wide = forced_dense_logits(&engine, &prompt(48), 8);
        assert_error_bound(&scalar, &wide, 1e-3, &format!("wide/{method:?}"));
    }
}

/// Paged prefill of `prompt` in `chunk`-token chunks under `mode`; returns
/// the last-token logits.
fn paged_prefill_logits(
    engine: &Engine,
    mode: KvStorageMode,
    prompt: &[u8],
    chunk: usize,
    quantize_kv: bool,
) -> Vec<f32> {
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let mut kv = PagedKvCache::with_storage_mode(shape, 8 << 20, mode);
    kv.reserve(1, prompt.len() + 8).unwrap();
    let mut ws = PrefillWorkspace::new(engine, prompt.len() + 8);
    let mut pos0 = 0;
    while pos0 < prompt.len() {
        let end = (pos0 + chunk).min(prompt.len());
        engine
            .prefill_chunk_paged(
                1,
                &prompt[pos0..end],
                pos0,
                &mut kv,
                &mut ws,
                end == prompt.len(),
                quantize_kv,
            )
            .unwrap();
        pos0 = end;
    }
    ws.logits().to_vec()
}

/// Packed-int4 storage quantizes on write and attends through the fused q4
/// kernels without ever materializing f32 rows — yet its prefill is
/// BITWISE the f32-storage `quantize_kv` round-trip path, for any chunk
/// partition of either side (both quantize every row before any attention
/// read).  This is the end-to-end exactness oracle for
/// `quant::dot_rows_scaled_q4` / `quant::axpy_rows_q4`.
#[test]
fn packed_prefill_is_bitwise_the_quantize_kv_roundtrip_path() {
    for method in PACKABLE {
        for (seed, n, packed_chunk, f32_chunk) in [(1u64, 37usize, 8usize, 3usize), (2, 64, 16, 1)]
        {
            let mut engine = synth_engine(method, seed);
            engine.set_kernel_path(KernelPath::Scalar);
            let p = prompt(n);
            let packed =
                paged_prefill_logits(&engine, KvStorageMode::PackedInt4, &p, packed_chunk, false);
            let f32_rt = paged_prefill_logits(&engine, KvStorageMode::F32, &p, f32_chunk, true);
            assert_eq!(packed.len(), f32_rt.len());
            for (t, (a, b)) in packed.iter().zip(&f32_rt).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{method:?} seed {seed}: logit {t}: packed {a} != round-tripped {b}"
                );
            }
        }
    }
}

/// Fused-int4 end to end (packed storage + FusedInt4 kernels, prefill then
/// teacher-forced paged decode) stays close to the Scalar `quantize_kv`
/// round-trip path — the reference with the *same* int4 error budget.
/// Prefill is bitwise (previous test); decode differs only in the wide
/// reassociation and in each step reading its own just-written row
/// quantized (packed) vs full-precision (the f32 round-trip happens after
/// the step, as in `RustBackend::quantize_range`).
#[test]
fn fused_int4_decode_tracks_the_scalar_quantize_kv_path() {
    for method in PACKABLE {
        let p = prompt(40);
        let n_steps = 8;
        let mut runs = Vec::new();
        for (path, mode) in [
            (KernelPath::Scalar, KvStorageMode::F32),
            (KernelPath::FusedInt4, KvStorageMode::PackedInt4),
        ] {
            let mut engine = synth_engine(method, 11);
            engine.set_kernel_path(path);
            let shape = CacheShape::of(&engine.cfg, &engine.spec);
            let mut kv = PagedKvCache::with_storage_mode(shape, 8 << 20, mode);
            let s_max = p.len() + n_steps + 1;
            kv.reserve(1, s_max).unwrap();
            let mut ws = PrefillWorkspace::new(&engine, s_max);
            engine
                .prefill_chunk_paged(1, &p, 0, &mut kv, &mut ws, true, true)
                .unwrap();
            let mut logits = vec![ws.logits().to_vec()];
            let mut batch = BatchWorkspace::new(&engine, s_max);
            for i in 0..n_steps {
                let pos = p.len() + i;
                let t = (i * 13 % 251) as u8;
                engine
                    .decode_batch_paged(&[(1, t, pos)], &mut kv, &mut batch, true)
                    .unwrap();
                if !kv.storage_mode().is_packed() {
                    // Post-step round-trip, exactly like the backend.
                    let (pages, store) = kv.tables_and_ptrs().unwrap();
                    let blocks = pages.blocks(1).unwrap();
                    for l in 0..engine.cfg.n_layers {
                        // SAFETY: one view at a time, single-threaded.
                        let mut view = unsafe { store.seq_layer(l, blocks) };
                        for h in 0..engine.cfg.n_kv_heads {
                            rap::kvcache::quant::roundtrip(view.k_row_mut(h, pos));
                            rap::kvcache::quant::roundtrip(view.v_row_mut(h, pos));
                        }
                    }
                }
                logits.push(batch.logits_row(0).to_vec());
            }
            runs.push(logits);
        }
        assert_error_bound(&runs[0], &runs[1], 0.5, &format!("fused-int4/{method:?}"));
    }
}

#[test]
fn packed_storage_rejects_reconstructing_methods() {
    for method in [Method::Svd, Method::Palu] {
        let engine = synth_engine(method, 3);
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let mut kv = PagedKvCache::with_storage_mode(shape, 4 << 20, KvStorageMode::PackedInt4);
        kv.reserve(1, 64).unwrap();
        let mut ws = PrefillWorkspace::new(&engine, 64);
        let err = engine
            .prefill_chunk_paged(1, &prompt(8), 0, &mut kv, &mut ws, true, false)
            .unwrap_err();
        assert!(err.to_string().contains("packed-int4"), "{err}");
        let mut batch = BatchWorkspace::new(&engine, 64);
        let err = engine
            .decode_batch_paged(&[(1, 5, 0)], &mut kv, &mut batch, true)
            .unwrap_err();
        assert!(err.to_string().contains("packed-int4"), "{err}");
    }
}

/// Same byte budget → strictly more packed blocks, and a packed block costs
/// at most half its f32 counterpart (the decode-bytes claim of
/// `BENCH_kernels.json`, checked here on the layout itself).
#[test]
fn packed_storage_fits_more_blocks_in_the_same_budget() {
    for method in PACKABLE {
        let engine = synth_engine(method, 5);
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        assert!(
            2 * shape.bytes_per_block_for(KvStorageMode::PackedInt4)
                <= shape.bytes_per_block_for(KvStorageMode::F32),
            "{method:?}: packed block must cost at most half the f32 block"
        );
        let budget = 1 << 20;
        let f32_kv = PagedKvCache::with_storage_mode(shape.clone(), budget, KvStorageMode::F32);
        let packed_kv = PagedKvCache::with_storage_mode(shape, budget, KvStorageMode::PackedInt4);
        assert!(
            packed_kv.capacity_blocks() >= 2 * f32_kv.capacity_blocks(),
            "{method:?}: {} packed vs {} f32 blocks",
            packed_kv.capacity_blocks(),
            f32_kv.capacity_blocks()
        );
        assert_eq!(packed_kv.storage_mode(), KvStorageMode::PackedInt4);
        assert_eq!(packed_kv.resident_kv_bytes(), 0);
    }
}

/// `BackendConfig` threads the kernel path into the engine and the storage
/// mode through the coordinator: a FusedInt4 RAP backend serves requests
/// over a packed cache, and the metrics report says so.
#[test]
fn coordinator_plumbs_packed_storage_from_backend_config() {
    let mut engine = synth_engine(Method::Rap, 9);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let backend = RustBackend::with_config(
        &mut engine,
        96,
        BackendConfig { kernel_path: KernelPath::FusedInt4, quantize_kv: false },
    );
    let mut coord = Coordinator::new(
        backend,
        shape,
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: 2,
                buckets: vec![1, 2],
                max_queue: 8,
                ..Default::default()
            },
            kv_budget_bytes: 4 << 20,
        },
    );
    assert!(coord.submit(Request::new(1, prompt(12), 6)));
    assert!(coord.submit(Request::new(2, prompt(20), 4)));
    let responses = coord.run_to_completion().unwrap();
    assert_eq!(responses.len(), 2);
    for r in &responses {
        assert!(!r.generated.is_empty());
    }
    assert_eq!(coord.metrics.kv_storage_mode, "packed-int4");
    assert!(coord.metrics.peak_kv_resident_bytes > 0);
    let report = coord.metrics.report();
    assert!(report.contains("storage=packed-int4"), "{report}");

    // SVD reconstructs K/V, so the same config must fall back to f32
    // storage instead of handing the engine a cache it cannot read.
    let mut engine = synth_engine(Method::Svd, 9);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let backend = RustBackend::with_config(
        &mut engine,
        96,
        BackendConfig { kernel_path: KernelPath::FusedInt4, quantize_kv: false },
    );
    let coord = Coordinator::new(
        backend,
        shape,
        CoordinatorConfig {
            batcher: BatcherConfig { max_sessions: 2, ..Default::default() },
            kv_budget_bytes: 4 << 20,
        },
    );
    assert_eq!(coord.metrics.kv_storage_mode, "f32");
}
