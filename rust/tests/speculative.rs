//! Speculative-decode propchecks over the real engine (synthetic
//! weights — runs without `make artifacts`).
//!
//! The contract under test is **bit-identity**: a request served with
//! `speculative: {ngram, k}` must produce byte-for-byte the output of
//! the same request served plain, for greedy AND seeded sampling, on
//! every kernel path × storage mode, and under an active retention
//! press.  Acceptance draws every emitted token from the verifier's
//! logits through the request's own seeded sampler, and the verify
//! chunk reuses the blocked prefill kernel that `tests/prefill.rs` pins
//! bitwise to token-by-token decode — so any divergence here means a
//! broken invariant, not a tuning regression.
//!
//! Satellites: rejected-draft rollback keeps `kv_used_blocks()` on the
//! plain-decode baseline at every tick boundary, cancelling a
//! speculative session mid-stream returns blocks to the pre-admission
//! baseline, and injected decode faults during verify chunks retry
//! without perturbing output.

use rap::config::Method;
use rap::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, Event, FaultBackend, Request, SamplingParams,
};
use rap::faults::FaultPlan;
use rap::kvcache::retention::{Press, RetentionSpec};
use rap::kvcache::CacheShape;
use rap::model::backend::{BackendConfig, RustBackend};
use rap::model::synth::synth_engine;
use rap::model::Engine;
use rap::speculate::SpeculativeSpec;
use rap::tensor::simd::KernelPath;

const METHODS: [Method; 4] = [Method::Baseline, Method::Svd, Method::Palu, Method::Rap];

/// Methods packed-int4 storage supports (no K/V reconstruction).
const PACKABLE: [Method; 2] = [Method::Baseline, Method::Rap];

/// A highly self-similar prompt: the n-gram drafter finds prior
/// occurrences of most suffixes, so speculation genuinely fires.
fn repetitive_prompt(n: usize) -> Vec<u8> {
    let phrase = b"the quick latent cache ran past the quick latent press ";
    (0..n).map(|i| phrase[i % phrase.len()]).collect()
}

struct Served {
    generated: Vec<u8>,
    spec_steps: u64,
    accepted: u64,
    rolled_back: u64,
}

/// Serve one request through the coordinator; both the retention and the
/// speculative fleet defaults are pinned off so the run is
/// env-independent under the CI matrices — the specs under test ride the
/// request itself.
fn serve(
    method: Method,
    path: KernelPath,
    quantize_kv: bool,
    speculative: Option<SpeculativeSpec>,
    retention: Option<RetentionSpec>,
    sampling: SamplingParams,
    prompt: Vec<u8>,
    max_new: usize,
) -> Served {
    let mut engine = synth_engine(method, 17);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let backend =
        RustBackend::with_config(&mut engine, 2048, BackendConfig { kernel_path: path, quantize_kv });
    let mut coord = Coordinator::new(
        backend,
        shape,
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: 2,
                buckets: vec![1],
                max_queue: 4,
                prefill_chunk_tokens: 256,
                default_retention: None,
                default_speculative: None,
                ..Default::default()
            },
            kv_budget_bytes: 64 << 20,
        },
    );
    let mut req = Request::new(1, prompt, max_new).with_sampling(sampling);
    if let Some(spec) = speculative {
        req = req.with_speculative(spec);
    }
    if let Some(spec) = retention {
        req = req.with_retention(spec);
    }
    assert!(coord.submit(req));
    let responses = coord.run_to_completion().unwrap();
    assert_eq!(responses.len(), 1);
    assert_eq!(responses[0].generated.len(), max_new);
    assert_eq!(coord.kv_used_blocks(), 0, "completion releases every block");
    Served {
        generated: responses[0].generated.clone(),
        spec_steps: coord.metrics.spec_steps,
        accepted: coord.metrics.spec_accepted_tokens,
        rolled_back: coord.metrics.spec_rolled_back_rows,
    }
}

/// Greedy speculative output is byte-identical to plain greedy decode on
/// every method × kernel path × storage mode, including the
/// `quantize_kv` f32 round-trip mode (where the backend verifies by
/// sequential re-decode instead of the blocked chunk).
#[test]
fn speculative_greedy_is_bitwise_inert_on_every_kernel_path() {
    let spec = SpeculativeSpec::parse("ngram:4").unwrap();
    let mut combos: Vec<(Method, KernelPath, bool)> = Vec::new();
    for m in METHODS {
        combos.push((m, KernelPath::Scalar, false));
        combos.push((m, KernelPath::Wide, false));
        combos.push((m, KernelPath::Scalar, true)); // quantize_kv fallback
    }
    for m in PACKABLE {
        combos.push((m, KernelPath::FusedInt4, false)); // packed-int4 storage
    }
    for (method, path, quant) in combos {
        let prompt = repetitive_prompt(200);
        let greedy = SamplingParams::greedy();
        let plain =
            serve(method, path, quant, None, None, greedy.clone(), prompt.clone(), 24);
        let fast = serve(method, path, quant, Some(spec), None, greedy, prompt, 24);
        assert_eq!(
            fast.generated, plain.generated,
            "{method:?}/{path:?} quant={quant}: speculative greedy output must be bit-identical"
        );
        assert_eq!(plain.spec_steps, 0, "the plain arm must not speculate");
    }
}

/// Seeded sampled speculative output equals plain sampled decode — the
/// per-request RNG stream advances exactly once per emitted token in
/// both runs, across seeds and kernel paths.
#[test]
fn speculative_seeded_sampling_is_bitwise_inert() {
    let spec = SpeculativeSpec::parse("ngram:6").unwrap();
    for (method, path) in [
        (Method::Rap, KernelPath::Scalar),
        (Method::Rap, KernelPath::FusedInt4),
        (Method::Baseline, KernelPath::Wide),
    ] {
        for seed in [1u64, 7, 42] {
            let sampling =
                SamplingParams { temperature: 0.9, top_k: 24, top_p: 0.95, seed };
            let prompt = repetitive_prompt(160);
            let plain = serve(
                method, path, false, None, None, sampling.clone(), prompt.clone(), 24,
            );
            let fast = serve(method, path, false, Some(spec), None, sampling, prompt, 24);
            assert_eq!(
                fast.generated, plain.generated,
                "{method:?}/{path:?} seed {seed}: sampled speculative output must be bit-identical"
            );
        }
    }
}

/// Speculation under an active Window press: the draft budget refuses to
/// cross a press boundary mid-step, so the press fires at the same
/// logical lengths as in the plain run and output stays identical even
/// while rows are being evicted.
#[test]
fn speculative_under_active_window_press_is_bitwise_inert() {
    let spec = SpeculativeSpec::parse("ngram:4").unwrap();
    let press = RetentionSpec { press: Press::Window, ratio: 0.5 };
    for path in [KernelPath::Scalar, KernelPath::Wide] {
        let prompt = repetitive_prompt(700);
        let greedy = SamplingParams::greedy();
        let plain = serve(
            Method::Rap, path, false, None, Some(press), greedy.clone(), prompt.clone(), 24,
        );
        let fast =
            serve(Method::Rap, path, false, Some(spec), Some(press), greedy, prompt, 24);
        assert_eq!(
            fast.generated, plain.generated,
            "{path:?}: speculative output under an active press must be bit-identical"
        );
    }
}

/// After every tick, a speculative session's `kv_used_blocks()` sits
/// exactly on the plain run's baseline for the same generated length —
/// accepted rows stay, every rejected draft row's block drains back to
/// the pool, nothing is stranded in between.
#[test]
fn rollback_keeps_blocks_on_the_plain_decode_baseline_every_tick() {
    fn build(
        engine: &mut Engine,
        speculative: Option<SpeculativeSpec>,
    ) -> Coordinator<RustBackend<'_>> {
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let backend = RustBackend::with_config(
            engine,
            1024,
            BackendConfig { kernel_path: KernelPath::Scalar, quantize_kv: false },
        );
        let mut coord = Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 1,
                    buckets: vec![1],
                    max_queue: 2,
                    prefill_chunk_tokens: 256,
                    default_retention: None,
                    default_speculative: None,
                    ..Default::default()
                },
                kv_budget_bytes: 64 << 20,
            },
        );
        let mut req = Request::new(1, repetitive_prompt(120), 32);
        if let Some(spec) = speculative {
            req = req.with_speculative(spec);
        }
        assert!(coord.submit(req));
        coord
    }

    // Plain run: record used blocks after each tick, keyed by how many
    // tokens have been emitted so far.
    let mut plain_engine = synth_engine(Method::Rap, 17);
    let mut plain = build(&mut plain_engine, None);
    let mut baseline = std::collections::BTreeMap::new();
    let mut emitted = 0usize;
    while plain.pending() > 0 {
        for ev in plain.tick().unwrap() {
            if let Event::Token { .. } = ev {
                emitted += 1;
            }
        }
        baseline.insert(emitted, plain.kv_used_blocks());
    }
    assert_eq!(emitted, 32);

    // Speculative run: every tick boundary must land on that baseline.
    let spec = SpeculativeSpec::parse("ngram:4").unwrap();
    let mut fast_engine = synth_engine(Method::Rap, 17);
    let mut fast = build(&mut fast_engine, Some(spec));
    let mut emitted = 0usize;
    while fast.pending() > 0 {
        for ev in fast.tick().unwrap() {
            if let Event::Token { .. } = ev {
                emitted += 1;
            }
        }
        assert_eq!(
            fast.kv_used_blocks(),
            baseline[&emitted],
            "blocks at {emitted} emitted tokens must match the plain run"
        );
    }
    assert_eq!(emitted, 32);
    if fast.metrics.spec_rolled_back_rows == 0 {
        // Every draft was fully accepted — fine for this invariant, the
        // rejection path is separately forced below.
        eprintln!("note: no rejected rows this run; rollback exercised in cancel test");
    }
}

/// Cancelling a speculative session mid-stream returns `kv_used_blocks()`
/// to the pre-admission baseline — no draft row survives teardown.
#[test]
fn cancel_mid_speculation_returns_blocks_to_baseline() {
    let mut engine = synth_engine(Method::Rap, 17);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let backend = RustBackend::with_config(
        &mut engine,
        1024,
        BackendConfig { kernel_path: KernelPath::Scalar, quantize_kv: false },
    );
    let mut coord = Coordinator::new(
        backend,
        shape,
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: 1,
                buckets: vec![1],
                max_queue: 2,
                prefill_chunk_tokens: 256,
                default_retention: None,
                default_speculative: None,
                ..Default::default()
            },
            kv_budget_bytes: 64 << 20,
        },
    );
    let baseline = coord.kv_used_blocks();
    let spec = SpeculativeSpec::parse("ngram:4").unwrap();
    assert!(coord.submit(Request::new(1, repetitive_prompt(120), 64).with_speculative(spec)));
    // Run prefill plus a few decode ticks so speculative steps (and their
    // mid-step reservations) have actually happened, then tear down.
    for _ in 0..6 {
        coord.tick().unwrap();
    }
    assert!(coord.kv_used_blocks() > baseline, "session is mid-generation");
    let resp = coord.cancel(1).expect("session is live");
    assert!(resp.generated.len() < 64, "cancelled before completion");
    assert_eq!(
        coord.kv_used_blocks(),
        baseline,
        "cancel returns every block, including any speculative residue"
    );
}

/// Injected decode faults land on verify chunks too: the step is skipped,
/// its draft rows roll back, and the retried stream is byte-identical to
/// an unfaulted plain run.
#[test]
fn decode_faults_during_verify_retry_without_changing_output() {
    let greedy = SamplingParams::greedy();
    let prompt = repetitive_prompt(160);
    let plain =
        serve(Method::Rap, KernelPath::Scalar, false, None, None, greedy, prompt.clone(), 24);

    // Sweep plan seeds so "faults actually fired" holds with overwhelming
    // margin; parity is asserted unconditionally per run.
    let mut total_faults = 0u64;
    let mut total_retries = 0u64;
    for plan_seed in [3u64, 17, 29] {
        let mut engine = synth_engine(Method::Rap, 17);
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let inner = RustBackend::with_config(
            &mut engine,
            2048,
            BackendConfig { kernel_path: KernelPath::Scalar, quantize_kv: false },
        );
        let plan = FaultPlan::new(plan_seed).with_decode_faults(0.3);
        let backend = FaultBackend::new(inner, &plan);
        let mut coord = Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 2,
                    buckets: vec![1],
                    max_queue: 4,
                    prefill_chunk_tokens: 256,
                    default_retention: None,
                    default_speculative: None,
                    ..Default::default()
                },
                kv_budget_bytes: 64 << 20,
            },
        );
        let spec = SpeculativeSpec::parse("ngram:4").unwrap();
        assert!(coord.submit(Request::new(1, prompt.clone(), 24).with_speculative(spec)));
        let responses = coord.run_to_completion().unwrap();
        assert_eq!(
            responses[0].generated, plain.generated,
            "plan seed {plan_seed}: faults never corrupt output"
        );
        assert_eq!(coord.kv_used_blocks(), 0);
        let (_, decode_faults) = coord.backend.injected();
        total_faults += decode_faults;
        total_retries += coord.metrics.backend_retries;
    }
    assert!(total_faults > 0, "a 30% plan across three seeds must fire");
    assert!(total_retries > 0, "every injected fault is retried, not fatal");
}

/// The speculative counters hang together: accepted tokens never exceed
/// drafted tokens, every drafted-but-unaccepted row is accounted as
/// rolled back, and a run that speculated reports a sane tokens/step.
#[test]
fn speculative_counters_are_consistent() {
    let spec = SpeculativeSpec::parse("ngram:4").unwrap();
    let greedy = SamplingParams::greedy();
    let fast = serve(
        Method::Rap,
        KernelPath::Scalar,
        false,
        Some(spec),
        None,
        greedy,
        repetitive_prompt(200),
        32,
    );
    // Per step, emitted = accepted + 1 (divergence or bonus token), except
    // when the length finish lands on an accepted draft token — possible
    // once, on the final step.  Emission totals max_new, so:
    assert!(fast.accepted <= 4 * fast.spec_steps, "k bounds per-step acceptance");
    assert!(
        fast.accepted + fast.spec_steps <= 32 + 1,
        "speculative steps cannot emit past max_new"
    );
    let _ = fast.rolled_back; // tallied in the scheduler; non-negative by type
}
