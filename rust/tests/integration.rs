//! Integration tests over the real artifacts (require `make artifacts`).
//!
//! The heart of the suite is the three-way numerics cross-check:
//! python-JAX (ppl_python in the manifest) vs the pure-Rust engine vs the
//! PJRT execution of the exported HLO — all three must agree, proving the
//! L1/L2/L3 layers compose with identical semantics.

use rap::eval::{eval_ppl, probe_suite};
use rap::manifest::Manifest;
use rap::model::{argmax, load_engine, Weights};
use rap::runtime::{session::Session, PjrtContext, PjrtEngine};

fn manifest() -> Manifest {
    Manifest::load_default().expect("run `make artifacts` before cargo test")
}

#[test]
fn manifest_loads_with_expected_structure() {
    let m = manifest();
    assert!(m.models.contains_key("tinyllama"));
    assert!(m.models.contains_key("tinymistral"));
    let tl = &m.models["tinyllama"];
    assert!(tl.variants.len() >= 20, "got {}", tl.variants.len());
    assert!(tl.variants.contains_key("baseline_r00"));
    assert!(tl.variants.contains_key("rap_r30"));
    assert!(tl.hlo.contains_key("rap_r30"));
    assert!(!m.rope_bench.is_empty());
    // KV ratios encoded in the specs match the variant names.
    for rho in [10usize, 20, 30, 40, 50] {
        let v = &tl.variants[&format!("rap_r{rho}")];
        let retained = v.spec.kv_retained(&tl.config);
        assert!(
            (retained - (1.0 - rho as f64 / 100.0)).abs() < 0.03,
            "rap_r{rho}: retained {retained}"
        );
    }
}

#[test]
fn weights_load_and_have_expected_tensors() {
    let m = manifest();
    let entry = m.model("tinyllama").unwrap();
    let ve = &entry.variants["rap_r30"];
    let w = Weights::load(&m, ve).unwrap();
    assert!(w.has("tok_emb"));
    assert!(w.has("layers.0.wq_t"));
    assert!(w.has("layers.0.theta_sel"));
    assert!(w.has("final_norm"));
    // absorbed widths match the spec
    let wq_t = w.layer(0, "wq_t");
    assert_eq!(
        wq_t.shape,
        vec![entry.config.d_model, entry.config.n_heads * ve.spec.k_rank[0]]
    );
}

#[test]
fn rust_engine_ppl_tracks_python_ppl() {
    // Same windowing as python but fewer windows: values must be within a
    // modest tolerance and the METHOD ORDERING must match exactly.
    let m = manifest();
    let corpus = m.eval_corpus().unwrap();
    let mut pairs = Vec::new();
    for key in ["baseline_r00", "svd_r30", "palu_r30", "rap_r30"] {
        let engine = load_engine(&m, "tinyllama", key).unwrap();
        let rust_ppl = eval_ppl(&engine, &corpus, m.eval_seq, 8).unwrap();
        let py_ppl = m.models["tinyllama"].variants[key].ppl_python;
        assert!(
            (rust_ppl / py_ppl - 1.0).abs() < 0.25,
            "{key}: rust {rust_ppl} vs python {py_ppl}"
        );
        pairs.push((key, rust_ppl, py_ppl));
    }
    // ordering: baseline < palu < {svd, rap} in both measurements
    let rust_base = pairs[0].1;
    for (key, rust_ppl, _) in &pairs[1..] {
        assert!(rust_ppl > &rust_base, "{key} should degrade vs baseline");
    }
}

#[test]
fn pjrt_and_rust_engine_agree_on_logits() {
    // Decode the same 12-token sequence through both execution paths.
    let m = manifest();
    let ctx = PjrtContext::cpu().unwrap();
    let corpus = m.eval_corpus().unwrap();
    for key in ["baseline_r00", "rap_r30", "svd_r30", "palu_r30"] {
        if !m.models["tinyllama"].hlo.contains_key(key) {
            continue;
        }
        let pjrt = PjrtEngine::load(&ctx, &m, "tinyllama", key).unwrap();
        let rust = load_engine(&m, "tinyllama", key).unwrap();

        let seq = &corpus[..12];
        // rust path
        let mut cache = rust.new_cache(pjrt.s_max);
        let mut rust_logits = Vec::new();
        for (i, &t) in seq.iter().enumerate() {
            rust_logits = rust.step(t, i, &mut cache);
        }
        // pjrt path
        let mut caches = pjrt.empty_caches(1).unwrap();
        let mut pjrt_logits = Vec::new();
        for (i, &t) in seq.iter().enumerate() {
            let out = pjrt
                .decode(&ctx, 1, &[t as i32], &[i as i32], &caches)
                .unwrap();
            caches = out.caches;
            pjrt_logits = out.logits;
        }
        let max_diff: f32 = rust_logits
            .iter()
            .zip(&pjrt_logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max_diff < 2e-2, "{key}: max logit diff {max_diff}");
        assert_eq!(
            argmax(&rust_logits),
            argmax(&pjrt_logits),
            "{key}: argmax disagreement"
        );
    }
}

#[test]
fn pjrt_prefill_bucket_matches_stepwise_decode() {
    let m = manifest();
    let ctx = PjrtContext::cpu().unwrap();
    let corpus = m.eval_corpus().unwrap();
    let engine = PjrtEngine::load(&ctx, &m, "tinyllama", "rap_r30").unwrap();

    let prompt = &corpus[..32];
    // bucketed prefill
    let tokens: Vec<i32> = prompt.iter().map(|&b| b as i32).collect();
    let bucketed = engine.prefill(&ctx, "prefill32", &tokens, 1).unwrap();
    // stepwise
    let mut caches = engine.empty_caches(1).unwrap();
    let mut logits = Vec::new();
    for (i, &t) in prompt.iter().enumerate() {
        let out = engine
            .decode(&ctx, 1, &[t as i32], &[i as i32], &caches)
            .unwrap();
        caches = out.caches;
        logits = out.logits;
    }
    let max_diff: f32 = bucketed
        .logits
        .iter()
        .zip(&logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(max_diff < 2e-2, "prefill bucket vs stepwise: {max_diff}");
    // caches must agree too (same latent layout)
    for (l, (a, b)) in bucketed.caches.iter().zip(&caches).enumerate() {
        let kd: f32 = a.k.iter().zip(&b.k).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
        assert!(kd < 2e-2, "layer {l} K cache diff {kd}");
    }
}

#[test]
fn mixed_position_batched_decode_matches_single() {
    // Two sessions at different offsets in one decode_b4 call must produce
    // the same logits as batch-1 calls (continuous-batching correctness).
    let m = manifest();
    let ctx = PjrtContext::cpu().unwrap();
    let corpus = m.eval_corpus().unwrap();
    let engine = PjrtEngine::load(&ctx, &m, "tinyllama", "rap_r30").unwrap();

    // session A: 5 tokens, session B: 9 tokens.
    let fill = |n: usize| {
        let mut caches = engine.empty_caches(1).unwrap();
        let mut logits = Vec::new();
        for (i, &t) in corpus[..n].iter().enumerate() {
            let out = engine
                .decode(&ctx, 1, &[t as i32], &[i as i32], &caches)
                .unwrap();
            caches = out.caches;
            logits = out.logits;
        }
        (caches, logits)
    };
    let (ca, _la) = fill(5);
    let (cb, _lb) = fill(9);

    // batch-1 references for the NEXT token
    let ra = engine
        .decode(&ctx, 1, &[corpus[5] as i32], &[5], &ca)
        .unwrap();
    let rb = engine
        .decode(&ctx, 1, &[corpus[9] as i32], &[9], &cb)
        .unwrap();

    // batched call (bucket 4 padded with zeros)
    let mut batch_caches = Vec::new();
    for l in 0..engine.n_layers {
        let mut k = Vec::new();
        let mut v = Vec::new();
        k.extend_from_slice(&ca[l].k);
        k.extend_from_slice(&cb[l].k);
        v.extend_from_slice(&ca[l].v);
        v.extend_from_slice(&cb[l].v);
        // two pad slots
        k.extend(std::iter::repeat(0.0).take(2 * ca[l].k.len()));
        v.extend(std::iter::repeat(0.0).take(2 * ca[l].v.len()));
        let mut k_dims = ca[l].k_dims.clone();
        let mut v_dims = ca[l].v_dims.clone();
        k_dims[0] = 4;
        v_dims[0] = 4;
        batch_caches.push(rap::runtime::PjrtCache { k, k_dims, v, v_dims });
    }
    let out = engine
        .decode(
            &ctx,
            4,
            &[corpus[5] as i32, corpus[9] as i32, 0, 0],
            &[5, 9, 0, 0],
            &batch_caches,
        )
        .unwrap();
    let vocab = out.logits.len() / 4;
    for (bi, reference) in [(0usize, &ra.logits), (1usize, &rb.logits)] {
        let got = &out.logits[bi * vocab..(bi + 1) * vocab];
        let max_diff: f32 = got
            .iter()
            .zip(reference.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max_diff < 2e-2, "batch slot {bi}: diff {max_diff}");
    }
}

#[test]
fn pjrt_session_generates_deterministically() {
    let m = manifest();
    let ctx = PjrtContext::cpu().unwrap();
    let engine = PjrtEngine::load(&ctx, &m, "tinyllama", "rap_r30").unwrap();
    let gen = |prompt: &[u8]| {
        let mut s = Session::new(&ctx, &engine).unwrap();
        s.prefill(prompt).unwrap();
        s.generate(16).unwrap()
    };
    let a = gen(b"the quick brown ");
    let b = gen(b"the quick brown ");
    assert_eq!(a, b);
    assert_eq!(a.len(), 16);
    // generated bytes are printable corpus-like text
    assert!(a.iter().all(|&c| c == b' ' || c == b'.' || c == b'?' || c == b'\n' || c.is_ascii_alphanumeric()),
        "got {:?}", String::from_utf8_lossy(&a));
}

#[test]
fn probe_suite_runs_and_baseline_beats_heavy_pruning() {
    let m = manifest();
    let corpus = m.eval_corpus().unwrap();
    let base = load_engine(&m, "tinyllama", "baseline_r00").unwrap();
    let heavy = load_engine(&m, "tinyllama", "svd_r50").unwrap();
    let sb = probe_suite(&base, &corpus, m.eval_seq, 6, 32).unwrap();
    let sh = probe_suite(&heavy, &corpus, m.eval_seq, 6, 32).unwrap();
    let avg = |s: &[rap::eval::ProbeScore]| {
        rap::eval::tasks::average_accuracy(s)
    };
    assert!(
        avg(&sb) > avg(&sh),
        "baseline {:.3} should beat svd@50% {:.3}",
        avg(&sb),
        avg(&sh)
    );
}

#[test]
fn engine_generation_stays_in_distribution() {
    let m = manifest();
    let engine = load_engine(&m, "tinyllama", "rap_r30").unwrap();
    let out = engine.generate(b"the ", 40, 128);
    assert_eq!(out.len(), 40);
    let printable = out
        .iter()
        .filter(|&&c| c.is_ascii_graphic() || c == b' ' || c == b'\n')
        .count();
    assert!(printable >= 38, "mostly printable, got {:?}", String::from_utf8_lossy(&out));
}
