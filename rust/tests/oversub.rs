//! Oversubscription, preemption, and fault-injection integration suite
//! over the real engine (synthetic weights — runs without `make
//! artifacts`).
//!
//! The scheduler-level suite in `coordinator/scheduler.rs` proves the
//! preemption state machine over `ToyBackend`; this file proves the same
//! invariants end-to-end through `RustBackend`'s storage-backed paged
//! kernels, where resume recomputes decode-written KV rows via the
//! chunked-prefill path.  That substitution is bit-safe because
//! `tests/prefill.rs` propchecks blocked prefill against the sequential
//! `step_inner` oracle for every method and every chunk partition:
//!   1. a 2x-oversubscribed storm (worst-case demand = 2x physical
//!      blocks), with and without injected allocation faults, completes
//!      every session **bit-identical** to an uncontended run;
//!   2. a combined storm (allocation + prefill + decode faults at once)
//!      recovers to the same outputs;
//!   3. cancelling a preempted-not-yet-resumed session mid-storm returns
//!      the cache exactly to baseline and never perturbs survivors.

use rap::config::Method;
use rap::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, Event, FaultBackend, FinishReason, Request,
};
use rap::faults::FaultPlan;
use rap::kvcache::{CacheShape, PagedKvCache, BLOCK_TOKENS};
use rap::model::backend::RustBackend;
use rap::model::synth::synth_engine;
use rap::runtime::backend::generate_once;

const SESSIONS: usize = 6;
const PROMPT: usize = 32; // exactly 2 blocks — admission reserves these
const MAX_NEW: usize = 24; // worst case 56 tokens = 4 blocks per session
const BLOCKS: usize = 12; // 6 * 4 = 24 worst-case blocks -> 2x oversubscribed
const S_MAX: usize = 96;

fn prompt(len: usize, salt: usize) -> Vec<u8> {
    (0..len).map(|i| ((i * 37 + salt * 101) % 251) as u8).collect()
}

fn prompts() -> Vec<Vec<u8>> {
    // Distinct salts: no shared prefixes, every session pays full freight.
    (0..SESSIONS).map(|i| prompt(PROMPT, 60 + i)).collect()
}

/// Uncontended reference: each request served alone on an ample cache.
fn reference(engine: &rap::model::Engine, shape: &CacheShape) -> Vec<Vec<u8>> {
    let mut backend = RustBackend::new(engine, S_MAX);
    let mut kv = PagedKvCache::with_storage(shape.clone(), 64 << 20);
    prompts()
        .iter()
        .enumerate()
        .map(|(i, p)| generate_once(&mut backend, &mut kv, 700 + i as u64, p, MAX_NEW).unwrap())
        .collect()
}

fn oversub_config(shape: &CacheShape, blocks: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        batcher: BatcherConfig {
            max_sessions: SESSIONS,
            buckets: vec![1, 4, 8],
            max_queue: 64,
            // Env-independent: these tests choreograph preemption victims
            // on an exact block budget; transient draft rows under the CI
            // speculative matrix would shift who gets parked when.
            // Speculation x faults is covered by tests/speculative.rs.
            default_speculative: None,
            ..Default::default()
        },
        kv_budget_bytes: shape.bytes_per_token() * BLOCK_TOKENS * blocks,
    }
}

/// Tentpole acceptance: admission reserves prompts only, decode grows on
/// demand, and when the 2x-oversubscribed storm exhausts the cache the
/// scheduler preempts and later resumes sessions instead of erroring —
/// with every output bit-identical to the uncontended run, both with a
/// clean allocator and under a seeded allocation-fault plan.
#[test]
fn oversubscribed_storm_with_alloc_faults_is_bit_identical() {
    for method in [Method::Baseline, Method::Rap] {
        let engine = synth_engine(method, 23);
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let expected = reference(&engine, &shape);

        for plan in [None, Some(FaultPlan::new(7).with_alloc_faults(0.5))] {
            let faulted = plan.is_some();
            let backend = RustBackend::new(&engine, S_MAX);
            let mut coord = Coordinator::new(backend, shape.clone(), oversub_config(&shape, BLOCKS));
            coord.set_fault_plan(plan.as_ref());
            assert_eq!(coord.kv_capacity_blocks(), BLOCKS, "{method:?}: budget maps to blocks");
            for (i, p) in prompts().iter().enumerate() {
                coord.try_submit(Request::new(i as u64, p.clone(), MAX_NEW)).unwrap();
            }
            let mut responses = coord.run_to_completion().unwrap();
            responses.sort_by_key(|r| r.id);
            assert_eq!(responses.len(), SESSIONS);
            for (r, e) in responses.iter().zip(&expected) {
                assert_eq!(
                    r.metrics.finish_reason,
                    FinishReason::Length,
                    "{method:?} session {} (faulted={faulted})",
                    r.id
                );
                assert_eq!(
                    &r.generated, e,
                    "{method:?} session {} (faulted={faulted}): oversubscribed \
                     decode must be bit-identical to the uncontended run",
                    r.id
                );
            }
            assert!(
                coord.metrics.preemptions >= 1,
                "{method:?} (faulted={faulted}): 2x oversubscription must preempt"
            );
            assert!(
                coord.metrics.resumes >= 1,
                "{method:?} (faulted={faulted}): parked sessions must resume"
            );
            if faulted {
                assert!(
                    coord.kv_alloc_faults_injected() >= 1,
                    "{method:?}: the fault plan never fired"
                );
            }
            assert_eq!(
                coord.kv_used_blocks(),
                0,
                "{method:?} (faulted={faulted}): blocks back to baseline after the storm"
            );
        }
    }
}

/// Combined storm: allocation faults in the kv allocator AND transient
/// prefill/decode faults from a wrapped backend, all while 2x
/// oversubscribed.  Every fault is retried or deferred; outputs stay
/// bit-identical and both the allocator and the backend end empty.
#[test]
fn combined_fault_storm_recovers_bit_identical() {
    let engine = synth_engine(Method::Rap, 29);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let expected = reference(&engine, &shape);

    let plan = FaultPlan::new(41)
        .with_alloc_faults(0.3)
        .with_prefill_faults(0.3)
        .with_decode_faults(0.3);
    let backend = FaultBackend::new(RustBackend::new(&engine, S_MAX), &plan);
    let mut coord = Coordinator::new(backend, shape.clone(), oversub_config(&shape, BLOCKS));
    coord.set_fault_plan(Some(&plan));
    for (i, p) in prompts().iter().enumerate() {
        coord.try_submit(Request::new(i as u64, p.clone(), MAX_NEW)).unwrap();
    }
    let mut responses = coord.run_to_completion().unwrap();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), SESSIONS);
    for (r, e) in responses.iter().zip(&expected) {
        assert_eq!(r.metrics.finish_reason, FinishReason::Length, "session {}", r.id);
        assert_eq!(&r.generated, e, "session {}: faulted storm must not change outputs", r.id);
    }
    let (pf, df) = coord.backend.injected();
    assert!(pf + df >= 1, "backend fault sites never fired");
    assert_eq!(
        coord.metrics.backend_retries,
        pf + df,
        "every injected backend fault is retried exactly once"
    );
    assert!(coord.metrics.preemptions >= 1);
    assert_eq!(coord.kv_used_blocks(), 0, "blocks back to baseline");
    assert_eq!(coord.backend.inner().session_count(), 0, "backend sessions all dropped");
}

/// CI fault-storm stress job: the combined storm swept across
/// `RAP_FAULT_SEEDS` fault-plan seeds (default 8).  Every seed must
/// complete every session bit-identical to the uncontended reference and
/// return the allocator and backend exactly to baseline; preemption must
/// fire somewhere in the sweep (it is driven by genuine exhaustion, not by
/// the injected faults).  `#[ignore]`d so the default `cargo test` gate
/// stays fast — the dedicated CI job opts in with `-- --ignored`.
#[test]
#[ignore = "seed-sweep stress job; run with -- --ignored (width via RAP_FAULT_SEEDS)"]
fn fault_storm_seed_sweep() {
    let seeds: u64 = std::env::var("RAP_FAULT_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let engine = synth_engine(Method::Rap, 23);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let expected = reference(&engine, &shape);

    let mut total_preemptions = 0u64;
    let mut total_injected = 0u64;
    for seed in 0..seeds {
        let plan = FaultPlan::new(seed)
            .with_alloc_faults(0.4)
            .with_prefill_faults(0.2)
            .with_decode_faults(0.2);
        let backend = FaultBackend::new(RustBackend::new(&engine, S_MAX), &plan);
        let mut coord = Coordinator::new(backend, shape.clone(), oversub_config(&shape, BLOCKS));
        coord.set_fault_plan(Some(&plan));
        for (i, p) in prompts().iter().enumerate() {
            coord.try_submit(Request::new(i as u64, p.clone(), MAX_NEW)).unwrap();
        }
        let mut responses = coord.run_to_completion().unwrap();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), SESSIONS, "seed {seed}");
        for (r, e) in responses.iter().zip(&expected) {
            assert_eq!(
                r.metrics.finish_reason,
                FinishReason::Length,
                "seed {seed} session {}",
                r.id
            );
            assert_eq!(&r.generated, e, "seed {seed} session {}: outputs drifted", r.id);
        }
        assert_eq!(coord.kv_used_blocks(), 0, "seed {seed}: blocks leaked");
        assert_eq!(coord.backend.inner().session_count(), 0, "seed {seed}: sessions leaked");
        total_preemptions += coord.metrics.preemptions;
        let (pf, df) = coord.backend.injected();
        total_injected += pf + df + coord.kv_alloc_faults_injected();
    }
    assert!(total_preemptions >= 1, "no seed ever preempted");
    assert!(total_injected >= seeds, "the sweep injected almost nothing");
}

/// Teardown race: cancel a victim while it is parked by preemption (before
/// its resume), then cancel it again.  The cancel must return the tokens
/// generated before preemption, the double-cancel must be a no-op, and the
/// survivors must finish bit-identically with the cache exactly at
/// baseline.
#[test]
fn cancel_of_parked_victim_mid_storm_restores_baseline() {
    const TIGHT_SESSIONS: usize = 4;
    const TIGHT_BLOCKS: usize = 8; // 4 * 4 = 16 worst-case -> 2x oversubscribed
    let engine = synth_engine(Method::Rap, 31);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let expected = reference(&engine, &shape);

    let backend = RustBackend::new(&engine, S_MAX);
    let mut coord = Coordinator::new(
        backend,
        shape.clone(),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: TIGHT_SESSIONS,
                buckets: vec![1, 4, 8],
                max_queue: 64,
                // Env-independent: see `oversub_config` — exact preemption
                // timing is the point of this test.
                default_speculative: None,
                ..Default::default()
            },
            kv_budget_bytes: shape.bytes_per_token() * BLOCK_TOKENS * TIGHT_BLOCKS,
        },
    );
    for (i, p) in prompts().iter().take(TIGHT_SESSIONS).enumerate() {
        coord.try_submit(Request::new(i as u64, p.clone(), MAX_NEW)).unwrap();
    }

    // Tick until the first preemption; the victim stays parked until at
    // least the next tick's resume pass, so cancelling now races the
    // park-without-resume window.
    let mut victim = None;
    for _ in 0..200 {
        let events = coord.tick().unwrap();
        victim = events.iter().find_map(|e| match e {
            Event::Preempted { id } => Some(*id),
            _ => None,
        });
        if victim.is_some() {
            break;
        }
    }
    let victim = victim.expect("2x-oversubscribed growth must preempt");

    let cancelled = coord.cancel(victim).expect("parked session is cancellable");
    assert_eq!(cancelled.metrics.finish_reason, FinishReason::Cancelled);
    assert!(
        !cancelled.generated.is_empty(),
        "pre-preemption tokens survive the cancel"
    );
    assert_eq!(
        cancelled.generated.as_slice(),
        &expected[victim as usize][..cancelled.generated.len()],
        "victim's partial generation is a bit-identical prefix of the reference"
    );
    assert!(coord.cancel(victim).is_none(), "double-cancel is a no-op");

    let mut responses = coord.run_to_completion().unwrap();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), TIGHT_SESSIONS, "cancelled victim included");
    for r in &responses {
        if r.id == victim {
            assert_eq!(r.metrics.finish_reason, FinishReason::Cancelled);
            continue;
        }
        assert_eq!(r.metrics.finish_reason, FinishReason::Length, "survivor {}", r.id);
        assert_eq!(
            &r.generated,
            &expected[r.id as usize],
            "survivor {}: unaffected by the victim's teardown",
            r.id
        );
    }
    assert_eq!(coord.metrics.cancelled, 1);
    assert_eq!(coord.kv_used_blocks(), 0, "blocks back to baseline");
    assert_eq!(coord.backend.session_count(), 0, "backend sessions all dropped");
}

/// A pruned (retention-pressed) session that loses its blocks to
/// preemption resumes by replaying only its surviving rows: the parked
/// survivor positions are re-reserved with their original RoPE positions,
/// every token streamed before the park is preserved verbatim, and the
/// storm still returns the allocator to baseline.  Retain-all neighbours
/// stay bit-identical to the uncontended reference throughout.
#[test]
fn pruned_session_preempts_and_resumes_via_survivor_replay() {
    use rap::kvcache::retention::{Press, RetentionSpec};

    const COMPETITORS: usize = 3;
    const COMP_NEW: usize = 120;
    const BIG_PROMPT: usize = 680; // crosses the press floor mid-prefill
    const BIG_NEW: usize = 120;
    const BIG_ID: u64 = 9;
    // Retain-all worst case is ~80 blocks (competitors 30 + big 50); the
    // press holds the big session near 32-40, so everything fits only
    // because pruning and preemption both work.
    const TIGHT_BLOCKS: usize = 52;

    let engine = synth_engine(Method::Rap, 37);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);

    // Uncontended reference for the retain-all competitors.
    let comp_prompts: Vec<Vec<u8>> = (0..COMPETITORS).map(|i| prompt(32, 80 + i)).collect();
    let expected: Vec<Vec<u8>> = {
        let mut backend = RustBackend::new(&engine, 1024);
        let mut kv = PagedKvCache::with_storage(shape.clone(), 64 << 20);
        comp_prompts
            .iter()
            .enumerate()
            .map(|(i, p)| generate_once(&mut backend, &mut kv, 800 + i as u64, p, COMP_NEW).unwrap())
            .collect()
    };

    let backend = RustBackend::new(&engine, 1024);
    let mut coord = Coordinator::new(
        backend,
        shape.clone(),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: COMPETITORS + 1,
                buckets: vec![1, 4],
                max_queue: 16,
                prefill_chunk_tokens: 128,
                // Env-independent under the CI retention matrix: only the
                // big session is pressed, by its own request-level spec.
                // Same for the speculative matrix: preemption must pick
                // the pruned session, not whoever drafted rows this tick.
                default_retention: None,
                default_speculative: None,
                ..Default::default()
            },
            kv_budget_bytes: shape.bytes_per_token() * BLOCK_TOKENS * TIGHT_BLOCKS,
        },
    );
    // Competitors first (lower seq), the pruned session last: preemption
    // always parks the youngest running session, so once decode growth
    // exhausts the budget the pruned session is the victim.
    for (i, p) in comp_prompts.iter().enumerate() {
        coord.try_submit(Request::new(i as u64, p.clone(), COMP_NEW)).unwrap();
    }
    coord.tick().unwrap();
    let spec = RetentionSpec { press: Press::Window, ratio: 0.5 };
    coord
        .try_submit(Request::new(BIG_ID, prompt(BIG_PROMPT, 90), BIG_NEW).with_retention(spec))
        .unwrap();

    let mut big_tokens: Vec<u8> = Vec::new();
    let mut big_preempted = false;
    let mut big_resumed = false;
    let mut evicted_at_preemption = 0u64;
    let mut responses = Vec::new();
    let mut ticks = 0;
    while responses.len() < COMPETITORS + 1 {
        for e in coord.tick().unwrap() {
            match e {
                Event::Token { id: BIG_ID, token } => big_tokens.push(token),
                Event::Preempted { id: BIG_ID } => {
                    big_preempted = true;
                    evicted_at_preemption = coord.kv_evicted_tokens();
                }
                Event::Resumed { id: BIG_ID } => big_resumed = true,
                Event::Finished { response, .. } => responses.push(response),
                _ => {}
            }
        }
        ticks += 1;
        assert!(ticks < 5000, "storm did not converge");
    }

    assert!(big_preempted, "the pruned session must be the preemption victim");
    assert!(big_resumed, "the parked pruned session must resume");
    assert!(evicted_at_preemption > 0, "the victim was pruned before it was parked");
    assert!(coord.metrics.retention_presses >= 1);
    assert!(coord.metrics.resumes >= 1);

    responses.sort_by_key(|r| r.id);
    for (r, e) in responses.iter().zip(&expected) {
        assert_eq!(r.metrics.finish_reason, FinishReason::Length, "session {}", r.id);
        assert_eq!(&r.generated, e, "retain-all competitor {} must stay bit-identical", r.id);
    }
    let big = responses.iter().find(|r| r.id == BIG_ID).unwrap();
    assert_eq!(big.metrics.finish_reason, FinishReason::Length);
    assert_eq!(big.generated.len(), BIG_NEW);
    assert_eq!(
        big.generated, big_tokens,
        "every streamed token (pre- and post-park) appears once, in order"
    );
    assert_eq!(coord.kv_used_blocks(), 0, "blocks back to baseline");
    assert_eq!(coord.backend.session_count(), 0, "backend sessions all dropped");
}
