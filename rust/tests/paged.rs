//! Paged-store decode identity suite over synthetic weights — runs without
//! `make artifacts`.
//!
//! Three layers of bit-exactness, for every method
//! (baseline/svd/palu/rap):
//!   1. the workspace-based dense step vs the seed's allocating per-row
//!      decode (`step_alloc_reference`);
//!   2. paged (block-scattered) decode vs dense decode;
//!   3. batched decode over 8 concurrent sessions through the scheduler vs
//!      sequential single-session decode.

use rap::config::Method;
use rap::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Request};
use rap::kvcache::{CacheShape, PagedKvCache, BLOCK_TOKENS};
use rap::model::backend::RustBackend;
use rap::model::synth::synth_engine;
use rap::model::BatchWorkspace;
use rap::runtime::backend::generate_once;

const METHODS: [Method; 4] = [Method::Baseline, Method::Svd, Method::Palu, Method::Rap];

fn prompt(len: usize, salt: usize) -> Vec<u8> {
    (0..len).map(|i| ((i * 37 + salt * 101) % 251) as u8).collect()
}

#[test]
fn workspace_step_matches_seed_reference_bitwise() {
    for method in METHODS {
        let engine = synth_engine(method, 42);
        let mut ws_cache = engine.new_cache(96);
        let mut ref_cache = engine.new_cache(96);
        for (i, &t) in prompt(80, 1).iter().enumerate() {
            let ws = engine.step(t, i, &mut ws_cache);
            let reference = engine.step_alloc_reference(t, i, &mut ref_cache);
            assert_eq!(ws, reference, "{method:?} step {i}");
        }
        assert_eq!(ws_cache.bytes_used(), ref_cache.bytes_used());
    }
}

#[test]
fn paged_decode_matches_dense_bitwise() {
    for method in METHODS {
        let engine = synth_engine(method, 9);
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        // Enough tokens to cross several block boundaries.
        let s = BLOCK_TOKENS * 3 + 5;
        let mut kv = PagedKvCache::with_storage(shape, 4 << 20);
        kv.reserve(1, s).unwrap();
        let mut batch = BatchWorkspace::new(&engine, 96);
        let mut dense = engine.new_cache(96);
        for (i, &t) in prompt(s, 2).iter().enumerate() {
            let dense_logits = engine.step(t, i, &mut dense);
            engine
                .decode_batch_paged(&[(1, t, i)], &mut kv, &mut batch, true)
                .unwrap();
            assert_eq!(
                dense_logits.as_slice(),
                batch.logits_row(0),
                "{method:?} pos {i}"
            );
        }
    }
}

#[test]
fn scheduler_batched_decode_bit_identical_to_sequential() {
    const SESSIONS: usize = 8;
    const MAX_NEW: usize = 12;
    for method in METHODS {
        let engine = synth_engine(method, 5);
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let s_max = 96;
        // Staggered prompt lengths put concurrent sessions at different
        // positions within the same decode batch.
        let prompts: Vec<Vec<u8>> = (0..SESSIONS).map(|i| prompt(5 + 2 * i, i)).collect();

        // Reference: each session decoded alone, one token per batch.
        let mut expected = Vec::new();
        {
            let mut backend = RustBackend::new(&engine, s_max);
            let mut kv = PagedKvCache::with_storage(shape.clone(), 16 << 20);
            for (i, p) in prompts.iter().enumerate() {
                expected.push(
                    generate_once(&mut backend, &mut kv, 500 + i as u64, p, MAX_NEW).unwrap(),
                );
            }
        }

        // All sessions live at once, decoded in buckets of up to 8.
        let backend = RustBackend::new(&engine, s_max);
        let mut coord = Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: SESSIONS,
                    buckets: vec![1, 4, 8],
                    max_queue: 64,
                    // Env-independent: under the CI speculative matrix every
                    // session would take the one-at-a-time verify path and
                    // starve the plain decode batches this test measures.
                    default_speculative: None,
                    ..Default::default()
                },
                kv_budget_bytes: 16 << 20,
            },
        );
        for (i, p) in prompts.iter().enumerate() {
            assert!(coord.submit(Request::new(i as u64, p.clone(), MAX_NEW)));
        }
        let mut responses = coord.run_to_completion().unwrap();
        assert_eq!(responses.len(), SESSIONS);
        responses.sort_by_key(|r| r.id);
        for (r, e) in responses.iter().zip(&expected) {
            assert_eq!(r.generated.len(), MAX_NEW, "{method:?} session {}", r.id);
            assert_eq!(&r.generated, e, "{method:?} session {}", r.id);
        }
        assert_eq!(coord.kv_used_blocks(), 0, "{method:?}: all KV released");
        assert!(coord.metrics.decode_batch_occupancy.mean() > 1.5, "{method:?}: batching exercised");
    }
}

/// Tentpole acceptance: 8 concurrent requests sharing a 512-token prompt
/// prefix must (i) allocate the prefix blocks **once** — used blocks stay
/// near prefix + 8·suffix instead of 8·(prefix + suffix) — and (ii) decode
/// bit-identically to the unshared path (each request served alone with no
/// resident prefix to attach).
#[test]
fn shared_prefix_sessions_bit_identical_and_allocate_prefix_once() {
    const SESSIONS: usize = 8;
    const MAX_NEW: usize = 8;
    const PREFIX: usize = 512;
    for method in [Method::Baseline, Method::Rap] {
        let engine = synth_engine(method, 17);
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let s_max = PREFIX + 64;
        let common = prompt(PREFIX, 0);
        let prompts: Vec<Vec<u8>> = (0..SESSIONS)
            .map(|i| {
                let mut p = common.clone();
                p.extend(prompt(9 + i, 50 + i)); // distinct suffixes
                p
            })
            .collect();

        // Reference: each request served alone — nothing resident to share.
        let mut expected = Vec::new();
        {
            let mut backend = RustBackend::new(&engine, s_max);
            let mut kv = PagedKvCache::with_storage(shape.clone(), 64 << 20);
            for (i, p) in prompts.iter().enumerate() {
                expected.push(
                    generate_once(&mut backend, &mut kv, 900 + i as u64, p, MAX_NEW).unwrap(),
                );
            }
        }

        // Shared: all 8 concurrent; requests 1..7 attach request 0's prefix.
        let backend = RustBackend::new(&engine, s_max);
        let mut coord = Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: SESSIONS,
                    buckets: vec![1, 4, 8],
                    max_queue: 64,
                    prefill_chunk_tokens: 128,
                    ..Default::default()
                },
                kv_budget_bytes: 64 << 20,
            },
        );
        for (i, p) in prompts.iter().enumerate() {
            assert!(coord.submit(Request::new(i as u64, p.clone(), MAX_NEW)));
        }
        let mut responses = coord.run_to_completion().unwrap();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), SESSIONS);
        for (r, e) in responses.iter().zip(&expected) {
            assert_eq!(
                &r.generated, e,
                "{method:?} session {}: shared-prefix decode must be bit-identical",
                r.id
            );
        }

        let prefix_blocks = PREFIX / BLOCK_TOKENS;
        assert_eq!(coord.metrics.prefix_hits, SESSIONS as u64 - 1);
        assert_eq!(
            coord.metrics.prefix_saved_blocks,
            (SESSIONS as u64 - 1) * prefix_blocks as u64
        );
        // Prefix allocated once + a few private suffix/generation blocks
        // per session; the unshared path would peak at ~8x the prefix.
        assert!(
            coord.metrics.peak_kv_blocks >= prefix_blocks
                && coord.metrics.peak_kv_blocks <= prefix_blocks + SESSIONS * 4,
            "{method:?}: peak {} blocks vs prefix {}",
            coord.metrics.peak_kv_blocks,
            prefix_blocks
        );
        assert_eq!(coord.kv_used_blocks(), 0, "{method:?}: all KV released");
        // Storage-backed coordinators keep the released prefix resident as
        // evictable cold cache (reclaimed on demand under pressure), so the
        // trie outlives its last session — as cold, not used, blocks.
        assert!(
            coord.kv_prefix_nodes() >= prefix_blocks,
            "{method:?}: shared prefix retained cold"
        );
        assert_eq!(coord.kv_cold_blocks(), coord.kv_prefix_nodes());
    }
}

#[test]
fn paged_sessions_are_isolated() {
    // Interleaving another session's decode must not perturb the first
    // session's outputs (disjoint blocks, no cross-talk).
    let engine = synth_engine(Method::Rap, 21);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let n = 40;

    let solo: Vec<Vec<f32>> = {
        let mut kv = PagedKvCache::with_storage(shape.clone(), 4 << 20);
        kv.reserve(1, n).unwrap();
        let mut batch = BatchWorkspace::new(&engine, 64);
        prompt(n, 3)
            .iter()
            .enumerate()
            .map(|(i, &t)| {
                engine
                    .decode_batch_paged(&[(1, t, i)], &mut kv, &mut batch, true)
                    .unwrap();
                batch.logits_row(0).to_vec()
            })
            .collect()
    };

    let mut kv = PagedKvCache::with_storage(shape, 4 << 20);
    kv.reserve(1, n).unwrap();
    kv.reserve(2, n).unwrap();
    let mut batch = BatchWorkspace::new(&engine, 64);
    let other = prompt(n, 4);
    for (i, &t) in prompt(n, 3).iter().enumerate() {
        // Batch both sessions together; session 2 runs a different stream.
        engine
            .decode_batch_paged(&[(1, t, i), (2, other[i], i)], &mut kv, &mut batch, true)
            .unwrap();
        assert_eq!(batch.logits_row(0), solo[i].as_slice(), "pos {i}");
    }
}
