//! Router integration tests: real replicas (TCP servers over the
//! synthetic Rust backend) behind a real router, driven by the seeded
//! chaos harness.  No artifacts needed — everything runs on
//! `synth_engine`.
//!
//! Every replica uses the same engine seed, so a request produces
//! byte-identical greedy output on whichever replica serves it — which is
//! what lets the storm assert that completed requests are *correct*, not
//! merely terminated.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use rap::config::Method;
use rap::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use rap::kvcache::{CacheShape, PagedKvCache};
use rap::model::backend::RustBackend;
use rap::model::synth::synth_engine;
use rap::model::Engine;
use rap::router::chaos::{ChaosAction, ChaosConfig, ChaosPlan, StallBackend, StallSwitch};
use rap::router::{
    serve_router, HealthConfig, RetryConfig, RoutePolicy, RouterConfig, RoutingTable,
};
use rap::server::{client_health, serve_with_config, ServerConfig, ServerHandle};
use rap::util::json::{self, num, obj, s, Value};

const ENGINE_SEED: u64 = 7;
const S_MAX: usize = 4096;

/// One replica: a real TCP server over the synthetic engine, its backend
/// wrapped in a [`StallBackend`] so tests can wedge it from outside.
fn spawn_replica(switch: StallSwitch, server_cfg: ServerConfig) -> ServerHandle {
    let factory = move || -> anyhow::Result<Coordinator<StallBackend<RustBackend<'static>>>> {
        // Leaks one engine per spawn: server lifetime == process lifetime,
        // and test restarts are bounded by the chaos plan.
        let engine: &'static Engine = Box::leak(Box::new(synth_engine(Method::Rap, ENGINE_SEED)));
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let backend = StallBackend::new(RustBackend::new(engine, S_MAX), switch);
        Ok(Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 4,
                    buckets: vec![1, 4],
                    max_queue: 32,
                    ..Default::default()
                },
                kv_budget_bytes: 64 << 20,
            },
        ))
    };
    serve_with_config("127.0.0.1:0", factory, server_cfg).unwrap()
}

fn replica_cfg() -> ServerConfig {
    ServerConfig {
        conn_threads: 4,
        // Short idle leash so orphaned handler connections can't stretch
        // test teardown.
        idle_read_timeout: Duration::from_secs(5),
        ..ServerConfig::default()
    }
}

/// The greedy reference output for `prompt` — what any replica must
/// produce, since they all share `ENGINE_SEED`.
fn expected_text(prompt: &[u8], max_new: usize) -> String {
    let engine = synth_engine(Method::Rap, ENGINE_SEED);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let mut backend = RustBackend::new(&engine, S_MAX);
    let mut kv = PagedKvCache::with_storage(shape, 16 << 20);
    let out =
        rap::runtime::backend::generate_once(&mut backend, &mut kv, 1, prompt, max_new).unwrap();
    String::from_utf8_lossy(&out).into_owned()
}

/// Distinct ASCII prompts, each at least one KV block long so they carry
/// an affinity key.
fn class_prompt(class: usize) -> Vec<u8> {
    (0..24).map(|i| (32 + ((i * 7 + class * 31) % 90)) as u8).collect()
}

fn wait_for(mut cond: impl FnMut() -> bool, timeout: Duration, what: &str) {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if cond() {
            return;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    panic!("timed out waiting for {what}");
}

/// How one request through the router ended, as the client saw it.
enum Outcome {
    Completed { text: String, deltas: String },
    Classified { error: String },
}

/// Send one streaming request on a fresh connection and read it to its
/// terminal line.  Panics if the router goes silent or closes without
/// one — the storm's core "no request is silently lost" assertion.
fn stream_one(addr: SocketAddr, body: &Value, read_timeout: Duration) -> Outcome {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(read_timeout)).unwrap();
    writeln!(stream, "{body}").unwrap();
    let mut reader = BufReader::new(stream);
    let mut deltas = String::new();
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader
            .read_line(&mut line)
            .expect("router must answer before the client timeout");
        assert!(n > 0, "router closed the stream without a terminal line");
        let v = json::parse(line.trim()).unwrap();
        if let Some(d) = v.get("delta").and_then(|d| d.as_str()) {
            deltas.push_str(d);
            continue;
        }
        if v.get("event").is_some() || v.get("ack").is_some() {
            continue;
        }
        if let Some(e) = v.get("error").and_then(|e| e.as_str()) {
            return Outcome::Classified { error: e.to_string() };
        }
        assert!(v.get("finish_reason").is_some(), "unrecognised terminal line: {line}");
        let text = v.get("text").and_then(|t| t.as_str()).unwrap_or("").to_string();
        return Outcome::Completed { text, deltas };
    }
}

/// One seeded chaos storm: 3 replicas, kill/restart/stall/unstall applied
/// between dispatches per the plan, every request demanded to terminate
/// deterministically — completed with the exact reference text, or an
/// explicit classified error.  Zero silent losses, zero duplicated or
/// divergent output.
fn run_storm(seed: u64) {
    const N: usize = 48;
    const MAX_NEW: usize = 12;
    const R: usize = 3;
    let plan = ChaosPlan::generate(seed, R, N, &ChaosConfig::default());
    let (kills, restarts, stalls, unstalls) = plan.counts();

    let mut switches: Vec<StallSwitch> = (0..R).map(|_| StallSwitch::new()).collect();
    let mut handles: Vec<Option<ServerHandle>> = switches
        .iter()
        .map(|sw| Some(spawn_replica(sw.clone(), replica_cfg())))
        .collect();
    let addrs: Vec<SocketAddr> = handles.iter().map(|h| h.as_ref().unwrap().addr).collect();
    let router = serve_router(
        "127.0.0.1:0",
        &addrs,
        RouterConfig {
            // Tight enough that a wedged replica costs ~a second per
            // attempt, loose enough that healthy decode never trips it.
            request_timeout: Duration::from_millis(1200),
            connect_timeout: Duration::from_millis(500),
            health: HealthConfig {
                interval: Duration::from_millis(100),
                probe_timeout: Duration::from_millis(300),
                // A stalled replica flaps (probes pass, relays time out);
                // a high down threshold keeps it Suspect instead of
                // wrongly Down.
                down_after: 4,
                up_after: 1,
            },
            retry: RetryConfig {
                max_attempts: 4,
                base: Duration::from_millis(10),
                cap: Duration::from_millis(80),
                seed,
            },
            ..RouterConfig::default()
        },
    )
    .unwrap();

    let classes: Vec<Vec<u8>> = (0..6).map(class_prompt).collect();
    let expected: Vec<String> = classes.iter().map(|p| expected_text(p, MAX_NEW)).collect();

    let mut completed = 0usize;
    let mut classified = 0usize;
    for i in 0..N {
        for a in plan.actions_at(i) {
            let r = a.replica();
            match a {
                ChaosAction::Kill { .. } => {
                    // Release the scheduler first: shutdown joins it, and
                    // a stalled scheduler would never see the message.
                    switches[r].set(false);
                    if let Some(h) = handles[r].take() {
                        h.shutdown();
                    }
                }
                ChaosAction::Restart { .. } => {
                    switches[r] = StallSwitch::new();
                    let h = spawn_replica(switches[r].clone(), replica_cfg());
                    router.register(h.addr);
                    handles[r] = Some(h);
                }
                ChaosAction::Stall { .. } => switches[r].set(true),
                ChaosAction::Unstall { .. } => switches[r].set(false),
            }
        }
        let class = i % classes.len();
        let body = obj(vec![
            ("prompt", s(String::from_utf8(classes[class].clone()).unwrap())),
            ("max_new", num(MAX_NEW as f64)),
            ("stream", Value::Bool(true)),
        ]);
        match stream_one(router.addr, &body, Duration::from_secs(30)) {
            Outcome::Completed { text, deltas } => {
                assert_eq!(
                    text, expected[class],
                    "seed {seed} request {i}: wrong or duplicated output"
                );
                assert_eq!(
                    deltas, text,
                    "seed {seed} request {i}: relayed deltas must reassemble to the summary"
                );
                completed += 1;
            }
            Outcome::Classified { error } => {
                assert!(
                    matches!(
                        error.as_str(),
                        "replica_unavailable" | "replica_failed" | "no_replicas" | "timeout"
                    ),
                    "seed {seed} request {i}: unclassified failure {error:?}"
                );
                classified += 1;
            }
        }
    }
    assert_eq!(completed + classified, N, "every request has exactly one outcome");
    assert!(
        completed >= N / 2,
        "seed {seed}: too lossy: {completed}/{N} completed \
         (plan: {kills} kills {restarts} restarts {stalls} stalls {unstalls} unstalls)"
    );
    assert!(kills + stalls >= 1, "seed {seed}: the plan exercised no faults");

    for sw in &switches {
        sw.set(false);
    }
    router.shutdown();
    for h in handles.into_iter().flatten() {
        h.shutdown();
    }
}

#[test]
fn chaos_storm_every_request_terminates_classified() {
    run_storm(0xB007);
}

/// CI router-chaos stress job: the storm swept across `RAP_ROUTER_SEEDS`
/// chaos-plan seeds (default 6).  `#[ignore]`d so the default
/// `cargo test` gate stays fast — the dedicated CI job opts in with
/// `-- --ignored`.
#[test]
#[ignore = "seed-sweep stress job; run with -- --ignored (width via RAP_ROUTER_SEEDS)"]
fn router_chaos_seed_sweep() {
    let seeds: u64 = std::env::var("RAP_ROUTER_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6);
    for seed in 0..seeds {
        run_storm(seed);
    }
}

/// Proxied cancellation: `{"cancel": id}` sent to the *router* on a
/// second connection reaches the owning replica, the stream ends with a
/// `cancelled` summary carrying the router-global id, and the replica's
/// `kv_used_blocks()` returns exactly to the pre-admission baseline —
/// across a hop, both mid-decode and while the scheduler is wedged.
#[test]
fn proxied_cancel_reaches_owner_and_frees_blocks_across_hop() {
    let switch = StallSwitch::new();
    let replica = spawn_replica(switch.clone(), replica_cfg());
    let router = serve_router(
        "127.0.0.1:0",
        &[replica.addr],
        RouterConfig {
            health: HealthConfig {
                interval: Duration::from_millis(100),
                ..HealthConfig::default()
            },
            ..RouterConfig::default()
        },
    )
    .unwrap();
    let stats = replica.stats();
    let baseline = stats.used_blocks.load(Ordering::Relaxed);

    // The replica's health endpoint answers through plain TCP too.
    let h = client_health(&replica.addr, Duration::from_secs(2)).unwrap();
    assert_eq!(h.get("ok").and_then(|o| o.as_bool()), Some(true));

    let cancel_round = |wedged: bool| {
        let body = obj(vec![
            ("prompt", s("cancel across the hop ")),
            ("max_new", num(2000.0)),
            ("stream", Value::Bool(true)),
            ("ack", Value::Bool(true)),
        ]);
        let stream = TcpStream::connect(router.addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut w = stream.try_clone().unwrap();
        writeln!(w, "{body}").unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let ack = json::parse(line.trim()).unwrap();
        assert_eq!(ack.get("ack").and_then(|a| a.as_bool()), Some(true), "got: {line}");
        let gid = ack.get("id").and_then(|i| i.as_usize()).unwrap();
        if !wedged {
            // Reach steady decode before cancelling.
            line.clear();
            reader.read_line(&mut line).unwrap();
            assert!(json::parse(line.trim()).unwrap().get("delta").is_some(), "got: {line}");
        }

        // Cancel from a different connection, addressed to the router.
        let mut c2 = TcpStream::connect(router.addr).unwrap();
        c2.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        writeln!(c2, "{}", obj(vec![("cancel", num(gid as f64))])).unwrap();
        let mut ackl = String::new();
        BufReader::new(c2).read_line(&mut ackl).unwrap();
        assert!(ackl.contains("\"ok\""), "cancel not acked: {ackl}");

        if wedged {
            switch.set(false);
        }
        // Drain to the terminal line: must be a cancelled summary with
        // the global id, never a silent close.
        loop {
            line.clear();
            assert!(reader.read_line(&mut line).unwrap() > 0, "stream ended without summary");
            let v = json::parse(line.trim()).unwrap();
            if let Some(f) = v.get("finish_reason").and_then(|f| f.as_str()) {
                assert_eq!(f, "cancelled");
                assert_eq!(v.get("id").and_then(|i| i.as_usize()), Some(gid));
                break;
            }
        }
    };

    // Mid-decode cancel.
    cancel_round(false);
    wait_for(
        || stats.used_blocks.load(Ordering::Relaxed) == baseline,
        Duration::from_secs(5),
        "mid-decode cancel to return used blocks to baseline",
    );

    // Cancel while the scheduler is wedged (request still queued or
    // mid-prefill from the replica's point of view).
    switch.set(true);
    cancel_round(true);
    wait_for(
        || stats.used_blocks.load(Ordering::Relaxed) == baseline,
        Duration::from_secs(5),
        "wedged-phase cancel to return used blocks to baseline",
    );

    assert!(router.metrics().cancels_proxied.load(Ordering::Relaxed) >= 2);
    router.shutdown();
    replica.shutdown();
}

/// Graceful drain: a draining replica takes no new work, its in-flight
/// stream finishes undisturbed, and once idle it leaves the table.
#[test]
fn graceful_drain_finishes_in_flight_and_removes_replica() {
    let sa = StallSwitch::new();
    let sb = StallSwitch::new();
    let a = spawn_replica(sa.clone(), replica_cfg());
    let b = spawn_replica(sb, replica_cfg());
    let router = serve_router(
        "127.0.0.1:0",
        &[a.addr, b.addr],
        RouterConfig {
            policy: RoutePolicy::LeastLoaded,
            health: HealthConfig {
                interval: Duration::from_millis(100),
                ..HealthConfig::default()
            },
            ..RouterConfig::default()
        },
    )
    .unwrap();

    // A long-running stream lands on A (least-loaded tie breaks by id).
    // A's scheduler is wedged first so the stream deterministically stays
    // in flight for the whole drain choreography — the fast synthetic
    // engine would otherwise race the assertions to completion.
    sa.set(true);
    let body = obj(vec![
        ("prompt", s("drain me gently ")),
        ("max_new", num(2000.0)),
        ("stream", Value::Bool(true)),
        ("ack", Value::Bool(true)),
    ]);
    let stream = TcpStream::connect(router.addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut w = stream.try_clone().unwrap();
    writeln!(w, "{body}").unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let gid = json::parse(line.trim()).unwrap().get("id").and_then(|i| i.as_usize()).unwrap();

    // Drain A over the admin endpoint while its stream is live.
    let admin = TcpStream::connect(router.addr).unwrap();
    admin.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut aw = admin.try_clone().unwrap();
    let mut areader = BufReader::new(admin);
    writeln!(aw, "{}", obj(vec![("admin", s("drain")), ("replica", s(a.addr.to_string()))]))
        .unwrap();
    let mut l = String::new();
    areader.read_line(&mut l).unwrap();
    assert!(l.contains("\"ok\""), "drain not acked: {l}");

    // New work routes to B and completes while A is still streaming.
    let small = obj(vec![
        ("prompt", s(String::from_utf8(class_prompt(0)).unwrap())),
        ("max_new", num(8.0)),
        ("stream", Value::Bool(true)),
    ]);
    match stream_one(router.addr, &small, Duration::from_secs(10)) {
        Outcome::Completed { text, .. } => {
            assert_eq!(text, expected_text(&class_prompt(0), 8));
        }
        Outcome::Classified { error } => panic!("drain must not break new work: {error}"),
    }
    let status = router.status();
    let reps = status.get("replicas").and_then(|r| r.as_arr()).unwrap();
    let entry = |addr: SocketAddr| {
        reps.iter()
            .find(|e| e.get("addr").and_then(|a| a.as_str()) == Some(addr.to_string().as_str()))
            .cloned()
            .unwrap_or_else(|| panic!("no status entry for {addr}"))
    };
    assert_eq!(entry(a.addr).get("state").and_then(|s| s.as_str()), Some("draining"));
    assert_eq!(entry(a.addr).get("in_flight").and_then(|i| i.as_usize()), Some(1));
    assert_eq!(entry(b.addr).get("completed").and_then(|c| c.as_usize()), Some(1));

    // Finish A's stream (cancel, then release the scheduler so the
    // cancellation can be served); the drained replica then leaves.
    let mut c2 = TcpStream::connect(router.addr).unwrap();
    c2.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    writeln!(c2, "{}", obj(vec![("cancel", num(gid as f64))])).unwrap();
    let mut ackl = String::new();
    BufReader::new(c2).read_line(&mut ackl).unwrap();
    sa.set(false);
    loop {
        line.clear();
        assert!(reader.read_line(&mut line).unwrap() > 0, "stream ended without summary");
        if json::parse(line.trim()).unwrap().get("finish_reason").is_some() {
            break;
        }
    }
    // Close every client connection before shutdown: router handler
    // threads park in read_line on them, and shutdown joins the pool.
    drop(reader);
    drop(w);
    drop(areader);
    drop(aw);
    wait_for(
        || router.replica_count() == 1,
        Duration::from_secs(5),
        "idle drained replica to be swept from the table",
    );

    router.shutdown();
    a.shutdown();
    b.shutdown();
}

/// Prefix affinity: every repeat of a prompt class routes to the class's
/// rendezvous owner (predicted exactly by a shadow table built the same
/// way), and the fleet's prefix caches serve every repeat — the
/// cross-replica single-compute property random routing cannot give.
#[test]
fn affinity_routes_repeats_to_owner_and_reuses_prefix_cache() {
    const CLASSES: usize = 4;
    const REPEATS: usize = 5;
    const R: usize = 3;
    let switches: Vec<StallSwitch> = (0..R).map(|_| StallSwitch::new()).collect();
    let handles: Vec<ServerHandle> = switches
        .iter()
        .map(|sw| spawn_replica(sw.clone(), replica_cfg()))
        .collect();
    let addrs: Vec<SocketAddr> = handles.iter().map(|h| h.addr).collect();
    let cfg = RouterConfig::default();
    let (affinity_blocks, load_slack) = (cfg.affinity_blocks, cfg.load_slack);
    let router = serve_router("127.0.0.1:0", &addrs, cfg).unwrap();

    // Predict each class's owner with a shadow table registered in the
    // same order (rendezvous hashing keys on replica ids, which are
    // assigned by registration order).
    let mut shadow = RoutingTable::new(RoutePolicy::Affinity, affinity_blocks, load_slack);
    for &a in &addrs {
        shadow.register(a);
    }
    let prompts: Vec<Vec<u8>> = (0..CLASSES).map(class_prompt).collect();
    let mut per_replica = vec![0usize; R];
    for p in &prompts {
        let owner = shadow.route(p, &[]).unwrap();
        per_replica[(owner - 1) as usize] += REPEATS;
    }

    for _ in 0..REPEATS {
        for p in &prompts {
            let body = obj(vec![
                ("prompt", s(String::from_utf8(p.clone()).unwrap())),
                ("max_new", num(8.0)),
                ("stream", Value::Bool(true)),
            ]);
            match stream_one(router.addr, &body, Duration::from_secs(10)) {
                Outcome::Completed { text, deltas } => assert_eq!(text, deltas),
                Outcome::Classified { error } => panic!("healthy fleet refused work: {error}"),
            }
        }
    }

    // Dispatch counts match the rendezvous prediction exactly — no class
    // ever strayed from its owner.
    let status = router.status();
    let reps = status.get("replicas").and_then(|r| r.as_arr()).unwrap();
    for (i, addr) in addrs.iter().enumerate() {
        let got = reps
            .iter()
            .find(|e| e.get("addr").and_then(|a| a.as_str()) == Some(addr.to_string().as_str()))
            .and_then(|e| e.get("dispatched"))
            .and_then(|d| d.as_usize());
        assert_eq!(got, Some(per_replica[i]), "replica {i} dispatch count");
    }

    // Fleet-wide reuse: each repeat hits its owner's cached prefix at
    // least once (gauges publish asynchronously, hence the wait).
    let target = ((REPEATS - 1) * CLASSES) as u64;
    wait_for(
        || {
            let hits: u64 = handles
                .iter()
                .map(|h| h.stats().prefix_hits.load(Ordering::Relaxed))
                .sum();
            hits >= target
        },
        Duration::from_secs(5),
        "fleet prefix-cache hits to reach the repeat count",
    );

    router.shutdown();
    for h in handles {
        h.shutdown();
    }
}

/// Server hardening over the wire: an oversized request line answers
/// `{"error": "bad_request", "field": "line"}` — and the reply actually
/// reaches the client (the server drains the line's remainder so its
/// close is clean, not a reset that would discard the answer).
#[test]
fn oversized_request_line_is_rejected_with_field_line() {
    let cfg = ServerConfig {
        max_line_bytes: 4096,
        ..replica_cfg()
    };
    let replica = spawn_replica(StallSwitch::new(), cfg);
    let stream = TcpStream::connect(replica.addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut w = stream.try_clone().unwrap();
    // ~6 KiB line: over the 4 KiB cap, within the drain budget.
    let big = "x".repeat(6000);
    writeln!(w, "{{\"prompt\": \"{big}\", \"max_new\": 4}}").unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert_eq!(v.get("error").and_then(|e| e.as_str()), Some("bad_request"));
    assert_eq!(v.get("field").and_then(|f| f.as_str()), Some("line"));
    // Clean close after the refusal.
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "connection must close");
    replica.shutdown();
}
