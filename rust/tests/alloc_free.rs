//! Steady-state decode AND chunked prefill must perform ZERO heap
//! allocations (the tentpole perf claim): all scratch lives in
//! `DecodeWorkspace`/`BatchWorkspace`/`PrefillWorkspace`, logits land in
//! the workspaces, and the paged store was reserved up front (as the
//! coordinator does at admission).
//!
//! Verified with a counting global allocator, so this file holds exactly
//! one test and pins RAP_THREADS=1 before the engine's first kernel call
//! (`kernel_threads` reads the env once; with one worker the scoped
//! parallelism runs inline — no spawns, which also allocate).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use rap::config::Method;
use rap::coordinator::{Sampler, SamplingParams};
use rap::kvcache::{quant, CacheShape, KvLayerView, KvStorageMode, PagedKvCache};
use rap::model::synth::synth_engine;
use rap::model::{BatchWorkspace, PrefillWorkspace};
use rap::speculate::accept::accept_step;
use rap::speculate::draft::{Drafter, NgramDrafter};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_paged_decode_allocates_nothing() {
    std::env::set_var("RAP_THREADS", "1");
    for method in [Method::Baseline, Method::Svd, Method::Palu, Method::Rap] {
        let engine = synth_engine(method, 1);
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let s_max = 256;
        let mut kv = PagedKvCache::with_storage(shape, 8 << 20);
        // Reserve the session's full budget up front, exactly like the
        // coordinator's admission policy — decode then never touches the
        // block free-list.
        kv.reserve(1, s_max).unwrap();
        let mut batch = BatchWorkspace::new(&engine, s_max);

        let mut pos = 0usize;
        let feed = |pos: &mut usize, kv: &mut PagedKvCache, batch: &mut BatchWorkspace, n: usize| {
            for _ in 0..n {
                let token = (*pos % 251) as u8;
                engine
                    .decode_batch_paged(&[(1, token, *pos)], kv, batch, true)
                    .unwrap();
                *pos += 1;
            }
        };
        // Warmup: first calls size the workspace buffers.
        feed(&mut pos, &mut kv, &mut batch, 64);

        let before = ALLOCS.load(Ordering::Relaxed);
        feed(&mut pos, &mut kv, &mut batch, 128);
        let after = ALLOCS.load(Ordering::Relaxed);
        assert_eq!(
            after - before,
            0,
            "{method:?}: steady-state single-token decode must not allocate"
        );

        // Chunked prefill: same contract.  The session's budget is already
        // reserved and the workspace has seen the chunk size after one
        // warmup chunk, so subsequent chunks touch neither the allocator
        // nor the block free-list.
        // 192 decode positions are filled; the remaining 64 of the
        // reservation take four 16-token chunks (1 warmup + 3 measured).
        let mut prefill_ws = PrefillWorkspace::new(&engine, s_max);
        let chunk: Vec<u8> = (0..16).map(|i| (i % 251) as u8).collect();
        engine
            .prefill_chunk_paged(1, &chunk, pos, &mut kv, &mut prefill_ws, false, false)
            .unwrap();
        let mut cpos = pos + 16;
        let before = ALLOCS.load(Ordering::Relaxed);
        for i in 0..3 {
            // Final chunk computes logits too — also allocation-free.
            let last = i == 2;
            engine
                .prefill_chunk_paged(1, &chunk, cpos, &mut kv, &mut prefill_ws, last, false)
                .unwrap();
            cpos += 16;
        }
        let after = ALLOCS.load(Ordering::Relaxed);
        assert_eq!(
            after - before,
            0,
            "{method:?}: steady-state chunked prefill must not allocate"
        );

        // Shared-prefix sessions decode through the same kernels and
        // refcounted blocks: reading another session's prefix blocks must
        // not change the zero-allocation contract.
        let prompt: Vec<u8> = (0..40).map(|i| (i % 251) as u8).collect();
        let r10 = kv.reserve_prefix(10, &prompt, 64).unwrap();
        assert_eq!(r10.matched_tokens, 0);
        engine
            .prefill_chunk_paged(10, &prompt, 0, &mut kv, &mut prefill_ws, false, false)
            .unwrap();
        let r11 = kv.reserve_prefix(11, &prompt, 64).unwrap();
        assert_eq!(r11.matched_tokens, 32, "40-token prompt shares its 2 full blocks");
        engine
            .prefill_chunk_paged(11, &prompt[32..], 32, &mut kv, &mut prefill_ws, false, false)
            .unwrap();
        let mut spos = 40usize;
        let feed2 =
            |spos: &mut usize, kv: &mut PagedKvCache, batch: &mut BatchWorkspace, n: usize| {
                for _ in 0..n {
                    let token = (*spos % 251) as u8;
                    let entries = [(10u64, token, *spos), (11u64, token, *spos)];
                    engine.decode_batch_paged(&entries, kv, batch, true).unwrap();
                    *spos += 1;
                }
            };
        feed2(&mut spos, &mut kv, &mut batch, 8); // warmup at batch size 2
        let before = ALLOCS.load(Ordering::Relaxed);
        feed2(&mut spos, &mut kv, &mut batch, 16);
        let after = ALLOCS.load(Ordering::Relaxed);
        assert_eq!(
            after - before,
            0,
            "{method:?}: shared-prefix batched decode must not allocate"
        );
        kv.release(10);
        kv.release(11);
        kv.release(1);

        // Quantized decode: the backend's post-step int4 round-trip runs in
        // place (`kvcache::quant::roundtrip`), so quantize_kv serving keeps
        // the zero-allocation contract.  Mirrors
        // `RustBackend::quantize_range` without the logits vectors the
        // Backend trait returns.
        kv.reserve(2, s_max).unwrap();
        let mut qpos = 0usize;
        let feed_q =
            |qpos: &mut usize, kv: &mut PagedKvCache, batch: &mut BatchWorkspace, n: usize| {
                for _ in 0..n {
                    let token = (*qpos % 251) as u8;
                    engine
                        .decode_batch_paged(&[(2, token, *qpos)], kv, batch, true)
                        .unwrap();
                    let (pages, store) = kv.tables_and_ptrs().unwrap();
                    let blocks = pages.blocks(2).unwrap();
                    for l in 0..engine.cfg.n_layers {
                        // SAFETY: one view at a time, single-threaded loop.
                        let mut view = unsafe { store.seq_layer(l, blocks) };
                        for h in 0..engine.cfg.n_kv_heads {
                            quant::roundtrip(view.k_row_mut(h, *qpos));
                            quant::roundtrip(view.v_row_mut(h, *qpos));
                        }
                    }
                    *qpos += 1;
                }
            };
        feed_q(&mut qpos, &mut kv, &mut batch, 32);
        let before = ALLOCS.load(Ordering::Relaxed);
        feed_q(&mut qpos, &mut kv, &mut batch, 64);
        let after = ALLOCS.load(Ordering::Relaxed);
        assert_eq!(
            after - before,
            0,
            "{method:?}: steady-state quantized decode must not allocate"
        );

        // Quantized chunked prefill: the engine round-trips rows in place
        // pre-attention — same contract with quantize_kv on.
        engine
            .prefill_chunk_paged(2, &chunk, qpos, &mut kv, &mut prefill_ws, false, true)
            .unwrap();
        let mut qcpos = qpos + 16;
        let before = ALLOCS.load(Ordering::Relaxed);
        for i in 0..3 {
            let last = i == 2;
            engine
                .prefill_chunk_paged(2, &chunk, qcpos, &mut kv, &mut prefill_ws, last, true)
                .unwrap();
            qcpos += 16;
        }
        let after = ALLOCS.load(Ordering::Relaxed);
        assert_eq!(
            after - before,
            0,
            "{method:?}: steady-state quantized chunked prefill must not allocate"
        );
        kv.release(2);

        // Speculative decode hot loop — drafter observe/draft, blocked
        // verification through the chunk kernel, greedy acceptance —
        // must also run allocation-free at steady state: the drafter's
        // stream buffer and tables are pre-reserved, verify logits live
        // in the workspace's grow-only scratch, and the draft/feed/row
        // buffers are reused across steps.  The drafter is fed a
        // synthetic period-8 stream so it deterministically proposes
        // k=4 every step, pinning the full-width verify path; emitted
        // tokens still come from the verifier's real logits.
        kv.reserve(4, s_max).unwrap();
        let sprompt: Vec<u8> = (0..64).map(|i| (i % 8) as u8).collect();
        engine
            .prefill_chunk_paged(4, &sprompt, 0, &mut kv, &mut prefill_ws, false, false)
            .unwrap();
        let mut drafter = NgramDrafter::with_capacity(s_max);
        drafter.observe(&sprompt);
        let mut sampler = Sampler::new(&SamplingParams::greedy());
        let mut generated: Vec<u8> = Vec::with_capacity(s_max);
        generated.push(0); // first "emitted" token; its KV row is unwritten
        let mut draft_buf: Vec<u8> = Vec::with_capacity(8);
        let mut feed_buf: Vec<u8> = Vec::with_capacity(8);
        // One 1-row warmup verify sizes the workspace's verify scratch
        // and tells us the vocab width for the per-row copy buffers.
        engine
            .verify_chunk_paged(4, &sprompt[..1], 64, &mut kv, &mut prefill_ws, false)
            .unwrap();
        let vocab = prefill_ws.verify_logits_row(0).len();
        let mut logits_bufs: Vec<Vec<f32>> = (0..5).map(|_| vec![0.0f32; vocab]).collect();
        let mut vpos = 64usize;
        let mut spec_step = |vpos: &mut usize, kv: &mut PagedKvCache, ws: &mut PrefillWorkspace| {
            let got = drafter.draft(&mut draft_buf, 4);
            assert_eq!(got, 4, "period-8 drafter stream always proposes k");
            feed_buf.clear();
            feed_buf.push(*generated.last().unwrap());
            feed_buf.extend_from_slice(&draft_buf);
            engine.verify_chunk_paged(4, &feed_buf, *vpos, kv, ws, false).unwrap();
            for i in 0..feed_buf.len() {
                logits_bufs[i].copy_from_slice(ws.verify_logits_row(i));
            }
            let out = accept_step(
                &draft_buf,
                &logits_bufs[..feed_buf.len()],
                &mut sampler,
                &mut generated,
                *vpos,
                |_, _| None,
            );
            // Advance the synthetic drafter stream by the emitted width;
            // rejected rows just get overwritten at the next step (the
            // coordinator's truncate_rows block accounting is covered in
            // tests/speculative.rs).
            for p in *vpos..*vpos + out.emitted {
                drafter.observe(&[(p % 8) as u8]);
            }
            *vpos += out.emitted;
        };
        for _ in 0..4 {
            spec_step(&mut vpos, &mut kv, &mut prefill_ws);
        }
        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..16 {
            spec_step(&mut vpos, &mut kv, &mut prefill_ws);
        }
        let after = ALLOCS.load(Ordering::Relaxed);
        assert_eq!(
            after - before,
            0,
            "{method:?}: steady-state draft/verify/accept must not allocate"
        );
        drop(spec_step);
        assert!(generated.len() > 16, "every step emits at least one token");
        kv.release(4);

        // Packed-int4 storage (methods that never reconstruct): decode and
        // prefill quantize on write into nibble-packed blocks and attend
        // through the fused q4 kernels — also allocation-free.
        if !method.reconstructs_k() && !method.reconstructs_v() {
            let pshape = CacheShape::of(&engine.cfg, &engine.spec);
            let mut pkv =
                PagedKvCache::with_storage_mode(pshape, 8 << 20, KvStorageMode::PackedInt4);
            pkv.reserve(3, s_max).unwrap();
            let mut ppos = 0usize;
            let feed_p =
                |ppos: &mut usize, pkv: &mut PagedKvCache, batch: &mut BatchWorkspace, n: usize| {
                    for _ in 0..n {
                        let token = (*ppos % 251) as u8;
                        engine
                            .decode_batch_paged(&[(3, token, *ppos)], pkv, batch, true)
                            .unwrap();
                        *ppos += 1;
                    }
                };
            feed_p(&mut ppos, &mut pkv, &mut batch, 32);
            let before = ALLOCS.load(Ordering::Relaxed);
            feed_p(&mut ppos, &mut pkv, &mut batch, 64);
            let after = ALLOCS.load(Ordering::Relaxed);
            assert_eq!(
                after - before,
                0,
                "{method:?}: steady-state packed-int4 decode must not allocate"
            );

            engine
                .prefill_chunk_paged(3, &chunk, ppos, &mut pkv, &mut prefill_ws, false, false)
                .unwrap();
            let mut pcpos = ppos + 16;
            let before = ALLOCS.load(Ordering::Relaxed);
            for i in 0..3 {
                let last = i == 2;
                engine
                    .prefill_chunk_paged(3, &chunk, pcpos, &mut pkv, &mut prefill_ws, last, false)
                    .unwrap();
                pcpos += 16;
            }
            let after = ALLOCS.load(Ordering::Relaxed);
            assert_eq!(
                after - before,
                0,
                "{method:?}: steady-state packed-int4 chunked prefill must not allocate"
            );
        }
    }
}
