//! Serving-stack integration tests: coordinator over both backends, the
//! TCP server, and KV accounting under load.  Require `make artifacts`.

use rap::config::Method;
use rap::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, FinishReason, Request, Sampler, SamplingParams,
};
use rap::kvcache::retention::{Press, RetentionSpec};
use rap::kvcache::CacheShape;
use rap::manifest::Manifest;
use rap::model::backend::RustBackend;
use rap::model::load_engine;
use rap::model::synth::synth_engine;
use rap::model::Engine;
use rap::runtime::backend::{generate_once, generate_sampled, PjrtBackend};
use rap::runtime::{PjrtContext, PjrtEngine};
use rap::server::{client_request, client_request_stream, serve};
use rap::util::json::{num, obj, s};
use rap::util::propcheck::forall_res;
use rap::workload::{generate, WorkloadConfig};

fn manifest() -> Manifest {
    Manifest::load_default().expect("run `make artifacts` before cargo test")
}

fn coordinator_cfg(buckets: Vec<usize>) -> CoordinatorConfig {
    CoordinatorConfig {
        batcher: BatcherConfig {
            max_sessions: 3,
            buckets,
            max_queue: 64,
            ..Default::default()
        },
        kv_budget_bytes: 32 << 20,
    }
}

#[test]
fn coordinator_over_rust_backend_serves_trace() {
    let m = manifest();
    let entry = m.model("tinyllama").unwrap();
    let engine = load_engine(&m, "tinyllama", "rap_r30").unwrap();
    let backend = RustBackend::new(&engine, 128);
    let shape = CacheShape::of(&entry.config, &entry.variants["rap_r30"].spec);
    let mut coord = Coordinator::new(backend, shape, coordinator_cfg(vec![1, 4]));

    let corpus = m.eval_corpus().unwrap();
    let wl = generate(
        &WorkloadConfig {
            n_requests: 6,
            prompt_lens: vec![8, 16],
            min_new: 4,
            max_new: 8,
            ..Default::default()
        },
        &corpus,
    );
    for tr in wl {
        assert!(coord.submit(tr.request));
    }
    let responses = coord.run_to_completion().unwrap();
    assert_eq!(responses.len(), 6);
    for r in &responses {
        assert!(!r.generated.is_empty());
        assert!(r.metrics.ttft_ms > 0.0);
    }
    assert_eq!(coord.kv_used_blocks(), 0, "all KV released");
    assert!(coord.metrics.throughput_tps() > 0.0);
}

#[test]
fn coordinator_over_pjrt_backend_matches_sequential_generation() {
    let m = manifest();
    let ctx = PjrtContext::cpu().unwrap();
    let engine = PjrtEngine::load(&ctx, &m, "tinyllama", "rap_r30").unwrap();
    let entry = m.model("tinyllama").unwrap();
    let shape = CacheShape::of(&entry.config, &entry.variants["rap_r30"].spec);

    // Reference: sequential generation of each prompt.
    let corpus = m.eval_corpus().unwrap();
    let prompts: Vec<Vec<u8>> = vec![
        corpus[..16].to_vec(),
        corpus[100..116].to_vec(),
        corpus[500..508].to_vec(),
    ];
    let mut expected = Vec::new();
    {
        let mut backend = PjrtBackend::new(&ctx, &engine).unwrap();
        let mut kv = rap::kvcache::PagedKvCache::new(shape.clone(), 32 << 20);
        for (i, p) in prompts.iter().enumerate() {
            expected.push(
                rap::runtime::backend::generate_once(&mut backend, &mut kv, 1000 + i as u64, p, 6)
                    .unwrap(),
            );
        }
    }

    // Coordinator path: all three concurrently (batched decode).
    let backend = PjrtBackend::new(&ctx, &engine).unwrap();
    let mut coord = Coordinator::new(backend, shape, coordinator_cfg(engine.decode_batches()));
    for (i, p) in prompts.iter().enumerate() {
        coord.submit(Request::new(i as u64, p.clone(), 6));
    }
    let mut responses = coord.run_to_completion().unwrap();
    responses.sort_by_key(|r| r.id);
    for (r, e) in responses.iter().zip(&expected) {
        assert_eq!(&r.generated, e, "batched output must equal sequential");
    }
}

#[test]
fn kv_pressure_defers_admission_but_everything_completes() {
    let m = manifest();
    let entry = m.model("tinyllama").unwrap();
    let engine = load_engine(&m, "tinyllama", "rap_r30").unwrap();
    let backend = RustBackend::new(&engine, 96);
    let shape = CacheShape::of(&entry.config, &entry.variants["rap_r30"].spec);
    // Tiny KV budget: only ~2 sessions' worth of blocks.
    let budget = shape.bytes_per_token() * 96 * 2;
    let mut coord = Coordinator::new(
        backend,
        shape,
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: 8,
                buckets: vec![1, 4],
                max_queue: 64,
                ..Default::default()
            },
            kv_budget_bytes: budget,
        },
    );
    let corpus = m.eval_corpus().unwrap();
    for i in 0..5u64 {
        coord.submit(Request::new(i, corpus[..32].to_vec(), 8));
    }
    let responses = coord.run_to_completion().unwrap();
    assert_eq!(responses.len(), 5, "deferred requests still complete");
    assert!(coord.metrics.peak_kv_blocks > 0);
}

#[test]
fn quantized_backend_still_generates_sensibly() {
    let m = manifest();
    let entry = m.model("tinyllama").unwrap();
    let engine = load_engine(&m, "tinyllama", "rap_r30").unwrap();
    let mut backend = RustBackend::new(&engine, 64);
    backend.quantize_kv = true;
    let shape = CacheShape::of(&entry.config, &entry.variants["rap_r30"].spec);
    let mut kv = rap::kvcache::PagedKvCache::with_storage(shape, 16 << 20);
    let corpus = m.eval_corpus().unwrap();
    let out =
        rap::runtime::backend::generate_once(&mut backend, &mut kv, 1, &corpus[..16], 8).unwrap();
    assert_eq!(out.len(), 8);
    assert!(out.iter().all(|&c| c == b' ' || c.is_ascii_graphic() || c == b'\n'));
    assert_eq!(kv.used_blocks(), 0, "generate_once releases its session");
}

/// A zero-token request admitted through the coordinator must complete
/// cleanly with an empty generation — the engine has no position to
/// compute logits at, and argmaxing a stale workspace would emit garbage
/// tokens (or, worse, another request's logits).  Runs on the real
/// RustBackend over synthetic weights — no artifacts needed.
#[test]
fn empty_prompt_over_rust_backend_yields_empty_generation() {
    let engine = synth_engine(Method::Rap, 23);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let s_max = 96;

    // Reference for the non-empty request decoded alone.
    let solo = {
        let mut backend = RustBackend::new(&engine, s_max);
        let mut kv = rap::kvcache::PagedKvCache::with_storage(shape.clone(), 8 << 20);
        rap::runtime::backend::generate_once(&mut backend, &mut kv, 50, &[5, 6, 7], 6).unwrap()
    };

    let backend = RustBackend::new(&engine, s_max);
    let mut coord = Coordinator::new(
        backend,
        shape,
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: 4,
                buckets: vec![1, 4],
                max_queue: 16,
                ..Default::default()
            },
            kv_budget_bytes: 8 << 20,
        },
    );
    assert!(coord.submit(Request::new(1, Vec::new(), 6)));
    assert!(coord.submit(Request::new(2, vec![5, 6, 7], 6)));
    assert!(coord.submit(Request::new(3, Vec::new(), 0)));
    let mut responses = coord.run_to_completion().unwrap();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), 3);
    assert!(responses[0].generated.is_empty(), "no prompt -> no tokens");
    assert_eq!(responses[0].metrics.generated_tokens, 0);
    assert_eq!(responses[1].generated, solo, "neighbour request unperturbed");
    assert!(responses[2].generated.is_empty());
    assert_eq!(coord.backend.session_count(), 0, "no dangling sessions");
    assert_eq!(coord.kv_used_blocks(), 0, "empty prompts release their reservation");
}

fn synth_prompt(len: usize, salt: usize) -> Vec<u8> {
    (0..len).map(|i| ((i * 37 + salt * 101) % 251) as u8).collect()
}

/// Dense (non-paged `Cache`) sampled generation — consumes logits in the
/// same order as the v2 serve loop, so with equal `SamplingParams` it is
/// the dense reference for the paged and batched paths.
fn dense_sampled(
    engine: &Engine,
    prompt: &[u8],
    n: usize,
    params: &SamplingParams,
    s_max: usize,
) -> Vec<u8> {
    let mut sampler = Sampler::new(params);
    let mut cache = engine.new_cache(s_max);
    let logits = engine.prefill(prompt, &mut cache);
    let mut out = Vec::with_capacity(n);
    if n == 0 || logits.is_empty() {
        return out;
    }
    out.push(sampler.sample(&logits) as u8);
    let mut pos = prompt.len();
    while out.len() < n && pos < s_max {
        let token = *out.last().unwrap();
        let next = sampler.sample(engine.step_reuse(token, pos, &mut cache)) as u8;
        pos += 1;
        out.push(next);
    }
    out
}

/// Propcheck: the same `(prompt, SamplingParams)` generates identical
/// bytes on the dense cache, the paged batch-1 backend, and the
/// coordinator — sampling is a pure function of (logits, seeded RNG), and
/// the three paths produce bit-identical logits.
#[test]
fn seeded_sampling_deterministic_across_dense_paged_and_coordinator_paths() {
    let engine = synth_engine(Method::Rap, 31);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let s_max = 96;
    forall_res(
        77,
        6,
        |r| {
            let prompt: Vec<u8> = (0..r.range(4, 24)).map(|_| r.below(251) as u8).collect();
            let params = SamplingParams {
                temperature: 0.25 + r.f32(),
                top_k: [0, 8, 40][r.below(3)],
                top_p: [1.0, 0.9][r.below(2)],
                seed: r.next_u64(),
            };
            (prompt, params, r.range(4, 12))
        },
        |(prompt, params, n)| {
            let dense = dense_sampled(&engine, prompt, *n, params, s_max);
            let mut backend = RustBackend::new(&engine, s_max);
            let mut kv = rap::kvcache::PagedKvCache::with_storage(shape.clone(), 16 << 20);
            let paged = generate_sampled(&mut backend, &mut kv, 1, prompt, *n, params).unwrap();
            if paged != dense {
                return Err(format!("paged {paged:?} != dense {dense:?}"));
            }
            let backend = RustBackend::new(&engine, s_max);
            let mut coord = Coordinator::new(backend, shape.clone(), coordinator_cfg(vec![1, 4]));
            let req = Request::new(5, prompt.clone(), *n).with_sampling(params.clone());
            assert!(coord.submit(req));
            let served = coord.run_to_completion().unwrap().remove(0).generated;
            if served != dense {
                return Err(format!("coordinator {served:?} != dense {dense:?}"));
            }
            Ok(())
        },
    );
}

/// 8 concurrent seeded requests batch-decode through the scheduler
/// bit-identically to each one generated alone — and temperature 0
/// through the sampler equals the v1 argmax path exactly.
#[test]
fn batched_seeded_sampling_matches_sequential_and_greedy_matches_argmax() {
    const SESSIONS: usize = 8;
    const MAX_NEW: usize = 10;
    let engine = synth_engine(Method::Rap, 37);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let s_max = 96;
    let params_for = |i: usize| SamplingParams {
        temperature: if i % 2 == 0 { 0.0 } else { 0.7 + 0.1 * i as f32 },
        top_k: if i % 3 == 0 { 0 } else { 16 },
        top_p: if i % 4 == 0 { 1.0 } else { 0.92 },
        seed: 1000 + i as u64,
    };
    let prompts: Vec<Vec<u8>> = (0..SESSIONS).map(|i| synth_prompt(6 + 2 * i, i)).collect();

    // Sequential references (batch-1 paged), one per request.
    let mut expected = Vec::new();
    {
        let mut backend = RustBackend::new(&engine, s_max);
        let mut kv = rap::kvcache::PagedKvCache::with_storage(shape.clone(), 16 << 20);
        for (i, p) in prompts.iter().enumerate() {
            expected.push(
                generate_sampled(&mut backend, &mut kv, 600 + i as u64, p, MAX_NEW, &params_for(i))
                    .unwrap(),
            );
        }
        // Greedy sessions must equal the pre-v2 argmax helper bitwise.
        for (i, p) in prompts.iter().enumerate() {
            if params_for(i).is_greedy() {
                let greedy =
                    generate_once(&mut backend, &mut kv, 700 + i as u64, p, MAX_NEW).unwrap();
                assert_eq!(expected[i], greedy, "session {i}: temp 0 must equal argmax");
            }
        }
    }

    // All 8 live at once through the coordinator's batched decode.
    let backend = RustBackend::new(&engine, s_max);
    let mut coord = Coordinator::new(
        backend,
        shape,
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: SESSIONS,
                buckets: vec![1, 4, 8],
                max_queue: 64,
                // Env-independent: the CI speculative matrix would route
                // sessions through one-at-a-time verify steps and starve
                // the plain decode batches whose occupancy is asserted.
                default_speculative: None,
                ..Default::default()
            },
            kv_budget_bytes: 16 << 20,
        },
    );
    for (i, p) in prompts.iter().enumerate() {
        let req = Request::new(i as u64, p.clone(), MAX_NEW).with_sampling(params_for(i));
        assert!(coord.submit(req));
    }
    let mut responses = coord.run_to_completion().unwrap();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), SESSIONS);
    for (r, e) in responses.iter().zip(&expected) {
        assert_eq!(&r.generated, e, "session {}: batched must equal sequential", r.id);
        assert_eq!(r.metrics.finish_reason, FinishReason::Length);
    }
    assert!(coord.metrics.decode_batch_occupancy.mean() > 1.5, "batching exercised");
    assert_eq!(coord.kv_used_blocks(), 0);
}

/// A stop sequence ends the generation the moment the generated bytes end
/// with it, frees the unused tail of the `prompt + max_new` reservation
/// immediately, and reports `finish_reason: Stop`.
#[test]
fn stop_sequence_over_rust_backend_releases_reservation_early() {
    let engine = synth_engine(Method::Rap, 29);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let s_max = 96;
    let prompt = synth_prompt(12, 3);

    let expected = {
        let mut backend = RustBackend::new(&engine, s_max);
        let mut kv = rap::kvcache::PagedKvCache::with_storage(shape.clone(), 8 << 20);
        generate_once(&mut backend, &mut kv, 50, &prompt, 12).unwrap()
    };
    // Stop on the greedy chain's bytes at positions 1..3: the serve loop
    // must cut the generation as soon as the suffix appears.
    let stop = expected[1..3].to_vec();

    let backend = RustBackend::new(&engine, s_max);
    let mut coord = Coordinator::new(backend, shape, coordinator_cfg(vec![1, 4]));
    assert!(coord.submit(Request::new(1, prompt, 12).with_stop(vec![stop.clone()])));
    let responses = coord.run_to_completion().unwrap();
    let r = &responses[0];
    assert_eq!(r.metrics.finish_reason, FinishReason::Stop);
    assert!(r.generated.ends_with(&stop), "{:?} !ends_with {stop:?}", r.generated);
    assert!(r.generated.len() <= 3, "stopped after at most 3 tokens");
    assert_eq!(r.generated[..], expected[..r.generated.len()], "a prefix of the greedy chain");
    assert_eq!(coord.metrics.stopped_early, 1);
    assert_eq!(coord.kv_used_blocks(), 0, "early stop released the whole reservation");
    assert_eq!(coord.backend.session_count(), 0);
}

/// Cancelling mid-prefill and mid-decode returns `kv_used_blocks()` to its
/// pre-admission value — including when the cancelled session holds
/// shared prefix blocks (refcounts decremented, not freed under the
/// surviving reader).
#[test]
fn cancel_mid_flight_releases_blocks_even_with_shared_prefix() {
    let engine = synth_engine(Method::Rap, 23);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let s_max = 256;
    let backend = RustBackend::new(&engine, s_max);
    let mut coord = Coordinator::new(
        backend,
        shape,
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: 4,
                buckets: vec![1, 4],
                max_queue: 16,
                prefill_chunk_tokens: 32,
                // Env-independent: the block-baseline equalities below
                // assume one token per tick freezes session 1's footprint;
                // the CI speculative matrix would emit several per tick.
                default_speculative: None,
                ..Default::default()
            },
            kv_budget_bytes: 32 << 20,
        },
    );

    // Session 1: 72-token prompt (64 block-aligned + 8), fed in 32-token
    // chunks; cancel it mid-prefill first to cover the prefilling state.
    let common = synth_prompt(64, 0);
    let mut p1 = common.clone();
    p1.extend([7u8; 8]);
    assert!(coord.submit(Request::new(9, p1.clone(), 40)));
    coord.tick().unwrap();
    assert!(coord.kv_used_blocks() > 0, "mid-prefill session holds blocks");
    let r9 = coord.cancel(9).expect("session 9 is mid-prefill");
    assert_eq!(r9.metrics.finish_reason, FinishReason::Cancelled);
    assert!(r9.generated.is_empty());
    assert_eq!(coord.kv_used_blocks(), 0, "mid-prefill cancel returns to baseline");

    // Session 1 again, run to steady decode; its prompt chunks are now
    // registered in the prefix trie.
    assert!(coord.submit(Request::new(1, p1, 40)));
    for _ in 0..4 {
        coord.tick().unwrap();
    }
    let baseline = coord.kv_used_blocks();
    assert!(baseline > 0, "session 1 decoding");

    // Session 2 shares the 64-token prefix read-only and decodes.
    let mut p2 = common.clone();
    p2.extend([9u8; 8]);
    assert!(coord.submit(Request::new(2, p2, 40)));
    coord.tick().unwrap();
    assert!(coord.metrics.prefix_hits >= 1, "session 2 attached the prefix");
    assert!(coord.kv_used_blocks() > baseline);

    // Cancel the sharer mid-decode: exactly its private blocks come back
    // (shared prefix refcounts drop without freeing under session 1).
    let r2 = coord.cancel(2).expect("session 2 is live");
    assert_eq!(r2.metrics.finish_reason, FinishReason::Cancelled);
    assert_eq!(
        coord.kv_used_blocks(),
        baseline,
        "cancel returned used blocks to the pre-admission value"
    );

    // Session 1 is unperturbed and still completes; then nothing is *used*
    // — but the shared prompt chunks stay resident as evictable cold cache
    // (storage-backed coordinators retain released prefixes by default, and
    // the allocator reclaims them on demand under pressure).
    let responses = coord.run_to_completion().unwrap();
    assert!(responses.iter().any(|r| r.id == 1 && r.generated.len() == 40));
    assert_eq!(coord.kv_used_blocks(), 0);
    assert!(coord.kv_prefix_nodes() > 0, "prompt chunks retained cold for reuse");
    assert_eq!(
        coord.kv_cold_blocks(),
        coord.kv_prefix_nodes(),
        "with no live session every resident chunk is cold (one block each)"
    );
    assert_eq!(coord.backend.session_count(), 0);
    assert_eq!(coord.metrics.cancelled, 2);
}

/// Retention under serving: a pressed session's evicted blocks return to
/// the free pool mid-flight, cancelling it restores `kv_used_blocks()` to
/// the pre-admission value, and the press never evicts blocks a second
/// session shares (refcount > 1 stays resident until release).
#[test]
fn retention_eviction_returns_blocks_and_respects_shared_prefix() {
    let engine = synth_engine(Method::Rap, 29);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let backend = RustBackend::new(&engine, 1024);
    let mut coord = Coordinator::new(
        backend,
        shape,
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: 4,
                buckets: vec![1, 4],
                max_queue: 16,
                prefill_chunk_tokens: 128,
                // Env-independent: the CI retention matrix sets
                // RAP_RETENTION, but this test manages specs per request.
                // Same for the speculative matrix: session 3's footprint
                // is frozen by a tick-counted one-token-per-tick argument.
                default_retention: None,
                default_speculative: None,
                ..Default::default()
            },
            kv_budget_bytes: 64 << 20,
        },
    );
    let spec = RetentionSpec { press: Press::Window, ratio: 0.5 };

    // Session 1: long prompt under a window press — the context crosses
    // the press floor during prefill, so blocks are evicted mid-flight.
    assert_eq!(coord.kv_used_blocks(), 0);
    assert!(coord.submit(Request::new(1, synth_prompt(792, 1), 24).with_retention(spec)));
    let mut ticks = 0;
    while coord.kv_evicted_tokens() == 0 {
        coord.tick().unwrap();
        ticks += 1;
        assert!(ticks < 64, "window press never fired on a 792-token context");
    }
    assert!(coord.metrics.retention_presses >= 1);
    assert!(coord.kv_used_blocks() > 0);
    let r1 = coord.cancel(1).expect("session 1 is live");
    assert_eq!(r1.metrics.finish_reason, FinishReason::Cancelled);
    assert_eq!(
        coord.kv_used_blocks(),
        0,
        "cancel of a mid-flight-evicted session returns every block (evicted and live)"
    );

    // Session 3 (retain-all) establishes a 256-token shared prefix and
    // decodes past its last block-boundary allocation (792 + 24 tokens
    // fill exactly 51 blocks, the last allocated at the 9th decode token),
    // so its footprint is frozen before the baseline is read.
    let common = synth_prompt(256, 5);
    let mut p3 = common.clone();
    p3.extend(synth_prompt(536, 6));
    let mut p4 = common.clone();
    p4.extend(synth_prompt(536, 7));
    assert!(coord.submit(Request::new(3, p3, 24)));
    for _ in 0..16 {
        coord.tick().unwrap();
    }
    let baseline = coord.kv_used_blocks();
    assert!(baseline > 0, "session 3 decoding");

    // Session 4 attaches the shared prefix and presses.  The press may
    // only evict its private rows: the shared blocks are refcount 2.
    let evicted_before = coord.kv_evicted_tokens();
    assert!(coord.submit(Request::new(4, p4, 24).with_retention(spec)));
    let mut ticks = 0;
    while coord.kv_evicted_tokens() == evicted_before {
        coord.tick().unwrap();
        ticks += 1;
        assert!(ticks < 64, "press never fired on the sharing session");
    }
    assert!(coord.metrics.prefix_hits >= 1, "session 4 attached the prefix");
    let pv = coord.kv_row_positions(4).expect("pressed session has an explicit map");
    let head: Vec<u32> = (0..256).collect();
    assert_eq!(&pv[..256], head.as_slice(), "shared refcount-2 blocks survive the press");

    // Cancel the sharer: exactly its private (and evicted-then-freed)
    // blocks come back; the shared prefix stays under session 3.
    let r4 = coord.cancel(4).expect("session 4 is live");
    assert_eq!(r4.metrics.finish_reason, FinishReason::Cancelled);
    assert_eq!(coord.kv_used_blocks(), baseline, "back to the pre-admission baseline");

    // Session 3 was never pressed and still completes in full.
    let responses = coord.run_to_completion().unwrap();
    assert!(responses.iter().any(|r| r.id == 3 && r.generated.len() == 24));
    assert_eq!(coord.kv_used_blocks(), 0);
}

/// TCP v2: streamed `{"delta"}` lines reassemble to exactly the one-shot
/// text for the same greedy request, the summary repeats the full text,
/// and the first delta arrives before the generation completes.
#[test]
fn tcp_streaming_deltas_reassemble_to_one_shot_text() {
    let factory = move || -> anyhow::Result<Coordinator<RustBackend<'static>>> {
        let engine: &'static Engine = Box::leak(Box::new(synth_engine(Method::Rap, 7)));
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let backend = RustBackend::new(engine, 128);
        Ok(Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 4,
                    buckets: vec![1, 4],
                    max_queue: 16,
                    ..Default::default()
                },
                kv_budget_bytes: 16 << 20,
            },
        ))
    };
    let handle = serve("127.0.0.1:0", factory, 2).unwrap();
    let addr = handle.addr;

    let one = client_request(&addr, "the quick brown ", 24).unwrap();
    let text = one.get("text").and_then(|t| t.as_str()).unwrap().to_string();
    assert_eq!(one.get("tokens").and_then(|t| t.as_usize()), Some(24));
    assert_eq!(
        one.get("finish_reason").and_then(|f| f.as_str()),
        Some("length"),
        "v1 one-shot replies gain the additive finish_reason field"
    );

    let body = obj(vec![("prompt", s("the quick brown ")), ("max_new", num(24.0))]);
    let sc = client_request_stream(&addr, &body).unwrap();
    assert!(sc.deltas.len() >= 2, "per-token deltas, not one blob: {:?}", sc.deltas);
    assert_eq!(
        sc.deltas.concat(),
        text,
        "greedy streamed deltas reassemble to the one-shot text"
    );
    assert_eq!(sc.summary.get("text").and_then(|t| t.as_str()), Some(text.as_str()));
    assert_eq!(sc.summary.get("finish_reason").and_then(|f| f.as_str()), Some("length"));
    assert_eq!(sc.summary.get("tokens").and_then(|t| t.as_usize()), Some(24));
    assert!(sc.first_delta_ms <= sc.total_ms);
    handle.shutdown();
}

/// TCP: a queue-full submission is answered with an explicit
/// `{"error": "queue_full"}` line immediately — the v1 code sent nothing
/// and left the client to ride out its full timeout.
#[test]
fn tcp_queue_full_rejected_immediately() {
    let factory = move || -> anyhow::Result<Coordinator<RustBackend<'static>>> {
        let engine: &'static Engine = Box::leak(Box::new(synth_engine(Method::Rap, 13)));
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let backend = RustBackend::new(engine, 64);
        Ok(Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 1,
                    buckets: vec![1],
                    max_queue: 0, // every submission is backpressured
                    ..Default::default()
                },
                kv_budget_bytes: 4 << 20,
            },
        ))
    };
    let handle = serve("127.0.0.1:0", factory, 2).unwrap();
    let t0 = std::time::Instant::now();
    let resp = client_request(&handle.addr, "hello", 8).unwrap();
    assert_eq!(resp.get("error").and_then(|e| e.as_str()), Some("queue_full"));
    assert_eq!(resp.get("finish_reason").and_then(|f| f.as_str()), Some("rejected"));
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(10),
        "rejection must be immediate, not a timeout"
    );
    handle.shutdown();
}

/// TCP: malformed `retention` fields are refused before admission with a
/// structured `{"error": "bad_request", "field": ...}` line naming the
/// offending field; a well-formed retention spec still serves.
#[test]
fn tcp_retention_bad_request_names_the_field() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let factory = move || -> anyhow::Result<Coordinator<RustBackend<'static>>> {
        let engine: &'static Engine = Box::leak(Box::new(synth_engine(Method::Rap, 31)));
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let backend = RustBackend::new(engine, 128);
        Ok(Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 2,
                    buckets: vec![1],
                    max_queue: 8,
                    ..Default::default()
                },
                kv_budget_bytes: 16 << 20,
            },
        ))
    };
    let handle = serve("127.0.0.1:0", factory, 2).unwrap();
    let addr = handle.addr;

    let send_raw = |raw: &str| -> rap::util::json::Value {
        let mut stream = TcpStream::connect(addr).unwrap();
        writeln!(stream, "{raw}").unwrap();
        let mut line = String::new();
        BufReader::new(stream).read_line(&mut line).unwrap();
        rap::util::json::parse(line.trim()).unwrap()
    };

    // Unknown policy: refused at parse time, before admission.
    let r =
        send_raw(r#"{"prompt": "x", "max_new": 4, "retention": {"policy": "lru", "ratio": 0.5}}"#);
    assert_eq!(r.get("error").and_then(|e| e.as_str()), Some("bad_request"), "{r:?}");
    assert_eq!(r.get("field").and_then(|f| f.as_str()), Some("retention.policy"));

    // A retention object with no policy at all is equally refused.
    let r = send_raw(r#"{"prompt": "x", "max_new": 4, "retention": {}}"#);
    assert_eq!(r.get("error").and_then(|e| e.as_str()), Some("bad_request"), "{r:?}");
    assert_eq!(r.get("field").and_then(|f| f.as_str()), Some("retention.policy"));

    // Ratio outside (0, 1].
    let r = send_raw(
        r#"{"prompt": "x", "max_new": 4, "retention": {"policy": "window", "ratio": 1.5}}"#,
    );
    assert_eq!(r.get("error").and_then(|e| e.as_str()), Some("bad_request"), "{r:?}");
    assert_eq!(r.get("field").and_then(|f| f.as_str()), Some("retention.ratio"));

    // A well-formed spec is admitted and serves (the context is far below
    // the press floor, so the reply is the plain one-shot shape).
    let r = send_raw(
        r#"{"prompt": "hello ", "max_new": 4, "retention": {"policy": "window", "ratio": 0.5}}"#,
    );
    assert!(r.get("error").is_none(), "valid retention must serve: {r:?}");
    assert_eq!(r.get("tokens").and_then(|t| t.as_usize()), Some(4));

    // Speculative specs ride the same parse-time validation: unknown or
    // missing policy, and k outside [1, 32], are refused before admission
    // with the offending field named.
    let r = send_raw(
        r#"{"prompt": "x", "max_new": 4, "speculative": {"policy": "medusa", "k": 4}}"#,
    );
    assert_eq!(r.get("error").and_then(|e| e.as_str()), Some("bad_request"), "{r:?}");
    assert_eq!(r.get("field").and_then(|f| f.as_str()), Some("speculative.policy"));

    let r = send_raw(r#"{"prompt": "x", "max_new": 4, "speculative": {}}"#);
    assert_eq!(r.get("error").and_then(|e| e.as_str()), Some("bad_request"), "{r:?}");
    assert_eq!(r.get("field").and_then(|f| f.as_str()), Some("speculative.policy"));

    let r = send_raw(
        r#"{"prompt": "x", "max_new": 4, "speculative": {"policy": "ngram", "k": 0}}"#,
    );
    assert_eq!(r.get("error").and_then(|e| e.as_str()), Some("bad_request"), "{r:?}");
    assert_eq!(r.get("field").and_then(|f| f.as_str()), Some("speculative.k"));

    let r = send_raw(
        r#"{"prompt": "x", "max_new": 4, "speculative": {"policy": "ngram", "k": 64}}"#,
    );
    assert_eq!(r.get("error").and_then(|e| e.as_str()), Some("bad_request"), "{r:?}");
    assert_eq!(r.get("field").and_then(|f| f.as_str()), Some("speculative.k"));

    // A well-formed speculative request serves, bit-identical to plain
    // decode (the text matches the non-speculative request above it).
    let plain = send_raw(r#"{"prompt": "hello ", "max_new": 4}"#);
    let spec = send_raw(
        r#"{"prompt": "hello ", "max_new": 4, "speculative": {"policy": "ngram", "k": 4}}"#,
    );
    assert!(spec.get("error").is_none(), "valid speculative must serve: {spec:?}");
    assert_eq!(
        spec.get("text").and_then(|t| t.as_str()),
        plain.get("text").and_then(|t| t.as_str()),
        "speculative output must match plain decode"
    );
    handle.shutdown();
}

/// TCP: `{"cancel": id}` from another connection tears down a streaming
/// request mid-decode; its stream ends with a `finish_reason: "cancelled"`
/// summary instead of running to max_new.
#[test]
fn tcp_cancel_mid_stream_ends_with_cancelled_summary() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let factory = move || -> anyhow::Result<Coordinator<RustBackend<'static>>> {
        let engine: &'static Engine = Box::leak(Box::new(synth_engine(Method::Rap, 19)));
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let backend = RustBackend::new(engine, 4096);
        Ok(Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 2,
                    buckets: vec![1],
                    max_queue: 8,
                    ..Default::default()
                },
                kv_budget_bytes: 64 << 20,
            },
        ))
    };
    let handle = serve("127.0.0.1:0", factory, 2).unwrap();
    let addr = handle.addr;

    let req = obj(vec![
        ("prompt", s("cancel me please ")),
        ("max_new", num(2000.0)),
        ("stream", rap::util::json::Value::Bool(true)),
    ]);
    let mut stream = TcpStream::connect(addr).unwrap();
    writeln!(stream, "{req}").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let first = rap::util::json::parse(line.trim()).unwrap();
    let id = first.get("id").and_then(|i| i.as_usize()).expect("first delta carries the id");
    assert!(first.get("delta").is_some(), "line 1 is a delta: {line}");

    // Cancel from a second connection.
    let mut c2 = TcpStream::connect(addr).unwrap();
    writeln!(c2, "{}", obj(vec![("cancel", num(id as f64))])).unwrap();
    let mut ack = String::new();
    BufReader::new(c2).read_line(&mut ack).unwrap();
    assert!(ack.contains("\"ok\""), "cancel acked: {ack}");

    // Drain the stream to its terminal line.
    let mut deltas = 1usize;
    let finish = loop {
        line.clear();
        assert!(reader.read_line(&mut line).unwrap() > 0, "stream closed without summary");
        let v = rap::util::json::parse(line.trim()).unwrap();
        if let Some(reason) = v.get("finish_reason").and_then(|f| f.as_str()) {
            break reason.to_string();
        }
        assert!(v.get("delta").is_some());
        deltas += 1;
    };
    assert_eq!(finish, "cancelled");
    assert!(
        deltas < 2000,
        "cancellation must end the stream early (saw {deltas} deltas)"
    );
    // Close the client connection before shutdown: the handler thread is
    // parked in read_line on it, and ServerHandle::shutdown joins the
    // handler pool.
    drop(reader);
    drop(stream);
    handle.shutdown();
}

#[test]
fn tcp_server_round_trip() {
    let factory = move || {
        let m = Manifest::load_default()?;
        let entry = m.model("tinyllama")?;
        let shape = CacheShape::of(&entry.config, &entry.variants["rap_r30"].spec);
        // Engine leaks deliberately: server lifetime == process lifetime.
        let engine: &'static rap::model::Engine =
            Box::leak(Box::new(load_engine(&m, "tinyllama", "rap_r30")?));
        let backend = RustBackend::new(engine, 128);
        Ok(Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 2,
                    buckets: vec![1, 4],
                    max_queue: 16,
                    ..Default::default()
                },
                kv_budget_bytes: 16 << 20,
            },
        ))
    };
    let handle = serve("127.0.0.1:0", factory, 2).unwrap();
    let addr = handle.addr;

    let resp = client_request(&addr, "the quick brown ", 8).unwrap();
    let text = resp.get("text").and_then(|t| t.as_str()).unwrap().to_string();
    assert_eq!(resp.get("tokens").and_then(|t| t.as_usize()), Some(8));
    assert_eq!(text.len(), 8);
    // Second request on a fresh connection also works.
    let resp2 = client_request(&addr, "words and more ", 4).unwrap();
    assert_eq!(resp2.get("tokens").and_then(|t| t.as_usize()), Some(4));
    handle.shutdown();
}
