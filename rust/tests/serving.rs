//! Serving-stack integration tests: coordinator over both backends, the
//! TCP server, and KV accounting under load.  Require `make artifacts`.

use rap::config::Method;
use rap::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Request};
use rap::kvcache::CacheShape;
use rap::manifest::Manifest;
use rap::model::backend::RustBackend;
use rap::model::load_engine;
use rap::model::synth::synth_engine;
use rap::runtime::backend::PjrtBackend;
use rap::runtime::{PjrtContext, PjrtEngine};
use rap::server::{client_request, serve};
use rap::workload::{generate, WorkloadConfig};

fn manifest() -> Manifest {
    Manifest::load_default().expect("run `make artifacts` before cargo test")
}

fn coordinator_cfg(buckets: Vec<usize>) -> CoordinatorConfig {
    CoordinatorConfig {
        batcher: BatcherConfig {
            max_sessions: 3,
            buckets,
            max_queue: 64,
            ..Default::default()
        },
        kv_budget_bytes: 32 << 20,
    }
}

#[test]
fn coordinator_over_rust_backend_serves_trace() {
    let m = manifest();
    let entry = m.model("tinyllama").unwrap();
    let engine = load_engine(&m, "tinyllama", "rap_r30").unwrap();
    let backend = RustBackend::new(&engine, 128);
    let shape = CacheShape::of(&entry.config, &entry.variants["rap_r30"].spec);
    let mut coord = Coordinator::new(backend, shape, coordinator_cfg(vec![1, 4]));

    let corpus = m.eval_corpus().unwrap();
    let wl = generate(
        &WorkloadConfig {
            n_requests: 6,
            prompt_lens: vec![8, 16],
            min_new: 4,
            max_new: 8,
            ..Default::default()
        },
        &corpus,
    );
    for tr in wl {
        assert!(coord.submit(tr.request));
    }
    let responses = coord.run_to_completion().unwrap();
    assert_eq!(responses.len(), 6);
    for r in &responses {
        assert!(!r.generated.is_empty());
        assert!(r.metrics.ttft_ms > 0.0);
    }
    assert_eq!(coord.kv_used_blocks(), 0, "all KV released");
    assert!(coord.metrics.throughput_tps() > 0.0);
}

#[test]
fn coordinator_over_pjrt_backend_matches_sequential_generation() {
    let m = manifest();
    let ctx = PjrtContext::cpu().unwrap();
    let engine = PjrtEngine::load(&ctx, &m, "tinyllama", "rap_r30").unwrap();
    let entry = m.model("tinyllama").unwrap();
    let shape = CacheShape::of(&entry.config, &entry.variants["rap_r30"].spec);

    // Reference: sequential generation of each prompt.
    let corpus = m.eval_corpus().unwrap();
    let prompts: Vec<Vec<u8>> = vec![
        corpus[..16].to_vec(),
        corpus[100..116].to_vec(),
        corpus[500..508].to_vec(),
    ];
    let mut expected = Vec::new();
    {
        let mut backend = PjrtBackend::new(&ctx, &engine).unwrap();
        let mut kv = rap::kvcache::PagedKvCache::new(shape.clone(), 32 << 20);
        for (i, p) in prompts.iter().enumerate() {
            expected.push(
                rap::runtime::backend::generate_once(&mut backend, &mut kv, 1000 + i as u64, p, 6)
                    .unwrap(),
            );
        }
    }

    // Coordinator path: all three concurrently (batched decode).
    let backend = PjrtBackend::new(&ctx, &engine).unwrap();
    let mut coord = Coordinator::new(backend, shape, coordinator_cfg(engine.decode_batches()));
    for (i, p) in prompts.iter().enumerate() {
        coord.submit(Request::new(i as u64, p.clone(), 6));
    }
    let mut responses = coord.run_to_completion().unwrap();
    responses.sort_by_key(|r| r.id);
    for (r, e) in responses.iter().zip(&expected) {
        assert_eq!(&r.generated, e, "batched output must equal sequential");
    }
}

#[test]
fn kv_pressure_defers_admission_but_everything_completes() {
    let m = manifest();
    let entry = m.model("tinyllama").unwrap();
    let engine = load_engine(&m, "tinyllama", "rap_r30").unwrap();
    let backend = RustBackend::new(&engine, 96);
    let shape = CacheShape::of(&entry.config, &entry.variants["rap_r30"].spec);
    // Tiny KV budget: only ~2 sessions' worth of blocks.
    let budget = shape.bytes_per_token() * 96 * 2;
    let mut coord = Coordinator::new(
        backend,
        shape,
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: 8,
                buckets: vec![1, 4],
                max_queue: 64,
                ..Default::default()
            },
            kv_budget_bytes: budget,
        },
    );
    let corpus = m.eval_corpus().unwrap();
    for i in 0..5u64 {
        coord.submit(Request::new(i, corpus[..32].to_vec(), 8));
    }
    let responses = coord.run_to_completion().unwrap();
    assert_eq!(responses.len(), 5, "deferred requests still complete");
    assert!(coord.metrics.peak_kv_blocks > 0);
}

#[test]
fn quantized_backend_still_generates_sensibly() {
    let m = manifest();
    let entry = m.model("tinyllama").unwrap();
    let engine = load_engine(&m, "tinyllama", "rap_r30").unwrap();
    let mut backend = RustBackend::new(&engine, 64);
    backend.quantize_kv = true;
    let shape = CacheShape::of(&entry.config, &entry.variants["rap_r30"].spec);
    let mut kv = rap::kvcache::PagedKvCache::with_storage(shape, 16 << 20);
    let corpus = m.eval_corpus().unwrap();
    let out =
        rap::runtime::backend::generate_once(&mut backend, &mut kv, 1, &corpus[..16], 8).unwrap();
    assert_eq!(out.len(), 8);
    assert!(out.iter().all(|&c| c == b' ' || c.is_ascii_graphic() || c == b'\n'));
    assert_eq!(kv.used_blocks(), 0, "generate_once releases its session");
}

/// A zero-token request admitted through the coordinator must complete
/// cleanly with an empty generation — the engine has no position to
/// compute logits at, and argmaxing a stale workspace would emit garbage
/// tokens (or, worse, another request's logits).  Runs on the real
/// RustBackend over synthetic weights — no artifacts needed.
#[test]
fn empty_prompt_over_rust_backend_yields_empty_generation() {
    let engine = synth_engine(Method::Rap, 23);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let s_max = 96;

    // Reference for the non-empty request decoded alone.
    let solo = {
        let mut backend = RustBackend::new(&engine, s_max);
        let mut kv = rap::kvcache::PagedKvCache::with_storage(shape.clone(), 8 << 20);
        rap::runtime::backend::generate_once(&mut backend, &mut kv, 50, &[5, 6, 7], 6).unwrap()
    };

    let backend = RustBackend::new(&engine, s_max);
    let mut coord = Coordinator::new(
        backend,
        shape,
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: 4,
                buckets: vec![1, 4],
                max_queue: 16,
                ..Default::default()
            },
            kv_budget_bytes: 8 << 20,
        },
    );
    assert!(coord.submit(Request::new(1, Vec::new(), 6)));
    assert!(coord.submit(Request::new(2, vec![5, 6, 7], 6)));
    assert!(coord.submit(Request::new(3, Vec::new(), 0)));
    let mut responses = coord.run_to_completion().unwrap();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), 3);
    assert!(responses[0].generated.is_empty(), "no prompt -> no tokens");
    assert_eq!(responses[0].metrics.generated_tokens, 0);
    assert_eq!(responses[1].generated, solo, "neighbour request unperturbed");
    assert!(responses[2].generated.is_empty());
    assert_eq!(coord.backend.session_count(), 0, "no dangling sessions");
    assert_eq!(coord.kv_used_blocks(), 0, "empty prompts release their reservation");
}

#[test]
fn tcp_server_round_trip() {
    let factory = move || {
        let m = Manifest::load_default()?;
        let entry = m.model("tinyllama")?;
        let shape = CacheShape::of(&entry.config, &entry.variants["rap_r30"].spec);
        // Engine leaks deliberately: server lifetime == process lifetime.
        let engine: &'static rap::model::Engine =
            Box::leak(Box::new(load_engine(&m, "tinyllama", "rap_r30")?));
        let backend = RustBackend::new(engine, 128);
        Ok(Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 2,
                    buckets: vec![1, 4],
                    max_queue: 16,
                    ..Default::default()
                },
                kv_budget_bytes: 16 << 20,
            },
        ))
    };
    let handle = serve("127.0.0.1:0", factory, 2).unwrap();
    let addr = handle.addr;

    let resp = client_request(&addr, "the quick brown ", 8).unwrap();
    let text = resp.get("text").and_then(|t| t.as_str()).unwrap().to_string();
    assert_eq!(resp.get("tokens").and_then(|t| t.as_usize()), Some(8));
    assert_eq!(text.len(), 8);
    // Second request on a fresh connection also works.
    let resp2 = client_request(&addr, "words and more ", 4).unwrap();
    assert_eq!(resp2.get("tokens").and_then(|t| t.as_usize()), Some(4));
    handle.shutdown();
}
