//! Blocked-prefill identity suite over synthetic weights — runs without
//! `make artifacts`.
//!
//! The block-parallel chunked prefill (`Engine::prefill_chunk_dense` /
//! `prefill_chunk_paged`) must be **bit-identical** to the token-by-token
//! loop (`Engine::prefill_token_loop`) for every method and every chunk
//! partition — same oracle convention as the decode suite in
//! `tests/paged.rs`.  Three layers:
//!   1. dense chunked prefill vs the token loop, logits AND cache rows,
//!      randomized prompt lengths / chunk sizes via `util::propcheck`;
//!   2. paged chunked prefill vs dense, including the decode step that
//!      consumes the chunk-written rows;
//!   3. chunked admission through the coordinator vs sequential
//!      whole-prompt generation.

use rap::config::Method;
use rap::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Request};
use rap::kvcache::{CacheShape, PagedKvCache};
use rap::model::backend::RustBackend;
use rap::model::synth::synth_engine;
use rap::model::{BatchWorkspace, PrefillWorkspace};
use rap::runtime::backend::generate_once;
use rap::util::propcheck::forall_res;

const METHODS: [Method; 4] = [Method::Baseline, Method::Svd, Method::Palu, Method::Rap];

fn prompt(len: usize, salt: usize) -> Vec<u8> {
    (0..len).map(|i| ((i * 37 + salt * 101) % 251) as u8).collect()
}

#[test]
fn blocked_prefill_matches_token_loop_bitwise() {
    for method in METHODS {
        let engine = synth_engine(method, 11);
        forall_res(
            17,
            10,
            |r| {
                let len = r.range(1, 96);
                let chunk = r.range(1, 40);
                let salt = r.below(1000);
                (len, chunk, salt)
            },
            |&(len, chunk, salt)| {
                let p = prompt(len, salt);
                let s_max = 128;
                let mut ref_cache = engine.new_cache(s_max);
                let ref_logits = engine.prefill_token_loop(&p, &mut ref_cache);
                let mut cache = engine.new_cache(s_max);
                let mut ws = PrefillWorkspace::new(&engine, s_max);
                engine.prefill_chunked(&p, chunk, &mut cache, &mut ws);
                if ws.logits() != ref_logits.as_slice() {
                    return Err(format!("{method:?}: logits diverge (len {len}, chunk {chunk})"));
                }
                for (l, (a, b)) in ref_cache.layers.iter().zip(&cache.layers).enumerate() {
                    if a.k != b.k {
                        return Err(format!("{method:?}: layer {l} K rows diverge"));
                    }
                    if a.v != b.v {
                        return Err(format!("{method:?}: layer {l} V rows diverge"));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn paged_chunked_prefill_matches_dense_and_decodes_identically() {
    for method in METHODS {
        let engine = synth_engine(method, 13);
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let s_max = 96;
        // 70 tokens in uneven chunks: crosses block seams (BLOCK_TOKENS=16)
        // and chunk boundaries that don't align with them.
        let p = prompt(70, 5);
        let chunks = [13usize, 16, 7, 20, 14];
        assert_eq!(chunks.iter().sum::<usize>(), p.len());

        let mut dense_cache = engine.new_cache(s_max);
        let mut dense_ws = PrefillWorkspace::new(&engine, s_max);
        engine.prefill_chunked(&p, 17, &mut dense_cache, &mut dense_ws);

        let mut kv = PagedKvCache::with_storage(shape, 8 << 20);
        kv.reserve(1, s_max).unwrap();
        let mut ws = PrefillWorkspace::new(&engine, s_max);
        let mut pos0 = 0;
        for (ci, &c) in chunks.iter().enumerate() {
            let last = ci + 1 == chunks.len();
            engine
                .prefill_chunk_paged(1, &p[pos0..pos0 + c], pos0, &mut kv, &mut ws, last, false)
                .unwrap();
            pos0 += c;
        }
        assert_eq!(ws.logits(), dense_ws.logits(), "{method:?}: prefill logits");

        // The chunk-written paged rows must serve decode exactly like the
        // dense cache: step one token both ways and compare logits bitwise.
        let next = 65u8;
        let dense_logits = engine.step(next, p.len(), &mut dense_cache);
        let mut batch = BatchWorkspace::new(&engine, s_max);
        engine
            .decode_batch_paged(&[(1, next, p.len())], &mut kv, &mut batch, true)
            .unwrap();
        assert_eq!(
            dense_logits.as_slice(),
            batch.logits_row(0),
            "{method:?}: decode after chunked prefill"
        );
    }
}

/// Quantized prefill must be chunk-size-invariant: every latent row is
/// int4 round-tripped right after it is written and before any attention
/// reads it, so the partition of the prompt into chunks cannot change the
/// logits.  Propchecked against the whole-prompt run for chunk sizes
/// {1, 16, 64} and random partitions — the regression was chunk-granular
/// round-trips, where the in-flight chunk read full-precision rows and
/// `prefill_chunk_tokens` leaked into the numerics.
#[test]
fn quantized_prefill_is_chunk_size_invariant() {
    for method in METHODS {
        let engine = synth_engine(method, 31);
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let s_max = 128;
        let p = prompt(70, 7);

        // Reference: the whole prompt in one quantized chunk.
        let quantized_prefill = |chunks: &[usize]| -> Vec<f32> {
            assert_eq!(chunks.iter().sum::<usize>(), p.len());
            let mut kv = PagedKvCache::with_storage(shape.clone(), 8 << 20);
            kv.reserve(1, s_max).unwrap();
            let mut ws = PrefillWorkspace::new(&engine, s_max);
            let mut pos0 = 0;
            for (ci, &c) in chunks.iter().enumerate() {
                let last = ci + 1 == chunks.len();
                engine
                    .prefill_chunk_paged(1, &p[pos0..pos0 + c], pos0, &mut kv, &mut ws, last, true)
                    .unwrap();
                pos0 += c;
            }
            ws.logits().to_vec()
        };
        let whole = quantized_prefill(&[p.len()]);
        for fixed in [1usize, 16, 64] {
            let mut chunks: Vec<usize> = vec![fixed; p.len() / fixed];
            if p.len() % fixed > 0 {
                chunks.push(p.len() % fixed);
            }
            assert_eq!(
                quantized_prefill(&chunks),
                whole,
                "{method:?}: chunk size {fixed} diverges from whole-prompt"
            );
        }
        forall_res(
            23,
            6,
            |r| {
                let mut chunks = Vec::new();
                let mut left = p.len();
                while left > 0 {
                    let c = r.range(1, 33).min(left);
                    chunks.push(c);
                    left -= c;
                }
                chunks
            },
            |chunks| {
                if quantized_prefill(chunks) != whole {
                    return Err(format!("{method:?}: partition {chunks:?} diverges"));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn chunked_admission_serves_bit_identical_outputs() {
    const MAX_NEW: usize = 8;
    for method in METHODS {
        let engine = synth_engine(method, 19);
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let s_max = 96;
        let prompts: Vec<Vec<u8>> = (0..3).map(|i| prompt(40 + 3 * i, i)).collect();

        // Reference: whole-prompt prefill, each session alone.
        let mut expected = Vec::new();
        {
            let mut backend = RustBackend::new(&engine, s_max);
            let mut kv = PagedKvCache::with_storage(shape.clone(), 16 << 20);
            for (i, p) in prompts.iter().enumerate() {
                expected.push(
                    generate_once(&mut backend, &mut kv, 700 + i as u64, p, MAX_NEW).unwrap(),
                );
            }
        }

        // Coordinator with a tiny prefill budget: every prompt is fed in
        // several chunks, interleaved with the other sessions' decodes.
        let backend = RustBackend::new(&engine, s_max);
        let mut coord = Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 3,
                    buckets: vec![1, 4],
                    max_queue: 16,
                    prefill_chunk_tokens: 16,
                    ..Default::default()
                },
                kv_budget_bytes: 16 << 20,
            },
        );
        for (i, p) in prompts.iter().enumerate() {
            assert!(coord.submit(Request::new(i as u64, p.clone(), MAX_NEW)));
        }
        let mut responses = coord.run_to_completion().unwrap();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), prompts.len());
        for (r, e) in responses.iter().zip(&expected) {
            assert_eq!(&r.generated, e, "{method:?} session {}", r.id);
        }
        assert!(
            coord.metrics.prefill_chunks as usize > prompts.len(),
            "{method:?}: prompts must actually be chunked"
        );
        assert_eq!(coord.kv_used_blocks(), 0, "{method:?}: all KV released");
    }
}
