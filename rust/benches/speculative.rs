//! Speculative-decode gate: self-drafted n-gram speculation vs plain
//! one-token decode on the real (synthetic-weight) engine.
//!
//! Headline: on a repetitive workload the best engine seed must accept
//! enough draft tokens to clear **1.5 emitted tokens per verify step**
//! and must not lose wall time (`decode speedup >= 1.0`).  The blocked
//! verify chunk streams the weight matrices once for the whole draft,
//! so every accepted token above one per step is a weight-streaming
//! pass saved — the same memory-bound argument as GPU speculative
//! decode.  A random-byte workload is reported alongside without the
//! speedup gate (n-gram drafting has nothing to copy there; the cost is
//! bounded wasted verify width, never wrong tokens).
//!
//! Every arm — repetitive or random, accepted or rejected — must be
//! **byte-identical** to its plain-decode twin; that parity is asserted
//! unconditionally.  Results land in `BENCH_speculative.json` (uploaded
//! by CI next to the serving/retention artifacts).
//!
//! Greedy decode from a random-weight transformer settles into a short
//! cycle once the context window is dominated by its own output; the
//! n-gram drafter then predicts the cycle exactly. Seeds differ in how
//! fast they settle, so the headline sweeps engine seeds and gates on
//! the best — the claim is "speculation pays on repetitive streams",
//! not "every random weight matrix repeats itself".

use std::time::Instant;

use rap::config::Method;
use rap::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, FinishReason, Request};
use rap::kvcache::{CacheShape, BLOCK_TOKENS};
use rap::model::backend::{BackendConfig, RustBackend};
use rap::model::synth::synth_engine;
use rap::model::Engine;
use rap::speculate::SpeculativeSpec;
use rap::tensor::simd::KernelPath;

fn repetitive_prompt(len: usize) -> Vec<u8> {
    let phrase = b"the quick latent cache ran past the quick latent press ";
    (0..len).map(|i| phrase[i % phrase.len()]).collect()
}

fn random_prompt(len: usize) -> Vec<u8> {
    (0..len).map(|i| ((i * 37 + 11) % 251) as u8).collect()
}

struct RunStats {
    generated: Vec<u8>,
    wall_ms: f64,
    decode_s: f64,
    decode_tok_s: f64,
    spec_steps: u64,
    drafted: u64,
    accepted: u64,
    rolled_back: u64,
    /// Mean emitted tokens per speculative step (0 when the run never
    /// speculated — i.e. the plain arm).
    tokens_per_step: f64,
}

/// Serve one request to completion; the speculative spec (if any) rides
/// on the request, and both fleet defaults are pinned off so the bench
/// is insensitive to the CI matrix environment.
fn run(
    engine: &mut Engine,
    shape: &CacheShape,
    prompt: Vec<u8>,
    max_new: usize,
    spec: Option<SpeculativeSpec>,
) -> RunStats {
    let s_max = prompt.len() + max_new + 16;
    let backend = RustBackend::with_config(
        engine,
        s_max,
        BackendConfig { kernel_path: KernelPath::Wide, quantize_kv: false },
    );
    let blocks = s_max.div_ceil(BLOCK_TOKENS) + 8;
    let mut coord = Coordinator::new(
        backend,
        shape.clone(),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: 1,
                buckets: vec![1],
                max_queue: 2,
                prefill_chunk_tokens: 512,
                default_retention: None,
                default_speculative: None,
                ..Default::default()
            },
            kv_budget_bytes: shape.bytes_per_token() * BLOCK_TOKENS * blocks,
        },
    );
    let mut req = Request::new(1, prompt, max_new);
    if let Some(spec) = spec {
        req = req.with_speculative(spec);
    }
    assert!(coord.submit(req));
    let t0 = Instant::now();
    let responses = coord.run_to_completion().unwrap();
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(responses.len(), 1);
    let r = &responses[0];
    assert_eq!(r.metrics.finish_reason, FinishReason::Length);
    assert_eq!(r.generated.len(), max_new);
    let decode_s = ((wall_ms - r.metrics.ttft_ms) / 1e3).max(1e-9);
    RunStats {
        generated: r.generated.clone(),
        wall_ms,
        decode_s,
        decode_tok_s: max_new.saturating_sub(1) as f64 / decode_s,
        spec_steps: coord.metrics.spec_steps,
        drafted: coord.metrics.spec_drafted_tokens,
        accepted: coord.metrics.spec_accepted_tokens,
        rolled_back: coord.metrics.spec_rolled_back_rows,
        tokens_per_step: coord.metrics.spec_tokens_per_step.mean(),
    }
}

fn main() {
    use rap::util::json::{num, obj, s, Value};

    let fast = std::env::var("RAP_BENCH_FAST").is_ok();
    let max_new = if fast { 96 } else { 160 };
    let prompt_len = 256;
    let seeds: &[u64] = if fast { &[11, 17] } else { &[11, 17, 23, 31] };
    let spec = SpeculativeSpec::parse("ngram:8").unwrap();

    println!("== bench: speculative (ngram:8, {max_new} new tokens, seeds {seeds:?}) ==");

    // Repetitive workload, engine-seed sweep; parity asserted per seed,
    // acceptance/speedup gated on the best seed.
    let mut sweep_rows = Vec::new();
    let mut best: Option<(u64, RunStats, RunStats)> = None;
    for &seed in seeds {
        let mut engine = synth_engine(Method::Rap, seed);
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let plain = run(&mut engine, &shape, repetitive_prompt(prompt_len), max_new, None);
        let fastr = run(&mut engine, &shape, repetitive_prompt(prompt_len), max_new, Some(spec));
        assert_eq!(
            fastr.generated, plain.generated,
            "seed {seed}: speculative output must be byte-identical to plain decode"
        );
        assert_eq!(plain.spec_steps, 0, "the plain arm must not speculate");
        let speedup = plain.decode_s / fastr.decode_s;
        println!(
            "seed {seed}: {:.2} tok/step over {} spec steps ({} drafted, {} accepted, \
             {} rolled back), decode {:.0} tok/s vs plain {:.0} tok/s (speedup {speedup:.2}x)",
            fastr.tokens_per_step,
            fastr.spec_steps,
            fastr.drafted,
            fastr.accepted,
            fastr.rolled_back,
            fastr.decode_tok_s,
            plain.decode_tok_s,
        );
        sweep_rows.push(obj(vec![
            ("engine_seed", num(seed as f64)),
            ("tokens_per_step", num(fastr.tokens_per_step)),
            ("spec_steps", num(fastr.spec_steps as f64)),
            ("drafted", num(fastr.drafted as f64)),
            ("accepted", num(fastr.accepted as f64)),
            ("rolled_back_rows", num(fastr.rolled_back as f64)),
            ("decode_tok_s", num(fastr.decode_tok_s)),
            ("plain_decode_tok_s", num(plain.decode_tok_s)),
            ("decode_speedup", num(speedup)),
        ]));
        let better = match &best {
            Some((_, _, b)) => fastr.tokens_per_step > b.tokens_per_step,
            None => true,
        };
        if better {
            best = Some((seed, plain, fastr));
        }
    }
    let (best_seed, best_plain, best_spec) = best.unwrap();
    let best_speedup = best_plain.decode_s / best_spec.decode_s;
    println!(
        "headline (seed {best_seed}): {:.2} tokens/step, decode speedup {best_speedup:.2}x",
        best_spec.tokens_per_step
    );
    assert!(
        best_spec.tokens_per_step > 1.5,
        "repetitive workload must accept > 1.5 tokens per verify step (best seed {best_seed} \
         managed {:.2})",
        best_spec.tokens_per_step
    );
    assert!(
        best_speedup >= 1.0,
        "speculation must not lose wall time on the repetitive workload (best seed {best_seed}: \
         {best_speedup:.2}x)"
    );

    // Random workload: no acceptance expectation, parity still holds.
    let mut engine = synth_engine(Method::Rap, seeds[0]);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let rnd_plain = run(&mut engine, &shape, random_prompt(prompt_len), max_new, None);
    let rnd_spec = run(&mut engine, &shape, random_prompt(prompt_len), max_new, Some(spec));
    assert_eq!(
        rnd_spec.generated, rnd_plain.generated,
        "random workload: speculative output must be byte-identical to plain decode"
    );
    let rnd_speedup = rnd_plain.decode_s / rnd_spec.decode_s;
    println!(
        "random: {:.2} tok/step over {} spec steps, decode {:.0} tok/s vs plain {:.0} tok/s \
         (speedup {rnd_speedup:.2}x)",
        rnd_spec.tokens_per_step,
        rnd_spec.spec_steps,
        rnd_spec.decode_tok_s,
        rnd_plain.decode_tok_s,
    );

    let stats_obj = |r: &RunStats| {
        obj(vec![
            ("wall_ms", num(r.wall_ms)),
            ("decode_tok_s", num(r.decode_tok_s)),
            ("spec_steps", num(r.spec_steps as f64)),
            ("drafted", num(r.drafted as f64)),
            ("accepted", num(r.accepted as f64)),
            ("rolled_back_rows", num(r.rolled_back as f64)),
            ("tokens_per_step", num(r.tokens_per_step)),
        ])
    };
    let summary: Value = obj(vec![
        ("bench", s("speculative")),
        ("policy", s("ngram")),
        ("draft_k", num(8.0)),
        ("max_new", num(max_new as f64)),
        ("prompt_tokens", num(prompt_len as f64)),
        ("headline_engine_seed", num(best_seed as f64)),
        ("headline_tokens_per_step", num(best_spec.tokens_per_step)),
        ("headline_decode_speedup", num(best_speedup)),
        ("headline_plain", stats_obj(&best_plain)),
        ("headline_speculative", stats_obj(&best_spec)),
        ("repetitive_seed_sweep", Value::Arr(sweep_rows)),
        ("random_plain", stats_obj(&rnd_plain)),
        ("random_speculative", stats_obj(&rnd_spec)),
        ("random_decode_speedup", num(rnd_speedup)),
    ]);
    let _ = std::fs::write("BENCH_speculative.json", summary.to_string_pretty());
    println!("-> BENCH_speculative.json");
}
