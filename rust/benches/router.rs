//! Router gate: prefix-affinity routing vs seeded-random routing across a
//! 3-replica fleet under skewed (zipf) prefix popularity, plus a
//! saturation burst for queue-full behaviour.  Pure-Rust synthetic
//! engine — no artifacts needed.
//!
//! The claim under test is the router's reason to exist: rendezvous
//! prefix affinity sends every repeat of a popular prompt prefix to the
//! replica already holding it warm, so after one warm pass the measured
//! phase takes **zero** prefix-cache misses — while random routing keeps
//! re-paying cold prefills on whichever replica the dice pick, which is
//! exactly what the TTFT p99 tail shows.  Results land in
//! `BENCH_router.json` (uploaded by the router-chaos CI job); the bench
//! asserts affinity wins on both fleet hit-rate and TTFT p99, so a
//! routing regression fails the gate instead of drifting.

use std::net::SocketAddr;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;
use rap::config::Method;
use rap::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use rap::kvcache::CacheShape;
use rap::model::backend::RustBackend;
use rap::model::synth::synth_engine;
use rap::router::{serve_router, HealthConfig, RetryConfig, RoutePolicy, RouterConfig};
use rap::server::{client_request_stream, serve_with_config, ServerConfig, ServerHandle};
use rap::util::json::{num, obj, s, Value};
use rap::util::rng::Rng;
use rap::util::threadpool::ThreadPool;

const REPLICAS: usize = 3;

fn start_replica(max_sessions: usize, max_queue: usize, s_max: usize) -> ServerHandle {
    let factory = move || -> Result<Coordinator<RustBackend<'static>>> {
        // Engine leaks deliberately: server lifetime == process lifetime.
        // Every replica shares the seed, so any replica serves any prompt
        // identically — what makes re-routing transparent.
        let engine: &'static rap::model::Engine =
            Box::leak(Box::new(synth_engine(Method::Rap, 11)));
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let backend = RustBackend::new(engine, s_max);
        Ok(Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions,
                    buckets: vec![1, 4],
                    max_queue,
                    prefill_chunk_tokens: 64,
                    ..Default::default()
                },
                kv_budget_bytes: 128 << 20,
            },
        ))
    };
    serve_with_config(
        "127.0.0.1:0",
        factory,
        ServerConfig {
            conn_threads: 8,
            idle_read_timeout: Duration::from_secs(10),
            ..ServerConfig::default()
        },
    )
    .unwrap()
}

/// The shared per-class prompt prefix — long enough (12 KV blocks) that a
/// cold prefill visibly dominates TTFT.  Classes diverge within the first
/// affinity block, so every class carries a distinct affinity key.
fn class_prefix(class: usize, len: usize) -> String {
    (0..len)
        .map(|i| char::from(b'a' + ((i * 7 + class * 13 + i * class) % 26) as u8))
        .collect()
}

fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[idx.saturating_sub(1).min(v.len() - 1)]
}

struct PhaseResult {
    ttft: Vec<f64>,
    hits: u64,
    lookups: u64,
    hit_rate: f64,
    errors: usize,
}

/// One routed phase: fresh fleet, one warm request per class through the
/// router, then `n_requests` zipf-drawn sequential requests (same class
/// sequence for every policy — the rng is phase-local and fixed-seed).
fn run_phase(
    policy: RoutePolicy,
    n_requests: usize,
    classes: usize,
    prefix_len: usize,
    max_new: usize,
) -> PhaseResult {
    let handles: Vec<ServerHandle> = (0..REPLICAS)
        .map(|_| start_replica(8, 64, prefix_len + max_new + 64))
        .collect();
    let addrs: Vec<SocketAddr> = handles.iter().map(|h| h.addr).collect();
    let router = serve_router(
        "127.0.0.1:0",
        &addrs,
        RouterConfig {
            policy,
            health: HealthConfig {
                interval: Duration::from_millis(200),
                ..HealthConfig::default()
            },
            ..RouterConfig::default()
        },
    )
    .unwrap();

    // Warm pass: each class once, wherever the policy sends it.  For
    // affinity this seeds every class at its rendezvous owner; for random
    // it warms one arbitrary (class, replica) pairing of the many the
    // measured phase will hit.
    for c in 0..classes {
        let body = obj(vec![
            ("prompt", s(format!("{}|warm", class_prefix(c, prefix_len)))),
            ("max_new", num(4.0)),
        ]);
        client_request_stream(&router.addr, &body).unwrap();
    }

    let mut rng = Rng::new(0xAFF1);
    let mut ttft = Vec::with_capacity(n_requests);
    let mut errors = 0usize;
    for i in 0..n_requests {
        let c = rng.zipf(classes, 1.2);
        let body = obj(vec![
            ("prompt", s(format!("{}|r{i:04}", class_prefix(c, prefix_len)))),
            ("max_new", num(max_new as f64)),
        ]);
        match client_request_stream(&router.addr, &body) {
            Ok(sc) if sc.summary.get("error").is_none() => ttft.push(sc.first_delta_ms),
            _ => errors += 1,
        }
    }

    // Gauges publish from the scheduler loop; give the final iteration a
    // beat before reading.
    std::thread::sleep(Duration::from_millis(100));
    let (hits, lookups) = handles.iter().fold((0u64, 0u64), |(h, l), hd| {
        let st = hd.stats();
        (
            h + st.prefix_hits.load(Ordering::Relaxed),
            l + st.prefix_lookups.load(Ordering::Relaxed),
        )
    });
    router.shutdown();
    for h in handles {
        h.shutdown();
    }
    PhaseResult {
        ttft,
        hits,
        lookups,
        hit_rate: hits as f64 / lookups.max(1) as f64,
        errors,
    }
}

/// Saturation burst: tiny replicas, a thick wave of concurrent clients,
/// and the question of how much backpressure escapes past the router's
/// bounded retry as a classified error.
fn run_burst(n_clients: usize) -> (usize, usize) {
    let handles: Vec<ServerHandle> = (0..2).map(|_| start_replica(2, 2, 256)).collect();
    let addrs: Vec<SocketAddr> = handles.iter().map(|h| h.addr).collect();
    let router = serve_router(
        "127.0.0.1:0",
        &addrs,
        RouterConfig {
            policy: RoutePolicy::LeastLoaded,
            retry: RetryConfig {
                max_attempts: 2,
                base: Duration::from_millis(5),
                cap: Duration::from_millis(20),
                seed: 1,
            },
            ..RouterConfig::default()
        },
    )
    .unwrap();
    let addr = router.addr;

    let outcomes: Arc<Mutex<Vec<bool>>> = Arc::new(Mutex::new(Vec::new()));
    let pool = ThreadPool::new(16);
    for i in 0..n_clients {
        let outcomes = Arc::clone(&outcomes);
        pool.execute(move || {
            let body = obj(vec![
                ("prompt", s(format!("burst client {i:03} says hello "))),
                ("max_new", num(16.0)),
            ]);
            let ok = client_request_stream(&addr, &body)
                .map(|sc| sc.summary.get("error").is_none())
                .unwrap_or(false);
            outcomes.lock().unwrap().push(ok);
        });
    }
    pool.wait_idle();
    router.shutdown();
    for h in handles {
        h.shutdown();
    }
    let outcomes = outcomes.lock().unwrap();
    let served = outcomes.iter().filter(|&&ok| ok).count();
    (served, outcomes.len() - served)
}

fn main() {
    let fast = std::env::var("RAP_BENCH_FAST").is_ok();
    let classes = 8usize;
    let prefix_len = 192usize;
    let max_new = 8usize;
    let n_requests = if fast { 48 } else { 96 };
    let burst_clients = if fast { 16 } else { 32 };

    println!(
        "== bench: router ({REPLICAS} replicas, {classes} prefix classes x {prefix_len} bytes, \
         {n_requests} zipf requests per policy) =="
    );

    let aff = run_phase(RoutePolicy::Affinity, n_requests, classes, prefix_len, max_new);
    let rnd = run_phase(
        RoutePolicy::Random { seed: 99 },
        n_requests,
        classes,
        prefix_len,
        max_new,
    );
    assert_eq!(aff.errors, 0, "healthy affinity fleet refused requests");
    assert_eq!(rnd.errors, 0, "healthy random fleet refused requests");

    let (aff_p50, aff_p99) = (percentile(&aff.ttft, 50.0), percentile(&aff.ttft, 99.0));
    let (rnd_p50, rnd_p99) = (percentile(&rnd.ttft, 50.0), percentile(&rnd.ttft, 99.0));
    println!(
        "affinity: hit-rate {:.3} ({}/{}), TTFT p50 {:.2} ms p99 {:.2} ms",
        aff.hit_rate, aff.hits, aff.lookups, aff_p50, aff_p99
    );
    println!(
        "random:   hit-rate {:.3} ({}/{}), TTFT p50 {:.2} ms p99 {:.2} ms",
        rnd.hit_rate, rnd.hits, rnd.lookups, rnd_p50, rnd_p99
    );
    assert!(
        aff.hit_rate > rnd.hit_rate,
        "affinity must strictly beat random routing on fleet prefix-cache hit-rate \
         ({:.3} vs {:.3})",
        aff.hit_rate,
        rnd.hit_rate
    );
    assert!(
        aff_p99 < rnd_p99,
        "affinity must strictly beat random routing on TTFT p99 ({aff_p99:.2} ms vs \
         {rnd_p99:.2} ms) — warm owners should never re-pay the cold prefill"
    );

    let (served, refused) = run_burst(burst_clients);
    let queue_full_rate = refused as f64 / (served + refused).max(1) as f64;
    println!(
        "burst: {served}/{} served through saturation, queue-full rate {queue_full_rate:.3}",
        served + refused
    );
    assert!(served > 0, "saturation burst must not starve everyone");

    let summary: Value = obj(vec![
        ("bench", s("router")),
        ("replicas", num(REPLICAS as f64)),
        ("classes", num(classes as f64)),
        ("prefix_bytes", num(prefix_len as f64)),
        ("requests_per_policy", num(n_requests as f64)),
        (
            "affinity",
            obj(vec![
                ("hit_rate", num(aff.hit_rate)),
                ("prefix_hits", num(aff.hits as f64)),
                ("prefix_lookups", num(aff.lookups as f64)),
                ("ttft_p50_ms", num(aff_p50)),
                ("ttft_p99_ms", num(aff_p99)),
            ]),
        ),
        (
            "random",
            obj(vec![
                ("hit_rate", num(rnd.hit_rate)),
                ("prefix_hits", num(rnd.hits as f64)),
                ("prefix_lookups", num(rnd.lookups as f64)),
                ("ttft_p50_ms", num(rnd_p50)),
                ("ttft_p99_ms", num(rnd_p99)),
            ]),
        ),
        ("ttft_p99_speedup", num(rnd_p99 / aff_p99.max(1e-9))),
        (
            "burst",
            obj(vec![
                ("clients", num((served + refused) as f64)),
                ("served", num(served as f64)),
                ("refused", num(refused as f64)),
                ("queue_full_rate", num(queue_full_rate)),
            ]),
        ),
    ]);
    let _ = std::fs::write("BENCH_router.json", summary.to_string_pretty());
    println!(
        "-> BENCH_router.json (affinity hit-rate {:.3} vs {:.3}, TTFT p99 {:.1}x better)",
        aff.hit_rate,
        rnd.hit_rate,
        rnd_p99 / aff_p99.max(1e-9)
    );
}
