//! Fig. 6 / Table 12 bench: measured per-token FLOPs by method and rho,
//! counted by the instrumented engine, printed as savings vs baseline
//! (values regenerated; timing is incidental).

use rap::experiments::bench_support::BenchReport;
use rap::manifest::Manifest;
use rap::model::load_engine;
use rap::util::json::{num, s};
use rap::util::stats::summarize;

fn main() {
    let mut report = BenchReport::new("flops");
    let Ok(manifest) = Manifest::load_default() else {
        println!("no artifacts; run `make artifacts` first");
        return;
    };
    let corpus = manifest.eval_corpus().unwrap();
    let model = "tinyllama";
    let ctx_len = 192usize;
    let mut base = 0u64;
    for rho in [0usize, 10, 20, 30, 40, 50] {
        for m in ["svd", "palu", "rap"] {
            let key = if rho == 0 {
                if m != "svd" {
                    continue;
                }
                "baseline_r00".to_string()
            } else {
                format!("{m}_r{rho}")
            };
            let Ok(engine) = load_engine(&manifest, model, &key) else { continue };
            let mut cache = engine.new_cache(ctx_len + 2);
            for (i, &t) in corpus[..ctx_len].iter().enumerate() {
                engine.step(t, i, &mut cache);
            }
            engine.flops.take();
            let t0 = std::time::Instant::now();
            engine.step(corpus[ctx_len], ctx_len, &mut cache);
            let ns = t0.elapsed().as_nanos() as f64;
            let step = engine.flops.take();
            if key == "baseline_r00" {
                base = step;
            }
            let saving = 1.0 - step as f64 / base as f64;
            println!(
                "{key:<14} step {:>10.3}M FLOPs  saving {:>6.1}%",
                step as f64 / 1e6,
                100.0 * saving
            );
            let st = summarize(&key, vec![ns]);
            report.record(
                &st,
                vec![
                    ("variant", s(key.clone())),
                    ("flops", num(step as f64)),
                    ("saving", num(saving)),
                ],
            );
        }
    }
    report.finish();
}
