//! Retention gate: per-request KV presses vs retain-all on long contexts.
//!
//! Headline: a 32k-token context served under `window:0.5` must hold its
//! peak resident KV footprint to <= 60% of the retain-all run's, with no
//! decode-throughput regression (the pressed session attends over fewer
//! rows, so decode should if anything speed up).  Satellite sweep: Window
//! at ratios {0.25, 0.5, 0.75} on an 8k context, each required to shrink
//! the peak footprint by at least 0.8 * (1 - ratio) relative to
//! retain-all.  Results land in `BENCH_retention.json` (uploaded by CI
//! next to the serving/oversub artifacts).
//!
//! Peak residency is sampled per tick — the external (scheduler-visible)
//! view of the cache after each chunk/decode round's press hook has run.

use std::time::Instant;

use rap::config::Method;
use rap::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, FinishReason, Request};
use rap::kvcache::retention::{Press, RetentionSpec};
use rap::kvcache::{CacheShape, BLOCK_TOKENS};
use rap::model::backend::{BackendConfig, RustBackend};
use rap::model::synth::synth_engine;
use rap::tensor::simd::KernelPath;

fn prompt(len: usize) -> Vec<u8> {
    (0..len).map(|i| ((i * 37 + 11) % 251) as u8).collect()
}

struct RunStats {
    peak_resident_bytes: usize,
    decode_tok_s: f64,
    wall_ms: f64,
    evicted_tokens: u64,
    presses: u64,
}

/// Serve one `ctx`-token request to completion, sampling resident KV
/// bytes per tick.
fn run(
    engine: &mut rap::model::Engine,
    shape: &CacheShape,
    ctx: usize,
    max_new: usize,
    chunk: usize,
    retention: Option<RetentionSpec>,
) -> RunStats {
    let s_max = ctx + max_new + 16;
    let backend = RustBackend::with_config(
        engine,
        s_max,
        BackendConfig { kernel_path: KernelPath::Wide, quantize_kv: false },
    );
    let blocks = s_max.div_ceil(BLOCK_TOKENS) + 8;
    let mut coord = Coordinator::new(
        backend,
        shape.clone(),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: 1,
                buckets: vec![1],
                max_queue: 2,
                prefill_chunk_tokens: chunk,
                // The spec under test rides on the request; the bench must
                // not inherit one from the CI matrix environment.
                default_retention: None,
                ..Default::default()
            },
            kv_budget_bytes: shape.bytes_per_token() * BLOCK_TOKENS * blocks,
        },
    );
    let mut req = Request::new(1, prompt(ctx), max_new);
    if let Some(spec) = retention {
        req = req.with_retention(spec);
    }
    assert!(coord.submit(req));

    let t0 = Instant::now();
    let mut peak = 0usize;
    let mut done = false;
    while !done {
        let events = coord.tick().unwrap();
        peak = peak.max(coord.kv_resident_bytes());
        done = events.iter().any(|e| e.is_finished());
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let evicted_tokens = coord.kv_evicted_tokens();
    let presses = coord.metrics.retention_presses;
    let responses = coord.run_to_completion().unwrap();
    assert_eq!(responses.len(), 1);
    let r = &responses[0];
    assert_eq!(r.metrics.finish_reason, FinishReason::Length);
    assert_eq!(r.generated.len(), max_new);
    let decode_s = ((wall_ms - r.metrics.ttft_ms) / 1e3).max(1e-9);
    RunStats {
        peak_resident_bytes: peak,
        decode_tok_s: (max_new.saturating_sub(1)) as f64 / decode_s,
        wall_ms,
        evicted_tokens,
        presses,
    }
}

fn main() {
    use rap::util::json::{num, obj, s, Value};

    let fast = std::env::var("RAP_BENCH_FAST").is_ok();
    // The headline geometry is fixed: the 32k <= 60% claim is the gate
    // this bench exists for.  Fast mode trims the decode tail and the
    // sweep, not the headline context.
    let headline_ctx = 32 * 1024;
    let max_new = if fast { 32 } else { 48 };
    let sweep_ctx = if fast { 4096 } else { 8192 };

    let mut engine = synth_engine(Method::Rap, 11);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);

    println!("== bench: retention (headline {headline_ctx} tokens, sweep {sweep_ctx} tokens) ==");

    let retain_all = run(&mut engine, &shape, headline_ctx, max_new, 1024, None);
    let spec = RetentionSpec { press: Press::Window, ratio: 0.5 };
    let pressed = run(&mut engine, &shape, headline_ctx, max_new, 1024, Some(spec));
    let frac = pressed.peak_resident_bytes as f64 / retain_all.peak_resident_bytes as f64;
    println!(
        "32k retain-all: peak {} KiB, decode {:.0} tok/s, wall {:.0} ms",
        retain_all.peak_resident_bytes / 1024,
        retain_all.decode_tok_s,
        retain_all.wall_ms
    );
    println!(
        "32k window:0.5: peak {} KiB ({:.1}% of retain-all), decode {:.0} tok/s, \
         {} evicted tokens over {} presses",
        pressed.peak_resident_bytes / 1024,
        100.0 * frac,
        pressed.decode_tok_s,
        pressed.evicted_tokens,
        pressed.presses
    );
    assert!(
        frac <= 0.60,
        "window:0.5 at 32k must hold peak resident KV to <= 60% of retain-all (got {:.1}%)",
        100.0 * frac
    );
    assert!(
        pressed.decode_tok_s >= 0.9 * retain_all.decode_tok_s,
        "pressed decode must not regress: {:.0} tok/s vs retain-all {:.0} tok/s",
        pressed.decode_tok_s,
        retain_all.decode_tok_s
    );
    assert!(pressed.presses >= 1, "the press never fired at 32k");

    // Ratio sweep on the shorter context: each ratio must shrink the peak
    // footprint by at least 0.8 * (1 - ratio).
    let sweep_ra = run(&mut engine, &shape, sweep_ctx, max_new, 512, None);
    let mut sweep_rows = Vec::new();
    for ratio in [0.25f32, 0.5, 0.75] {
        let spec = RetentionSpec { press: Press::Window, ratio };
        let r = run(&mut engine, &shape, sweep_ctx, max_new, 512, Some(spec));
        let shrink = 1.0 - r.peak_resident_bytes as f64 / sweep_ra.peak_resident_bytes as f64;
        let floor = 0.8 * (1.0 - ratio as f64);
        println!(
            "{sweep_ctx} window:{ratio:.2}: peak {} KiB, shrink {:.1}% (floor {:.1}%), \
             decode {:.0} tok/s",
            r.peak_resident_bytes / 1024,
            100.0 * shrink,
            100.0 * floor,
            r.decode_tok_s
        );
        assert!(
            shrink >= floor,
            "window:{ratio} at {sweep_ctx} shrank peak KV by {:.1}% < floor {:.1}%",
            100.0 * shrink,
            100.0 * floor
        );
        sweep_rows.push(obj(vec![
            ("ratio", num(ratio as f64)),
            ("peak_resident_bytes", num(r.peak_resident_bytes as f64)),
            ("shrink", num(shrink)),
            ("shrink_floor", num(floor)),
            ("decode_tok_s", num(r.decode_tok_s)),
            ("evicted_tokens", num(r.evicted_tokens as f64)),
            ("presses", num(r.presses as f64)),
        ]));
    }

    let stats_obj = |r: &RunStats| {
        obj(vec![
            ("peak_resident_bytes", num(r.peak_resident_bytes as f64)),
            ("decode_tok_s", num(r.decode_tok_s)),
            ("wall_ms", num(r.wall_ms)),
            ("evicted_tokens", num(r.evicted_tokens as f64)),
            ("presses", num(r.presses as f64)),
        ])
    };
    let summary: Value = obj(vec![
        ("bench", s("retention")),
        ("headline_ctx_tokens", num(headline_ctx as f64)),
        ("sweep_ctx_tokens", num(sweep_ctx as f64)),
        ("max_new", num(max_new as f64)),
        ("headline_retain_all", stats_obj(&retain_all)),
        ("headline_window_half", stats_obj(&pressed)),
        (
            "headline_peak_fraction_of_retain_all",
            num(pressed.peak_resident_bytes as f64 / retain_all.peak_resident_bytes as f64),
        ),
        ("sweep_retain_all", stats_obj(&sweep_ra)),
        ("sweep_window", Value::Arr(sweep_rows)),
    ]);
    let _ = std::fs::write("BENCH_retention.json", summary.to_string_pretty());
    println!("-> BENCH_retention.json");
}
