//! Serving API v2 gate: streaming TTFT vs one-shot total latency, 8
//! concurrent sessions through the JSON-lines TCP server over the
//! pure-Rust paged engine (synthetic weights — no artifacts needed).
//!
//! A streaming client's first `{"delta"}` line lands at prefill
//! completion, while a one-shot client waits for the whole generation —
//! so the workload must show streamed first-token latency well below the
//! one-shot total.  Results land in `BENCH_serving.json` (uploaded by CI
//! next to the decode/prefill/prefix artifacts) so the serving-latency
//! trajectory is tracked across PRs.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;
use rap::config::Method;
use rap::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use rap::kvcache::CacheShape;
use rap::model::backend::RustBackend;
use rap::model::synth::synth_engine;
use rap::server::{client_request, client_request_stream, serve, ServerHandle};
use rap::util::json::{num, obj, s, Value};
use rap::util::threadpool::ThreadPool;

fn start_server(sessions: usize, s_max: usize) -> ServerHandle {
    let factory = move || -> Result<Coordinator<RustBackend<'static>>> {
        // Engine leaks deliberately: server lifetime == process lifetime.
        let engine: &'static rap::model::Engine =
            Box::leak(Box::new(synth_engine(Method::Rap, 11)));
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let backend = RustBackend::new(engine, s_max);
        Ok(Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: sessions,
                    buckets: vec![1, 4, 8],
                    max_queue: 64,
                    prefill_chunk_tokens: 64,
                    ..Default::default()
                },
                kv_budget_bytes: 128 << 20,
            },
        ))
    };
    serve("127.0.0.1:0", factory, sessions).unwrap()
}

fn prompt_text(len: usize, salt: usize) -> String {
    // The i*salt cross term keeps prompts with different salts distinct
    // within the first KV block, so the prefix cache never shares across
    // clients and the phases measure plain serving latency.
    (0..len)
        .map(|i| char::from(b'a' + ((i * 7 + salt * 13 + i * salt) % 26) as u8))
        .collect()
}

struct Lat {
    mean: f64,
    max: f64,
}

fn stats(xs: &[f64]) -> Lat {
    let n = xs.len().max(1) as f64;
    Lat {
        mean: xs.iter().sum::<f64>() / n,
        max: xs.iter().cloned().fold(0.0, f64::max),
    }
}

fn main() {
    let fast = std::env::var("RAP_BENCH_FAST").is_ok();
    let sessions = 8usize;
    let prompt_len = if fast { 96 } else { 192 };
    let max_new = if fast { 12 } else { 32 };

    println!(
        "== bench: serving_v2 ({sessions} concurrent sessions, prompt {prompt_len}, max_new {max_new}) =="
    );
    let handle = start_server(sessions, prompt_len + max_new + 64);
    let addr = handle.addr;

    // Warm the engine (workspace sizing, thread pool spin-up) off-clock.
    // Salts stay below 26 so no two prompts are congruent mod the
    // 26-letter alphabet (identical prompts would wake the prefix cache).
    client_request(&addr, &prompt_text(prompt_len, 25), 4).unwrap();

    // Phase 1: one-shot clients — latency is the full-generation wall.
    // Worker threads only collect; assertions run on the main thread (a
    // panic inside a pool job would wedge `wait_idle`).
    let pool = ThreadPool::new(sessions);
    let one_shot: Arc<Mutex<Vec<(usize, f64)>>> = Arc::new(Mutex::new(Vec::new()));
    for i in 0..sessions {
        let one_shot = Arc::clone(&one_shot);
        let prompt = prompt_text(prompt_len, i);
        pool.execute(move || {
            let t0 = Instant::now();
            let tokens = client_request(&addr, &prompt, max_new)
                .ok()
                .and_then(|resp| resp.get("tokens").and_then(|t| t.as_usize()))
                .unwrap_or(0);
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            one_shot.lock().unwrap().push((tokens, wall_ms));
        });
    }
    pool.wait_idle();

    // Phase 2: the same workload streamed — the interesting number is the
    // wall time to the FIRST delta line, observed client-side.
    type StreamSample = (usize, usize, f64, f64); // (tokens, deltas, first_ms, total_ms)
    let streamed: Arc<Mutex<Vec<StreamSample>>> = Arc::new(Mutex::new(Vec::new()));
    for i in 0..sessions {
        let streamed = Arc::clone(&streamed);
        let prompt = prompt_text(prompt_len, 10 + i);
        pool.execute(move || {
            let body = obj(vec![("prompt", s(prompt)), ("max_new", num(max_new as f64))]);
            let sample = client_request_stream(&addr, &body)
                .map(|sc| {
                    let tokens = sc
                        .summary
                        .get("tokens")
                        .and_then(|t| t.as_usize())
                        .unwrap_or(0);
                    (tokens, sc.deltas.len(), sc.first_delta_ms, sc.total_ms)
                })
                .unwrap_or((0, 0, 0.0, 0.0));
            streamed.lock().unwrap().push(sample);
        });
    }
    pool.wait_idle();
    handle.shutdown();

    let one_shot = one_shot.lock().unwrap();
    let streamed = streamed.lock().unwrap();
    assert!(
        one_shot.iter().all(|&(tokens, _)| tokens == max_new),
        "every one-shot client got its full generation: {one_shot:?}"
    );
    assert!(
        streamed.iter().all(|&(tokens, deltas, _, _)| tokens == max_new && deltas > 0),
        "every streaming client got deltas plus a full summary: {streamed:?}"
    );
    let one = stats(&one_shot.iter().map(|&(_, ms)| ms).collect::<Vec<f64>>());
    let ttft = stats(&streamed.iter().map(|&(_, _, f, _)| f).collect::<Vec<f64>>());
    let stot = stats(&streamed.iter().map(|&(_, _, _, t)| t).collect::<Vec<f64>>());
    let speedup = one.mean / ttft.mean.max(1e-9);
    println!(
        "one-shot:  total mean {:.1} ms (max {:.1})",
        one.mean, one.max
    );
    println!(
        "streaming: first delta mean {:.1} ms (max {:.1}), total mean {:.1} ms",
        ttft.mean, ttft.max, stot.mean
    );
    println!("    -> first token {speedup:.1}x sooner than the one-shot response");
    assert!(
        ttft.mean < one.mean,
        "streamed first-token latency ({:.1} ms) must beat the one-shot total ({:.1} ms)",
        ttft.mean,
        one.mean
    );

    let summary: Value = obj(vec![
        ("bench", s("serving_v2")),
        ("sessions", num(sessions as f64)),
        ("prompt_tokens", num(prompt_len as f64)),
        ("max_new", num(max_new as f64)),
        ("one_shot", obj(vec![("mean_total_ms", num(one.mean)), ("max_total_ms", num(one.max))])),
        (
            "streaming",
            obj(vec![
                ("mean_first_delta_ms", num(ttft.mean)),
                ("max_first_delta_ms", num(ttft.max)),
                ("mean_total_ms", num(stot.mean)),
            ]),
        ),
        ("ttft_speedup", num(speedup)),
    ]);
    let _ = std::fs::write("BENCH_serving.json", summary.to_string_pretty());
    println!("-> BENCH_serving.json (streamed first token {speedup:.1}x sooner)");
}
