//! Prefix-cache serving gate: 8 concurrent requests sharing a 512-token
//! prompt prefix vs the same workload with pairwise-distinct prefixes,
//! through the coordinator over the pure-Rust backend (synthetic weights —
//! no artifacts needed).
//!
//! Shared-prefix admission attaches the resident blocks read-only and
//! starts chunked prefill past the match, so the workload must show BOTH
//! fewer allocated blocks (peak ~ prefix + N·suffix instead of
//! N·(prefix + suffix)) and a lower time-to-first-token (the prefix is
//! prefillled once, not N times).  Results land in `BENCH_prefix.json`
//! (uploaded by CI next to the decode/prefill artifacts) so the
//! prefix-cache trajectory is tracked across PRs.

use rap::config::Method;
use rap::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Request};
use rap::kvcache::{CacheShape, BLOCK_TOKENS};
use rap::model::backend::RustBackend;
use rap::model::synth::synth_engine;
use rap::util::json::{num, obj, s, Value};

fn prompt(len: usize, salt: usize) -> Vec<u8> {
    (0..len).map(|i| ((i * 37 + salt * 101) % 251) as u8).collect()
}

struct WorkloadStats {
    mean_ttft_ms: f64,
    max_ttft_ms: f64,
    peak_blocks: usize,
    prefix_hits: u64,
    saved_blocks: u64,
    throughput_tps: f64,
}

impl WorkloadStats {
    fn to_json(&self) -> Value {
        obj(vec![
            ("mean_ttft_ms", num(self.mean_ttft_ms)),
            ("max_ttft_ms", num(self.max_ttft_ms)),
            ("peak_blocks", num(self.peak_blocks as f64)),
            ("prefix_hits", num(self.prefix_hits as f64)),
            ("saved_blocks", num(self.saved_blocks as f64)),
            ("throughput_tps", num(self.throughput_tps)),
        ])
    }
}

fn run(shared: bool, sessions: usize, prefix_len: usize, suffix: usize, max_new: usize) -> WorkloadStats {
    let engine = synth_engine(Method::Rap, 11);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let s_max = prefix_len + suffix + max_new + BLOCK_TOKENS;
    let backend = RustBackend::new(&engine, s_max);
    let mut coord = Coordinator::new(
        backend,
        shape,
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: sessions,
                buckets: vec![1, 4, 8],
                max_queue: 64,
                prefill_chunk_tokens: 128,
                ..Default::default()
            },
            kv_budget_bytes: 256 << 20,
        },
    );
    for i in 0..sessions {
        // Shared workload: one common prefix.  Unshared: per-request salt
        // makes every prefix distinct, so the trie never matches.
        let mut p = prompt(prefix_len, if shared { 0 } else { 1000 + i });
        p.extend(prompt(suffix, 500 + i));
        assert!(coord.submit(Request::new(i as u64, p, max_new)));
    }
    let responses = coord.run_to_completion().unwrap();
    assert_eq!(responses.len(), sessions);
    let mut mean_ttft = 0.0;
    let mut max_ttft = 0.0f64;
    for r in &responses {
        mean_ttft += r.metrics.ttft_ms / sessions as f64;
        max_ttft = max_ttft.max(r.metrics.ttft_ms);
    }
    WorkloadStats {
        mean_ttft_ms: mean_ttft,
        max_ttft_ms: max_ttft,
        peak_blocks: coord.metrics.peak_kv_blocks,
        prefix_hits: coord.metrics.prefix_hits,
        saved_blocks: coord.metrics.prefix_saved_blocks,
        throughput_tps: coord.metrics.throughput_tps(),
    }
}

fn main() {
    let fast = std::env::var("RAP_BENCH_FAST").is_ok();
    let sessions = 8usize;
    let prefix_len = if fast { 256 } else { 512 };
    let (suffix, max_new) = (12usize, if fast { 8 } else { 16 });

    println!("== bench: prefix_cache ({sessions} sessions, {prefix_len}-token prefix) ==");
    let shared = run(true, sessions, prefix_len, suffix, max_new);
    let unshared = run(false, sessions, prefix_len, suffix, max_new);
    let ttft_speedup = unshared.mean_ttft_ms / shared.mean_ttft_ms.max(1e-9);
    let block_savings = unshared.peak_blocks as f64 / shared.peak_blocks.max(1) as f64;
    println!(
        "shared:   ttft mean {:.2} ms (max {:.2})  peak blocks {}  hits {}  saved {}",
        shared.mean_ttft_ms, shared.max_ttft_ms, shared.peak_blocks, shared.prefix_hits, shared.saved_blocks
    );
    println!(
        "unshared: ttft mean {:.2} ms (max {:.2})  peak blocks {}",
        unshared.mean_ttft_ms, unshared.max_ttft_ms, unshared.peak_blocks
    );
    println!("    -> ttft {ttft_speedup:.2}x faster, {block_savings:.2}x fewer peak blocks with sharing");

    let summary = obj(vec![
        ("bench", s("prefix_cache")),
        ("sessions", num(sessions as f64)),
        ("prefix_tokens", num(prefix_len as f64)),
        ("suffix_tokens", num(suffix as f64)),
        ("max_new", num(max_new as f64)),
        ("shared", shared.to_json()),
        ("unshared", unshared.to_json()),
        ("ttft_speedup", num(ttft_speedup)),
        ("peak_block_savings", num(block_savings)),
    ]);
    let _ = std::fs::write("BENCH_prefix.json", summary.to_string_pretty());
    println!("-> BENCH_prefix.json (ttft {ttft_speedup:.2}x, blocks {block_savings:.2}x)");
}
