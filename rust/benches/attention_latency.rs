//! Fig. 7 / Table 16 (prefill side): attention-path latency per variant.
//!
//! Times (a) PJRT prefill executables at the exported buckets, (b) the
//! rust engine's prefill at the bucket size, per method at rho=30%,
//! reporting ratios vs baseline — the paper's "attention latency relative
//! to baseline" series — and (c) the perf gate for the block-parallel
//! prefill path: token-by-token (`Engine::prefill_token_loop`) vs blocked
//! chunked prefill (`Engine::prefill_chunked`) at 512/2k-token prompts on
//! synthetic weights (no artifacts needed), with the speedups written to
//! `BENCH_prefill.json` so the prefill-latency trajectory is tracked
//! across PRs next to `BENCH_decode.json`.

use rap::config::Method;
use rap::experiments::bench_support::{budgets, BenchReport};
use rap::manifest::Manifest;
use rap::model::synth::synth_engine;
use rap::model::{load_engine, PrefillWorkspace};
use rap::runtime::{PjrtContext, PjrtEngine};
use rap::util::json::{arr, num, obj, s};
use rap::util::stats::{bench, bench_with_samples};

fn main() {
    let (warm, budget) = budgets();
    let mut report = BenchReport::new("attention_latency");

    if let Ok(manifest) = Manifest::load_default() {
        let corpus = manifest.eval_corpus().unwrap();
        let model = "tinyllama";
        let keys = ["baseline_r00", "svd_r30", "palu_r30", "rap_r30"];

        // (a) PJRT prefill bucket 128.
        if let Ok(pctx) = PjrtContext::cpu() {
            let mut base = 0.0f64;
            for key in keys {
                let Ok(engine) = PjrtEngine::load(&pctx, &manifest, model, key) else { continue };
                let tokens: Vec<i32> = corpus[..128].iter().map(|&b| b as i32).collect();
                let st = bench(&format!("pjrt_prefill128/{key}"), warm, budget, || {
                    let _ = engine.prefill(&pctx, "prefill128", &tokens, 1).unwrap();
                });
                if key == "baseline_r00" {
                    base = st.mean_ns;
                }
                println!("    -> {:.0}% of baseline", 100.0 * st.mean_ns / base);
                report.record(
                    &st,
                    vec![("variant", s(key)), ("rel", num(st.mean_ns / base))],
                );
            }
        }

        // (b) Rust engine prefill of 128 tokens.  The workspace is hoisted
        // out of the timed loop (its reconstruction scratch is
        // method-dependent, so allocating it per sample would skew the
        // per-variant ratios).
        let mut base = 0.0f64;
        for key in keys {
            let Ok(engine) = load_engine(&manifest, model, key) else { continue };
            let prompt = &corpus[..128];
            let mut ws = PrefillWorkspace::new(&engine, 160);
            let st = bench(&format!("engine_prefill128/{key}"), warm, budget, || {
                let mut cache = engine.new_cache(160);
                engine.prefill_chunked(prompt, 128, &mut cache, &mut ws);
            });
            if key == "baseline_r00" {
                base = st.mean_ns;
            }
            println!("    -> {:.0}% of baseline", 100.0 * st.mean_ns / base);
            report.record(
                &st,
                vec![("variant", s(key)), ("rel", num(st.mean_ns / base))],
            );
        }
    } else {
        println!("no artifacts; skipping PJRT/manifest sweeps");
    }

    // (c) Token-loop vs blocked chunked prefill — synthetic weights,
    // always runs.  The token loop is the seed's prefill (T sequential
    // step_inner calls); the blocked path is bit-identical to it
    // (tests/prefill.rs), so this ratio is pure implementation speedup.
    let max_samples = if std::env::var("RAP_BENCH_FAST").is_ok() { 3 } else { 10 };
    let chunk = 128usize;
    let mut variants = Vec::new();
    let mut rap_speedup_2k = 0.0f64;
    for method in [Method::Baseline, Method::Svd, Method::Palu, Method::Rap] {
        let engine = synth_engine(method, 2);
        for plen in [512usize, 2048] {
            let s_max = plen + 8;
            let prompt: Vec<u8> = (0..plen).map(|i| (i % 251) as u8).collect();
            let tok_st = bench_with_samples(
                &format!("prefill_token_loop/{plen}/{}", method.name()),
                warm,
                budget,
                max_samples,
                &mut || {
                    let mut cache = engine.new_cache(s_max);
                    let _ = engine.prefill_token_loop(&prompt, &mut cache);
                },
            );
            println!("{}", tok_st.report());
            let mut ws = PrefillWorkspace::new(&engine, s_max);
            let blk_st = bench_with_samples(
                &format!("prefill_blocked/{plen}/{}", method.name()),
                warm,
                budget,
                max_samples,
                &mut || {
                    let mut cache = engine.new_cache(s_max);
                    engine.prefill_chunked(&prompt, chunk, &mut cache, &mut ws);
                },
            );
            println!("{}", blk_st.report());
            let speedup = tok_st.mean_ns / blk_st.mean_ns;
            println!(
                "    -> {}: blocked prefill {speedup:.2}x vs token loop at {plen} tokens",
                method.name()
            );
            if method == Method::Rap && plen == 2048 {
                rap_speedup_2k = speedup;
            }
            report.record(
                &tok_st,
                vec![
                    ("variant", s(method.name())),
                    ("prompt", num(plen as f64)),
                    ("kind", s("token_loop")),
                ],
            );
            report.record(
                &blk_st,
                vec![
                    ("variant", s(method.name())),
                    ("prompt", num(plen as f64)),
                    ("kind", s("blocked")),
                    ("speedup", num(speedup)),
                ],
            );
            variants.push(obj(vec![
                ("method", s(method.name())),
                ("prompt", num(plen as f64)),
                ("token_loop_us", num(tok_st.mean_ns / 1e3)),
                ("blocked_us", num(blk_st.mean_ns / 1e3)),
                ("speedup", num(speedup)),
            ]));
        }
    }
    let summary = obj(vec![
        ("bench", s("prefill_latency")),
        ("chunk", num(chunk as f64)),
        ("target_rap_speedup_2k", num(3.0)),
        ("rap_speedup_2k", num(rap_speedup_2k)),
        ("variants", arr(variants)),
    ]);
    let _ = std::fs::write("BENCH_prefill.json", summary.to_string_pretty());
    println!("-> BENCH_prefill.json (rap {rap_speedup_2k:.2}x vs token loop at 2k prompt)");

    report.finish();
}
