//! Fig. 7 / Table 16 (prefill side): attention-path latency per variant.
//!
//! Times (a) PJRT prefill executables at the exported buckets and (b) the
//! rust engine's prefill loop, per method at rho=30%, reporting ratios vs
//! baseline — the paper's "attention latency relative to baseline" series.

use rap::experiments::bench_support::{budgets, BenchReport};
use rap::manifest::Manifest;
use rap::model::load_engine;
use rap::runtime::{PjrtContext, PjrtEngine};
use rap::util::json::{num, s};
use rap::util::stats::bench;

fn main() {
    let (warm, budget) = budgets();
    let mut report = BenchReport::new("attention_latency");
    let Ok(manifest) = Manifest::load_default() else {
        println!("no artifacts; run `make artifacts` first");
        return;
    };
    let corpus = manifest.eval_corpus().unwrap();
    let model = "tinyllama";
    let keys = ["baseline_r00", "svd_r30", "palu_r30", "rap_r30"];

    // (a) PJRT prefill bucket 128.
    if let Ok(pctx) = PjrtContext::cpu() {
        let mut base = 0.0f64;
        for key in keys {
            let Ok(engine) = PjrtEngine::load(&pctx, &manifest, model, key) else { continue };
            let tokens: Vec<i32> = corpus[..128].iter().map(|&b| b as i32).collect();
            let st = bench(&format!("pjrt_prefill128/{key}"), warm, budget, || {
                let _ = engine.prefill(&pctx, "prefill128", &tokens, 1).unwrap();
            });
            if key == "baseline_r00" {
                base = st.mean_ns;
            }
            println!("    -> {:.0}% of baseline", 100.0 * st.mean_ns / base);
            report.record(
                &st,
                vec![("variant", s(key)), ("rel", num(st.mean_ns / base))],
            );
        }
    }

    // (b) Rust engine prefill of 128 tokens.
    let mut base = 0.0f64;
    for key in keys {
        let Ok(engine) = load_engine(&manifest, model, key) else { continue };
        let prompt = &corpus[..128];
        let st = bench(&format!("engine_prefill128/{key}"), warm, budget, || {
            let mut cache = engine.new_cache(160);
            let _ = engine.prefill(prompt, &mut cache);
        });
        if key == "baseline_r00" {
            base = st.mean_ns;
        }
        println!("    -> {:.0}% of baseline", 100.0 * st.mean_ns / base);
        report.record(
            &st,
            vec![("variant", s(key)), ("rel", num(st.mean_ns / base))],
        );
    }
    report.finish();
}
