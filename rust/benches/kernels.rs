//! ISSUE 7 perf gate for the selectable kernel paths: explicit 8-lane wide
//! kernels vs the bit-exact scalar path across latent widths, plus the
//! fused-int4 decode byte discount over packed rows.  Writes the sweep to
//! `BENCH_kernels.json` (uploaded by CI):
//!
//! * Wide must beat Scalar by ≥ 1.3x on `dot_rows_scaled` / `axpy_rows`
//!   at width ≥ 64 — asserted only when AVX2+FMA is actually available
//!   (the portable 8-accumulator fallback is recorded, not gated);
//! * a packed q4 row must cost ≤ 0.5x the bytes of its f32 row at every
//!   swept width — a layout property, asserted unconditionally.

use rap::experiments::bench_support::{budgets, BenchReport};
use rap::kvcache::quant;
use rap::tensor::ops;
use rap::tensor::simd::{avx2_available, axpy_rows_path, dot_rows_scaled_path, KernelPath};
use rap::util::json::{arr, num, obj, s};
use rap::util::rng::Rng;
use rap::util::stats::bench;

const TARGET_WIDE_SPEEDUP: f64 = 1.3;
const GATED_WIDTH: usize = 64;

fn main() {
    let (warm, budget) = budgets();
    let mut report = BenchReport::new("kernels");
    let rows_n: usize = if std::env::var("RAP_BENCH_FAST").is_ok() {
        1024
    } else {
        4096
    };
    let avx2 = avx2_available();
    println!("avx2+fma available: {avx2}; {rows_n} rows per width");

    let mut rng = Rng::new(42);
    let mut sweep = Vec::new();
    for w in [16usize, 32, 64, 128, 256] {
        let mut q = vec![0.0f32; w];
        let mut rows = vec![0.0f32; rows_n * w];
        let mut weights = vec![0.0f32; rows_n];
        rng.fill_normal(&mut q, 1.0);
        rng.fill_normal(&mut rows, 1.0);
        rng.fill_normal(&mut weights, 1.0);
        let scale = 1.0 / (w as f32).sqrt();
        let mut scores = vec![0.0f32; rows_n];
        let mut ctx = vec![0.0f32; w];

        let dot_s = bench(&format!("dot_rows_scaled/scalar/w{w}"), warm, budget, || {
            ops::dot_rows_scaled(&q, &rows, w, scale, &mut scores);
        });
        let dot_w = bench(&format!("dot_rows_scaled/wide/w{w}"), warm, budget, || {
            dot_rows_scaled_path(KernelPath::Wide, &q, &rows, w, scale, &mut scores);
        });
        let axpy_s = bench(&format!("axpy_rows/scalar/w{w}"), warm, budget, || {
            ctx.fill(0.0);
            ops::axpy_rows(&weights, &rows, w, &mut ctx);
        });
        let axpy_w = bench(&format!("axpy_rows/wide/w{w}"), warm, budget, || {
            ctx.fill(0.0);
            axpy_rows_path(KernelPath::Wide, &weights, &rows, w, &mut ctx);
        });

        // Fused-int4: quantize the same rows into packed storage and sweep
        // the in-register dequantizing kernels over the packed bytes.
        let rb = quant::row_bytes(w);
        let mut packed = vec![0u8; rows_n * rb];
        for (r, dst) in packed.chunks_exact_mut(rb).enumerate() {
            quant::quantize_row_into(&rows[r * w..(r + 1) * w], dst);
        }
        let dot_q4 = bench(&format!("dot_rows_scaled_q4/w{w}"), warm, budget, || {
            quant::dot_rows_scaled_q4(&q, &packed, w, scale, &mut scores);
        });
        let axpy_q4 = bench(&format!("axpy_rows_q4/w{w}"), warm, budget, || {
            ctx.fill(0.0);
            quant::axpy_rows_q4(&weights, &packed, w, &mut ctx);
        });

        let dot_speedup = dot_s.mean_ns / dot_w.mean_ns;
        let axpy_speedup = axpy_s.mean_ns / axpy_w.mean_ns;
        let byte_ratio = rb as f64 / (4 * w) as f64;
        println!(
            "    -> w{w}: dot {dot_speedup:.2}x axpy {axpy_speedup:.2}x q4 bytes {:.2}x",
            byte_ratio
        );

        // Decode-bytes gate: a packed row reads at most half the bytes of
        // its f32 counterpart.  Pure layout — independent of the machine.
        assert!(
            2 * rb <= 4 * w,
            "w{w}: packed row is {rb} bytes, f32 row {} bytes",
            4 * w
        );
        if avx2 && w >= GATED_WIDTH {
            assert!(
                dot_speedup >= TARGET_WIDE_SPEEDUP,
                "w{w}: wide dot_rows_scaled only {dot_speedup:.2}x over scalar"
            );
            assert!(
                axpy_speedup >= TARGET_WIDE_SPEEDUP,
                "w{w}: wide axpy_rows only {axpy_speedup:.2}x over scalar"
            );
        }

        for (st, kind) in [
            (&dot_s, "dot_scalar"),
            (&dot_w, "dot_wide"),
            (&axpy_s, "axpy_scalar"),
            (&axpy_w, "axpy_wide"),
            (&dot_q4, "dot_q4"),
            (&axpy_q4, "axpy_q4"),
        ] {
            report.record(st, vec![("width", num(w as f64)), ("kind", s(kind))]);
        }
        sweep.push(obj(vec![
            ("width", num(w as f64)),
            ("dot_scalar_ns", num(dot_s.mean_ns)),
            ("dot_wide_ns", num(dot_w.mean_ns)),
            ("dot_speedup", num(dot_speedup)),
            ("axpy_scalar_ns", num(axpy_s.mean_ns)),
            ("axpy_wide_ns", num(axpy_w.mean_ns)),
            ("axpy_speedup", num(axpy_speedup)),
            ("dot_q4_ns", num(dot_q4.mean_ns)),
            ("axpy_q4_ns", num(axpy_q4.mean_ns)),
            ("q4_row_bytes", num(rb as f64)),
            ("f32_row_bytes", num((4 * w) as f64)),
            ("q4_byte_ratio", num(byte_ratio)),
        ]));
    }

    let summary = obj(vec![
        ("bench", s("kernels")),
        ("avx2", s(if avx2 { "true" } else { "false" })),
        ("rows", num(rows_n as f64)),
        ("target_wide_speedup", num(TARGET_WIDE_SPEEDUP)),
        ("gated_width", num(GATED_WIDTH as f64)),
        ("max_q4_byte_ratio", num(0.5)),
        ("sweep", arr(sweep)),
    ]);
    let _ = std::fs::write("BENCH_kernels.json", summary.to_string_pretty());
    println!("-> BENCH_kernels.json");

    report.finish();
}
