//! Fig. 16 / Tables 8 & 11 bench: RoPE application strategies.
//!
//! Level 1 — rust hot path: contiguous full-dim vs materialising gather
//! ("PyTorch") vs fused per-head-table (`RopeTable::apply_fused`, the RAP
//! kernel) across rho and S.
//! Level 2 — compiled PJRT graphs from `artifacts/hlo/ropebench` (the
//! Pallas kernels), when artifacts are present.

use rap::config::Pairing;
use rap::experiments::bench_support::{budgets, BenchReport};
use rap::manifest::Manifest;
use rap::rope::{apply_full, apply_gather, RopeTable};
use rap::runtime::PjrtContext;
use rap::util::json::{num, s};
use rap::util::rng::Rng;
use rap::util::stats::{bench, black_box};

fn main() {
    let (warm, budget) = budgets();
    let mut report = BenchReport::new("rope_kernel");
    let mut rng = Rng::new(11);
    let head_dim = 128usize;
    let h = 8usize;

    for s_len in [1usize, 128, 512] {
        // contiguous baseline (full dim, shared table)
        let mut xs: Vec<Vec<f32>> = (0..h * s_len)
            .map(|_| (0..head_dim).map(|_| rng.normal_f32()).collect())
            .collect();
        let st = bench(&format!("contig/S{s_len}"), warm, budget, || {
            for (i, row) in xs.iter_mut().enumerate() {
                apply_full(row, i % s_len + 1, Pairing::Half, 10_000.0);
            }
        });
        report.record(&st, vec![("impl", s("contig")), ("seq", num(s_len as f64))]);

        for rho in [0.3f64, 0.5] {
            let m = (((1.0 - rho) * (head_dim / 2) as f64).round()) as usize;
            let idx: Vec<Vec<usize>> = (0..h)
                .map(|_| rng.choose_distinct(head_dim / 2, m))
                .collect();
            let table = RopeTable::new(&idx, head_dim, 10_000.0);
            let mut xs: Vec<Vec<f32>> = (0..h * s_len)
                .map(|_| (0..2 * m).map(|_| rng.normal_f32()).collect())
                .collect();

            let st = bench(
                &format!("gather/S{s_len}/rho{:.0}", rho * 100.0),
                warm,
                budget,
                || {
                    for (i, row) in xs.iter_mut().enumerate() {
                        apply_gather(row, i % s_len + 1, &idx[i % h], head_dim, 10_000.0);
                    }
                },
            );
            report.record(
                &st,
                vec![("impl", s("gather")), ("seq", num(s_len as f64)), ("rho", num(rho))],
            );

            let st = bench(
                &format!("fused/S{s_len}/rho{:.0}", rho * 100.0),
                warm,
                budget,
                || {
                    for (i, row) in xs.iter_mut().enumerate() {
                        table.apply_fused(i % h, row, black_box(i % s_len + 1));
                    }
                },
            );
            report.record(
                &st,
                vec![("impl", s("fused")), ("seq", num(s_len as f64)), ("rho", num(rho))],
            );
        }
    }

    // Level 2: compiled Pallas/XLA graphs (skipped gracefully if artifacts
    // are absent, e.g. bare `cargo bench` before `make artifacts`).
    if let Ok(manifest) = Manifest::load_default() {
        if let Ok(pctx) = PjrtContext::cpu() {
            let mut done = 0;
            for e in &manifest.rope_bench {
                if !(e.batch == 1 && e.seq == 512 && matches!(e.impl_name.as_str(), "contig" | "gather" | "fused"))
                {
                    continue;
                }
                if e.impl_name != "contig" && (e.ratio - 0.3).abs() > 1e-6 {
                    continue;
                }
                let Ok(exe) = pctx.compile_file(&manifest.root.join(&e.path)) else { continue };
                let hh = 8usize;
                let width = if e.impl_name == "contig" { 2 * e.m } else { 2 * e.m };
                let n = e.batch * hh * e.seq * width;
                let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
                let pos: Vec<i32> = (0..e.seq as i32).collect();
                let device = pctx.client.devices().into_iter().next().unwrap();
                let xb = pctx
                    .client
                    .buffer_from_host_buffer(&x, &[e.batch, hh, e.seq, width], Some(&device))
                    .unwrap();
                let pb = pctx
                    .client
                    .buffer_from_host_buffer(&pos, &[e.seq], Some(&device))
                    .unwrap();
                let st = bench(
                    &format!("pjrt/{}/b{}s{}r{:.0}", e.impl_name, e.batch, e.seq, e.ratio * 100.0),
                    warm,
                    budget,
                    || {
                        let _ = exe.execute_b(&[&xb, &pb]).unwrap();
                    },
                );
                report.record(
                    &st,
                    vec![
                        ("impl", s(format!("pjrt_{}", e.impl_name))),
                        ("seq", num(e.seq as f64)),
                        ("rho", num(e.ratio)),
                    ],
                );
                done += 1;
            }
            if done == 0 {
                println!("(no matching rope-bench artifacts; run `make artifacts`)");
            }
        }
    }
    report.finish();
}
