//! Tensor-kernel microbench: the L3 hot-path primitives (matmul/vecmat/
//! softmax/rms-norm) at the shapes the engine actually uses.  Baseline for
//! the §Perf optimization log in EXPERIMENTS.md.

use rap::experiments::bench_support::{budgets, BenchReport};
use rap::tensor::ops::{matmul, rms_norm, softmax_inplace, vecmat};
use rap::tensor::Tensor;
use rap::util::json::num;
use rap::util::rng::Rng;
use rap::util::stats::{bench, black_box};

fn main() {
    let (warm, budget) = budgets();
    let mut report = BenchReport::new("tensor_ops");
    let mut rng = Rng::new(3);

    // vecmat at the engine's projection shapes (d_model x q_dim etc.)
    for (k, n) in [(192usize, 192usize), (192, 512), (512, 192), (192, 96)] {
        let w = Tensor::randn(vec![k, n], 1.0, &mut rng);
        let x: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
        let st = bench(&format!("vecmat/{k}x{n}"), warm, budget, || {
            black_box(vecmat(&x, &w));
        });
        let flops = 2.0 * (k * n) as f64;
        report.record(
            &st,
            vec![
                ("k", num(k as f64)),
                ("n", num(n as f64)),
                ("gflops", num(flops / st.mean_ns)),
            ],
        );
    }

    // matmul at prefill shapes.
    for (m, k, n) in [(32usize, 192usize, 192usize), (128, 192, 512)] {
        let a = Tensor::randn(vec![m, k], 1.0, &mut rng);
        let b = Tensor::randn(vec![k, n], 1.0, &mut rng);
        let st = bench(&format!("matmul/{m}x{k}x{n}"), warm, budget, || {
            black_box(matmul(&a, &b));
        });
        let flops = 2.0 * (m * k * n) as f64;
        report.record(&st, vec![("gflops", num(flops / st.mean_ns))]);
    }

    // softmax + rms-norm at attention-row shapes.
    for n in [128usize, 384, 1024] {
        let mut x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let st = bench(&format!("softmax/{n}"), warm, budget, || {
            softmax_inplace(black_box(&mut x));
        });
        report.record(&st, vec![("n", num(n as f64))]);
    }
    let w: Vec<f32> = (0..192).map(|_| 1.0).collect();
    let x: Vec<f32> = (0..192).map(|_| rng.normal_f32()).collect();
    let mut out = vec![0.0f32; 192];
    let st = bench("rms_norm/192", warm, budget, || {
        rms_norm(black_box(&x), &w, 1e-5, &mut out);
    });
    report.record(&st, vec![]);
    report.finish();
}
