//! Table 17 bench: end-to-end serving throughput through the coordinator
//! (continuous batching + paged KV + PJRT) on the same seeded trace per
//! variant — plus the pure-Rust engine decoding straight out of the
//! storage-backed paged cache (synthetic weights, runs without artifacts).

use rap::config::Method;
use rap::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use rap::experiments::bench_support::BenchReport;
use rap::kvcache::CacheShape;
use rap::manifest::Manifest;
use rap::model::backend::RustBackend;
use rap::model::synth::synth_engine;
use rap::runtime::backend::PjrtBackend;
use rap::runtime::{PjrtContext, PjrtEngine};
use rap::util::json::{num, s};
use rap::util::stats::summarize;
use rap::workload::{generate, WorkloadConfig};

/// Continuous batching over the storage-backed paged KV with the Rust
/// engine: 8 concurrent sessions, batched decode through the scheduler.
fn rust_engine_paged_sweep(report: &mut BenchReport, fast: bool) {
    let corpus: Vec<u8> = (0..4096).map(|i| (i % 251) as u8).collect();
    let wl = WorkloadConfig {
        n_requests: if fast { 8 } else { 24 },
        arrival_rate: 200.0,
        prompt_lens: vec![16, 32, 48],
        min_new: 8,
        max_new: if fast { 12 } else { 24 },
        seed: 42,
    };
    let mut base_tps = 0.0f64;
    for method in [Method::Baseline, Method::Rap] {
        let engine = synth_engine(method, 3);
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let backend = RustBackend::new(&engine, 256);
        let mut coord = Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 8,
                    buckets: vec![1, 4, 8],
                    max_queue: 128,
                    ..Default::default()
                },
                kv_budget_bytes: 32 << 20,
            },
        );
        for tr in generate(&wl, &corpus) {
            coord.submit(tr.request);
        }
        coord.run_to_completion().unwrap();
        let m = &coord.metrics;
        if method == Method::Baseline {
            base_tps = m.throughput_tps();
        }
        println!(
            "rust_paged/{:<8} {:>7.1} tok/s ({:>4.0}% of baseline)  dec {:>5.2} ms/tok  occupancy {:.2}  peak_kv {} blocks",
            method.name(),
            m.throughput_tps(),
            100.0 * m.throughput_tps() / base_tps,
            m.decode_per_token.mean(),
            m.decode_batch_occupancy.mean(),
            m.peak_kv_blocks,
        );
        let st = summarize(&format!("rust_paged/{}", method.name()), vec![m.wall.as_nanos() as f64]);
        report.record(
            &st,
            vec![
                ("variant", s(method.name())),
                ("kind", s("rust_paged")),
                ("tps", num(m.throughput_tps())),
                ("rel_tps", num(m.throughput_tps() / base_tps)),
                ("occupancy", num(m.decode_batch_occupancy.mean())),
            ],
        );
    }
}

fn main() {
    let mut report = BenchReport::new("e2e_serving");
    let fast = std::env::var("RAP_BENCH_FAST").is_ok();
    rust_engine_paged_sweep(&mut report, fast);
    let Ok(manifest) = Manifest::load_default() else {
        println!("no artifacts; skipping the PJRT sweep");
        report.finish();
        return;
    };
    let Ok(pctx) = PjrtContext::cpu() else {
        report.finish();
        return;
    };
    let corpus = manifest.eval_corpus().unwrap();
    let model = "tinyllama";
    let wl = WorkloadConfig {
        n_requests: if fast { 6 } else { 16 },
        arrival_rate: 100.0,
        prompt_lens: vec![16, 32, 32],
        min_new: 8,
        max_new: if fast { 12 } else { 24 },
        seed: 42,
    };

    let mut base_tps = 0.0f64;
    for key in ["baseline_r00", "svd_r30", "palu_r30", "rap_r30"] {
        let entry = manifest.model(model).unwrap();
        if !entry.hlo.contains_key(key) {
            continue;
        }
        let engine = PjrtEngine::load(&pctx, &manifest, model, key).unwrap();
        let backend = PjrtBackend::new(&pctx, &engine).unwrap();
        let shape = CacheShape::of(&entry.config, &entry.variants[key].spec);
        let mut coord = Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 4,
                    buckets: engine.decode_batches(),
                    max_queue: 128,
                    ..Default::default()
                },
                kv_budget_bytes: 32 << 20,
            },
        );
        for tr in generate(&wl, &corpus) {
            coord.submit(tr.request);
        }
        coord.run_to_completion().unwrap();
        let m = &coord.metrics;
        if key == "baseline_r00" {
            base_tps = m.throughput_tps();
        }
        println!(
            "{key:<14} {:>7.1} tok/s ({:>4.0}% of baseline)  ttft {:>6.1} ms  dec {:>5.2} ms/tok  occupancy {:.2}",
            m.throughput_tps(),
            100.0 * m.throughput_tps() / base_tps,
            m.ttft.mean(),
            m.decode_per_token.mean(),
            m.decode_batch_occupancy.mean(),
        );
        let st = summarize(key, vec![m.wall.as_nanos() as f64]);
        report.record(
            &st,
            vec![
                ("variant", s(key)),
                ("tps", num(m.throughput_tps())),
                ("rel_tps", num(m.throughput_tps() / base_tps)),
                ("ttft_ms", num(m.ttft.mean())),
            ],
        );
    }
    report.finish();
}
