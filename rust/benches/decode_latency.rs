//! Fig. 11 / Table 16 (decode side): per-token decode latency vs KV length,
//! per method — the series where SVD/PaLU pay per-step reconstruction of
//! the whole visible cache and RAP does not.
//!
//! Section (c) is the perf gate for the allocation-free paged decode path:
//! it times the seed's allocating dense step (`step_alloc_reference`)
//! against `decode_batch_paged` at 2k context on synthetic weights (no
//! artifacts needed) and writes the speedups to `BENCH_decode.json`, so
//! the decode-latency trajectory is tracked across PRs.

use rap::config::Method;
use rap::experiments::bench_support::{budgets, BenchReport};
use rap::kvcache::{CacheShape, PagedKvCache};
use rap::manifest::Manifest;
use rap::model::load_engine;
use rap::model::synth::synth_engine;
use rap::model::BatchWorkspace;
use rap::runtime::{PjrtContext, PjrtEngine};
use rap::util::json::{arr, num, obj, s};
use rap::util::stats::bench;

fn main() {
    let (warm, budget) = budgets();
    let mut report = BenchReport::new("decode_latency");

    if let Ok(manifest) = Manifest::load_default() {
        let corpus = manifest.eval_corpus().unwrap();
        let model = "tinyllama";
        let keys = ["baseline_r00", "svd_r30", "palu_r30", "rap_r30"];

        // (a) PJRT decode at mid-context.
        if let Ok(pctx) = PjrtContext::cpu() {
            let mut base = 0.0f64;
            for key in keys {
                let Ok(engine) = PjrtEngine::load(&pctx, &manifest, model, key) else { continue };
                let mut caches = engine.empty_caches(1).unwrap();
                for (i, &b) in corpus[..8].iter().enumerate() {
                    caches = engine
                        .decode(&pctx, 1, &[b as i32], &[i as i32], &caches)
                        .unwrap()
                        .caches;
                }
                let pos = (engine.s_max / 2) as i32;
                let st = bench(&format!("pjrt_decode/{key}"), warm, budget, || {
                    let _ = engine.decode(&pctx, 1, &[65], &[pos], &caches).unwrap();
                });
                if key == "baseline_r00" {
                    base = st.mean_ns;
                }
                println!("    -> {:.0}% of baseline", 100.0 * st.mean_ns / base);
                report.record(
                    &st,
                    vec![("variant", s(key)), ("rel", num(st.mean_ns / base)), ("kind", s("pjrt"))],
                );
            }
        }

        // (b) Rust engine decode step across KV lengths (the Fig. 11 sweep).
        for ctx_len in [64usize, 192, 320] {
            let mut base = 0.0f64;
            for key in keys {
                let Ok(engine) = load_engine(&manifest, model, key) else { continue };
                let mut cache = engine.new_cache(ctx_len + 8);
                for (i, &t) in corpus[..ctx_len].iter().enumerate() {
                    engine.step_reuse(t, i, &mut cache);
                }
                let st = bench(
                    &format!("engine_decode/ctx{ctx_len}/{key}"),
                    warm,
                    budget,
                    || {
                        engine.step_reuse(corpus[ctx_len], ctx_len, &mut cache);
                    },
                );
                if key == "baseline_r00" {
                    base = st.mean_ns;
                }
                println!("    -> {:.0}% of baseline", 100.0 * st.mean_ns / base);
                report.record(
                    &st,
                    vec![
                        ("variant", s(key)),
                        ("ctx", num(ctx_len as f64)),
                        ("rel", num(st.mean_ns / base)),
                        ("kind", s("engine")),
                    ],
                );
            }
        }
    } else {
        println!("no artifacts; skipping PJRT/manifest sweeps");
    }

    // (c) Seed dense allocating step vs allocation-free paged decode at
    // long context — synthetic weights, always runs.
    let ctx_len: usize = if std::env::var("RAP_BENCH_FAST").is_ok() { 512 } else { 2048 };
    let s_max = ctx_len + 8;
    let mut variants = Vec::new();
    let mut rap_speedup = 0.0f64;
    for method in [Method::Baseline, Method::Svd, Method::Palu, Method::Rap] {
        let engine = synth_engine(method, 2);
        let shape = CacheShape::of(&engine.cfg, &engine.spec);

        let mut dense = engine.new_cache(s_max);
        for i in 0..ctx_len {
            engine.step_reuse((i % 251) as u8, i, &mut dense);
        }
        let seed_st = bench(
            &format!("seed_dense/ctx{ctx_len}/{}", method.name()),
            warm,
            budget,
            || {
                let _ = engine.step_alloc_reference(65, ctx_len, &mut dense);
            },
        );

        let mut kv = PagedKvCache::with_storage(shape, 64 << 20);
        kv.reserve(1, s_max).unwrap();
        let mut batch = BatchWorkspace::new(&engine, s_max);
        for i in 0..ctx_len {
            engine
                .decode_batch_paged(&[(1, (i % 251) as u8, i)], &mut kv, &mut batch, false)
                .unwrap();
        }
        let paged_st = bench(
            &format!("paged_ws/ctx{ctx_len}/{}", method.name()),
            warm,
            budget,
            || {
                engine
                    .decode_batch_paged(&[(1, 65, ctx_len)], &mut kv, &mut batch, true)
                    .unwrap();
            },
        );

        let speedup = seed_st.mean_ns / paged_st.mean_ns;
        println!("    -> {}: paged workspace {speedup:.2}x vs seed dense", method.name());
        if method == Method::Rap {
            rap_speedup = speedup;
        }
        report.record(
            &seed_st,
            vec![("variant", s(method.name())), ("ctx", num(ctx_len as f64)), ("kind", s("seed_dense"))],
        );
        report.record(
            &paged_st,
            vec![
                ("variant", s(method.name())),
                ("ctx", num(ctx_len as f64)),
                ("kind", s("paged_ws")),
                ("speedup", num(speedup)),
            ],
        );
        variants.push(obj(vec![
            ("method", s(method.name())),
            ("ctx", num(ctx_len as f64)),
            ("seed_dense_us", num(seed_st.mean_ns / 1e3)),
            ("paged_ws_us", num(paged_st.mean_ns / 1e3)),
            ("speedup", num(speedup)),
        ]));
    }
    let summary = obj(vec![
        ("bench", s("decode_latency")),
        ("ctx", num(ctx_len as f64)),
        ("target_rap_speedup", num(1.3)),
        ("rap_speedup", num(rap_speedup)),
        ("variants", arr(variants)),
    ]);
    let _ = std::fs::write("BENCH_decode.json", summary.to_string_pretty());
    println!("-> BENCH_decode.json (rap {rap_speedup:.2}x vs seed dense at ctx {ctx_len})");

    report.finish();
}
