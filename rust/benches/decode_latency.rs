//! Fig. 11 / Table 16 (decode side): per-token decode latency vs KV length,
//! per method — the series where SVD/PaLU pay per-step reconstruction of
//! the whole visible cache and RAP does not.

use rap::experiments::bench_support::{budgets, BenchReport};
use rap::manifest::Manifest;
use rap::model::load_engine;
use rap::runtime::{PjrtContext, PjrtEngine};
use rap::util::json::{num, s};
use rap::util::stats::bench;

fn main() {
    let (warm, budget) = budgets();
    let mut report = BenchReport::new("decode_latency");
    let Ok(manifest) = Manifest::load_default() else {
        println!("no artifacts; run `make artifacts` first");
        return;
    };
    let corpus = manifest.eval_corpus().unwrap();
    let model = "tinyllama";
    let keys = ["baseline_r00", "svd_r30", "palu_r30", "rap_r30"];

    // (a) PJRT decode at mid-context.
    if let Ok(pctx) = PjrtContext::cpu() {
        let mut base = 0.0f64;
        for key in keys {
            let Ok(engine) = PjrtEngine::load(&pctx, &manifest, model, key) else { continue };
            let mut caches = engine.empty_caches(1).unwrap();
            for (i, &b) in corpus[..8].iter().enumerate() {
                caches = engine
                    .decode(&pctx, 1, &[b as i32], &[i as i32], &caches)
                    .unwrap()
                    .caches;
            }
            let pos = (engine.s_max / 2) as i32;
            let st = bench(&format!("pjrt_decode/{key}"), warm, budget, || {
                let _ = engine.decode(&pctx, 1, &[65], &[pos], &caches).unwrap();
            });
            if key == "baseline_r00" {
                base = st.mean_ns;
            }
            println!("    -> {:.0}% of baseline", 100.0 * st.mean_ns / base);
            report.record(
                &st,
                vec![("variant", s(key)), ("rel", num(st.mean_ns / base)), ("kind", s("pjrt"))],
            );
        }
    }

    // (b) Rust engine decode step across KV lengths (the Fig. 11 sweep).
    for ctx_len in [64usize, 192, 320] {
        let mut base = 0.0f64;
        for key in keys {
            let Ok(engine) = load_engine(&manifest, model, key) else { continue };
            let mut cache = engine.new_cache(ctx_len + 8);
            for (i, &t) in corpus[..ctx_len].iter().enumerate() {
                engine.step(t, i, &mut cache);
            }
            let st = bench(
                &format!("engine_decode/ctx{ctx_len}/{key}"),
                warm,
                budget,
                || {
                    engine.step(corpus[ctx_len], ctx_len, &mut cache);
                },
            );
            if key == "baseline_r00" {
                base = st.mean_ns;
            }
            println!("    -> {:.0}% of baseline", 100.0 * st.mean_ns / base);
            report.record(
                &st,
                vec![
                    ("variant", s(key)),
                    ("ctx", num(ctx_len as f64)),
                    ("rel", num(st.mean_ns / base)),
                    ("kind", s("engine")),
                ],
            );
        }
    }
    report.finish();
}
