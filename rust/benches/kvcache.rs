//! KV-cache manager bench: allocator throughput, capacity gain under
//! compression, storage-backed page-table access, and int4 quantization
//! round-trip cost.

use rap::experiments::bench_support::{budgets, BenchReport};
use rap::kvcache::{quant, CacheShape, KvLayerView, PagedKvCache, BLOCK_TOKENS};
use rap::util::json::num;
use rap::util::rng::Rng;
use rap::util::stats::{bench, black_box};

fn shape(k: usize, v: usize) -> CacheShape {
    CacheShape {
        n_layers: 4,
        n_kv_heads: 4,
        k_width: vec![k; 4],
        v_width: vec![v; 4],
    }
}

fn main() {
    let (warm, budget) = budgets();
    let mut report = BenchReport::new("kvcache");

    // Capacity: tokens a 64 MiB budget holds, full vs rho=30% widths.
    let full = PagedKvCache::new(shape(24, 24), 64 << 20);
    let rap = PagedKvCache::new(shape(17, 17), 64 << 20);
    println!(
        "64MiB budget: baseline {} tokens, rap@30% {} tokens ({:.2}x)",
        full.free_token_capacity(),
        rap.free_token_capacity(),
        rap.free_token_capacity() as f64 / full.free_token_capacity() as f64
    );

    let st = bench("reserve_release_cycle", warm, budget, || {
        let mut c = PagedKvCache::new(shape(24, 24), 8 << 20);
        for sess in 0..64u64 {
            let _ = c.reserve(sess, BLOCK_TOKENS * 2);
        }
        for sess in 0..64u64 {
            c.release(sess);
        }
        black_box(c.used_blocks());
    });
    report.record(&st, vec![("sessions", num(64.0))]);

    // Same cycle against a storage-backed cache: the delta is the cost of
    // zeroing recycled blocks at reserve time (amortised 1/BLOCK_TOKENS per
    // decoded token on the serving path).
    {
        let mut c = PagedKvCache::with_storage(shape(24, 24), 8 << 20);
        let st = bench("reserve_release_cycle_zeroed", warm, budget, || {
            for sess in 0..64u64 {
                let _ = c.reserve(sess, BLOCK_TOKENS * 2);
            }
            for sess in 0..64u64 {
                c.release(sess);
            }
            black_box(c.used_blocks());
        });
        report.record(&st, vec![("sessions", num(64.0))]);
    }

    // Page-table row writes + blocked run reads at a long context — the
    // access pattern of the engine's paged decode hot path.
    {
        let sh = shape(17, 17);
        let ctx = 4096usize;
        let mut c = PagedKvCache::with_storage(sh.clone(), 64 << 20);
        c.reserve(1, ctx).unwrap();
        let st = bench("paged_rows_write_sweep/ctx4096", warm, budget, || {
            let (pages, store) = c.tables_and_ptrs().unwrap();
            let blocks = pages.blocks(1).unwrap();
            // SAFETY: one live view at a time.
            let mut view = unsafe { store.seq_layer(0, blocks) };
            for t in 0..256 {
                view.k_row_mut(0, t)[0] = t as f32;
            }
            let mut acc = 0.0f32;
            view.for_k_runs(0, ctx, |_, rows| acc += rows[0]);
            black_box(acc);
        });
        report.record(&st, vec![("ctx", num(ctx as f64))]);
    }

    // int4 quantization round-trip at latent row widths.
    let mut rng = Rng::new(5);
    for width in [16usize, 24, 48, 128] {
        let row: Vec<f32> = (0..width).map(|_| rng.normal_f32()).collect();
        let st = bench(&format!("int4_roundtrip/{width}"), warm, budget, || {
            let mut r = row.clone();
            quant::roundtrip(black_box(&mut r));
        });
        report.record(&st, vec![("width", num(width as f64))]);
    }
    report.finish();
}
