//! KV-cache manager bench: allocator throughput, capacity gain under
//! compression, and int4 quantization round-trip cost.

use rap::experiments::bench_support::{budgets, BenchReport};
use rap::kvcache::{quant, CacheShape, PagedKvCache, BLOCK_TOKENS};
use rap::util::json::num;
use rap::util::rng::Rng;
use rap::util::stats::{bench, black_box};

fn shape(k: usize, v: usize) -> CacheShape {
    CacheShape {
        n_layers: 4,
        n_kv_heads: 4,
        k_width: vec![k; 4],
        v_width: vec![v; 4],
    }
}

fn main() {
    let (warm, budget) = budgets();
    let mut report = BenchReport::new("kvcache");

    // Capacity: tokens a 64 MiB budget holds, full vs rho=30% widths.
    let full = PagedKvCache::new(shape(24, 24), 64 << 20);
    let rap = PagedKvCache::new(shape(17, 17), 64 << 20);
    println!(
        "64MiB budget: baseline {} tokens, rap@30% {} tokens ({:.2}x)",
        full.free_token_capacity(),
        rap.free_token_capacity(),
        rap.free_token_capacity() as f64 / full.free_token_capacity() as f64
    );

    let st = bench("reserve_release_cycle", warm, budget, || {
        let mut c = PagedKvCache::new(shape(24, 24), 8 << 20);
        for sess in 0..64u64 {
            let _ = c.reserve(sess, BLOCK_TOKENS * 2);
        }
        for sess in 0..64u64 {
            c.release(sess);
        }
        black_box(c.used_blocks());
    });
    report.record(&st, vec![("sessions", num(64.0))]);

    // int4 quantization round-trip at latent row widths.
    let mut rng = Rng::new(5);
    for width in [16usize, 24, 48, 128] {
        let row: Vec<f32> = (0..width).map(|_| rng.normal_f32()).collect();
        let st = bench(&format!("int4_roundtrip/{width}"), warm, budget, || {
            let mut r = row.clone();
            quant::roundtrip(black_box(&mut r));
        });
        report.record(&st, vec![("width", num(width as f64))]);
    }
    report.finish();
}
