//! Oversubscription gate: optimistic (prompt-only) admission vs worst-case
//! up-front reservation on the SAME physical block budget, at 2x and 4x
//! oversubscription (worst-case token demand over physical capacity).
//!
//! Worst-case reservation admits only as many sessions as could all grow
//! to `prompt + max_new` simultaneously, leaving the cache underused while
//! requests queue.  Optimistic admission packs sessions by their prompt
//! footprint and lets the preemption/resume machinery absorb the (rare)
//! exhaustion — so it must sustain strictly more concurrent decodes on the
//! same budget.  Results land in `BENCH_oversub.json` (uploaded by CI next
//! to the serving/prefix artifacts): per policy and level, wall-clock
//! throughput, TTFT p50/p99, peak concurrent decodes, and the pressure
//! counters (preemptions / resumes / evictions).

use std::collections::BTreeSet;
use std::time::Instant;

use rap::config::Method;
use rap::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, Event, FinishReason, Request,
};
use rap::kvcache::{CacheShape, BLOCK_TOKENS};
use rap::model::backend::RustBackend;
use rap::model::synth::synth_engine;

fn prompt(len: usize, salt: usize) -> Vec<u8> {
    // Cross term keeps prompts distinct inside the first block: no prefix
    // sharing, every session pays its full footprint.
    (0..len).map(|i| ((i * 37 + salt * 101 + i * salt) % 251) as u8).collect()
}

fn percentile(xs: &mut [f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[((xs.len() as f64 * p).ceil() as usize).clamp(1, xs.len()) - 1]
}

struct RunStats {
    throughput_tok_s: f64,
    ttft_p50_ms: f64,
    ttft_p99_ms: f64,
    peak_concurrent: usize,
    preemptions: u64,
    resumes: u64,
    evictions: u64,
    wall_ms: f64,
}

/// Serve `sessions` requests to completion on a `blocks`-block budget,
/// sampling the number of distinct sessions that decoded each tick.
fn run(
    engine: &rap::model::Engine,
    shape: &CacheShape,
    sessions: usize,
    blocks: usize,
    prompt_len: usize,
    max_new: usize,
    reserve_worst_case: bool,
) -> RunStats {
    let s_max = prompt_len + max_new + 16;
    let backend = RustBackend::new(engine, s_max);
    let mut coord = Coordinator::new(
        backend,
        shape.clone(),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: sessions,
                buckets: vec![1, 4, 8, 16],
                max_queue: sessions * 2,
                // Whole workload prefills in the first tick, so peak
                // concurrency reflects admission policy, not prefill
                // staggering.
                prefill_chunk_tokens: 1024,
                reserve_worst_case,
                default_retention: None,
                default_speculative: None,
            },
            kv_budget_bytes: shape.bytes_per_token() * BLOCK_TOKENS * blocks,
        },
    );
    assert_eq!(coord.kv_capacity_blocks(), blocks);
    for i in 0..sessions {
        assert!(
            coord.try_submit(Request::new(i as u64, prompt(prompt_len, 60 + i), max_new)).is_ok(),
            "submit {i}"
        );
    }

    let t0 = Instant::now();
    let mut peak_concurrent = 0usize;
    let mut done = 0usize;
    while done < sessions {
        let events = coord.tick().unwrap();
        let decoding: BTreeSet<u64> = events
            .iter()
            .filter_map(|e| match e {
                Event::Token { id, .. } => Some(*id),
                _ => None,
            })
            .collect();
        peak_concurrent = peak_concurrent.max(decoding.len());
        done += events.iter().filter(|e| e.is_finished()).count();
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    let responses = coord.run_to_completion().unwrap();
    assert_eq!(responses.len(), sessions);
    let mut tokens = 0usize;
    let mut ttfts: Vec<f64> = Vec::with_capacity(sessions);
    for r in &responses {
        assert_eq!(
            r.metrics.finish_reason,
            FinishReason::Length,
            "session {} must run to full length (reserve_worst_case={reserve_worst_case})",
            r.id
        );
        assert_eq!(r.generated.len(), max_new, "session {}", r.id);
        tokens += r.generated.len();
        ttfts.push(r.metrics.ttft_ms);
    }
    RunStats {
        throughput_tok_s: tokens as f64 / (wall_ms / 1e3).max(1e-9),
        ttft_p50_ms: percentile(&mut ttfts, 0.50),
        ttft_p99_ms: percentile(&mut ttfts, 0.99),
        peak_concurrent,
        preemptions: coord.metrics.preemptions,
        resumes: coord.metrics.resumes,
        evictions: coord.kv_evictions(),
        wall_ms,
    }
}

fn main() {
    use rap::util::json::{num, obj, s, Value};

    // Fixed geometry (no RAP_BENCH_FAST knob): the peak-concurrency gap
    // between the two admission policies depends on the block arithmetic
    // below, and the whole workload is tiny anyway.
    let prompt_len = 32; // 2 blocks at admission
    let max_new = 24; // worst case 56 tokens = 4 blocks per session
    let worst_blocks = (prompt_len + max_new).div_ceil(BLOCK_TOKENS); // per session
    let blocks = 12usize;

    let engine = synth_engine(Method::Rap, 11);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);

    println!(
        "== bench: oversub (budget {blocks} blocks, prompt {prompt_len}, max_new {max_new}, \
         worst case {worst_blocks} blocks/session) =="
    );

    let mut levels = Vec::new();
    for oversub in [2usize, 4] {
        // `sessions * worst_blocks = oversub * blocks`: worst-case token
        // demand is `oversub` times the physical budget.
        let sessions = oversub * blocks / worst_blocks;
        let reserve = run(&engine, &shape, sessions, blocks, prompt_len, max_new, true);
        let optimistic = run(&engine, &shape, sessions, blocks, prompt_len, max_new, false);
        println!(
            "{oversub}x ({sessions} sessions): reserve-up-front {:.0} tok/s, peak {} concurrent, \
             ttft p99 {:.1} ms",
            reserve.throughput_tok_s, reserve.peak_concurrent, reserve.ttft_p99_ms
        );
        println!(
            "{oversub}x ({sessions} sessions): oversubscribed  {:.0} tok/s, peak {} concurrent, \
             ttft p99 {:.1} ms ({} preemptions, {} resumes, {} evictions)",
            optimistic.throughput_tok_s,
            optimistic.peak_concurrent,
            optimistic.ttft_p99_ms,
            optimistic.preemptions,
            optimistic.resumes,
            optimistic.evictions
        );
        assert!(
            optimistic.peak_concurrent > reserve.peak_concurrent,
            "{oversub}x: optimistic admission must sustain more concurrent decodes \
             ({} vs {}) on the same {blocks}-block budget",
            optimistic.peak_concurrent,
            reserve.peak_concurrent
        );
        let stats_obj = |r: &RunStats| {
            obj(vec![
                ("throughput_tok_s", num(r.throughput_tok_s)),
                ("ttft_p50_ms", num(r.ttft_p50_ms)),
                ("ttft_p99_ms", num(r.ttft_p99_ms)),
                ("peak_concurrent", num(r.peak_concurrent as f64)),
                ("preemptions", num(r.preemptions as f64)),
                ("resumes", num(r.resumes as f64)),
                ("evictions", num(r.evictions as f64)),
                ("wall_ms", num(r.wall_ms)),
            ])
        };
        levels.push(obj(vec![
            ("oversubscription", num(oversub as f64)),
            ("sessions", num(sessions as f64)),
            ("reserve_worst_case", stats_obj(&reserve)),
            ("oversubscribed", stats_obj(&optimistic)),
        ]));
    }

    let summary: Value = obj(vec![
        ("bench", s("oversub")),
        ("budget_blocks", num(blocks as f64)),
        ("prompt_tokens", num(prompt_len as f64)),
        ("max_new", num(max_new as f64)),
        ("worst_case_blocks_per_session", num(worst_blocks as f64)),
        ("levels", Value::Arr(levels)),
    ]);
    let _ = std::fs::write("BENCH_oversub.json", summary.to_string_pretty());
    println!("-> BENCH_oversub.json");
}
