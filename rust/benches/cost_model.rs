//! Bench + regeneration for Table 2 / Table 6 / Table 10 (analytic side):
//! evaluates the closed-form cost model across the full (method, rho, H, D)
//! grid and times it (the model itself is used inside the scheduler's
//! admission accounting, so its speed matters a little; its *values* are
//! the real deliverable and are printed for comparison with the paper).

use rap::config::Method;
use rap::cost::{head_cost, layer_kv_params, uniform_spec, variant_accounting, Granularity};
use rap::config::ModelConfig;
use rap::experiments::bench_support::{budgets, BenchReport};
use rap::util::json::num;
use rap::util::stats::{bench, black_box};

fn main() {
    let (warm, budget) = budgets();
    let mut report = BenchReport::new("cost_model");

    // Value regeneration (Table 6 row check).
    let base = head_cost(Method::Baseline, 32, 128, 1, 1.0).flops;
    println!("Table 6 @ rho=30%:");
    for (m, paper) in [
        (Method::Svd, 1.514),
        (Method::Palu, 1.491),
        (Method::Rap, 1.468),
    ] {
        let got = head_cost(m, 32, 128, 1, 0.7).flops / 1e6;
        println!(
            "  {:>8}: {:.3}M (paper {:.3}M, base {:.3}M)",
            m.name(),
            got,
            paper,
            base / 1e6
        );
        assert!((got - paper).abs() < 0.002);
    }

    let st = bench("head_cost_grid(3x5x3x4)", warm, budget, || {
        let mut acc = 0.0f64;
        for m in [Method::Svd, Method::Palu, Method::Rap] {
            for rho in [0.1, 0.2, 0.3, 0.4, 0.5] {
                for h in [1usize, 8, 32] {
                    for d in [64usize, 96, 128, 256] {
                        acc += head_cost(m, h, d, 1, 1.0 - rho).flops;
                    }
                }
            }
        }
        black_box(acc);
    });
    report.record(&st, vec![("cases", num(180.0))]);

    let cfg = ModelConfig::paper_llama();
    let st = bench("variant_accounting(paper_llama)", warm, budget, || {
        let spec = uniform_spec(&cfg, Method::Rap, 0.3);
        black_box(variant_accounting(&cfg, &spec, 4096));
    });
    report.record(&st, vec![]);

    let st = bench("granularity_bounds(paper_llama)", warm, budget, || {
        let mut acc = 0.0;
        for m in [Method::Svd, Method::Palu] {
            for g in [Granularity::PerHead, Granularity::CrossHead] {
                acc += layer_kv_params(&cfg, m, 0.7, g);
            }
        }
        black_box(acc);
    });
    report.record(&st, vec![]);
    report.finish();
}
