//! Analytic cost model — Table 2 and Appendix C, exactly.
//!
//! For a single K/V head with input dimension D̂ = H·D, per Appendix C:
//!
//! | method   | KV-cache | params              | FLOPs               |
//! |----------|----------|---------------------|---------------------|
//! | baseline | 2SD      | 2HD²                | 4SHD²               |
//! | SVD      | r·2SD    | (r + r/H)·2HD²      | (r + r/H)·4SHD²     |
//! | PaLU     | r·2SD    | (r + r/2H)·2HD²     | (r + r/2H)·4SHD²    |
//! | RAP      | r·2SD    | r·2HD²              | r·4SHD²             |
//!
//! plus the *architecture-level* accounting (GQA, per-layer adaptive
//! widths, attention-block totals) used by the measured-FLOPs experiments.

use crate::config::{Method, ModelConfig, VariantSpec};

/// Symbolic per-head costs of computing the KV cache (Table 2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HeadCost {
    /// cached scalars per token-pair (K+V).
    pub kv_cache: f64,
    /// parameters in W_k/W_v (+ reconstruction matrices).
    pub params: f64,
    /// FLOPs to produce the cached K/V states for S tokens (incl.
    /// reconstruction for SVD/PaLU).
    pub flops: f64,
}

/// Table 2 row for one K/V head: H heads total, per-head dim D, sequence S,
/// retained ratio r = 1 - rho.
pub fn head_cost(method: Method, h: usize, d: usize, s: usize, r: f64) -> HeadCost {
    let (hf, df, sf) = (h as f64, d as f64, s as f64);
    let base = HeadCost {
        kv_cache: 2.0 * sf * df,
        params: 2.0 * hf * df * df,
        flops: 4.0 * sf * hf * df * df,
    };
    match method {
        Method::Baseline => base,
        Method::Svd => {
            // A: D̂×rD each for K and V (2 r H D²); B: rD×D each (2 r D²).
            let factor = r + r / hf;
            HeadCost {
                kv_cache: r * base.kv_cache,
                params: factor * base.params,
                flops: factor * base.flops,
            }
        }
        Method::Palu => {
            // V's B is absorbed: params 2rHD² + rD², flops likewise.
            let factor = r + r / (2.0 * hf);
            HeadCost {
                kv_cache: r * base.kv_cache,
                params: factor * base.params,
                flops: factor * base.flops,
            }
        }
        Method::Rap => HeadCost {
            kv_cache: r * base.kv_cache,
            params: r * base.params,
            flops: r * base.flops,
        },
    }
}

/// Break-even retained ratio below which a method reduces params/FLOPs
/// versus baseline (paper §3: SVD needs rho > 50% at H=1, PaLU > 33%).
pub fn break_even_rho(method: Method, h: usize) -> f64 {
    let hf = h as f64;
    match method {
        Method::Baseline => 0.0,
        // (r + r/H) < 1  =>  r < H/(H+1)  =>  rho > 1/(H+1)
        Method::Svd => 1.0 / (hf + 1.0),
        // (r + r/2H) < 1 =>  rho > 1/(2H+1)
        Method::Palu => 1.0 / (2.0 * hf + 1.0),
        Method::Rap => 0.0,
    }
}

/// Factorization granularity (paper Table 3 footnote): per-head is optimal;
/// cross-head factorizes all H heads jointly so A is D̂×(H·rD) against a
/// shared B (H·rD)×(H·D), inflating the reconstruction matrix H-fold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    PerHead,
    CrossHead,
}

/// Parameters of W_k+W_v (+reconstruction) for one layer of `cfg` under a
/// factorization method, per granularity.  Returns raw parameter counts.
pub fn layer_kv_params(
    cfg: &ModelConfig,
    method: Method,
    r: f64,
    gran: Granularity,
) -> f64 {
    let dhat = cfg.d_model as f64;
    let d = cfg.head_dim as f64;
    let hkv = cfg.n_kv_heads as f64;
    let rd = r * d;
    match method {
        Method::Baseline => 2.0 * dhat * hkv * d,
        Method::Rap => 2.0 * dhat * hkv * rd,
        Method::Svd => match gran {
            // per head: A per head D̂×rD, B per head rD×D (both K and V)
            Granularity::PerHead => 2.0 * (dhat * hkv * rd + hkv * rd * d),
            // cross head: B couples all heads: (Hkv rD)×(Hkv D)
            Granularity::CrossHead => {
                2.0 * (dhat * hkv * rd + (hkv * rd) * (hkv * d))
            }
        },
        Method::Palu => match gran {
            // K keeps B; V's B absorbed into W_o.
            Granularity::PerHead => 2.0 * dhat * hkv * rd + hkv * rd * d,
            Granularity::CrossHead => 2.0 * dhat * hkv * rd + (hkv * rd) * (hkv * d),
        },
    }
}

/// Whole-model attention accounting for a concrete variant (adaptive
/// per-layer widths) — drives Fig. 5 / Table 10 "measured" columns.
#[derive(Debug, Clone, Default)]
pub struct AttnAccounting {
    /// Attention parameters (q,k,v,o + reconstruction), all layers.
    pub attn_params: f64,
    /// Full model parameters.
    pub model_params: f64,
    /// KV-cache floats per token (all layers).
    pub kv_per_token: f64,
    /// Per-token attention-block FLOPs at context length `s` (projections,
    /// reconstruction, scores, AV, output).
    pub attn_flops_per_token: f64,
}

/// FLOPs convention: multiply-add counts as 2 (paper Table 6 note).
pub fn variant_accounting(cfg: &ModelConfig, spec: &VariantSpec, s: usize) -> AttnAccounting {
    let dhat = cfg.d_model as f64;
    let d = cfg.head_dim as f64;
    let h = cfg.n_heads as f64;
    let hkv = cfg.n_kv_heads as f64;
    let sf = s as f64;
    let mut acc = AttnAccounting::default();

    for l in 0..cfg.n_layers {
        let kr = spec.k_rank[l] as f64;
        let vr = spec.v_rank[l] as f64;
        acc.kv_per_token += hkv * (kr + vr);

        let (wq, wk, wv, wo, rec_params) = match spec.method {
            Method::Baseline => (dhat * h * d, dhat * hkv * d, dhat * hkv * d, h * d * dhat, 0.0),
            Method::Svd => (
                dhat * h * d,
                dhat * hkv * kr,
                dhat * hkv * vr,
                h * d * dhat,
                hkv * kr * d + hkv * vr * d, // B_k and B_v
            ),
            Method::Palu => (
                dhat * h * d,
                dhat * hkv * kr,
                dhat * hkv * vr,
                h * vr * dhat, // W_o absorbed to latent V width
                hkv * kr * d,  // B_k only
            ),
            Method::Rap => (
                dhat * h * kr, // absorbed W_q at latent width
                dhat * hkv * kr,
                dhat * hkv * vr,
                h * vr * dhat, // absorbed W_o
                0.0,
            ),
        };
        acc.attn_params += wq + wk + wv + wo + rec_params;

        // Per-token FLOPs at context length s (decode-style accounting):
        // projections (2·params of the matmuls), per-step reconstruction of
        // the cached context for SVD/PaLU, scores + AV over the context.
        let proj = 2.0 * (wq + wk + wv + wo);
        let recon_k = if spec.method.reconstructs_k() {
            2.0 * sf * hkv * kr * d
        } else {
            0.0
        };
        let recon_v = if spec.method.reconstructs_v() {
            2.0 * sf * hkv * vr * d
        } else {
            0.0
        };
        let (score_w, v_w) = match spec.method {
            Method::Baseline => (d, d),
            Method::Svd => (d, d),  // reconstructed to full dim
            Method::Palu => (d, vr),
            Method::Rap => (kr, vr),
        };
        let attn = 2.0 * sf * h * score_w + 2.0 * sf * h * v_w;
        acc.attn_flops_per_token += proj + recon_k + recon_v + attn;
    }

    // Non-attention parameters are method-invariant.
    let mlp = 3.0 * dhat * cfg.mlp_hidden as f64;
    let norms = 2.0 * dhat;
    let other = cfg.vocab as f64 * dhat + cfg.n_layers as f64 * (mlp + norms) + dhat;
    acc.model_params = acc.attn_params + other;
    acc
}

/// Uniform-width spec for cost sweeps (exact ratio, no adaptivity) — used
/// when regenerating the paper-scale tables where only ratios matter.
pub fn uniform_spec(cfg: &ModelConfig, method: Method, rho: f64) -> VariantSpec {
    let r = 1.0 - rho;
    let (kw, vw) = match method {
        Method::Baseline => (cfg.head_dim as f64, cfg.head_dim as f64),
        _ => (r * cfg.head_dim as f64, r * cfg.head_dim as f64),
    };
    VariantSpec {
        method,
        ratio: rho,
        model: cfg.name.clone(),
        tag: String::new(),
        key: format!("{}_r{:02}", method.name(), (rho * 100.0).round() as usize),
        k_rank: vec![kw.round() as usize; cfg.n_layers],
        v_rank: vec![vw.round() as usize; cfg.n_layers],
        k_pairs: vec![],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_matches_symbols() {
        // H=32, D=128, S=1: baseline 2HD^2 params, 4SHD^2 flops, 2SD cache.
        let c = head_cost(Method::Baseline, 32, 128, 1, 1.0);
        assert_eq!(c.params, 2.0 * 32.0 * 128.0 * 128.0);
        assert_eq!(c.flops, 4.0 * 32.0 * 128.0 * 128.0);
        assert_eq!(c.kv_cache, 2.0 * 128.0);
    }

    #[test]
    fn table6_values() {
        // Paper Table 6 (H=32, D=128): baseline 2.097M; at rho=30%:
        // SVD 1.514M, PaLU 1.491M, RAP 1.468M per-head per-token FLOPs.
        let h = 32;
        let d = 128;
        let base = head_cost(Method::Baseline, h, d, 1, 1.0).flops;
        assert!((base / 1e6 - 2.097).abs() < 0.001, "base {base}");
        let checks = [
            (Method::Svd, 1.514),
            (Method::Palu, 1.491),
            (Method::Rap, 1.468),
        ];
        for (m, expect) in checks {
            let f = head_cost(m, h, d, 1, 0.7).flops / 1e6;
            assert!((f - expect).abs() < 0.002, "{m:?}: {f} vs {expect}");
        }
    }

    #[test]
    fn table6_savings_column() {
        // RAP saving is exactly rho; SVD/PaLU strictly less.
        let (h, d) = (32, 128);
        let base = head_cost(Method::Baseline, h, d, 1, 1.0).flops;
        for rho in [0.1, 0.2, 0.3, 0.4, 0.5] {
            let rap = 1.0 - head_cost(Method::Rap, h, d, 1, 1.0 - rho).flops / base;
            assert!((rap - rho).abs() < 1e-12);
            let svd = 1.0 - head_cost(Method::Svd, h, d, 1, 1.0 - rho).flops / base;
            let palu = 1.0 - head_cost(Method::Palu, h, d, 1, 1.0 - rho).flops / base;
            assert!(svd < palu && palu < rap);
        }
    }

    #[test]
    fn break_even_single_head() {
        // Paper §3: at H=1, SVD reduces only when rho > 50%, PaLU > 33%.
        assert!((break_even_rho(Method::Svd, 1) - 0.5).abs() < 1e-12);
        assert!((break_even_rho(Method::Palu, 1) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(break_even_rho(Method::Rap, 1), 0.0);
        // And the cost function is consistent with the break-even claim.
        let at = |m: Method, rho: f64| head_cost(m, 1, 128, 1, 1.0 - rho).params;
        let base = at(Method::Baseline, 0.0);
        assert!(at(Method::Svd, 0.49) > base);
        assert!(at(Method::Svd, 0.51) < base);
        assert!(at(Method::Palu, 0.32) > base);
        assert!(at(Method::Palu, 0.34) < base);
    }

    #[test]
    fn kv_cache_identical_across_methods() {
        for rho in [0.1, 0.3, 0.5] {
            let r = 1.0 - rho;
            let kv: Vec<f64> = [Method::Svd, Method::Palu, Method::Rap]
                .iter()
                .map(|&m| head_cost(m, 8, 64, 100, r).kv_cache)
                .collect();
            assert!(kv.iter().all(|&x| (x - kv[0]).abs() < 1e-9));
        }
    }

    #[test]
    fn granularity_ordering() {
        // per-head strictly cheaper than cross-head for factorizations.
        let cfg = ModelConfig::paper_llama();
        for m in [Method::Svd, Method::Palu] {
            let ph = layer_kv_params(&cfg, m, 0.7, Granularity::PerHead);
            let ch = layer_kv_params(&cfg, m, 0.7, Granularity::CrossHead);
            assert!(ph < ch, "{m:?}");
        }
        // RAP is below both and exactly r * baseline.
        let rap = layer_kv_params(&cfg, Method::Rap, 0.7, Granularity::PerHead);
        let base = layer_kv_params(&cfg, Method::Baseline, 1.0, Granularity::PerHead);
        assert!((rap / base - 0.7).abs() < 1e-12);
    }

    #[test]
    fn variant_accounting_rap_attn_params_scale_linearly() {
        let cfg = ModelConfig::paper_llama();
        let base = variant_accounting(&cfg, &uniform_spec(&cfg, Method::Baseline, 0.0), 1024);
        for rho in [0.1, 0.3, 0.5] {
            let v = variant_accounting(&cfg, &uniform_spec(&cfg, Method::Rap, rho), 1024);
            let ratio = v.attn_params / base.attn_params;
            // Paper Fig. 5: RAP attention size tracks 1 - rho exactly
            // (up to integer rounding of widths).
            assert!((ratio - (1.0 - rho)).abs() < 0.01, "rho {rho}: {ratio}");
            // And the KV cache reduction matches by construction.
            let kv_ratio = v.kv_per_token / base.kv_per_token;
            assert!((kv_ratio - (1.0 - rho)).abs() < 0.01);
        }
    }

    #[test]
    fn variant_accounting_svd_has_overhead() {
        // Paper Fig. 5 / Table 10: SVD's factorization matrices can push
        // attention size ABOVE baseline at low rho.  At the whole-attention
        // level this is sharpest in the single-head worst case (§3); under
        // heavy GQA the K/V share shrinks and SVD sits just under 100%.
        let sh = ModelConfig::single_head();
        let base = variant_accounting(&sh, &uniform_spec(&sh, Method::Baseline, 0.0), 1);
        let svd10 = variant_accounting(&sh, &uniform_spec(&sh, Method::Svd, 0.1), 1);
        assert!(svd10.attn_params > base.attn_params);
        // GQA paper-scale: strict ordering SVD > PaLU > RAP and SVD barely
        // below baseline (the Fig. 5 "97.6%" point).
        let cfg = ModelConfig::paper_llama();
        let base = variant_accounting(&cfg, &uniform_spec(&cfg, Method::Baseline, 0.0), 1);
        let svd = variant_accounting(&cfg, &uniform_spec(&cfg, Method::Svd, 0.1), 1);
        let palu = variant_accounting(&cfg, &uniform_spec(&cfg, Method::Palu, 0.1), 1);
        let rap10 = variant_accounting(&cfg, &uniform_spec(&cfg, Method::Rap, 0.1), 1);
        assert!(svd.attn_params > palu.attn_params);
        assert!(palu.attn_params > rap10.attn_params);
        assert!(svd.attn_params > 0.95 * base.attn_params);
        assert!(rap10.attn_params < base.attn_params);
    }

    #[test]
    fn reconstruction_flops_grow_with_context() {
        // SVD per-token FLOPs grow with S (reconstruction of the whole
        // cache per step); RAP's stay flat in the projection term and grow
        // only via attention itself — and slower.
        let cfg = ModelConfig::paper_llama();
        let f = |m: Method, s: usize| {
            variant_accounting(&cfg, &uniform_spec(&cfg, m, 0.3), s).attn_flops_per_token
        };
        let svd_growth = f(Method::Svd, 4096) - f(Method::Svd, 1024);
        let rap_growth = f(Method::Rap, 4096) - f(Method::Rap, 1024);
        assert!(svd_growth > rap_growth);
    }
}
