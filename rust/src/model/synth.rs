//! Synthetic tiny-model weights for every compression variant.
//!
//! The paged-store identity tests, the allocation-free decode test, and
//! the decode-latency bench all need a working `Engine` for each method
//! *without* the `make artifacts` pipeline.  Numerical quality is
//! irrelevant there — only shapes and the execution graph matter — so the
//! factors are random (seeded, reproducible) rather than actual SVD/PaLU/
//! RAP decompositions of a trained model.  The genuine artifacts remain
//! the only source for accuracy experiments.

use std::collections::BTreeMap;

use crate::config::{Method, ModelConfig, Pairing, VariantSpec};
use crate::model::{Engine, Weights};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Byte-vocab toy architecture (GQA: 4 query heads over 2 KV heads) big
/// enough to exercise every code path, small enough for tight test loops.
pub fn tiny_config() -> ModelConfig {
    ModelConfig {
        name: "synth".into(),
        vocab: 256,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        head_dim: 8,
        mlp_hidden: 48,
        max_seq: 4096,
        rope_theta: 10_000.0,
        pairing: Pairing::Half,
        norm_eps: 1e-5,
    }
}

/// Latent widths used by the synthetic compressed variants: K keeps 3 of
/// the 4 RoPE pairs (width 6), V keeps rank 6 of 8.
const K_RANK: usize = 6;
const V_RANK: usize = 6;

/// Build a `VariantSpec` + random `Weights` for `method` over `cfg`.
pub fn synth_weights(cfg: &ModelConfig, method: Method, seed: u64) -> (VariantSpec, Weights) {
    let mut rng = Rng::new(seed);
    let (d, dh, h, hkv, mlp) = (
        cfg.d_model,
        cfg.head_dim,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.mlp_hidden,
    );
    let sc = 1.0 / (d as f32).sqrt();
    let mut named: BTreeMap<String, Tensor> = BTreeMap::new();
    named.insert("tok_emb".into(), Tensor::randn(vec![cfg.vocab, d], 0.3, &mut rng));
    named.insert("final_norm".into(), Tensor::full(vec![d], 1.0));

    let (k_rank, v_rank) = match method {
        Method::Baseline => (dh, dh),
        _ => (K_RANK, V_RANK),
    };
    let mut k_pairs: Vec<Vec<Vec<usize>>> = Vec::new();
    for l in 0..cfg.n_layers {
        let mut ins = |field: &str, t: Tensor| {
            named.insert(format!("layers.{l}.{field}"), t);
        };
        ins("attn_norm", Tensor::full(vec![d], 1.0));
        ins("mlp_norm", Tensor::full(vec![d], 1.0));
        ins("w_gate", Tensor::randn(vec![d, mlp], sc, &mut rng));
        ins("w_up", Tensor::randn(vec![d, mlp], sc, &mut rng));
        ins("w_down", Tensor::randn(vec![mlp, d], sc, &mut rng));
        match method {
            Method::Baseline => {
                ins("wq", Tensor::randn(vec![d, h * dh], sc, &mut rng));
                ins("wk", Tensor::randn(vec![d, hkv * dh], sc, &mut rng));
                ins("wv", Tensor::randn(vec![d, hkv * dh], sc, &mut rng));
                ins("wo", Tensor::randn(vec![h * dh, d], sc, &mut rng));
            }
            Method::Svd => {
                ins("wq", Tensor::randn(vec![d, h * dh], sc, &mut rng));
                ins("a_k", Tensor::randn(vec![d, hkv * k_rank], sc, &mut rng));
                ins("b_k", Tensor::randn(vec![hkv, k_rank, dh], sc, &mut rng));
                ins("a_v", Tensor::randn(vec![d, hkv * v_rank], sc, &mut rng));
                ins("b_v", Tensor::randn(vec![hkv, v_rank, dh], sc, &mut rng));
                ins("wo", Tensor::randn(vec![h * dh, d], sc, &mut rng));
            }
            Method::Palu => {
                ins("wq", Tensor::randn(vec![d, h * dh], sc, &mut rng));
                ins("a_k", Tensor::randn(vec![d, hkv * k_rank], sc, &mut rng));
                ins("b_k", Tensor::randn(vec![hkv, k_rank, dh], sc, &mut rng));
                ins("a_v", Tensor::randn(vec![d, hkv * v_rank], sc, &mut rng));
                ins("wo_t", Tensor::randn(vec![h * v_rank, d], sc, &mut rng));
            }
            Method::Rap => {
                ins("wq_t", Tensor::randn(vec![d, h * k_rank], sc, &mut rng));
                ins("a_k", Tensor::randn(vec![d, hkv * k_rank], sc, &mut rng));
                ins("a_v", Tensor::randn(vec![d, hkv * v_rank], sc, &mut rng));
                ins("wo_t", Tensor::randn(vec![h * v_rank, d], sc, &mut rng));
            }
        }
        if method == Method::Rap {
            k_pairs.push(
                (0..hkv)
                    .map(|_| rng.choose_distinct(cfg.n_pairs(), k_rank / 2))
                    .collect(),
            );
        }
    }
    if method == Method::Baseline {
        let mut spec = VariantSpec::baseline(cfg);
        spec.key = "synth_baseline".into();
        return (spec, Weights { named });
    }
    let spec = VariantSpec {
        method,
        ratio: 0.3,
        model: cfg.name.clone(),
        tag: String::new(),
        key: format!("synth_{}", method.name()),
        k_rank: vec![k_rank; cfg.n_layers],
        v_rank: vec![v_rank; cfg.n_layers],
        k_pairs,
    };
    (spec, Weights { named })
}

/// A ready-to-run synthetic engine for `method`.
pub fn synth_engine(method: Method, seed: u64) -> Engine {
    let cfg = tiny_config();
    let (spec, weights) = synth_weights(&cfg, method, seed);
    Engine::new(cfg, spec, &weights).expect("synthetic weights are complete")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_methods_build_and_step() {
        for method in [Method::Baseline, Method::Svd, Method::Palu, Method::Rap] {
            let engine = synth_engine(method, 7);
            let mut cache = engine.new_cache(16);
            let logits = engine.step(b'a', 0, &mut cache);
            assert_eq!(logits.len(), 256);
            assert!(logits.iter().all(|v| v.is_finite()), "{method:?}");
            let logits = engine.step(b'b', 1, &mut cache);
            assert!(logits.iter().all(|v| v.is_finite()), "{method:?}");
            assert_eq!(cache.len, 2);
            assert_eq!(cache.bytes_used(), cache.shape.bytes_for_tokens(2));
        }
    }

    #[test]
    fn synth_is_seed_deterministic() {
        let a = synth_engine(Method::Rap, 3);
        let b = synth_engine(Method::Rap, 3);
        let (mut ca, mut cb) = (a.new_cache(8), b.new_cache(8));
        assert_eq!(a.step(10, 0, &mut ca), b.step(10, 0, &mut cb));
    }
}
