//! Variant weight loading: flat little-endian f32 binaries indexed by the
//! manifest's tensor table (written by `python/compile/aot.py`).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::manifest::{Manifest, VariantEntry};
use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct Weights {
    pub named: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn load(manifest: &Manifest, entry: &VariantEntry) -> Result<Weights> {
        let path = manifest.root.join(&entry.weights_path);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading weights {}", path.display()))?;
        if bytes.len() != entry.weights_bytes {
            bail!(
                "weights size mismatch for {}: {} vs manifest {}",
                entry.weights_path,
                bytes.len(),
                entry.weights_bytes
            );
        }
        let mut named = BTreeMap::new();
        for t in &entry.tensors {
            let n: usize = t.shape.iter().product();
            let start = t.offset;
            let end = start + 4 * n;
            if end > bytes.len() {
                bail!("tensor {} overruns weights file", t.name);
            }
            let mut data = Vec::with_capacity(n);
            for chunk in bytes[start..end].chunks_exact(4) {
                data.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
            }
            named.insert(t.name.clone(), Tensor::new(t.shape.clone(), data));
        }
        Ok(Weights { named })
    }

    pub fn get(&self, name: &str) -> &Tensor {
        self.named
            .get(name)
            .unwrap_or_else(|| panic!("missing tensor {name:?}"))
    }

    pub fn layer(&self, layer: usize, field: &str) -> &Tensor {
        self.get(&format!("layers.{layer}.{field}"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.named.contains_key(name)
    }

    pub fn total_params(&self) -> usize {
        self.named.values().map(|t| t.numel()).sum()
    }

    /// Flatten in a given name order (the order PJRT executables expect).
    pub fn in_order<'a>(&'a self, names: &[String]) -> Vec<&'a Tensor> {
        names.iter().map(|n| self.get(n)).collect()
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn f32_le_roundtrip() {
        let vals = [0.0f32, 1.5, -3.25, f32::MIN_POSITIVE];
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let parsed: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(parsed, vals);
    }
}
