//! Pure-Rust model substrate: weight loading + the reference inference
//! engine used by evaluation experiments and cross-checked against PJRT.

pub mod backend;
pub mod engine;
pub mod synth;
pub mod weights;

pub use engine::{
    argmax, BatchWorkspace, Cache, DecodeWorkspace, Engine, LayerCache, PrefillWorkspace,
};
pub use weights::Weights;

use anyhow::Result;

use crate::manifest::Manifest;

/// Convenience: build an engine for `model/variant` straight from the
/// manifest.
pub fn load_engine(manifest: &Manifest, model: &str, variant: &str) -> Result<Engine> {
    let entry = manifest.model(model)?;
    let ve = entry
        .variants
        .get(variant)
        .ok_or_else(|| anyhow::anyhow!("variant {variant:?} not found for {model}"))?;
    let w = Weights::load(manifest, ve)?;
    Engine::new(entry.config.clone(), ve.spec.clone(), &w)
}
