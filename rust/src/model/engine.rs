//! Pure-Rust inference engine for every compression variant.
//!
//! The engine mirrors `python/compile/model.py` operation-for-operation and
//! is cross-validated against PJRT executions of the exported HLO in the
//! integration tests.  It powers the evaluation experiments (PPL, probe
//! tasks, long-context suite), dense latency sweeps, and the measured-FLOPs
//! harness (every matmul is routed through a FLOP counter).
//!
//! Method semantics (paper Figure 1 / §4.3):
//! * baseline — full K (post-RoPE) and V cached.
//! * svd      — pre-RoPE latent K and latent V cached; **both reconstructed
//!              every attention call** (the overhead RAP removes).
//! * palu     — latent K reconstructed; latent V consumed directly through
//!              the absorbed W_o.
//! * rap      — index-aware-RoPE'd latent K and latent V consumed directly:
//!              attention runs entirely at latent widths.
//!
//! ## Decode paths
//!
//! All hot-path arithmetic lives in kernels generic over
//! [`KvLayerView`], so the same code serves two cache layouts:
//!
//! * the dense per-sequence [`LayerCache`] (evaluation, latency sweeps),
//!   driven by [`Engine::step`];
//! * the storage-backed `kvcache::PagedKvCache`, driven by
//!   [`Engine::decode_batch_paged`] — the serving path.  It steps a whole
//!   batch of sessions through one layer at a time (weights stay hot in
//!   cache), parallelises across sessions via `scoped_chunks_indexed`, and
//!   performs **zero heap allocations** in steady state: all scratch lives
//!   in a reusable [`DecodeWorkspace`] / [`BatchWorkspace`], and scores are
//!   computed with the blocked `dot_rows_scaled` / `axpy_rows` kernels
//!   whose accumulation order makes paged and dense decode bit-identical.
//!
//! [`Engine::step_alloc_reference`] preserves the original allocating
//! per-row decode verbatim; it is the oracle the workspace path is tested
//! against bitwise, and the baseline `benches/decode_latency.rs` reports
//! speedups over in `BENCH_decode.json`.
//!
//! ## Prefill paths
//!
//! Prompt processing is block-parallel: [`Engine::prefill_chunk_dense`] /
//! [`Engine::prefill_chunk_paged`] run a whole prompt chunk token-major —
//! one GEMM per weight matrix per chunk (`tensor::ops::matmul_rows_into`,
//! per-row arithmetic identical to the token loop's `vecmat_into`), RoPE
//! applied to the chunk in place, the chunk's latent K/V rows written to
//! the cache run-by-run, and causal attention fanned across workers per
//! query row with the same blocked kernels as decode.  Scratch lives in a
//! reusable [`PrefillWorkspace`] (zero steady-state allocations, same
//! contract as [`DecodeWorkspace`]).  [`Engine::prefill_token_loop`] keeps
//! the original token-by-token prefill as the bitwise oracle
//! (`tests/prefill.rs`) and the `benches/attention_latency.rs` /
//! `BENCH_prefill.json` baseline.
//!
//! ## Kernel paths
//!
//! Every dot/axpy/vecmat/GEMM call — hot paths *and* the preserved
//! reference oracles — routes through `tensor::simd` dispatch on the
//! engine's [`KernelPath`] (`RAP_KERNEL_PATH`): `scalar` keeps the seed's
//! bit-exact kernels, `wide` uses explicit 8-lane f32 kernels (AVX2+FMA
//! when available), and `fused-int4` additionally attends directly over
//! nibble-packed int4 cache blocks via `kvcache::quant`'s fused kernels.
//! Both sides of every bitwise oracle dispatch identically, so those
//! propchecks hold under any forced path; Wide/FusedInt4 accuracy is
//! instead bounded by the error-bound oracle in `tests/kernels.rs`.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::config::{Method, ModelConfig, VariantSpec};
use crate::kvcache::{quant, CacheShape, KvLayerView, PagedKvCache};
use crate::model::weights::Weights;
use crate::rap::plan::LayerPlan;
use crate::rope::{apply_full, apply_full_tokens};
use crate::tensor::ops::{add_inplace, kernel_threads, rms_norm, silu, softmax_inplace};
use crate::tensor::simd::{
    axpy_path, axpy_rows_path, dot_path, dot_rows_scaled_path, matmul_rows_into_path,
    vecmat_into_path, vecmat_path, KernelPath,
};
use crate::tensor::Tensor;
use crate::util::threadpool::scoped_chunks_indexed;

/// Per-layer KV cache in *latent* widths.  Row-major [Hkv, Smax, width].
#[derive(Debug, Clone)]
pub struct LayerCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub k_width: usize,
    pub v_width: usize,
    pub s_max: usize,
    pub n_kv_heads: usize,
}

impl LayerCache {
    pub fn new(n_kv_heads: usize, s_max: usize, k_width: usize, v_width: usize) -> LayerCache {
        LayerCache {
            k: vec![0.0; n_kv_heads * s_max * k_width],
            v: vec![0.0; n_kv_heads * s_max * v_width],
            k_width,
            v_width,
            s_max,
            n_kv_heads,
        }
    }

    #[inline]
    pub fn k_row(&self, head: usize, s: usize) -> &[f32] {
        let o = (head * self.s_max + s) * self.k_width;
        &self.k[o..o + self.k_width]
    }

    #[inline]
    pub fn k_row_mut(&mut self, head: usize, s: usize) -> &mut [f32] {
        let o = (head * self.s_max + s) * self.k_width;
        &mut self.k[o..o + self.k_width]
    }

    #[inline]
    pub fn v_row(&self, head: usize, s: usize) -> &[f32] {
        let o = (head * self.s_max + s) * self.v_width;
        &self.v[o..o + self.v_width]
    }

    #[inline]
    pub fn v_row_mut(&mut self, head: usize, s: usize) -> &mut [f32] {
        let o = (head * self.s_max + s) * self.v_width;
        &mut self.v[o..o + self.v_width]
    }
}

/// The dense layout is one maximal contiguous run per head, which lets the
/// blocked kernels sweep the whole visible context in a single call.
impl KvLayerView for LayerCache {
    #[inline]
    fn k_row(&self, head: usize, t: usize) -> &[f32] {
        LayerCache::k_row(self, head, t)
    }

    #[inline]
    fn v_row(&self, head: usize, t: usize) -> &[f32] {
        LayerCache::v_row(self, head, t)
    }

    #[inline]
    fn k_row_mut(&mut self, head: usize, t: usize) -> &mut [f32] {
        LayerCache::k_row_mut(self, head, t)
    }

    #[inline]
    fn v_row_mut(&mut self, head: usize, t: usize) -> &mut [f32] {
        LayerCache::v_row_mut(self, head, t)
    }

    fn for_k_runs<F: FnMut(usize, &[f32])>(&self, head: usize, s: usize, mut f: F) {
        if s > 0 {
            let o = head * self.s_max * self.k_width;
            f(0, &self.k[o..o + s * self.k_width]);
        }
    }

    fn for_v_runs<F: FnMut(usize, &[f32])>(&self, head: usize, s: usize, mut f: F) {
        if s > 0 {
            let o = head * self.s_max * self.v_width;
            f(0, &self.v[o..o + s * self.v_width]);
        }
    }

    fn for_k_runs_mut<F: FnMut(usize, &mut [f32])>(&mut self, head: usize, t0: usize, n: usize, mut f: F) {
        if n > 0 {
            let o = (head * self.s_max + t0) * self.k_width;
            f(t0, &mut self.k[o..o + n * self.k_width]);
        }
    }

    fn for_v_runs_mut<F: FnMut(usize, &mut [f32])>(&mut self, head: usize, t0: usize, n: usize, mut f: F) {
        if n > 0 {
            let o = (head * self.s_max + t0) * self.v_width;
            f(t0, &mut self.v[o..o + n * self.v_width]);
        }
    }
}

/// Whole-model cache for one sequence, plus the per-sequence decode
/// workspace that makes repeated `step` calls allocation-free.
#[derive(Debug, Clone)]
pub struct Cache {
    pub layers: Vec<LayerCache>,
    pub len: usize,
    /// Variant cache geometry — the single source of byte accounting,
    /// shared with the allocator (`kvcache::CacheShape`).
    pub shape: CacheShape,
    x: Vec<f32>,
    ws: DecodeWorkspace,
}

impl Cache {
    /// Bytes resident for the *current* length, derived from the same
    /// `CacheShape` the paged allocator bills against — engine-side and
    /// allocator-side accounting cannot diverge.
    pub fn bytes_used(&self) -> usize {
        self.shape.bytes_for_tokens(self.len)
    }
}

struct Layer {
    attn_norm: Tensor,
    mlp_norm: Tensor,
    w_gate: Tensor,
    w_up: Tensor,
    w_down: Tensor,
    attn: AttnKind,
}

#[allow(clippy::large_enum_variant)]
enum AttnKind {
    Baseline {
        wq: Tensor,
        wk: Tensor,
        wv: Tensor,
        wo: Tensor,
    },
    Svd {
        wq: Tensor,
        a_k: Tensor,
        /// per KV head [rk, dh]
        b_k: Vec<Tensor>,
        a_v: Tensor,
        b_v: Vec<Tensor>,
        wo: Tensor,
    },
    Palu {
        wq: Tensor,
        a_k: Tensor,
        b_k: Vec<Tensor>,
        a_v: Tensor,
        wo_t: Tensor,
    },
    Rap {
        wq_t: Tensor,
        a_k: Tensor,
        a_v: Tensor,
        wo_t: Tensor,
        plan: LayerPlan,
    },
}

/// FLOP counter (mul+add = 2, matching the paper's Table 6 convention).
/// Atomic so batched decode workers can share the engine across threads.
#[derive(Debug, Default)]
pub struct Flops(AtomicU64);

impl Flops {
    #[inline]
    fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn take(&self) -> u64 {
        self.0.swap(0, Ordering::Relaxed)
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Reusable per-token scratch: every buffer the decode step needs, sized
/// once for the engine's widest layer and an `s_max` context.  Reusing it
/// is what makes steady-state decode allocation-free.
#[derive(Debug, Clone)]
pub struct DecodeWorkspace {
    /// Normed hidden state (also the logits head's norm scratch).
    h: Vec<f32>,
    /// Raw Q projection output.
    q: Vec<f32>,
    /// Raw latent-K projection output.
    kl: Vec<f32>,
    /// Raw latent-V projection output.
    vl: Vec<f32>,
    /// Rotated per-head Q rows, packed [H, q_width].
    q_rows: Vec<f32>,
    /// Attention scores over the visible context.
    scores: Vec<f32>,
    /// SVD/PaLU reconstructed K, packed [Hkv, s, dh] (empty otherwise).
    recon_k: Vec<f32>,
    /// SVD reconstructed V (empty otherwise).
    recon_v: Vec<f32>,
    /// Per-head context vectors, packed [H, ctx_width] — contiguity makes
    /// this directly consumable by the output projection (no merge copy).
    ctx: Vec<f32>,
    /// d_model-sized projection output (attention out / MLP down).
    o: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    logits: Vec<f32>,
}

impl DecodeWorkspace {
    pub fn new(engine: &Engine, s_max: usize) -> DecodeWorkspace {
        let cfg = &engine.cfg;
        let (h_n, hkv, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.head_dim);
        let max_qw = (0..cfg.n_layers).map(|l| engine.q_width(l)).max().unwrap_or(dh);
        let max_kw = engine.spec.k_rank.iter().copied().max().unwrap_or(dh);
        let max_vw = engine.spec.v_rank.iter().copied().max().unwrap_or(dh);
        let max_cw = (0..cfg.n_layers).map(|l| engine.ctx_width(l)).max().unwrap_or(dh);
        let recon_k_n = if engine.spec.method.reconstructs_k() { hkv * s_max * dh } else { 0 };
        let recon_v_n = if engine.spec.method.reconstructs_v() { hkv * s_max * dh } else { 0 };
        DecodeWorkspace {
            h: vec![0.0; cfg.d_model],
            q: vec![0.0; h_n * max_qw],
            kl: vec![0.0; hkv * max_kw],
            vl: vec![0.0; hkv * max_vw],
            q_rows: vec![0.0; h_n * max_qw],
            scores: vec![0.0; s_max],
            recon_k: vec![0.0; recon_k_n],
            recon_v: vec![0.0; recon_v_n],
            ctx: vec![0.0; h_n * max_cw],
            o: vec![0.0; cfg.d_model],
            gate: vec![0.0; cfg.mlp_hidden],
            up: vec![0.0; cfg.mlp_hidden],
            logits: vec![0.0; cfg.vocab],
        }
    }

    /// Longest context this workspace can attend over.
    pub fn s_max(&self) -> usize {
        self.scores.len()
    }
}

/// Batched-decode scratch: per-session hidden states and logits plus one
/// [`DecodeWorkspace`] per worker thread.  Buffers only ever grow, so once
/// every decode bucket size has been seen the steady state allocates
/// nothing.
pub struct BatchWorkspace {
    s_max: usize,
    d_model: usize,
    vocab: usize,
    /// Hidden states, packed [B, d_model].
    x: Vec<f32>,
    /// Logits, packed [B, vocab].
    logits: Vec<f32>,
    workers: Vec<DecodeWorkspace>,
    /// Per-entry physical cache row for this step (== pos for retain-all
    /// sessions; last row of the compacted table after a retention press).
    rows: Vec<usize>,
    batch_capacity: usize,
}

impl BatchWorkspace {
    pub fn new(engine: &Engine, s_max: usize) -> BatchWorkspace {
        BatchWorkspace {
            s_max,
            d_model: engine.cfg.d_model,
            vocab: engine.cfg.vocab,
            x: Vec::new(),
            logits: Vec::new(),
            workers: Vec::new(),
            rows: Vec::new(),
            batch_capacity: 0,
        }
    }

    pub fn s_max(&self) -> usize {
        self.s_max
    }

    /// Logits of batch entry `i` from the last `decode_batch_paged` call.
    pub fn logits_row(&self, i: usize) -> &[f32] {
        &self.logits[i * self.vocab..(i + 1) * self.vocab]
    }

    fn ensure(&mut self, engine: &Engine, b: usize) {
        let workers = kernel_threads().min(b.max(1));
        while self.workers.len() < workers {
            self.workers.push(DecodeWorkspace::new(engine, self.s_max));
        }
        if b > self.batch_capacity {
            self.x.resize(b * self.d_model, 0.0);
            self.logits.resize(b * self.vocab, 0.0);
            self.rows.reserve(b.saturating_sub(self.rows.capacity()));
            self.batch_capacity = b;
        }
    }
}

/// `*mut T` that scoped workers may share; every use dereferences a
/// worker-exclusive region (same idiom as the matmul kernel's `OutPtr`).
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}

/// Chunk-sized prefill scratch, token-major: every buffer the blocked
/// prefill needs for one prompt chunk, sized for the engine's widest layer.
/// Chunk buffers only ever grow (first call at each chunk size), so
/// steady-state chunked prefill performs zero heap allocations — the same
/// contract as [`DecodeWorkspace`], asserted by `tests/alloc_free.rs`.
pub struct PrefillWorkspace {
    s_max: usize,
    chunk_capacity: usize,
    d_model: usize,
    mlp: usize,
    row_q: usize,
    row_kl: usize,
    row_vl: usize,
    row_ctx: usize,
    /// Chunk hidden states [T, d_model].
    x: Vec<f32>,
    /// Normed hidden states [T, d_model] (and the logits head's scratch).
    h: Vec<f32>,
    /// Rotated Q rows, tight-packed [T, H * q_width(l)].
    q: Vec<f32>,
    /// Latent K rows, tight-packed [T, Hkv * k_width(l)].
    kl: Vec<f32>,
    /// Latent V rows, tight-packed [T, Hkv * v_width(l)].
    vl: Vec<f32>,
    /// Per-head context vectors, tight-packed [T, H * ctx_width(l)].
    ctx: Vec<f32>,
    /// d_model-sized projection outputs [T, d_model].
    o: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    /// SVD/PaLU reconstructed K over the whole visible context,
    /// [Hkv, s_end, dh] — built once per (layer, chunk) and shared by every
    /// query row, instead of once per token as the token loop does.
    recon_k: Vec<f32>,
    recon_v: Vec<f32>,
    /// Per-worker score rows, [kernel_threads(), s_max].
    scores: Vec<f32>,
    /// Final-token logits (filled when the chunk closes the prompt).
    logits: Vec<f32>,
    /// Per-row logits [T, vocab] (filled by `verify_chunk_paged`; grows
    /// on first use so plain prefill never pays for it).
    verify_logits: Vec<f32>,
    vocab: usize,
}

impl PrefillWorkspace {
    pub fn new(engine: &Engine, s_max: usize) -> PrefillWorkspace {
        let cfg = &engine.cfg;
        let (h_n, hkv, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.head_dim);
        let max_qw = (0..cfg.n_layers).map(|l| engine.q_width(l)).max().unwrap_or(dh);
        let max_kw = engine.spec.k_rank.iter().copied().max().unwrap_or(dh);
        let max_vw = engine.spec.v_rank.iter().copied().max().unwrap_or(dh);
        let max_cw = (0..cfg.n_layers).map(|l| engine.ctx_width(l)).max().unwrap_or(dh);
        let recon_k_n = if engine.spec.method.reconstructs_k() { hkv * s_max * dh } else { 0 };
        let recon_v_n = if engine.spec.method.reconstructs_v() { hkv * s_max * dh } else { 0 };
        PrefillWorkspace {
            s_max,
            chunk_capacity: 0,
            d_model: cfg.d_model,
            mlp: cfg.mlp_hidden,
            row_q: h_n * max_qw,
            row_kl: hkv * max_kw,
            row_vl: hkv * max_vw,
            row_ctx: h_n * max_cw,
            x: Vec::new(),
            h: Vec::new(),
            q: Vec::new(),
            kl: Vec::new(),
            vl: Vec::new(),
            ctx: Vec::new(),
            o: Vec::new(),
            gate: Vec::new(),
            up: Vec::new(),
            recon_k: vec![0.0; recon_k_n],
            recon_v: vec![0.0; recon_v_n],
            scores: vec![0.0; kernel_threads() * s_max],
            logits: vec![0.0; cfg.vocab],
            verify_logits: Vec::new(),
            vocab: cfg.vocab,
        }
    }

    /// Longest context this workspace can attend over.
    pub fn s_max(&self) -> usize {
        self.s_max
    }

    /// Logits of the prompt's final token, valid after the chunk that was
    /// run with `want_logits`.
    pub fn logits(&self) -> &[f32] {
        &self.logits
    }

    /// Logits of verify row `i`, valid after `verify_chunk_paged` ran a
    /// chunk of more than `i` tokens.
    pub fn verify_logits_row(&self, i: usize) -> &[f32] {
        &self.verify_logits[i * self.vocab..(i + 1) * self.vocab]
    }

    fn ensure(&mut self, n: usize) {
        if n > self.chunk_capacity {
            self.x.resize(n * self.d_model, 0.0);
            self.h.resize(n * self.d_model, 0.0);
            self.o.resize(n * self.d_model, 0.0);
            self.q.resize(n * self.row_q, 0.0);
            self.kl.resize(n * self.row_kl, 0.0);
            self.vl.resize(n * self.row_vl, 0.0);
            self.ctx.resize(n * self.row_ctx, 0.0);
            self.gate.resize(n * self.mlp, 0.0);
            self.up.resize(n * self.mlp, 0.0);
            self.chunk_capacity = n;
        }
    }
}

pub struct Engine {
    pub cfg: ModelConfig,
    pub spec: VariantSpec,
    tok_emb: Tensor,
    final_norm: Tensor,
    layers: Vec<Layer>,
    pub flops: Flops,
    /// Kernel implementations every matmul/dot/axpy call site routes
    /// through — hot paths AND the preserved reference oracles, so the
    /// existing bitwise propchecks compare like against like under any
    /// forced path.  Defaults from `RAP_KERNEL_PATH` (scalar when unset).
    kernel_path: KernelPath,
}

fn split_heads(b_k: &Tensor, n_heads: usize) -> Vec<Tensor> {
    // manifest shape [H, r, dh] -> H tensors [r, dh]
    assert_eq!(b_k.rank(), 3);
    let (h, r, dh) = (b_k.shape[0], b_k.shape[1], b_k.shape[2]);
    assert_eq!(h, n_heads);
    (0..h)
        .map(|i| {
            Tensor::new(
                vec![r, dh],
                b_k.data[i * r * dh..(i + 1) * r * dh].to_vec(),
            )
        })
        .collect()
}

impl Engine {
    pub fn new(cfg: ModelConfig, spec: VariantSpec, w: &Weights) -> Result<Engine> {
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let attn = match spec.method {
                Method::Baseline => AttnKind::Baseline {
                    wq: w.layer(l, "wq").clone(),
                    wk: w.layer(l, "wk").clone(),
                    wv: w.layer(l, "wv").clone(),
                    wo: w.layer(l, "wo").clone(),
                },
                Method::Svd => AttnKind::Svd {
                    wq: w.layer(l, "wq").clone(),
                    a_k: w.layer(l, "a_k").clone(),
                    b_k: split_heads(w.layer(l, "b_k"), cfg.n_kv_heads),
                    a_v: w.layer(l, "a_v").clone(),
                    b_v: split_heads(w.layer(l, "b_v"), cfg.n_kv_heads),
                    wo: w.layer(l, "wo").clone(),
                },
                Method::Palu => AttnKind::Palu {
                    wq: w.layer(l, "wq").clone(),
                    a_k: w.layer(l, "a_k").clone(),
                    b_k: split_heads(w.layer(l, "b_k"), cfg.n_kv_heads),
                    a_v: w.layer(l, "a_v").clone(),
                    wo_t: w.layer(l, "wo_t").clone(),
                },
                Method::Rap => {
                    if spec.k_pairs.len() != cfg.n_layers {
                        bail!("rap spec missing k_pairs for layer {l}");
                    }
                    AttnKind::Rap {
                        wq_t: w.layer(l, "wq_t").clone(),
                        a_k: w.layer(l, "a_k").clone(),
                        a_v: w.layer(l, "a_v").clone(),
                        wo_t: w.layer(l, "wo_t").clone(),
                        plan: LayerPlan::new(&cfg, spec.k_pairs[l].clone()),
                    }
                }
            };
            layers.push(Layer {
                attn_norm: w.layer(l, "attn_norm").clone(),
                mlp_norm: w.layer(l, "mlp_norm").clone(),
                w_gate: w.layer(l, "w_gate").clone(),
                w_up: w.layer(l, "w_up").clone(),
                w_down: w.layer(l, "w_down").clone(),
                attn,
            });
        }
        Ok(Engine {
            tok_emb: w.get("tok_emb").clone(),
            final_norm: w.get("final_norm").clone(),
            layers,
            cfg,
            spec,
            flops: Flops::default(),
            kernel_path: KernelPath::from_env(),
        })
    }

    /// The kernel path all engine arithmetic is dispatched through.
    pub fn kernel_path(&self) -> KernelPath {
        self.kernel_path
    }

    /// Force a kernel path (tests and the serving `BackendConfig`;
    /// production engines inherit `RAP_KERNEL_PATH` at construction).
    pub fn set_kernel_path(&mut self, path: KernelPath) {
        self.kernel_path = path;
    }

    /// Width of one rotated Q row at layer `l` (latent for RAP, full head
    /// dimension otherwise).
    pub fn q_width(&self, l: usize) -> usize {
        match self.spec.method {
            Method::Rap => self.spec.k_rank[l],
            _ => self.cfg.head_dim,
        }
    }

    /// Width of one per-head context vector at layer `l` (latent when V is
    /// consumed through the absorbed W_o).
    pub fn ctx_width(&self, l: usize) -> usize {
        match self.spec.method {
            Method::Baseline | Method::Svd => self.cfg.head_dim,
            Method::Palu | Method::Rap => self.spec.v_rank[l],
        }
    }

    pub fn new_cache(&self, s_max: usize) -> Cache {
        let shape = CacheShape::of(&self.cfg, &self.spec);
        Cache {
            layers: (0..self.cfg.n_layers)
                .map(|l| {
                    LayerCache::new(shape.n_kv_heads, s_max, shape.k_width[l], shape.v_width[l])
                })
                .collect(),
            len: 0,
            x: vec![0.0; self.cfg.d_model],
            ws: DecodeWorkspace::new(self, s_max),
            shape,
        }
    }

    #[inline]
    fn vecmat_counted_into(&self, x: &[f32], w: &Tensor, out: &mut [f32]) {
        let (k, n) = w.dims2();
        self.flops.add(2 * (k * n) as u64);
        vecmat_into_path(self.kernel_path, x, w, out);
    }

    fn embed_into(&self, token: u8, x: &mut [f32]) {
        let d = self.cfg.d_model;
        x.copy_from_slice(&self.tok_emb.data[token as usize * d..(token as usize + 1) * d]);
    }

    fn logits_into(&self, x: &[f32], h: &mut [f32], logits: &mut [f32]) {
        let d = self.cfg.d_model;
        let v = self.cfg.vocab;
        rms_norm(x, &self.final_norm.data, self.cfg.norm_eps, h);
        // tied embedding head: logits = h @ tok_emb^T
        self.flops.add(2 * (d * v) as u64);
        for t in 0..v {
            logits[t] = dot_path(self.kernel_path, h, &self.tok_emb.data[t * d..(t + 1) * d]);
        }
    }

    /// Project ONE token's normed hidden state into the cacheable K/V rows
    /// at physical row `row` (written through `kv`) and the rotated Q rows
    /// (`q_rows`, packed [H, q_width(l)]).  RoPE rotates at the *logical*
    /// position `pos`; for identity (retain-all) sessions `row == pos` and
    /// this is exactly the seed arithmetic.
    #[allow(clippy::too_many_arguments)]
    fn project_into<L: KvLayerView>(
        &self,
        l: usize,
        layer: &Layer,
        h: &[f32],
        row: usize,
        pos: usize,
        kv: &mut L,
        q: &mut [f32],
        kl: &mut [f32],
        vl: &mut [f32],
        q_rows: &mut [f32],
    ) {
        let cfg = &self.cfg;
        let dh = cfg.head_dim;
        match &layer.attn {
            AttnKind::Baseline { wq, wk, wv, .. } => {
                let q = &mut q[..cfg.n_heads * dh];
                let kl = &mut kl[..cfg.n_kv_heads * dh];
                let vl = &mut vl[..cfg.n_kv_heads * dh];
                self.vecmat_counted_into(h, wq, q);
                self.vecmat_counted_into(h, wk, kl);
                self.vecmat_counted_into(h, wv, vl);
                for hd in 0..cfg.n_kv_heads {
                    let krow = &mut kl[hd * dh..(hd + 1) * dh];
                    apply_full(krow, pos, cfg.pairing, cfg.rope_theta);
                    kv.write_k_row(hd, row, krow);
                    kv.write_v_row(hd, row, &vl[hd * dh..(hd + 1) * dh]);
                }
                q_rows.copy_from_slice(q);
                for hq in 0..cfg.n_heads {
                    apply_full(
                        &mut q_rows[hq * dh..(hq + 1) * dh],
                        pos,
                        cfg.pairing,
                        cfg.rope_theta,
                    );
                }
            }
            AttnKind::Svd { wq, a_k, a_v, .. } | AttnKind::Palu { wq, a_k, a_v, .. } => {
                // Pre-RoPE latents cached; Q full-rope'd.
                let (kw, vw) = (self.spec.k_rank[l], self.spec.v_rank[l]);
                let q = &mut q[..cfg.n_heads * dh];
                let kl = &mut kl[..cfg.n_kv_heads * kw];
                let vl = &mut vl[..cfg.n_kv_heads * vw];
                self.vecmat_counted_into(h, wq, q);
                self.vecmat_counted_into(h, a_k, kl);
                self.vecmat_counted_into(h, a_v, vl);
                for hd in 0..cfg.n_kv_heads {
                    kv.write_k_row(hd, row, &kl[hd * kw..(hd + 1) * kw]);
                    kv.write_v_row(hd, row, &vl[hd * vw..(hd + 1) * vw]);
                }
                q_rows.copy_from_slice(q);
                for hq in 0..cfg.n_heads {
                    apply_full(
                        &mut q_rows[hq * dh..(hq + 1) * dh],
                        pos,
                        cfg.pairing,
                        cfg.rope_theta,
                    );
                }
            }
            AttnKind::Rap {
                wq_t, a_k, a_v, plan, ..
            } => {
                let (kw, vw) = (self.spec.k_rank[l], self.spec.v_rank[l]);
                let q = &mut q[..cfg.n_heads * kw];
                let kl = &mut kl[..cfg.n_kv_heads * kw];
                let vl = &mut vl[..cfg.n_kv_heads * vw];
                self.vecmat_counted_into(h, wq_t, q);
                self.vecmat_counted_into(h, a_k, kl);
                self.vecmat_counted_into(h, a_v, vl);
                for hd in 0..cfg.n_kv_heads {
                    let krow = &mut kl[hd * kw..(hd + 1) * kw];
                    // Index-aware RoPE directly on the latent — the fused
                    // hot path (no reconstruction, no gather).
                    plan.k_table.apply_fused(hd, krow, pos);
                    kv.write_k_row(hd, row, krow);
                    kv.write_v_row(hd, row, &vl[hd * vw..(hd + 1) * vw]);
                }
                q_rows.copy_from_slice(q);
                for hq in 0..cfg.n_heads {
                    plan.q_table
                        .apply_fused(hq, &mut q_rows[hq * kw..(hq + 1) * kw], pos);
                }
            }
        }
    }

    /// Attention for ONE query token over cache rows `[0, s)`, writing the
    /// per-head context vectors into `ctx` (packed [H, ctx_width(l)]).
    /// `s` is the visible *row* count (for identity sessions, `pos + 1`).
    /// Scores sweep the cache run-by-run through the blocked kernels —
    /// identical arithmetic for dense and paged layouts.  Post-softmax
    /// mass is fed to the view's score accounting (a no-op unless the
    /// session tracks scores for the `AttnScore` retention press).
    #[allow(clippy::too_many_arguments)]
    fn attend_into<L: KvLayerView>(
        &self,
        l: usize,
        layer: &Layer,
        s: usize,
        kv: &L,
        q_rows: &[f32],
        scores: &mut [f32],
        recon_k: &mut [f32],
        recon_v: &mut [f32],
        ctx: &mut [f32],
    ) {
        let cfg = &self.cfg;
        let dh = cfg.head_dim;
        let group = cfg.group_size();
        let scale = 1.0 / (dh as f32).sqrt();
        let qw = q_rows.len() / cfg.n_heads;
        let cw = ctx.len() / cfg.n_heads;
        let (kw, vw) = (self.spec.k_rank[l], self.spec.v_rank[l]);

        // Reconstruction step for factorization methods (paper Fig. 1):
        // K (and V for SVD) are expanded to full dimension for the whole
        // visible context, every call.
        let (use_rk, use_rv) = match &layer.attn {
            AttnKind::Svd { b_k, b_v, .. } => {
                self.reconstruct_into(kv, b_k, true, s, recon_k);
                self.reconstruct_into(kv, b_v, false, s, recon_v);
                (true, true)
            }
            AttnKind::Palu { b_k, .. } => {
                self.reconstruct_into(kv, b_k, true, s, recon_k);
                (true, false)
            }
            _ => (false, false),
        };

        // Packed-int4 caches dequantize in-register inside the fused q4
        // kernels; the f32 rows are never materialized.
        let packed = kv.packed_q4();
        let (krb, vrb) = if packed {
            (quant::row_bytes(kw), quant::row_bytes(vw))
        } else {
            (0, 0)
        };

        for hq in 0..cfg.n_heads {
            let hk = hq / group;
            let q = &q_rows[hq * qw..(hq + 1) * qw];
            if use_rk {
                dot_rows_scaled_path(
                    self.kernel_path,
                    q,
                    &recon_k[hk * s * dh..(hk + 1) * s * dh],
                    dh,
                    scale,
                    &mut scores[..s],
                );
                self.flops.add(2 * (s * dh) as u64);
            } else if packed {
                kv.for_k_runs_q4(hk, s, |t0, rows| {
                    let n = rows.len() / krb;
                    quant::dot_rows_scaled_q4(q, rows, kw, scale, &mut scores[t0..t0 + n]);
                });
                self.flops.add(2 * (s * kw) as u64);
            } else {
                kv.for_k_runs(hk, s, |t0, rows| {
                    let n = rows.len() / kw;
                    dot_rows_scaled_path(
                        self.kernel_path,
                        q,
                        rows,
                        kw,
                        scale,
                        &mut scores[t0..t0 + n],
                    );
                });
                self.flops.add(2 * (s * kw) as u64);
            }
            softmax_inplace(&mut scores[..s]);
            kv.score_accum(s, &scores[..s]);
            let c = &mut ctx[hq * cw..(hq + 1) * cw];
            c.fill(0.0);
            if use_rv {
                axpy_rows_path(
                    self.kernel_path,
                    &scores[..s],
                    &recon_v[hk * s * dh..(hk + 1) * s * dh],
                    dh,
                    c,
                );
            } else if packed {
                kv.for_v_runs_q4(hk, s, |t0, rows| {
                    let n = rows.len() / vrb;
                    quant::axpy_rows_q4(&scores[t0..t0 + n], rows, vw, c);
                });
            } else {
                kv.for_v_runs(hk, s, |t0, rows| {
                    let n = rows.len() / vw;
                    axpy_rows_path(self.kernel_path, &scores[t0..t0 + n], rows, vw, c);
                });
            }
            self.flops.add(2 * (s * cw) as u64);
        }
    }

    /// Expand the latent cache rows [0, s) of every KV head through the
    /// per-head reconstruction matrices ([w, dh] each) into `out`, packed
    /// [Hkv, s, dh].  Counted as FLOPs — this is exactly the overhead
    /// Table 2 attributes to SVD/PaLU.
    fn reconstruct_into<L: KvLayerView>(
        &self,
        kv: &L,
        b: &[Tensor],
        is_k: bool,
        s: usize,
        out: &mut [f32],
    ) {
        let dh = self.cfg.head_dim;
        for hd in 0..self.cfg.n_kv_heads {
            let bw = &b[hd];
            let (w, _) = bw.dims2();
            let rows = &mut out[hd * s * dh..(hd + 1) * s * dh];
            for t in 0..s {
                let lat = if is_k { kv.k_row(hd, t) } else { kv.v_row(hd, t) };
                let dst = &mut rows[t * dh..(t + 1) * dh];
                dst.fill(0.0);
                for (p, &lv) in lat.iter().enumerate().take(w) {
                    if lv != 0.0 {
                        axpy_path(self.kernel_path, lv, bw.row(p), dst);
                    }
                }
            }
            self.flops.add(2 * (s * w * dh) as u64);
            if is_k {
                // RoPE the reconstructed K at its token positions — the
                // view's logical positions, which for identity sessions
                // are the row indices themselves.
                for t in 0..s {
                    apply_full(
                        &mut rows[t * dh..(t + 1) * dh],
                        kv.row_pos(t),
                        self.cfg.pairing,
                        self.cfg.rope_theta,
                    );
                }
            }
        }
    }

    /// One full transformer layer for one token: attention (through `kv`)
    /// plus MLP, accumulated into the hidden state `x`.  `row` is the
    /// physical cache row the token's K/V lands in; `pos` its logical RoPE
    /// position (`row == pos` for dense caches and retain-all sessions).
    #[allow(clippy::too_many_arguments)]
    fn layer_forward<L: KvLayerView>(
        &self,
        l: usize,
        layer: &Layer,
        x: &mut [f32],
        row: usize,
        pos: usize,
        kv: &mut L,
        ws: &mut DecodeWorkspace,
    ) {
        let cfg = &self.cfg;
        let DecodeWorkspace {
            h,
            q,
            kl,
            vl,
            q_rows,
            scores,
            recon_k,
            recon_v,
            ctx,
            o,
            gate,
            up,
            ..
        } = ws;
        let qw = self.q_width(l);
        let cw = self.ctx_width(l);

        rms_norm(x, &layer.attn_norm.data, cfg.norm_eps, h);
        self.project_into(l, layer, h, row, pos, kv, q, kl, vl, &mut q_rows[..cfg.n_heads * qw]);
        self.attend_into(
            l,
            layer,
            row + 1,
            kv,
            &q_rows[..cfg.n_heads * qw],
            scores,
            recon_k,
            recon_v,
            &mut ctx[..cfg.n_heads * cw],
        );
        let wo = match &layer.attn {
            AttnKind::Baseline { wo, .. } | AttnKind::Svd { wo, .. } => wo,
            AttnKind::Palu { wo_t, .. } | AttnKind::Rap { wo_t, .. } => wo_t,
        };
        self.vecmat_counted_into(&ctx[..cfg.n_heads * cw], wo, o);
        add_inplace(x, o);

        rms_norm(x, &layer.mlp_norm.data, cfg.norm_eps, h);
        self.vecmat_counted_into(h, &layer.w_gate, gate);
        self.vecmat_counted_into(h, &layer.w_up, up);
        for (gv, uv) in gate.iter_mut().zip(up.iter()) {
            *gv = silu(*gv) * *uv;
        }
        self.vecmat_counted_into(gate, &layer.w_down, o);
        add_inplace(x, o);
    }

    fn step_inner<'c>(
        &self,
        token: u8,
        pos: usize,
        cache: &'c mut Cache,
        want_logits: bool,
    ) -> &'c [f32] {
        assert!(pos < cache.layers[0].s_max, "cache overflow at pos {pos}");
        let Cache { layers, len, x, ws, .. } = cache;
        self.embed_into(token, x);
        for (l, layer) in self.layers.iter().enumerate() {
            self.layer_forward(l, layer, x, pos, pos, &mut layers[l], ws);
        }
        *len = (*len).max(pos + 1);
        let DecodeWorkspace { h, logits, .. } = ws;
        if want_logits {
            self.logits_into(x, h, logits);
        }
        logits
    }

    /// Process one token at `pos` given cache filled for [0, pos); updates
    /// the cache and returns the logits as a borrow of the cache's
    /// workspace — the allocation-free form of [`Engine::step`].
    pub fn step_reuse<'c>(&self, token: u8, pos: usize, cache: &'c mut Cache) -> &'c [f32] {
        self.step_inner(token, pos, cache, true)
    }

    /// Process one token at `pos`; returns owned logits (compatibility
    /// wrapper over [`Engine::step_reuse`]).
    pub fn step(&self, token: u8, pos: usize, cache: &mut Cache) -> Vec<f32> {
        self.step_reuse(token, pos, cache).to_vec()
    }

    /// One decode step for a batch of `(session, token, pos)` entries
    /// against the storage-backed paged KV-cache, layer-major: all sessions
    /// advance through layer 0, then layer 1, … so each layer's weights are
    /// touched once per step regardless of batch size.  Sessions are split
    /// across `kernel_threads()` scoped workers (their blocks are disjoint
    /// by construction).
    ///
    /// Zero heap allocations in steady state: scratch lives in `batch`,
    /// which only grows the first time a batch size is seen.  Logits land
    /// in `batch` (read via [`BatchWorkspace::logits_row`]) and are only
    /// computed when `compute_logits` — prefill loops skip the head for all
    /// but the final token.
    ///
    /// Every session must already hold a reservation covering `pos`
    /// (`PagedKvCache::ensure_tokens`; the coordinator reserves a request's
    /// full budget at admission).
    pub fn decode_batch_paged(
        &self,
        entries: &[(u64, u8, usize)],
        kv: &mut PagedKvCache,
        batch: &mut BatchWorkspace,
        compute_logits: bool,
    ) -> Result<()> {
        let b = entries.len();
        if b == 0 {
            return Ok(());
        }
        if kv.storage_mode().is_packed()
            && (self.spec.method.reconstructs_k() || self.spec.method.reconstructs_v())
        {
            bail!(
                "packed-int4 KV storage cannot back {:?}: reconstruction reads f32 latent rows",
                self.spec.method
            );
        }
        batch.ensure(self, b);
        batch.rows.clear();
        for (i, &(sid, _, pos)) in entries.iter().enumerate() {
            if pos >= batch.s_max {
                bail!("session {sid}: pos {pos} exceeds workspace s_max {}", batch.s_max);
            }
            // The token's physical cache row: its position for identity
            // (retain-all) sessions — the seed invariant — or the tail of
            // the compacted table for a pressed session, whose last
            // surviving row must be the previous logical position.
            let row = match kv.row_positions(sid) {
                None => {
                    if kv.session_tokens(sid) <= pos {
                        bail!(
                            "session {sid}: pos {pos} beyond its {}-token reservation",
                            kv.session_tokens(sid)
                        );
                    }
                    pos
                }
                Some(pv) => {
                    let rows = pv.len();
                    if rows == 0 || pv[rows - 1] as usize != pos {
                        bail!(
                            "session {sid}: decode pos {pos} does not extend its retained \
                             rows (last resident position {:?})",
                            pv.last()
                        );
                    }
                    rows - 1
                }
            };
            batch.rows.push(row);
            // A duplicated session id would give two workers overlapping
            // views of the same blocks — reject it before any write.
            if entries[..i].iter().any(|&(other, _, _)| other == sid) {
                bail!("session {sid} appears twice in one decode batch");
            }
        }
        let d = self.cfg.d_model;
        let (pages, store) = kv.tables_and_ptrs()?;
        for (i, &(_, token, _)) in entries.iter().enumerate() {
            self.embed_into(token, &mut batch.x[i * d..(i + 1) * d]);
        }
        let threads = kernel_threads().min(b);
        let ws_ptr = SendPtr(batch.workers.as_mut_ptr());
        let x_ptr = SendPtr(batch.x.as_mut_ptr());
        let entry_rows: &[usize] = &batch.rows;
        for (l, layer) in self.layers.iter().enumerate() {
            scoped_chunks_indexed(b, threads, |widx, range| {
                // SAFETY: each worker owns a unique workspace index and a
                // disjoint range of batch entries; sessions write disjoint
                // cache blocks (a written block has refcount 1 — prefix
                // blocks shared across sessions are read-only), so no two
                // workers write the same memory.
                let ws = unsafe { &mut *ws_ptr.0.add(widx) };
                for bi in range {
                    let (sid, _, pos) = entries[bi];
                    let x = unsafe { std::slice::from_raw_parts_mut(x_ptr.0.add(bi * d), d) };
                    // SAFETY: session ids are unique within `entries`
                    // (checked above), so this worker holds the only view
                    // that *writes* this session's blocks; concurrent
                    // views may read its shared prefix blocks.
                    let sv = pages.view(sid).unwrap();
                    let mut view = unsafe { store.session_layer(l, &sv) };
                    self.layer_forward(l, layer, x, entry_rows[bi], pos, &mut view, ws);
                }
            });
        }
        if compute_logits {
            let v = self.cfg.vocab;
            let lg_ptr = SendPtr(batch.logits.as_mut_ptr());
            scoped_chunks_indexed(b, threads, |widx, range| {
                // SAFETY: as above — disjoint entries and workspaces.
                let ws = unsafe { &mut *ws_ptr.0.add(widx) };
                for bi in range {
                    let x = unsafe { std::slice::from_raw_parts(x_ptr.0.add(bi * d), d) };
                    let logits =
                        unsafe { std::slice::from_raw_parts_mut(lg_ptr.0.add(bi * v), v) };
                    self.logits_into(x, &mut ws.h, logits);
                }
            });
        }
        Ok(())
    }

    /// One full transformer layer for a whole prompt chunk, token-major:
    /// per-layer projections run as one GEMM over the chunk
    /// (`matmul_rows_into`, per-row arithmetic identical to the token
    /// loop's `vecmat_into`), RoPE rotates the chunk in place, the chunk's
    /// latent K/V rows land in the cache run-by-run, and causal attention
    /// fans query rows across `scoped_chunks_indexed` workers using the
    /// same blocked `dot_rows_scaled`/`axpy_rows` kernels as decode — so
    /// the blocked path is **bit-identical** to token-by-token prefill
    /// (asserted in `tests/prefill.rs`).
    ///
    /// For SVD/PaLU the reconstruction of the visible context is built once
    /// per (layer, chunk) and shared by every query row — each row's
    /// reconstruction arithmetic is position-independent, so this too is
    /// bit-identical to the token loop's per-token rebuilds while removing
    /// their O(T²) reconstruction cost.
    fn prefill_chunk_layer<L: KvLayerView + Sync>(
        &self,
        l: usize,
        layer: &Layer,
        n: usize,
        pos0: usize,
        kv: &mut L,
        ws: &mut PrefillWorkspace,
        quantize_kv: bool,
    ) {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let dh = cfg.head_dim;
        let hkv = cfg.n_kv_heads;
        let h_n = cfg.n_heads;
        let qw = self.q_width(l);
        let cw = self.ctx_width(l);
        let (kw, vw) = (self.spec.k_rank[l], self.spec.v_rank[l]);
        let threads = kernel_threads().min(n);
        let PrefillWorkspace {
            x,
            h,
            q,
            kl,
            vl,
            ctx,
            o,
            gate,
            up,
            recon_k,
            recon_v,
            scores,
            s_max,
            ..
        } = ws;

        // Attention norm, per token row.
        for (xi, hi) in x[..n * d].chunks_exact(d).zip(h[..n * d].chunks_exact_mut(d)) {
            rms_norm(xi, &layer.attn_norm.data, cfg.norm_eps, hi);
        }

        // Q/K/V projections: one GEMM per weight for the whole chunk, then
        // RoPE over the chunk in place (same per-row rotation the token
        // loop applies after copying each row into the cache).
        // `pos0` is the chunk's first *row*.  Retain-all sessions are the
        // identity map (row == logical position) and take the seed
        // chunk-RoPE fast path bit-for-bit; a pressed session rotates each
        // row at its preserved logical position instead.
        let gapped = kv.has_positions();
        match &layer.attn {
            AttnKind::Baseline { wq, wk, wv, .. } => {
                self.gemm_counted(&h[..n * d], wq, &mut q[..n * h_n * dh], threads);
                self.gemm_counted(&h[..n * d], wk, &mut kl[..n * hkv * dh], threads);
                self.gemm_counted(&h[..n * d], wv, &mut vl[..n * hkv * dh], threads);
                if gapped {
                    for i in 0..n {
                        let p = kv.row_pos(pos0 + i);
                        for hq in 0..h_n {
                            let r = &mut q[(i * h_n + hq) * dh..(i * h_n + hq + 1) * dh];
                            apply_full(r, p, cfg.pairing, cfg.rope_theta);
                        }
                        for hd in 0..hkv {
                            let r = &mut kl[(i * hkv + hd) * dh..(i * hkv + hd + 1) * dh];
                            apply_full(r, p, cfg.pairing, cfg.rope_theta);
                        }
                    }
                } else {
                    apply_full_tokens(&mut q[..n * h_n * dh], h_n, dh, pos0, cfg.pairing, cfg.rope_theta);
                    apply_full_tokens(&mut kl[..n * hkv * dh], hkv, dh, pos0, cfg.pairing, cfg.rope_theta);
                }
            }
            AttnKind::Svd { wq, a_k, a_v, .. } | AttnKind::Palu { wq, a_k, a_v, .. } => {
                self.gemm_counted(&h[..n * d], wq, &mut q[..n * h_n * dh], threads);
                self.gemm_counted(&h[..n * d], a_k, &mut kl[..n * hkv * kw], threads);
                self.gemm_counted(&h[..n * d], a_v, &mut vl[..n * hkv * vw], threads);
                // Pre-RoPE latents cached; only Q rotates.
                if gapped {
                    for i in 0..n {
                        let p = kv.row_pos(pos0 + i);
                        for hq in 0..h_n {
                            let r = &mut q[(i * h_n + hq) * dh..(i * h_n + hq + 1) * dh];
                            apply_full(r, p, cfg.pairing, cfg.rope_theta);
                        }
                    }
                } else {
                    apply_full_tokens(&mut q[..n * h_n * dh], h_n, dh, pos0, cfg.pairing, cfg.rope_theta);
                }
            }
            AttnKind::Rap {
                wq_t, a_k, a_v, plan, ..
            } => {
                self.gemm_counted(&h[..n * d], wq_t, &mut q[..n * h_n * kw], threads);
                self.gemm_counted(&h[..n * d], a_k, &mut kl[..n * hkv * kw], threads);
                self.gemm_counted(&h[..n * d], a_v, &mut vl[..n * hkv * vw], threads);
                // Index-aware RoPE on the latent chunk — the fused hot path.
                if gapped {
                    for i in 0..n {
                        let p = kv.row_pos(pos0 + i);
                        for hq in 0..h_n {
                            plan.q_table.apply_fused(hq, &mut q[(i * h_n + hq) * kw..(i * h_n + hq + 1) * kw], p);
                        }
                        for hd in 0..hkv {
                            plan.k_table.apply_fused(hd, &mut kl[(i * hkv + hd) * kw..(i * hkv + hd + 1) * kw], p);
                        }
                    }
                } else {
                    plan.q_table.apply_fused_chunk(&mut q[..n * h_n * kw], h_n, pos0);
                    plan.k_table.apply_fused_chunk(&mut kl[..n * hkv * kw], hkv, pos0);
                }
            }
        }

        // Write the chunk's K/V rows into the cache in one pass per head
        // (run-by-run through the page table for the paged layout).  Packed
        // caches quantize each row into its nibble-packed slot on write.
        if kv.packed_q4() {
            for hd in 0..hkv {
                for i in 0..n {
                    kv.write_k_row(hd, pos0 + i, &kl[(i * hkv + hd) * kw..(i * hkv + hd + 1) * kw]);
                    kv.write_v_row(hd, pos0 + i, &vl[(i * hkv + hd) * vw..(i * hkv + hd + 1) * vw]);
                }
            }
        } else {
            for hd in 0..hkv {
                kv.for_k_runs_mut(hd, pos0, n, |t0, rows| {
                    for (j, dst) in rows.chunks_exact_mut(kw).enumerate() {
                        let i = t0 - pos0 + j;
                        dst.copy_from_slice(&kl[(i * hkv + hd) * kw..(i * hkv + hd + 1) * kw]);
                    }
                });
                kv.for_v_runs_mut(hd, pos0, n, |t0, rows| {
                    for (j, dst) in rows.chunks_exact_mut(vw).enumerate() {
                        let i = t0 - pos0 + j;
                        dst.copy_from_slice(&vl[(i * hkv + hd) * vw..(i * hkv + hd + 1) * vw]);
                    }
                });
            }
        }

        // Quantized-KV mode: int4 round-trip the freshly written rows
        // run-by-run, BEFORE any attention (or reconstruction) reads them.
        // Every query row then sees only round-tripped K/V — including the
        // rows of its own chunk — so prefill numerics are invariant to the
        // chunk partition (each row's round-trip depends on that row
        // alone, never on where a chunk boundary fell).  Packed storage
        // already quantized on write, so the round-trip would be a no-op.
        if quantize_kv && !kv.packed_q4() {
            for hd in 0..hkv {
                kv.for_k_runs_mut(hd, pos0, n, |_, rows| {
                    for row in rows.chunks_exact_mut(kw) {
                        quant::roundtrip(row);
                    }
                });
                kv.for_v_runs_mut(hd, pos0, n, |_, rows| {
                    for row in rows.chunks_exact_mut(vw) {
                        quant::roundtrip(row);
                    }
                });
            }
        }

        // Reconstruction for the factorization baselines: once per chunk,
        // covering the whole visible context [0, pos0 + n).
        let s_end = pos0 + n;
        let (use_rk, use_rv) = match &layer.attn {
            AttnKind::Svd { b_k, b_v, .. } => {
                self.reconstruct_into(&*kv, b_k, true, s_end, recon_k);
                self.reconstruct_into(&*kv, b_v, false, s_end, recon_v);
                (true, true)
            }
            AttnKind::Palu { b_k, .. } => {
                self.reconstruct_into(&*kv, b_k, true, s_end, recon_k);
                (true, false)
            }
            _ => (false, false),
        };

        // Causal attention, one query row per chunk token, fanned across
        // workers.  All chunk K/V rows are already written, and row t only
        // reads rows [0, t] — the same visible set as the token loop.
        let group = cfg.group_size();
        let scale = 1.0 / (dh as f32).sqrt();
        let kv_r: &L = kv;
        let packed = kv_r.packed_q4();
        let (krb, vrb) = if packed {
            (quant::row_bytes(kw), quant::row_bytes(vw))
        } else {
            (0, 0)
        };
        let q_r: &[f32] = &q[..n * h_n * qw];
        let recon_k_r: &[f32] = recon_k;
        let recon_v_r: &[f32] = recon_v;
        let s_cap = *s_max;
        let ctx_ptr = SendPtr(ctx.as_mut_ptr());
        let scores_ptr = SendPtr(scores.as_mut_ptr());
        scoped_chunks_indexed(n, threads, |widx, range| {
            // SAFETY: each worker owns a unique score row (by worker index)
            // and disjoint ctx rows (by token index); K/V and the
            // reconstruction are only read.
            let sc = unsafe { std::slice::from_raw_parts_mut(scores_ptr.0.add(widx * s_cap), s_cap) };
            for i in range {
                // Row-space: query row pos0 + i attends rows [0, pos0 + i]
                // (for identity sessions this is exactly pos + 1).
                let s = pos0 + i + 1;
                let ctx_i =
                    unsafe { std::slice::from_raw_parts_mut(ctx_ptr.0.add(i * h_n * cw), h_n * cw) };
                for hq in 0..h_n {
                    let hk = hq / group;
                    let qrow = &q_r[(i * h_n + hq) * qw..(i * h_n + hq + 1) * qw];
                    if use_rk {
                        dot_rows_scaled_path(
                            self.kernel_path,
                            qrow,
                            &recon_k_r[hk * s_end * dh..hk * s_end * dh + s * dh],
                            dh,
                            scale,
                            &mut sc[..s],
                        );
                        self.flops.add(2 * (s * dh) as u64);
                    } else if packed {
                        kv_r.for_k_runs_q4(hk, s, |t0, rows| {
                            let m = rows.len() / krb;
                            quant::dot_rows_scaled_q4(qrow, rows, kw, scale, &mut sc[t0..t0 + m]);
                        });
                        self.flops.add(2 * (s * kw) as u64);
                    } else {
                        kv_r.for_k_runs(hk, s, |t0, rows| {
                            let m = rows.len() / kw;
                            dot_rows_scaled_path(
                                self.kernel_path,
                                qrow,
                                rows,
                                kw,
                                scale,
                                &mut sc[t0..t0 + m],
                            );
                        });
                        self.flops.add(2 * (s * kw) as u64);
                    }
                    softmax_inplace(&mut sc[..s]);
                    let c = &mut ctx_i[hq * cw..(hq + 1) * cw];
                    c.fill(0.0);
                    if use_rv {
                        axpy_rows_path(
                            self.kernel_path,
                            &sc[..s],
                            &recon_v_r[hk * s_end * dh..hk * s_end * dh + s * dh],
                            dh,
                            c,
                        );
                    } else if packed {
                        kv_r.for_v_runs_q4(hk, s, |t0, rows| {
                            let m = rows.len() / vrb;
                            quant::axpy_rows_q4(&sc[t0..t0 + m], rows, vw, c);
                        });
                    } else {
                        kv_r.for_v_runs(hk, s, |t0, rows| {
                            let m = rows.len() / vw;
                            axpy_rows_path(self.kernel_path, &sc[t0..t0 + m], rows, vw, c);
                        });
                    }
                    self.flops.add(2 * (s * cw) as u64);
                }
            }
        });

        // Output projection + residual, then the MLP — all chunk GEMMs.
        let wo = match &layer.attn {
            AttnKind::Baseline { wo, .. } | AttnKind::Svd { wo, .. } => wo,
            AttnKind::Palu { wo_t, .. } | AttnKind::Rap { wo_t, .. } => wo_t,
        };
        self.gemm_counted(&ctx[..n * h_n * cw], wo, &mut o[..n * d], threads);
        add_inplace(&mut x[..n * d], &o[..n * d]);

        for (xi, hi) in x[..n * d].chunks_exact(d).zip(h[..n * d].chunks_exact_mut(d)) {
            rms_norm(xi, &layer.mlp_norm.data, cfg.norm_eps, hi);
        }
        let mlp = cfg.mlp_hidden;
        self.gemm_counted(&h[..n * d], &layer.w_gate, &mut gate[..n * mlp], threads);
        self.gemm_counted(&h[..n * d], &layer.w_up, &mut up[..n * mlp], threads);
        for (gv, uv) in gate[..n * mlp].iter_mut().zip(up[..n * mlp].iter()) {
            *gv = silu(*gv) * *uv;
        }
        self.gemm_counted(&gate[..n * mlp], &layer.w_down, &mut o[..n * d], threads);
        add_inplace(&mut x[..n * d], &o[..n * d]);
    }

    /// FLOP-counted chunk GEMM (rows = chunk tokens).
    #[inline]
    fn gemm_counted(&self, a: &[f32], w: &Tensor, out: &mut [f32], threads: usize) {
        let (k, nn) = w.dims2();
        self.flops.add(2 * ((a.len() / k) * k * nn) as u64);
        matmul_rows_into_path(self.kernel_path, a, w, out, threads);
    }

    /// Blocked prefill of `tokens` at positions `[pos0, pos0 + len)` over a
    /// dense per-sequence cache, layer-major (weights touched once per
    /// chunk).  `want_logits` computes the vocabulary head for the chunk's
    /// final token into the workspace ([`PrefillWorkspace::logits`]).
    pub fn prefill_chunk_dense(
        &self,
        tokens: &[u8],
        pos0: usize,
        cache: &mut Cache,
        ws: &mut PrefillWorkspace,
        want_logits: bool,
    ) {
        let n = tokens.len();
        if n == 0 {
            return;
        }
        assert!(pos0 + n <= cache.layers[0].s_max, "cache overflow at {}", pos0 + n);
        assert!(pos0 + n <= ws.s_max, "workspace overflow at {}", pos0 + n);
        ws.ensure(n);
        let d = self.cfg.d_model;
        for (i, &t) in tokens.iter().enumerate() {
            self.embed_into(t, &mut ws.x[i * d..(i + 1) * d]);
        }
        for (l, layer) in self.layers.iter().enumerate() {
            self.prefill_chunk_layer(l, layer, n, pos0, &mut cache.layers[l], ws, false);
        }
        cache.len = cache.len.max(pos0 + n);
        if want_logits {
            let PrefillWorkspace { x, h, logits, .. } = ws;
            self.logits_into(&x[(n - 1) * d..n * d], &mut h[..d], logits);
        }
    }

    /// Blocked prefill of one prompt chunk through the storage-backed paged
    /// KV-cache — the serving path behind `Backend::prefill_chunk`.  The
    /// session's reservation must already cover `pos0 + tokens.len()` (the
    /// coordinator reserves a request's full budget at admission).  Zero
    /// heap allocations once `ws` has seen the chunk size — including under
    /// `quantize_kv`, whose int4 round-trips run in place.
    ///
    /// With `quantize_kv` the chunk's latent rows are round-tripped
    /// through int4 immediately after they are written and before any
    /// attention reads them, so quantized prefill logits do not depend on
    /// the chunk partition (`tests/prefill.rs` propchecks this).
    pub fn prefill_chunk_paged(
        &self,
        session: u64,
        tokens: &[u8],
        pos0: usize,
        kv: &mut PagedKvCache,
        ws: &mut PrefillWorkspace,
        want_logits: bool,
        quantize_kv: bool,
    ) -> Result<()> {
        let n = tokens.len();
        if n == 0 {
            return Ok(());
        }
        if kv.storage_mode().is_packed()
            && (self.spec.method.reconstructs_k() || self.spec.method.reconstructs_v())
        {
            bail!(
                "packed-int4 KV storage cannot back {:?}: reconstruction reads f32 latent rows",
                self.spec.method
            );
        }
        if pos0 + n > ws.s_max {
            bail!("session {session}: chunk end {} exceeds workspace s_max {}", pos0 + n, ws.s_max);
        }
        if kv.session_tokens(session) < pos0 + n {
            bail!(
                "session {session}: chunk end {} beyond its {}-token reservation",
                pos0 + n,
                kv.session_tokens(session)
            );
        }
        ws.ensure(n);
        let d = self.cfg.d_model;
        for (i, &t) in tokens.iter().enumerate() {
            self.embed_into(t, &mut ws.x[i * d..(i + 1) * d]);
        }
        let (pages, store) = kv.tables_and_ptrs()?;
        let sv = pages
            .view(session)
            .ok_or_else(|| anyhow::anyhow!("session {session} has no page table"))?;
        for (l, layer) in self.layers.iter().enumerate() {
            // SAFETY: one live view per session; the chunk's attention
            // workers only share it read-only after its writes complete.
            let mut view = unsafe { store.session_layer(l, &sv) };
            self.prefill_chunk_layer(l, layer, n, pos0, &mut view, ws, quantize_kv);
        }
        if want_logits {
            let PrefillWorkspace { x, h, logits, .. } = ws;
            self.logits_into(&x[(n - 1) * d..n * d], &mut h[..d], logits);
        }
        Ok(())
    }

    /// Speculative verification: feed `tokens` (the session's last
    /// emitted token followed by its draft) at rows `[row0, row0 + len)`
    /// through the blocked chunk kernel, writing their KV rows exactly as
    /// [`Engine::prefill_chunk_paged`] would, but computing the
    /// vocabulary head for **every** row — `len` next-token distributions
    /// in one block-parallel call instead of `len` sequential decode
    /// steps.  Row `i`'s logits condition on the stream through
    /// `tokens[i]`; read them back with
    /// [`PrefillWorkspace::verify_logits_row`].
    ///
    /// Per-row arithmetic is the chunk kernel's, which is bit-identical
    /// to token-by-token decode (`tests/prefill.rs` pins this), so with
    /// f32 KV storage — or packed-int4 storage, which quantizes rows on
    /// write in both paths — the verify logits equal sequential decode's
    /// bit for bit.  The `quantize_kv` *round-trip* over f32 storage is
    /// the one exception (prefill rounds a row before its own attention
    /// reads it, decode after), which is why `RustBackend::verify_chunk`
    /// falls back to the sequential loop in that mode.
    pub fn verify_chunk_paged(
        &self,
        session: u64,
        tokens: &[u8],
        row0: usize,
        kv: &mut PagedKvCache,
        ws: &mut PrefillWorkspace,
        quantize_kv: bool,
    ) -> Result<()> {
        let n = tokens.len();
        if n == 0 {
            return Ok(());
        }
        if kv.storage_mode().is_packed()
            && (self.spec.method.reconstructs_k() || self.spec.method.reconstructs_v())
        {
            bail!(
                "packed-int4 KV storage cannot back {:?}: reconstruction reads f32 latent rows",
                self.spec.method
            );
        }
        if row0 + n > ws.s_max {
            bail!("session {session}: verify end {} exceeds workspace s_max {}", row0 + n, ws.s_max);
        }
        if kv.session_tokens(session) < row0 + n {
            bail!(
                "session {session}: verify end {} beyond its {}-token reservation",
                row0 + n,
                kv.session_tokens(session)
            );
        }
        ws.ensure(n);
        if ws.verify_logits.len() < n * ws.vocab {
            ws.verify_logits.resize(n * ws.vocab, 0.0);
        }
        let d = self.cfg.d_model;
        for (i, &t) in tokens.iter().enumerate() {
            self.embed_into(t, &mut ws.x[i * d..(i + 1) * d]);
        }
        let (pages, store) = kv.tables_and_ptrs()?;
        let sv = pages
            .view(session)
            .ok_or_else(|| anyhow::anyhow!("session {session} has no page table"))?;
        for (l, layer) in self.layers.iter().enumerate() {
            // SAFETY: one live view per session; the chunk's attention
            // workers only share it read-only after its writes complete.
            let mut view = unsafe { store.session_layer(l, &sv) };
            self.prefill_chunk_layer(l, layer, n, row0, &mut view, ws, quantize_kv);
        }
        let PrefillWorkspace { x, h, verify_logits, vocab, .. } = ws;
        for i in 0..n {
            self.logits_into(
                &x[i * d..(i + 1) * d],
                &mut h[..d],
                &mut verify_logits[i * *vocab..(i + 1) * *vocab],
            );
        }
        Ok(())
    }

    /// Blocked prefill of a whole prompt over a dense cache in chunks of
    /// `chunk` tokens; the final chunk fills [`PrefillWorkspace::logits`].
    pub fn prefill_chunked(
        &self,
        tokens: &[u8],
        chunk: usize,
        cache: &mut Cache,
        ws: &mut PrefillWorkspace,
    ) {
        let chunk = chunk.max(1);
        let mut pos0 = 0;
        while pos0 < tokens.len() {
            let end = (pos0 + chunk).min(tokens.len());
            self.prefill_chunk_dense(&tokens[pos0..end], pos0, cache, ws, end == tokens.len());
            pos0 = end;
        }
    }

    /// Default chunk length for blocked prefill: long enough to amortise
    /// the per-chunk GEMM setup, short enough that the chunk scratch stays
    /// cache-resident.
    pub const PREFILL_CHUNK: usize = 64;

    /// Prefill a prompt, returning logits at the last position.  Runs the
    /// block-parallel chunked path; only the final token pays for the
    /// vocabulary head.  Returns an empty vector for an empty prompt (no
    /// position to compute logits at).
    ///
    /// Convenience form: allocates a fresh [`PrefillWorkspace`] per call
    /// (small next to the `Cache` such callers also build per prompt).
    /// Hot paths that prefill repeatedly should hold a workspace and call
    /// [`Engine::prefill_chunked`] / [`Engine::prefill_chunk_paged`]
    /// directly, as the serving backend and benches do.
    pub fn prefill(&self, tokens: &[u8], cache: &mut Cache) -> Vec<f32> {
        if tokens.is_empty() {
            return Vec::new();
        }
        let mut ws = PrefillWorkspace::new(self, cache.layers[0].s_max);
        self.prefill_chunked(tokens, Self::PREFILL_CHUNK, cache, &mut ws);
        ws.logits().to_vec()
    }

    /// The original token-by-token prefill (T sequential `step_inner`
    /// calls) — the oracle the blocked path is tested against bitwise
    /// (`tests/prefill.rs`) and the baseline `benches/attention_latency.rs`
    /// measures blocked-prefill speedups over in `BENCH_prefill.json`.
    pub fn prefill_token_loop(&self, tokens: &[u8], cache: &mut Cache) -> Vec<f32> {
        let Some((&last, rest)) = tokens.split_last() else {
            return Vec::new();
        };
        for (i, &t) in rest.iter().enumerate() {
            self.step_inner(t, i, cache, false);
        }
        self.step_inner(last, tokens.len() - 1, cache, true).to_vec()
    }

    /// Mean negative log-likelihood of `targets` given `tokens` (teacher
    /// forcing), batch-1 full-sequence evaluation.
    pub fn nll(&self, tokens: &[u8], targets: &[u8], s_max: usize) -> f64 {
        assert_eq!(tokens.len(), targets.len());
        let mut cache = self.new_cache(s_max.max(tokens.len()));
        let mut total = 0.0f64;
        for (i, (&t, &y)) in tokens.iter().zip(targets.iter()).enumerate() {
            let logits = self.step_reuse(t, i, &mut cache);
            // log-softmax at the target
            let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = logits.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
            total += (lse - logits[y as usize]) as f64;
        }
        total / tokens.len() as f64
    }

    /// Greedy-decode `n` tokens after a prompt; returns generated bytes.
    /// An empty prompt yields no output: `prefill` computes no logits then,
    /// and argmaxing untouched workspace memory would emit a garbage first
    /// token.
    pub fn generate(&self, prompt: &[u8], n: usize, s_max: usize) -> Vec<u8> {
        let mut cache = self.new_cache(s_max);
        let logits = self.prefill(prompt, &mut cache);
        if logits.is_empty() {
            return Vec::new();
        }
        let mut next = argmax(&logits) as u8;
        let mut out = Vec::with_capacity(n);
        let mut pos = prompt.len();
        for _ in 0..n {
            out.push(next);
            if pos >= s_max {
                break;
            }
            next = argmax(self.step_reuse(next, pos, &mut cache)) as u8;
            pos += 1;
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Seed reference path.
//
// The original (pre-workspace) decode, preserved verbatim: per-token heap
// allocations for every projection/score/context buffer and per-row `dot`
// calls over the dense cache.  It serves two purposes:
//   * the oracle for the workspace refactor — `step` must match it bitwise
//     (asserted in `tests/paged.rs`);
//   * the measured baseline in `benches/decode_latency.rs`, whose speedup
//     ratio is recorded in BENCH_decode.json.
// ---------------------------------------------------------------------------

impl Engine {
    #[inline]
    fn vecmat_counted(&self, x: &[f32], w: &Tensor) -> Vec<f32> {
        let (k, n) = w.dims2();
        self.flops.add(2 * (k * n) as u64);
        vecmat_path(self.kernel_path, x, w)
    }

    fn project_token_ref(
        &self,
        layer: &Layer,
        h: &[f32],
        pos: usize,
        cache: &mut LayerCache,
    ) -> Vec<Vec<f32>> {
        let cfg = &self.cfg;
        let dh = cfg.head_dim;
        match &layer.attn {
            AttnKind::Baseline { wq, wk, wv, .. } => {
                let q = self.vecmat_counted(h, wq);
                let k = self.vecmat_counted(h, wk);
                let v = self.vecmat_counted(h, wv);
                for hd in 0..cfg.n_kv_heads {
                    let krow = cache.k_row_mut(hd, pos);
                    krow.copy_from_slice(&k[hd * dh..(hd + 1) * dh]);
                    apply_full(krow, pos, cfg.pairing, cfg.rope_theta);
                    cache
                        .v_row_mut(hd, pos)
                        .copy_from_slice(&v[hd * dh..(hd + 1) * dh]);
                }
                (0..cfg.n_heads)
                    .map(|hq| {
                        let mut row = q[hq * dh..(hq + 1) * dh].to_vec();
                        apply_full(&mut row, pos, cfg.pairing, cfg.rope_theta);
                        row
                    })
                    .collect()
            }
            AttnKind::Svd { wq, a_k, a_v, .. } | AttnKind::Palu { wq, a_k, a_v, .. } => {
                let q = self.vecmat_counted(h, wq);
                let kl = self.vecmat_counted(h, a_k);
                let vl = self.vecmat_counted(h, a_v);
                let (kw, vw) = (cache.k_width, cache.v_width);
                for hd in 0..cfg.n_kv_heads {
                    cache
                        .k_row_mut(hd, pos)
                        .copy_from_slice(&kl[hd * kw..(hd + 1) * kw]);
                    cache
                        .v_row_mut(hd, pos)
                        .copy_from_slice(&vl[hd * vw..(hd + 1) * vw]);
                }
                (0..cfg.n_heads)
                    .map(|hq| {
                        let mut row = q[hq * dh..(hq + 1) * dh].to_vec();
                        apply_full(&mut row, pos, cfg.pairing, cfg.rope_theta);
                        row
                    })
                    .collect()
            }
            AttnKind::Rap {
                wq_t, a_k, a_v, plan, ..
            } => {
                let q = self.vecmat_counted(h, wq_t);
                let kl = self.vecmat_counted(h, a_k);
                let vl = self.vecmat_counted(h, a_v);
                let (kw, vw) = (cache.k_width, cache.v_width);
                for hd in 0..cfg.n_kv_heads {
                    let krow = cache.k_row_mut(hd, pos);
                    krow.copy_from_slice(&kl[hd * kw..(hd + 1) * kw]);
                    plan.k_table.apply_fused(hd, krow, pos);
                    cache
                        .v_row_mut(hd, pos)
                        .copy_from_slice(&vl[hd * vw..(hd + 1) * vw]);
                }
                (0..cfg.n_heads)
                    .map(|hq| {
                        let mut row = q[hq * kw..(hq + 1) * kw].to_vec();
                        plan.q_table.apply_fused(hq, &mut row, pos);
                        row
                    })
                    .collect()
            }
        }
    }

    fn reconstruct_ref(
        &self,
        cache: &LayerCache,
        b: &[Tensor],
        is_k: bool,
        s: usize,
    ) -> Vec<Vec<f32>> {
        let dh = self.cfg.head_dim;
        let mut out = Vec::with_capacity(self.cfg.n_kv_heads);
        for hd in 0..self.cfg.n_kv_heads {
            let bw = &b[hd];
            let (w, _) = bw.dims2();
            let mut rows = vec![0.0f32; s * dh];
            for t in 0..s {
                let lat = if is_k { cache.k_row(hd, t) } else { cache.v_row(hd, t) };
                let dst = &mut rows[t * dh..(t + 1) * dh];
                for (p, &lv) in lat.iter().enumerate().take(w) {
                    if lv != 0.0 {
                        axpy_path(self.kernel_path, lv, bw.row(p), dst);
                    }
                }
            }
            self.flops.add(2 * (s * w * dh) as u64);
            if is_k {
                for t in 0..s {
                    apply_full(
                        &mut rows[t * dh..(t + 1) * dh],
                        t,
                        self.cfg.pairing,
                        self.cfg.rope_theta,
                    );
                }
            }
            out.push(rows);
        }
        out
    }

    fn attend_ref(
        &self,
        layer: &Layer,
        q_rows: &[Vec<f32>],
        cache: &LayerCache,
        ctx_end: usize,
    ) -> Vec<Vec<f32>> {
        let cfg = &self.cfg;
        let dh = cfg.head_dim;
        let group = cfg.group_size();
        let scale = 1.0 / (dh as f32).sqrt();
        let s = ctx_end + 1;

        let (recon_k, recon_v): (Option<Vec<Vec<f32>>>, Option<Vec<Vec<f32>>>) =
            match &layer.attn {
                AttnKind::Svd { b_k, b_v, .. } => (
                    Some(self.reconstruct_ref(cache, b_k, true, s)),
                    Some(self.reconstruct_ref(cache, b_v, false, s)),
                ),
                AttnKind::Palu { b_k, .. } => {
                    (Some(self.reconstruct_ref(cache, b_k, true, s)), None)
                }
                _ => (None, None),
            };

        let mut out = Vec::with_capacity(cfg.n_heads);
        let mut scores = vec![0.0f32; s];
        for hq in 0..cfg.n_heads {
            let hk = hq / group;
            let q = &q_rows[hq];
            match &recon_k {
                Some(k_full) => {
                    let krows = &k_full[hk];
                    for t in 0..s {
                        scores[t] =
                            dot_path(self.kernel_path, q, &krows[t * dh..(t + 1) * dh]) * scale;
                    }
                    self.flops.add(2 * (s * dh) as u64);
                }
                None => {
                    let w = cache.k_width;
                    for t in 0..s {
                        scores[t] = dot_path(self.kernel_path, q, cache.k_row(hk, t)) * scale;
                    }
                    self.flops.add(2 * (s * w) as u64);
                }
            }
            softmax_inplace(&mut scores[..s]);
            let vw_eff = match &layer.attn {
                AttnKind::Svd { .. } | AttnKind::Baseline { .. } => dh,
                _ => cache.v_width,
            };
            let mut ctx = vec![0.0f32; vw_eff];
            match &recon_v {
                Some(v_full) => {
                    let vrows = &v_full[hk];
                    for t in 0..s {
                        axpy_path(
                            self.kernel_path,
                            scores[t],
                            &vrows[t * dh..(t + 1) * dh],
                            &mut ctx,
                        );
                    }
                }
                None => {
                    for t in 0..s {
                        axpy_path(self.kernel_path, scores[t], cache.v_row(hk, t), &mut ctx);
                    }
                }
            }
            self.flops.add(2 * (s * vw_eff) as u64);
            out.push(ctx);
        }
        out
    }

    fn mlp_inplace_ref(&self, layer: &Layer, x: &mut [f32]) {
        let d = self.cfg.d_model;
        let mut h = vec![0.0f32; d];
        rms_norm(x, &layer.mlp_norm.data, self.cfg.norm_eps, &mut h);
        let mut g = self.vecmat_counted(&h, &layer.w_gate);
        let u = self.vecmat_counted(&h, &layer.w_up);
        for (gv, uv) in g.iter_mut().zip(&u) {
            *gv = silu(*gv) * uv;
        }
        let down = self.vecmat_counted(&g, &layer.w_down);
        add_inplace(x, &down);
    }

    /// The seed's decode step, allocation behaviour and all.  See the
    /// section comment above.
    pub fn step_alloc_reference(&self, token: u8, pos: usize, cache: &mut Cache) -> Vec<f32> {
        assert!(pos < cache.layers[0].s_max, "cache overflow at pos {pos}");
        let d = self.cfg.d_model;
        let mut x = self.tok_emb.data[token as usize * d..(token as usize + 1) * d].to_vec();
        let mut h = vec![0.0f32; d];
        for (l, layer) in self.layers.iter().enumerate() {
            rms_norm(&x, &layer.attn_norm.data, self.cfg.norm_eps, &mut h);
            let lc = &mut cache.layers[l];
            let q_rows = self.project_token_ref(layer, &h, pos, lc);
            let ctx = self.attend_ref(layer, &q_rows, lc, pos);
            let merged: Vec<f32> = ctx.iter().flatten().copied().collect();
            let wo = match &layer.attn {
                AttnKind::Baseline { wo, .. } | AttnKind::Svd { wo, .. } => wo,
                AttnKind::Palu { wo_t, .. } | AttnKind::Rap { wo_t, .. } => wo_t,
            };
            let o = self.vecmat_counted(&merged, wo);
            add_inplace(&mut x, &o);
            self.mlp_inplace_ref(layer, &mut x);
        }
        cache.len = cache.len.max(pos + 1);
        let mut hn = vec![0.0f32; d];
        rms_norm(&x, &self.final_norm.data, self.cfg.norm_eps, &mut hn);
        let v = self.cfg.vocab;
        self.flops.add(2 * (d * v) as u64);
        let mut logits = vec![0.0f32; v];
        for t in 0..v {
            logits[t] = dot_path(self.kernel_path, &hn, &self.tok_emb.data[t * d..(t + 1) * d]);
        }
        logits
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[1.0, 5.0, 3.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn layer_cache_rows_disjoint() {
        let mut c = LayerCache::new(2, 4, 3, 5);
        c.k_row_mut(0, 1).copy_from_slice(&[1.0, 2.0, 3.0]);
        c.k_row_mut(1, 1).copy_from_slice(&[9.0, 9.0, 9.0]);
        assert_eq!(c.k_row(0, 1), &[1.0, 2.0, 3.0]);
        assert_eq!(c.k_row(0, 0), &[0.0, 0.0, 0.0]);
        assert_eq!(c.v_row(1, 3).len(), 5);
    }

    #[test]
    fn layer_cache_runs_match_rows() {
        let mut c = LayerCache::new(2, 8, 3, 2);
        for t in 0..6 {
            c.k_row_mut(1, t)[0] = t as f32;
            c.v_row_mut(1, t)[1] = -(t as f32);
        }
        let mut calls = 0;
        KvLayerView::for_k_runs(&c, 1, 6, |t0, rows| {
            calls += 1;
            assert_eq!(t0, 0);
            assert_eq!(rows.len(), 6 * 3);
            for (i, chunk) in rows.chunks_exact(3).enumerate() {
                assert_eq!(chunk[0], i as f32);
            }
        });
        assert_eq!(calls, 1, "dense layout yields one maximal run");
        KvLayerView::for_v_runs(&c, 1, 6, |_, rows| {
            for (i, chunk) in rows.chunks_exact(2).enumerate() {
                assert_eq!(chunk[1], -(i as f32));
            }
        });
    }

    // Engine integration tests (vs manifest weights, PJRT, and the paged
    // batched-decode identity suite) live in rust/tests/.
}
