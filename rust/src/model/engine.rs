//! Pure-Rust inference engine for every compression variant.
//!
//! The engine mirrors `python/compile/model.py` operation-for-operation and
//! is cross-validated against PJRT executions of the exported HLO in the
//! integration tests.  It powers the evaluation experiments (PPL, probe
//! tasks, long-context suite), dense latency sweeps, and the measured-FLOPs
//! harness (every matmul is routed through a FLOP counter).
//!
//! Method semantics (paper Figure 1 / §4.3):
//! * baseline — full K (post-RoPE) and V cached.
//! * svd      — pre-RoPE latent K and latent V cached; **both reconstructed
//!              every attention call** (the overhead RAP removes).
//! * palu     — latent K reconstructed; latent V consumed directly through
//!              the absorbed W_o.
//! * rap      — index-aware-RoPE'd latent K and latent V consumed directly:
//!              attention runs entirely at latent widths.

use std::cell::Cell;

use anyhow::{bail, Result};

use crate::config::{Method, ModelConfig, VariantSpec};
use crate::model::weights::Weights;
use crate::rap::plan::LayerPlan;
use crate::rope::apply_full;
use crate::tensor::ops::{add_inplace, dot, rms_norm, silu, softmax_inplace, vecmat};
use crate::tensor::Tensor;

/// Per-layer KV cache in *latent* widths.  Row-major [Hkv, Smax, width].
#[derive(Debug, Clone)]
pub struct LayerCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub k_width: usize,
    pub v_width: usize,
    pub s_max: usize,
    pub n_kv_heads: usize,
}

impl LayerCache {
    pub fn new(n_kv_heads: usize, s_max: usize, k_width: usize, v_width: usize) -> LayerCache {
        LayerCache {
            k: vec![0.0; n_kv_heads * s_max * k_width],
            v: vec![0.0; n_kv_heads * s_max * v_width],
            k_width,
            v_width,
            s_max,
            n_kv_heads,
        }
    }

    #[inline]
    pub fn k_row(&self, head: usize, s: usize) -> &[f32] {
        let o = (head * self.s_max + s) * self.k_width;
        &self.k[o..o + self.k_width]
    }

    #[inline]
    pub fn k_row_mut(&mut self, head: usize, s: usize) -> &mut [f32] {
        let o = (head * self.s_max + s) * self.k_width;
        &mut self.k[o..o + self.k_width]
    }

    #[inline]
    pub fn v_row(&self, head: usize, s: usize) -> &[f32] {
        let o = (head * self.s_max + s) * self.v_width;
        &self.v[o..o + self.v_width]
    }

    #[inline]
    pub fn v_row_mut(&mut self, head: usize, s: usize) -> &mut [f32] {
        let o = (head * self.s_max + s) * self.v_width;
        &mut self.v[o..o + self.v_width]
    }

    pub fn bytes(&self) -> usize {
        4 * (self.k.len() + self.v.len())
    }
}

/// Whole-model cache for one sequence.
#[derive(Debug, Clone)]
pub struct Cache {
    pub layers: Vec<LayerCache>,
    pub len: usize,
}

impl Cache {
    pub fn bytes_used(&self) -> usize {
        // Bytes that would be resident for the *current* length.
        self.layers
            .iter()
            .map(|l| 4 * self.len * l.n_kv_heads * (l.k_width + l.v_width))
            .sum()
    }
}

struct Layer {
    attn_norm: Tensor,
    mlp_norm: Tensor,
    w_gate: Tensor,
    w_up: Tensor,
    w_down: Tensor,
    attn: AttnKind,
}

#[allow(clippy::large_enum_variant)]
enum AttnKind {
    Baseline {
        wq: Tensor,
        wk: Tensor,
        wv: Tensor,
        wo: Tensor,
    },
    Svd {
        wq: Tensor,
        a_k: Tensor,
        /// per KV head [rk, dh]
        b_k: Vec<Tensor>,
        a_v: Tensor,
        b_v: Vec<Tensor>,
        wo: Tensor,
    },
    Palu {
        wq: Tensor,
        a_k: Tensor,
        b_k: Vec<Tensor>,
        a_v: Tensor,
        wo_t: Tensor,
    },
    Rap {
        wq_t: Tensor,
        a_k: Tensor,
        a_v: Tensor,
        wo_t: Tensor,
        plan: LayerPlan,
    },
}

/// FLOP counter (mul+add = 2, matching the paper's Table 6 convention).
#[derive(Debug, Default)]
pub struct Flops(Cell<u64>);

impl Flops {
    #[inline]
    fn add(&self, n: u64) {
        self.0.set(self.0.get() + n);
    }

    pub fn take(&self) -> u64 {
        let v = self.0.get();
        self.0.set(0);
        v
    }

    pub fn get(&self) -> u64 {
        self.0.get()
    }
}

pub struct Engine {
    pub cfg: ModelConfig,
    pub spec: VariantSpec,
    tok_emb: Tensor,
    final_norm: Tensor,
    layers: Vec<Layer>,
    pub flops: Flops,
}

fn split_heads(b_k: &Tensor, n_heads: usize) -> Vec<Tensor> {
    // manifest shape [H, r, dh] -> H tensors [r, dh]
    assert_eq!(b_k.rank(), 3);
    let (h, r, dh) = (b_k.shape[0], b_k.shape[1], b_k.shape[2]);
    assert_eq!(h, n_heads);
    (0..h)
        .map(|i| {
            Tensor::new(
                vec![r, dh],
                b_k.data[i * r * dh..(i + 1) * r * dh].to_vec(),
            )
        })
        .collect()
}

impl Engine {
    pub fn new(cfg: ModelConfig, spec: VariantSpec, w: &Weights) -> Result<Engine> {
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let attn = match spec.method {
                Method::Baseline => AttnKind::Baseline {
                    wq: w.layer(l, "wq").clone(),
                    wk: w.layer(l, "wk").clone(),
                    wv: w.layer(l, "wv").clone(),
                    wo: w.layer(l, "wo").clone(),
                },
                Method::Svd => AttnKind::Svd {
                    wq: w.layer(l, "wq").clone(),
                    a_k: w.layer(l, "a_k").clone(),
                    b_k: split_heads(w.layer(l, "b_k"), cfg.n_kv_heads),
                    a_v: w.layer(l, "a_v").clone(),
                    b_v: split_heads(w.layer(l, "b_v"), cfg.n_kv_heads),
                    wo: w.layer(l, "wo").clone(),
                },
                Method::Palu => AttnKind::Palu {
                    wq: w.layer(l, "wq").clone(),
                    a_k: w.layer(l, "a_k").clone(),
                    b_k: split_heads(w.layer(l, "b_k"), cfg.n_kv_heads),
                    a_v: w.layer(l, "a_v").clone(),
                    wo_t: w.layer(l, "wo_t").clone(),
                },
                Method::Rap => {
                    if spec.k_pairs.len() != cfg.n_layers {
                        bail!("rap spec missing k_pairs for layer {l}");
                    }
                    AttnKind::Rap {
                        wq_t: w.layer(l, "wq_t").clone(),
                        a_k: w.layer(l, "a_k").clone(),
                        a_v: w.layer(l, "a_v").clone(),
                        wo_t: w.layer(l, "wo_t").clone(),
                        plan: LayerPlan::new(&cfg, spec.k_pairs[l].clone()),
                    }
                }
            };
            layers.push(Layer {
                attn_norm: w.layer(l, "attn_norm").clone(),
                mlp_norm: w.layer(l, "mlp_norm").clone(),
                w_gate: w.layer(l, "w_gate").clone(),
                w_up: w.layer(l, "w_up").clone(),
                w_down: w.layer(l, "w_down").clone(),
                attn,
            });
        }
        Ok(Engine {
            tok_emb: w.get("tok_emb").clone(),
            final_norm: w.get("final_norm").clone(),
            layers,
            cfg,
            spec,
            flops: Flops::default(),
        })
    }

    pub fn new_cache(&self, s_max: usize) -> Cache {
        Cache {
            layers: (0..self.cfg.n_layers)
                .map(|l| {
                    LayerCache::new(
                        self.cfg.n_kv_heads,
                        s_max,
                        self.spec.k_rank[l],
                        self.spec.v_rank[l],
                    )
                })
                .collect(),
            len: 0,
        }
    }

    #[inline]
    fn vecmat_counted(&self, x: &[f32], w: &Tensor) -> Vec<f32> {
        let (k, n) = w.dims2();
        self.flops.add(2 * (k * n) as u64);
        vecmat(x, w)
    }

    fn embed(&self, token: u8) -> Vec<f32> {
        let d = self.cfg.d_model;
        self.tok_emb.data[token as usize * d..(token as usize + 1) * d].to_vec()
    }

    fn logits_from_hidden(&self, x: &[f32]) -> Vec<f32> {
        let d = self.cfg.d_model;
        let v = self.cfg.vocab;
        let mut h = vec![0.0f32; d];
        rms_norm(x, &self.final_norm.data, self.cfg.norm_eps, &mut h);
        // tied embedding head: logits = h @ tok_emb^T
        self.flops.add(2 * (d * v) as u64);
        let mut logits = vec![0.0f32; v];
        for t in 0..v {
            logits[t] = dot(&h, &self.tok_emb.data[t * d..(t + 1) * d]);
        }
        logits
    }

    fn mlp_inplace(&self, layer: &Layer, x: &mut [f32]) {
        let d = self.cfg.d_model;
        let mut h = vec![0.0f32; d];
        rms_norm(x, &layer.mlp_norm.data, self.cfg.norm_eps, &mut h);
        let mut g = self.vecmat_counted(&h, &layer.w_gate);
        let u = self.vecmat_counted(&h, &layer.w_up);
        for (gv, uv) in g.iter_mut().zip(&u) {
            *gv = silu(*gv) * uv;
        }
        let down = self.vecmat_counted(&g, &layer.w_down);
        add_inplace(x, &down);
    }

    /// Project the normed hidden state of ONE token at `pos` into the
    /// cacheable K/V rows for layer `l`, and return the rotated Q rows
    /// [H][q_width].  Writes the K/V rows into the cache at `pos`.
    fn project_token(
        &self,
        layer: &Layer,
        h: &[f32],
        pos: usize,
        cache: &mut LayerCache,
    ) -> Vec<Vec<f32>> {
        let cfg = &self.cfg;
        let dh = cfg.head_dim;
        match &layer.attn {
            AttnKind::Baseline { wq, wk, wv, .. } => {
                let q = self.vecmat_counted(h, wq);
                let k = self.vecmat_counted(h, wk);
                let v = self.vecmat_counted(h, wv);
                for hd in 0..cfg.n_kv_heads {
                    let krow = cache.k_row_mut(hd, pos);
                    krow.copy_from_slice(&k[hd * dh..(hd + 1) * dh]);
                    apply_full(krow, pos, cfg.pairing, cfg.rope_theta);
                    cache
                        .v_row_mut(hd, pos)
                        .copy_from_slice(&v[hd * dh..(hd + 1) * dh]);
                }
                (0..cfg.n_heads)
                    .map(|hq| {
                        let mut row = q[hq * dh..(hq + 1) * dh].to_vec();
                        apply_full(&mut row, pos, cfg.pairing, cfg.rope_theta);
                        row
                    })
                    .collect()
            }
            AttnKind::Svd { wq, a_k, a_v, .. } | AttnKind::Palu { wq, a_k, a_v, .. } => {
                // Pre-RoPE latents cached; Q full-rope'd.
                let q = self.vecmat_counted(h, wq);
                let kl = self.vecmat_counted(h, a_k);
                let vl = self.vecmat_counted(h, a_v);
                let (kw, vw) = (cache.k_width, cache.v_width);
                for hd in 0..cfg.n_kv_heads {
                    cache
                        .k_row_mut(hd, pos)
                        .copy_from_slice(&kl[hd * kw..(hd + 1) * kw]);
                    cache
                        .v_row_mut(hd, pos)
                        .copy_from_slice(&vl[hd * vw..(hd + 1) * vw]);
                }
                (0..cfg.n_heads)
                    .map(|hq| {
                        let mut row = q[hq * dh..(hq + 1) * dh].to_vec();
                        apply_full(&mut row, pos, cfg.pairing, cfg.rope_theta);
                        row
                    })
                    .collect()
            }
            AttnKind::Rap {
                wq_t, a_k, a_v, plan, ..
            } => {
                let q = self.vecmat_counted(h, wq_t);
                let kl = self.vecmat_counted(h, a_k);
                let vl = self.vecmat_counted(h, a_v);
                let (kw, vw) = (cache.k_width, cache.v_width);
                for hd in 0..cfg.n_kv_heads {
                    let krow = cache.k_row_mut(hd, pos);
                    krow.copy_from_slice(&kl[hd * kw..(hd + 1) * kw]);
                    // Index-aware RoPE directly on the latent — the fused
                    // hot path (no reconstruction, no gather).
                    plan.k_table.apply_fused(hd, krow, pos);
                    cache
                        .v_row_mut(hd, pos)
                        .copy_from_slice(&vl[hd * vw..(hd + 1) * vw]);
                }
                (0..cfg.n_heads)
                    .map(|hq| {
                        let mut row = q[hq * kw..(hq + 1) * kw].to_vec();
                        plan.q_table.apply_fused(hq, &mut row, pos);
                        row
                    })
                    .collect()
            }
        }
    }

    /// Attention for ONE query token at `pos` over cache[0..=ctx_end].
    /// Returns the per-head context vectors [H][v_width_effective].
    fn attend(
        &self,
        layer: &Layer,
        q_rows: &[Vec<f32>],
        cache: &LayerCache,
        ctx_end: usize,
    ) -> Vec<Vec<f32>> {
        let cfg = &self.cfg;
        let dh = cfg.head_dim;
        let group = cfg.group_size();
        let scale = 1.0 / (dh as f32).sqrt();
        let s = ctx_end + 1;

        // Reconstruction step for factorization methods (paper Fig. 1):
        // K (and V for SVD) are expanded to full dimension for the whole
        // visible context, every call.
        let (recon_k, recon_v): (Option<Vec<Vec<f32>>>, Option<Vec<Vec<f32>>>) =
            match &layer.attn {
                AttnKind::Svd { b_k, b_v, .. } => (
                    Some(self.reconstruct(cache, b_k, true, s)),
                    Some(self.reconstruct(cache, b_v, false, s)),
                ),
                AttnKind::Palu { b_k, .. } => {
                    (Some(self.reconstruct(cache, b_k, true, s)), None)
                }
                _ => (None, None),
            };

        let mut out = Vec::with_capacity(cfg.n_heads);
        let mut scores = vec![0.0f32; s];
        for hq in 0..cfg.n_heads {
            let hk = hq / group;
            let q = &q_rows[hq];
            // scores
            match &recon_k {
                Some(k_full) => {
                    let krows = &k_full[hk];
                    for t in 0..s {
                        scores[t] = dot(q, &krows[t * dh..(t + 1) * dh]) * scale;
                    }
                    self.flops.add(2 * (s * dh) as u64);
                }
                None => {
                    let w = cache.k_width;
                    for t in 0..s {
                        scores[t] = dot(q, cache.k_row(hk, t)) * scale;
                    }
                    self.flops.add(2 * (s * w) as u64);
                }
            }
            softmax_inplace(&mut scores[..s]);
            // values
            let vw_eff = match &layer.attn {
                AttnKind::Svd { .. } | AttnKind::Baseline { .. } => dh,
                _ => cache.v_width,
            };
            let mut ctx = vec![0.0f32; vw_eff];
            match &recon_v {
                Some(v_full) => {
                    let vrows = &v_full[hk];
                    for t in 0..s {
                        crate::tensor::ops::axpy(scores[t], &vrows[t * dh..(t + 1) * dh], &mut ctx);
                    }
                }
                None => {
                    for t in 0..s {
                        crate::tensor::ops::axpy(scores[t], cache.v_row(hk, t), &mut ctx);
                    }
                }
            }
            self.flops.add(2 * (s * vw_eff) as u64);
            out.push(ctx);
        }
        out
    }

    /// Expand the latent cache rows [0, s) of every KV head through the
    /// per-head reconstruction matrices ([w, dh] each).  Counted as FLOPs —
    /// this is exactly the overhead Table 2 attributes to SVD/PaLU.
    fn reconstruct(
        &self,
        cache: &LayerCache,
        b: &[Tensor],
        is_k: bool,
        s: usize,
    ) -> Vec<Vec<f32>> {
        let dh = self.cfg.head_dim;
        let mut out = Vec::with_capacity(self.cfg.n_kv_heads);
        for hd in 0..self.cfg.n_kv_heads {
            let bw = &b[hd];
            let (w, _) = bw.dims2();
            let mut rows = vec![0.0f32; s * dh];
            for t in 0..s {
                let lat = if is_k { cache.k_row(hd, t) } else { cache.v_row(hd, t) };
                let dst = &mut rows[t * dh..(t + 1) * dh];
                for (p, &lv) in lat.iter().enumerate().take(w) {
                    if lv != 0.0 {
                        crate::tensor::ops::axpy(lv, bw.row(p), dst);
                    }
                }
            }
            self.flops.add(2 * (s * w * dh) as u64);
            let mut full = rows;
            if is_k {
                // RoPE the reconstructed K at its token positions.
                for t in 0..s {
                    apply_full(
                        &mut full[t * dh..(t + 1) * dh],
                        t,
                        self.cfg.pairing,
                        self.cfg.rope_theta,
                    );
                }
            }
            out.push(full);
        }
        out
    }

    fn output_proj(&self, layer: &Layer, ctx: &[Vec<f32>], x: &mut [f32]) {
        let merged: Vec<f32> = ctx.iter().flatten().copied().collect();
        let wo = match &layer.attn {
            AttnKind::Baseline { wo, .. } | AttnKind::Svd { wo, .. } => wo,
            AttnKind::Palu { wo_t, .. } | AttnKind::Rap { wo_t, .. } => wo_t,
        };
        let o = self.vecmat_counted(&merged, wo);
        add_inplace(x, &o);
    }

    /// Process one token at `pos` given cache filled for [0, pos); updates
    /// the cache and returns the hidden state's logits.
    pub fn step(&self, token: u8, pos: usize, cache: &mut Cache) -> Vec<f32> {
        assert!(pos < cache.layers[0].s_max, "cache overflow at pos {pos}");
        let d = self.cfg.d_model;
        let mut x = self.embed(token);
        let mut h = vec![0.0f32; d];
        for (l, layer) in self.layers.iter().enumerate() {
            rms_norm(&x, &layer.attn_norm.data, self.cfg.norm_eps, &mut h);
            let lc = &mut cache.layers[l];
            let q_rows = self.project_token(layer, &h, pos, lc);
            let ctx = self.attend(layer, &q_rows, lc, pos);
            self.output_proj(layer, &ctx, &mut x);
            self.mlp_inplace(layer, &mut x);
        }
        cache.len = cache.len.max(pos + 1);
        self.logits_from_hidden(&x)
    }

    /// Prefill a prompt, returning logits at the last position.
    pub fn prefill(&self, tokens: &[u8], cache: &mut Cache) -> Vec<f32> {
        let mut logits = Vec::new();
        for (i, &t) in tokens.iter().enumerate() {
            logits = self.step(t, i, cache);
        }
        logits
    }

    /// Mean negative log-likelihood of `targets` given `tokens` (teacher
    /// forcing), batch-1 full-sequence evaluation.
    pub fn nll(&self, tokens: &[u8], targets: &[u8], s_max: usize) -> f64 {
        assert_eq!(tokens.len(), targets.len());
        let mut cache = self.new_cache(s_max.max(tokens.len()));
        let mut total = 0.0f64;
        for (i, (&t, &y)) in tokens.iter().zip(targets.iter()).enumerate() {
            let logits = self.step(t, i, &mut cache);
            // log-softmax at the target
            let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = logits.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
            total += (lse - logits[y as usize]) as f64;
        }
        total / tokens.len() as f64
    }

    /// Greedy-decode `n` tokens after a prompt; returns generated bytes.
    pub fn generate(&self, prompt: &[u8], n: usize, s_max: usize) -> Vec<u8> {
        let mut cache = self.new_cache(s_max);
        let mut logits = self.prefill(prompt, &mut cache);
        let mut out = Vec::with_capacity(n);
        let mut pos = prompt.len();
        for _ in 0..n {
            let next = argmax(&logits) as u8;
            out.push(next);
            if pos >= s_max {
                break;
            }
            logits = self.step(next, pos, &mut cache);
            pos += 1;
        }
        out
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[1.0, 5.0, 3.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn layer_cache_rows_disjoint() {
        let mut c = LayerCache::new(2, 4, 3, 5);
        c.k_row_mut(0, 1).copy_from_slice(&[1.0, 2.0, 3.0]);
        c.k_row_mut(1, 1).copy_from_slice(&[9.0, 9.0, 9.0]);
        assert_eq!(c.k_row(0, 1), &[1.0, 2.0, 3.0]);
        assert_eq!(c.k_row(0, 0), &[0.0, 0.0, 0.0]);
        assert_eq!(c.v_row(1, 3).len(), 5);
    }

    // Engine integration tests (vs manifest weights and PJRT) live in
    // rust/tests/.
}
