//! Pure-Rust inference engine for every compression variant.
//!
//! The engine mirrors `python/compile/model.py` operation-for-operation and
//! is cross-validated against PJRT executions of the exported HLO in the
//! integration tests.  It powers the evaluation experiments (PPL, probe
//! tasks, long-context suite), dense latency sweeps, and the measured-FLOPs
//! harness (every matmul is routed through a FLOP counter).
//!
//! Method semantics (paper Figure 1 / §4.3):
//! * baseline — full K (post-RoPE) and V cached.
//! * svd      — pre-RoPE latent K and latent V cached; **both reconstructed
//!              every attention call** (the overhead RAP removes).
//! * palu     — latent K reconstructed; latent V consumed directly through
//!              the absorbed W_o.
//! * rap      — index-aware-RoPE'd latent K and latent V consumed directly:
//!              attention runs entirely at latent widths.
//!
//! ## Decode paths
//!
//! All hot-path arithmetic lives in kernels generic over
//! [`KvLayerView`], so the same code serves two cache layouts:
//!
//! * the dense per-sequence [`LayerCache`] (evaluation, latency sweeps),
//!   driven by [`Engine::step`];
//! * the storage-backed `kvcache::PagedKvCache`, driven by
//!   [`Engine::decode_batch_paged`] — the serving path.  It steps a whole
//!   batch of sessions through one layer at a time (weights stay hot in
//!   cache), parallelises across sessions via `scoped_chunks_indexed`, and
//!   performs **zero heap allocations** in steady state: all scratch lives
//!   in a reusable [`DecodeWorkspace`] / [`BatchWorkspace`], and scores are
//!   computed with the blocked `dot_rows_scaled` / `axpy_rows` kernels
//!   whose accumulation order makes paged and dense decode bit-identical.
//!
//! [`Engine::step_alloc_reference`] preserves the original allocating
//! per-row decode verbatim; it is the oracle the workspace path is tested
//! against bitwise, and the baseline `benches/decode_latency.rs` reports
//! speedups over in `BENCH_decode.json`.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::config::{Method, ModelConfig, VariantSpec};
use crate::kvcache::{CacheShape, KvLayerView, PagedKvCache};
use crate::model::weights::Weights;
use crate::rap::plan::LayerPlan;
use crate::rope::apply_full;
use crate::tensor::ops::{
    add_inplace, axpy_rows, dot, dot_rows_scaled, kernel_threads, rms_norm, silu,
    softmax_inplace, vecmat, vecmat_into,
};
use crate::tensor::Tensor;
use crate::util::threadpool::scoped_chunks_indexed;

/// Per-layer KV cache in *latent* widths.  Row-major [Hkv, Smax, width].
#[derive(Debug, Clone)]
pub struct LayerCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub k_width: usize,
    pub v_width: usize,
    pub s_max: usize,
    pub n_kv_heads: usize,
}

impl LayerCache {
    pub fn new(n_kv_heads: usize, s_max: usize, k_width: usize, v_width: usize) -> LayerCache {
        LayerCache {
            k: vec![0.0; n_kv_heads * s_max * k_width],
            v: vec![0.0; n_kv_heads * s_max * v_width],
            k_width,
            v_width,
            s_max,
            n_kv_heads,
        }
    }

    #[inline]
    pub fn k_row(&self, head: usize, s: usize) -> &[f32] {
        let o = (head * self.s_max + s) * self.k_width;
        &self.k[o..o + self.k_width]
    }

    #[inline]
    pub fn k_row_mut(&mut self, head: usize, s: usize) -> &mut [f32] {
        let o = (head * self.s_max + s) * self.k_width;
        &mut self.k[o..o + self.k_width]
    }

    #[inline]
    pub fn v_row(&self, head: usize, s: usize) -> &[f32] {
        let o = (head * self.s_max + s) * self.v_width;
        &self.v[o..o + self.v_width]
    }

    #[inline]
    pub fn v_row_mut(&mut self, head: usize, s: usize) -> &mut [f32] {
        let o = (head * self.s_max + s) * self.v_width;
        &mut self.v[o..o + self.v_width]
    }
}

/// The dense layout is one maximal contiguous run per head, which lets the
/// blocked kernels sweep the whole visible context in a single call.
impl KvLayerView for LayerCache {
    #[inline]
    fn k_row(&self, head: usize, t: usize) -> &[f32] {
        LayerCache::k_row(self, head, t)
    }

    #[inline]
    fn v_row(&self, head: usize, t: usize) -> &[f32] {
        LayerCache::v_row(self, head, t)
    }

    #[inline]
    fn k_row_mut(&mut self, head: usize, t: usize) -> &mut [f32] {
        LayerCache::k_row_mut(self, head, t)
    }

    #[inline]
    fn v_row_mut(&mut self, head: usize, t: usize) -> &mut [f32] {
        LayerCache::v_row_mut(self, head, t)
    }

    fn for_k_runs<F: FnMut(usize, &[f32])>(&self, head: usize, s: usize, mut f: F) {
        if s > 0 {
            let o = head * self.s_max * self.k_width;
            f(0, &self.k[o..o + s * self.k_width]);
        }
    }

    fn for_v_runs<F: FnMut(usize, &[f32])>(&self, head: usize, s: usize, mut f: F) {
        if s > 0 {
            let o = head * self.s_max * self.v_width;
            f(0, &self.v[o..o + s * self.v_width]);
        }
    }
}

/// Whole-model cache for one sequence, plus the per-sequence decode
/// workspace that makes repeated `step` calls allocation-free.
#[derive(Debug, Clone)]
pub struct Cache {
    pub layers: Vec<LayerCache>,
    pub len: usize,
    /// Variant cache geometry — the single source of byte accounting,
    /// shared with the allocator (`kvcache::CacheShape`).
    pub shape: CacheShape,
    x: Vec<f32>,
    ws: DecodeWorkspace,
}

impl Cache {
    /// Bytes resident for the *current* length, derived from the same
    /// `CacheShape` the paged allocator bills against — engine-side and
    /// allocator-side accounting cannot diverge.
    pub fn bytes_used(&self) -> usize {
        self.shape.bytes_for_tokens(self.len)
    }
}

struct Layer {
    attn_norm: Tensor,
    mlp_norm: Tensor,
    w_gate: Tensor,
    w_up: Tensor,
    w_down: Tensor,
    attn: AttnKind,
}

#[allow(clippy::large_enum_variant)]
enum AttnKind {
    Baseline {
        wq: Tensor,
        wk: Tensor,
        wv: Tensor,
        wo: Tensor,
    },
    Svd {
        wq: Tensor,
        a_k: Tensor,
        /// per KV head [rk, dh]
        b_k: Vec<Tensor>,
        a_v: Tensor,
        b_v: Vec<Tensor>,
        wo: Tensor,
    },
    Palu {
        wq: Tensor,
        a_k: Tensor,
        b_k: Vec<Tensor>,
        a_v: Tensor,
        wo_t: Tensor,
    },
    Rap {
        wq_t: Tensor,
        a_k: Tensor,
        a_v: Tensor,
        wo_t: Tensor,
        plan: LayerPlan,
    },
}

/// FLOP counter (mul+add = 2, matching the paper's Table 6 convention).
/// Atomic so batched decode workers can share the engine across threads.
#[derive(Debug, Default)]
pub struct Flops(AtomicU64);

impl Flops {
    #[inline]
    fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn take(&self) -> u64 {
        self.0.swap(0, Ordering::Relaxed)
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Reusable per-token scratch: every buffer the decode step needs, sized
/// once for the engine's widest layer and an `s_max` context.  Reusing it
/// is what makes steady-state decode allocation-free.
#[derive(Debug, Clone)]
pub struct DecodeWorkspace {
    /// Normed hidden state (also the logits head's norm scratch).
    h: Vec<f32>,
    /// Raw Q projection output.
    q: Vec<f32>,
    /// Raw latent-K projection output.
    kl: Vec<f32>,
    /// Raw latent-V projection output.
    vl: Vec<f32>,
    /// Rotated per-head Q rows, packed [H, q_width].
    q_rows: Vec<f32>,
    /// Attention scores over the visible context.
    scores: Vec<f32>,
    /// SVD/PaLU reconstructed K, packed [Hkv, s, dh] (empty otherwise).
    recon_k: Vec<f32>,
    /// SVD reconstructed V (empty otherwise).
    recon_v: Vec<f32>,
    /// Per-head context vectors, packed [H, ctx_width] — contiguity makes
    /// this directly consumable by the output projection (no merge copy).
    ctx: Vec<f32>,
    /// d_model-sized projection output (attention out / MLP down).
    o: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    logits: Vec<f32>,
}

impl DecodeWorkspace {
    pub fn new(engine: &Engine, s_max: usize) -> DecodeWorkspace {
        let cfg = &engine.cfg;
        let (h_n, hkv, dh) = (cfg.n_heads, cfg.n_kv_heads, cfg.head_dim);
        let max_qw = (0..cfg.n_layers).map(|l| engine.q_width(l)).max().unwrap_or(dh);
        let max_kw = engine.spec.k_rank.iter().copied().max().unwrap_or(dh);
        let max_vw = engine.spec.v_rank.iter().copied().max().unwrap_or(dh);
        let max_cw = (0..cfg.n_layers).map(|l| engine.ctx_width(l)).max().unwrap_or(dh);
        let recon_k_n = if engine.spec.method.reconstructs_k() { hkv * s_max * dh } else { 0 };
        let recon_v_n = if engine.spec.method.reconstructs_v() { hkv * s_max * dh } else { 0 };
        DecodeWorkspace {
            h: vec![0.0; cfg.d_model],
            q: vec![0.0; h_n * max_qw],
            kl: vec![0.0; hkv * max_kw],
            vl: vec![0.0; hkv * max_vw],
            q_rows: vec![0.0; h_n * max_qw],
            scores: vec![0.0; s_max],
            recon_k: vec![0.0; recon_k_n],
            recon_v: vec![0.0; recon_v_n],
            ctx: vec![0.0; h_n * max_cw],
            o: vec![0.0; cfg.d_model],
            gate: vec![0.0; cfg.mlp_hidden],
            up: vec![0.0; cfg.mlp_hidden],
            logits: vec![0.0; cfg.vocab],
        }
    }

    /// Longest context this workspace can attend over.
    pub fn s_max(&self) -> usize {
        self.scores.len()
    }
}

/// Batched-decode scratch: per-session hidden states and logits plus one
/// [`DecodeWorkspace`] per worker thread.  Buffers only ever grow, so once
/// every decode bucket size has been seen the steady state allocates
/// nothing.
pub struct BatchWorkspace {
    s_max: usize,
    d_model: usize,
    vocab: usize,
    /// Hidden states, packed [B, d_model].
    x: Vec<f32>,
    /// Logits, packed [B, vocab].
    logits: Vec<f32>,
    workers: Vec<DecodeWorkspace>,
    batch_capacity: usize,
}

impl BatchWorkspace {
    pub fn new(engine: &Engine, s_max: usize) -> BatchWorkspace {
        BatchWorkspace {
            s_max,
            d_model: engine.cfg.d_model,
            vocab: engine.cfg.vocab,
            x: Vec::new(),
            logits: Vec::new(),
            workers: Vec::new(),
            batch_capacity: 0,
        }
    }

    pub fn s_max(&self) -> usize {
        self.s_max
    }

    /// Logits of batch entry `i` from the last `decode_batch_paged` call.
    pub fn logits_row(&self, i: usize) -> &[f32] {
        &self.logits[i * self.vocab..(i + 1) * self.vocab]
    }

    fn ensure(&mut self, engine: &Engine, b: usize) {
        let workers = kernel_threads().min(b.max(1));
        while self.workers.len() < workers {
            self.workers.push(DecodeWorkspace::new(engine, self.s_max));
        }
        if b > self.batch_capacity {
            self.x.resize(b * self.d_model, 0.0);
            self.logits.resize(b * self.vocab, 0.0);
            self.batch_capacity = b;
        }
    }
}

/// `*mut T` that scoped workers may share; every use dereferences a
/// worker-exclusive region (same idiom as the matmul kernel's `OutPtr`).
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}

pub struct Engine {
    pub cfg: ModelConfig,
    pub spec: VariantSpec,
    tok_emb: Tensor,
    final_norm: Tensor,
    layers: Vec<Layer>,
    pub flops: Flops,
}

fn split_heads(b_k: &Tensor, n_heads: usize) -> Vec<Tensor> {
    // manifest shape [H, r, dh] -> H tensors [r, dh]
    assert_eq!(b_k.rank(), 3);
    let (h, r, dh) = (b_k.shape[0], b_k.shape[1], b_k.shape[2]);
    assert_eq!(h, n_heads);
    (0..h)
        .map(|i| {
            Tensor::new(
                vec![r, dh],
                b_k.data[i * r * dh..(i + 1) * r * dh].to_vec(),
            )
        })
        .collect()
}

impl Engine {
    pub fn new(cfg: ModelConfig, spec: VariantSpec, w: &Weights) -> Result<Engine> {
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let attn = match spec.method {
                Method::Baseline => AttnKind::Baseline {
                    wq: w.layer(l, "wq").clone(),
                    wk: w.layer(l, "wk").clone(),
                    wv: w.layer(l, "wv").clone(),
                    wo: w.layer(l, "wo").clone(),
                },
                Method::Svd => AttnKind::Svd {
                    wq: w.layer(l, "wq").clone(),
                    a_k: w.layer(l, "a_k").clone(),
                    b_k: split_heads(w.layer(l, "b_k"), cfg.n_kv_heads),
                    a_v: w.layer(l, "a_v").clone(),
                    b_v: split_heads(w.layer(l, "b_v"), cfg.n_kv_heads),
                    wo: w.layer(l, "wo").clone(),
                },
                Method::Palu => AttnKind::Palu {
                    wq: w.layer(l, "wq").clone(),
                    a_k: w.layer(l, "a_k").clone(),
                    b_k: split_heads(w.layer(l, "b_k"), cfg.n_kv_heads),
                    a_v: w.layer(l, "a_v").clone(),
                    wo_t: w.layer(l, "wo_t").clone(),
                },
                Method::Rap => {
                    if spec.k_pairs.len() != cfg.n_layers {
                        bail!("rap spec missing k_pairs for layer {l}");
                    }
                    AttnKind::Rap {
                        wq_t: w.layer(l, "wq_t").clone(),
                        a_k: w.layer(l, "a_k").clone(),
                        a_v: w.layer(l, "a_v").clone(),
                        wo_t: w.layer(l, "wo_t").clone(),
                        plan: LayerPlan::new(&cfg, spec.k_pairs[l].clone()),
                    }
                }
            };
            layers.push(Layer {
                attn_norm: w.layer(l, "attn_norm").clone(),
                mlp_norm: w.layer(l, "mlp_norm").clone(),
                w_gate: w.layer(l, "w_gate").clone(),
                w_up: w.layer(l, "w_up").clone(),
                w_down: w.layer(l, "w_down").clone(),
                attn,
            });
        }
        Ok(Engine {
            tok_emb: w.get("tok_emb").clone(),
            final_norm: w.get("final_norm").clone(),
            layers,
            cfg,
            spec,
            flops: Flops::default(),
        })
    }

    /// Width of one rotated Q row at layer `l` (latent for RAP, full head
    /// dimension otherwise).
    pub fn q_width(&self, l: usize) -> usize {
        match self.spec.method {
            Method::Rap => self.spec.k_rank[l],
            _ => self.cfg.head_dim,
        }
    }

    /// Width of one per-head context vector at layer `l` (latent when V is
    /// consumed through the absorbed W_o).
    pub fn ctx_width(&self, l: usize) -> usize {
        match self.spec.method {
            Method::Baseline | Method::Svd => self.cfg.head_dim,
            Method::Palu | Method::Rap => self.spec.v_rank[l],
        }
    }

    pub fn new_cache(&self, s_max: usize) -> Cache {
        let shape = CacheShape::of(&self.cfg, &self.spec);
        Cache {
            layers: (0..self.cfg.n_layers)
                .map(|l| {
                    LayerCache::new(shape.n_kv_heads, s_max, shape.k_width[l], shape.v_width[l])
                })
                .collect(),
            len: 0,
            x: vec![0.0; self.cfg.d_model],
            ws: DecodeWorkspace::new(self, s_max),
            shape,
        }
    }

    #[inline]
    fn vecmat_counted_into(&self, x: &[f32], w: &Tensor, out: &mut [f32]) {
        let (k, n) = w.dims2();
        self.flops.add(2 * (k * n) as u64);
        vecmat_into(x, w, out);
    }

    fn embed_into(&self, token: u8, x: &mut [f32]) {
        let d = self.cfg.d_model;
        x.copy_from_slice(&self.tok_emb.data[token as usize * d..(token as usize + 1) * d]);
    }

    fn logits_into(&self, x: &[f32], h: &mut [f32], logits: &mut [f32]) {
        let d = self.cfg.d_model;
        let v = self.cfg.vocab;
        rms_norm(x, &self.final_norm.data, self.cfg.norm_eps, h);
        // tied embedding head: logits = h @ tok_emb^T
        self.flops.add(2 * (d * v) as u64);
        for t in 0..v {
            logits[t] = dot(h, &self.tok_emb.data[t * d..(t + 1) * d]);
        }
    }

    /// Project ONE token's normed hidden state into the cacheable K/V rows
    /// at `pos` (written through `kv`) and the rotated Q rows (`q_rows`,
    /// packed [H, q_width(l)]).
    fn project_into<L: KvLayerView>(
        &self,
        l: usize,
        layer: &Layer,
        h: &[f32],
        pos: usize,
        kv: &mut L,
        q: &mut [f32],
        kl: &mut [f32],
        vl: &mut [f32],
        q_rows: &mut [f32],
    ) {
        let cfg = &self.cfg;
        let dh = cfg.head_dim;
        match &layer.attn {
            AttnKind::Baseline { wq, wk, wv, .. } => {
                let q = &mut q[..cfg.n_heads * dh];
                let kl = &mut kl[..cfg.n_kv_heads * dh];
                let vl = &mut vl[..cfg.n_kv_heads * dh];
                self.vecmat_counted_into(h, wq, q);
                self.vecmat_counted_into(h, wk, kl);
                self.vecmat_counted_into(h, wv, vl);
                for hd in 0..cfg.n_kv_heads {
                    let krow = kv.k_row_mut(hd, pos);
                    krow.copy_from_slice(&kl[hd * dh..(hd + 1) * dh]);
                    apply_full(krow, pos, cfg.pairing, cfg.rope_theta);
                    kv.v_row_mut(hd, pos)
                        .copy_from_slice(&vl[hd * dh..(hd + 1) * dh]);
                }
                q_rows.copy_from_slice(q);
                for hq in 0..cfg.n_heads {
                    apply_full(
                        &mut q_rows[hq * dh..(hq + 1) * dh],
                        pos,
                        cfg.pairing,
                        cfg.rope_theta,
                    );
                }
            }
            AttnKind::Svd { wq, a_k, a_v, .. } | AttnKind::Palu { wq, a_k, a_v, .. } => {
                // Pre-RoPE latents cached; Q full-rope'd.
                let (kw, vw) = (self.spec.k_rank[l], self.spec.v_rank[l]);
                let q = &mut q[..cfg.n_heads * dh];
                let kl = &mut kl[..cfg.n_kv_heads * kw];
                let vl = &mut vl[..cfg.n_kv_heads * vw];
                self.vecmat_counted_into(h, wq, q);
                self.vecmat_counted_into(h, a_k, kl);
                self.vecmat_counted_into(h, a_v, vl);
                for hd in 0..cfg.n_kv_heads {
                    kv.k_row_mut(hd, pos)
                        .copy_from_slice(&kl[hd * kw..(hd + 1) * kw]);
                    kv.v_row_mut(hd, pos)
                        .copy_from_slice(&vl[hd * vw..(hd + 1) * vw]);
                }
                q_rows.copy_from_slice(q);
                for hq in 0..cfg.n_heads {
                    apply_full(
                        &mut q_rows[hq * dh..(hq + 1) * dh],
                        pos,
                        cfg.pairing,
                        cfg.rope_theta,
                    );
                }
            }
            AttnKind::Rap {
                wq_t, a_k, a_v, plan, ..
            } => {
                let (kw, vw) = (self.spec.k_rank[l], self.spec.v_rank[l]);
                let q = &mut q[..cfg.n_heads * kw];
                let kl = &mut kl[..cfg.n_kv_heads * kw];
                let vl = &mut vl[..cfg.n_kv_heads * vw];
                self.vecmat_counted_into(h, wq_t, q);
                self.vecmat_counted_into(h, a_k, kl);
                self.vecmat_counted_into(h, a_v, vl);
                for hd in 0..cfg.n_kv_heads {
                    let krow = kv.k_row_mut(hd, pos);
                    krow.copy_from_slice(&kl[hd * kw..(hd + 1) * kw]);
                    // Index-aware RoPE directly on the latent — the fused
                    // hot path (no reconstruction, no gather).
                    plan.k_table.apply_fused(hd, krow, pos);
                    kv.v_row_mut(hd, pos)
                        .copy_from_slice(&vl[hd * vw..(hd + 1) * vw]);
                }
                q_rows.copy_from_slice(q);
                for hq in 0..cfg.n_heads {
                    plan.q_table
                        .apply_fused(hq, &mut q_rows[hq * kw..(hq + 1) * kw], pos);
                }
            }
        }
    }

    /// Attention for ONE query token at `pos` over cache rows `[0, pos]`,
    /// writing the per-head context vectors into `ctx` (packed
    /// [H, ctx_width(l)]).  Scores sweep the cache run-by-run through the
    /// blocked kernels — identical arithmetic for dense and paged layouts.
    #[allow(clippy::too_many_arguments)]
    fn attend_into<L: KvLayerView>(
        &self,
        l: usize,
        layer: &Layer,
        pos: usize,
        kv: &L,
        q_rows: &[f32],
        scores: &mut [f32],
        recon_k: &mut [f32],
        recon_v: &mut [f32],
        ctx: &mut [f32],
    ) {
        let cfg = &self.cfg;
        let dh = cfg.head_dim;
        let group = cfg.group_size();
        let scale = 1.0 / (dh as f32).sqrt();
        let s = pos + 1;
        let qw = q_rows.len() / cfg.n_heads;
        let cw = ctx.len() / cfg.n_heads;
        let (kw, vw) = (self.spec.k_rank[l], self.spec.v_rank[l]);

        // Reconstruction step for factorization methods (paper Fig. 1):
        // K (and V for SVD) are expanded to full dimension for the whole
        // visible context, every call.
        let (use_rk, use_rv) = match &layer.attn {
            AttnKind::Svd { b_k, b_v, .. } => {
                self.reconstruct_into(kv, b_k, true, s, recon_k);
                self.reconstruct_into(kv, b_v, false, s, recon_v);
                (true, true)
            }
            AttnKind::Palu { b_k, .. } => {
                self.reconstruct_into(kv, b_k, true, s, recon_k);
                (true, false)
            }
            _ => (false, false),
        };

        for hq in 0..cfg.n_heads {
            let hk = hq / group;
            let q = &q_rows[hq * qw..(hq + 1) * qw];
            if use_rk {
                dot_rows_scaled(q, &recon_k[hk * s * dh..(hk + 1) * s * dh], dh, scale, &mut scores[..s]);
                self.flops.add(2 * (s * dh) as u64);
            } else {
                kv.for_k_runs(hk, s, |t0, rows| {
                    let n = rows.len() / kw;
                    dot_rows_scaled(q, rows, kw, scale, &mut scores[t0..t0 + n]);
                });
                self.flops.add(2 * (s * kw) as u64);
            }
            softmax_inplace(&mut scores[..s]);
            let c = &mut ctx[hq * cw..(hq + 1) * cw];
            c.fill(0.0);
            if use_rv {
                axpy_rows(&scores[..s], &recon_v[hk * s * dh..(hk + 1) * s * dh], dh, c);
            } else {
                kv.for_v_runs(hk, s, |t0, rows| {
                    let n = rows.len() / vw;
                    axpy_rows(&scores[t0..t0 + n], rows, vw, c);
                });
            }
            self.flops.add(2 * (s * cw) as u64);
        }
    }

    /// Expand the latent cache rows [0, s) of every KV head through the
    /// per-head reconstruction matrices ([w, dh] each) into `out`, packed
    /// [Hkv, s, dh].  Counted as FLOPs — this is exactly the overhead
    /// Table 2 attributes to SVD/PaLU.
    fn reconstruct_into<L: KvLayerView>(
        &self,
        kv: &L,
        b: &[Tensor],
        is_k: bool,
        s: usize,
        out: &mut [f32],
    ) {
        let dh = self.cfg.head_dim;
        for hd in 0..self.cfg.n_kv_heads {
            let bw = &b[hd];
            let (w, _) = bw.dims2();
            let rows = &mut out[hd * s * dh..(hd + 1) * s * dh];
            for t in 0..s {
                let lat = if is_k { kv.k_row(hd, t) } else { kv.v_row(hd, t) };
                let dst = &mut rows[t * dh..(t + 1) * dh];
                dst.fill(0.0);
                for (p, &lv) in lat.iter().enumerate().take(w) {
                    if lv != 0.0 {
                        crate::tensor::ops::axpy(lv, bw.row(p), dst);
                    }
                }
            }
            self.flops.add(2 * (s * w * dh) as u64);
            if is_k {
                // RoPE the reconstructed K at its token positions.
                for t in 0..s {
                    apply_full(
                        &mut rows[t * dh..(t + 1) * dh],
                        t,
                        self.cfg.pairing,
                        self.cfg.rope_theta,
                    );
                }
            }
        }
    }

    /// One full transformer layer for one token: attention (through `kv`)
    /// plus MLP, accumulated into the hidden state `x`.
    fn layer_forward<L: KvLayerView>(
        &self,
        l: usize,
        layer: &Layer,
        x: &mut [f32],
        pos: usize,
        kv: &mut L,
        ws: &mut DecodeWorkspace,
    ) {
        let cfg = &self.cfg;
        let DecodeWorkspace {
            h,
            q,
            kl,
            vl,
            q_rows,
            scores,
            recon_k,
            recon_v,
            ctx,
            o,
            gate,
            up,
            ..
        } = ws;
        let qw = self.q_width(l);
        let cw = self.ctx_width(l);

        rms_norm(x, &layer.attn_norm.data, cfg.norm_eps, h);
        self.project_into(l, layer, h, pos, kv, q, kl, vl, &mut q_rows[..cfg.n_heads * qw]);
        self.attend_into(
            l,
            layer,
            pos,
            kv,
            &q_rows[..cfg.n_heads * qw],
            scores,
            recon_k,
            recon_v,
            &mut ctx[..cfg.n_heads * cw],
        );
        let wo = match &layer.attn {
            AttnKind::Baseline { wo, .. } | AttnKind::Svd { wo, .. } => wo,
            AttnKind::Palu { wo_t, .. } | AttnKind::Rap { wo_t, .. } => wo_t,
        };
        self.vecmat_counted_into(&ctx[..cfg.n_heads * cw], wo, o);
        add_inplace(x, o);

        rms_norm(x, &layer.mlp_norm.data, cfg.norm_eps, h);
        self.vecmat_counted_into(h, &layer.w_gate, gate);
        self.vecmat_counted_into(h, &layer.w_up, up);
        for (gv, uv) in gate.iter_mut().zip(up.iter()) {
            *gv = silu(*gv) * *uv;
        }
        self.vecmat_counted_into(gate, &layer.w_down, o);
        add_inplace(x, o);
    }

    fn step_inner<'c>(
        &self,
        token: u8,
        pos: usize,
        cache: &'c mut Cache,
        want_logits: bool,
    ) -> &'c [f32] {
        assert!(pos < cache.layers[0].s_max, "cache overflow at pos {pos}");
        let Cache { layers, len, x, ws, .. } = cache;
        self.embed_into(token, x);
        for (l, layer) in self.layers.iter().enumerate() {
            self.layer_forward(l, layer, x, pos, &mut layers[l], ws);
        }
        *len = (*len).max(pos + 1);
        let DecodeWorkspace { h, logits, .. } = ws;
        if want_logits {
            self.logits_into(x, h, logits);
        }
        logits
    }

    /// Process one token at `pos` given cache filled for [0, pos); updates
    /// the cache and returns the logits as a borrow of the cache's
    /// workspace — the allocation-free form of [`Engine::step`].
    pub fn step_reuse<'c>(&self, token: u8, pos: usize, cache: &'c mut Cache) -> &'c [f32] {
        self.step_inner(token, pos, cache, true)
    }

    /// Process one token at `pos`; returns owned logits (compatibility
    /// wrapper over [`Engine::step_reuse`]).
    pub fn step(&self, token: u8, pos: usize, cache: &mut Cache) -> Vec<f32> {
        self.step_reuse(token, pos, cache).to_vec()
    }

    /// One decode step for a batch of `(session, token, pos)` entries
    /// against the storage-backed paged KV-cache, layer-major: all sessions
    /// advance through layer 0, then layer 1, … so each layer's weights are
    /// touched once per step regardless of batch size.  Sessions are split
    /// across `kernel_threads()` scoped workers (their blocks are disjoint
    /// by construction).
    ///
    /// Zero heap allocations in steady state: scratch lives in `batch`,
    /// which only grows the first time a batch size is seen.  Logits land
    /// in `batch` (read via [`BatchWorkspace::logits_row`]) and are only
    /// computed when `compute_logits` — prefill loops skip the head for all
    /// but the final token.
    ///
    /// Every session must already hold a reservation covering `pos`
    /// (`PagedKvCache::ensure_tokens`; the coordinator reserves a request's
    /// full budget at admission).
    pub fn decode_batch_paged(
        &self,
        entries: &[(u64, u8, usize)],
        kv: &mut PagedKvCache,
        batch: &mut BatchWorkspace,
        compute_logits: bool,
    ) -> Result<()> {
        let b = entries.len();
        if b == 0 {
            return Ok(());
        }
        batch.ensure(self, b);
        for (i, &(sid, _, pos)) in entries.iter().enumerate() {
            if pos >= batch.s_max {
                bail!("session {sid}: pos {pos} exceeds workspace s_max {}", batch.s_max);
            }
            if kv.session_tokens(sid) <= pos {
                bail!(
                    "session {sid}: pos {pos} beyond its {}-token reservation",
                    kv.session_tokens(sid)
                );
            }
            // A duplicated session id would give two workers overlapping
            // views of the same blocks — reject it before any write.
            if entries[..i].iter().any(|&(other, _, _)| other == sid) {
                bail!("session {sid} appears twice in one decode batch");
            }
        }
        let d = self.cfg.d_model;
        let (pages, store) = kv.tables_and_ptrs()?;
        for (i, &(_, token, _)) in entries.iter().enumerate() {
            self.embed_into(token, &mut batch.x[i * d..(i + 1) * d]);
        }
        let threads = kernel_threads().min(b);
        let ws_ptr = SendPtr(batch.workers.as_mut_ptr());
        let x_ptr = SendPtr(batch.x.as_mut_ptr());
        for (l, layer) in self.layers.iter().enumerate() {
            scoped_chunks_indexed(b, threads, |widx, range| {
                // SAFETY: each worker owns a unique workspace index and a
                // disjoint range of batch entries; sessions own disjoint
                // cache blocks, so no two workers touch the same memory.
                let ws = unsafe { &mut *ws_ptr.0.add(widx) };
                for bi in range {
                    let (sid, _, pos) = entries[bi];
                    let x = unsafe { std::slice::from_raw_parts_mut(x_ptr.0.add(bi * d), d) };
                    // SAFETY: session ids are unique within `entries`
                    // (checked above), so this worker holds the only live
                    // view over this session's blocks.
                    let mut view = unsafe { store.seq_layer(l, pages.blocks(sid).unwrap()) };
                    self.layer_forward(l, layer, x, pos, &mut view, ws);
                }
            });
        }
        if compute_logits {
            let v = self.cfg.vocab;
            let lg_ptr = SendPtr(batch.logits.as_mut_ptr());
            scoped_chunks_indexed(b, threads, |widx, range| {
                // SAFETY: as above — disjoint entries and workspaces.
                let ws = unsafe { &mut *ws_ptr.0.add(widx) };
                for bi in range {
                    let x = unsafe { std::slice::from_raw_parts(x_ptr.0.add(bi * d), d) };
                    let logits =
                        unsafe { std::slice::from_raw_parts_mut(lg_ptr.0.add(bi * v), v) };
                    self.logits_into(x, &mut ws.h, logits);
                }
            });
        }
        Ok(())
    }

    /// Prefill a prompt, returning logits at the last position.  Only the
    /// final token pays for the vocabulary head; intermediate positions run
    /// the allocation-free layer stack alone.
    pub fn prefill(&self, tokens: &[u8], cache: &mut Cache) -> Vec<f32> {
        let Some((&last, rest)) = tokens.split_last() else {
            return Vec::new();
        };
        for (i, &t) in rest.iter().enumerate() {
            self.step_inner(t, i, cache, false);
        }
        self.step_inner(last, tokens.len() - 1, cache, true).to_vec()
    }

    /// Mean negative log-likelihood of `targets` given `tokens` (teacher
    /// forcing), batch-1 full-sequence evaluation.
    pub fn nll(&self, tokens: &[u8], targets: &[u8], s_max: usize) -> f64 {
        assert_eq!(tokens.len(), targets.len());
        let mut cache = self.new_cache(s_max.max(tokens.len()));
        let mut total = 0.0f64;
        for (i, (&t, &y)) in tokens.iter().zip(targets.iter()).enumerate() {
            let logits = self.step_reuse(t, i, &mut cache);
            // log-softmax at the target
            let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = logits.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
            total += (lse - logits[y as usize]) as f64;
        }
        total / tokens.len() as f64
    }

    /// Greedy-decode `n` tokens after a prompt; returns generated bytes.
    pub fn generate(&self, prompt: &[u8], n: usize, s_max: usize) -> Vec<u8> {
        let mut cache = self.new_cache(s_max);
        self.prefill(prompt, &mut cache);
        let mut out = Vec::with_capacity(n);
        let mut pos = prompt.len();
        for _ in 0..n {
            let next = argmax(cache.ws.logits.as_slice()) as u8;
            out.push(next);
            if pos >= s_max {
                break;
            }
            self.step_reuse(next, pos, &mut cache);
            pos += 1;
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Seed reference path.
//
// The original (pre-workspace) decode, preserved verbatim: per-token heap
// allocations for every projection/score/context buffer and per-row `dot`
// calls over the dense cache.  It serves two purposes:
//   * the oracle for the workspace refactor — `step` must match it bitwise
//     (asserted in `tests/paged.rs`);
//   * the measured baseline in `benches/decode_latency.rs`, whose speedup
//     ratio is recorded in BENCH_decode.json.
// ---------------------------------------------------------------------------

impl Engine {
    #[inline]
    fn vecmat_counted(&self, x: &[f32], w: &Tensor) -> Vec<f32> {
        let (k, n) = w.dims2();
        self.flops.add(2 * (k * n) as u64);
        vecmat(x, w)
    }

    fn project_token_ref(
        &self,
        layer: &Layer,
        h: &[f32],
        pos: usize,
        cache: &mut LayerCache,
    ) -> Vec<Vec<f32>> {
        let cfg = &self.cfg;
        let dh = cfg.head_dim;
        match &layer.attn {
            AttnKind::Baseline { wq, wk, wv, .. } => {
                let q = self.vecmat_counted(h, wq);
                let k = self.vecmat_counted(h, wk);
                let v = self.vecmat_counted(h, wv);
                for hd in 0..cfg.n_kv_heads {
                    let krow = cache.k_row_mut(hd, pos);
                    krow.copy_from_slice(&k[hd * dh..(hd + 1) * dh]);
                    apply_full(krow, pos, cfg.pairing, cfg.rope_theta);
                    cache
                        .v_row_mut(hd, pos)
                        .copy_from_slice(&v[hd * dh..(hd + 1) * dh]);
                }
                (0..cfg.n_heads)
                    .map(|hq| {
                        let mut row = q[hq * dh..(hq + 1) * dh].to_vec();
                        apply_full(&mut row, pos, cfg.pairing, cfg.rope_theta);
                        row
                    })
                    .collect()
            }
            AttnKind::Svd { wq, a_k, a_v, .. } | AttnKind::Palu { wq, a_k, a_v, .. } => {
                let q = self.vecmat_counted(h, wq);
                let kl = self.vecmat_counted(h, a_k);
                let vl = self.vecmat_counted(h, a_v);
                let (kw, vw) = (cache.k_width, cache.v_width);
                for hd in 0..cfg.n_kv_heads {
                    cache
                        .k_row_mut(hd, pos)
                        .copy_from_slice(&kl[hd * kw..(hd + 1) * kw]);
                    cache
                        .v_row_mut(hd, pos)
                        .copy_from_slice(&vl[hd * vw..(hd + 1) * vw]);
                }
                (0..cfg.n_heads)
                    .map(|hq| {
                        let mut row = q[hq * dh..(hq + 1) * dh].to_vec();
                        apply_full(&mut row, pos, cfg.pairing, cfg.rope_theta);
                        row
                    })
                    .collect()
            }
            AttnKind::Rap {
                wq_t, a_k, a_v, plan, ..
            } => {
                let q = self.vecmat_counted(h, wq_t);
                let kl = self.vecmat_counted(h, a_k);
                let vl = self.vecmat_counted(h, a_v);
                let (kw, vw) = (cache.k_width, cache.v_width);
                for hd in 0..cfg.n_kv_heads {
                    let krow = cache.k_row_mut(hd, pos);
                    krow.copy_from_slice(&kl[hd * kw..(hd + 1) * kw]);
                    plan.k_table.apply_fused(hd, krow, pos);
                    cache
                        .v_row_mut(hd, pos)
                        .copy_from_slice(&vl[hd * vw..(hd + 1) * vw]);
                }
                (0..cfg.n_heads)
                    .map(|hq| {
                        let mut row = q[hq * kw..(hq + 1) * kw].to_vec();
                        plan.q_table.apply_fused(hq, &mut row, pos);
                        row
                    })
                    .collect()
            }
        }
    }

    fn reconstruct_ref(
        &self,
        cache: &LayerCache,
        b: &[Tensor],
        is_k: bool,
        s: usize,
    ) -> Vec<Vec<f32>> {
        let dh = self.cfg.head_dim;
        let mut out = Vec::with_capacity(self.cfg.n_kv_heads);
        for hd in 0..self.cfg.n_kv_heads {
            let bw = &b[hd];
            let (w, _) = bw.dims2();
            let mut rows = vec![0.0f32; s * dh];
            for t in 0..s {
                let lat = if is_k { cache.k_row(hd, t) } else { cache.v_row(hd, t) };
                let dst = &mut rows[t * dh..(t + 1) * dh];
                for (p, &lv) in lat.iter().enumerate().take(w) {
                    if lv != 0.0 {
                        crate::tensor::ops::axpy(lv, bw.row(p), dst);
                    }
                }
            }
            self.flops.add(2 * (s * w * dh) as u64);
            if is_k {
                for t in 0..s {
                    apply_full(
                        &mut rows[t * dh..(t + 1) * dh],
                        t,
                        self.cfg.pairing,
                        self.cfg.rope_theta,
                    );
                }
            }
            out.push(rows);
        }
        out
    }

    fn attend_ref(
        &self,
        layer: &Layer,
        q_rows: &[Vec<f32>],
        cache: &LayerCache,
        ctx_end: usize,
    ) -> Vec<Vec<f32>> {
        let cfg = &self.cfg;
        let dh = cfg.head_dim;
        let group = cfg.group_size();
        let scale = 1.0 / (dh as f32).sqrt();
        let s = ctx_end + 1;

        let (recon_k, recon_v): (Option<Vec<Vec<f32>>>, Option<Vec<Vec<f32>>>) =
            match &layer.attn {
                AttnKind::Svd { b_k, b_v, .. } => (
                    Some(self.reconstruct_ref(cache, b_k, true, s)),
                    Some(self.reconstruct_ref(cache, b_v, false, s)),
                ),
                AttnKind::Palu { b_k, .. } => {
                    (Some(self.reconstruct_ref(cache, b_k, true, s)), None)
                }
                _ => (None, None),
            };

        let mut out = Vec::with_capacity(cfg.n_heads);
        let mut scores = vec![0.0f32; s];
        for hq in 0..cfg.n_heads {
            let hk = hq / group;
            let q = &q_rows[hq];
            match &recon_k {
                Some(k_full) => {
                    let krows = &k_full[hk];
                    for t in 0..s {
                        scores[t] = dot(q, &krows[t * dh..(t + 1) * dh]) * scale;
                    }
                    self.flops.add(2 * (s * dh) as u64);
                }
                None => {
                    let w = cache.k_width;
                    for t in 0..s {
                        scores[t] = dot(q, cache.k_row(hk, t)) * scale;
                    }
                    self.flops.add(2 * (s * w) as u64);
                }
            }
            softmax_inplace(&mut scores[..s]);
            let vw_eff = match &layer.attn {
                AttnKind::Svd { .. } | AttnKind::Baseline { .. } => dh,
                _ => cache.v_width,
            };
            let mut ctx = vec![0.0f32; vw_eff];
            match &recon_v {
                Some(v_full) => {
                    let vrows = &v_full[hk];
                    for t in 0..s {
                        crate::tensor::ops::axpy(scores[t], &vrows[t * dh..(t + 1) * dh], &mut ctx);
                    }
                }
                None => {
                    for t in 0..s {
                        crate::tensor::ops::axpy(scores[t], cache.v_row(hk, t), &mut ctx);
                    }
                }
            }
            self.flops.add(2 * (s * vw_eff) as u64);
            out.push(ctx);
        }
        out
    }

    fn mlp_inplace_ref(&self, layer: &Layer, x: &mut [f32]) {
        let d = self.cfg.d_model;
        let mut h = vec![0.0f32; d];
        rms_norm(x, &layer.mlp_norm.data, self.cfg.norm_eps, &mut h);
        let mut g = self.vecmat_counted(&h, &layer.w_gate);
        let u = self.vecmat_counted(&h, &layer.w_up);
        for (gv, uv) in g.iter_mut().zip(&u) {
            *gv = silu(*gv) * uv;
        }
        let down = self.vecmat_counted(&g, &layer.w_down);
        add_inplace(x, &down);
    }

    /// The seed's decode step, allocation behaviour and all.  See the
    /// section comment above.
    pub fn step_alloc_reference(&self, token: u8, pos: usize, cache: &mut Cache) -> Vec<f32> {
        assert!(pos < cache.layers[0].s_max, "cache overflow at pos {pos}");
        let d = self.cfg.d_model;
        let mut x = self.tok_emb.data[token as usize * d..(token as usize + 1) * d].to_vec();
        let mut h = vec![0.0f32; d];
        for (l, layer) in self.layers.iter().enumerate() {
            rms_norm(&x, &layer.attn_norm.data, self.cfg.norm_eps, &mut h);
            let lc = &mut cache.layers[l];
            let q_rows = self.project_token_ref(layer, &h, pos, lc);
            let ctx = self.attend_ref(layer, &q_rows, lc, pos);
            let merged: Vec<f32> = ctx.iter().flatten().copied().collect();
            let wo = match &layer.attn {
                AttnKind::Baseline { wo, .. } | AttnKind::Svd { wo, .. } => wo,
                AttnKind::Palu { wo_t, .. } | AttnKind::Rap { wo_t, .. } => wo_t,
            };
            let o = self.vecmat_counted(&merged, wo);
            add_inplace(&mut x, &o);
            self.mlp_inplace_ref(layer, &mut x);
        }
        cache.len = cache.len.max(pos + 1);
        let mut hn = vec![0.0f32; d];
        rms_norm(&x, &self.final_norm.data, self.cfg.norm_eps, &mut hn);
        let v = self.cfg.vocab;
        self.flops.add(2 * (d * v) as u64);
        let mut logits = vec![0.0f32; v];
        for t in 0..v {
            logits[t] = dot(&hn, &self.tok_emb.data[t * d..(t + 1) * d]);
        }
        logits
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[1.0, 5.0, 3.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn layer_cache_rows_disjoint() {
        let mut c = LayerCache::new(2, 4, 3, 5);
        c.k_row_mut(0, 1).copy_from_slice(&[1.0, 2.0, 3.0]);
        c.k_row_mut(1, 1).copy_from_slice(&[9.0, 9.0, 9.0]);
        assert_eq!(c.k_row(0, 1), &[1.0, 2.0, 3.0]);
        assert_eq!(c.k_row(0, 0), &[0.0, 0.0, 0.0]);
        assert_eq!(c.v_row(1, 3).len(), 5);
    }

    #[test]
    fn layer_cache_runs_match_rows() {
        let mut c = LayerCache::new(2, 8, 3, 2);
        for t in 0..6 {
            c.k_row_mut(1, t)[0] = t as f32;
            c.v_row_mut(1, t)[1] = -(t as f32);
        }
        let mut calls = 0;
        KvLayerView::for_k_runs(&c, 1, 6, |t0, rows| {
            calls += 1;
            assert_eq!(t0, 0);
            assert_eq!(rows.len(), 6 * 3);
            for (i, chunk) in rows.chunks_exact(3).enumerate() {
                assert_eq!(chunk[0], i as f32);
            }
        });
        assert_eq!(calls, 1, "dense layout yields one maximal run");
        KvLayerView::for_v_runs(&c, 1, 6, |_, rows| {
            for (i, chunk) in rows.chunks_exact(2).enumerate() {
                assert_eq!(chunk[1], -(i as f32));
            }
        });
    }

    // Engine integration tests (vs manifest weights, PJRT, and the paged
    // batched-decode identity suite) live in rust/tests/.
}
