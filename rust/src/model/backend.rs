//! Pure-Rust engine as a serving backend (dense latency sweeps and tests:
//! no PJRT dependency, deterministic, FLOP-instrumented).
//!
//! Session KV state lives in the coordinator's storage-backed
//! `PagedKvCache` (`wants_paged_storage`), not in per-session host vectors:
//! prefill runs the engine's block-parallel chunk kernel
//! (`Engine::prefill_chunk_paged`) — one GEMM per weight matrix per chunk,
//! chunked admission feeds it bounded slices — and `decode_batch` runs the
//! layer-major batched step over all entries at once.  Both paths are
//! allocation-free in steady state apart from the logits vectors the
//! `Backend` trait returns.

use std::collections::BTreeSet;

use anyhow::Result;

use crate::coordinator::scheduler::Backend;
use crate::coordinator::RequestId;
use crate::kvcache::{KvLayerView, KvStorageMode, PagedKvCache};
use crate::model::{BatchWorkspace, Engine, PrefillWorkspace};
use crate::tensor::simd::KernelPath;

/// Configuration threaded into [`RustBackend::with_config`]: the kernel
/// dispatch path and the optional int4 round-trip of cached latent rows.
///
/// [`KernelPath::FusedInt4`] additionally selects nibble-packed int4 KV
/// storage ([`KvStorageMode::PackedInt4`]) via
/// [`Backend::kv_storage_mode`] — but only for methods that never
/// reconstruct K/V (baseline, RAP); SVD/PaLU read f32 latent rows during
/// reconstruction and fall back to f32 storage with wide kernels.
#[derive(Debug, Clone, Copy, Default)]
pub struct BackendConfig {
    /// Kernel implementations the engine dispatches every
    /// matmul/dot/axpy call through.
    pub kernel_path: KernelPath,
    /// int4 round-trip newly written latent rows (f32 storage only;
    /// packed storage quantizes on write and ignores this).
    pub quantize_kv: bool,
}

pub struct RustBackend<'a> {
    pub engine: &'a Engine,
    s_max: usize,
    batch: BatchWorkspace,
    prefill_ws: PrefillWorkspace,
    sessions: BTreeSet<RequestId>,
    /// Optional int4 round-trip of newly written latent rows (Fig. 12).
    ///
    /// Prefill quantization is **chunk-size-invariant**: the engine
    /// round-trips each latent row immediately after it is projected and
    /// written, *before* any attention reads it, so every prefill query
    /// sees only int4 rows and the logits cannot depend on
    /// `BatcherConfig::prefill_chunk_tokens` (propchecked in
    /// `tests/prefill.rs`).  This reverts the chunk-granular semantics a
    /// previous refactor introduced, where the in-flight chunk read
    /// full-precision rows and the same prompt produced different logits
    /// at different chunk sizes.  Decode keeps the per-token round-trip
    /// *after* the step (a decode step reads its own just-written row
    /// full-precision, earlier rows quantized).
    pub quantize_kv: bool,
    /// Config captured by [`RustBackend::with_config`]; plain
    /// [`RustBackend::new`] keeps the default (f32 storage, whatever
    /// kernel path the engine picked up from `RAP_KERNEL_PATH`).
    config: BackendConfig,
}

impl<'a> RustBackend<'a> {
    pub fn new(engine: &'a Engine, s_max: usize) -> RustBackend<'a> {
        RustBackend {
            batch: BatchWorkspace::new(engine, s_max),
            prefill_ws: PrefillWorkspace::new(engine, s_max),
            engine,
            s_max,
            sessions: BTreeSet::new(),
            quantize_kv: false,
            config: BackendConfig::default(),
        }
    }

    /// Build a backend with an explicit [`BackendConfig`], overriding the
    /// engine's env-derived kernel path.  Takes the engine mutably for the
    /// override, then holds it shared like [`RustBackend::new`].
    pub fn with_config(
        engine: &'a mut Engine,
        s_max: usize,
        config: BackendConfig,
    ) -> RustBackend<'a> {
        engine.set_kernel_path(config.kernel_path);
        let mut backend = RustBackend::new(engine, s_max);
        backend.quantize_kv = config.quantize_kv;
        backend.config = config;
        backend
    }

    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// int4 round-trip the rows just written at positions
    /// `[pos0, pos0 + n)` of `sid` — the decode path's post-step
    /// round-trip (prefill quantizes inside the engine, pre-attention).
    fn quantize_range(&self, kv: &mut PagedKvCache, sid: RequestId, pos0: usize, n: usize) {
        if !self.quantize_kv || n == 0 {
            return;
        }
        if kv.storage_mode().is_packed() {
            // Packed rows were already quantized on write, and the f32 row
            // accessors the round-trip uses do not exist in this mode.
            return;
        }
        let (pages, store) = kv.tables_and_ptrs().expect("storage-backed kv");
        let blocks = pages.blocks(sid).expect("session reserved");
        for l in 0..self.engine.cfg.n_layers {
            // SAFETY: one view at a time, single-threaded loop.
            let mut view = unsafe { store.seq_layer(l, blocks) };
            for pos in pos0..pos0 + n {
                for h in 0..self.engine.cfg.n_kv_heads {
                    crate::kvcache::quant::roundtrip(view.k_row_mut(h, pos));
                    crate::kvcache::quant::roundtrip(view.v_row_mut(h, pos));
                }
            }
        }
    }
}

impl<'a> Backend for RustBackend<'a> {
    fn s_max(&self) -> usize {
        self.s_max
    }

    fn wants_paged_storage(&self) -> bool {
        true
    }

    fn kv_storage_mode(&self) -> KvStorageMode {
        let m = self.engine.spec.method;
        if self.config.kernel_path.fuses_int4() && !m.reconstructs_k() && !m.reconstructs_v() {
            KvStorageMode::PackedInt4
        } else {
            KvStorageMode::F32
        }
    }

    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    fn prefill_chunk(
        &mut self,
        kv: &mut PagedKvCache,
        session: RequestId,
        tokens: &[u8],
        pos0: usize,
        last: bool,
    ) -> Result<Option<Vec<f32>>> {
        if tokens.is_empty() {
            // Covers the whole-prompt case AND the degenerate empty
            // last-chunk shape: returning logits for a zero-length chunk
            // would hand back another request's stale workspace contents.
            anyhow::bail!("empty prefill chunk (session {session}, pos {pos0})");
        }
        // First chunks no longer always start at 0: a shared prompt
        // prefix lets the coordinator begin prefill at the first
        // unmatched token.
        self.sessions.insert(session);
        // Under the coordinator the prompt (or resume feed) is reserved at
        // admission, so this is a zero-deficit no-op there; it only
        // allocates blocks for standalone (coordinator-less) use.  `pos0`
        // is row-space: identical to the logical position for retain-all
        // sessions, and for a pruned session's survivor replay the rows
        // were reserved up front (`reserve_with_positions`), so this call
        // never grows a pruned table (`pos0 + len <= rows <= next_pos`).
        kv.ensure_tokens(session, pos0 + tokens.len())?;
        self.engine.prefill_chunk_paged(
            session,
            tokens,
            pos0,
            kv,
            &mut self.prefill_ws,
            last,
            self.quantize_kv,
        )?;
        // Report write progress: sharers of this session's prefix blocks
        // debug-assert the rows exist before their first read.
        kv.note_filled(session, pos0 + tokens.len());
        Ok(if last { Some(self.prefill_ws.logits().to_vec()) } else { None })
    }

    fn prefill(&mut self, kv: &mut PagedKvCache, session: RequestId, prompt: &[u8]) -> Result<Vec<f32>> {
        match self.prefill_chunk(kv, session, prompt, 0, true)? {
            Some(logits) => Ok(logits),
            None => unreachable!("last chunk always returns logits"),
        }
    }

    fn decode_batch(
        &mut self,
        kv: &mut PagedKvCache,
        entries: &[(RequestId, u8, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        for &(sid, _, pos) in entries {
            if !self.sessions.contains(&sid) {
                anyhow::bail!("unknown session {sid}");
            }
            kv.ensure_tokens(sid, pos + 1)?;
        }
        self.engine
            .decode_batch_paged(entries, kv, &mut self.batch, true)?;
        for &(sid, _, pos) in entries {
            // Pruned sessions store the just-written token at the last
            // resident row, not at its logical position.
            let row = kv.row_index_of(sid, pos).unwrap_or(pos);
            self.quantize_range(kv, sid, row, 1);
        }
        Ok((0..entries.len())
            .map(|i| self.batch.logits_row(i).to_vec())
            .collect())
    }

    fn verify_chunk(
        &mut self,
        kv: &mut PagedKvCache,
        session: RequestId,
        tokens: &[u8],
        pos0: usize,
    ) -> Result<Vec<Vec<f32>>> {
        if !self.sessions.contains(&session) {
            anyhow::bail!("unknown session {session}");
        }
        let n = tokens.len();
        if self.quantize_kv && !kv.storage_mode().is_packed() {
            // The int4 round-trip over f32 storage breaks chunk/decode
            // bit-identity: prefill rounds a row before its own chunk's
            // attention reads it, decode rounds *after* the step that
            // wrote it.  Re-run the feed token-by-token instead — exact,
            // just not batched.  The caller pre-reserved the draft rows;
            // drop them first so each decode step regrows its own row
            // (a pruned session's decode requires its last resident row
            // to be the previous logical position).
            let row0 = kv.row_index_of(session, pos0).unwrap_or(pos0);
            kv.truncate_rows(session, row0)?;
            let mut rows = Vec::with_capacity(n);
            for (i, &t) in tokens.iter().enumerate() {
                let mut lg = self.decode_batch(kv, &[(session, t, pos0 + i)])?;
                rows.push(lg.pop().expect("decode_batch returns one row per entry"));
            }
            return Ok(rows);
        }
        kv.ensure_tokens(session, pos0 + n)?;
        let row0 = kv.row_index_of(session, pos0).unwrap_or(pos0);
        self.engine.verify_chunk_paged(
            session,
            tokens,
            row0,
            kv,
            &mut self.prefill_ws,
            self.quantize_kv,
        )?;
        kv.note_filled(session, row0 + n);
        Ok((0..n).map(|i| self.prefill_ws.verify_logits_row(i).to_vec()).collect())
    }

    fn drop_session(&mut self, session: RequestId) {
        self.sessions.remove(&session);
    }
}
