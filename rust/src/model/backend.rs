//! Pure-Rust engine as a serving backend (dense latency sweeps and tests:
//! no PJRT dependency, deterministic, FLOP-instrumented).
//!
//! Session KV state lives in the coordinator's storage-backed
//! `PagedKvCache` (`wants_paged_storage`), not in per-session host vectors:
//! prefill writes latent rows through the page table, and `decode_batch`
//! runs the engine's layer-major batched step over all entries at once —
//! allocation-free in steady state apart from the logits vectors the
//! `Backend` trait returns.

use std::collections::BTreeSet;

use anyhow::Result;

use crate::coordinator::scheduler::Backend;
use crate::coordinator::RequestId;
use crate::kvcache::{KvLayerView, PagedKvCache};
use crate::model::{BatchWorkspace, Engine};

pub struct RustBackend<'a> {
    pub engine: &'a Engine,
    s_max: usize,
    batch: BatchWorkspace,
    sessions: BTreeSet<RequestId>,
    /// Optional int4 round-trip of newly written latent rows (Fig. 12).
    pub quantize_kv: bool,
}

impl<'a> RustBackend<'a> {
    pub fn new(engine: &'a Engine, s_max: usize) -> RustBackend<'a> {
        RustBackend {
            batch: BatchWorkspace::new(engine, s_max),
            engine,
            s_max,
            sessions: BTreeSet::new(),
            quantize_kv: false,
        }
    }

    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// int4 round-trip the rows just written at each entry's position.
    fn quantize_step(&self, kv: &mut PagedKvCache, entries: &[(RequestId, u8, usize)]) {
        if !self.quantize_kv {
            return;
        }
        let (pages, store) = kv.tables_and_ptrs().expect("storage-backed kv");
        for &(sid, _, pos) in entries {
            let blocks = pages.blocks(sid).expect("session reserved");
            for l in 0..self.engine.cfg.n_layers {
                // SAFETY: one view at a time, single-threaded loop.
                let mut view = unsafe { store.seq_layer(l, blocks) };
                for h in 0..self.engine.cfg.n_kv_heads {
                    crate::kvcache::quant::roundtrip(view.k_row_mut(h, pos));
                    crate::kvcache::quant::roundtrip(view.v_row_mut(h, pos));
                }
            }
        }
    }
}

impl<'a> Backend for RustBackend<'a> {
    fn s_max(&self) -> usize {
        self.s_max
    }

    fn wants_paged_storage(&self) -> bool {
        true
    }

    fn prefill(&mut self, kv: &mut PagedKvCache, session: RequestId, prompt: &[u8]) -> Result<Vec<f32>> {
        if prompt.is_empty() {
            anyhow::bail!("empty prompt");
        }
        // Under the coordinator the full budget is already reserved; this
        // only allocates blocks for standalone use.
        kv.ensure_tokens(session, prompt.len())?;
        self.sessions.insert(session);
        for (i, &t) in prompt.iter().enumerate() {
            let last = i + 1 == prompt.len();
            self.engine
                .decode_batch_paged(&[(session, t, i)], kv, &mut self.batch, last)?;
            self.quantize_step(kv, &[(session, t, i)]);
        }
        Ok(self.batch.logits_row(0).to_vec())
    }

    fn decode_batch(
        &mut self,
        kv: &mut PagedKvCache,
        entries: &[(RequestId, u8, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        for &(sid, _, pos) in entries {
            if !self.sessions.contains(&sid) {
                anyhow::bail!("unknown session {sid}");
            }
            kv.ensure_tokens(sid, pos + 1)?;
        }
        self.engine
            .decode_batch_paged(entries, kv, &mut self.batch, true)?;
        self.quantize_step(kv, entries);
        Ok((0..entries.len())
            .map(|i| self.batch.logits_row(i).to_vec())
            .collect())
    }

    fn drop_session(&mut self, session: RequestId) {
        self.sessions.remove(&session);
    }
}
