//! Pure-Rust engine as a serving backend (dense latency sweeps and tests:
//! no PJRT dependency, deterministic, FLOP-instrumented).  Decode batches
//! execute sequentially — batching still amortises scheduler work, and the
//! identical coordinator logic is exercised.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::coordinator::scheduler::Backend;
use crate::coordinator::RequestId;
use crate::model::{Cache, Engine};

pub struct RustBackend<'a> {
    pub engine: &'a Engine,
    s_max: usize,
    sessions: BTreeMap<RequestId, Cache>,
    /// Optional int4 round-trip of newly written latent rows (Fig. 12).
    pub quantize_kv: bool,
}

impl<'a> RustBackend<'a> {
    pub fn new(engine: &'a Engine, s_max: usize) -> RustBackend<'a> {
        RustBackend {
            engine,
            s_max,
            sessions: BTreeMap::new(),
            quantize_kv: false,
        }
    }

    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    fn quantize_step(&self, cache: &mut Cache, pos: usize) {
        if !self.quantize_kv {
            return;
        }
        for lc in &mut cache.layers {
            for h in 0..lc.n_kv_heads {
                crate::kvcache::quant::roundtrip(lc.k_row_mut(h, pos));
                crate::kvcache::quant::roundtrip(lc.v_row_mut(h, pos));
            }
        }
    }
}

impl<'a> Backend for RustBackend<'a> {
    fn s_max(&self) -> usize {
        self.s_max
    }

    fn prefill(&mut self, session: RequestId, prompt: &[u8]) -> Result<Vec<f32>> {
        let mut cache = self.engine.new_cache(self.s_max);
        let mut logits = Vec::new();
        for (i, &t) in prompt.iter().enumerate() {
            logits = self.engine.step(t, i, &mut cache);
            self.quantize_step(&mut cache, i);
        }
        self.sessions.insert(session, cache);
        Ok(logits)
    }

    fn decode_batch(&mut self, entries: &[(RequestId, u8, usize)]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(entries.len());
        for &(id, token, pos) in entries {
            let mut cache = self
                .sessions
                .remove(&id)
                .with_context(|| format!("unknown session {id}"))?;
            let logits = self.engine.step(token, pos, &mut cache);
            self.quantize_step(&mut cache, pos);
            self.sessions.insert(id, cache);
            out.push(logits);
        }
        Ok(out)
    }

    fn drop_session(&mut self, session: RequestId) {
        self.sessions.remove(&session);
    }
}
