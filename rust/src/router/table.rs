//! The routing table: replica registry + prefix-affinity route choice.
//!
//! Affinity keying deliberately mirrors the prefix cache's granularity
//! (`kvcache::BLOCK_TOKENS`): the key hashes only the first
//! block-*aligned* bytes of the prompt (up to `affinity_blocks` blocks),
//! so two requests that share a system prompt — identical through at
//! least one full block — map to the same key even when their suffixes
//! differ, and land on the replica already holding those blocks warm.
//! Placement is rendezvous (highest-random-weight) hashing over the live
//! replica set: adding or removing one replica only remaps the keys that
//! pointed at it, so a drain or a crash doesn't cold-start the whole
//! fleet's prefix caches.
//!
//! Affinity yields to load: when the affine replica is more than
//! `load_slack` requests busier than the least-loaded candidate, the
//! request overflows to the least-loaded one — a popular prefix can
//! saturate one replica but not the router.

use std::net::SocketAddr;

use crate::kvcache::BLOCK_TOKENS;
use crate::router::health::{HealthState, Hysteresis};
use crate::router::retry::mix;
use crate::util::rng::Rng;

pub type ReplicaId = u64;

/// How the router picks a replica for a fresh request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Prefix-affinity rendezvous hashing with least-loaded overflow
    /// (the default; what the prefix cache wants).
    Affinity,
    /// Seeded uniform choice — the control arm in `benches/router.rs`.
    Random { seed: u64 },
    /// Pure least-loaded, ignoring prefixes.
    LeastLoaded,
}

/// Last successful probe's gauges (from the replica's `{"health": true}`
/// line), for status reporting and load-aware routing.
#[derive(Debug, Clone, Copy, Default)]
pub struct ProbeGauges {
    pub pending: u64,
    pub used_blocks: u64,
    pub capacity_blocks: u64,
    pub prefix_hits: u64,
    pub prefix_lookups: u64,
}

#[derive(Debug)]
pub struct Replica {
    pub id: ReplicaId,
    pub addr: SocketAddr,
    pub health: HealthState,
    pub hysteresis: Hysteresis,
    /// Requests this router currently has relayed onto the replica.
    pub in_flight: usize,
    pub gauges: Option<ProbeGauges>,
    pub dispatched: u64,
    pub completed: u64,
}

pub struct RoutingTable {
    pub(crate) replicas: Vec<Replica>,
    next_id: ReplicaId,
    pub(crate) policy: RoutePolicy,
    pub(crate) affinity_blocks: usize,
    pub(crate) load_slack: usize,
    /// RNG for `RoutePolicy::Random` draws.
    rng: Rng,
}

impl RoutingTable {
    pub fn new(policy: RoutePolicy, affinity_blocks: usize, load_slack: usize) -> RoutingTable {
        let seed = match policy {
            RoutePolicy::Random { seed } => seed,
            _ => 0,
        };
        RoutingTable {
            replicas: Vec::new(),
            next_id: 1,
            policy,
            affinity_blocks: affinity_blocks.max(1),
            load_slack,
            rng: Rng::new(seed),
        }
    }

    pub fn register(&mut self, addr: SocketAddr) -> ReplicaId {
        if let Some(r) = self.replicas.iter_mut().find(|r| r.addr == addr) {
            // Re-registering a known address revives it (e.g. a restarted
            // replica on the same port) but makes it prove itself first.
            if r.health == HealthState::Down || r.health == HealthState::Draining {
                r.health = HealthState::Suspect;
                r.hysteresis = Hysteresis::default();
            }
            return r.id;
        }
        let id = self.next_id;
        self.next_id += 1;
        self.replicas.push(Replica {
            id,
            addr,
            health: HealthState::Healthy,
            hysteresis: Hysteresis::default(),
            in_flight: 0,
            gauges: None,
            dispatched: 0,
            completed: 0,
        });
        id
    }

    pub fn remove(&mut self, id: ReplicaId) -> bool {
        let before = self.replicas.len();
        self.replicas.retain(|r| r.id != id);
        self.replicas.len() != before
    }

    pub fn get_mut(&mut self, id: ReplicaId) -> Option<&mut Replica> {
        self.replicas.iter_mut().find(|r| r.id == id)
    }

    pub fn by_addr_mut(&mut self, addr: SocketAddr) -> Option<&mut Replica> {
        self.replicas.iter_mut().find(|r| r.addr == addr)
    }

    pub fn addr_of(&self, id: ReplicaId) -> Option<SocketAddr> {
        self.replicas.iter().find(|r| r.id == id).map(|r| r.addr)
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    /// The affinity key: FNV-1a over the longest block-aligned prompt
    /// prefix, capped at `affinity_blocks` blocks.  `None` when the
    /// prompt doesn't cover even one block — sub-block prompts can't hit
    /// the prefix cache, so they route by load instead of all piling
    /// onto one rendezvous winner.
    pub fn affinity_key(&self, prompt: &[u8]) -> Option<u64> {
        let aligned = (prompt.len() / BLOCK_TOKENS) * BLOCK_TOKENS;
        let take = aligned.min(self.affinity_blocks * BLOCK_TOKENS);
        if take == 0 {
            return None;
        }
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        for &b in &prompt[..take] {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        Some(h)
    }

    /// Pick a replica for `prompt`, skipping `exclude` (already-tried
    /// replicas on a retry).  `None` when nothing is routable.
    pub fn route(&mut self, prompt: &[u8], exclude: &[ReplicaId]) -> Option<ReplicaId> {
        let candidate_ids = |table: &RoutingTable, state: HealthState| -> Vec<usize> {
            table
                .replicas
                .iter()
                .enumerate()
                .filter(|(_, r)| r.health == state && !exclude.contains(&r.id))
                .map(|(i, _)| i)
                .collect()
        };
        // Healthy first; a fleet with nothing healthy falls back to
        // Suspect (still plausibly alive).  Down/Draining never route.
        let mut cands = candidate_ids(self, HealthState::Healthy);
        if cands.is_empty() {
            cands = candidate_ids(self, HealthState::Suspect);
        }
        if cands.is_empty() {
            return None;
        }
        let idx = match self.policy {
            RoutePolicy::Random { .. } => cands[self.rng.below(cands.len())],
            RoutePolicy::LeastLoaded => self.least_loaded_of(&cands),
            RoutePolicy::Affinity => match self.affinity_key(prompt) {
                None => self.least_loaded_of(&cands),
                Some(key) => {
                    // Rendezvous: the candidate with the highest
                    // mix(key, id) owns this key.
                    let affine = *cands
                        .iter()
                        .max_by_key(|&&i| mix(key, self.replicas[i].id))
                        .expect("cands non-empty");
                    let least = self.least_loaded_of(&cands);
                    let slack = self.replicas[least].in_flight + self.load_slack;
                    if self.replicas[affine].in_flight > slack {
                        least // popular prefix saturating its owner: overflow
                    } else {
                        affine
                    }
                }
            },
        };
        Some(self.replicas[idx].id)
    }

    /// Index (into `self.replicas`) of the least-loaded candidate;
    /// ties break to the lowest id for determinism.
    fn least_loaded_of(&self, cands: &[usize]) -> usize {
        *cands
            .iter()
            .min_by_key(|&&i| (self.replicas[i].in_flight, self.replicas[i].id))
            .expect("cands non-empty")
    }

    pub fn note_dispatch(&mut self, id: ReplicaId) {
        if let Some(r) = self.get_mut(id) {
            r.in_flight += 1;
            r.dispatched += 1;
        }
    }

    /// Decrement the in-flight count.  Returns `true` when this was the
    /// last in-flight request of a draining replica — the caller should
    /// then [`RoutingTable::remove`] it (see [`super::drain`]).
    pub fn note_done(&mut self, id: ReplicaId) -> bool {
        if let Some(r) = self.get_mut(id) {
            r.in_flight = r.in_flight.saturating_sub(1);
            r.completed += 1;
            return r.health == HealthState::Draining && r.in_flight == 0;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    fn table_with(n: u16, policy: RoutePolicy) -> RoutingTable {
        let mut t = RoutingTable::new(policy, 4, 4);
        for p in 0..n {
            t.register(addr(9000 + p));
        }
        t
    }

    #[test]
    fn affinity_key_ignores_suffix_past_aligned_prefix() {
        let t = table_with(3, RoutePolicy::Affinity);
        let mut a = vec![b'S'; 64]; // 4 blocks of shared system prompt
        let mut b = a.clone();
        a.extend_from_slice(b"user question one");
        b.extend_from_slice(b"completely different tail");
        assert_eq!(t.affinity_key(&a), t.affinity_key(&b));
        // A different system prompt keys differently.
        let mut c = vec![b'T'; 64];
        c.extend_from_slice(b"user question one");
        assert_ne!(t.affinity_key(&a), t.affinity_key(&c));
        // Sub-block prompts have no affinity.
        assert_eq!(t.affinity_key(&[b'x'; BLOCK_TOKENS - 1]), None);
    }

    #[test]
    fn affinity_is_sticky_per_key() {
        let mut t = table_with(4, RoutePolicy::Affinity);
        let prompt = vec![b'p'; 48];
        let first = t.route(&prompt, &[]).unwrap();
        for _ in 0..10 {
            assert_eq!(t.route(&prompt, &[]), Some(first));
        }
    }

    #[test]
    fn rendezvous_remaps_only_the_lost_replicas_keys() {
        let mut t = table_with(4, RoutePolicy::Affinity);
        let prompts: Vec<Vec<u8>> = (0..40u8).map(|i| vec![i; 32]).collect();
        let before: Vec<ReplicaId> =
            prompts.iter().map(|p| t.route(p, &[]).unwrap()).collect();
        let victim = before[0];
        t.remove(victim);
        for (p, &owner) in prompts.iter().zip(&before) {
            let after = t.route(p, &[]).unwrap();
            if owner != victim {
                assert_eq!(after, owner, "surviving replicas keep their keys");
            } else {
                assert_ne!(after, victim);
            }
        }
    }

    #[test]
    fn affinity_overflows_to_least_loaded_past_slack() {
        let mut t = table_with(2, RoutePolicy::Affinity);
        let prompt = vec![b'h'; 32]; // hot prefix
        let owner = t.route(&prompt, &[]).unwrap();
        for _ in 0..=t.load_slack {
            t.note_dispatch(owner);
        }
        let spill = t.route(&prompt, &[]).unwrap();
        assert_ne!(spill, owner, "saturated owner overflows");
        // Draining the owner's load restores affinity.
        for _ in 0..=t.load_slack {
            t.note_done(owner);
        }
        assert_eq!(t.route(&prompt, &[]), Some(owner));
    }

    #[test]
    fn routing_skips_down_draining_and_excluded() {
        let mut t = table_with(3, RoutePolicy::LeastLoaded);
        let ids: Vec<ReplicaId> = t.replicas.iter().map(|r| r.id).collect();
        t.get_mut(ids[0]).unwrap().health = HealthState::Down;
        t.get_mut(ids[1]).unwrap().health = HealthState::Draining;
        assert_eq!(t.route(b"", &[]), Some(ids[2]));
        assert_eq!(t.route(b"", &[ids[2]]), None, "everything excluded or unroutable");
    }

    #[test]
    fn suspect_is_a_last_resort() {
        let mut t = table_with(2, RoutePolicy::LeastLoaded);
        let ids: Vec<ReplicaId> = t.replicas.iter().map(|r| r.id).collect();
        t.get_mut(ids[0]).unwrap().health = HealthState::Suspect;
        // A healthy replica wins even when busier.
        for _ in 0..5 {
            t.note_dispatch(ids[1]);
        }
        assert_eq!(t.route(b"", &[]), Some(ids[1]));
        // With no healthy replica left, suspect still serves.
        t.get_mut(ids[1]).unwrap().health = HealthState::Down;
        assert_eq!(t.route(b"", &[]), Some(ids[0]));
    }

    #[test]
    fn random_policy_is_seeded_and_spreads() {
        let runs = |seed: u64| -> Vec<ReplicaId> {
            let mut t = table_with(3, RoutePolicy::Random { seed });
            (0..30).map(|_| t.route(b"same prompt", &[]).unwrap()).collect()
        };
        assert_eq!(runs(5), runs(5), "seeded draws replay");
        let picks = runs(5);
        let distinct: std::collections::BTreeSet<_> = picks.iter().collect();
        assert!(distinct.len() > 1, "random routing spreads the same prompt");
    }

    #[test]
    fn reregistering_a_down_replica_makes_it_suspect() {
        let mut t = table_with(1, RoutePolicy::Affinity);
        let id = t.replicas[0].id;
        t.get_mut(id).unwrap().health = HealthState::Down;
        let again = t.register(addr(9000));
        assert_eq!(again, id, "same address keeps its id");
        assert_eq!(t.replicas[0].health, HealthState::Suspect);
    }

    #[test]
    fn note_done_flags_drained_replicas() {
        let mut t = table_with(1, RoutePolicy::Affinity);
        let id = t.replicas[0].id;
        t.note_dispatch(id);
        t.note_dispatch(id);
        t.get_mut(id).unwrap().health = HealthState::Draining;
        assert!(!t.note_done(id), "still one in flight");
        assert!(t.note_done(id), "last one out signals removal");
    }
}
