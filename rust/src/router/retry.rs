//! Bounded retry with exponential backoff and seeded full jitter.
//!
//! A request is re-routed only while it is provably unstarted from the
//! client's point of view — zero relayed deltas (see
//! [`crate::server::ClientError::is_retryable`]).  Backoff delays are
//! drawn from the deterministic in-tree RNG, streamed per request id, so
//! a seeded storm test replays the exact same retry timing.

use std::time::Duration;

use crate::util::rng::Rng;

/// Retry tuning.
#[derive(Debug, Clone)]
pub struct RetryConfig {
    /// Total attempts per request, including the first (>= 1).
    pub max_attempts: usize,
    /// First backoff window; doubles each retry.
    pub base: Duration,
    /// Backoff window ceiling.
    pub cap: Duration,
    /// Jitter seed; combined with the request id so concurrent requests
    /// draw independent (but reproducible) delays.
    pub seed: u64,
}

impl Default for RetryConfig {
    fn default() -> RetryConfig {
        RetryConfig {
            max_attempts: 3,
            base: Duration::from_millis(20),
            cap: Duration::from_secs(1),
            seed: 0,
        }
    }
}

/// SplitMix64 finalizer — decorrelates (seed, request id) into an RNG
/// stream, mirroring how `faults::FaultPlan` keys its per-site streams.
pub(crate) fn mix(a: u64, b: u64) -> u64 {
    let mut z = a ^ b.rotate_left(31) ^ 0x9E37_79B9_7F4A_7C15;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Per-request backoff schedule: full jitter, i.e. each delay is uniform
/// in `[0, min(cap, base << attempt))`.
pub struct Backoff {
    rng: Rng,
    base: Duration,
    cap: Duration,
    attempt: u32,
}

impl Backoff {
    pub fn new(cfg: &RetryConfig, request_id: u64) -> Backoff {
        Backoff {
            rng: Rng::new(mix(cfg.seed, request_id)),
            base: cfg.base,
            cap: cfg.cap,
            attempt: 0,
        }
    }

    /// The delay to sleep before the next attempt.
    pub fn next_delay(&mut self) -> Duration {
        let window = self
            .base
            .saturating_mul(1u32 << self.attempt.min(16))
            .min(self.cap);
        self.attempt = self.attempt.saturating_add(1);
        let nanos = window.as_nanos() as u64;
        if nanos == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.rng.below(nanos as usize + 1) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delays_are_bounded_by_doubling_window_and_cap() {
        let cfg = RetryConfig {
            max_attempts: 8,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(50),
            seed: 7,
        };
        let mut b = Backoff::new(&cfg, 1);
        for attempt in 0..8u32 {
            let window = cfg.base.saturating_mul(1 << attempt).min(cfg.cap);
            let d = b.next_delay();
            assert!(d <= window, "attempt {attempt}: {d:?} > {window:?}");
        }
    }

    #[test]
    fn same_seed_same_request_replays_identically() {
        let cfg = RetryConfig {
            seed: 42,
            ..RetryConfig::default()
        };
        let a: Vec<Duration> = {
            let mut b = Backoff::new(&cfg, 9);
            (0..5).map(|_| b.next_delay()).collect()
        };
        let b2: Vec<Duration> = {
            let mut b = Backoff::new(&cfg, 9);
            (0..5).map(|_| b.next_delay()).collect()
        };
        assert_eq!(a, b2);
    }

    #[test]
    fn different_requests_draw_independent_streams() {
        let cfg = RetryConfig {
            seed: 42,
            ..RetryConfig::default()
        };
        let a: Vec<Duration> = {
            let mut b = Backoff::new(&cfg, 1);
            (0..4).map(|_| b.next_delay()).collect()
        };
        let c: Vec<Duration> = {
            let mut b = Backoff::new(&cfg, 2);
            (0..4).map(|_| b.next_delay()).collect()
        };
        assert_ne!(a, c, "request ids must decorrelate the jitter");
    }
}
