//! Replica health tracking with hysteresis.
//!
//! Every replica carries a [`HealthState`] driven by two signal sources:
//! the periodic prober (a `{"health": true}` round-trip per interval) and
//! dispatch-time transport failures observed by the relay path.  The
//! state machine is deliberately asymmetric — one failure is enough to
//! *suspect* a replica (stop preferring it), but it takes
//! `down_after` consecutive failures to declare it down and `up_after`
//! consecutive successes to trust it again — so a single dropped probe
//! doesn't flap the routing table, and a replica that just came back
//! must prove itself before traffic returns.
//!
//! `Draining` is administrative, not observational: probes never enter
//! or leave it.  A draining replica accepts no new work and is removed
//! from the table once its in-flight count reaches zero (see
//! [`super::drain`]).

use std::time::Duration;

/// Replica lifecycle state.  Routability: `Healthy` replicas are
/// preferred, `Suspect` ones are a last resort, `Down` and `Draining`
/// never receive new work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    /// Probes passing; full routing weight.
    Healthy,
    /// At least one recent failure — deprioritised but not abandoned
    /// (used only when no healthy replica remains).
    Suspect,
    /// `down_after` consecutive failures; receives no traffic until
    /// `up_after` consecutive probe successes.
    Down,
    /// Administratively draining: no new work, in-flight sessions finish,
    /// then the replica is removed from the table.
    Draining,
}

impl HealthState {
    pub fn as_str(self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Suspect => "suspect",
            HealthState::Down => "down",
            HealthState::Draining => "draining",
        }
    }
}

/// Prober tuning.
#[derive(Debug, Clone)]
pub struct HealthConfig {
    /// Time between probe rounds.
    pub interval: Duration,
    /// Per-probe connect/read budget — probes want a short leash so a
    /// wedged replica can't stall the prober round.
    pub probe_timeout: Duration,
    /// Consecutive failures before `Suspect` becomes `Down`.
    pub down_after: u32,
    /// Consecutive successes before a `Suspect`/`Down` replica is
    /// trusted (`Healthy`) again.
    pub up_after: u32,
}

impl Default for HealthConfig {
    fn default() -> HealthConfig {
        HealthConfig {
            interval: Duration::from_secs(1),
            probe_timeout: Duration::from_millis(500),
            down_after: 3,
            up_after: 2,
        }
    }
}

/// Hysteresis counters, one set per replica.
#[derive(Debug, Clone, Copy, Default)]
pub struct Hysteresis {
    pub consecutive_failures: u32,
    pub consecutive_successes: u32,
}

/// Apply a successful probe.  Returns the next state.
pub fn note_success(state: HealthState, h: &mut Hysteresis, cfg: &HealthConfig) -> HealthState {
    h.consecutive_failures = 0;
    h.consecutive_successes = h.consecutive_successes.saturating_add(1);
    match state {
        HealthState::Draining => HealthState::Draining,
        HealthState::Healthy => HealthState::Healthy,
        HealthState::Suspect | HealthState::Down => {
            if h.consecutive_successes >= cfg.up_after {
                HealthState::Healthy
            } else {
                state
            }
        }
    }
}

/// Apply a failed probe (or a dispatch-time transport failure — both
/// mean "the replica did not answer").  Returns the next state.
pub fn note_failure(state: HealthState, h: &mut Hysteresis, cfg: &HealthConfig) -> HealthState {
    h.consecutive_successes = 0;
    h.consecutive_failures = h.consecutive_failures.saturating_add(1);
    match state {
        HealthState::Draining => HealthState::Draining,
        HealthState::Healthy => HealthState::Suspect,
        HealthState::Suspect => {
            if h.consecutive_failures >= cfg.down_after {
                HealthState::Down
            } else {
                HealthState::Suspect
            }
        }
        HealthState::Down => HealthState::Down,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HealthConfig {
        HealthConfig {
            down_after: 3,
            up_after: 2,
            ..HealthConfig::default()
        }
    }

    #[test]
    fn one_failure_suspects_three_down() {
        let cfg = cfg();
        let mut h = Hysteresis::default();
        let mut s = HealthState::Healthy;
        s = note_failure(s, &mut h, &cfg);
        assert_eq!(s, HealthState::Suspect, "first failure demotes immediately");
        s = note_failure(s, &mut h, &cfg);
        assert_eq!(s, HealthState::Suspect);
        s = note_failure(s, &mut h, &cfg);
        assert_eq!(s, HealthState::Down, "down_after consecutive failures");
    }

    #[test]
    fn recovery_needs_up_after_consecutive_successes() {
        let cfg = cfg();
        let mut h = Hysteresis::default();
        let mut s = HealthState::Down;
        h.consecutive_failures = 5;
        s = note_success(s, &mut h, &cfg);
        assert_eq!(s, HealthState::Down, "one success is not trust");
        s = note_success(s, &mut h, &cfg);
        assert_eq!(s, HealthState::Healthy);
        // A failure mid-recovery resets the success streak.
        let mut h = Hysteresis::default();
        let mut s = HealthState::Down;
        s = note_success(s, &mut h, &cfg);
        s = note_failure(s, &mut h, &cfg);
        s = note_success(s, &mut h, &cfg);
        assert_eq!(s, HealthState::Down, "streak was broken");
    }

    #[test]
    fn draining_is_sticky_under_probes() {
        let cfg = cfg();
        let mut h = Hysteresis::default();
        assert_eq!(
            note_success(HealthState::Draining, &mut h, &cfg),
            HealthState::Draining
        );
        for _ in 0..10 {
            assert_eq!(
                note_failure(HealthState::Draining, &mut h, &cfg),
                HealthState::Draining
            );
        }
    }
}
