//! Multi-replica front-end router: prefix-affinity routing, health
//! checks, bounded retry, proxied cancellation, graceful drain.
//!
//! The router fronts N replicas — each a [`crate::coordinator::Coordinator`]
//! behind the v2 JSON-lines protocol of [`crate::server`] — and speaks
//! the *same* protocol to clients, so a client cannot tell one replica
//! from a fleet.  Per request it:
//!
//! 1. picks a replica ([`table`]): prefix-affinity rendezvous hashing
//!    over the first `BLOCK_TOKENS`-aligned prompt chunks (requests
//!    sharing a system prompt land on the replica already holding it
//!    warm), overflowing to least-loaded past a slack bound; `Down` /
//!    `Draining` replicas never route;
//! 2. relays the stream with every `id` rewritten to the router-global
//!    one, always requesting replica mode upstream (`"ack": true`, see
//!    the server module docs) so cancellation can be proxied from any
//!    client connection even while the request is still queued;
//! 3. on failure, classifies: a fault with **zero relayed deltas**
//!    (connect refused, timeout, reset, replica `queue_full`) retries on
//!    another replica with exponential backoff + seeded jitter, bounded
//!    by [`retry::RetryConfig::max_attempts`]; a fault **after** deltas
//!    were relayed is never silently re-run — the client gets an
//!    explicit `{"error": "replica_failed", "retryable": false,
//!    "deltas_streamed": n}` marking the replay boundary.
//!
//! Error lines the router itself can emit (all carry the global `id`):
//! `no_replicas` (nothing routable), `replica_unavailable` (+`attempts`,
//! retry budget exhausted), `replica_failed` (+`deltas_streamed`).
//! Replica-origin request errors (`bad_request`, `too_large`, and
//! `queue_full`/`timeout` once retries are spent or deltas flowed) are
//! relayed as-is.
//!
//! A prober thread drives per-replica health with hysteresis
//! ([`health`]: `Healthy → Suspect → Down` and back, `Draining` is
//! admin-only), and admin lines manage the fleet over the same socket:
//! `{"admin": "status"}`, `{"admin": "register", "replica": "h:p"}`,
//! `{"admin": "drain", "replica": "h:p"}`.  `{"health": true}` answers
//! with fleet-level gauges.  [`chaos`] provides the seeded kill /
//! restart / stall harness the storm tests drive.

pub mod chaos;
pub mod drain;
pub mod health;
pub mod retry;
pub mod table;

pub use health::{HealthConfig, HealthState};
pub use retry::RetryConfig;
pub use table::{ReplicaId, RoutePolicy, RoutingTable};

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::router::health::{note_failure, note_success};
use crate::router::retry::Backoff;
use crate::router::table::ProbeGauges;
use crate::server::{client_health, drain_oversized_line, read_line_bounded, LineRead};
use crate::util::json::{self, Value};
use crate::util::threadpool::ThreadPool;

/// Router tuning.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub policy: RoutePolicy,
    /// Client-connection handler threads.
    pub conn_threads: usize,
    /// Client request-line byte cap (same hardening as the server).
    pub max_line_bytes: usize,
    /// Idle budget between client request lines.
    pub idle_read_timeout: Duration,
    /// Per-attempt TCP connect budget to a replica.
    pub connect_timeout: Duration,
    /// Per-event idle budget on an upstream stream.  Upstream relays are
    /// always streaming, so this bounds the gap between *events*, not a
    /// whole generation — a healthy long generation keeps renewing it.
    pub request_timeout: Duration,
    /// Prompt blocks hashed into the affinity key.
    pub affinity_blocks: usize,
    /// Affinity yields to least-loaded when the affine replica is this
    /// many requests busier.
    pub load_slack: usize,
    pub health: HealthConfig,
    pub retry: RetryConfig,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            policy: RoutePolicy::Affinity,
            conn_threads: 8,
            max_line_bytes: 256 * 1024,
            idle_read_timeout: Duration::from_secs(120),
            connect_timeout: Duration::from_secs(2),
            request_timeout: Duration::from_secs(120),
            affinity_blocks: 4,
            load_slack: 4,
            health: HealthConfig::default(),
            retry: RetryConfig::default(),
        }
    }
}

/// Router-level counters (monotonic; exposed via `{"admin": "status"}`).
#[derive(Debug, Default)]
pub struct RouterMetrics {
    /// Generation requests accepted from clients.
    pub requests: AtomicU64,
    /// Requests whose terminal line was relayed (including
    /// replica-reported request errors — the request *got its answer*).
    pub completed: AtomicU64,
    /// Re-route attempts performed.
    pub retries: AtomicU64,
    /// Streams that failed after deltas were relayed (`replica_failed`).
    pub broken_streams: AtomicU64,
    /// Requests that spent the whole retry budget (`replica_unavailable`).
    pub exhausted: AtomicU64,
    /// Requests refused because nothing was routable (`no_replicas`).
    pub no_replicas: AtomicU64,
    /// Cancellations forwarded to an owning replica.
    pub cancels_proxied: AtomicU64,
}

/// In-flight request registry entry: which replica owns the request and
/// (once the upstream ack arrives) its replica-local id.
struct ProxyEntry {
    replica_addr: SocketAddr,
    remote: Option<u64>,
    /// A cancel arrived before the upstream ack: the relay thread issues
    /// the upstream cancel itself as soon as it learns the remote id.
    cancel_requested: bool,
}

struct RouterState {
    table: Mutex<RoutingTable>,
    proxy: Mutex<HashMap<u64, ProxyEntry>>,
    metrics: RouterMetrics,
    cfg: RouterConfig,
    ids: AtomicU64,
}

pub struct RouterHandle {
    pub addr: SocketAddr,
    state: Arc<RouterState>,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl RouterHandle {
    /// Add a replica to the fleet (new requests may route to it
    /// immediately).
    pub fn register(&self, addr: SocketAddr) -> ReplicaId {
        self.state.table.lock().unwrap().register(addr)
    }

    /// Begin a graceful drain: no new work, in-flight finishes, then the
    /// replica leaves the table.  `None` if the address is unknown.
    pub fn drain(&self, addr: SocketAddr) -> Option<ReplicaId> {
        self.state.table.lock().unwrap().drain_addr(addr)
    }

    /// Replicas currently in the table (drained ones leave once idle).
    pub fn replica_count(&self) -> usize {
        self.state.table.lock().unwrap().len()
    }

    pub fn metrics(&self) -> &RouterMetrics {
        &self.state.metrics
    }

    /// The same JSON the `{"admin": "status"}` endpoint serves.
    pub fn status(&self) -> Value {
        status_value(&self.state)
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the acceptor so it notices the stop flag.
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Start the router on `addr` ("127.0.0.1:0" for an ephemeral port)
/// fronting `replicas` (more can be registered later).
pub fn serve_router(
    addr: &str,
    replicas: &[SocketAddr],
    cfg: RouterConfig,
) -> Result<RouterHandle> {
    let listener = TcpListener::bind(addr).context("bind router")?;
    let local = listener.local_addr()?;
    let mut table = RoutingTable::new(cfg.policy, cfg.affinity_blocks, cfg.load_slack);
    for &r in replicas {
        table.register(r);
    }
    let state = Arc::new(RouterState {
        table: Mutex::new(table),
        proxy: Mutex::new(HashMap::new()),
        metrics: RouterMetrics::default(),
        cfg,
        ids: AtomicU64::new(1),
    });
    let stop = Arc::new(AtomicBool::new(false));

    let st = Arc::clone(&state);
    let sp = Arc::clone(&stop);
    let prober = std::thread::Builder::new()
        .name("rap-router-prober".into())
        .spawn(move || prober_loop(st, sp))?;

    let st = Arc::clone(&state);
    let sp = Arc::clone(&stop);
    let acceptor = std::thread::Builder::new()
        .name("rap-router-acceptor".into())
        .spawn(move || {
            let pool = ThreadPool::new(st.cfg.conn_threads.max(1));
            for stream in listener.incoming() {
                if sp.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let st = Arc::clone(&st);
                pool.execute(move || handle_client(stream, st));
            }
        })?;

    Ok(RouterHandle {
        addr: local,
        state,
        stop,
        threads: vec![prober, acceptor],
    })
}

/// Health prober: one `{"health": true}` round-trip per replica per
/// interval, applied through the hysteresis machine; also sweeps idle
/// drained replicas out of the table.  Probes run without the table
/// lock so a slow replica can't stall routing.
fn prober_loop(state: Arc<RouterState>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        let targets: Vec<(ReplicaId, SocketAddr)> = {
            let t = state.table.lock().unwrap();
            t.replicas
                .iter()
                .filter(|r| r.health != HealthState::Draining)
                .map(|r| (r.id, r.addr))
                .collect()
        };
        for (id, addr) in targets {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            let probe = client_health(&addr, state.cfg.health.probe_timeout);
            let mut t = state.table.lock().unwrap();
            if let Some(r) = t.get_mut(id) {
                match probe {
                    Ok(v) => {
                        r.health = note_success(r.health, &mut r.hysteresis, &state.cfg.health);
                        r.gauges = Some(gauges_from(&v));
                    }
                    Err(_) => {
                        r.health = note_failure(r.health, &mut r.hysteresis, &state.cfg.health);
                    }
                }
            }
        }
        state.table.lock().unwrap().sweep_drained();
        // Sleep in slices so shutdown stays prompt.
        let mut slept = Duration::ZERO;
        while slept < state.cfg.health.interval && !stop.load(Ordering::SeqCst) {
            let step = Duration::from_millis(10).min(state.cfg.health.interval - slept);
            std::thread::sleep(step);
            slept += step;
        }
    }
}

fn gauges_from(v: &Value) -> ProbeGauges {
    let n = |k: &str| v.get(k).and_then(|x| x.as_i64()).unwrap_or(0) as u64;
    ProbeGauges {
        pending: n("pending"),
        used_blocks: n("used_blocks"),
        capacity_blocks: n("capacity_blocks"),
        prefix_hits: n("prefix_hits"),
        prefix_lookups: n("prefix_lookups"),
    }
}

fn status_value(state: &RouterState) -> Value {
    let replicas: Vec<Value> = {
        let t = state.table.lock().unwrap();
        t.replicas
            .iter()
            .map(|r| {
                let mut fields = vec![
                    ("addr", json::s(r.addr.to_string())),
                    ("state", json::s(r.health.as_str())),
                    ("in_flight", json::num(r.in_flight as f64)),
                    ("dispatched", json::num(r.dispatched as f64)),
                    ("completed", json::num(r.completed as f64)),
                ];
                if let Some(g) = r.gauges {
                    fields.push(("pending", json::num(g.pending as f64)));
                    fields.push(("used_blocks", json::num(g.used_blocks as f64)));
                    fields.push(("prefix_hits", json::num(g.prefix_hits as f64)));
                    fields.push(("prefix_lookups", json::num(g.prefix_lookups as f64)));
                }
                json::obj(fields)
            })
            .collect()
    };
    let m = &state.metrics;
    let c = |a: &AtomicU64| json::num(a.load(Ordering::Relaxed) as f64);
    json::obj(vec![
        ("replicas", json::arr(replicas)),
        ("requests", c(&m.requests)),
        ("completed", c(&m.completed)),
        ("retries", c(&m.retries)),
        ("broken_streams", c(&m.broken_streams)),
        ("exhausted", c(&m.exhausted)),
        ("no_replicas", c(&m.no_replicas)),
        ("cancels_proxied", c(&m.cancels_proxied)),
    ])
}

/// Clone `v` with its `"id"` replaced — every relayed line carries the
/// router-global id, never the replica-local one.
fn with_id(v: &Value, id: u64) -> Value {
    match v {
        Value::Obj(m) => {
            let mut m = m.clone();
            m.insert("id".to_string(), json::num(id as f64));
            Value::Obj(m)
        }
        other => other.clone(),
    }
}

/// Open a fresh connection to the replica and cancel `remote` there.  A
/// fresh connection is required: the connection relaying the request is
/// single-duplex by protocol (the replica reads the next line only after
/// the current request's stream ends).
fn send_upstream_cancel(addr: &SocketAddr, remote: u64, timeout: Duration) -> bool {
    let Ok(mut s) = TcpStream::connect_timeout(addr, timeout) else {
        return false;
    };
    let _ = s.set_read_timeout(Some(timeout));
    let req = json::obj(vec![("cancel", json::num(remote as f64))]);
    if writeln!(s, "{req}").is_err() {
        return false;
    }
    let mut reader = BufReader::new(s);
    let mut line = String::new();
    matches!(reader.read_line(&mut line), Ok(n) if n > 0)
}

/// Proxy `{"cancel": gid}` to whichever replica owns the request.  An
/// unknown id (already finished, never existed) is an acked no-op, same
/// as the single-server semantics.
fn proxy_cancel(state: &RouterState, gid: u64) {
    let target = {
        let mut proxy = state.proxy.lock().unwrap();
        match proxy.get_mut(&gid) {
            None => None,
            Some(e) => match e.remote {
                Some(remote) => Some((e.replica_addr, remote)),
                None => {
                    // Upstream id not known yet: flag it; the relay
                    // thread cancels as soon as the ack arrives.
                    e.cancel_requested = true;
                    None
                }
            },
        }
    };
    if let Some((addr, remote)) = target {
        state.metrics.cancels_proxied.fetch_add(1, Ordering::Relaxed);
        let _ = send_upstream_cancel(&addr, remote, state.cfg.connect_timeout);
    }
}

fn handle_admin(state: &RouterState, v: &Value, cmd: &str) -> Value {
    let replica_addr = || -> Option<SocketAddr> {
        v.get("replica")
            .and_then(|r| r.as_str())
            .and_then(|s| s.parse().ok())
    };
    match cmd {
        "status" => status_value(state),
        "register" => match replica_addr() {
            Some(addr) => {
                let id = state.table.lock().unwrap().register(addr);
                json::obj(vec![
                    ("ok", Value::Bool(true)),
                    ("registered", json::s(addr.to_string())),
                    ("replica_id", json::num(id as f64)),
                ])
            }
            None => json::obj(vec![
                ("error", json::s("bad_request")),
                ("field", json::s("replica")),
            ]),
        },
        "drain" => match replica_addr() {
            Some(addr) => match state.table.lock().unwrap().drain_addr(addr) {
                Some(_) => json::obj(vec![
                    ("ok", Value::Bool(true)),
                    ("draining", json::s(addr.to_string())),
                ]),
                None => json::obj(vec![("error", json::s("unknown_replica"))]),
            },
            None => json::obj(vec![
                ("error", json::s("bad_request")),
                ("field", json::s("replica")),
            ]),
        },
        _ => json::obj(vec![
            ("error", json::s("bad_request")),
            ("field", json::s("admin")),
        ]),
    }
}

fn handle_client(stream: TcpStream, state: Arc<RouterState>) {
    let _ = stream.set_read_timeout(Some(state.cfg.idle_read_timeout));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match read_line_bounded(&mut reader, &mut line, state.cfg.max_line_bytes) {
            LineRead::Closed => break,
            LineRead::TooLong => {
                let reply = json::obj(vec![
                    ("error", json::s("bad_request")),
                    ("field", json::s("line")),
                ]);
                let _ = writeln!(out, "{reply}");
                drain_oversized_line(&mut reader, state.cfg.max_line_bytes);
                break;
            }
            LineRead::Line => {}
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let v = match json::parse(trimmed) {
            Ok(v) => v,
            Err(e) => {
                let reply = json::obj(vec![("error", json::s(format!("bad json: {e}")))]);
                if writeln!(out, "{reply}").is_err() {
                    break;
                }
                continue;
            }
        };
        if let Some(cmd) = v.get("admin").and_then(|a| a.as_str()) {
            let reply = handle_admin(&state, &v, cmd);
            if writeln!(out, "{reply}").is_err() {
                break;
            }
            continue;
        }
        if v.get("health").and_then(|h| h.as_bool()).unwrap_or(false) {
            let (total, healthy) = {
                let t = state.table.lock().unwrap();
                let healthy = t
                    .replicas
                    .iter()
                    .filter(|r| r.health == HealthState::Healthy)
                    .count();
                (t.len(), healthy)
            };
            let reply = json::obj(vec![
                ("ok", Value::Bool(true)),
                ("replicas", json::num(total as f64)),
                ("healthy", json::num(healthy as f64)),
            ]);
            if writeln!(out, "{reply}").is_err() {
                break;
            }
            continue;
        }
        if let Some(cid) = v.get("cancel").and_then(|c| c.as_i64()) {
            proxy_cancel(&state, cid as u64);
            let ack = json::obj(vec![
                ("cancel", json::num(cid as f64)),
                ("ok", Value::Bool(true)),
            ]);
            if writeln!(out, "{ack}").is_err() {
                break;
            }
            continue;
        }
        let gid = state.ids.fetch_add(1, Ordering::SeqCst);
        if !relay_request(&state, &mut out, gid, &v) {
            break;
        }
    }
}

/// How one relay attempt ended.
enum RelayEnd {
    /// The terminal line reached the client — the request is answered.
    Served,
    /// The client connection died; upstream was cancelled.
    ClientGone,
    /// Replayable: the failure provably produced no client-visible
    /// output.  `transport` distinguishes a replica-health signal
    /// (connect/reset/timeout) from mere backpressure (`queue_full`).
    Retry {
        reason: &'static str,
        transport: bool,
    },
    /// Failed *after* deltas were relayed: never replayed; the client
    /// gets an explicit error marking the boundary.
    Broken { reason: String, deltas: usize },
}

/// Relay one generation request end to end, retrying across replicas
/// while that is provably safe.  Returns `false` when the client
/// connection itself is gone.
fn relay_request(state: &Arc<RouterState>, out: &mut TcpStream, gid: u64, body: &Value) -> bool {
    state.metrics.requests.fetch_add(1, Ordering::Relaxed);
    let prompt: Vec<u8> = body
        .get("prompt")
        .and_then(|p| p.as_str())
        .unwrap_or("")
        .as_bytes()
        .to_vec();
    let client_stream = body.get("stream").and_then(|s| s.as_bool()).unwrap_or(false);
    let client_ack = body.get("ack").and_then(|a| a.as_bool()).unwrap_or(false);
    let mut backoff = Backoff::new(&state.cfg.retry, gid);
    let mut tried: Vec<ReplicaId> = Vec::new();
    let mut last_reason = "";
    let max_attempts = state.cfg.retry.max_attempts.max(1);
    for attempt in 0..max_attempts {
        let pick = {
            let mut t = state.table.lock().unwrap();
            let picked = t
                .route(&prompt, &tried)
                // Every candidate already tried: allow repeats (a replica
                // that answered queue_full may have drained by now)
                // rather than giving up early.
                .or_else(|| t.route(&prompt, &[]));
            picked.and_then(|id| t.addr_of(id).map(|a| (id, a)))
        };
        let Some((rid, raddr)) = pick else {
            state.metrics.no_replicas.fetch_add(1, Ordering::Relaxed);
            let reply = json::obj(vec![
                ("id", json::num(gid as f64)),
                ("error", json::s("no_replicas")),
                ("retryable", Value::Bool(true)),
            ]);
            return writeln!(out, "{reply}").is_ok();
        };
        state.table.lock().unwrap().note_dispatch(rid);
        state.proxy.lock().unwrap().insert(
            gid,
            ProxyEntry {
                replica_addr: raddr,
                remote: None,
                cancel_requested: false,
            },
        );
        let end = relay_once(state, out, gid, body, raddr, client_stream, client_ack);
        state.proxy.lock().unwrap().remove(&gid);
        {
            let mut t = state.table.lock().unwrap();
            if t.note_done(rid) {
                t.sweep_drained();
            }
        }
        match end {
            RelayEnd::Served => {
                state.metrics.completed.fetch_add(1, Ordering::Relaxed);
                return true;
            }
            RelayEnd::ClientGone => return false,
            RelayEnd::Broken { reason, deltas } => {
                note_transport_failure(state, rid);
                state.metrics.broken_streams.fetch_add(1, Ordering::Relaxed);
                let reply = json::obj(vec![
                    ("id", json::num(gid as f64)),
                    ("error", json::s("replica_failed")),
                    ("retryable", Value::Bool(false)),
                    ("deltas_streamed", json::num(deltas as f64)),
                    ("reason", json::s(reason)),
                ]);
                return writeln!(out, "{reply}").is_ok();
            }
            RelayEnd::Retry { reason, transport } => {
                tried.push(rid);
                last_reason = reason;
                if transport {
                    note_transport_failure(state, rid);
                }
                if attempt + 1 < max_attempts {
                    state.metrics.retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(backoff.next_delay());
                }
            }
        }
    }
    state.metrics.exhausted.fetch_add(1, Ordering::Relaxed);
    let reply = json::obj(vec![
        ("id", json::num(gid as f64)),
        ("error", json::s("replica_unavailable")),
        ("retryable", Value::Bool(true)),
        ("attempts", json::num(max_attempts as f64)),
        ("reason", json::s(last_reason)),
    ]);
    writeln!(out, "{reply}").is_ok()
}

/// A dispatch-time transport failure is a health signal, same as a
/// failed probe.
fn note_transport_failure(state: &RouterState, id: ReplicaId) {
    let mut t = state.table.lock().unwrap();
    if let Some(r) = t.get_mut(id) {
        r.health = note_failure(r.health, &mut r.hysteresis, &state.cfg.health);
    }
}

/// One attempt against one replica: forward the request (forced
/// streaming + ack upstream), relay lines back with the id rewritten,
/// classify whatever ends the exchange.
fn relay_once(
    state: &Arc<RouterState>,
    out: &mut TcpStream,
    gid: u64,
    body: &Value,
    raddr: SocketAddr,
    client_stream: bool,
    client_ack: bool,
) -> RelayEnd {
    let cfg = &state.cfg;
    // Upstream body: the client's fields, with streaming + replica-mode
    // ack forced on.  Streaming upstream even for one-shot clients turns
    // `request_timeout` into a per-event liveness bound instead of a
    // whole-generation one.
    let mut fields: Vec<(&str, Value)> =
        vec![("stream", Value::Bool(true)), ("ack", Value::Bool(true))];
    let owned: Vec<(String, Value)> = body
        .as_obj()
        .map(|m| m.iter().map(|(k, v)| (k.clone(), v.clone())).collect())
        .unwrap_or_default();
    for (k, v) in &owned {
        if k != "stream" && k != "ack" {
            fields.push((k.as_str(), v.clone()));
        }
    }
    let upstream_body = json::obj(fields);
    let up = match TcpStream::connect_timeout(&raddr, cfg.connect_timeout) {
        Ok(s) => s,
        Err(_) => {
            return RelayEnd::Retry {
                reason: "connect",
                transport: true,
            }
        }
    };
    let _ = up.set_read_timeout(Some(cfg.request_timeout));
    let mut reader = BufReader::new(match up.try_clone() {
        Ok(s) => s,
        Err(_) => {
            return RelayEnd::Retry {
                reason: "connect",
                transport: true,
            }
        }
    });
    let mut up_w = up;
    if writeln!(up_w, "{upstream_body}").is_err() {
        return RelayEnd::Retry {
            reason: "write",
            transport: true,
        };
    }
    let mut remote: Option<u64> = None;
    let mut deltas_relayed = 0usize;
    let mut line = String::new();
    loop {
        line.clear();
        let n = match reader.read_line(&mut line) {
            Ok(n) => n,
            Err(_) => {
                return upstream_failed(&raddr, remote, deltas_relayed, "upstream_timeout", cfg);
            }
        };
        if n == 0 {
            return upstream_failed(&raddr, remote, deltas_relayed, "upstream_closed", cfg);
        }
        let v = match json::parse(line.trim()) {
            Ok(v) => v,
            Err(_) => {
                // Protocol violation — never replayed (a rerun can't fix
                // a broken peer), surfaced like a post-delta break.
                if let Some(r) = remote {
                    let _ = send_upstream_cancel(&raddr, r, cfg.connect_timeout);
                }
                return RelayEnd::Broken {
                    reason: "malformed_frame".to_string(),
                    deltas: deltas_relayed,
                };
            }
        };
        if v.get("ack").and_then(|a| a.as_bool()).unwrap_or(false) {
            remote = v.get("id").and_then(|i| i.as_i64()).map(|i| i as u64);
            let cancel_now = {
                let mut proxy = state.proxy.lock().unwrap();
                match proxy.get_mut(&gid) {
                    Some(e) => {
                        e.remote = remote;
                        e.cancel_requested
                    }
                    None => false,
                }
            };
            if cancel_now {
                if let Some(r) = remote {
                    state.metrics.cancels_proxied.fetch_add(1, Ordering::Relaxed);
                    let _ = send_upstream_cancel(&raddr, r, cfg.connect_timeout);
                }
            }
            if client_ack && writeln!(out, "{}", with_id(&v, gid)).is_err() {
                if let Some(r) = remote {
                    let _ = send_upstream_cancel(&raddr, r, cfg.connect_timeout);
                }
                return RelayEnd::ClientGone;
            }
            continue;
        }
        let is_delta = v.get("delta").is_some();
        if is_delta || v.get("event").is_some() {
            // One-shot clients never see deltas/lifecycle lines — and
            // since nothing was relayed, their requests stay replayable
            // for the whole generation.
            if client_stream {
                if writeln!(out, "{}", with_id(&v, gid)).is_err() {
                    if let Some(r) = remote {
                        let _ = send_upstream_cancel(&raddr, r, cfg.connect_timeout);
                    }
                    return RelayEnd::ClientGone;
                }
                if is_delta {
                    deltas_relayed += 1;
                }
            }
            continue;
        }
        // Terminal line.  Replica-side backpressure and nothing-streamed
        // timeouts are replayable; everything else is the request's
        // answer and gets relayed.
        if let Some(err) = v.get("error").and_then(|e| e.as_str()) {
            if err == "queue_full" {
                return RelayEnd::Retry {
                    reason: "queue_full",
                    transport: false,
                };
            }
            if err == "timeout" && deltas_relayed == 0 {
                return RelayEnd::Retry {
                    reason: "replica_timeout",
                    transport: true,
                };
            }
        }
        return if writeln!(out, "{}", with_id(&v, gid)).is_ok() {
            RelayEnd::Served
        } else {
            RelayEnd::ClientGone
        };
    }
}

/// The upstream connection failed (timeout / reset / close).  The
/// replica may still be computing — cancel explicitly so a retry can't
/// leave duplicate work running — then classify by whether the client
/// saw output.
fn upstream_failed(
    raddr: &SocketAddr,
    remote: Option<u64>,
    deltas_relayed: usize,
    reason: &'static str,
    cfg: &RouterConfig,
) -> RelayEnd {
    if let Some(r) = remote {
        let _ = send_upstream_cancel(raddr, r, cfg.connect_timeout);
    }
    if deltas_relayed == 0 {
        RelayEnd::Retry {
            reason,
            transport: true,
        }
    } else {
        RelayEnd::Broken {
            reason: reason.to_string(),
            deltas: deltas_relayed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn with_id_rewrites_preserving_other_fields() {
        let v = json::parse(r#"{"id": 4, "delta": "ab"}"#).unwrap();
        let w = with_id(&v, 99);
        assert_eq!(w.get("id").and_then(|i| i.as_i64()), Some(99));
        assert_eq!(w.get("delta").and_then(|d| d.as_str()), Some("ab"));
        // The original is untouched.
        assert_eq!(v.get("id").and_then(|i| i.as_i64()), Some(4));
    }

    #[test]
    fn default_config_is_affinity_with_bounded_retry() {
        let cfg = RouterConfig::default();
        assert_eq!(cfg.policy, RoutePolicy::Affinity);
        assert!(cfg.retry.max_attempts >= 2, "retry must actually retry");
        assert!(cfg.connect_timeout < cfg.request_timeout);
    }
}
