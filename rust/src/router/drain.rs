//! Graceful drain: stop admitting, let in-flight sessions finish, then
//! remove the replica from the table.
//!
//! Draining is a three-step contract spread across the router:
//!
//! 1. an admin marks the replica [`HealthState::Draining`] (here) — the
//!    routing policy in [`super::table`] stops offering it new work the
//!    same instant, while its in-flight relays keep streaming;
//! 2. every relay completion calls [`super::table::RoutingTable::note_done`],
//!    which reports when a draining replica's in-flight count hits zero;
//! 3. the reporter (relay path or prober sweep) then removes the entry —
//!    the prober sweep covers the case where the replica was already idle
//!    when the drain was requested, so `note_done` never fires.

use std::net::SocketAddr;

use crate::router::health::HealthState;
use crate::router::table::{ReplicaId, RoutingTable};

impl RoutingTable {
    /// Begin draining the replica with this id.  Idempotent; `false` if
    /// the id is unknown.
    pub fn drain(&mut self, id: ReplicaId) -> bool {
        match self.get_mut(id) {
            Some(r) => {
                r.health = HealthState::Draining;
                true
            }
            None => false,
        }
    }

    /// [`RoutingTable::drain`] addressed by socket address (the admin
    /// endpoint speaks addresses, not internal ids).
    pub fn drain_addr(&mut self, addr: SocketAddr) -> Option<ReplicaId> {
        let id = self.by_addr_mut(addr)?.id;
        self.drain(id);
        Some(id)
    }

    /// Remove every draining replica whose in-flight count has reached
    /// zero.  Called from the prober loop so an idle replica leaves the
    /// table promptly even when no relay completion is left to notice.
    pub fn sweep_drained(&mut self) -> Vec<ReplicaId> {
        let done: Vec<ReplicaId> = self
            .replicas
            .iter()
            .filter(|r| r.health == HealthState::Draining && r.in_flight == 0)
            .map(|r| r.id)
            .collect();
        for &id in &done {
            self.remove(id);
        }
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::table::RoutePolicy;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    #[test]
    fn draining_replica_stops_receiving_work_immediately() {
        let mut t = RoutingTable::new(RoutePolicy::LeastLoaded, 4, 4);
        let a = t.register(addr(9100));
        let b = t.register(addr(9101));
        // Make `a` the clear least-loaded winner, then drain it.
        for _ in 0..3 {
            t.note_dispatch(b);
        }
        assert_eq!(t.route(b"", &[]), Some(a));
        assert!(t.drain(a));
        assert_eq!(t.route(b"", &[]), Some(b), "drained replica is unroutable");
    }

    #[test]
    fn busy_drained_replica_leaves_only_after_last_completion() {
        let mut t = RoutingTable::new(RoutePolicy::LeastLoaded, 4, 4);
        let a = t.register(addr(9102));
        t.note_dispatch(a);
        t.drain(a);
        assert!(t.sweep_drained().is_empty(), "in-flight work pins the entry");
        assert_eq!(t.len(), 1);
        assert!(t.note_done(a), "last completion signals removal");
        t.remove(a);
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn idle_drained_replica_is_swept() {
        let mut t = RoutingTable::new(RoutePolicy::LeastLoaded, 4, 4);
        let a = t.register(addr(9103));
        let b = t.register(addr(9104));
        assert_eq!(t.drain_addr(addr(9103)), Some(a));
        assert_eq!(t.drain_addr(addr(9999)), None, "unknown address");
        assert_eq!(t.sweep_drained(), vec![a]);
        assert_eq!(t.len(), 1);
        assert!(t.addr_of(b).is_some());
    }
}
