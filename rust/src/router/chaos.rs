//! Deterministic replica-fault harness for router storm tests.
//!
//! Two pieces, seeded like [`crate::faults::FaultPlan`]:
//!
//! * [`ChaosPlan`] — a precomputed, replayable schedule of fleet-level
//!   actions (kill / restart / stall / unstall, one batch per request
//!   index).  Generation is stateful so the schedule is always
//!   *survivable*: at least one replica stays alive **and** unstalled at
//!   every step, which is what lets the storm test demand that every
//!   request terminates deterministically.
//! * [`StallBackend`] — a [`Backend`] wrapper whose [`StallSwitch`] can
//!   freeze the scheduler thread mid-prefill or mid-decode from outside.
//!   A stalled replica keeps accepting connections and answering health
//!   probes from its handler threads (with going-stale gauges) — the
//!   realistic "alive but wedged" failure the router's per-request
//!   timeout exists for, distinct from the connection-refused failure of
//!   a killed replica.
//!
//! The harness itself (spawning real servers, applying the actions) lives
//! in `tests/router.rs`, where the engine factories are.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::scheduler::Backend;
use crate::coordinator::RequestId;
use crate::kvcache::PagedKvCache;
use crate::router::retry::mix;
use crate::util::rng::Rng;

/// One fleet-level action, applied just before dispatching the request
/// with the matching index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosAction {
    /// Shut the replica's server down (connections start failing).
    Kill { replica: usize },
    /// Bring a killed replica back (fresh server, cold caches).
    Restart { replica: usize },
    /// Flip the replica's [`StallSwitch`] on — its scheduler freezes at
    /// the next backend call.
    Stall { replica: usize },
    /// Release a stalled replica.
    Unstall { replica: usize },
}

impl ChaosAction {
    pub fn replica(&self) -> usize {
        match *self {
            ChaosAction::Kill { replica }
            | ChaosAction::Restart { replica }
            | ChaosAction::Stall { replica }
            | ChaosAction::Unstall { replica } => replica,
        }
    }
}

/// Per-step action probabilities.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    pub kill_rate: f64,
    /// Chance per step that one dead replica restarts.
    pub restart_rate: f64,
    pub stall_rate: f64,
    /// Chance per step that one stalled replica is released.
    pub unstall_rate: f64,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            kill_rate: 0.10,
            restart_rate: 0.45,
            stall_rate: 0.10,
            unstall_rate: 0.45,
        }
    }
}

/// A replayable fleet-fault schedule: `steps[i]` is applied before
/// request `i` is dispatched.
#[derive(Debug, Clone)]
pub struct ChaosPlan {
    pub seed: u64,
    steps: Vec<Vec<ChaosAction>>,
}

impl ChaosPlan {
    /// Generate the schedule.  Requires at least two replicas — with one
    /// there is nothing to fail over to, so every kill would be vetoed
    /// and the plan degenerates.
    pub fn generate(
        seed: u64,
        n_replicas: usize,
        n_steps: usize,
        cfg: &ChaosConfig,
    ) -> ChaosPlan {
        assert!(n_replicas >= 2, "chaos needs a failover target");
        let mut rng = Rng::new(mix(seed, 0x4348_414F_535F_5631)); // "CHAOS_V1"
        let mut alive = vec![true; n_replicas];
        let mut stalled = vec![false; n_replicas];
        let mut steps = Vec::with_capacity(n_steps);
        // A replica can serve traffic iff alive and not stalled; the
        // generator refuses any action that would leave zero such
        // replicas, keeping every schedule survivable.
        let serviceable = |alive: &[bool], stalled: &[bool]| {
            alive.iter().zip(stalled).filter(|(a, s)| **a && !**s).count()
        };
        let pick = |rng: &mut Rng, mask: &[bool]| -> Option<usize> {
            let cands: Vec<usize> = (0..mask.len()).filter(|&i| mask[i]).collect();
            if cands.is_empty() {
                None
            } else {
                Some(cands[rng.below(cands.len())])
            }
        };
        for _ in 0..n_steps {
            let mut acts = Vec::new();
            // Recoveries first so a step can free capacity before it
            // breaks something else.
            if rng.f64() < cfg.restart_rate {
                let dead: Vec<bool> = alive.iter().map(|a| !a).collect();
                if let Some(r) = pick(&mut rng, &dead) {
                    alive[r] = true;
                    stalled[r] = false;
                    acts.push(ChaosAction::Restart { replica: r });
                }
            }
            if rng.f64() < cfg.unstall_rate {
                if let Some(r) = pick(&mut rng, &stalled) {
                    stalled[r] = false;
                    acts.push(ChaosAction::Unstall { replica: r });
                }
            }
            if rng.f64() < cfg.kill_rate {
                let can_kill: Vec<bool> = (0..n_replicas)
                    .map(|i| {
                        alive[i] && {
                            let margin = if stalled[i] { 0 } else { 1 };
                            serviceable(&alive, &stalled) > margin
                        }
                    })
                    .collect();
                if let Some(r) = pick(&mut rng, &can_kill) {
                    alive[r] = false;
                    acts.push(ChaosAction::Kill { replica: r });
                }
            }
            if rng.f64() < cfg.stall_rate {
                let can_stall: Vec<bool> = (0..n_replicas)
                    .map(|i| alive[i] && !stalled[i] && serviceable(&alive, &stalled) > 1)
                    .collect();
                if let Some(r) = pick(&mut rng, &can_stall) {
                    stalled[r] = true;
                    acts.push(ChaosAction::Stall { replica: r });
                }
            }
            steps.push(acts);
        }
        ChaosPlan { seed, steps }
    }

    pub fn actions_at(&self, step: usize) -> &[ChaosAction] {
        self.steps.get(step).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// (kills, restarts, stalls, unstalls) across the whole schedule —
    /// storm tests assert the plan actually exercised something.
    pub fn counts(&self) -> (usize, usize, usize, usize) {
        let mut c = (0, 0, 0, 0);
        for a in self.steps.iter().flatten() {
            match a {
                ChaosAction::Kill { .. } => c.0 += 1,
                ChaosAction::Restart { .. } => c.1 += 1,
                ChaosAction::Stall { .. } => c.2 += 1,
                ChaosAction::Unstall { .. } => c.3 += 1,
            }
        }
        c
    }
}

/// Shared on/off switch a test can flip to freeze a replica's backend.
#[derive(Debug, Clone, Default)]
pub struct StallSwitch(Arc<AtomicBool>);

impl StallSwitch {
    pub fn new() -> StallSwitch {
        StallSwitch::default()
    }

    pub fn set(&self, stalled: bool) {
        self.0.store(stalled, Ordering::SeqCst);
    }

    pub fn is_stalled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// [`Backend`] wrapper that blocks every compute call while its switch
/// is on.  Unlike `FaultBackend`'s seeded slow ticks (bounded, baked
/// into the plan), this is an externally controlled freeze of unbounded
/// length — the shape of a replica wedged on a sick accelerator.
pub struct StallBackend<B> {
    inner: B,
    switch: StallSwitch,
    poll: Duration,
}

impl<B: Backend> StallBackend<B> {
    pub fn new(inner: B, switch: StallSwitch) -> StallBackend<B> {
        StallBackend {
            inner,
            switch,
            poll: Duration::from_millis(2),
        }
    }

    fn hold(&self) {
        while self.switch.is_stalled() {
            std::thread::sleep(self.poll);
        }
    }
}

impl<B: Backend> Backend for StallBackend<B> {
    fn s_max(&self) -> usize {
        self.inner.s_max()
    }

    fn wants_paged_storage(&self) -> bool {
        self.inner.wants_paged_storage()
    }

    fn supports_chunked_prefill(&self) -> bool {
        self.inner.supports_chunked_prefill()
    }

    fn prefill(
        &mut self,
        kv: &mut PagedKvCache,
        session: RequestId,
        prompt: &[u8],
    ) -> Result<Vec<f32>> {
        self.hold();
        self.inner.prefill(kv, session, prompt)
    }

    fn prefill_chunk(
        &mut self,
        kv: &mut PagedKvCache,
        session: RequestId,
        tokens: &[u8],
        pos0: usize,
        last: bool,
    ) -> Result<Option<Vec<f32>>> {
        self.hold();
        self.inner.prefill_chunk(kv, session, tokens, pos0, last)
    }

    fn decode_batch(
        &mut self,
        kv: &mut PagedKvCache,
        entries: &[(RequestId, u8, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        self.hold();
        self.inner.decode_batch(kv, entries)
    }

    fn drop_session(&mut self, session: RequestId) {
        // Teardown is never stalled, mirroring FaultBackend: the
        // coordinator must always be able to release a session.
        self.inner.drop_session(session);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_replay_and_seeds_differ() {
        let cfg = ChaosConfig::default();
        let a = ChaosPlan::generate(3, 3, 64, &cfg);
        let b = ChaosPlan::generate(3, 3, 64, &cfg);
        assert_eq!(a.steps, b.steps, "same seed, same schedule");
        let c = ChaosPlan::generate(4, 3, 64, &cfg);
        assert_ne!(a.steps, c.steps, "different seed, different schedule");
    }

    #[test]
    fn every_schedule_keeps_one_serviceable_replica() {
        for seed in 0..20u64 {
            let plan = ChaosPlan::generate(seed, 3, 128, &ChaosConfig::default());
            let mut alive = [true; 3];
            let mut stalled = [false; 3];
            for step in 0..plan.len() {
                for a in plan.actions_at(step) {
                    match *a {
                        ChaosAction::Kill { replica } => alive[replica] = false,
                        ChaosAction::Restart { replica } => {
                            assert!(!alive[replica], "seed {seed}: restart of a live replica");
                            alive[replica] = true;
                            stalled[replica] = false;
                        }
                        ChaosAction::Stall { replica } => {
                            assert!(alive[replica], "seed {seed}: stall of a dead replica");
                            stalled[replica] = true;
                        }
                        ChaosAction::Unstall { replica } => stalled[replica] = false,
                    }
                }
                let serviceable = alive
                    .iter()
                    .zip(&stalled)
                    .filter(|(a, s)| **a && !**s)
                    .count();
                assert!(serviceable >= 1, "seed {seed} step {step} wedged the fleet");
            }
        }
    }

    #[test]
    fn default_rates_exercise_kills_and_stalls() {
        let plan = ChaosPlan::generate(7, 3, 200, &ChaosConfig::default());
        let (kills, restarts, stalls, _) = plan.counts();
        assert!(kills >= 3, "got {kills} kills");
        assert!(restarts >= 1, "got {restarts} restarts");
        assert!(stalls >= 3, "got {stalls} stalls");
    }

    /// Minimal backend for the stall test.
    struct Instant0;

    impl Backend for Instant0 {
        fn s_max(&self) -> usize {
            64
        }
        fn prefill(
            &mut self,
            _kv: &mut PagedKvCache,
            _session: RequestId,
            _prompt: &[u8],
        ) -> Result<Vec<f32>> {
            Ok(vec![0.0; 256])
        }
        fn decode_batch(
            &mut self,
            _kv: &mut PagedKvCache,
            entries: &[(RequestId, u8, usize)],
        ) -> Result<Vec<Vec<f32>>> {
            Ok(entries.iter().map(|_| vec![0.0; 256]).collect())
        }
        fn drop_session(&mut self, _session: RequestId) {}
    }

    #[test]
    fn stall_switch_freezes_and_releases_backend_calls() {
        let switch = StallSwitch::new();
        switch.set(true);
        let sw2 = switch.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        let t = std::thread::spawn(move || {
            let mut b = StallBackend::new(Instant0, sw2);
            let shape = crate::kvcache::CacheShape {
                n_layers: 1,
                n_kv_heads: 1,
                k_width: vec![4],
                v_width: vec![4],
            };
            let mut kv = PagedKvCache::new(shape, 1 << 20);
            b.prefill(&mut kv, 1, &[1, 2]).unwrap();
            let _ = tx.send(());
        });
        // While stalled, the call must not complete.
        assert!(
            rx.recv_timeout(Duration::from_millis(50)).is_err(),
            "stalled backend completed a call"
        );
        switch.set(false);
        rx.recv_timeout(Duration::from_secs(5))
            .expect("released backend never completed");
        t.join().unwrap();
    }
}
