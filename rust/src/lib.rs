//! # RAP — KV-Cache Compression via RoPE-Aligned Pruning
//!
//! Production-quality reproduction of *RAP: KV-Cache Compression via
//! RoPE-Aligned Pruning* (Xin et al., 2026) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): index-aware
//!   non-contiguous RoPE and fused latent-KV decode attention, AOT-lowered.
//! * **L2** — JAX model + the offline RAP pipeline (`python/compile/`):
//!   Fisher scoring, Algorithm-2 budgets, pair pruning, B-absorption,
//!   KD+LoRA recovery; exported as HLO text + weight binaries.
//! * **L3** — this crate: the serving coordinator (router, continuous
//!   batcher, latent-width-aware paged KV cache), the PJRT runtime that
//!   executes the AOT artifacts, a pure-Rust reference engine, the analytic
//!   cost model, and the full experiments harness regenerating every table
//!   and figure in the paper.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `rap` binary is self-contained.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod eval;
pub mod experiments;
pub mod faults;
pub mod kvcache;
pub mod manifest;
pub mod model;
pub mod rap;
pub mod rope;
pub mod router;
pub mod runtime;
pub mod server;
pub mod speculate;
pub mod tensor;
pub mod util;
pub mod workload;
