//! Native implementations of the factorization baselines the paper
//! compares against (§6.1): naive per-head truncated SVD (Eq. 1) and
//! PaLU-style whitened SVD with B_v absorption.
//!
//! The shipped artifacts are produced by the Python pipeline; these native
//! versions exist so the full comparison can also be constructed and
//! property-tested in Rust (used by the `plan` CLI and unit suites).

pub mod palu;
pub mod svd;
