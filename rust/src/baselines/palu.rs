//! PaLU-style whitened SVD + B_v absorption (native mirror of
//! `python/compile/rap/palu.py`).
//!
//! Whitening: with activation covariance C = S S^T (Cholesky), truncating
//! the SVD of S^T W minimises ||X (W − Ŵ)||_F rather than ||W − Ŵ||_F —
//! the same data-aware objective PaLU/SVD-LLM use.

use crate::config::ModelConfig;
use crate::tensor::linalg::{cholesky, solve_upper_from_lower, svd_thin};
use crate::tensor::ops::matmul;
use crate::tensor::Tensor;

/// Whitened per-head truncated SVD.
/// `w`: [D, H*dh]; `cov`: [D, D] accumulated X^T X; returns
/// (A [D, H*rank], B per head [rank, dh]).
pub fn whitened_svd_per_head(
    w: &Tensor,
    cov: &Tensor,
    n_heads: usize,
    rank: usize,
    damp: f64,
) -> (Tensor, Vec<Tensor>) {
    let (d, hd) = w.dims2();
    let dh = hd / n_heads;
    // Damped covariance keeps Cholesky well-posed.
    let mut c = cov.clone();
    let trace: f64 = (0..d).map(|i| c.at2(i, i) as f64).sum();
    let eps = (damp * trace / d as f64) as f32;
    for i in 0..d {
        c.data[i * d + i] += eps;
    }
    let s_mat = cholesky(&c); // lower L with C = L L^T

    let mut a = Tensor::zeros(vec![d, n_heads * rank]);
    let mut bs = Vec::with_capacity(n_heads);
    for h in 0..n_heads {
        let cols: Vec<usize> = (h * dh..(h + 1) * dh).collect();
        let wh = w.gather_cols(&cols);
        let wp = matmul(&s_mat.transpose2(), &wh); // S^T W
        let (u, sv, v) = svd_thin(&wp);
        // U_r Σ_r
        let mut ur = Tensor::zeros(vec![d, rank]);
        for i in 0..d {
            for r in 0..rank {
                ur.data[i * rank + r] = u.data[i * dh + r] * sv[r];
            }
        }
        // A_h = S^{-T} U_r Σ_r : solve S^T A = U_r Σ_r.
        let a_h = solve_upper_from_lower(&s_mat, &ur);
        for i in 0..d {
            for r in 0..rank {
                a.data[i * (n_heads * rank) + h * rank + r] = a_h.data[i * rank + r];
            }
        }
        let mut b = Tensor::zeros(vec![rank, dh]);
        for r in 0..rank {
            for j in 0..dh {
                b.data[r * dh + j] = v.data[j * dh + r];
            }
        }
        bs.push(b);
    }
    (a, bs)
}

/// Absorb B_v into W_o (GQA-aware): query head h consumes KV head
/// g = h / group's latent V, so its [dh, D] row block of W_o becomes
/// B_v[g] @ block, of shape [rv, D].
pub fn absorb_bv_into_wo(cfg: &ModelConfig, wo: &Tensor, b_v: &[Tensor]) -> Tensor {
    let (hd, d) = wo.dims2();
    let dh = cfg.head_dim;
    assert_eq!(hd, cfg.n_heads * dh);
    let rv = b_v[0].dims2().0;
    let mut out = Tensor::zeros(vec![cfg.n_heads * rv, d]);
    for h in 0..cfg.n_heads {
        let g = h / cfg.group_size();
        let block = wo.slice_rows(h * dh, (h + 1) * dh); // [dh, D]
        let absorbed = matmul(&b_v[g], &block); // [rv, D]
        out.data[h * rv * d..(h + 1) * rv * d].copy_from_slice(&absorbed.data);
    }
    out
}

/// Activation-space reconstruction error tr((W−Ŵ)^T C (W−Ŵ)) for one head.
pub fn activation_error(w_h: &Tensor, a_h: &Tensor, b_h: &Tensor, cov: &Tensor) -> f64 {
    let rec = matmul(a_h, b_h);
    let (d, dh) = w_h.dims2();
    let mut dw = Tensor::zeros(vec![d, dh]);
    for i in 0..d * dh {
        dw.data[i] = w_h.data[i] - rec.data[i];
    }
    let cd = matmul(cov, &dw); // [D, dh]
    let mut tr = 0.0f64;
    for i in 0..d {
        for j in 0..dh {
            tr += dw.data[i * dh + j] as f64 * cd.data[i * dh + j] as f64;
        }
    }
    tr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::svd::truncated_svd_per_head;
    use crate::config::Pairing;
    use crate::util::rng::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 16,
            d_model: 20,
            n_layers: 1,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 8,
            mlp_hidden: 16,
            max_seq: 32,
            rope_theta: 10_000.0,
            pairing: Pairing::Half,
            norm_eps: 1e-5,
        }
    }

    fn spd_cov(d: usize, rng: &mut Rng) -> Tensor {
        let x = Tensor::randn(vec![4 * d, d], 1.0, rng);
        matmul(&x.transpose2(), &x)
    }

    #[test]
    fn whitened_full_rank_exact() {
        let mut rng = Rng::new(1);
        let c = cfg();
        let w = Tensor::randn(vec![c.d_model, c.kv_dim()], 1.0, &mut rng);
        let cov = spd_cov(c.d_model, &mut rng);
        let (a, bs) = whitened_svd_per_head(&w, &cov, c.n_kv_heads, c.head_dim, 1e-8);
        // reconstruct and compare
        for h in 0..c.n_kv_heads {
            let cols: Vec<usize> = (h * c.head_dim..(h + 1) * c.head_dim).collect();
            let wh = w.gather_cols(&cols);
            let rank = c.head_dim;
            let acols: Vec<usize> = (h * rank..(h + 1) * rank).collect();
            let ah = a.gather_cols(&acols);
            let rec = matmul(&ah, &bs[h]);
            assert!(wh.max_abs_diff(&rec) < 1e-2, "head {h}: {}", wh.max_abs_diff(&rec));
        }
    }

    #[test]
    fn whitened_beats_plain_in_activation_norm() {
        let mut rng = Rng::new(2);
        let c = cfg();
        let w = Tensor::randn(vec![c.d_model, c.kv_dim()], 1.0, &mut rng);
        // strongly anisotropic covariance so whitening matters
        let mut cov = spd_cov(c.d_model, &mut rng);
        for i in 0..c.d_model {
            let scale = if i < 4 { 50.0 } else { 1.0 };
            for j in 0..c.d_model {
                cov.data[i * c.d_model + j] *= scale;
                cov.data[j * c.d_model + i] *= scale;
            }
        }
        let rank = 3;
        let (a_w, b_w) = whitened_svd_per_head(&w, &cov, c.n_kv_heads, rank, 1e-6);
        let (a_p, b_p) = truncated_svd_per_head(&w, c.n_kv_heads, rank);
        for h in 0..c.n_kv_heads {
            let cols: Vec<usize> = (h * c.head_dim..(h + 1) * c.head_dim).collect();
            let wh = w.gather_cols(&cols);
            let aw = a_w.gather_cols(&(h * rank..(h + 1) * rank).collect::<Vec<_>>());
            let ap = a_p.gather_cols(&(h * rank..(h + 1) * rank).collect::<Vec<_>>());
            let ew = activation_error(&wh, &aw, &b_w[h], &cov);
            let ep = activation_error(&wh, &ap, &b_p[h], &cov);
            assert!(ew <= ep * 1.01, "head {h}: whitened {ew} vs plain {ep}");
        }
    }

    #[test]
    fn absorb_bv_shapes_and_values() {
        let mut rng = Rng::new(3);
        let c = cfg();
        let wo = Tensor::randn(vec![c.q_dim(), c.d_model], 1.0, &mut rng);
        let rv = 3;
        let b_v: Vec<Tensor> = (0..c.n_kv_heads)
            .map(|_| Tensor::randn(vec![rv, c.head_dim], 1.0, &mut rng))
            .collect();
        let wo_t = absorb_bv_into_wo(&c, &wo, &b_v);
        assert_eq!(wo_t.dims2(), (c.n_heads * rv, c.d_model));
        // functional identity: (p @ B_v[g]) @ wo_block == p @ absorbed_block
        let p = Tensor::randn(vec![1, rv], 1.0, &mut rng);
        for h in 0..c.n_heads {
            let g = h / c.group_size();
            let full = matmul(
                &matmul(&p, &b_v[g]),
                &wo.slice_rows(h * c.head_dim, (h + 1) * c.head_dim),
            );
            let absorbed = matmul(&p, &wo_t.slice_rows(h * rv, (h + 1) * rv));
            assert!(full.max_abs_diff(&absorbed) < 1e-4);
        }
    }
}
