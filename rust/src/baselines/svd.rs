//! Per-head truncated SVD factorization (paper Eq. 1):
//! W_h ≈ A_h B_h with A = U_r Σ_r^{1/2}, B = Σ_r^{1/2} V_r^T.

use crate::tensor::{svd_thin, Tensor};

/// Factorize each head block of `w` [D, H*dh] at `rank`; returns
/// (A [D, H*rank], B per head [rank, dh]).
pub fn truncated_svd_per_head(
    w: &Tensor,
    n_heads: usize,
    rank: usize,
) -> (Tensor, Vec<Tensor>) {
    let (d, hd) = w.dims2();
    let dh = hd / n_heads;
    assert!(rank >= 1 && rank <= dh);
    let mut a = Tensor::zeros(vec![d, n_heads * rank]);
    let mut bs = Vec::with_capacity(n_heads);
    for h in 0..n_heads {
        let cols: Vec<usize> = (h * dh..(h + 1) * dh).collect();
        let wh = w.gather_cols(&cols); // [D, dh]
        let (u, s, v) = svd_thin(&wh);
        let mut b = Tensor::zeros(vec![rank, dh]);
        for r in 0..rank {
            let sq = s[r].max(0.0).sqrt();
            for i in 0..d {
                a.data[i * (n_heads * rank) + h * rank + r] = u.data[i * dh + r] * sq;
            }
            for j in 0..dh {
                b.data[r * dh + j] = sq * v.data[j * dh + r];
            }
        }
        bs.push(b);
    }
    (a, bs)
}

/// Relative Frobenius reconstruction error over all heads.
pub fn reconstruction_error(w: &Tensor, a: &Tensor, bs: &[Tensor], n_heads: usize) -> f64 {
    let (d, hd) = w.dims2();
    let dh = hd / n_heads;
    let rank = a.shape[1] / n_heads;
    let mut err = 0.0f64;
    let mut base = 0.0f64;
    for h in 0..n_heads {
        for i in 0..d {
            for j in 0..dh {
                let mut rec = 0.0f64;
                for r in 0..rank {
                    rec += a.data[i * (n_heads * rank) + h * rank + r] as f64
                        * bs[h].data[r * dh + j] as f64;
                }
                let orig = w.data[i * hd + h * dh + j] as f64;
                err += (orig - rec) * (orig - rec);
                base += orig * orig;
            }
        }
    }
    (err / base).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn full_rank_is_exact() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(vec![24, 16], 1.0, &mut rng); // 2 heads of dh=8
        let (a, bs) = truncated_svd_per_head(&w, 2, 8);
        assert!(reconstruction_error(&w, &a, &bs, 2) < 1e-4);
    }

    #[test]
    fn error_monotone_in_rank() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(vec![32, 24], 1.0, &mut rng); // 2 heads of dh=12
        let mut prev = f64::INFINITY;
        for rank in [2, 4, 8, 12] {
            let (a, bs) = truncated_svd_per_head(&w, 2, rank);
            let e = reconstruction_error(&w, &a, &bs, 2);
            assert!(e <= prev + 1e-9, "rank {rank}: {e} > {prev}");
            prev = e;
        }
    }

    #[test]
    fn shapes() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(vec![16, 12], 1.0, &mut rng);
        let (a, bs) = truncated_svd_per_head(&w, 3, 2);
        assert_eq!(a.dims2(), (16, 6));
        assert_eq!(bs.len(), 3);
        assert_eq!(bs[0].dims2(), (2, 4));
    }
}
