//! The serve loop: admission → chunked prefill → continuous decode, over
//! an abstract `Backend` (PJRT or pure-Rust engine).
//!
//! API v2: `tick` returns a stream of [`Event`]s — one `Token` per
//! sampled token (the first is emitted the moment its prompt's final
//! prefill chunk completes, before any decode round: that is the
//! streamed TTFT) and a terminal `Finished` carrying the full
//! [`Response`] and its [`FinishReason`].  Each request samples through
//! its own seeded [`Sampler`] (temperature 0 ≡ the v1 argmax path,
//! bit-identical), can end early on a byte-level stop sequence, and can
//! be torn down mid-flight — queued, prefilling, or decoding — by
//! [`Coordinator::cancel`], which releases its KV reservation (including
//! shared prefix-block refcounts) immediately.
//!
//! Prefill is Sarathi-style chunked: each tick spends at most
//! `BatcherConfig::prefill_chunk_tokens` prompt tokens (fed to
//! `Backend::prefill_chunk`) before running its decode round, so a long
//! prompt admitted mid-stream delays in-flight decode sessions by at most
//! one chunk — `AggregateMetrics::max_prefill_chunks_between_decodes`
//! tracks the realised bound.
//!
//! Admission is prefix-aware (storage-backed caches only): the batcher
//! consults the `kvcache::prefix` trie, attaches any resident
//! block-aligned prompt prefix read-only, and prefill starts at
//! `pos0 = matched_tokens` — the shared prefix is neither recomputed nor
//! stored again.  Prefill strictly FIFO-orders sessions, so a sharer's
//! first chunk always runs after the session that registered the prefix
//! finished prefilling it (its rows exist before anyone reads them).
//!
//! ## Oversubscription and preemption
//!
//! Admission is optimistic (prompt-only reservation; see
//! [`BatcherConfig::reserve_worst_case`]), so decode-time growth can hit
//! a genuinely full cache.  Each tick grows every decodable session by
//! one KV row in **admission order** (oldest first) *before* the decode
//! round; when a growth allocation fails the scheduler preempts the
//! newest admission instead of erroring:
//!
//! * a still-prefilling session (always the newest) is requeued at the
//!   queue *front* with its KV state released — it re-admits, re-reserves
//!   and re-prefills from scratch (usually cheaply, via the prefix cache);
//! * otherwise the newest-seniority *running* session — possibly the very
//!   session being grown — is parked: its blocks are released, an
//!   [`Event::Preempted`] is emitted, and its sampler + generated tokens
//!   are kept.  Parked sessions resume with priority over fresh
//!   admissions: the scheduler re-reserves `prompt ++ generated[..n-1]`,
//!   re-prefills it through the normal chunked path (discarding the final
//!   chunk's logits — the token they name was already emitted), emits
//!   [`Event::Resumed`], and decoding continues **bit-identically** to an
//!   uncontended run;
//! * a lone session on a genuinely exhausted cache (nothing to preempt,
//!   nothing cold to evict) finishes early with `Length`.
//!
//! Injected faults (see [`crate::faults`]) are recognised by downcast and
//! handled as transients: an allocator fault defers that session's decode
//! one tick, and a backend fault retries the same prefill chunk / skips
//! the decode round, bounded by a consecutive-failure circuit breaker.

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::batcher::{Admission, Batcher, BatcherConfig};
use crate::coordinator::metrics::{AggregateMetrics, RequestMetrics};
use crate::coordinator::request::{Event, FinishReason, Request, RequestId, Response};
use crate::coordinator::sampling::Sampler;
use crate::faults::{FaultPlan, InjectedFault};
use crate::kvcache::retention::Press;
use crate::kvcache::{CacheShape, KvStorageMode, PagedKvCache, BLOCK_TOKENS};
use crate::speculate::accept::accept_step;
use crate::speculate::draft::{Drafter, NgramDrafter};
use crate::speculate::verify::draft_budget;

/// Consecutive injected backend failures tolerated before the scheduler
/// stops treating them as transient and propagates the error.  Far above
/// any plausible storm; purely a circuit breaker against a backend that
/// fails every call forever.
const MAX_CONSECUTIVE_BACKEND_FAULTS: u32 = 64;

/// Why [`Coordinator::try_submit`] refused a request.  Both count toward
/// `AggregateMetrics::rejected`, but the server reports them differently:
/// `queue_full` is transient backpressure worth retrying, `too_large`
/// never becomes admissible.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at capacity, or the id is already queued/running.
    QueueFull,
    /// The prompt alone needs more blocks than the cache physically has;
    /// this request could not be admitted even on an idle server.
    PromptTooLarge,
}

/// Model-execution backend.  The coordinator owns the paged KV allocator
/// and passes it into every call: backends that want real paged storage
/// (`wants_paged_storage`, e.g. the pure-Rust engine) read and write latent
/// rows through its page tables, while backends with external KV state
/// (PJRT's re-uploaded literals) use it for accounting only and ignore the
/// handle.
pub trait Backend {
    /// Max cache length per session.
    fn s_max(&self) -> usize;
    /// Whether the coordinator should allocate latent K/V storage behind
    /// the paged allocator (`PagedKvCache::with_storage`).
    fn wants_paged_storage(&self) -> bool {
        false
    }
    /// Storage mode for the coordinator-owned paged cache: plain f32 rows,
    /// or nibble-packed int4 rows for backends whose kernels attend
    /// directly over packed blocks (`KernelPath::FusedInt4`).  Only
    /// meaningful together with [`Backend::wants_paged_storage`].
    fn kv_storage_mode(&self) -> KvStorageMode {
        KvStorageMode::F32
    }
    /// Create session state and run the prompt; returns last-token logits.
    fn prefill(&mut self, kv: &mut PagedKvCache, session: RequestId, prompt: &[u8])
        -> Result<Vec<f32>>;
    /// Whether `prefill_chunk` can resume a partially-fed prompt
    /// (`pos0 > 0`).  Backends answering `false` are only ever handed the
    /// whole prompt in one call.
    fn supports_chunked_prefill(&self) -> bool {
        false
    }
    /// Run one bounded chunk of `session`'s prompt: `tokens` sit at
    /// positions `[pos0, pos0 + len)`, `last` marks the chunk holding the
    /// prompt's final token.  Returns `Some(last-token logits)` on the last
    /// chunk, `None` otherwise.  The default forwards whole prompts to
    /// [`Backend::prefill`] for backends without chunk support.
    fn prefill_chunk(
        &mut self,
        kv: &mut PagedKvCache,
        session: RequestId,
        tokens: &[u8],
        pos0: usize,
        last: bool,
    ) -> Result<Option<Vec<f32>>> {
        if pos0 != 0 || !last {
            anyhow::bail!("backend does not support chunked prefill");
        }
        self.prefill(kv, session, tokens).map(Some)
    }
    /// One decode step for a batch of (session, token, position).
    /// Returns logits per entry, in order.
    fn decode_batch(
        &mut self,
        kv: &mut PagedKvCache,
        entries: &[(RequestId, u8, usize)],
    ) -> Result<Vec<Vec<f32>>>;
    /// Verify a speculative draft: feed `tokens` — the session's last
    /// emitted token followed by its draft — at logical positions
    /// `pos0, pos0 + 1, ..`, writing their KV rows, and return one logits
    /// row per fed token (row `i` names the token after the stream
    /// through `tokens[i]`).  The caller has already reserved the rows
    /// and rolls rejected ones back afterwards.  The default runs the
    /// feed as sequential single-token decode steps — semantically
    /// identical, no speedup; backends with a blocked multi-token
    /// forward override it (see `RustBackend::verify_chunk`).
    fn verify_chunk(
        &mut self,
        kv: &mut PagedKvCache,
        session: RequestId,
        tokens: &[u8],
        pos0: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let mut rows = Vec::with_capacity(tokens.len());
        for (i, &t) in tokens.iter().enumerate() {
            let mut lg = self.decode_batch(kv, &[(session, t, pos0 + i)])?;
            rows.push(lg.pop().ok_or_else(|| anyhow!("decode_batch returned no logits"))?);
        }
        Ok(rows)
    }
    /// Drop a finished session's state (its KV blocks are released by the
    /// coordinator via the batcher).
    fn drop_session(&mut self, session: RequestId);
}

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    /// KV memory budget in bytes for the paged allocator.
    pub kv_budget_bytes: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            batcher: BatcherConfig::default(),
            kv_budget_bytes: 64 << 20,
        }
    }
}

struct Running {
    req: Request,
    /// Per-request seeded sampler; `generated.last()` is always the next
    /// token the backend consumes (the v2 decode loop samples token i+1
    /// from the logits of feeding token i).
    sampler: Sampler,
    generated: Vec<u8>,
    pos: usize,
    /// Admission seniority (monotonic): growth runs oldest-first and the
    /// newest admission is always the preemption victim, so the set of
    /// sessions that make progress under pressure is deterministic.
    seq: u64,
    ttft_ms: f64,
    queue_ms: f64,
    decode_ms: f64,
    /// Decode steps billed to `decode_ms` (backend calls: single-token
    /// rounds and speculative verify chunks each count once) — a
    /// multi-token accepted step must not be billed per emitted token.
    decode_steps: u64,
    started: Instant,
    /// Self-drafting state for a `req.speculative` session (built at
    /// prefill completion, rebuilt from `prompt ++ generated` on resume).
    /// Advisory only: a lost drafter can never change emitted tokens.
    drafter: Option<NgramDrafter>,
    /// Set the instant a finish condition is met (length / stop); the
    /// end-of-tick sweep releases the session and emits `Finished`.
    finish: Option<FinishReason>,
}

/// A running session parked by preemption: its KV blocks are gone but its
/// sampler state and generated tokens are intact.  Resume re-reserves and
/// re-prefills `prompt ++ generated[..n-1]`, then decoding continues from
/// exactly where it stopped.
struct ParkedSession {
    req: Request,
    sampler: Sampler,
    generated: Vec<u8>,
    seq: u64,
    ttft_ms: f64,
    queue_ms: f64,
    decode_ms: f64,
    decode_steps: u64,
    started: Instant,
    /// Logical positions of the KV rows that survived this session's
    /// retention presses, captured at preemption (restricted to the replay
    /// feed `[0, prompt + generated - 1)`).  `None` for retain-all
    /// sessions, which resume through the seed recompute path.
    survivors: Option<Vec<u32>>,
}

/// State a resumed session carries through its recompute prefill; restored
/// into [`Running`] (with the final chunk's logits discarded) when the
/// prefill completes.
struct ResumeCtx {
    sampler: Sampler,
    generated: Vec<u8>,
    ttft_ms: f64,
    decode_ms: f64,
    decode_steps: u64,
    /// Logical decode position to restore (`prompt + generated - 1`); for
    /// retain-all resumes this equals the replay feed length, for pruned
    /// resumes it exceeds the (survivor-only) feed length.
    pos: usize,
    /// Survivor positions (pruned resume only), kept so a session
    /// re-parked mid-recompute replays the same survivor set.
    survivors: Option<Vec<u32>>,
}

/// Does `generated` end with any of the request's stop sequences?
/// Matched against generated bytes only (never the prompt); the matched
/// bytes stay in the output, so streamed deltas never have to be
/// retracted.  Empty stop sequences are ignored.
fn stop_hit(stop: &[Vec<u8>], generated: &[u8]) -> bool {
    stop.iter().any(|s| !s.is_empty() && generated.ends_with(s))
}

/// Finish decision after appending a token: stop sequences win over the
/// simultaneous length limit, and `pos >= s_max` ends a session that can
/// no longer write KV rows.
fn finish_check(req: &Request, generated: &[u8], pos: usize, s_max: usize) -> Option<FinishReason> {
    if stop_hit(&req.stop, generated) {
        Some(FinishReason::Stop)
    } else if generated.len() >= req.max_new || pos >= s_max {
        Some(FinishReason::Length)
    } else {
        None
    }
}

/// How a speculative step for one session resolved this tick.
enum SpecStep {
    /// The verify chunk ran; tokens were emitted and rows rolled back.
    Done,
    /// No step was possible (no draft, budget 0, allocation refused) —
    /// the session joins this tick's plain decode round.
    Fallback,
    /// A transient backend fault consumed the attempt; the session sits
    /// this round out and retries next tick (mirrors the plain round's
    /// fault handling — nothing advanced).
    Skipped,
}

/// An admitted request whose prompt (or, on resume, prompt + replayed
/// generation) is still being fed chunk-by-chunk.
struct Prefilling {
    req: Request,
    /// Prompt tokens already in the cache: fed to the backend by earlier
    /// chunks, or covered by shared prefix blocks at admission (prefill
    /// then starts at `matched_tokens` and never recomputes the prefix).
    done: usize,
    /// Admission seniority, preserved across preemption and resume.
    seq: u64,
    queue_ms: f64,
    /// Admission instant — TTFT spans from here (including any decode
    /// rounds interleaved between this prompt's chunks).
    started: Instant,
    /// Recompute feed for a resumed session (`prompt ++ generated[..n-1]`);
    /// `None` for a fresh admission, which prefills `req.prompt`.
    feed: Option<Vec<u8>>,
    /// Whether `done` indexes *rows* rather than logical positions: true
    /// only for a pruned session's survivor replay, whose feed holds one
    /// token per surviving row (`reserve_with_positions` carries the
    /// logical positions).  Retain-all feeds are logical (row == position).
    row_feed: bool,
    /// Present iff this is a preemption resume.
    resume: Option<ResumeCtx>,
}

impl Prefilling {
    fn feed(&self) -> &[u8] {
        self.feed.as_deref().unwrap_or(&self.req.prompt)
    }
}

/// Synchronous coordinator: drives a backend over a stream of requests.
/// The server wraps it in a thread; benches call `run_to_completion`.
pub struct Coordinator<B: Backend> {
    pub backend: B,
    batcher: Batcher,
    kv: PagedKvCache,
    /// Admitted requests still mid-prefill, oldest first.
    prefilling: VecDeque<Prefilling>,
    running: BTreeMap<RequestId, Running>,
    /// Preempted sessions awaiting resume, oldest first.
    preempted: VecDeque<ParkedSession>,
    pub metrics: AggregateMetrics,
    finished: Vec<Response>,
    /// Prefill chunks run since the last decode round while decodable
    /// sessions were waiting (feeds `max_prefill_chunks_between_decodes`).
    stalled_chunks: u64,
    /// Monotonic admission counter feeding `Running::seq`.
    admission_seq: u64,
    /// Reusable scratch for speculative steps (draft tokens and the
    /// verify feed) — taken and returned per step, never reallocated in
    /// steady state.
    draft_buf: Vec<u8>,
    feed_buf: Vec<u8>,
    /// Injected backend failures since the last successful call (circuit
    /// breaker: past `MAX_CONSECUTIVE_BACKEND_FAULTS` they propagate).
    consecutive_backend_faults: u32,
}

impl<B: Backend> Coordinator<B> {
    pub fn new(backend: B, shape: CacheShape, cfg: CoordinatorConfig) -> Coordinator<B> {
        let kv = if backend.wants_paged_storage() {
            let mut kv = PagedKvCache::with_storage_mode(
                shape,
                cfg.kv_budget_bytes,
                backend.kv_storage_mode(),
            );
            // Storage-backed caches keep released prefix chunks resident
            // (evictable) so repeated prompts and preemption resumes skip
            // recompute; accounting-only caches have no rows to keep.
            kv.retain_cold_prefixes(true);
            kv
        } else {
            PagedKvCache::new(shape, cfg.kv_budget_bytes)
        };
        let metrics = AggregateMetrics {
            kv_storage_mode: kv.storage_mode().name(),
            ..AggregateMetrics::default()
        };
        Coordinator {
            backend,
            batcher: Batcher::new(cfg.batcher),
            kv,
            prefilling: VecDeque::new(),
            running: BTreeMap::new(),
            preempted: VecDeque::new(),
            metrics,
            finished: Vec::new(),
            stalled_chunks: 0,
            admission_seq: 0,
            draft_buf: Vec::new(),
            feed_buf: Vec::new(),
            consecutive_backend_faults: 0,
        }
    }

    /// Install (or clear) a seeded allocator fault plan; backend-call
    /// faults are layered separately by wrapping the backend in
    /// [`crate::coordinator::FaultBackend`].
    pub fn set_fault_plan(&mut self, plan: Option<&FaultPlan>) {
        self.kv.set_alloc_faults(plan.map(|p| p.alloc_injector()));
    }

    /// Toggle cold-prefix retention on the underlying allocator (on by
    /// default for storage-backed caches).
    pub fn retain_cold_prefixes(&mut self, on: bool) {
        self.kv.retain_cold_prefixes(on);
    }

    /// Submit a request; `Err` carries the distinct rejection reason.
    pub fn try_submit(&mut self, mut req: Request) -> Result<(), SubmitError> {
        req.arrival = Some(Instant::now());
        // A prompt that cannot fit in the cache *empty* can never be
        // admitted: growing the queue with it would wedge admission (every
        // reserve_prefix fails) until its deadline or a cancel.  Reject it
        // now, with a reason distinct from transient backpressure.
        if req.prompt.len().div_ceil(BLOCK_TOKENS) > self.kv.capacity_blocks() {
            self.metrics.rejected += 1;
            self.metrics.rejected_too_large += 1;
            return Err(SubmitError::PromptTooLarge);
        }
        if self.batcher.submit(req) {
            Ok(())
        } else {
            self.metrics.rejected += 1;
            Err(SubmitError::QueueFull)
        }
    }

    /// Submit a request (returns false on any rejection); see
    /// [`Coordinator::try_submit`] for the distinguishable reasons.
    pub fn submit(&mut self, req: Request) -> bool {
        self.try_submit(req).is_ok()
    }

    pub fn pending(&self) -> usize {
        self.batcher.queue_len()
            + self.prefilling.len()
            + self.running.len()
            + self.preempted.len()
    }

    /// One scheduler tick: admit, spend the tick's prefill-token budget in
    /// chunks, then one decode round.  Returns the per-token [`Event`]s
    /// produced during this tick — `Token` as each token is sampled, then
    /// a terminal `Finished` per completed request.
    pub fn tick(&mut self) -> Result<Vec<Event>> {
        let mut out = Vec::new();
        let s_max = self.backend.s_max();

        // 0. Deadline sweep: sessions past their wall-clock budget finish
        // with `Timeout` *now*, through the same teardown as cancellation,
        // so their blocks are free before this tick allocates anything.
        self.sweep_deadlines(&mut out);

        // 0b. Resume preempted sessions — strictly senior to fresh
        // admissions (they already consumed service).  Re-reserve the
        // replay feed and push it through the chunked-prefill path; if the
        // cache still cannot hold it, stay parked and retry next tick.
        // (`Batcher::running_len` counts every admitted unfinished session,
        // prefilling included, so it is the whole cap check.)
        while self.batcher.running_len() < self.batcher.cfg.max_sessions {
            let Some(parked) = self.preempted.front() else { break };
            let n = parked.generated.len();
            let resume_pos = parked.req.prompt.len() + n - 1;
            if let Some(sv) = &parked.survivors {
                // Pruned session: replay only the tokens whose rows
                // survived its presses, at their preserved logical
                // positions.  The survivor set is gapped, so it cannot
                // attach prefix-trie blocks — reserve rows directly.
                let prompt_len = parked.req.prompt.len();
                let feed: Vec<u8> = sv
                    .iter()
                    .map(|&p| {
                        let p = p as usize;
                        if p < prompt_len {
                            parked.req.prompt[p]
                        } else {
                            parked.generated[p - prompt_len]
                        }
                    })
                    .collect();
                match self.kv.reserve_with_positions(parked.req.id, sv) {
                    Ok(()) => {
                        let parked = self.preempted.pop_front().unwrap();
                        self.batcher.note_running(parked.req.id);
                        if parked.req.retention.is_some_and(|s| s.press == Press::AttnScore) {
                            self.kv.set_score_tracking(parked.req.id, true);
                        }
                        self.prefilling.push_back(Prefilling {
                            done: 0,
                            seq: parked.seq,
                            queue_ms: parked.queue_ms,
                            started: parked.started,
                            feed: Some(feed),
                            row_feed: true,
                            resume: Some(ResumeCtx {
                                sampler: parked.sampler,
                                generated: parked.generated,
                                ttft_ms: parked.ttft_ms,
                                decode_ms: parked.decode_ms,
                                decode_steps: parked.decode_steps,
                                pos: resume_pos,
                                survivors: parked.survivors,
                            }),
                            req: parked.req,
                        });
                    }
                    Err(_) => break,
                }
                continue;
            }
            let mut feed =
                Vec::with_capacity(parked.req.prompt.len() + n.saturating_sub(1));
            feed.extend_from_slice(&parked.req.prompt);
            feed.extend_from_slice(&parked.generated[..n - 1]);
            match self.kv.reserve_prefix(parked.req.id, &feed, feed.len()) {
                Ok(m) => {
                    let parked = self.preempted.pop_front().unwrap();
                    self.batcher.note_running(parked.req.id);
                    if parked.req.retention.is_some_and(|s| s.press == Press::AttnScore) {
                        self.kv.set_score_tracking(parked.req.id, true);
                    }
                    self.metrics.prefix_lookups += 1;
                    if m.matched_tokens > 0 {
                        self.metrics.prefix_hits += 1;
                        self.metrics.prefix_saved_blocks += m.shared_blocks as u64;
                        self.metrics.prefix_matched_tokens.add(m.matched_tokens as f64);
                    }
                    let feed_len = feed.len();
                    self.prefilling.push_back(Prefilling {
                        done: m.matched_tokens,
                        seq: parked.seq,
                        queue_ms: parked.queue_ms,
                        started: parked.started,
                        feed: Some(feed),
                        row_feed: false,
                        resume: Some(ResumeCtx {
                            sampler: parked.sampler,
                            generated: parked.generated,
                            ttft_ms: parked.ttft_ms,
                            decode_ms: parked.decode_ms,
                            decode_steps: parked.decode_steps,
                            pos: feed_len,
                            survivors: None,
                        }),
                        req: parked.req,
                    });
                }
                Err(_) => break,
            }
        }

        // 1. Admission: query the prefix trie, reserve the unmatched
        // suffix (prompt-only by default — oversubscribing admission), and
        // queue the prompt for chunked prefill past the shared prefix.
        for adm in self.batcher.admit(&mut self.kv) {
            let Admission { req, matched_tokens, shared_blocks } = adm;
            let queue_ms = req
                .arrival
                .map(|a| a.elapsed().as_secs_f64() * 1e3)
                .unwrap_or(0.0);
            if req.prompt.is_empty() {
                // A zero-token request has no position to compute logits
                // at: complete it immediately with an empty generation
                // instead of handing the backend an empty chunk (whose
                // "logits" would be another request's stale workspace).
                // The batcher admitted it without a reservation, so there
                // is nothing to release beyond the bookkeeping below.
                let m = RequestMetrics {
                    queue_ms,
                    ttft_ms: queue_ms,
                    decode_ms_per_token: 0.0,
                    decode_ms_per_step: 0.0,
                    prompt_tokens: 0,
                    generated_tokens: 0,
                    total_ms: queue_ms,
                    finish_reason: FinishReason::Length,
                };
                self.batcher.finish(req.id, &mut self.kv);
                self.backend.drop_session(req.id);
                self.metrics.record(&m);
                let resp =
                    Response { id: req.id, generated: Vec::new(), metrics: m, reject_reason: None };
                self.finished.push(resp.clone());
                out.push(Event::Finished { id: req.id, response: resp });
                continue;
            }
            self.metrics.prefix_lookups += 1;
            if matched_tokens > 0 {
                self.metrics.prefix_hits += 1;
                self.metrics.prefix_saved_blocks += shared_blocks as u64;
                self.metrics.prefix_matched_tokens.add(matched_tokens as f64);
            }
            self.admission_seq += 1;
            if req.retention.is_some_and(|s| s.press == Press::AttnScore) {
                // Per-row attention-mass accounting feeds this press; turn
                // it on while the reservation is fresh so decode rounds
                // accumulate from the first step.
                self.kv.set_score_tracking(req.id, true);
            }
            self.prefilling.push_back(Prefilling {
                req,
                done: matched_tokens,
                seq: self.admission_seq,
                queue_ms,
                started: Instant::now(),
                feed: None,
                row_feed: false,
                resume: None,
            });
        }
        self.metrics.peak_kv_blocks = self.metrics.peak_kv_blocks.max(self.kv.used_blocks());
        self.metrics.peak_kv_resident_bytes = self
            .metrics
            .peak_kv_resident_bytes
            .max(self.kv.resident_kv_bytes());

        // 2. Chunked prefill: spend at most `prefill_chunk_tokens` prompt
        // tokens, oldest request first, then fall through to the decode
        // round — a long prompt can never freeze in-flight decodes.
        let mut budget = self.batcher.cfg.prefill_chunk_tokens.max(1);
        while budget > 0 {
            let Some(mut p) = self.prefilling.pop_front() else { break };
            let feed_len = p.feed().len();
            let remaining = feed_len - p.done;
            let take = if self.backend.supports_chunked_prefill() {
                remaining.min(budget)
            } else {
                // Whole-prompt backends can't resume mid-prompt; the tick
                // still bills the full length against its budget.
                remaining
            };
            let last = p.done + take == feed_len;
            // A partially matched prefix block is copied into the
            // session's private block before its first write (idempotent;
            // FIFO prefill guarantees the source rows exist by now).
            self.kv.materialize_cow(p.req.id);
            // The backend writes rows: a survivor replay's `done` already
            // is a row index, and a session pressed mid-prefill maps its
            // next logical position to the row after its survivors.
            let row0 = if p.row_feed {
                p.done
            } else {
                self.kv.row_index_of(p.req.id, p.done).unwrap_or(p.done)
            };
            let logits = match self.backend.prefill_chunk(
                &mut self.kv,
                p.req.id,
                &p.feed()[p.done..p.done + take],
                row0,
                last,
            ) {
                Ok(l) => {
                    self.consecutive_backend_faults = 0;
                    l
                }
                Err(e)
                    if e.downcast_ref::<InjectedFault>().is_some()
                        && self.consecutive_backend_faults < MAX_CONSECUTIVE_BACKEND_FAULTS =>
                {
                    // Transient: the fault fired before the backend saw the
                    // chunk, so re-running the identical chunk next tick is
                    // clean.  Stop prefilling this tick (FIFO order keeps
                    // the prefix-sharing safety argument intact).
                    self.consecutive_backend_faults += 1;
                    self.metrics.backend_retries += 1;
                    self.prefilling.push_front(p);
                    break;
                }
                Err(e) => return Err(e),
            };
            p.done += take;
            budget = budget.saturating_sub(take.max(1));
            self.metrics.prefill_chunks += 1;
            self.metrics.prefill_chunk_tokens.add(take as f64);
            if !self.running.is_empty() {
                self.stalled_chunks += 1;
            }
            // 2b. Mid-prefill press: long prompts shed rows between
            // chunks, bounding peak residency during prefill itself.
            // Survivor replays (row-space feed) and attention-score
            // presses (no decode scores yet) wait for decode rounds.
            if !p.row_feed {
                if let Some(spec) = p.req.retention.filter(|s| s.press.works_during_prefill()) {
                    let evicted = self.kv.apply_press(p.req.id, &spec, p.done)?;
                    if evicted > 0 {
                        self.metrics.retention_presses += 1;
                        self.metrics.retention_evicted_tokens += evicted as u64;
                    }
                }
            }
            if last {
                let logits =
                    logits.ok_or_else(|| anyhow!("no logits for final prefill chunk"))?;
                if let Some(ctx) = p.resume {
                    // Recompute complete: restore the session exactly as
                    // preempted.  The final chunk's logits are *discarded*,
                    // not sampled — the token they name (`generated.last()`)
                    // was emitted before preemption; the next decode feeds
                    // it at `pos = feed_len`, exactly as the uncontended
                    // run would have.  The sampler was therefore called the
                    // same number of times in both histories.
                    drop(logits);
                    let id = p.req.id;
                    // Drafter state is advisory (acceptance re-samples
                    // every token from verifier logits), so a preempted
                    // session simply rebuilds its n-gram index from the
                    // stream it has — deterministic, and bit-identity
                    // never depends on it.
                    let drafter = p.req.speculative.map(|_| {
                        let mut d = NgramDrafter::with_capacity(
                            p.req.prompt.len() + p.req.max_new,
                        );
                        d.observe(&p.req.prompt);
                        d.observe(&ctx.generated);
                        d
                    });
                    self.running.insert(
                        id,
                        Running {
                            sampler: ctx.sampler,
                            generated: ctx.generated,
                            // Logical position, not the feed length: a
                            // survivor replay feeds fewer tokens than the
                            // session has logically consumed.
                            pos: ctx.pos,
                            seq: p.seq,
                            ttft_ms: ctx.ttft_ms,
                            queue_ms: p.queue_ms,
                            decode_ms: ctx.decode_ms,
                            decode_steps: ctx.decode_steps,
                            started: p.started,
                            drafter,
                            finish: None,
                            req: p.req,
                        },
                    );
                    self.metrics.resumes += 1;
                    out.push(Event::Resumed { id });
                    continue;
                }
                let pos = p.req.prompt.len();
                let ttft_ms = p.queue_ms + p.started.elapsed().as_secs_f64() * 1e3;
                let drafter = p.req.speculative.map(|_| {
                    let mut d =
                        NgramDrafter::with_capacity(p.req.prompt.len() + p.req.max_new);
                    d.observe(&p.req.prompt);
                    d
                });
                let mut r = Running {
                    sampler: Sampler::new(&p.req.sampling),
                    generated: Vec::with_capacity(p.req.max_new),
                    pos,
                    seq: p.seq,
                    ttft_ms,
                    queue_ms: p.queue_ms,
                    decode_ms: 0.0,
                    decode_steps: 0,
                    started: p.started,
                    drafter,
                    finish: None,
                    req: p.req,
                };
                if r.req.max_new == 0 {
                    // Nothing to emit; prefill ran for its side effects
                    // only (e.g. registering prefix blocks).
                    r.finish = Some(FinishReason::Length);
                } else {
                    // The prompt's final-position logits already name the
                    // first generated token: sample and emit it *now*,
                    // before any decode round — this is the streamed TTFT.
                    let first = r.sampler.sample(&logits) as u8;
                    r.generated.push(first);
                    if let Some(d) = r.drafter.as_mut() {
                        d.observe(std::slice::from_ref(&first));
                    }
                    out.push(Event::Token { id: r.req.id, token: first });
                    r.finish = finish_check(&r.req, &r.generated, r.pos, s_max);
                }
                self.running.insert(r.req.id, r);
            } else {
                self.prefilling.push_front(p);
            }
        }

        // 3. Pre-grow every decodable session's KV by one row, oldest
        // admission first, preempting the newest admission when a growth
        // allocation genuinely fails.  Growing *before* the decode round
        // (in seniority order) makes the preemption choice deterministic
        // and keeps the backend's own `ensure_tokens` calls zero-alloc.
        let mut order: Vec<(u64, RequestId)> = self
            .running
            .iter()
            .filter(|(_, r)| r.finish.is_none())
            .map(|(&id, r)| (r.seq, id))
            .collect();
        order.sort_unstable();
        let mut runnable: Vec<RequestId> = Vec::with_capacity(order.len());
        'grow: for (_, id) in order {
            if !self.running.contains_key(&id) {
                continue; // parked earlier in this loop as someone's victim
            }
            let pos = self.running[&id].pos;
            loop {
                match self.kv.ensure_tokens(id, pos + 1) {
                    Ok(()) => {
                        runnable.push(id);
                        continue 'grow;
                    }
                    Err(e) if e.downcast_ref::<InjectedFault>().is_some() => {
                        // Planned transient: defer this session's decode one
                        // tick.  Nothing is released — preempting on a fault
                        // that clears by itself would thrash.
                        self.metrics.alloc_defers += 1;
                        continue 'grow;
                    }
                    Err(_) => match self.preempt_one(&mut out) {
                        Some(victim) if victim == id => continue 'grow, // parked itself
                        Some(_) => continue,                            // retry the growth
                        None => {
                            // Lone session, genuinely full cache, cold cache
                            // already drained by the allocator: finish with
                            // what it has.
                            let r = self.running.get_mut(&id).unwrap();
                            r.finish = Some(FinishReason::Length);
                            self.metrics.oom_truncations += 1;
                            continue 'grow;
                        }
                    },
                }
            }
        }

        // 4. Continuous decode round over all runnable sessions.  A
        // runnable session always holds at least one sampled token
        // (`generated.last()` — pushed at prefill completion) which the
        // backend consumes at `pos`; its logits sample the *next* token.
        // A finished request therefore never pays for the trailing decode
        // step whose logits the v1 loop used to throw away.
        //
        // Speculative sessions run first, one verify chunk each: draft →
        // batched verify → deterministic accept → rejected-row rollback.
        // A session whose step cannot run this tick (no draft, budget 0,
        // allocation refused) degrades to the plain round below — it is
        // never worse off than a non-speculative session.
        let mut plain: Vec<RequestId> = Vec::with_capacity(runnable.len());
        for &id in &runnable {
            let r = &self.running[&id];
            if r.req.speculative.is_none() || r.drafter.is_none() {
                plain.push(id);
                continue;
            }
            match self.speculative_step(id, s_max, &mut out)? {
                SpecStep::Done | SpecStep::Skipped => {}
                SpecStep::Fallback => plain.push(id),
            }
        }
        for group in self.batcher.decode_batches(&plain) {
            let entries: Vec<(RequestId, u8, usize)> = group
                .iter()
                .map(|id| {
                    let r = &self.running[id];
                    (*id, *r.generated.last().expect("runnable implies >= 1 token"), r.pos)
                })
                .collect();
            let t0 = Instant::now();
            let logits = match self.backend.decode_batch(&mut self.kv, &entries) {
                Ok(l) => {
                    self.consecutive_backend_faults = 0;
                    l
                }
                Err(e)
                    if e.downcast_ref::<InjectedFault>().is_some()
                        && self.consecutive_backend_faults < MAX_CONSECUTIVE_BACKEND_FAULTS =>
                {
                    // Transient: the fault fired before the backend ran, so
                    // no KV row or position advanced — the identical round
                    // re-runs next tick.
                    self.consecutive_backend_faults += 1;
                    self.metrics.backend_retries += 1;
                    continue;
                }
                Err(e) => return Err(e),
            };
            let step_ms = t0.elapsed().as_secs_f64() * 1e3;
            self.metrics.decode_batches += 1;
            self.metrics.decode_batch_occupancy.add(entries.len() as f64);
            // Throughput-side cost: the step's wall time amortised over
            // the batch (what one token costs the fleet).
            self.metrics.decode_per_token_shared.add(step_ms / entries.len() as f64);
            for ((id, _, _), lg) in entries.iter().zip(logits) {
                let r = self.running.get_mut(id).unwrap();
                r.pos += 1;
                // Latency-side cost: every session in the batch waits the
                // FULL step before its next token — dividing by the batch
                // size under-reported per-request decode latency by the
                // occupancy factor.
                r.decode_ms += step_ms;
                r.decode_steps += 1;
                let token = r.sampler.sample(&lg) as u8;
                r.generated.push(token);
                // A speculative session that fell back this tick still
                // feeds its n-gram index, so the next draft sees the
                // whole stream.
                if let Some(d) = r.drafter.as_mut() {
                    d.observe(std::slice::from_ref(&token));
                }
                out.push(Event::Token { id: *id, token });
                r.finish = finish_check(&r.req, &r.generated, r.pos, s_max);
            }
        }
        if !runnable.is_empty() {
            // A decode round ran: record how many prefill chunks the
            // waiting sessions sat through since the previous round.
            self.metrics.max_prefill_chunks_between_decodes = self
                .metrics
                .max_prefill_chunks_between_decodes
                .max(self.stalled_chunks);
            self.stalled_chunks = 0;
        }

        // 4b. Post-decode retention presses: every session that decoded
        // this round sheds rows down to its spec's budget.  Runs after the
        // round so `AttnScore` sees this step's attention mass; finishing
        // sessions release everything in step 5 anyway and are skipped.
        for &id in &runnable {
            let Some(r) = self.running.get(&id) else { continue };
            if r.finish.is_some() {
                continue;
            }
            let Some(spec) = r.req.retention else { continue };
            let evicted = self.kv.apply_press(id, &spec, r.pos)?;
            if evicted > 0 {
                self.metrics.retention_presses += 1;
                self.metrics.retention_evicted_tokens += evicted as u64;
            }
        }

        // 5. Collect completions: sessions whose finish condition was met
        // this tick release their KV reservation (and any shared
        // prefix-block refcounts) immediately — an early finish frees its
        // blocks for the very next tick's admissions and growth.
        let done: Vec<RequestId> = self
            .running
            .iter()
            .filter(|(_, r)| r.finish.is_some())
            .map(|(&id, _)| id)
            .collect();
        out.reserve(done.len());
        for id in done {
            let r = self.running.remove(&id).unwrap();
            self.batcher.finish(id, &mut self.kv);
            self.backend.drop_session(id);
            let m = RequestMetrics {
                queue_ms: r.queue_ms,
                ttft_ms: r.ttft_ms,
                // decode_ms bills each backend call once, so this really
                // is wall-per-accepted-token under speculation (and
                // unchanged for plain decode, where steps == tokens - 1).
                decode_ms_per_token: if r.generated.is_empty() {
                    0.0
                } else {
                    r.decode_ms / r.generated.len() as f64
                },
                decode_ms_per_step: if r.decode_steps == 0 {
                    0.0
                } else {
                    r.decode_ms / r.decode_steps as f64
                },
                prompt_tokens: r.req.prompt.len(),
                generated_tokens: r.generated.len(),
                total_ms: r.started.elapsed().as_secs_f64() * 1e3,
                finish_reason: r.finish.unwrap_or(FinishReason::Length),
            };
            self.metrics.record(&m);
            let resp = Response {
                id,
                generated: r.generated,
                metrics: m,
                reject_reason: None,
            };
            self.finished.push(resp.clone());
            out.push(Event::Finished { id, response: resp });
        }
        Ok(out)
    }

    /// One speculative decode step for `id` (see the phase-4 loop): draft
    /// from the session's own n-gram index, verify the whole draft in one
    /// blocked `Backend::verify_chunk` call, accept the longest prefix the
    /// verifier agrees with through the request's own seeded sampler, and
    /// truncate the rejected suffix's KV rows back to the pool.  Output is
    /// bit-identical to plain decode by construction — the draft only
    /// decides how many sampler draws one backend call covers.
    fn speculative_step(
        &mut self,
        id: RequestId,
        s_max: usize,
        out: &mut Vec<Event>,
    ) -> Result<SpecStep> {
        let r = &self.running[&id];
        let spec = r.req.speculative.expect("phase 4 checked the knob");
        let (pos, gen_len, max_new) = (r.pos, r.generated.len(), r.req.max_new);
        let ret = r.req.retention;
        let retention = ret.as_ref().map(|s| (s, self.kv.session_tokens(id), pos));
        let n = draft_budget(spec.k, gen_len, max_new, pos, s_max, retention);
        if n == 0 {
            return Ok(SpecStep::Fallback);
        }

        let mut draft = std::mem::take(&mut self.draft_buf);
        let got = {
            let r = self.running.get_mut(&id).unwrap();
            r.drafter.as_mut().expect("phase 4 checked the drafter").draft(&mut draft, n)
        };
        if got == 0 {
            self.draft_buf = draft;
            return Ok(SpecStep::Fallback);
        }

        // Reserve rows for the draft's positions `pos + 1 ..= pos + got`
        // (the grow phase already reserved `pos`'s row).  Any refusal —
        // including an injected alloc fault — only degrades this step to
        // plain decode: speculation never preempts another session.
        let row0 = self.kv.row_index_of(id, pos).unwrap_or(pos);
        if self.kv.ensure_tokens(id, pos + 1 + got).is_err() {
            // Partial growth would leave a pruned session's position map
            // reaching past `pos`; truncating restores the exact
            // pre-draft tail either way.
            self.kv.truncate_rows(id, row0 + 1)?;
            self.draft_buf = draft;
            return Ok(SpecStep::Fallback);
        }

        // Feed = [last_emitted, d_1 .. d_got] at positions pos .. pos+got.
        let mut feed = std::mem::take(&mut self.feed_buf);
        feed.clear();
        feed.push(*self.running[&id].generated.last().expect("runnable implies >= 1 token"));
        feed.extend_from_slice(&draft[..got]);

        let t0 = Instant::now();
        let logits = match self.backend.verify_chunk(&mut self.kv, id, &feed, pos) {
            Ok(l) => {
                self.consecutive_backend_faults = 0;
                l
            }
            Err(e)
                if e.downcast_ref::<InjectedFault>().is_some()
                    && self.consecutive_backend_faults < MAX_CONSECUTIVE_BACKEND_FAULTS =>
            {
                // Transient: the fault fired before the backend ran, so no
                // position advanced — drop the draft rows and retry (or
                // fall back) next tick.
                self.consecutive_backend_faults += 1;
                self.metrics.backend_retries += 1;
                self.kv.truncate_rows(id, row0 + 1)?;
                self.draft_buf = draft;
                self.feed_buf = feed;
                return Ok(SpecStep::Skipped);
            }
            Err(e) => return Err(e),
        };
        let step_ms = t0.elapsed().as_secs_f64() * 1e3;

        let r = self.running.get_mut(&id).unwrap();
        let outcome = {
            let Running { sampler, generated, req, .. } = &mut *r;
            accept_step(&draft[..got], &logits, sampler, generated, pos, |g, p| {
                finish_check(req, g, p, s_max)
            })
        };
        r.pos += outcome.emitted;
        // One verify call is one decode step: bill its wall time once —
        // not once per emitted token, which over-counted decode_ms by the
        // acceptance factor.
        r.decode_ms += step_ms;
        r.decode_steps += 1;
        r.finish = outcome.finish;
        let first_new = r.generated.len() - outcome.emitted;
        for i in first_new..r.generated.len() {
            out.push(Event::Token { id, token: r.generated[i] });
        }
        {
            let Running { drafter, generated, .. } = &mut *r;
            if let Some(d) = drafter.as_mut() {
                d.observe(&generated[first_new..]);
            }
        }
        // Roll back the rejected suffix: rows `row0 .. row0 + emitted`
        // hold exactly the tokens the stream actually consumed (the fed
        // token at `pos` plus the accepted draft); everything past them
        // is KV for a continuation that never happened.  Truncation
        // restores `kv_used_blocks()` to what plain decode would show.
        self.kv.truncate_rows(id, row0 + outcome.emitted)?;
        self.metrics.spec_steps += 1;
        self.metrics.spec_drafted_tokens += got as u64;
        self.metrics.spec_accepted_tokens += outcome.accepted_draft as u64;
        self.metrics.spec_rolled_back_rows += (got + 1 - outcome.emitted) as u64;
        self.metrics.spec_tokens_per_step.add(outcome.emitted as f64);
        self.metrics.decode_per_token_shared.add(step_ms / outcome.emitted as f64);
        self.draft_buf = draft;
        self.feed_buf = feed;
        Ok(SpecStep::Done)
    }

    /// Preempt one admission to free KV blocks for older sessions.
    /// Cheapest victim first: the newest still-prefilling admission (no
    /// sampled state — it is requeued at the queue *front* and restarts
    /// cleanly), otherwise the newest-seniority running session, which is
    /// parked with its sampler and generated tokens intact.  Returns the
    /// victim's id, or `None` when there is nothing left to preempt.
    fn preempt_one(&mut self, out: &mut Vec<Event>) -> Option<RequestId> {
        if let Some(p) = self.prefilling.pop_back() {
            let id = p.req.id;
            self.batcher.finish(id, &mut self.kv);
            self.backend.drop_session(id);
            self.metrics.preemptions += 1;
            if let Some(ctx) = p.resume {
                // A resumed session caught mid-recompute goes back to the
                // *front* of the parked queue with its state intact — it
                // already emitted its tokens once and must never replay
                // them as a fresh admission.  (No second `Preempted`
                // event: its `Resumed` was never emitted.)
                self.preempted.push_front(ParkedSession {
                    req: p.req,
                    sampler: ctx.sampler,
                    generated: ctx.generated,
                    seq: p.seq,
                    ttft_ms: ctx.ttft_ms,
                    queue_ms: p.queue_ms,
                    decode_ms: ctx.decode_ms,
                    decode_steps: ctx.decode_steps,
                    started: p.started,
                    survivors: ctx.survivors,
                });
            } else {
                self.batcher.requeue_front(p.req);
            }
            return Some(id);
        }
        let victim = self
            .running
            .iter()
            .filter(|(_, r)| r.finish.is_none())
            .max_by_key(|(_, r)| r.seq)
            .map(|(&id, _)| id)?;
        // A lone session preempting itself would just thrash; the caller
        // handles that case as genuine exhaustion.
        if self.running.iter().filter(|(_, r)| r.finish.is_none()).count() == 1 {
            return None;
        }
        let r = self.running.remove(&victim).unwrap();
        // A pruned victim must replay only its surviving rows on resume;
        // capture their logical positions before the release below frees
        // the page table.  The replay feed spans `[0, pos)`, so a row the
        // grow phase reserved at `pos` this tick is excluded.
        let survivors: Option<Vec<u32>> = self.kv.row_positions(victim).map(|pv| {
            let limit = r.pos as u32;
            pv.iter().copied().filter(|&p| p < limit).collect()
        });
        self.batcher.finish(victim, &mut self.kv);
        self.backend.drop_session(victim);
        self.metrics.preemptions += 1;
        out.push(Event::Preempted { id: victim });
        self.preempted.push_back(ParkedSession {
            req: r.req,
            sampler: r.sampler,
            generated: r.generated,
            seq: r.seq,
            ttft_ms: r.ttft_ms,
            queue_ms: r.queue_ms,
            decode_ms: r.decode_ms,
            decode_steps: r.decode_steps,
            started: r.started,
            survivors,
        });
        Some(victim)
    }

    /// Finish every session whose `deadline_ms` has expired — wherever it
    /// lives — through the same teardown as cancellation, emitting the
    /// terminal `Finished` event with `FinishReason::Timeout`.
    fn sweep_deadlines(&mut self, out: &mut Vec<Event>) {
        let mut expired: Vec<RequestId> = self.batcher.expired_queued();
        expired.extend(
            self.prefilling
                .iter()
                .filter(|p| p.req.deadline_expired())
                .map(|p| p.req.id),
        );
        expired.extend(
            self.running
                .values()
                .filter(|r| r.req.deadline_expired())
                .map(|r| r.req.id),
        );
        expired.extend(
            self.preempted
                .iter()
                .filter(|p| p.req.deadline_expired())
                .map(|p| p.req.id),
        );
        for id in expired {
            if let Some(response) = self.teardown(id, FinishReason::Timeout) {
                out.push(Event::Finished { id, response });
            }
        }
    }

    /// Tear down a request wherever it lives — still queued, mid-prefill,
    /// decoding, or parked by preemption.  Its KV reservation (including
    /// shared prefix-block refcounts) is released immediately, so
    /// `kv_used_blocks()` returns to its pre-admission value; returns the
    /// terminal response carrying any tokens generated so far, or `None`
    /// for an unknown (or already finished) id.
    fn teardown(&mut self, id: RequestId, reason: FinishReason) -> Option<Response> {
        let (req, generated, queue_ms, ttft_ms, decode_ms, decode_steps, started) =
            if let Some(req) = self.batcher.remove_queued(id) {
                // Queued requests hold no reservation and no backend state.
                let queue_ms = req
                    .arrival
                    .map(|a| a.elapsed().as_secs_f64() * 1e3)
                    .unwrap_or(0.0);
                (req, Vec::new(), queue_ms, 0.0, 0.0, 0, None)
            } else if let Some(i) = self.prefilling.iter().position(|p| p.req.id == id) {
                let p = self.prefilling.remove(i).unwrap();
                self.batcher.finish(id, &mut self.kv);
                self.backend.drop_session(id);
                // A resumed session torn down mid-recompute still returns
                // the tokens it generated before preemption.
                let (generated, ttft, decode_ms, decode_steps) = match p.resume {
                    Some(c) => (c.generated, c.ttft_ms, c.decode_ms, c.decode_steps),
                    None => (Vec::new(), 0.0, 0.0, 0),
                };
                (p.req, generated, p.queue_ms, ttft, decode_ms, decode_steps, Some(p.started))
            } else if let Some(r) = self.running.remove(&id) {
                self.batcher.finish(id, &mut self.kv);
                self.backend.drop_session(id);
                (
                    r.req,
                    r.generated,
                    r.queue_ms,
                    r.ttft_ms,
                    r.decode_ms,
                    r.decode_steps,
                    Some(r.started),
                )
            } else if let Some(i) = self.preempted.iter().position(|p| p.req.id == id) {
                // Parked sessions hold no KV blocks and no backend state —
                // preemption already released both.
                let p = self.preempted.remove(i).unwrap();
                (
                    p.req,
                    p.generated,
                    p.queue_ms,
                    p.ttft_ms,
                    p.decode_ms,
                    p.decode_steps,
                    Some(p.started),
                )
            } else {
                return None;
            };
        let m = RequestMetrics {
            queue_ms,
            ttft_ms,
            decode_ms_per_token: if generated.is_empty() {
                0.0
            } else {
                decode_ms / generated.len() as f64
            },
            decode_ms_per_step: if decode_steps == 0 {
                0.0
            } else {
                decode_ms / decode_steps as f64
            },
            prompt_tokens: req.prompt.len(),
            generated_tokens: generated.len(),
            total_ms: started
                .map(|s| s.elapsed().as_secs_f64() * 1e3)
                .unwrap_or(queue_ms),
            finish_reason: reason,
        };
        self.metrics.record(&m);
        let resp = Response { id, generated, metrics: m, reject_reason: None };
        self.finished.push(resp.clone());
        Some(resp)
    }

    /// Cancel a request wherever it lives (see [`Coordinator::teardown`]);
    /// the server wires this to client disconnects and explicit
    /// `{"cancel": id}` messages.  Returns the terminal `Cancelled`
    /// response, or `None` for an unknown (or already finished) id —
    /// double-cancel is a no-op.
    pub fn cancel(&mut self, id: RequestId) -> Option<Response> {
        self.teardown(id, FinishReason::Cancelled)
    }

    /// Drop buffered completed responses (the `run_to_completion` return
    /// value).  The long-lived server routes per-event instead and calls
    /// this after every tick to keep the coordinator's memory flat.
    pub fn discard_finished(&mut self) {
        self.finished.clear();
    }

    /// Drive until every submitted request has completed.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let t0 = Instant::now();
        while self.pending() > 0 {
            self.tick()?;
        }
        self.metrics.wall += t0.elapsed();
        Ok(std::mem::take(&mut self.finished))
    }

    pub fn kv_used_blocks(&self) -> usize {
        self.kv.used_blocks()
    }

    /// Distinct prompt chunks currently cached in the prefix trie.
    pub fn kv_prefix_nodes(&self) -> usize {
        self.kv.prefix_nodes()
    }

    /// Blocks held only by the cold-prefix cache (reclaimable on demand).
    pub fn kv_cold_blocks(&self) -> usize {
        self.kv.cold_blocks()
    }

    /// Cold-prefix chunks evicted under allocation pressure so far.
    pub fn kv_evictions(&self) -> u64 {
        self.kv.evictions()
    }

    /// Total physical blocks in the paged cache.
    pub fn kv_capacity_blocks(&self) -> usize {
        self.kv.capacity_blocks()
    }

    /// Allocation faults injected by the installed fault plan so far.
    pub fn kv_alloc_faults_injected(&self) -> u64 {
        self.kv.alloc_faults_injected()
    }

    /// Token rows evicted by retention presses so far.
    pub fn kv_evicted_tokens(&self) -> u64 {
        self.kv.evicted_tokens()
    }

    /// Token rows currently resident across all live sessions.
    pub fn kv_resident_rows(&self) -> usize {
        self.kv.resident_rows()
    }

    /// Bytes physically resident for KV rows right now (hot + cold).
    pub fn kv_resident_bytes(&self) -> usize {
        self.kv.resident_kv_bytes()
    }

    /// Surviving logical positions of a pruned session's rows (`None` for
    /// retain-all sessions) — lets tests and the quality ablation check
    /// which planted tokens a press kept.
    pub fn kv_row_positions(&self, id: RequestId) -> Option<&[u32]> {
        self.kv.row_positions(id)
    }

    /// Cheap point-in-time gauges for load reporting.  The server publishes
    /// this after every scheduler iteration so health probes (and the
    /// multi-replica router's least-loaded fallback) can read replica load
    /// without a round-trip through the scheduler thread.
    pub fn snapshot(&self) -> CoordSnapshot {
        CoordSnapshot {
            queued: self.batcher.queue_len(),
            prefilling: self.prefilling.len(),
            running: self.running.len(),
            preempted: self.preempted.len(),
            used_blocks: self.kv.used_blocks(),
            capacity_blocks: self.kv.capacity_blocks(),
            prefix_hits: self.metrics.prefix_hits,
            prefix_lookups: self.metrics.prefix_lookups,
            retained_tokens: self.kv.resident_rows() as u64,
            evicted_tokens: self.kv.evicted_tokens(),
            resident_kv_bytes: self.kv.resident_kv_bytes(),
        }
    }
}

/// Point-in-time coordinator gauges (see [`Coordinator::snapshot`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CoordSnapshot {
    pub queued: usize,
    pub prefilling: usize,
    pub running: usize,
    pub preempted: usize,
    pub used_blocks: usize,
    pub capacity_blocks: usize,
    pub prefix_hits: u64,
    pub prefix_lookups: u64,
    /// Token rows currently resident across live sessions (post-press).
    pub retained_tokens: u64,
    /// Token rows evicted by retention presses since start.
    pub evicted_tokens: u64,
    /// Bytes physically resident for KV rows (post-press, hot + cold).
    pub resident_kv_bytes: usize,
}

impl CoordSnapshot {
    /// Requests anywhere in the coordinator — the replica's load gauge.
    pub fn in_flight(&self) -> usize {
        self.queued + self.prefilling + self.running + self.preempted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic toy backend: logits always argmax to (token + 1) % 7.
    struct ToyBackend {
        s_max: usize,
        sessions: std::collections::BTreeMap<RequestId, usize>,
        decode_calls: usize,
        batch_sizes: Vec<usize>,
        verify_calls: usize,
        verify_fed_tokens: usize,
    }

    impl ToyBackend {
        fn new(s_max: usize) -> ToyBackend {
            ToyBackend {
                s_max,
                sessions: Default::default(),
                decode_calls: 0,
                batch_sizes: vec![],
                verify_calls: 0,
                verify_fed_tokens: 0,
            }
        }

        fn logits_for(token: u8) -> Vec<f32> {
            let mut l = vec![0.0f32; 256];
            l[((token as usize) + 1) % 7] = 1.0;
            l
        }
    }

    impl Backend for ToyBackend {
        fn s_max(&self) -> usize {
            self.s_max
        }
        fn prefill(
            &mut self,
            _kv: &mut PagedKvCache,
            session: RequestId,
            prompt: &[u8],
        ) -> Result<Vec<f32>> {
            self.sessions.insert(session, prompt.len());
            Ok(Self::logits_for(*prompt.last().unwrap_or(&0)))
        }
        fn decode_batch(
            &mut self,
            _kv: &mut PagedKvCache,
            entries: &[(RequestId, u8, usize)],
        ) -> Result<Vec<Vec<f32>>> {
            self.decode_calls += 1;
            self.batch_sizes.push(entries.len());
            Ok(entries.iter().map(|&(_, t, _)| Self::logits_for(t)).collect())
        }
        fn verify_chunk(
            &mut self,
            _kv: &mut PagedKvCache,
            _session: RequestId,
            tokens: &[u8],
            _pos0: usize,
        ) -> Result<Vec<Vec<f32>>> {
            // One blocked call for the whole feed — row i names the token
            // after tokens[i], exactly what sequential decode would say.
            self.verify_calls += 1;
            self.verify_fed_tokens += tokens.len();
            Ok(tokens.iter().map(|&t| Self::logits_for(t)).collect())
        }
        fn drop_session(&mut self, session: RequestId) {
            self.sessions.remove(&session);
        }
    }

    fn coordinator(max_sessions: usize) -> Coordinator<ToyBackend> {
        let shape = CacheShape {
            n_layers: 2,
            n_kv_heads: 2,
            k_width: vec![8, 8],
            v_width: vec![8, 8],
        };
        Coordinator::new(
            ToyBackend::new(64),
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions,
                    buckets: vec![1, 4],
                    max_queue: 100,
                    // Env-independent: the CI speculative matrix sets
                    // RAP_SPECULATIVE, but ToyBackend's periodic chain
                    // would then speculate in every test; tests opt in
                    // per request instead.
                    default_speculative: None,
                    ..Default::default()
                },
                kv_budget_bytes: 16 << 20,
            },
        )
    }

    #[test]
    fn serves_all_requests() {
        let mut c = coordinator(4);
        for i in 0..10 {
            assert!(c.submit(Request::new(i, vec![1, 2, 3], 5)));
        }
        let responses = c.run_to_completion().unwrap();
        assert_eq!(responses.len(), 10);
        for r in &responses {
            assert_eq!(r.generated.len(), 5);
            // deterministic chain: 3 -> 4 -> 5 -> 6 -> 0 -> 1
            assert_eq!(r.generated, vec![4, 5, 6, 0, 1]);
        }
        assert_eq!(c.metrics.requests, 10);
        assert_eq!(c.backend.sessions.len(), 0, "all sessions dropped");
    }

    #[test]
    fn snapshot_tracks_load_and_empties_at_completion() {
        let mut c = coordinator(2);
        let s0 = c.snapshot();
        assert_eq!(s0.in_flight(), 0);
        assert_eq!(s0.used_blocks, 0);
        assert!(s0.capacity_blocks > 0);
        for i in 0..4 {
            assert!(c.submit(Request::new(i, vec![1, 2, 3], 5)));
        }
        assert_eq!(c.snapshot().in_flight(), 4, "queued requests count as load");
        c.tick().unwrap();
        let mid = c.snapshot();
        assert_eq!(mid.in_flight(), 4, "admitted + still-queued");
        assert!(mid.running + mid.prefilling >= 1);
        assert!(mid.used_blocks > 0);
        c.run_to_completion().unwrap();
        let end = c.snapshot();
        assert_eq!(end.in_flight(), 0);
        assert_eq!(end.used_blocks, 0);
        assert_eq!(end.prefix_lookups, c.metrics.prefix_lookups);
    }

    #[test]
    fn batches_fill_buckets() {
        let mut c = coordinator(8);
        for i in 0..8 {
            c.submit(Request::new(i, vec![9], 3));
        }
        c.run_to_completion().unwrap();
        // With 8 concurrent sessions and buckets [1,4], most decode rounds
        // should use the 4-bucket.
        let fours = c.backend.batch_sizes.iter().filter(|&&b| b == 4).count();
        assert!(fours >= 4, "batch sizes: {:?}", c.backend.batch_sizes);
        assert!(c.metrics.decode_batch_occupancy.mean() > 1.5);
    }

    #[test]
    fn respects_s_max() {
        let mut c = coordinator(2);
        // prompt 60 + max_new 100 but s_max 64 -> generation truncated.
        c.submit(Request::new(1, vec![0u8; 60], 100));
        let responses = c.run_to_completion().unwrap();
        assert_eq!(responses.len(), 1);
        assert!(responses[0].generated.len() <= 4 + 1);
    }

    #[test]
    fn metrics_populated() {
        let mut c = coordinator(2);
        c.submit(Request::new(1, vec![1, 2], 4));
        let r = c.run_to_completion().unwrap();
        let m = &r[0].metrics;
        assert_eq!(m.prompt_tokens, 2);
        assert_eq!(m.generated_tokens, 4);
        assert!(m.ttft_ms >= 0.0 && m.total_ms >= 0.0);
        assert!(c.metrics.throughput_tps() > 0.0);
        assert_eq!(c.metrics.prefill_chunks, 1, "whole prompt in one chunk");
        assert_eq!(c.metrics.prefix_lookups, 1);
        assert_eq!(c.metrics.prefix_hits, 0, "accounting-only cache never matches");
    }

    #[test]
    fn empty_prompt_completes_without_touching_the_backend() {
        let mut c = coordinator(2);
        assert!(c.submit(Request::new(7, Vec::new(), 5)));
        let r = c.run_to_completion().unwrap();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, 7);
        assert!(
            r[0].generated.is_empty(),
            "no prompt token -> no logits -> no generation (not stale-workspace argmax)"
        );
        assert_eq!(c.metrics.prefill_chunks, 0, "backend never saw an empty chunk");
        assert_eq!(c.backend.decode_calls, 0);
        assert_eq!(c.backend.sessions.len(), 0);
        assert_eq!(c.kv_used_blocks(), 0, "reservation released immediately");
        assert_eq!(c.metrics.requests, 1, "still recorded as a served request");
    }

    #[test]
    fn decode_latency_attributed_per_session_not_per_batch() {
        // Every session in a batch waits the full decode step, so the
        // occupancy-normalised (shared) number can never exceed the
        // per-request attribution, and both are sampled.
        let mut c = coordinator(4);
        for i in 0..4 {
            c.submit(Request::new(i, vec![1, 2, 3], 6));
        }
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.decode_per_token_shared.n, c.metrics.decode_batches);
        assert!(
            c.metrics.decode_per_token_shared.mean() <= c.metrics.decode_per_token.mean() + 1e-12,
            "shared (step/occupancy) must not exceed full-step attribution"
        );
    }

    /// Toy backend with real chunked-prefill support: tracks how many
    /// prompt tokens each session has been fed and insists chunks arrive
    /// in order.
    struct ChunkedToy {
        s_max: usize,
        fed: std::collections::BTreeMap<RequestId, usize>,
    }

    impl Backend for ChunkedToy {
        fn s_max(&self) -> usize {
            self.s_max
        }
        fn supports_chunked_prefill(&self) -> bool {
            true
        }
        fn prefill_chunk(
            &mut self,
            _kv: &mut PagedKvCache,
            session: RequestId,
            tokens: &[u8],
            pos0: usize,
            last: bool,
        ) -> Result<Option<Vec<f32>>> {
            let fed = self.fed.entry(session).or_insert(0);
            assert_eq!(*fed, pos0, "chunks must arrive in prompt order");
            *fed += tokens.len();
            Ok(if last {
                Some(ToyBackend::logits_for(*tokens.last().unwrap_or(&0)))
            } else {
                None
            })
        }
        fn prefill(
            &mut self,
            kv: &mut PagedKvCache,
            session: RequestId,
            prompt: &[u8],
        ) -> Result<Vec<f32>> {
            Ok(self.prefill_chunk(kv, session, prompt, 0, true)?.unwrap())
        }
        fn decode_batch(
            &mut self,
            _kv: &mut PagedKvCache,
            entries: &[(RequestId, u8, usize)],
        ) -> Result<Vec<Vec<f32>>> {
            Ok(entries.iter().map(|&(_, t, _)| ToyBackend::logits_for(t)).collect())
        }
        fn drop_session(&mut self, session: RequestId) {
            self.fed.remove(&session);
        }
    }

    #[test]
    fn long_prompt_admission_interleaves_with_decode() {
        // A 2k-token prompt admitted mid-stream must not freeze the
        // in-flight session: with a 256-token per-tick budget it is fed in
        // 8 chunks, and every decode round waits on at most ONE chunk.
        let shape = CacheShape {
            n_layers: 2,
            n_kv_heads: 2,
            k_width: vec![8, 8],
            v_width: vec![8, 8],
        };
        let mut c = Coordinator::new(
            ChunkedToy { s_max: 4096, fed: Default::default() },
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 2,
                    buckets: vec![1, 4],
                    max_queue: 16,
                    prefill_chunk_tokens: 256,
                    reserve_worst_case: false,
                    default_retention: None,
                    default_speculative: None,
                },
                kv_budget_bytes: 64 << 20,
            },
        );
        // In-flight session decoding away...
        assert!(c.submit(Request::new(1, vec![1, 2, 3], 64)));
        c.tick().unwrap();
        assert_eq!(c.running.len(), 1, "session 1 decoding");
        // ...when a 2k-token prompt arrives.
        assert!(c.submit(Request::new(2, vec![0u8; 2048], 4)));
        let mut responses = c.run_to_completion().unwrap();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 2);
        assert_eq!(responses[0].generated.len(), 64);
        assert_eq!(responses[1].generated.len(), 4);
        // 1 chunk for session 1's prompt + ceil(2048/256) for session 2's.
        assert_eq!(c.metrics.prefill_chunks, 1 + 8);
        assert_eq!(
            c.metrics.max_prefill_chunks_between_decodes, 1,
            "an in-flight decode round waits on at most one prefill chunk"
        );
        assert!(c.metrics.prefill_chunk_tokens.max <= 256.0);
    }

    #[test]
    fn stop_sequence_ends_generation_early_and_releases_blocks() {
        // ToyBackend chain from prompt [1,2,3]: 4, 5, 6, 0, 1, ...  A stop
        // sequence on [5, 6] must end the request after three tokens
        // (matched bytes included), long before max_new.
        let mut c = coordinator(4);
        assert!(c.submit(Request::new(1, vec![1, 2, 3], 50).with_stop(vec![vec![5, 6]])));
        let responses = c.run_to_completion().unwrap();
        assert_eq!(responses.len(), 1);
        assert_eq!(responses[0].generated, vec![4, 5, 6]);
        assert_eq!(responses[0].metrics.finish_reason, FinishReason::Stop);
        assert_eq!(c.metrics.stopped_early, 1);
        assert_eq!(c.kv_used_blocks(), 0, "early stop frees the unused reservation");
        assert_eq!(c.backend.sessions.len(), 0);
    }

    #[test]
    fn stop_sequence_longer_than_generation_never_matches() {
        let mut c = coordinator(4);
        assert!(c.submit(Request::new(1, vec![1, 2, 3], 3).with_stop(vec![vec![4, 5, 6, 0]])));
        let responses = c.run_to_completion().unwrap();
        assert_eq!(responses[0].generated, vec![4, 5, 6]);
        assert_eq!(responses[0].metrics.finish_reason, FinishReason::Length);
        assert_eq!(c.metrics.stopped_early, 0);
    }

    #[test]
    fn tick_streams_token_events_before_the_finish() {
        let mut c = coordinator(4);
        assert!(c.submit(Request::new(1, vec![1, 2, 3], 5)));
        assert!(c.submit(Request::new(2, vec![9], 3)));
        let mut per_req: std::collections::BTreeMap<RequestId, Vec<u8>> = Default::default();
        let mut finished: std::collections::BTreeMap<RequestId, Response> = Default::default();
        while c.pending() > 0 {
            for ev in c.tick().unwrap() {
                match ev {
                    Event::Token { id, token } => {
                        assert!(!finished.contains_key(&id), "no tokens after Finished");
                        per_req.entry(id).or_default().push(token);
                    }
                    Event::Finished { id, response } => {
                        finished.insert(id, response);
                    }
                    Event::Preempted { .. } | Event::Resumed { .. } => {
                        unreachable!("no memory pressure in this test")
                    }
                }
            }
        }
        assert_eq!(finished.len(), 2);
        for (id, resp) in &finished {
            assert_eq!(
                per_req[id], resp.generated,
                "token events reassemble to the final generation"
            );
        }
        // The first Token event fires at prefill completion, so a request
        // streams its first token before any of its decode rounds ran.
        assert_eq!(finished[&1].generated, vec![4, 5, 6, 0, 1]);
    }

    #[test]
    fn cancel_queued_and_running_sessions_releases_everything() {
        let mut c = coordinator(1); // one session slot: request 2 stays queued
        assert!(c.submit(Request::new(1, vec![1, 2, 3], 50)));
        assert!(c.submit(Request::new(2, vec![4, 5, 6], 50)));
        c.tick().unwrap();
        assert_eq!(c.running.len(), 1, "request 1 decoding");
        assert!(c.kv_used_blocks() > 0);

        // Cancel the queued request: no reservation to release, id gone.
        let r2 = c.cancel(2).expect("request 2 is queued");
        assert!(r2.generated.is_empty());
        assert_eq!(r2.metrics.finish_reason, FinishReason::Cancelled);

        // Cancel the decoding request mid-flight: partial generation comes
        // back and every block returns to the free list.
        let r1 = c.cancel(1).expect("request 1 is running");
        assert!(!r1.generated.is_empty(), "mid-decode cancel keeps partial output");
        assert_eq!(r1.metrics.finish_reason, FinishReason::Cancelled);
        assert_eq!(c.kv_used_blocks(), 0, "cancellation released the reservation");
        assert_eq!(c.backend.sessions.len(), 0);
        assert_eq!(c.pending(), 0);
        assert_eq!(c.metrics.cancelled, 2);
        assert!(c.cancel(1).is_none(), "double cancel is a no-op");
        // The id is immediately reusable after cancellation.
        assert!(c.submit(Request::new(1, vec![1, 2, 3], 2)));
        assert_eq!(c.run_to_completion().unwrap().len(), 3, "2 cancelled + 1 served");
    }

    #[test]
    fn cancel_mid_prefill_releases_the_partial_session() {
        let shape = CacheShape {
            n_layers: 2,
            n_kv_heads: 2,
            k_width: vec![8, 8],
            v_width: vec![8, 8],
        };
        let mut c = Coordinator::new(
            ChunkedToy { s_max: 4096, fed: Default::default() },
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 2,
                    buckets: vec![1, 4],
                    max_queue: 16,
                    prefill_chunk_tokens: 256,
                    reserve_worst_case: false,
                    default_retention: None,
                    default_speculative: None,
                },
                kv_budget_bytes: 64 << 20,
            },
        );
        assert!(c.submit(Request::new(7, vec![0u8; 2048], 4)));
        c.tick().unwrap();
        assert_eq!(c.prefilling.len(), 1, "2048-token prompt is mid-prefill");
        assert!(c.kv_used_blocks() > 0);
        let r = c.cancel(7).expect("mid-prefill cancel");
        assert!(r.generated.is_empty(), "no token was ever sampled");
        assert_eq!(r.metrics.finish_reason, FinishReason::Cancelled);
        assert_eq!(c.kv_used_blocks(), 0, "partial prefill fully released");
        assert_eq!(c.pending(), 0);
        assert!(c.backend.fed.is_empty(), "backend session dropped");
    }

    #[test]
    fn seeded_sampling_reproducible_and_greedy_matches_v1() {
        use crate::coordinator::sampling::SamplingParams;
        let sampled = |seed: u64| {
            let mut c = coordinator(2);
            let params = SamplingParams { temperature: 1.0, seed, ..Default::default() };
            assert!(c.submit(Request::new(1, vec![1, 2, 3], 16).with_sampling(params)));
            c.run_to_completion().unwrap().remove(0).generated
        };
        assert_eq!(sampled(7), sampled(7), "same seed, same generation");
        assert_ne!(
            sampled(7),
            sampled(8),
            "ToyBackend logits are near-uniform at temperature 1: distinct \
             seeds diverge within 16 tokens"
        );

        // temperature 0 through the sampler == the v1 argmax chain.
        let mut c = coordinator(2);
        let greedy = SamplingParams { temperature: 0.0, seed: 123, ..Default::default() };
        assert!(c.submit(Request::new(1, vec![1, 2, 3], 5).with_sampling(greedy)));
        assert_eq!(c.run_to_completion().unwrap()[0].generated, vec![4, 5, 6, 0, 1]);
    }

    /// Like `coordinator`, but with an exact block budget: the test shape
    /// costs 2 layers * 2 heads * 16 tokens * (8+8) lanes * 4 bytes =
    /// 8192 bytes per block.
    fn tight_coordinator(max_sessions: usize, blocks: usize) -> Coordinator<ToyBackend> {
        let shape = CacheShape {
            n_layers: 2,
            n_kv_heads: 2,
            k_width: vec![8, 8],
            v_width: vec![8, 8],
        };
        Coordinator::new(
            ToyBackend::new(64),
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions,
                    buckets: vec![1, 4],
                    max_queue: 100,
                    // See `coordinator`: speculation is opt-in per request.
                    default_speculative: None,
                    ..Default::default()
                },
                kv_budget_bytes: blocks * 8192,
            },
        )
    }

    #[test]
    fn oversubscribed_decode_preempts_resumes_and_stays_bit_identical() {
        // Two sessions that each peak at 3 blocks (prompt 16, max_new 33)
        // on a 4-block cache: optimistic admission takes both, growth
        // exhausts the cache mid-decode, the newer admission is parked and
        // later resumed — and every emitted token must match the
        // uncontended (100-block) run exactly.
        let run = |blocks: usize| {
            let mut c = tight_coordinator(2, blocks);
            for id in 0..2u64 {
                assert!(c.submit(Request::new(id, vec![3u8; 16], 33)));
            }
            let mut out = c.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            let gens: Vec<Vec<u8>> = out.iter().map(|r| r.generated.clone()).collect();
            (gens, c.metrics.preemptions, c.metrics.resumes, c.kv_used_blocks())
        };
        let (baseline, p0, r0, _) = run(100);
        assert_eq!(p0, 0, "uncontended run never preempts");
        assert_eq!(r0, 0);
        assert_eq!(baseline[0].len(), 33);
        let (contended, preemptions, resumes, used) = run(4);
        assert_eq!(contended, baseline, "preempt/resume must be bit-identical");
        assert!(preemptions >= 1, "4 blocks cannot hold two 3-block peaks");
        assert!(resumes >= 1);
        assert_eq!(used, 0, "all blocks returned after the storm");
    }

    #[test]
    fn impossible_prompts_rejected_at_submit_with_distinct_reason() {
        let mut c = tight_coordinator(2, 2);
        // 48 tokens need 3 blocks; the cache physically has 2.
        let err = c.try_submit(Request::new(1, vec![0u8; BLOCK_TOKENS * 3], 4));
        assert_eq!(err, Err(SubmitError::PromptTooLarge));
        assert_eq!(c.metrics.rejected, 1);
        assert_eq!(c.metrics.rejected_too_large, 1);
        assert_eq!(c.pending(), 0, "never queued");

        // Queue backpressure stays a *distinct* reason.
        let shape = CacheShape {
            n_layers: 2,
            n_kv_heads: 2,
            k_width: vec![8, 8],
            v_width: vec![8, 8],
        };
        let mut c = Coordinator::new(
            ToyBackend::new(64),
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig { max_queue: 1, ..Default::default() },
                kv_budget_bytes: 16 << 20,
            },
        );
        assert_eq!(c.try_submit(Request::new(1, vec![1, 2], 2)), Ok(()));
        assert_eq!(c.try_submit(Request::new(2, vec![1, 2], 2)), Err(SubmitError::QueueFull));
        assert_eq!(c.metrics.rejected, 1);
        assert_eq!(c.metrics.rejected_too_large, 0);
    }

    #[test]
    fn deadline_expiry_times_out_wherever_the_session_lives() {
        // Expired while still queued: swept on the first tick, before
        // admission could even reserve for it.
        let mut c = coordinator(1);
        assert!(c.submit(Request::new(1, vec![1, 2, 3], 5).with_deadline_ms(0)));
        let out = c.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].metrics.finish_reason, FinishReason::Timeout);
        assert!(out[0].generated.is_empty());
        assert_eq!(c.metrics.timeouts, 1);

        // Expired mid-decode: keeps the tokens generated so far, releases
        // its blocks the same tick.
        let mut c = coordinator(1);
        assert!(c.submit(Request::new(2, vec![1, 2, 3], 1000).with_deadline_ms(30)));
        for _ in 0..3 {
            c.tick().unwrap();
        }
        assert_eq!(c.pending(), 1, "still decoding");
        std::thread::sleep(std::time::Duration::from_millis(40));
        let out = c.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].metrics.finish_reason, FinishReason::Timeout);
        assert!(!out[0].generated.is_empty(), "partial generation survives the timeout");
        assert!(out[0].generated.len() < 1000);
        assert_eq!(c.metrics.timeouts, 1);
        assert_eq!(c.kv_used_blocks(), 0, "timed-out session fully released");
        assert_eq!(c.backend.sessions.len(), 0);
    }

    #[test]
    fn cancel_of_a_preempted_session_returns_blocks_and_tokens() {
        let mut c = tight_coordinator(2, 4);
        for id in 0..2u64 {
            assert!(c.submit(Request::new(id, vec![3u8; 16], 33)));
        }
        let mut victim = None;
        for _ in 0..200 {
            for ev in c.tick().unwrap() {
                if let Event::Preempted { id } = ev {
                    victim = Some(id);
                }
            }
            if victim.is_some() {
                break;
            }
        }
        let victim = victim.expect("4 blocks force a preemption");
        let r = c.cancel(victim).expect("parked sessions are cancellable");
        assert_eq!(r.metrics.finish_reason, FinishReason::Cancelled);
        assert!(!r.generated.is_empty(), "tokens emitted before parking survive");
        assert!(c.cancel(victim).is_none(), "double-cancel is a no-op");
        let out = c.run_to_completion().unwrap();
        let survivor = out.iter().find(|r| r.id != victim).unwrap();
        assert_eq!(survivor.generated.len(), 33);
        assert_eq!(c.metrics.resumes, 0, "cancelled before any resume");
        assert_eq!(c.metrics.cancelled, 1);
        assert_eq!(c.kv_used_blocks(), 0, "blocks back to baseline");
        assert_eq!(c.backend.sessions.len(), 0);
    }

    #[test]
    fn injected_alloc_faults_are_transient_and_recoverable() {
        let mut c = coordinator(2);
        let plan = FaultPlan::new(3).with_alloc_faults(1.0);
        c.set_fault_plan(Some(&plan));
        assert!(c.submit(Request::new(1, vec![1, 2, 3], 5)));
        // Every admission reserve fails by injection; the request just
        // stays queued — nothing is preempted, nothing errors.
        for _ in 0..3 {
            let ev = c.tick().unwrap();
            assert!(ev.is_empty(), "no progress under a 100% alloc-fault storm");
        }
        assert_eq!(c.pending(), 1);
        assert!(c.kv_alloc_faults_injected() >= 3);
        c.set_fault_plan(None);
        let out = c.run_to_completion().unwrap();
        assert_eq!(out[0].generated, vec![4, 5, 6, 0, 1], "output unchanged by the storm");
        assert_eq!(c.metrics.preemptions, 0, "faults defer, never preempt");
        assert_eq!(c.kv_used_blocks(), 0);
    }

    #[test]
    fn transient_backend_faults_retry_without_changing_output() {
        use crate::coordinator::faults::FaultBackend;
        let shape = CacheShape {
            n_layers: 2,
            n_kv_heads: 2,
            k_width: vec![8, 8],
            v_width: vec![8, 8],
        };
        let plan = FaultPlan::new(11).with_prefill_faults(0.5).with_decode_faults(0.5);
        let mut c = Coordinator::new(
            FaultBackend::new(ToyBackend::new(64), &plan),
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 4,
                    buckets: vec![1, 4],
                    max_queue: 100,
                    ..Default::default()
                },
                kv_budget_bytes: 16 << 20,
            },
        );
        for id in 0..4u64 {
            assert!(c.submit(Request::new(id, vec![1, 2, 3], 8)));
        }
        let out = c.run_to_completion().unwrap();
        assert_eq!(out.len(), 4);
        for r in &out {
            assert_eq!(r.generated, vec![4, 5, 6, 0, 1, 2, 3, 4], "faults never corrupt output");
        }
        let (pf, df) = c.backend.injected();
        assert!(pf + df > 0, "a 50% plan over 4 sessions must fire");
        assert_eq!(
            c.metrics.backend_retries,
            pf + df,
            "every injected backend fault was absorbed as a retry"
        );
        assert_eq!(c.kv_used_blocks(), 0);
        assert_eq!(c.backend.inner().sessions.len(), 0);
    }

    #[test]
    fn speculative_output_matches_plain_with_fewer_backend_calls() {
        use crate::speculate::SpeculativeSpec;
        let mut plain = coordinator(2);
        plain.submit(Request::new(1, vec![1, 2, 3], 16));
        let pr = plain.run_to_completion().unwrap();

        let mut spec = coordinator(2);
        spec.submit(
            Request::new(1, vec![1, 2, 3], 16)
                .with_speculative(SpeculativeSpec::parse("ngram:4").unwrap()),
        );
        let sr = spec.run_to_completion().unwrap();

        assert_eq!(pr[0].generated, sr[0].generated, "speculation never changes output");
        // ToyBackend's chain is periodic mod 7, so once the stream wraps
        // the n-gram drafter predicts it perfectly: several accepted
        // tokens per verify chunk, far fewer backend calls than tokens.
        assert!(spec.metrics.spec_steps > 0, "drafter must fire on a periodic stream");
        assert!(spec.metrics.spec_accepted_tokens > 0);
        assert!(
            spec.backend.verify_calls + spec.backend.decode_calls
                < plain.backend.decode_calls,
            "verify={} decode={} vs plain decode={}",
            spec.backend.verify_calls,
            spec.backend.decode_calls,
            plain.backend.decode_calls
        );
        assert_eq!(spec.metrics.spec_steps, spec.backend.verify_calls as u64);
        assert_eq!(spec.kv_used_blocks(), 0);
        // Multi-token steps are billed per backend call, not per token.
        let m = &sr[0].metrics;
        assert!(m.decode_ms_per_step >= m.decode_ms_per_token);
    }

    #[test]
    fn rejected_draft_rolls_back_and_output_is_unchanged() {
        use crate::speculate::SpeculativeSpec;
        // Prompt [2, 3, 9, 2]: the first emitted token is 3, so the
        // stream's suffix [2, 3] matches the prompt's head — which was
        // followed by 9, not the chain's true 4.  The first speculative
        // step drafts [9, 2, 3], the verifier rejects everything, and the
        // three dead rows roll back.
        let mut plain = coordinator(2);
        plain.submit(Request::new(1, vec![2, 3, 9, 2], 8));
        let pr = plain.run_to_completion().unwrap();
        assert_eq!(pr[0].generated, vec![3, 4, 5, 6, 0, 1, 2, 3]);

        let mut spec = coordinator(2);
        spec.submit(
            Request::new(1, vec![2, 3, 9, 2], 8)
                .with_speculative(SpeculativeSpec::parse("ngram:4").unwrap()),
        );
        let sr = spec.run_to_completion().unwrap();
        assert_eq!(sr[0].generated, pr[0].generated, "rejected drafts cost rows, not tokens");
        assert!(spec.metrics.spec_steps >= 1);
        assert_eq!(spec.metrics.spec_accepted_tokens, 0, "the misleading draft never matches");
        assert!(spec.metrics.spec_rolled_back_rows >= 3, "all dead draft rows returned");
        assert_eq!(spec.kv_used_blocks(), 0, "rollback leaves no stranded blocks");
    }
}
