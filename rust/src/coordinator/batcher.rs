//! Admission queue + continuous-batching policy.
//!
//! The batcher decides, each scheduler tick, (i) which queued requests to
//! admit (bounded by the paged KV-cache budget and a max concurrent-session
//! cap) and (ii) how to group running sessions into decode batches for the
//! exported batch buckets.  Decode-heavy continuous batching: new requests
//! are admitted as soon as cache capacity allows; running sequences never
//! wait for stragglers because the decode graphs take per-sequence
//! positions.
//!
//! Admission is **optimistic** by default: only the prompt's blocks are
//! reserved up front, and decode-time growth allocates block-by-block on
//! demand.  This oversubscribes the cache — admitted sessions' worst-case
//! footprints may exceed physical capacity — trading the old "admitted
//! implies guaranteed to finish" invariant for much higher concurrency;
//! the scheduler's preemption path restores progress when growth fails.
//! Set [`BatcherConfig::reserve_worst_case`] to get the old
//! `prompt + max_new` up-front reservation back (no preemption possible,
//! admission-limited throughput — kept as the benchmark baseline).

use std::collections::VecDeque;

use crate::coordinator::request::{Request, RequestId};
use crate::kvcache::retention::RetentionSpec;
use crate::kvcache::PagedKvCache;
use crate::speculate::SpeculativeSpec;

/// One admitted request plus its prefix-cache outcome.
#[derive(Debug)]
pub struct Admission {
    pub req: Request,
    /// Prompt tokens already resident in shared prefix blocks — chunked
    /// prefill starts at this position instead of 0.
    pub matched_tokens: usize,
    /// Leading blocks attached from the prefix trie instead of allocated.
    pub shared_blocks: usize,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max sessions decoding concurrently.
    pub max_sessions: usize,
    /// Available decode batch buckets, ascending (e.g. [1, 4]).
    pub buckets: Vec<usize>,
    /// Queue bound; submits beyond this are rejected (backpressure).
    pub max_queue: usize,
    /// Prompt-token budget one scheduler tick may spend on prefill before
    /// its decode round (Sarathi-style chunked prefill).  For backends
    /// with `supports_chunked_prefill()`, long prompts are fed to
    /// `Backend::prefill_chunk` in pieces of at most this many tokens, so
    /// admitting a 2k-token prompt can never stall in-flight decode
    /// sessions for more than one chunk.  Backends that cannot resume a
    /// partial prompt receive it whole in a single call — the budget then
    /// only bounds how many *prompts* one tick starts, not the length of
    /// the stall.
    pub prefill_chunk_tokens: usize,
    /// Reserve `prompt + max_new` blocks at admission (the pre-preemption
    /// policy) instead of the default optimistic prompt-only reservation.
    /// With this set a session can never be preempted, at the cost of
    /// admitting far fewer concurrent sessions on the same budget.
    pub reserve_worst_case: bool,
    /// Fleet-wide KV retention default applied at admission to requests
    /// that did not carry their own `retention` field.  `None` (the
    /// default when `RAP_RETENTION` is unset) = retain-all, which is
    /// bit-identical to the pre-retention stack.
    pub default_retention: Option<RetentionSpec>,
    /// Fleet-wide speculative-decode default applied at admission to
    /// requests that did not carry their own `speculative` field.  `None`
    /// (the default when `RAP_SPECULATIVE` is unset) = plain one-token
    /// decode.  Output is unchanged either way — the knob only changes
    /// how many sampler draws each backend call covers.
    pub default_speculative: Option<SpeculativeSpec>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_sessions: 8,
            buckets: vec![1, 4],
            max_queue: 1024,
            prefill_chunk_tokens: 128,
            reserve_worst_case: false,
            default_retention: RetentionSpec::from_env(),
            default_speculative: SpeculativeSpec::from_env(),
        }
    }
}

#[derive(Debug)]
pub struct Batcher {
    pub cfg: BatcherConfig,
    queue: VecDeque<Request>,
    running: Vec<RequestId>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher {
            cfg,
            queue: VecDeque::new(),
            running: Vec::new(),
        }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Enqueue a request; returns false when the queue is full or the id
    /// is already queued/running.  Admission reserves per-id KV state
    /// (`reserve_prefix` refuses an id with a live reservation), so letting
    /// a duplicate reach the queue front would wedge admission behind an
    /// error that cannot clear until the original session finishes.
    pub fn submit(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.cfg.max_queue
            || self.running.contains(&req.id)
            || self.queue.iter().any(|r| r.id == req.id)
        {
            return false;
        }
        self.queue.push_back(req);
        true
    }

    /// Admit queued requests while session slots and KV capacity allow.
    /// Admission queries the prefix trie (`PagedKvCache::reserve_prefix`):
    /// a prompt whose block-aligned prefix is already resident attaches
    /// those blocks read-only and reserves fresh blocks only for the
    /// *unmatched* suffix.  By default only the prompt is reserved
    /// (optimistic admission; decode grows on demand and may preempt);
    /// with [`BatcherConfig::reserve_worst_case`] the whole
    /// `prompt + max_new` budget is reserved up front.
    pub fn admit(&mut self, kv: &mut PagedKvCache) -> Vec<Admission> {
        let mut admitted: Vec<Admission> = Vec::new();
        while self.running.len() + admitted.len() < self.cfg.max_sessions {
            let Some(req) = self.queue.front() else { break };
            let retention = req.retention.or(self.cfg.default_retention);
            let speculative = req.speculative.or(self.cfg.default_speculative);
            // Zero-token requests complete at admission without touching
            // the allocator: reserving (and zeroing) max_new blocks just
            // to release them in the same tick would let an empty prompt
            // head-of-line block admission under KV pressure.
            if req.prompt.is_empty() {
                let mut req = self.queue.pop_front().unwrap();
                req.retention = retention;
                req.speculative = speculative;
                admitted.push(Admission { req, matched_tokens: 0, shared_blocks: 0 });
                continue;
            }
            let reserve = if self.cfg.reserve_worst_case {
                req.total_tokens()
            } else {
                req.prompt.len()
            };
            match kv.reserve_prefix(req.id, &req.prompt, reserve) {
                Ok(m) => {
                    let mut req = self.queue.pop_front().unwrap();
                    req.retention = retention;
                    req.speculative = speculative;
                    admitted.push(Admission {
                        req,
                        matched_tokens: m.matched_tokens,
                        shared_blocks: m.shared_blocks,
                    });
                }
                Err(_) => break, // KV pressure: stop admitting this tick
            }
        }
        for a in &admitted {
            self.running.push(a.req.id);
        }
        admitted
    }

    /// Group runnable sessions into decode batches using the largest bucket
    /// that is fully utilisable, falling back to smaller buckets for the
    /// tail.  `runnable` is the set of session ids wanting one more token.
    pub fn decode_batches(&self, runnable: &[RequestId]) -> Vec<Vec<RequestId>> {
        let mut out = Vec::new();
        let mut rest = runnable.to_vec();
        let mut buckets = self.cfg.buckets.clone();
        buckets.sort_unstable();
        while !rest.is_empty() {
            // Largest bucket <= remaining; smallest bucket otherwise.
            let b = buckets
                .iter()
                .rev()
                .find(|&&b| b <= rest.len())
                .copied()
                .unwrap_or_else(|| buckets[0]);
            let take = b.min(rest.len());
            let mut batch: Vec<RequestId> = rest.drain(..take).collect();
            // Pad by repeating the last session? No — the scheduler pads
            // with an idle slot; the batcher just reports the group.
            batch.truncate(b);
            out.push(batch);
        }
        out
    }

    pub fn finish(&mut self, id: RequestId, kv: &mut PagedKvCache) {
        self.running.retain(|&r| r != id);
        kv.release(id);
    }

    /// Put a request at the *front* of the queue (preemption of a
    /// prefilling session: it must re-admit before anything newer).  The
    /// caller has already released its KV state; this only rewinds the
    /// queue position.
    pub fn requeue_front(&mut self, req: Request) {
        self.running.retain(|&r| r != req.id);
        self.queue.push_front(req);
    }

    /// Register a session admitted outside [`Batcher::admit`] — the
    /// scheduler's preemption-resume path reserves KV state itself and
    /// then claims the slot here so the session cap and duplicate
    /// detection keep holding.
    pub fn note_running(&mut self, id: RequestId) {
        if !self.running.contains(&id) {
            self.running.push(id);
        }
    }

    /// Ids of queued requests whose deadline has already expired — the
    /// scheduler tears them down with `FinishReason::Timeout` before
    /// admission can waste KV blocks on them.
    pub fn expired_queued(&self) -> Vec<RequestId> {
        self.queue
            .iter()
            .filter(|r| r.deadline_expired())
            .map(|r| r.id)
            .collect()
    }

    /// Remove a still-queued request (cancellation before admission).
    /// Queued requests hold no KV reservation, so there is nothing to
    /// release; returns the request so the caller can build the final
    /// `Cancelled` response from it.
    pub fn remove_queued(&mut self, id: RequestId) -> Option<Request> {
        let pos = self.queue.iter().position(|r| r.id == id)?;
        self.queue.remove(pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{CacheShape, PagedKvCache, BLOCK_TOKENS};

    fn kv(blocks: usize) -> PagedKvCache {
        let shape = CacheShape {
            n_layers: 2,
            n_kv_heads: 2,
            k_width: vec![8, 8],
            v_width: vec![8, 8],
        };
        let bytes = shape.bytes_per_block() * blocks;
        PagedKvCache::new(shape, bytes)
    }

    fn req(id: u64, total: usize) -> Request {
        Request::new(id, vec![0u8; total / 2], total - total / 2)
    }

    #[test]
    fn admit_respects_session_cap() {
        let mut b = Batcher::new(BatcherConfig {
            max_sessions: 2,
            ..Default::default()
        });
        let mut kv = kv(100);
        for i in 0..5 {
            assert!(b.submit(req(i, 8)));
        }
        let adm = b.admit(&mut kv);
        assert_eq!(adm.len(), 2);
        assert_eq!(b.queue_len(), 3);
        assert_eq!(b.running_len(), 2);
    }

    #[test]
    fn worst_case_admission_respects_kv_budget() {
        let mut b = Batcher::new(BatcherConfig {
            max_sessions: 10,
            reserve_worst_case: true,
            ..Default::default()
        });
        // 3 blocks: each request needs 2 blocks (BLOCK_TOKENS*2 tokens).
        let mut kv = kv(3);
        for i in 0..3 {
            b.submit(req(i, BLOCK_TOKENS * 2));
        }
        let adm = b.admit(&mut kv);
        assert_eq!(adm.len(), 1, "only one 2-block request fits in 3 blocks");
        // Finishing frees capacity; the next admit succeeds.
        b.finish(adm[0].req.id, &mut kv);
        let adm2 = b.admit(&mut kv);
        assert_eq!(adm2.len(), 1);
    }

    #[test]
    fn optimistic_admission_oversubscribes() {
        let mut b = Batcher::new(BatcherConfig {
            max_sessions: 10,
            ..Default::default()
        });
        // Same workload, same 3 physical blocks: optimistic admission
        // reserves only the 1-block prompts, so all three fit even though
        // their combined worst case (6 blocks) is 2x the capacity.
        let mut kv = kv(3);
        for i in 0..3 {
            b.submit(req(i, BLOCK_TOKENS * 2));
        }
        let adm = b.admit(&mut kv);
        assert_eq!(adm.len(), 3, "prompt-only reservations all fit");
        assert_eq!(kv.used_blocks(), 3);
    }

    #[test]
    fn requeue_front_restores_queue_priority() {
        let mut b = Batcher::new(BatcherConfig {
            max_sessions: 1,
            ..Default::default()
        });
        let mut kv = kv(100);
        assert!(b.submit(req(1, 8)));
        assert!(b.submit(req(2, 8)));
        let adm = b.admit(&mut kv);
        assert_eq!(adm[0].req.id, 1);
        // Preempt 1: its KV state goes away, the request goes back to the
        // queue FRONT — re-admitted before 2 despite 2 queueing first.
        kv.release(1);
        b.requeue_front(adm.into_iter().next().unwrap().req);
        assert_eq!(b.running_len(), 0);
        assert!(!b.submit(req(1, 8)), "requeued id still counts as queued");
        let adm2 = b.admit(&mut kv);
        assert_eq!(adm2[0].req.id, 1, "preempted request re-admits first");
    }

    #[test]
    fn note_running_claims_a_slot() {
        let mut b = Batcher::new(BatcherConfig {
            max_sessions: 1,
            ..Default::default()
        });
        let mut kv = kv(100);
        b.note_running(7);
        b.note_running(7);
        assert_eq!(b.running_len(), 1, "idempotent");
        assert!(!b.submit(req(7, 8)), "duplicate of a noted session rejected");
        assert!(b.submit(req(8, 8)));
        assert_eq!(b.admit(&mut kv).len(), 0, "noted session holds the only slot");
        b.finish(7, &mut kv);
        assert_eq!(b.admit(&mut kv).len(), 1);
    }

    #[test]
    fn admit_shares_resident_prompt_prefixes() {
        let mut b = Batcher::new(BatcherConfig {
            max_sessions: 4,
            ..Default::default()
        });
        let shape = CacheShape {
            n_layers: 2,
            n_kv_heads: 2,
            k_width: vec![8, 8],
            v_width: vec![8, 8],
        };
        let mut kv = PagedKvCache::with_storage(shape.clone(), shape.bytes_per_block() * 64);
        // Two prompts sharing a 2-block prefix, one unrelated prompt.
        let prefix: Vec<u8> = (0..BLOCK_TOKENS * 2).map(|i| (i % 97) as u8).collect();
        let mut p1 = prefix.clone();
        p1.extend([200u8; 8]);
        let mut p2 = prefix.clone();
        p2.extend([201u8; 8]);
        assert!(b.submit(Request::new(1, p1, 8)));
        assert!(b.submit(Request::new(2, p2, 8)));
        assert!(b.submit(Request::new(3, vec![7u8; BLOCK_TOKENS * 2 + 8], 8)));
        let adm = b.admit(&mut kv);
        assert_eq!(adm.len(), 3);
        assert_eq!(adm[0].matched_tokens, 0, "cold trie");
        assert_eq!(adm[1].matched_tokens, BLOCK_TOKENS * 2);
        assert_eq!(adm[1].shared_blocks, 2);
        assert_eq!(adm[2].matched_tokens, 0, "different prefix never matches");
        // 1 and 2 share the two prefix blocks: 3 + 1 + 3 blocks, not 3+3+3.
        assert_eq!(kv.used_blocks(), 7);
    }

    #[test]
    fn admit_fills_in_fleet_default_retention() {
        use crate::kvcache::retention::{Press, RetentionSpec};
        let fleet = RetentionSpec { press: Press::Window, ratio: 0.5 };
        let own = RetentionSpec { press: Press::L2Norm, ratio: 0.25 };
        let mut b = Batcher::new(BatcherConfig {
            default_retention: Some(fleet),
            ..Default::default()
        });
        let mut kv = kv(100);
        assert!(b.submit(req(1, 8)));
        assert!(b.submit(req(2, 8).with_retention(own)));
        let adm = b.admit(&mut kv);
        assert_eq!(adm.len(), 2);
        assert_eq!(adm[0].req.retention, Some(fleet), "default fills the gap");
        assert_eq!(adm[1].req.retention, Some(own), "per-request wins");
    }

    #[test]
    fn admit_fills_in_fleet_default_speculative() {
        use crate::speculate::{DraftPolicy, SpeculativeSpec};
        let fleet = SpeculativeSpec { policy: DraftPolicy::Ngram, k: 4 };
        let own = SpeculativeSpec { policy: DraftPolicy::Ngram, k: 8 };
        let mut b = Batcher::new(BatcherConfig {
            default_speculative: Some(fleet),
            ..Default::default()
        });
        let mut kv = kv(100);
        assert!(b.submit(req(1, 8)));
        assert!(b.submit(req(2, 8).with_speculative(own)));
        let adm = b.admit(&mut kv);
        assert_eq!(adm.len(), 2);
        assert_eq!(adm[0].req.speculative, Some(fleet), "default fills the gap");
        assert_eq!(adm[1].req.speculative, Some(own), "per-request wins");
    }

    #[test]
    fn queue_backpressure() {
        let mut b = Batcher::new(BatcherConfig {
            max_queue: 2,
            ..Default::default()
        });
        assert!(b.submit(req(1, 4)));
        assert!(b.submit(req(2, 4)));
        assert!(!b.submit(req(3, 4)), "queue full must reject");
    }

    #[test]
    fn duplicate_ids_rejected_not_wedged() {
        let mut b = Batcher::new(BatcherConfig::default());
        let mut kv = kv(100);
        assert!(b.submit(req(1, 8)));
        assert!(!b.submit(req(1, 8)), "queued duplicate rejected");
        let adm = b.admit(&mut kv);
        assert_eq!(adm.len(), 1);
        assert!(!b.submit(req(1, 8)), "running duplicate rejected");
        // Admission keeps flowing for other ids behind a would-be duplicate.
        assert!(b.submit(req(2, 8)));
        assert_eq!(b.admit(&mut kv).len(), 1);
        b.finish(1, &mut kv);
        assert!(b.submit(req(1, 8)), "id reusable once the session finished");
    }

    #[test]
    fn remove_queued_cancels_before_admission() {
        let mut b = Batcher::new(BatcherConfig::default());
        let mut kv = kv(100);
        assert!(b.submit(req(1, 8)));
        assert!(b.submit(req(2, 8)));
        let cancelled = b.remove_queued(1).expect("request 1 is queued");
        assert_eq!(cancelled.id, 1);
        assert_eq!(b.queue_len(), 1);
        assert!(b.remove_queued(1).is_none(), "already removed");
        // Admission proceeds normally for the survivor; the cancelled id
        // never reserved anything, so the id is immediately reusable.
        assert_eq!(b.admit(&mut kv).len(), 1);
        assert_eq!(b.running_len(), 1);
        assert!(b.submit(req(1, 8)));
        assert!(b.remove_queued(99).is_none(), "unknown ids are a no-op");
    }

    #[test]
    fn decode_batches_prefer_large_buckets() {
        let b = Batcher::new(BatcherConfig {
            buckets: vec![1, 4],
            ..Default::default()
        });
        let groups = b.decode_batches(&[10, 11, 12, 13, 14, 15]);
        let sizes: Vec<usize> = groups.iter().map(|g| g.len()).collect();
        assert_eq!(sizes, vec![4, 1, 1]);
        let flat: Vec<u64> = groups.into_iter().flatten().collect();
        assert_eq!(flat, vec![10, 11, 12, 13, 14, 15]);
    }

    #[test]
    fn decode_batches_small_tail() {
        let b = Batcher::new(BatcherConfig {
            buckets: vec![1, 4],
            ..Default::default()
        });
        assert_eq!(b.decode_batches(&[1, 2]).len(), 2);
        assert_eq!(b.decode_batches(&[]).len(), 0);
    }
}
