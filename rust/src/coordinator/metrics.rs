//! Serving metrics: per-request and aggregate (TTFT, per-token latency,
//! throughput, KV pressure).

use std::time::Duration;

use crate::coordinator::request::FinishReason;
use crate::util::stats::Welford;

#[derive(Debug, Clone, Default)]
pub struct RequestMetrics {
    pub queue_ms: f64,
    /// Time to first token (queue + prefill).
    pub ttft_ms: f64,
    /// Mean wall time this request waited per generated token.  Every
    /// session in a decode batch waits the *full* step, so each step
    /// contributes its whole wall time here (the throughput-side,
    /// occupancy-normalised number lives in
    /// `AggregateMetrics::decode_per_token_shared`).
    pub decode_ms_per_token: f64,
    /// Mean wall time per *decode step* (one backend call: a single-token
    /// round or one speculative verify chunk).  Equal to
    /// `decode_ms_per_token` for plain decode; under speculation a step
    /// emits several tokens, so this stays at the per-call latency while
    /// `decode_ms_per_token` drops below it — the ratio is the realised
    /// speedup.  (The v1 accounting billed a multi-token step once per
    /// emitted token, over-counting decode wall time m×.)
    pub decode_ms_per_step: f64,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub total_ms: f64,
    /// Why the request ended (length / stop / cancelled / rejected) —
    /// surfaced in the server's final summary line.
    pub finish_reason: FinishReason,
}

#[derive(Debug, Default)]
pub struct AggregateMetrics {
    pub requests: u64,
    pub ttft: Welford,
    pub decode_per_token: Welford,
    pub queue: Welford,
    pub total_tokens: u64,
    pub wall: Duration,
    pub peak_kv_blocks: usize,
    /// Storage mode of the coordinator's paged cache (`KvStorageMode::name`:
    /// "f32" or "packed-int4") — fixed at construction.
    pub kv_storage_mode: &'static str,
    /// Peak bytes physically resident for KV rows under that storage mode
    /// (hot session blocks + cold prefix blocks), sampled every tick.
    pub peak_kv_resident_bytes: usize,
    /// Submissions refused by queue backpressure (the server answers them
    /// with an explicit `queue_full` rejection, never silence).
    pub rejected: u64,
    /// Sessions torn down mid-flight by `Coordinator::cancel` — queued,
    /// prefilling, or decoding; their KV reservation (and any shared
    /// prefix refcounts) is released at cancellation.
    pub cancelled: u64,
    /// Sessions ended by a stop sequence before reaching `max_new`.
    pub stopped_early: u64,
    pub decode_batches: u64,
    pub decode_batch_occupancy: Welford,
    /// Prefill chunks executed (Sarathi-style chunked admission).
    pub prefill_chunks: u64,
    /// Tokens per prefill chunk.
    pub prefill_chunk_tokens: Welford,
    /// Max prefill chunks executed between two consecutive decode rounds
    /// while at least one session was waiting to decode — the chunked
    /// admission interleave bound (1 when the per-tick prefill budget
    /// equals one chunk: a long prompt delays in-flight decodes by at most
    /// one chunk).
    pub max_prefill_chunks_between_decodes: u64,
    /// Decode-step wall time divided by batch occupancy, one sample per
    /// decode batch — what a token costs the fleet.  Per-request
    /// `decode_ms_per_token` instead attributes the full step to every
    /// waiting session (the latency each session actually observed).
    pub decode_per_token_shared: Welford,
    /// Admissions that consulted the prefix trie.
    pub prefix_lookups: u64,
    /// Admissions that found a shared block-aligned prompt prefix.
    pub prefix_hits: u64,
    /// Blocks attached from the shared prefix cache instead of being
    /// allocated (and prefilled) again.
    pub prefix_saved_blocks: u64,
    /// Prompt tokens skipped at prefill, per prefix hit.
    pub prefix_matched_tokens: Welford,
    /// Submissions refused because the prompt alone exceeds the cache's
    /// total physical blocks (a subset of `rejected` — these could never
    /// be admitted, not even on an idle server).
    pub rejected_too_large: u64,
    /// Sessions that lost their KV blocks to memory pressure (running
    /// sessions parked for recompute + prefilling sessions requeued).
    pub preemptions: u64,
    /// Parked sessions restored to decoding after prefix recompute.
    pub resumes: u64,
    /// Sessions ended by their `deadline_ms` budget.
    pub timeouts: u64,
    /// Lone sessions truncated with `Length` on a genuinely full cache
    /// (nothing left to preempt or evict).
    pub oom_truncations: u64,
    /// Transient (injected) backend failures absorbed by retrying the
    /// prefill chunk or skipping the decode round.
    pub backend_retries: u64,
    /// Decode-growth allocations deferred one tick by an injected
    /// allocator fault (distinct from preemption: nothing was released).
    pub alloc_defers: u64,
    /// Retention presses executed (one per session compaction).
    pub retention_presses: u64,
    /// Token rows evicted by retention presses across all sessions.
    pub retention_evicted_tokens: u64,
    /// Speculative steps executed (one verify chunk each).
    pub spec_steps: u64,
    /// Draft tokens submitted for verification across all spec steps.
    pub spec_drafted_tokens: u64,
    /// Draft tokens the verifier confirmed (accepted prefix lengths).
    pub spec_accepted_tokens: u64,
    /// KV rows written for rejected draft suffixes and rolled back via
    /// `truncate_rows` (returned to the pool the same tick).
    pub spec_rolled_back_rows: u64,
    /// Tokens emitted per speculative step (accepted draft + the bonus
    /// token) — the headline acceptance metric; > 1 means speculation
    /// beat plain decode on call count.
    pub spec_tokens_per_step: Welford,
    /// Per-request mean decode wall per step, one sample per finished
    /// request that decoded (companion to `decode_per_token`).
    pub decode_per_step: Welford,
}

impl AggregateMetrics {
    pub fn record(&mut self, m: &RequestMetrics) {
        self.requests += 1;
        self.ttft.add(m.ttft_ms);
        if m.generated_tokens > 0 {
            self.decode_per_token.add(m.decode_ms_per_token);
            self.decode_per_step.add(m.decode_ms_per_step);
        }
        self.queue.add(m.queue_ms);
        self.total_tokens += (m.prompt_tokens + m.generated_tokens) as u64;
        match m.finish_reason {
            FinishReason::Cancelled => self.cancelled += 1,
            FinishReason::Stop => self.stopped_early += 1,
            FinishReason::Timeout => self.timeouts += 1,
            FinishReason::Length | FinishReason::Rejected => {}
        }
    }

    /// Fraction of admissions served a shared prompt prefix.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / self.prefix_lookups as f64
    }

    /// Generated tokens per second of wall time.
    pub fn throughput_tps(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.total_tokens as f64 / self.wall.as_secs_f64()
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} rejected={} cancelled={} stopped_early={} tokens={} wall={:.2}s throughput={:.1} tok/s\n\
             ttft: mean {:.1} ms (max {:.1})  decode: mean {:.2} ms/tok (shared {:.2})  queue: mean {:.1} ms\n\
             decode batches={} mean occupancy={:.2}  peak kv blocks={} storage={} resident={:.2} MiB\n\
             prefill chunks={} mean tokens={:.1}  max decode stall={} chunks\n\
             prefix cache: {}/{} hits ({:.0}%)  saved blocks={}  mean matched={:.0} tok\n\
             pressure: preemptions={} resumes={} timeouts={} oom_truncations={} \
             backend_retries={} alloc_defers={} too_large={}\n\
             retention: presses={} evicted_tokens={}\n\
             speculative: steps={} drafted={} accepted={} rolled_back_rows={} \
             tokens/step={:.2}  decode: mean {:.2} ms/step",
            self.requests,
            self.rejected,
            self.cancelled,
            self.stopped_early,
            self.total_tokens,
            self.wall.as_secs_f64(),
            self.throughput_tps(),
            self.ttft.mean(),
            self.ttft.max,
            self.decode_per_token.mean(),
            self.decode_per_token_shared.mean(),
            self.queue.mean(),
            self.decode_batches,
            self.decode_batch_occupancy.mean(),
            self.peak_kv_blocks,
            self.kv_storage_mode,
            self.peak_kv_resident_bytes as f64 / (1 << 20) as f64,
            self.prefill_chunks,
            self.prefill_chunk_tokens.mean(),
            self.max_prefill_chunks_between_decodes,
            self.prefix_hits,
            self.prefix_lookups,
            100.0 * self.prefix_hit_rate(),
            self.prefix_saved_blocks,
            self.prefix_matched_tokens.mean(),
            self.preemptions,
            self.resumes,
            self.timeouts,
            self.oom_truncations,
            self.backend_retries,
            self.alloc_defers,
            self.rejected_too_large,
            self.retention_presses,
            self.retention_evicted_tokens,
            self.spec_steps,
            self.spec_drafted_tokens,
            self.spec_accepted_tokens,
            self.spec_rolled_back_rows,
            self.spec_tokens_per_step.mean(),
            self.decode_per_step.mean(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_accumulates() {
        let mut a = AggregateMetrics::default();
        a.record(&RequestMetrics {
            queue_ms: 1.0,
            ttft_ms: 10.0,
            decode_ms_per_token: 2.0,
            decode_ms_per_step: 4.0,
            prompt_tokens: 5,
            generated_tokens: 10,
            total_ms: 30.0,
            finish_reason: FinishReason::Length,
        });
        a.record(&RequestMetrics {
            queue_ms: 3.0,
            ttft_ms: 20.0,
            decode_ms_per_token: 4.0,
            decode_ms_per_step: 8.0,
            prompt_tokens: 5,
            generated_tokens: 10,
            total_ms: 60.0,
            finish_reason: FinishReason::Length,
        });
        assert_eq!(a.requests, 2);
        assert_eq!(a.total_tokens, 30);
        assert!((a.ttft.mean() - 15.0).abs() < 1e-9);
        assert!((a.decode_per_step.mean() - 6.0).abs() < 1e-9);
        a.wall = Duration::from_secs(3);
        assert!((a.throughput_tps() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn finish_reasons_feed_the_counters() {
        let mut a = AggregateMetrics::default();
        for reason in [
            FinishReason::Length,
            FinishReason::Stop,
            FinishReason::Stop,
            FinishReason::Cancelled,
            FinishReason::Timeout,
        ] {
            a.record(&RequestMetrics {
                finish_reason: reason,
                ..Default::default()
            });
        }
        assert_eq!(a.requests, 5);
        assert_eq!(a.stopped_early, 2);
        assert_eq!(a.cancelled, 1);
        assert_eq!(a.timeouts, 1);
        let report = a.report();
        assert!(report.contains("cancelled=1"), "{report}");
        assert!(report.contains("stopped_early=2"), "{report}");
        assert!(report.contains("timeouts=1"), "{report}");
    }

    #[test]
    fn report_shows_retention_counters() {
        let a = AggregateMetrics {
            retention_presses: 3,
            retention_evicted_tokens: 4096,
            ..AggregateMetrics::default()
        };
        let report = a.report();
        assert!(report.contains("presses=3"), "{report}");
        assert!(report.contains("evicted_tokens=4096"), "{report}");
    }

    #[test]
    fn report_shows_speculative_counters() {
        let mut a = AggregateMetrics {
            spec_steps: 4,
            spec_drafted_tokens: 12,
            spec_accepted_tokens: 9,
            spec_rolled_back_rows: 3,
            ..AggregateMetrics::default()
        };
        a.spec_tokens_per_step.add(3.0);
        a.spec_tokens_per_step.add(2.0);
        let report = a.report();
        assert!(report.contains("speculative: steps=4 drafted=12 accepted=9"), "{report}");
        assert!(report.contains("rolled_back_rows=3"), "{report}");
        assert!(report.contains("tokens/step=2.50"), "{report}");
    }

    #[test]
    fn report_shows_kv_storage_mode_and_resident_bytes() {
        let a = AggregateMetrics {
            kv_storage_mode: "packed-int4",
            peak_kv_resident_bytes: 3 << 20,
            ..AggregateMetrics::default()
        };
        let report = a.report();
        assert!(report.contains("storage=packed-int4"), "{report}");
        assert!(report.contains("resident=3.00 MiB"), "{report}");
    }

    #[test]
    fn prefix_hit_rate_handles_zero_lookups() {
        let mut a = AggregateMetrics::default();
        assert_eq!(a.prefix_hit_rate(), 0.0);
        a.prefix_lookups = 4;
        a.prefix_hits = 3;
        assert!((a.prefix_hit_rate() - 0.75).abs() < 1e-9);
    }
}
