//! Request/response types for the serving layer.

use std::time::Instant;

pub type RequestId = u64;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// Byte-level prompt (vocab 256).
    pub prompt: Vec<u8>,
    /// Number of tokens to generate.
    pub max_new: usize,
    /// Arrival timestamp (set by the coordinator on submit).
    pub arrival: Option<Instant>,
}

impl Request {
    pub fn new(id: RequestId, prompt: impl Into<Vec<u8>>, max_new: usize) -> Request {
        Request {
            id,
            prompt: prompt.into(),
            max_new,
            arrival: None,
        }
    }

    /// Total KV tokens this request will need at completion.
    pub fn total_tokens(&self) -> usize {
        self.prompt.len() + self.max_new
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub generated: Vec<u8>,
    pub metrics: super::metrics::RequestMetrics,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_tokens() {
        let r = Request::new(1, b"hello".to_vec(), 10);
        assert_eq!(r.total_tokens(), 15);
    }
}
