//! [`FaultBackend`] — a deterministic fault-injecting [`Backend`] wrapper.
//!
//! Each call site (prefill chunk, decode batch) draws from its own seeded
//! stream ([`crate::faults::FaultPlan`]); when a site fires the call fails
//! with an [`InjectedFault`] **before** touching the inner backend, so a
//! retry of the same chunk or decode round is always clean — the inner
//! backend never observes a half-applied call.  Slow ticks sleep for the
//! plan's `slow_tick_ms` before delegating, perturbing wall-clock timing
//! (TTFT, queue times) without changing any output.
//!
//! `drop_session` is never faulted: teardown must always succeed, or a
//! storm could leak backend state for sessions the coordinator already
//! released.

use anyhow::Result;

use crate::coordinator::scheduler::Backend;
use crate::coordinator::request::RequestId;
use crate::faults::{FaultInjector, FaultPlan};
use crate::kvcache::PagedKvCache;

pub struct FaultBackend<B> {
    inner: B,
    prefill: FaultInjector,
    decode: FaultInjector,
    slow: FaultInjector,
    slow_ms: u64,
}

impl<B: Backend> FaultBackend<B> {
    pub fn new(inner: B, plan: &FaultPlan) -> FaultBackend<B> {
        FaultBackend {
            inner,
            prefill: plan.prefill_injector(),
            decode: plan.decode_injector(),
            slow: plan.slow_tick_injector(),
            slow_ms: plan.slow_tick_ms,
        }
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }

    pub fn into_inner(self) -> B {
        self.inner
    }

    /// (prefill faults, decode faults) fired so far — storm tests assert
    /// the plan actually injected something.
    pub fn injected(&self) -> (u64, u64) {
        (self.prefill.injected(), self.decode.injected())
    }

    fn maybe_slow(&mut self) {
        if self.slow_ms > 0 && self.slow.fires() {
            std::thread::sleep(std::time::Duration::from_millis(self.slow_ms));
        }
    }
}

impl<B: Backend> Backend for FaultBackend<B> {
    fn s_max(&self) -> usize {
        self.inner.s_max()
    }

    fn wants_paged_storage(&self) -> bool {
        self.inner.wants_paged_storage()
    }

    fn supports_chunked_prefill(&self) -> bool {
        self.inner.supports_chunked_prefill()
    }

    fn prefill(
        &mut self,
        kv: &mut PagedKvCache,
        session: RequestId,
        prompt: &[u8],
    ) -> Result<Vec<f32>> {
        self.maybe_slow();
        if self.prefill.fires() {
            return Err(anyhow::Error::new(self.prefill.fault()));
        }
        self.inner.prefill(kv, session, prompt)
    }

    fn prefill_chunk(
        &mut self,
        kv: &mut PagedKvCache,
        session: RequestId,
        tokens: &[u8],
        pos0: usize,
        last: bool,
    ) -> Result<Option<Vec<f32>>> {
        self.maybe_slow();
        if self.prefill.fires() {
            return Err(anyhow::Error::new(self.prefill.fault()));
        }
        self.inner.prefill_chunk(kv, session, tokens, pos0, last)
    }

    fn decode_batch(
        &mut self,
        kv: &mut PagedKvCache,
        entries: &[(RequestId, u8, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        self.maybe_slow();
        if self.decode.fires() {
            return Err(anyhow::Error::new(self.decode.fault()));
        }
        self.inner.decode_batch(kv, entries)
    }

    fn verify_chunk(
        &mut self,
        kv: &mut PagedKvCache,
        session: RequestId,
        tokens: &[u8],
        pos0: usize,
    ) -> Result<Vec<Vec<f32>>> {
        self.maybe_slow();
        // One decode-fault draw per verify chunk — the whole chunk is one
        // decode step, and the fault fires before the inner backend sees
        // any of it (no KV row written, clean retry).
        if self.decode.fires() {
            return Err(anyhow::Error::new(self.decode.fault()));
        }
        self.inner.verify_chunk(kv, session, tokens, pos0)
    }

    fn drop_session(&mut self, session: RequestId) {
        self.inner.drop_session(session);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::InjectedFault;

    /// Minimal backend that records what actually reached it.
    struct Probe {
        prefills: usize,
        decodes: usize,
    }

    impl Backend for Probe {
        fn s_max(&self) -> usize {
            64
        }
        fn prefill(
            &mut self,
            _kv: &mut PagedKvCache,
            _session: RequestId,
            _prompt: &[u8],
        ) -> Result<Vec<f32>> {
            self.prefills += 1;
            Ok(vec![0.0; 256])
        }
        fn decode_batch(
            &mut self,
            _kv: &mut PagedKvCache,
            entries: &[(RequestId, u8, usize)],
        ) -> Result<Vec<Vec<f32>>> {
            self.decodes += 1;
            Ok(entries.iter().map(|_| vec![0.0; 256]).collect())
        }
        fn drop_session(&mut self, _session: RequestId) {}
    }

    fn kv() -> PagedKvCache {
        let shape = crate::kvcache::CacheShape {
            n_layers: 1,
            n_kv_heads: 1,
            k_width: vec![4],
            v_width: vec![4],
        };
        PagedKvCache::new(shape, 1 << 20)
    }

    #[test]
    fn faults_fire_before_the_inner_backend_sees_the_call() {
        let plan = FaultPlan::new(5).with_prefill_faults(1.0).with_decode_faults(1.0);
        let mut b = FaultBackend::new(Probe { prefills: 0, decodes: 0 }, &plan);
        let mut kv = kv();
        let err = b.prefill(&mut kv, 1, &[1, 2]).unwrap_err();
        assert!(err.downcast_ref::<InjectedFault>().is_some());
        let err = b.decode_batch(&mut kv, &[(1, 0, 2)]).unwrap_err();
        assert!(err.downcast_ref::<InjectedFault>().is_some());
        assert_eq!(b.inner().prefills, 0, "inner backend never touched");
        assert_eq!(b.inner().decodes, 0);
        assert_eq!(b.injected(), (1, 1));
    }

    #[test]
    fn zero_rate_plan_is_transparent() {
        let plan = FaultPlan::new(5);
        let mut b = FaultBackend::new(Probe { prefills: 0, decodes: 0 }, &plan);
        let mut kv = kv();
        for _ in 0..8 {
            b.prefill(&mut kv, 1, &[1]).unwrap();
            b.decode_batch(&mut kv, &[(1, 0, 1)]).unwrap();
        }
        assert_eq!(b.injected(), (0, 0));
        assert_eq!(b.into_inner().prefills, 8);
    }

    #[test]
    fn same_plan_same_fault_schedule_through_the_wrapper() {
        let run = |seed: u64| -> Vec<bool> {
            let plan = FaultPlan::new(seed).with_decode_faults(0.5);
            let mut b = FaultBackend::new(Probe { prefills: 0, decodes: 0 }, &plan);
            let mut kv = kv();
            (0..32).map(|_| b.decode_batch(&mut kv, &[(1, 0, 1)]).is_err()).collect()
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }
}
