//! L3 serving coordinator: admission, continuous batching, prefill/decode
//! scheduling, latent-width-aware KV accounting, metrics.
//!
//! The coordinator is backend-agnostic: the same scheduler drives the PJRT
//! runtime (`runtime::backend::PjrtBackend`, the production path) and the
//! pure-Rust engine (`model::backend::RustBackend`, used for dense latency
//! sweeps) — so every experiment exercises the identical batching logic.

pub mod batcher;
pub mod faults;
pub mod metrics;
pub mod request;
pub mod sampling;
pub mod scheduler;

pub use batcher::{Admission, Batcher, BatcherConfig};
pub use faults::FaultBackend;
pub use metrics::{AggregateMetrics, RequestMetrics};
pub use request::{Event, FinishReason, Request, RequestId, Response};
pub use sampling::{Sampler, SamplingParams};
pub use scheduler::{Backend, CoordSnapshot, Coordinator, CoordinatorConfig, SubmitError};
