//! Seeded token sampling for the serving API v2.
//!
//! Each request carries [`SamplingParams`] and owns a [`Sampler`] — a
//! deterministic per-request RNG (`util::rng`, xoshiro256**) seeded from
//! the request, so the same `(prompt, params, seed)` reproduces the same
//! generation on every backend path.  Because the dense, paged, and
//! batched decode paths produce bit-identical logits (tests/paged.rs),
//! sampling is a pure function of `(logits, rng state)` and the whole
//! generation is path-independent — propchecked in `tests/serving.rs`.
//!
//! `temperature == 0` short-circuits to `model::argmax`, bit-identical to
//! the pre-v2 greedy serving path: every existing identity test (and any
//! v1 client) sees exactly the old behaviour.

use crate::model::argmax;
use crate::util::rng::Rng;

/// Per-request decoding controls (v2 API).  The default is greedy argmax —
/// the exact pre-v2 behaviour — so a request that sets nothing decodes as
/// before.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; `0` (or anything non-positive) means greedy
    /// argmax, matching the v1 path bit-for-bit.
    pub temperature: f32,
    /// Keep only the `top_k` highest-logit tokens (`0` = disabled).
    pub top_k: usize,
    /// Nucleus sampling: keep the smallest high-probability set whose
    /// cumulative mass reaches `top_p` (`>= 1.0` = disabled).
    pub top_p: f32,
    /// Seed for the per-request RNG; same seed, same generation.
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: 0,
        }
    }
}

impl SamplingParams {
    /// The v1-equivalent greedy configuration.
    pub fn greedy() -> SamplingParams {
        SamplingParams::default()
    }

    /// Greedy requests take the allocation-free argmax fast path and are
    /// bit-identical to the pre-v2 coordinator.
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }
}

/// Deterministic per-request sampler: params + an owned RNG stream.
#[derive(Debug, Clone)]
pub struct Sampler {
    pub params: SamplingParams,
    rng: Rng,
}

impl Sampler {
    pub fn new(params: &SamplingParams) -> Sampler {
        Sampler {
            rng: Rng::new(params.seed),
            params: params.clone(),
        }
    }

    /// Draw the next token id from `logits`.
    ///
    /// Candidates are ordered by (logit desc, index asc) — `total_cmp`
    /// plus the index tie-break makes the order, and therefore the draw,
    /// fully deterministic.  Softmax runs in f64 (single-threaded, so the
    /// accumulation order is fixed) after the top-k cut; the top-p cut
    /// then trims the low-probability tail before an inverse-CDF draw
    /// from the request's own RNG.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        if self.params.is_greedy() || logits.len() <= 1 {
            return argmax(logits);
        }
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_unstable_by(|&a, &b| logits[b].total_cmp(&logits[a]).then(a.cmp(&b)));
        if self.params.top_k > 0 {
            idx.truncate(self.params.top_k.max(1));
        }
        let max = logits[idx[0]] as f64;
        let inv_t = 1.0 / self.params.temperature as f64;
        let mut probs: Vec<f64> = idx
            .iter()
            .map(|&i| ((logits[i] as f64 - max) * inv_t).exp())
            .collect();
        if (self.params.top_p as f64) < 1.0 {
            let total: f64 = probs.iter().sum();
            let target = (self.params.top_p.max(0.0) as f64) * total;
            let mut acc = 0.0;
            let mut cut = probs.len();
            for (i, p) in probs.iter().enumerate() {
                acc += p;
                if acc >= target {
                    cut = i + 1;
                    break;
                }
            }
            probs.truncate(cut);
            idx.truncate(cut);
        }
        let total: f64 = probs.iter().sum();
        let draw = self.rng.f64() * total;
        let mut acc = 0.0;
        for (i, p) in probs.iter().enumerate() {
            acc += p;
            if acc >= draw {
                return idx[i];
            }
        }
        // Float round-off on the final partial sum: fall back to the last
        // candidate still in the nucleus.
        *idx.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    fn logits_from(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32() * 4.0).collect()
    }

    #[test]
    fn temperature_zero_is_argmax() {
        forall(
            11,
            200,
            |r| logits_from(r, 64),
            |logits| {
                let mut s = Sampler::new(&SamplingParams::greedy());
                s.sample(logits) == argmax(logits)
            },
        );
    }

    #[test]
    fn top_k_one_is_argmax_at_any_temperature() {
        forall(
            13,
            200,
            |r| logits_from(r, 48),
            |logits| {
                let mut s = Sampler::new(&SamplingParams {
                    temperature: 3.0,
                    top_k: 1,
                    ..Default::default()
                });
                s.sample(logits) == argmax(logits)
            },
        );
    }

    #[test]
    fn tiny_top_p_is_argmax() {
        forall(
            17,
            100,
            |r| logits_from(r, 48),
            |logits| {
                let mut s = Sampler::new(&SamplingParams {
                    temperature: 1.0,
                    top_p: 1e-9,
                    ..Default::default()
                });
                s.sample(logits) == argmax(logits)
            },
        );
    }

    #[test]
    fn same_seed_same_stream() {
        let params = SamplingParams {
            temperature: 0.9,
            top_k: 20,
            top_p: 0.95,
            seed: 42,
        };
        let mut rng = Rng::new(5);
        let logit_seq: Vec<Vec<f32>> = (0..64).map(|_| logits_from(&mut rng, 96)).collect();
        let mut a = Sampler::new(&params);
        let mut b = Sampler::new(&params);
        for logits in &logit_seq {
            assert_eq!(a.sample(logits), b.sample(logits));
        }
        // A different seed must eventually diverge on the same logits.
        let mut c = Sampler::new(&SamplingParams { seed: 43, ..params });
        let mut a2 = Sampler::new(&SamplingParams { seed: 42, ..params });
        let diverged = logit_seq
            .iter()
            .any(|logits| a2.sample(logits) != c.sample(logits));
        assert!(diverged, "seeds 42 and 43 produced identical 64-draw streams");
    }

    #[test]
    fn sampled_tokens_respect_top_k() {
        let mut rng = Rng::new(7);
        let logits = logits_from(&mut rng, 128);
        let mut order: Vec<usize> = (0..logits.len()).collect();
        order.sort_unstable_by(|&a, &b| logits[b].total_cmp(&logits[a]).then(a.cmp(&b)));
        let allowed: std::collections::BTreeSet<usize> = order[..8].iter().copied().collect();
        let mut s = Sampler::new(&SamplingParams {
            temperature: 2.0,
            top_k: 8,
            ..Default::default()
        });
        for _ in 0..200 {
            assert!(allowed.contains(&s.sample(&logits)));
        }
    }

    #[test]
    fn high_probability_token_dominates() {
        let mut logits = vec![0.0f32; 16];
        logits[3] = 10.0;
        let mut s = Sampler::new(&SamplingParams {
            temperature: 1.0,
            seed: 9,
            ..Default::default()
        });
        let hits = (0..500).filter(|_| s.sample(&logits) == 3).count();
        assert!(hits > 450, "token with ~e^10 odds drawn only {hits}/500 times");
    }
}
