//! Poor-man's property testing (no proptest offline).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop`; on failure it re-runs a simple shrink loop (halving
//! numeric fields via the `Shrink` trait when implemented) and reports the
//! failing seed so the case is reproducible.

use super::rng::Rng;

pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    for case in 0..cases {
        let mut rng = Rng::new(seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B9));
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed at case {case} (seed {seed}): input = {input:?}"
            );
        }
    }
}

/// Like `forall` but the property returns a Result with a diagnostic.
pub fn forall_res<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Rng::new(seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B9));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {seed}): {msg}\ninput = {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        forall(1, 200, |r| r.below(100), |&x| x < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_invalid_property() {
        forall(2, 200, |r| r.below(100), |&x| x < 50);
    }
}
