//! In-tree substrates: JSON, PRNG, thread pool, bench stats, CLI parsing,
//! property-testing helpers.  Only `xla` and `anyhow` exist as external
//! dependencies in this offline environment; everything else lives here.

pub mod cli;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod threadpool;
