//! Minimal JSON parser/serializer (no serde in this offline environment).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and the
//! experiment result files: objects, arrays, strings (with escapes), numbers,
//! booleans, null.  Numbers are parsed as f64; integer accessors check
//! round-trip exactness.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for required manifest fields.
    pub fn req(&self, key: &str) -> &Value {
        self.get(key)
            .unwrap_or_else(|| panic!("missing required key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_arr(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default()
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        write_value(&mut s, self, 0, true);
        s
    }
}

impl std::fmt::Display for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        write_value(&mut s, self, 0, false);
        f.write_str(&s)
    }
}

fn write_value(out: &mut String, v: &Value, indent: usize, pretty: bool) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => write_escaped(out, s),
        Value::Arr(a) => {
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                }
                write_value(out, e, indent + 1, pretty);
            }
            if pretty && !a.is_empty() {
                out.push('\n');
                out.push_str(&" ".repeat(indent));
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, e)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                }
                write_escaped(out, k);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_value(out, e, indent + 1, pretty);
            }
            if pretty && !m.is_empty() {
                out.push('\n');
                out.push_str(&" ".repeat(indent));
            }
            out.push('}');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

pub fn parse(input: &str) -> Result<Value, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("eof in \\u escape")?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or("bad hex digit")?;
                        }
                        // Surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err("lone surrogate".into());
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump().ok_or("eof in \\u escape")?;
                                low = low * 16
                                    + (c as char).to_digit(16).ok_or("bad hex digit")?;
                            }
                            0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                        } else {
                            code
                        };
                        out.push(char::from_u32(ch).ok_or("bad codepoint")?);
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) => {
                    // Collect a UTF-8 sequence starting at c.
                    let len = utf8_len(c);
                    if len == 1 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or("eof in utf8 sequence")?;
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| e.to_string())?;
                        out.push_str(s);
                    }
                }
                None => return Err("eof in string".into()),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Convenience builders used by the experiment writers.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Arr(items)
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = parse(r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null}"#).unwrap();
        assert_eq!(v.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.req("a").as_arr().unwrap()[0].as_i64(), Some(1));
        assert_eq!(v.req("b").as_str(), Some("x\ny"));
        assert_eq!(v.req("c").as_bool(), Some(true));
        assert_eq!(*v.req("d"), Value::Null);
        let text = v.to_string();
        let v2 = parse(&text).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn nested_and_empty() {
        let v = parse(r#"{"o": {}, "a": [], "n": [[1],[2,[3]]]}"#).unwrap();
        assert!(v.req("o").as_obj().unwrap().is_empty());
        assert!(v.req("a").as_arr().unwrap().is_empty());
        assert_eq!(
            v.req("n").as_arr().unwrap()[1].as_arr().unwrap()[1].as_arr().unwrap()[0].as_i64(),
            Some(3)
        );
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        let v = parse("\"caf\u{00e9}\"").unwrap();
        assert_eq!(v.as_str(), Some("café"));
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01a").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("[1] junk").is_err());
    }

    #[test]
    fn pretty_roundtrip() {
        let v = obj(vec![
            ("x", num(1.5)),
            ("y", arr(vec![s("a"), Value::Bool(false)])),
        ]);
        let p = v.to_string_pretty();
        assert_eq!(parse(&p).unwrap(), v);
        assert!(p.contains('\n'));
    }
}
