//! Micro-benchmark statistics — the in-tree replacement for criterion.
//!
//! `bench()` runs warmup + timed iterations, adaptively choosing the
//! iteration count for a target measurement time, and reports mean / p50 /
//! p95 / p99 / min with a simple outlier-robust summary.  All `cargo bench`
//! targets in `rust/benches/` use this harness.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchStats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.2} us/iter (p50 {:.2}, p95 {:.2}, p99 {:.2}, min {:.2}; n={})",
            self.name,
            self.mean_ns / 1e3,
            self.p50_ns / 1e3,
            self.p95_ns / 1e3,
            self.p99_ns / 1e3,
            self.min_ns / 1e3,
            self.iters
        )
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

/// Time `f` adaptively: warm up for `warmup`, then sample individual
/// invocations until `budget` elapses (min 10, max `max_samples` samples).
pub fn bench<F: FnMut()>(name: &str, warmup: Duration, budget: Duration, mut f: F) -> BenchStats {
    bench_with_samples(name, warmup, budget, 10_000, &mut f)
}

/// Quick preset used inside experiments: ~30 ms warmup, ~300 ms budget.
pub fn bench_quick<F: FnMut()>(name: &str, f: F) -> BenchStats {
    bench(
        name,
        Duration::from_millis(30),
        Duration::from_millis(300),
        f,
    )
}

pub fn bench_with_samples<F: FnMut()>(
    name: &str,
    warmup: Duration,
    budget: Duration,
    max_samples: usize,
    f: &mut F,
) -> BenchStats {
    let wstart = Instant::now();
    let mut warm_iters = 0usize;
    while wstart.elapsed() < warmup || warm_iters < 2 {
        f();
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }

    let mut samples = Vec::with_capacity(256);
    let start = Instant::now();
    while (start.elapsed() < budget || samples.len() < 10) && samples.len() < max_samples {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    summarize(name, samples)
}

pub fn summarize(name: &str, mut samples: Vec<f64>) -> BenchStats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n.max(1) as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        p50_ns: percentile(&samples, 0.50),
        p95_ns: percentile(&samples, 0.95),
        p99_ns: percentile(&samples, 0.99),
        min_ns: samples.first().copied().unwrap_or(f64::NAN),
        max_ns: samples.last().copied().unwrap_or(f64::NAN),
    }
}

/// Prevent the optimizer from deleting a computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Welford online mean/variance — used by latency metrics in the coordinator.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut acc = 0u64;
        let st = bench(
            "busy",
            Duration::from_millis(1),
            Duration::from_millis(10),
            || {
                for i in 0..1000u64 {
                    acc = acc.wrapping_add(black_box(i));
                }
            },
        );
        assert!(st.iters >= 10);
        assert!(st.mean_ns > 0.0);
        assert!(st.p50_ns <= st.p99_ns + 1.0);
        assert!(st.min_ns <= st.mean_ns);
    }

    #[test]
    fn welford_matches_closed_form() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.add(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        let var =
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.var() - var).abs() < 1e-9);
        assert_eq!(w.min, 1.0);
        assert_eq!(w.max, 10.0);
    }

    #[test]
    fn percentile_sane() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert!((percentile(&v, 0.5) - 50.0).abs() <= 1.0);
    }
}
