//! Deterministic PRNG (SplitMix64 + xoshiro256**) — no `rand` crate offline.
//!
//! Used by workload generators, the property-test harness and benchmark
//! input synthesis.  Seeded runs are bit-reproducible across platforms.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// xoshiro256** core step.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
            if n.wrapping_neg() % n == 0 {
                return (m >> 64) as usize;
            }
        }
    }

    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Exponential with rate lambda (Poisson inter-arrival times).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Zipf-distributed rank in [0, n) with exponent alpha (workload gen).
    /// Exact inverse-CDF over the generalized harmonic weights — O(n) per
    /// call, fine at our scales (n <= a few thousand).
    pub fn zipf(&mut self, n: usize, alpha: f64) -> usize {
        debug_assert!(n > 0);
        let mut total = 0.0f64;
        for k in 1..=n {
            total += (k as f64).powf(-alpha);
        }
        let target = self.f64() * total;
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += (k as f64).powf(-alpha);
            if acc >= target {
                return k - 1;
            }
        }
        n - 1
    }

    pub fn fill_normal(&mut self, buf: &mut [f32], scale: f32) {
        for v in buf.iter_mut() {
            *v = self.normal_f32() * scale;
        }
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n), sorted ascending.
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        let mut out = idx[..k].to_vec();
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.below(13);
            assert!(v < 13);
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 100];
        for _ in 0..5_000 {
            counts[r.zipf(100, 1.2)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[60]);
    }

    #[test]
    fn choose_distinct_sorted_unique() {
        let mut r = Rng::new(9);
        let v = r.choose_distinct(20, 8);
        assert_eq!(v.len(), 8);
        for w in v.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
