//! Tiny CLI argument parser (no clap offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse argv (excluding argv[0]).  The first token not starting with
    /// `--` becomes the subcommand; later bare tokens are positionals.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["serve", "--port", "8080", "--verbose", "--model=tinyllama", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("model"), Some("tinyllama"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["extra"]);
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["x", "--n", "12", "--rho", "0.3"]);
        assert_eq!(a.get_usize("n", 0), 12);
        assert!((a.get_f64("rho", 0.0) - 0.3).abs() < 1e-12);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["bench", "--quick"]);
        assert!(a.flag("quick"));
        assert_eq!(a.get("quick"), None);
    }

    #[test]
    fn list_option() {
        let a = parse(&["x", "--models", "a, b,c"]);
        assert_eq!(a.get_list("models", &[]), vec!["a", "b", "c"]);
        assert_eq!(a.get_list("other", &["d"]), vec!["d"]);
    }
}
