//! Small fixed-size thread pool (no tokio/rayon offline).
//!
//! Two facilities:
//!   * `ThreadPool` — long-lived workers consuming boxed jobs from a channel;
//!     used by the serving layer for request handling.
//!   * `scoped_chunks` — data-parallel helper that splits an index range
//!     across `std::thread::scope` workers; used by the tensor kernels.
//!
//! On this single-core testbed the pool defaults to 1 worker and the scoped
//! helper falls back to inline execution — zero overhead — but the code
//! paths are exercised by tests with forced worker counts.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("rap-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cv) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                cv.notify_all();
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            tx: Some(tx),
            workers,
            pending,
        }
    }

    /// Default worker count: available parallelism minus nothing (min 1).
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Split `0..n` into contiguous chunks and run `f(chunk_range)` on up to
/// `threads` scoped workers.  `f` must be `Sync` since multiple workers call
/// it concurrently on disjoint ranges.
pub fn scoped_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    scoped_chunks_indexed(n, threads, |_, range| f(range));
}

/// `scoped_chunks` variant that also hands each worker its chunk index
/// (`0..threads`).  The batched decode path uses the index to address a
/// per-worker `DecodeWorkspace` without locking.  With one worker (or one
/// item) the closure runs inline on the caller's thread — no spawn, no
/// allocation — which is what makes single-token decode allocation-free.
pub fn scoped_chunks_indexed<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= 1 {
        f(0, 0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(t, lo..hi));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn scoped_chunks_covers_range() {
        for threads in [1, 2, 3, 8] {
            let hits: Vec<AtomicUsize> = (0..23).map(|_| AtomicUsize::new(0)).collect();
            scoped_chunks(23, threads, |range| {
                for i in range {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        }
    }

    #[test]
    fn scoped_chunks_empty() {
        scoped_chunks(0, 4, |r| assert!(r.is_empty()));
    }

    #[test]
    fn scoped_chunks_indexed_distinct_workers() {
        // Every chunk index is within 0..threads and owned by one worker.
        let threads = 4;
        let seen: Vec<AtomicUsize> = (0..threads).map(|_| AtomicUsize::new(0)).collect();
        let hits: Vec<AtomicUsize> = (0..19).map(|_| AtomicUsize::new(0)).collect();
        scoped_chunks_indexed(19, threads, |idx, range| {
            assert!(idx < threads);
            seen[idx].fetch_add(1, Ordering::SeqCst);
            for i in range {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        assert!(seen.iter().all(|s| s.load(Ordering::SeqCst) <= 1));
    }
}
