//! JSON-lines TCP server in front of the coordinator.
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": "...", "max_new": 32}
//!   <- {"id": 1, "text": "...", "ttft_ms": 12.3, "decode_ms_per_token": 1.8}
//!
//! Architecture: acceptor thread + per-connection handler threads (from the
//! in-tree `ThreadPool`) feeding an mpsc channel into the single scheduler
//! thread that owns the backend; responses are routed back over per-request
//! channels.  (std-only: no tokio in this offline environment.)

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{Backend, Coordinator, Request, Response};
use crate::util::json::{self, Value};
use crate::util::threadpool::ThreadPool;

enum Msg {
    Submit(Request, Sender<Response>),
    Shutdown,
}

pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    tx: Sender<Msg>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Msg::Shutdown);
        // Poke the acceptor so it notices the stop flag.
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Scheduler loop: owns the coordinator, multiplexes submissions and ticks.
fn scheduler_loop<B: Backend>(mut coord: Coordinator<B>, rx: Receiver<Msg>) {
    let mut reply_to: HashMap<u64, Sender<Response>> = HashMap::new();
    loop {
        // Drain pending submissions (non-blocking when busy, blocking when
        // idle so we don't spin).
        let msg = if coord.pending() == 0 {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            }
        } else {
            rx.try_recv().ok()
        };
        match msg {
            Some(Msg::Submit(req, reply)) => {
                reply_to.insert(req.id, reply);
                if !coord.submit(req) {
                    // queue full: synthesize an immediate empty response
                    // (the client treats empty text + 0 tokens as a 429).
                }
                continue; // keep draining before ticking
            }
            Some(Msg::Shutdown) => break,
            None => {}
        }
        if coord.pending() > 0 {
            match coord.tick() {
                Ok(done) => {
                    for resp in done {
                        if let Some(ch) = reply_to.remove(&resp.id) {
                            let _ = ch.send(resp);
                        }
                    }
                }
                Err(e) => {
                    eprintln!("[server] tick error: {e:#}");
                    break;
                }
            }
        }
    }
}

fn handle_conn(stream: TcpStream, tx: Sender<Msg>, ids: Arc<AtomicU64>) {
    let peer = stream.peer_addr().ok();
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let reply = match json::parse(trimmed) {
            Ok(v) => {
                let prompt = v
                    .get("prompt")
                    .and_then(|p| p.as_str())
                    .unwrap_or("")
                    .as_bytes()
                    .to_vec();
                let max_new = v
                    .get("max_new")
                    .and_then(|m| m.as_usize())
                    .unwrap_or(32);
                let id = ids.fetch_add(1, Ordering::SeqCst);
                let (rtx, rrx) = channel();
                if tx.send(Msg::Submit(Request::new(id, prompt, max_new), rtx)).is_err() {
                    break;
                }
                match rrx.recv_timeout(Duration::from_secs(120)) {
                    Ok(resp) => json::obj(vec![
                        ("id", json::num(resp.id as f64)),
                        (
                            "text",
                            json::s(String::from_utf8_lossy(&resp.generated).to_string()),
                        ),
                        ("ttft_ms", json::num(resp.metrics.ttft_ms)),
                        (
                            "decode_ms_per_token",
                            json::num(resp.metrics.decode_ms_per_token),
                        ),
                        ("tokens", json::num(resp.metrics.generated_tokens as f64)),
                    ]),
                    Err(_) => json::obj(vec![("error", json::s("timeout"))]),
                }
            }
            Err(e) => json::obj(vec![("error", json::s(format!("bad json: {e}")))]),
        };
        if writeln!(out, "{reply}").is_err() {
            break;
        }
    }
    let _ = peer;
}

/// Start serving on `addr` ("127.0.0.1:0" for an ephemeral port).
///
/// The coordinator is built *inside* the scheduler thread by `factory`
/// (PJRT handles are `!Send`: raw PJRT pointers and `Rc` internals must
/// never cross threads, so the whole backend is constructed where it runs).
pub fn serve<B, F>(addr: &str, factory: F, n_conn_threads: usize) -> Result<ServerHandle>
where
    B: Backend + 'static,
    F: FnOnce() -> Result<Coordinator<B>> + Send + 'static,
{
    let listener = TcpListener::bind(addr).context("bind")?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = channel::<Msg>();

    let sched = std::thread::Builder::new()
        .name("rap-scheduler".into())
        .spawn(move || match factory() {
            Ok(coord) => scheduler_loop(coord, rx),
            Err(e) => eprintln!("[server] backend init failed: {e:#}"),
        })?;

    let stop2 = Arc::clone(&stop);
    let tx2 = tx.clone();
    let acceptor = std::thread::Builder::new()
        .name("rap-acceptor".into())
        .spawn(move || {
            let pool = ThreadPool::new(n_conn_threads);
            let ids = Arc::new(AtomicU64::new(1));
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let tx = tx2.clone();
                let ids = Arc::clone(&ids);
                pool.execute(move || handle_conn(stream, tx, ids));
            }
        })?;

    Ok(ServerHandle {
        addr: local,
        stop,
        tx,
        threads: vec![sched, acceptor],
    })
}

/// Minimal client for tests/examples.
pub fn client_request(addr: &std::net::SocketAddr, prompt: &str, max_new: usize) -> Result<Value> {
    let mut stream = TcpStream::connect(addr)?;
    let req = json::obj(vec![
        ("prompt", json::s(prompt)),
        ("max_new", json::num(max_new as f64)),
    ]);
    writeln!(stream, "{req}")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    json::parse(line.trim()).map_err(|e| anyhow::anyhow!("client parse: {e}"))
}
