//! JSON-lines TCP server in front of the coordinator — serving API v2.
//!
//! Protocol (one JSON object per line; all request fields beyond
//! `prompt` are optional):
//!
//!   -> {"prompt": "...", "max_new": 32,
//!       "stream": true,                      // per-token delta lines
//!       "temperature": 0.8, "top_k": 40,     // sampling (0 temp = greedy,
//!       "top_p": 0.95, "seed": 7,            //  bit-identical to v1)
//!       "stop": ["\n\n", "END"],             // byte-level stop sequences
//!       "deadline_ms": 5000,                 // optional wall-clock budget
//!       "retention": {"policy": "window", "ratio": 0.5},  // KV press
//!       "speculative": {"policy": "ngram", "k": 4}}       // spec decode
//!
//! `speculative` turns on self-drafting speculative decode for the
//! request: up to `k` n-gram-drafted tokens are verified per backend
//! call, and the emitted bytes are **bit-identical** to plain decode for
//! greedy and seeded sampling alike (acceptance re-samples every token
//! from the verifier's logits through the request's own seeded stream).
//! Omitting it picks up the fleet default (`RAP_SPECULATIVE`, e.g.
//! `ngram:4`), if any.
//!
//! Malformed sampling parameters (NaN/negative temperature, `top_p`
//! outside (0, 1], `max_new` beyond any servable length, negative
//! `deadline_ms`), an unknown `retention`/`speculative` policy, or a
//! `speculative.k` outside `[1, 32]` are answered immediately with
//! `{"error": "bad_request", "field": "..."}` — nothing is submitted.
//!
//! Streaming (`"stream": true`) responses are incremental:
//!
//!   <- {"id": 1, "delta": "..."}             // as each token is sampled;
//!                                            // the first arrives at
//!                                            // prefill completion, before
//!                                            // the request's decode runs
//!   <- {"id": 1, "done": true, "text": "...", "finish_reason": "length",
//!       "tokens": 32, "ttft_ms": 12.3, "decode_ms_per_token": 1.8}
//!
//! v1 one-shot requests (no `"stream"`) are still accepted and answered
//! in the old single-line shape — `{"id", "text", "ttft_ms",
//! "decode_ms_per_token", "tokens"}` — plus an additive `finish_reason`
//! field old clients ignore.
//!
//! Finish reasons: `length` (max_new / context limit), `stop` (a stop
//! sequence matched; the matched bytes stay in the output), `cancelled`,
//! `timeout` (`deadline_ms` elapsed; partial text is returned), and
//! `rejected` — reported as `{"error": "queue_full", ...}` for transient
//! backpressure (worth retrying) or `{"error": "too_large", ...}` for a
//! prompt that exceeds the cache's physical capacity (never retryable).
//!
//! Memory pressure is visible to streaming clients: a session whose KV
//! blocks are reclaimed for a more senior request emits
//! `{"id": n, "event": "preempted"}`, and `{"id": n, "event": "resumed"}`
//! once its state has been recomputed — generation continues
//! bit-identically, so non-streaming clients never notice.
//!
//! Cancellation: `-> {"cancel": <id>}` (acked with `{"cancel": id, "ok":
//! true}`) tears the session down wherever it is — queued, prefilling, or
//! decoding — and its stream ends with a `finish_reason: "cancelled"`
//! summary line.  A client that disconnects mid-stream is cancelled
//! automatically on the first failed delta write, releasing its KV
//! reservation (and shared prefix-block refcounts) instead of pinning
//! them for the rest of the generation.
//!
//! Replica mode (used by the multi-replica [`crate::router`]):
//!
//! * `-> {"health": true}` answers immediately with load gauges sampled
//!   off the scheduler thread — `{"ok": true, "pending", "used_blocks",
//!   "capacity_blocks", "prefix_hits", "prefix_lookups"}` — so health
//!   probes never queue behind generation work;
//! * a request carrying `"ack": true` is acknowledged with
//!   `{"id": n, "ack": true}` the moment it is submitted, *before* any
//!   delta — giving a proxy the id it needs to cancel a request that is
//!   still queued or prefilling.
//!
//! Hardening: request lines are capped (`ServerConfig::max_line_bytes`,
//! default 256 KiB) — an oversized line answers
//! `{"error": "bad_request", "field": "line"}` and closes the connection
//! instead of buffering without bound — and reads carry an idle timeout
//! (`ServerConfig::idle_read_timeout`) so a silent or byte-dribbling
//! client cannot pin a connection worker forever.
//!
//! Architecture: acceptor thread + per-connection handler threads (from
//! the in-tree `ThreadPool`) feeding an mpsc channel into the single
//! scheduler thread that owns the backend; per-token [`Event`]s are
//! routed back over per-request channels.  (std-only: no tokio in this
//! offline environment.)

use std::collections::HashMap;
use std::io::{self, BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{
    Backend, Coordinator, Event, FinishReason, Request, RequestId, Response, SamplingParams,
    SubmitError,
};
use crate::kvcache::retention::{Press, RetentionSpec};
use crate::speculate::{DraftPolicy, SpeculativeSpec, DEFAULT_DRAFT_K, MAX_DRAFT_K};
use crate::util::json::{self, Value};
use crate::util::threadpool::ThreadPool;

/// Per-request completion deadline for clients waiting on events.
const CLIENT_TIMEOUT: Duration = Duration::from_secs(120);

/// Tunable limits for [`serve_with_config`]; [`serve`] uses the defaults.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Connection-handler threads (= concurrently served clients).
    pub conn_threads: usize,
    /// Longest accepted request line in bytes; anything larger answers
    /// `{"error": "bad_request", "field": "line"}` and closes the
    /// connection instead of buffering without bound.
    pub max_line_bytes: usize,
    /// How long a connection may sit idle between request lines before
    /// the worker drops it (a byte-dribbling client resets the clock but
    /// still hits `max_line_bytes`).
    pub idle_read_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            conn_threads: 8,
            max_line_bytes: 256 * 1024,
            idle_read_timeout: Duration::from_secs(120),
        }
    }
}

/// Load gauges published by the scheduler thread on every loop iteration
/// and served to `{"health": true}` probes straight off the connection
/// handler — a probe never queues behind generation work, so a *stalled*
/// scheduler shows up as stale-but-answered gauges while a *dead* process
/// shows up as a connect failure (the router treats both via timeouts).
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Requests queued + prefilling + running + preempted.
    pub pending: AtomicU64,
    /// Hot KV blocks in use (excludes the cold prefix cache).
    pub used_blocks: AtomicU64,
    /// Physical KV capacity in blocks.
    pub capacity_blocks: AtomicU64,
    /// Prefix-cache hits since start.
    pub prefix_hits: AtomicU64,
    /// Prefix-cache lookups since start.
    pub prefix_lookups: AtomicU64,
    /// Token rows evicted by retention presses since start.
    pub evicted_tokens: AtomicU64,
    /// Bytes physically resident for KV rows (post-press).
    pub resident_kv_bytes: AtomicU64,
}

impl ServerStats {
    fn publish(&self, snap: &crate::coordinator::CoordSnapshot) {
        self.pending.store(snap.in_flight() as u64, Ordering::Relaxed);
        self.used_blocks.store(snap.used_blocks as u64, Ordering::Relaxed);
        self.capacity_blocks
            .store(snap.capacity_blocks as u64, Ordering::Relaxed);
        self.prefix_hits.store(snap.prefix_hits, Ordering::Relaxed);
        self.prefix_lookups.store(snap.prefix_lookups, Ordering::Relaxed);
        self.evicted_tokens.store(snap.evicted_tokens, Ordering::Relaxed);
        self.resident_kv_bytes
            .store(snap.resident_kv_bytes as u64, Ordering::Relaxed);
    }

    fn health_line(&self) -> Value {
        json::obj(vec![
            ("ok", Value::Bool(true)),
            ("pending", json::num(self.pending.load(Ordering::Relaxed) as f64)),
            (
                "used_blocks",
                json::num(self.used_blocks.load(Ordering::Relaxed) as f64),
            ),
            (
                "capacity_blocks",
                json::num(self.capacity_blocks.load(Ordering::Relaxed) as f64),
            ),
            (
                "prefix_hits",
                json::num(self.prefix_hits.load(Ordering::Relaxed) as f64),
            ),
            (
                "prefix_lookups",
                json::num(self.prefix_lookups.load(Ordering::Relaxed) as f64),
            ),
            (
                "evicted_tokens",
                json::num(self.evicted_tokens.load(Ordering::Relaxed) as f64),
            ),
            (
                "resident_kv_bytes",
                json::num(self.resident_kv_bytes.load(Ordering::Relaxed) as f64),
            ),
        ])
    }
}

enum Msg {
    Submit(Request, Sender<Event>),
    Cancel(RequestId),
    Shutdown,
}

pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
    tx: Sender<Msg>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The live load gauges this server publishes (same numbers the
    /// `{"health": true}` endpoint serves).
    pub fn stats(&self) -> Arc<ServerStats> {
        Arc::clone(&self.stats)
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.tx.send(Msg::Shutdown);
        // Poke the acceptor so it notices the stop flag.
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Scheduler loop: owns the coordinator, multiplexes submissions,
/// cancellations and ticks, and routes per-token events to the
/// per-request reply channels.
fn scheduler_loop<B: Backend>(
    mut coord: Coordinator<B>,
    rx: Receiver<Msg>,
    stats: Arc<ServerStats>,
) {
    let mut reply_to: HashMap<u64, Sender<Event>> = HashMap::new();
    loop {
        // Publish load gauges every iteration — including right before the
        // idle blocking recv, so health probes see the drained state rather
        // than the last busy one.
        stats.publish(&coord.snapshot());
        // Drain pending submissions (non-blocking when busy, blocking when
        // idle so we don't spin).
        let msg = if coord.pending() == 0 {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            }
        } else {
            rx.try_recv().ok()
        };
        match msg {
            Some(Msg::Submit(req, reply)) => {
                let id = req.id;
                reply_to.insert(id, reply);
                if let Err(e) = coord.try_submit(req) {
                    // Refused: answer with an explicit Rejected event and
                    // drop the routing entry — the v1 code claimed to
                    // "synthesize an immediate empty response" but sent
                    // nothing, leaving the client to ride out its full
                    // timeout while the reply_to entry leaked forever.
                    // The two reasons stay distinct on the wire: a
                    // `queue_full` is worth retrying, a `too_large` never is.
                    let response = match e {
                        SubmitError::QueueFull => Response::rejected(id),
                        SubmitError::PromptTooLarge => Response::too_large(id),
                    };
                    if let Some(ch) = reply_to.remove(&id) {
                        let _ = ch.send(Event::Finished { id, response });
                    }
                }
                continue; // keep draining before ticking
            }
            Some(Msg::Cancel(id)) => {
                // Cancellation of an id that already finished (or never
                // existed) is a no-op; otherwise the terminal Cancelled
                // event closes the request's stream.
                if let Some(resp) = coord.cancel(id) {
                    if let Some(ch) = reply_to.remove(&id) {
                        let _ = ch.send(Event::Finished { id, response: resp });
                    }
                    // cancel() buffers the response for run_to_completion
                    // callers; the event above already served it, and a
                    // submit+cancel cycle may never reach a tick — drop it
                    // here or it leaks per cancellation.
                    coord.discard_finished();
                }
                continue;
            }
            Some(Msg::Shutdown) => break,
            None => {}
        }
        if coord.pending() > 0 {
            match coord.tick() {
                Ok(events) => {
                    for ev in events {
                        let id = ev.id();
                        if ev.is_finished() {
                            if let Some(ch) = reply_to.remove(&id) {
                                let _ = ch.send(ev);
                            }
                        } else if let Some(ch) = reply_to.get(&id) {
                            let _ = ch.send(ev);
                        }
                    }
                    // Events were routed; don't also accumulate responses
                    // in the coordinator's run_to_completion buffer.
                    coord.discard_finished();
                }
                Err(e) => {
                    eprintln!("[server] tick error: {e:#}");
                    break;
                }
            }
        }
    }
}

/// Incremental UTF-8 framing for streamed deltas: tokens are single bytes,
/// so a multi-byte character arrives across several events.  `push`
/// returns the longest decoded prefix whose text can no longer change —
/// everything except a trailing incomplete (so far valid) multi-byte
/// sequence — so concatenating every delta equals
/// `String::from_utf8_lossy` over the whole generation, with no byte-split
/// artefacts (e.g. two replacement chars where one two-byte char stood).
struct Utf8Stream {
    buf: Vec<u8>,
}

impl Utf8Stream {
    fn new() -> Utf8Stream {
        Utf8Stream { buf: Vec::new() }
    }

    /// Byte count of a trailing incomplete-but-potentially-valid UTF-8
    /// sequence (0 when every byte is decodable now).  Only the final
    /// lead byte within the last 3 positions can still be in flight.
    fn undecided_tail(buf: &[u8]) -> usize {
        let n = buf.len();
        for i in (n.saturating_sub(3)..n).rev() {
            let need = match buf[i] {
                0xC2..=0xDF => 2,
                0xE0..=0xEF => 3,
                0xF0..=0xF4 => 4,
                _ => continue, // ASCII / continuation / invalid: decided
            };
            let have = n - i;
            if have < need && buf[i + 1..].iter().all(|&c| (0x80..=0xBF).contains(&c)) {
                return have;
            }
            break; // complete (or already invalid) sequence: decided
        }
        0
    }

    fn push(&mut self, byte: u8) -> Option<String> {
        self.buf.push(byte);
        let decided = self.buf.len() - Self::undecided_tail(&self.buf);
        if decided == 0 {
            return None;
        }
        let rest = self.buf.split_off(decided);
        let head = std::mem::replace(&mut self.buf, rest);
        Some(String::from_utf8_lossy(&head).into_owned())
    }

    /// End of stream: whatever is still buffered is final now.
    fn finish(&mut self) -> Option<String> {
        if self.buf.is_empty() {
            return None;
        }
        let head = std::mem::take(&mut self.buf);
        Some(String::from_utf8_lossy(&head).into_owned())
    }
}

/// Largest `max_new` the server will accept.  Generations are already
/// bounded by the backend's context limit (`s_max`, a few thousand at
/// most); anything past this is a typo or abuse, not a workload.
const MAX_MAX_NEW: usize = 1 << 20;

/// Outcome of one bounded line read.
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum LineRead {
    /// A complete line is in the caller's buffer (no trailing newline).
    Line,
    /// The line blew past the cap before a newline arrived; nothing was
    /// delivered and the connection should be answered and closed.
    TooLong,
    /// Clean EOF with nothing buffered, an I/O error, or the idle read
    /// timeout elapsed.
    Closed,
}

/// `read_line` with a byte cap: `BufRead::read_line` happily buffers an
/// endless newline-free stream, letting one malicious client OOM the
/// server.  This reads through `fill_buf`/`consume` and gives up at
/// `max_bytes`.  EOF with a partial (unterminated) line still delivers
/// the line, matching `read_line` semantics; a read timeout (the idle
/// hardening) surfaces as `Closed`.
pub(crate) fn read_line_bounded<R: BufRead>(
    reader: &mut R,
    line: &mut String,
    max_bytes: usize,
) -> LineRead {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = match reader.fill_buf() {
            Ok(c) => c,
            Err(_) => return LineRead::Closed, // includes idle-timeout kinds
        };
        if chunk.is_empty() {
            // EOF: a final unterminated line is still a line.
            if buf.is_empty() {
                return LineRead::Closed;
            }
            line.push_str(&String::from_utf8_lossy(&buf));
            return LineRead::Line;
        }
        if let Some(nl) = chunk.iter().position(|&b| b == b'\n') {
            if buf.len() + nl > max_bytes {
                reader.consume(nl + 1);
                return LineRead::TooLong;
            }
            buf.extend_from_slice(&chunk[..nl]);
            reader.consume(nl + 1);
            line.push_str(&String::from_utf8_lossy(&buf));
            return LineRead::Line;
        }
        let n = chunk.len();
        if buf.len() + n > max_bytes {
            // Over the cap with no newline in sight: stop buffering.  The
            // unread tail dies with the socket.
            return LineRead::TooLong;
        }
        buf.extend_from_slice(chunk);
        reader.consume(n);
    }
}

/// After refusing an oversized line, consume its remainder — up to
/// `budget` extra bytes — before closing.  Without this, the unread tail
/// turns the close into a TCP reset, which discards the already-sent
/// `bad_request` reply from the peer's receive queue; a moderately
/// oversized client then sees a bare reset instead of the answer.  A
/// line still unfinished past the budget is abuse and gets cut off.
pub(crate) fn drain_oversized_line<R: BufRead>(reader: &mut R, budget: usize) {
    let mut spent = 0usize;
    while spent <= budget {
        let (n, done) = match reader.fill_buf() {
            Err(_) => return,
            Ok(chunk) if chunk.is_empty() => return,
            Ok(chunk) => match chunk.iter().position(|&b| b == b'\n') {
                Some(nl) => (nl + 1, true),
                None => (chunk.len(), false),
            },
        };
        reader.consume(n);
        if done {
            return;
        }
        spent += n;
    }
}

/// Parse and validate a v2 request body (everything beyond
/// `prompt`/`max_new` is optional, defaulting to the v1 greedy one-shot
/// behaviour).  `Err` names the offending field for the `bad_request`
/// reply; a request that would poison the sampler (NaN temperature,
/// `top_p` outside (0, 1]) or wedge the scheduler (absurd `max_new`) is
/// refused here, before anything is submitted.
fn parse_request(v: &Value, id: RequestId) -> Result<Request, &'static str> {
    let prompt = v
        .get("prompt")
        .and_then(|p| p.as_str())
        .unwrap_or("")
        .as_bytes()
        .to_vec();
    let max_new = match v.get("max_new") {
        Some(m) => match m.as_usize() {
            Some(n) if n <= MAX_MAX_NEW => n,
            _ => return Err("max_new"), // negative, non-numeric, or absurd
        },
        None => 32,
    };
    let temperature = v.get("temperature").and_then(|t| t.as_f64()).unwrap_or(0.0) as f32;
    if !temperature.is_finite() || temperature < 0.0 {
        return Err("temperature");
    }
    let top_p = v.get("top_p").and_then(|t| t.as_f64()).unwrap_or(1.0) as f32;
    if !top_p.is_finite() || top_p <= 0.0 || top_p > 1.0 {
        return Err("top_p");
    }
    let sampling = SamplingParams {
        temperature,
        top_k: v.get("top_k").and_then(|t| t.as_usize()).unwrap_or(0),
        top_p,
        seed: v.get("seed").and_then(|t| t.as_i64()).unwrap_or(0) as u64,
    };
    let stop: Vec<Vec<u8>> = v
        .get("stop")
        .and_then(|s| s.as_arr())
        .map(|a| {
            a.iter()
                .filter_map(|x| x.as_str())
                .map(|s| s.as_bytes().to_vec())
                .collect()
        })
        .unwrap_or_default();
    let stream = v.get("stream").and_then(|s| s.as_bool()).unwrap_or(false);
    let deadline_ms = match v.get("deadline_ms") {
        Some(d) => match d.as_i64() {
            Some(ms) if ms >= 0 => Some(ms as u64),
            _ => return Err("deadline_ms"),
        },
        None => None,
    };
    // Optional KV retention spec, validated before admission so a bogus
    // policy or ratio never reaches the scheduler (mirrors the
    // sampling-params validation above): `{"policy": "window", "ratio":
    // 0.5}`.  Omitted ratio defaults to the policy's bare-name default;
    // omitted object = retain-all.
    let retention = match v.get("retention") {
        Some(r) => {
            let press = match r.get("policy").and_then(|p| p.as_str()).map(Press::parse) {
                Some(Some(p)) => p,
                _ => return Err("retention.policy"), // missing or unknown
            };
            let ratio = r.get("ratio").and_then(|x| x.as_f64()).unwrap_or(0.5) as f32;
            if !ratio.is_finite() || ratio <= 0.0 || ratio > 1.0 {
                return Err("retention.ratio");
            }
            Some(RetentionSpec { press, ratio })
        }
        None => None,
    };
    // Optional speculative-decode spec, validated the same way:
    // `{"policy": "ngram", "k": 4}`.  `policy` is required; `k` defaults
    // to `DEFAULT_DRAFT_K` and must stay in `[1, MAX_DRAFT_K]`.  Omitted
    // object = the fleet default (`RAP_SPECULATIVE`), or plain decode.
    let speculative = match v.get("speculative") {
        Some(s) => {
            let policy = match s.get("policy").and_then(|p| p.as_str()).map(DraftPolicy::parse) {
                Some(Some(p)) => p,
                _ => return Err("speculative.policy"), // missing or unknown
            };
            let k = match s.get("k") {
                Some(k) => match k.as_usize() {
                    Some(k) if (1..=MAX_DRAFT_K).contains(&k) => k,
                    _ => return Err("speculative.k"), // 0, negative, or absurd
                },
                None => DEFAULT_DRAFT_K,
            };
            Some(SpeculativeSpec { policy, k })
        }
        None => None,
    };
    let mut req = Request::new(id, prompt, max_new)
        .with_sampling(sampling)
        .with_stop(stop)
        .with_stream(stream);
    if let Some(ms) = deadline_ms {
        req = req.with_deadline_ms(ms);
    }
    if let Some(spec) = retention {
        req = req.with_retention(spec);
    }
    if let Some(spec) = speculative {
        req = req.with_speculative(spec);
    }
    Ok(req)
}

/// The terminal summary line shared by both modes (v1 keeps its exact old
/// field set; `done`/`finish_reason` are additive).
fn summary_line(resp: &Response) -> Value {
    if resp.metrics.finish_reason == FinishReason::Rejected {
        return json::obj(vec![
            ("id", json::num(resp.id as f64)),
            ("error", json::s(resp.reject_reason.unwrap_or("queue_full"))),
            ("finish_reason", json::s("rejected")),
        ]);
    }
    json::obj(vec![
        ("id", json::num(resp.id as f64)),
        ("done", Value::Bool(true)),
        ("text", json::s(String::from_utf8_lossy(&resp.generated).to_string())),
        ("finish_reason", json::s(resp.metrics.finish_reason.as_str())),
        ("ttft_ms", json::num(resp.metrics.ttft_ms)),
        ("decode_ms_per_token", json::num(resp.metrics.decode_ms_per_token)),
        ("tokens", json::num(resp.metrics.generated_tokens as f64)),
    ])
}

fn handle_conn(
    stream: TcpStream,
    tx: Sender<Msg>,
    ids: Arc<AtomicU64>,
    stats: Arc<ServerStats>,
    cfg: ServerConfig,
) {
    let peer = stream.peer_addr().ok();
    // Idle hardening: a connection that goes silent between request lines
    // times out instead of pinning this worker forever.  (While a request
    // streams, the worker blocks on the event channel, not this socket.)
    let _ = stream.set_read_timeout(Some(cfg.idle_read_timeout));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match read_line_bounded(&mut reader, &mut line, cfg.max_line_bytes) {
            LineRead::Closed => break,
            LineRead::TooLong => {
                let reply = json::obj(vec![
                    ("error", json::s("bad_request")),
                    ("field", json::s("line")),
                ]);
                let _ = writeln!(out, "{reply}");
                drain_oversized_line(&mut reader, cfg.max_line_bytes);
                break;
            }
            LineRead::Line => {}
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let v = match json::parse(trimmed) {
            Ok(v) => v,
            Err(e) => {
                let reply = json::obj(vec![("error", json::s(format!("bad json: {e}")))]);
                if writeln!(out, "{reply}").is_err() {
                    break;
                }
                continue;
            }
        };
        // Health probe: answered from the published gauges without a
        // scheduler round-trip, so it stays fast under load.
        if v.get("health").and_then(|h| h.as_bool()).unwrap_or(false) {
            if writeln!(out, "{}", stats.health_line()).is_err() {
                break;
            }
            continue;
        }
        // Explicit cancellation of any in-flight request by id: the
        // cancelled request's own stream receives the terminal line; this
        // connection just gets an ack.
        if let Some(cid) = v.get("cancel").and_then(|c| c.as_i64()) {
            let _ = tx.send(Msg::Cancel(cid as u64));
            let ack = json::obj(vec![
                ("cancel", json::num(cid as f64)),
                ("ok", Value::Bool(true)),
            ]);
            if writeln!(out, "{ack}").is_err() {
                break;
            }
            continue;
        }
        let id = ids.fetch_add(1, Ordering::SeqCst);
        let req = match parse_request(&v, id) {
            Ok(r) => r,
            Err(field) => {
                let reply = json::obj(vec![
                    ("error", json::s("bad_request")),
                    ("field", json::s(field)),
                ]);
                if writeln!(out, "{reply}").is_err() {
                    break;
                }
                continue;
            }
        };
        let stream_mode = req.stream;
        let want_ack = v.get("ack").and_then(|a| a.as_bool()).unwrap_or(false);
        let (rtx, rrx) = channel();
        if tx.send(Msg::Submit(req, rtx)).is_err() {
            break;
        }
        // Replica mode: hand the proxy the id *now*, before any delta, so
        // a cancel can reach a request that is still queued or prefilling.
        if want_ack {
            let ack = json::obj(vec![
                ("id", json::num(id as f64)),
                ("ack", Value::Bool(true)),
            ]);
            if writeln!(out, "{ack}").is_err() {
                let _ = tx.send(Msg::Cancel(id));
                break;
            }
        }
        let served = if stream_mode {
            stream_reply(&mut out, &tx, id, &rrx)
        } else {
            oneshot_reply(&mut out, id, &rrx)
        };
        if !served {
            break;
        }
    }
    let _ = peer;
}

/// v2 streaming: one `{"delta"}` line per decodable text fragment, then
/// the summary.  A failed write means the client is gone — cancel the
/// request so its KV blocks are released instead of decoding to the wall.
/// The timeout is per-event (idle), not total: a generation that keeps
/// producing tokens is healthy however long it runs, so only a stall of
/// `CLIENT_TIMEOUT` with no event tears it down.
fn stream_reply(
    out: &mut TcpStream,
    tx: &Sender<Msg>,
    id: RequestId,
    rrx: &Receiver<Event>,
) -> bool {
    let mut text = Utf8Stream::new();
    loop {
        match rrx.recv_timeout(CLIENT_TIMEOUT) {
            Ok(Event::Token { token, .. }) => {
                if let Some(delta) = text.push(token) {
                    let ev = json::obj(vec![
                        ("id", json::num(id as f64)),
                        ("delta", json::s(delta)),
                    ]);
                    if writeln!(out, "{ev}").is_err() {
                        let _ = tx.send(Msg::Cancel(id));
                        return false;
                    }
                }
            }
            Ok(Event::Preempted { .. }) => {
                // Memory-pressure lifecycle, surfaced so a streaming client
                // can tell a preemption stall from a dead server.  The
                // generation itself is unaffected (resume is bit-identical).
                let line = json::obj(vec![
                    ("id", json::num(id as f64)),
                    ("event", json::s("preempted")),
                ]);
                if writeln!(out, "{line}").is_err() {
                    let _ = tx.send(Msg::Cancel(id));
                    return false;
                }
            }
            Ok(Event::Resumed { .. }) => {
                let line = json::obj(vec![
                    ("id", json::num(id as f64)),
                    ("event", json::s("resumed")),
                ]);
                if writeln!(out, "{line}").is_err() {
                    let _ = tx.send(Msg::Cancel(id));
                    return false;
                }
            }
            Ok(Event::Finished { response, .. }) => {
                if let Some(delta) = text.finish() {
                    let ev = json::obj(vec![
                        ("id", json::num(id as f64)),
                        ("delta", json::s(delta)),
                    ]);
                    if writeln!(out, "{ev}").is_err() {
                        return false;
                    }
                }
                return writeln!(out, "{}", summary_line(&response)).is_ok();
            }
            Err(_) => {
                let _ = tx.send(Msg::Cancel(id));
                let ev = json::obj(vec![
                    ("id", json::num(id as f64)),
                    ("error", json::s("timeout")),
                ]);
                return writeln!(out, "{ev}").is_ok();
            }
        }
    }
}

/// v1 one-shot: swallow token events, answer with the complete text in
/// the original single-line shape.
fn oneshot_reply(out: &mut TcpStream, id: RequestId, rrx: &Receiver<Event>) -> bool {
    let deadline = Instant::now() + CLIENT_TIMEOUT;
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        match rrx.recv_timeout(left) {
            // One-shot clients only care about the terminal line; the
            // preemption lifecycle is invisible to them (by design — the
            // resumed generation is bit-identical).
            Ok(Event::Token { .. }) | Ok(Event::Preempted { .. }) | Ok(Event::Resumed { .. }) => {}
            Ok(Event::Finished { response, .. }) => {
                return writeln!(out, "{}", summary_line(&response)).is_ok();
            }
            Err(_) => {
                let ev = json::obj(vec![
                    ("id", json::num(id as f64)),
                    ("error", json::s("timeout")),
                ]);
                return writeln!(out, "{ev}").is_ok();
            }
        }
    }
}

/// Start serving on `addr` ("127.0.0.1:0" for an ephemeral port) with
/// default limits.
///
/// The coordinator is built *inside* the scheduler thread by `factory`
/// (PJRT handles are `!Send`: raw PJRT pointers and `Rc` internals must
/// never cross threads, so the whole backend is constructed where it runs).
pub fn serve<B, F>(addr: &str, factory: F, n_conn_threads: usize) -> Result<ServerHandle>
where
    B: Backend + 'static,
    F: FnOnce() -> Result<Coordinator<B>> + Send + 'static,
{
    let cfg = ServerConfig {
        conn_threads: n_conn_threads,
        ..ServerConfig::default()
    };
    serve_with_config(addr, factory, cfg)
}

/// [`serve`] with explicit [`ServerConfig`] limits.
pub fn serve_with_config<B, F>(addr: &str, factory: F, cfg: ServerConfig) -> Result<ServerHandle>
where
    B: Backend + 'static,
    F: FnOnce() -> Result<Coordinator<B>> + Send + 'static,
{
    let listener = TcpListener::bind(addr).context("bind")?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stats = Arc::new(ServerStats::default());
    let (tx, rx) = channel::<Msg>();

    let sched_stats = Arc::clone(&stats);
    let sched = std::thread::Builder::new()
        .name("rap-scheduler".into())
        .spawn(move || match factory() {
            Ok(coord) => scheduler_loop(coord, rx, sched_stats),
            Err(e) => eprintln!("[server] backend init failed: {e:#}"),
        })?;

    let stop2 = Arc::clone(&stop);
    let tx2 = tx.clone();
    let conn_stats = Arc::clone(&stats);
    let acceptor = std::thread::Builder::new()
        .name("rap-acceptor".into())
        .spawn(move || {
            let pool = ThreadPool::new(cfg.conn_threads.max(1));
            let ids = Arc::new(AtomicU64::new(1));
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let tx = tx2.clone();
                let ids = Arc::clone(&ids);
                let stats = Arc::clone(&conn_stats);
                let cfg = cfg.clone();
                pool.execute(move || handle_conn(stream, tx, ids, stats, cfg));
            }
        })?;

    Ok(ServerHandle {
        addr: local,
        stats,
        stop,
        tx,
        threads: vec![sched, acceptor],
    })
}

/// Minimal v1 one-shot client for tests/examples.
pub fn client_request(addr: &std::net::SocketAddr, prompt: &str, max_new: usize) -> Result<Value> {
    let mut stream = TcpStream::connect(addr)?;
    let req = json::obj(vec![
        ("prompt", json::s(prompt)),
        ("max_new", json::num(max_new as f64)),
    ]);
    writeln!(stream, "{req}")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    json::parse(line.trim()).map_err(|e| anyhow::anyhow!("client parse: {e}"))
}

/// Everything a streaming client saw, in order.
#[derive(Debug)]
pub struct StreamOutcome {
    /// The `delta` payloads, in arrival order.
    pub deltas: Vec<String>,
    /// Lifecycle notifications (`"preempted"` / `"resumed"`), in arrival
    /// order — non-empty only when the request was caught by memory
    /// pressure.
    pub events: Vec<String>,
    /// The terminal summary (or error) line.
    pub summary: Value,
    /// Client-side wall time from sending the request to the first delta
    /// line — the streamed TTFT a user actually experiences.
    pub first_delta_ms: f64,
    /// Client-side wall time to the terminal line.
    pub total_ms: f64,
}

/// Classified client-side failure.  The router's retry logic pivots on
/// this split: a failure that provably produced no output can be replayed
/// on another replica, one that already streamed deltas cannot (replaying
/// would duplicate text the caller has seen).
#[derive(Debug)]
pub enum ClientError {
    /// TCP connect failed — the replica is unreachable.  Always
    /// retryable: nothing was ever submitted.
    Connect(io::Error),
    /// An established connection failed mid-exchange (reset, broken
    /// pipe, ...).
    Io {
        source: io::Error,
        /// Delta lines already received when the failure hit.
        deltas_seen: usize,
    },
    /// No line arrived within the read timeout.
    Timeout { deltas_seen: usize },
    /// The server closed the stream before the terminal summary line.
    Disconnected { deltas_seen: usize },
    /// The server sent a line that is not JSON — a protocol violation,
    /// never retryable (a rerun can't fix a broken peer).
    MalformedFrame { line: String },
}

impl ClientError {
    /// Whether re-routing to another replica is safe: only failures
    /// where zero deltas were streamed can be replayed without
    /// duplicating output.
    pub fn is_retryable(&self) -> bool {
        match self {
            ClientError::Connect(_) => true,
            ClientError::Io { deltas_seen, .. }
            | ClientError::Timeout { deltas_seen }
            | ClientError::Disconnected { deltas_seen } => *deltas_seen == 0,
            ClientError::MalformedFrame { .. } => false,
        }
    }

    /// Delta lines already received when the failure hit (the replay
    /// boundary a proxy must surface to its client).
    pub fn deltas_seen(&self) -> usize {
        match self {
            ClientError::Connect(_) | ClientError::MalformedFrame { .. } => 0,
            ClientError::Io { deltas_seen, .. }
            | ClientError::Timeout { deltas_seen }
            | ClientError::Disconnected { deltas_seen } => *deltas_seen,
        }
    }
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Connect(e) => write!(f, "connect failed: {e}"),
            ClientError::Io { source, deltas_seen } => {
                write!(f, "i/o error after {deltas_seen} deltas: {source}")
            }
            ClientError::Timeout { deltas_seen } => {
                write!(f, "read timeout after {deltas_seen} deltas")
            }
            ClientError::Disconnected { deltas_seen } => {
                write!(f, "stream closed before summary after {deltas_seen} deltas")
            }
            ClientError::MalformedFrame { line } => write!(f, "malformed frame: {line:?}"),
        }
    }
}

impl std::error::Error for ClientError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ClientError::Connect(e) | ClientError::Io { source: e, .. } => Some(e),
            _ => None,
        }
    }
}

/// `true` for the error kinds a read timeout surfaces as (platform
/// dependent: unix says WouldBlock, windows TimedOut).
fn is_timeout_kind(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Minimal v2 streaming client: sends `body` (any fields from the
/// protocol above; `stream: true` is forced) and collects delta lines
/// until the terminal `done`/`error` line.  Failures come back
/// classified ([`ClientError`]) so callers can tell retryable transport
/// faults from terminal protocol errors.
pub fn client_request_stream(
    addr: &std::net::SocketAddr,
    body: &Value,
) -> std::result::Result<StreamOutcome, ClientError> {
    client_request_stream_timeout(addr, body, CLIENT_TIMEOUT)
}

/// [`client_request_stream`] with an explicit per-read timeout (the
/// router wants a much shorter leash than interactive clients).
pub fn client_request_stream_timeout(
    addr: &std::net::SocketAddr,
    body: &Value,
    read_timeout: Duration,
) -> std::result::Result<StreamOutcome, ClientError> {
    let mut fields: Vec<(&str, Value)> = vec![("stream", Value::Bool(true))];
    let owned: Vec<(String, Value)> = body
        .as_obj()
        .map(|m| m.iter().map(|(k, v)| (k.clone(), v.clone())).collect())
        .unwrap_or_default();
    for (k, v) in &owned {
        if k != "stream" {
            fields.push((k.as_str(), v.clone()));
        }
    }
    let req = json::obj(fields);
    let mut stream = TcpStream::connect(addr).map_err(ClientError::Connect)?;
    let _ = stream.set_read_timeout(Some(read_timeout));
    writeln!(stream, "{req}").map_err(|e| ClientError::Io {
        source: e,
        deltas_seen: 0,
    })?;
    let t0 = Instant::now();
    let mut reader = BufReader::new(stream);
    let mut deltas = Vec::new();
    let mut events = Vec::new();
    let mut first_delta_ms = 0.0f64;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => {
                return Err(ClientError::Disconnected {
                    deltas_seen: deltas.len(),
                })
            }
            Ok(_) => {}
            Err(e) if is_timeout_kind(&e) => {
                return Err(ClientError::Timeout {
                    deltas_seen: deltas.len(),
                })
            }
            Err(e) => {
                return Err(ClientError::Io {
                    source: e,
                    deltas_seen: deltas.len(),
                })
            }
        }
        let v = json::parse(line.trim()).map_err(|_| ClientError::MalformedFrame {
            line: line.trim().to_string(),
        })?;
        if v.get("ack").is_some() {
            continue; // replica-mode submit ack (when the body asked for it)
        }
        if let Some(delta) = v.get("delta").and_then(|d| d.as_str()) {
            if deltas.is_empty() {
                first_delta_ms = t0.elapsed().as_secs_f64() * 1e3;
            }
            deltas.push(delta.to_string());
            continue;
        }
        if let Some(ev) = v.get("event").and_then(|e| e.as_str()) {
            events.push(ev.to_string());
            continue;
        }
        let total_ms = t0.elapsed().as_secs_f64() * 1e3;
        return Ok(StreamOutcome {
            deltas,
            events,
            summary: v,
            first_delta_ms,
            total_ms,
        });
    }
}

/// One-shot health probe: sends `{"health": true}` and returns the gauge
/// line (`{"ok", "pending", "used_blocks", "capacity_blocks",
/// "prefix_hits", "prefix_lookups"}`).  `timeout` bounds connect, write
/// and read — probers want a short leash.
pub fn client_health(
    addr: &std::net::SocketAddr,
    timeout: Duration,
) -> std::result::Result<Value, ClientError> {
    let mut stream = TcpStream::connect_timeout(addr, timeout).map_err(ClientError::Connect)?;
    let _ = stream.set_read_timeout(Some(timeout));
    let _ = stream.set_write_timeout(Some(timeout));
    let req = json::obj(vec![("health", Value::Bool(true))]);
    writeln!(stream, "{req}").map_err(|e| ClientError::Io {
        source: e,
        deltas_seen: 0,
    })?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) => return Err(ClientError::Disconnected { deltas_seen: 0 }),
        Ok(_) => {}
        Err(e) if is_timeout_kind(&e) => return Err(ClientError::Timeout { deltas_seen: 0 }),
        Err(e) => {
            return Err(ClientError::Io {
                source: e,
                deltas_seen: 0,
            })
        }
    }
    json::parse(line.trim()).map_err(|_| ClientError::MalformedFrame {
        line: line.trim().to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream_all(bytes: &[u8]) -> String {
        let mut s = Utf8Stream::new();
        let mut out = String::new();
        for &b in bytes {
            if let Some(d) = s.push(b) {
                out.push_str(&d);
            }
        }
        if let Some(d) = s.finish() {
            out.push_str(&d);
        }
        out
    }

    #[test]
    fn utf8_stream_matches_lossy_decoding() {
        let cases: Vec<Vec<u8>> = vec![
            b"plain ascii".to_vec(),
            "héllo wörld".as_bytes().to_vec(),
            "byte-split 😀 emoji".as_bytes().to_vec(),
            vec![0xC3],             // dangling 2-byte lead
            vec![0xC3, 0x41],       // broken 2-byte sequence
            vec![0xE0, 0x80, 0x41], // invalid continuation
            vec![0xFF, 0xFE, b'a'], // not UTF-8 at all
            vec![0x80, 0x81],       // stray continuations
            vec![0xF0, 0x9F, 0x98], // dangling 4-byte prefix
            {
                let mut v = b"mixed ".to_vec();
                v.extend("é".as_bytes());
                v.push(0xFF);
                v.extend("😀".as_bytes());
                v.push(0xC3);
                v
            },
        ];
        for bytes in cases {
            assert_eq!(
                stream_all(&bytes),
                String::from_utf8_lossy(&bytes),
                "bytes {bytes:?}"
            );
        }
    }

    #[test]
    fn utf8_stream_emits_multibyte_chars_once_complete() {
        let mut s = Utf8Stream::new();
        let e = "é".as_bytes(); // [0xC3, 0xA9]
        assert_eq!(s.push(e[0]), None, "incomplete char is held back");
        assert_eq!(s.push(e[1]).as_deref(), Some("é"));
        assert_eq!(s.finish(), None);
    }

    #[test]
    fn parse_request_defaults_match_v1() {
        let v = json::parse(r#"{"prompt": "hi", "max_new": 4}"#).unwrap();
        let r = parse_request(&v, 7).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, b"hi");
        assert_eq!(r.max_new, 4);
        assert!(r.sampling.is_greedy());
        assert!(r.stop.is_empty());
        assert!(!r.stream);
        assert!(r.deadline_ms.is_none());
    }

    #[test]
    fn parse_request_reads_v2_fields() {
        let v = json::parse(
            r#"{"prompt": "x", "max_new": 8, "stream": true, "temperature": 0.5,
                "top_k": 10, "top_p": 0.9, "seed": 99, "stop": ["ab", "c"],
                "deadline_ms": 1500}"#,
        )
        .unwrap();
        let r = parse_request(&v, 1).unwrap();
        assert!(r.stream);
        assert!((r.sampling.temperature - 0.5).abs() < 1e-6);
        assert_eq!(r.sampling.top_k, 10);
        assert!((r.sampling.top_p - 0.9).abs() < 1e-6);
        assert_eq!(r.sampling.seed, 99);
        assert_eq!(r.stop, vec![b"ab".to_vec(), b"c".to_vec()]);
        assert_eq!(r.deadline_ms, Some(1500));
    }

    #[test]
    fn parse_request_rejects_poisonous_sampling_params() {
        let cases = [
            (r#"{"prompt": "x", "temperature": -0.5}"#, "temperature"),
            (r#"{"prompt": "x", "temperature": 1e999}"#, "temperature"), // json inf
            (r#"{"prompt": "x", "top_p": 0.0}"#, "top_p"),
            (r#"{"prompt": "x", "top_p": -1}"#, "top_p"),
            (r#"{"prompt": "x", "top_p": 1.5}"#, "top_p"),
            (r#"{"prompt": "x", "max_new": -3}"#, "max_new"),
            (r#"{"prompt": "x", "max_new": 99000000}"#, "max_new"),
            (r#"{"prompt": "x", "deadline_ms": -10}"#, "deadline_ms"),
            (r#"{"prompt": "x", "retention": {}}"#, "retention.policy"),
            (r#"{"prompt": "x", "retention": {"ratio": 0.5}}"#, "retention.policy"),
            (
                r#"{"prompt": "x", "retention": {"policy": "lru"}}"#,
                "retention.policy",
            ),
            (
                r#"{"prompt": "x", "retention": {"policy": "window", "ratio": 0}}"#,
                "retention.ratio",
            ),
            (
                r#"{"prompt": "x", "retention": {"policy": "window", "ratio": -0.5}}"#,
                "retention.ratio",
            ),
            (
                r#"{"prompt": "x", "retention": {"policy": "window", "ratio": 1.5}}"#,
                "retention.ratio",
            ),
        ];
        for (body, field) in cases {
            let Ok(v) = json::parse(body) else { continue }; // 1e999 may not parse
            assert_eq!(parse_request(&v, 1).unwrap_err(), field, "body {body}");
        }
        // The boundary values stay valid.
        let v = json::parse(r#"{"prompt": "x", "temperature": 0, "top_p": 1, "max_new": 0}"#)
            .unwrap();
        assert!(parse_request(&v, 1).is_ok());
    }

    #[test]
    fn parse_request_reads_retention() {
        let v = json::parse(
            r#"{"prompt": "x", "retention": {"policy": "l2norm", "ratio": 0.25}}"#,
        )
        .unwrap();
        let r = parse_request(&v, 1).unwrap();
        let spec = r.retention.expect("retention parsed");
        assert_eq!(spec.press, Press::L2Norm);
        assert!((spec.ratio - 0.25).abs() < 1e-6);
        // Omitted ratio defaults; omitted object = retain-all; ratio 1.0
        // (retain-all through the press machinery) is a valid boundary.
        let v = json::parse(r#"{"prompt": "x", "retention": {"policy": "window"}}"#).unwrap();
        let r = parse_request(&v, 1).unwrap();
        assert_eq!(r.retention.map(|s| s.press), Some(Press::Window));
        let v = json::parse(r#"{"prompt": "x"}"#).unwrap();
        assert!(parse_request(&v, 1).unwrap().retention.is_none());
        let v = json::parse(
            r#"{"prompt": "x", "retention": {"policy": "anchor-reservoir", "ratio": 1.0}}"#,
        )
        .unwrap();
        assert!(parse_request(&v, 1).is_ok());
    }

    #[test]
    fn parse_request_reads_speculative() {
        let v = json::parse(r#"{"prompt": "x", "speculative": {"policy": "ngram", "k": 8}}"#)
            .unwrap();
        let spec = parse_request(&v, 1).unwrap().speculative.expect("speculative parsed");
        assert_eq!(spec.policy, DraftPolicy::Ngram);
        assert_eq!(spec.k, 8);
        // Omitted k defaults; omitted object = no per-request override.
        let v = json::parse(r#"{"prompt": "x", "speculative": {"policy": "ngram"}}"#).unwrap();
        assert_eq!(parse_request(&v, 1).unwrap().speculative.map(|s| s.k), Some(DEFAULT_DRAFT_K));
        let v = json::parse(r#"{"prompt": "x"}"#).unwrap();
        assert!(parse_request(&v, 1).unwrap().speculative.is_none());
        // Boundary k values stay valid.
        for k in [1, MAX_DRAFT_K] {
            let v = json::parse(&format!(
                r#"{{"prompt": "x", "speculative": {{"policy": "ngram", "k": {k}}}}}"#
            ))
            .unwrap();
            assert!(parse_request(&v, 1).is_ok());
        }
    }

    #[test]
    fn parse_request_rejects_bad_speculative() {
        let cases = [
            (r#"{"prompt": "x", "speculative": {}}"#, "speculative.policy"),
            (r#"{"prompt": "x", "speculative": {"k": 4}}"#, "speculative.policy"),
            (
                r#"{"prompt": "x", "speculative": {"policy": "medusa"}}"#,
                "speculative.policy",
            ),
            (
                r#"{"prompt": "x", "speculative": {"policy": "ngram", "k": 0}}"#,
                "speculative.k",
            ),
            (
                r#"{"prompt": "x", "speculative": {"policy": "ngram", "k": -2}}"#,
                "speculative.k",
            ),
            (
                r#"{"prompt": "x", "speculative": {"policy": "ngram", "k": 33}}"#,
                "speculative.k",
            ),
        ];
        for (body, field) in cases {
            let v = json::parse(body).unwrap();
            assert_eq!(parse_request(&v, 1).unwrap_err(), field, "body {body}");
        }
    }

    #[test]
    fn rejected_summary_is_queue_full_error() {
        let line = summary_line(&Response::rejected(3));
        assert_eq!(line.get("error").and_then(|e| e.as_str()), Some("queue_full"));
        assert_eq!(
            line.get("finish_reason").and_then(|f| f.as_str()),
            Some("rejected")
        );
        assert!(line.get("done").is_none());
    }

    #[test]
    fn too_large_summary_is_a_distinct_error() {
        let line = summary_line(&Response::too_large(4));
        assert_eq!(line.get("error").and_then(|e| e.as_str()), Some("too_large"));
        assert_eq!(
            line.get("finish_reason").and_then(|f| f.as_str()),
            Some("rejected")
        );
    }

    fn bounded(input: &[u8], max: usize) -> (LineRead, String) {
        let mut reader = std::io::Cursor::new(input.to_vec());
        let mut line = String::new();
        let r = read_line_bounded(&mut reader, &mut line, max);
        (r, line)
    }

    #[test]
    fn bounded_reader_delivers_lines_under_the_cap() {
        let (r, line) = bounded(b"hello\nworld\n", 64);
        assert_eq!(r, LineRead::Line);
        assert_eq!(line, "hello");
        // Exactly at the cap is still accepted.
        let (r, line) = bounded(b"abcde\n", 5);
        assert_eq!(r, LineRead::Line);
        assert_eq!(line, "abcde");
    }

    #[test]
    fn bounded_reader_refuses_oversized_lines() {
        // One byte over the cap, newline present.
        let (r, line) = bounded(b"abcdef\n", 5);
        assert_eq!(r, LineRead::TooLong);
        assert!(line.is_empty(), "nothing delivered on TooLong");
        // No newline at all: must give up instead of buffering forever.
        let big = vec![b'x'; 1024];
        let (r, _) = bounded(&big, 100);
        assert_eq!(r, LineRead::TooLong);
    }

    #[test]
    fn bounded_reader_matches_read_line_at_eof() {
        // Clean EOF, nothing buffered.
        let (r, _) = bounded(b"", 64);
        assert_eq!(r, LineRead::Closed);
        // EOF with an unterminated final line still delivers it.
        let (r, line) = bounded(b"partial", 64);
        assert_eq!(r, LineRead::Line);
        assert_eq!(line, "partial");
    }

    #[test]
    fn bounded_reader_consumes_across_reads() {
        let mut reader = std::io::Cursor::new(b"first\nsecond\nthird".to_vec());
        let mut seen = Vec::new();
        let mut line = String::new();
        loop {
            line.clear();
            match read_line_bounded(&mut reader, &mut line, 64) {
                LineRead::Line => seen.push(line.clone()),
                LineRead::Closed => break,
                LineRead::TooLong => panic!("unexpected TooLong"),
            }
        }
        assert_eq!(seen, vec!["first", "second", "third"]);
    }

    #[test]
    fn client_error_retryability_matrix() {
        let io_err = || io::Error::new(io::ErrorKind::ConnectionReset, "reset");
        // Nothing streamed yet: safe to replay elsewhere.
        assert!(ClientError::Connect(io_err()).is_retryable());
        assert!(ClientError::Io { source: io_err(), deltas_seen: 0 }.is_retryable());
        assert!(ClientError::Timeout { deltas_seen: 0 }.is_retryable());
        assert!(ClientError::Disconnected { deltas_seen: 0 }.is_retryable());
        // Output already streamed: a replay would duplicate it.
        assert!(!ClientError::Io { source: io_err(), deltas_seen: 3 }.is_retryable());
        assert!(!ClientError::Timeout { deltas_seen: 1 }.is_retryable());
        assert!(!ClientError::Disconnected { deltas_seen: 7 }.is_retryable());
        // Protocol violations are never retryable.
        let mal = ClientError::MalformedFrame { line: "not json".into() };
        assert!(!mal.is_retryable());
        assert_eq!(mal.deltas_seen(), 0);
        assert_eq!(
            ClientError::Disconnected { deltas_seen: 7 }.deltas_seen(),
            7
        );
    }
}
