//! `rap` — leader binary: serve, generate, evaluate, plan, benchmark,
//! and regenerate every paper table/figure.
//!
//! Subcommands:
//!   info                         — manifest summary
//!   generate  --model --variant --prompt --max-new [--engine rust|pjrt]
//!   eval      --model [--variants a,b] [--quant]
//!   serve     --model --variant [--addr 127.0.0.1:7433]
//!   route     --replicas H:P,H:P [--addr 127.0.0.1:7432] [--policy affinity]
//!   bench-serving --model --variant [--requests N] [--rate R]
//!   plan      --rho 0.3          — run the native RAP planner on a config
//!   experiments [name|--all] [--quick]

use anyhow::{Context, Result};

use rap::config::{Method, ModelConfig};
use rap::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use rap::eval::{eval_ppl, eval_ppl_quantized};
use rap::experiments::{self, ExpContext};
use rap::kvcache::CacheShape;
use rap::manifest::Manifest;
use rap::model::load_engine;
use rap::rap::budget::{allocate, ranks_from_ratios, GroupScores};
use rap::router::{serve_router, RoutePolicy, RouterConfig};
use rap::runtime::backend::PjrtBackend;
use rap::runtime::{session::Session, PjrtContext, PjrtEngine};
use rap::util::cli::Args;
use rap::workload::{generate as gen_workload, WorkloadConfig};

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("info") => cmd_info(),
        Some("generate") => cmd_generate(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("route") => cmd_route(&args),
        Some("bench-serving") => cmd_bench_serving(&args),
        Some("plan") => cmd_plan(&args),
        Some("experiments") => cmd_experiments(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "rap — RoPE-Aligned Pruning serving stack\n\n\
         USAGE: rap <subcommand> [options]\n\n\
         subcommands:\n\
           info                                   manifest & artifact summary\n\
           generate  --model M --variant V --prompt P [--max-new N] [--engine rust|pjrt]\n\
           eval      --model M [--variants a,b,c] [--quant] [--windows N]\n\
           serve     --model M --variant V [--addr HOST:PORT] [--sessions N]\n\
                     (API v2: per-token streaming, seeded sampling, stop\n\
                      sequences, {{\"cancel\": id}}, per-request KV retention\n\
                      {{\"retention\": {{\"policy\", \"ratio\"}}}}, per-request\n\
                      speculative decode {{\"speculative\": {{\"policy\":\n\
                      \"ngram\", \"k\": N}}}} (self-drafted, output-identical;\n\
                      fleet default via RAP_SPECULATIVE=ngram:K); v1\n\
                      one-shot still served)\n\
           route     --replicas H:P,H:P [--addr HOST:PORT] [--policy affinity]\n\
                     (fronts `serve` replicas: prefix-affinity or\n\
                      least-loaded/random routing, health probing, bounded\n\
                      retry of never-streamed requests, proxied cancel;\n\
                      admin lines {{\"admin\": \"status\"|\"register\"|\"drain\"}})\n\
           bench-serving --model M --variant V [--requests N] [--rate R]\n\
           plan      --rho R [--layers L] [--seed S]   native Alg.2 + pair-selection demo\n\
           experiments [NAME ...|--all] [--quick]      regenerate paper tables/figures\n"
    );
}

fn cmd_info() -> Result<()> {
    let manifest = Manifest::load_default()?;
    println!("artifacts root: {}", manifest.root.display());
    println!(
        "s_max: {}  eval: seq {} x {} windows",
        manifest.s_max, manifest.eval_seq, manifest.eval_windows
    );
    for (name, entry) in &manifest.models {
        let c = &entry.config;
        println!(
            "\nmodel {name}: d={} L={} H={}/{} dh={} pairing={:?}",
            c.d_model, c.n_layers, c.n_heads, c.n_kv_heads, c.head_dim, c.pairing,
        );
        println!("  variants ({}):", entry.variants.len());
        for (key, ve) in &entry.variants {
            let graphs = entry.hlo.get(key).map(|g| g.len()).unwrap_or(0);
            println!(
                "    {key:<18} kv={:>5.1}% ppl(py)={:<8.3} graphs={graphs}",
                100.0 * ve.spec.kv_retained(c),
                ve.ppl_python
            );
        }
    }
    println!("\nrope-bench graphs: {}", manifest.rope_bench.len());
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let model = args.get_or("model", "tinyllama").to_string();
    let variant = args.get_or("variant", "rap_r30").to_string();
    let prompt = args.get_or("prompt", "the quick brown fox ").as_bytes().to_vec();
    let max_new = args.get_usize("max-new", 48);
    let manifest = Manifest::load_default()?;

    match args.get_or("engine", "pjrt") {
        "rust" => {
            let engine = load_engine(&manifest, &model, &variant)?;
            let out = engine.generate(&prompt, max_new, manifest.s_max);
            println!("{}", String::from_utf8_lossy(&out));
        }
        _ => {
            let ctx = PjrtContext::cpu()?;
            let engine = PjrtEngine::load(&ctx, &manifest, &model, &variant)?;
            let mut session = Session::new(&ctx, &engine)?;
            session.prefill(&prompt)?;
            let out = session.generate(max_new)?;
            println!("{}", String::from_utf8_lossy(&out));
        }
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.get_or("model", "tinyllama").to_string();
    let manifest = Manifest::load_default()?;
    let entry = manifest.model(&model)?;
    let corpus = manifest.eval_corpus()?;
    let windows = args.get_usize("windows", 12);
    let variants = match args.get("variants") {
        Some(_) => args.get_list("variants", &[]),
        None => entry.variants.keys().cloned().collect(),
    };
    println!(
        "{:<22} {:>8} {:>8} {:>8}",
        "variant",
        "ppl",
        "py-ppl",
        if args.flag("quant") { "int4" } else { "" }
    );
    for key in variants {
        let Some(ve) = entry.variants.get(&key) else { continue };
        let engine = load_engine(&manifest, &model, &key)?;
        let ppl = eval_ppl(&engine, &corpus, manifest.eval_seq, windows)?;
        if args.flag("quant") {
            let q = eval_ppl_quantized(&engine, &corpus, manifest.eval_seq, windows.min(4))?;
            println!("{key:<22} {ppl:>8.3} {:>8.3} {q:>8.3}", ve.ppl_python);
        } else {
            println!("{key:<22} {ppl:>8.3} {:>8.3}", ve.ppl_python);
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = args.get_or("model", "tinyllama").to_string();
    let variant = args.get_or("variant", "rap_r30").to_string();
    let addr = args.get_or("addr", "127.0.0.1:7433").to_string();
    let manifest = Manifest::load_default()?;
    let entry = manifest.model(&model)?;
    let shape = CacheShape::of(&entry.config, &entry.variants[&variant].spec);

    println!(
        "serving {model}/{variant} on {addr} (KV {:.0}% of baseline)",
        100.0 * entry.variants[&variant].spec.kv_retained(&entry.config)
    );
    let sessions = args.get_usize("sessions", 4);
    let model2 = model.clone();
    let variant2 = variant.clone();
    // PJRT handles are !Send: the factory builds the whole backend on the
    // scheduler thread (process-lifetime objects leak intentionally).
    let factory = move || -> Result<Coordinator<PjrtBackend<'static>>> {
        let manifest = Manifest::load_default()?;
        let ctx: &'static PjrtContext = Box::leak(Box::new(PjrtContext::cpu()?));
        let engine: &'static PjrtEngine =
            Box::leak(Box::new(PjrtEngine::load(ctx, &manifest, &model2, &variant2)?));
        let backend = PjrtBackend::new(ctx, engine)?;
        Ok(Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: sessions,
                    buckets: engine.decode_batches(),
                    max_queue: 512,
                    ..Default::default()
                },
                kv_budget_bytes: 128 << 20,
            },
        ))
    };
    let handle = rap::server::serve(&addr, factory, 4)?;
    println!(
        "listening on {} — serving API v2, one JSON object per line:\n\
         \x20 {{\"prompt\", \"max_new\", \"stream\", \"temperature\", \"top_k\", \"top_p\", \
         \"seed\", \"stop\", \"retention\", \"speculative\"}}\n\
         \x20 streaming replies: {{\"delta\"}} lines then a {{\"done\", \"finish_reason\"}} \
         summary; {{\"cancel\": id}} tears a request down mid-flight\n\
         \x20 retention: {{\"policy\": \"window\"|\"l2norm\"|\"attn-score\"|\
         \"anchor-reservoir\", \"ratio\": (0,1]}} prunes the request's KV \
         cache to ratio x context once it clears the press floor\n\
         \x20 speculative: {{\"policy\": \"ngram\", \"k\": 1..=32}} self-drafts k \
         tokens per step and verifies them in one batched pass — output is \
         bit-identical to plain decode (fleet default: RAP_SPECULATIVE=ngram:K)\n\
         \x20 rejected before admission as {{\"error\": \"bad_request\", \"field\": \
         \"retention.policy\"}} (unknown policy), \"retention.ratio\" \
         (ratio outside (0,1]), \"speculative.policy\", or \"speculative.k\"\n\
         \x20 (v1 one-shot requests still answered in the old shape)",
        handle.addr
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_route(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7432").to_string();
    let replicas: Vec<std::net::SocketAddr> = args
        .get_list("replicas", &[])
        .iter()
        .map(|r| r.parse().with_context(|| format!("replica address {r:?}")))
        .collect::<Result<_>>()?;
    let policy = match args.get_or("policy", "affinity") {
        "least-loaded" => RoutePolicy::LeastLoaded,
        "random" => RoutePolicy::Random {
            seed: args.get_usize("seed", 0) as u64,
        },
        _ => RoutePolicy::Affinity,
    };
    let handle = serve_router(
        &addr,
        &replicas,
        RouterConfig {
            policy,
            ..RouterConfig::default()
        },
    )?;
    println!(
        "router on {} fronting {} replica(s) ({:?} routing)\n\
         \x20 requests: serving API v2 lines, relayed with bounded retry —\n\
         \x20 a request that has streamed nothing re-routes on replica failure,\n\
         \x20 one that already streamed surfaces {{\"error\": \"replica_failed\",\n\
         \x20 \"deltas_streamed\": n}} so the caller knows the replay boundary\n\
         \x20 {{\"cancel\": id}} is proxied to the owning replica\n\
         \x20 admin: {{\"admin\": \"status\"}}, {{\"admin\": \"register\", \"replica\": \
         \"H:P\"}},\n\
         \x20        {{\"admin\": \"drain\", \"replica\": \"H:P\"}} (finish in-flight, \
         then drop)\n\
         \x20 health: {{\"health\": true}} returns fleet gauges",
        handle.addr,
        replicas.len(),
        policy,
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_bench_serving(args: &Args) -> Result<()> {
    let model = args.get_or("model", "tinyllama").to_string();
    let variant = args.get_or("variant", "rap_r30").to_string();
    let manifest = Manifest::load_default()?;
    let entry = manifest.model(&model)?;
    let corpus = manifest.eval_corpus()?;
    let ctx = PjrtContext::cpu()?;
    let engine = PjrtEngine::load(&ctx, &manifest, &model, &variant)?;
    let backend = PjrtBackend::new(&ctx, &engine)?;
    let shape = CacheShape::of(&entry.config, &entry.variants[&variant].spec);
    let mut coord = Coordinator::new(
        backend,
        shape,
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_sessions: args.get_usize("sessions", 4),
                buckets: engine.decode_batches(),
                max_queue: 1024,
                ..Default::default()
            },
            kv_budget_bytes: 64 << 20,
        },
    );
    let wl = WorkloadConfig {
        n_requests: args.get_usize("requests", 32),
        arrival_rate: args.get_f64("rate", 50.0),
        ..Default::default()
    };
    for tr in gen_workload(&wl, &corpus) {
        coord.submit(tr.request);
    }
    coord.run_to_completion()?;
    println!("{}", coord.metrics.report());
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    // Native Algorithm-2 + pair-selection demo on a synthetic config: shows
    // the budget allocation and the selected pairs without any artifacts.
    let rho = args.get_f64("rho", 0.3);
    let layers = args.get_usize("layers", 4);
    let seed = args.get_usize("seed", 42) as u64;
    let mut cfg = ModelConfig::paper_llama();
    cfg.n_layers = layers;
    let mut rng = rap::util::rng::Rng::new(seed);
    let scores = GroupScores {
        k: (0..layers).map(|_| rng.f64() * 10.0 + 0.1).collect(),
        v: (0..layers).map(|_| rng.f64() * 30.0 + 5.0).collect(),
    };
    let (rk, rv) = allocate(&scores, rho);
    let (m, rvv) = ranks_from_ratios(&cfg, &rk, &rv);
    println!("Algorithm 2 on synthetic Fisher mass (rho={rho}):");
    for l in 0..layers {
        println!(
            "  layer {l}: score k={:.2} v={:.2}  ->  rho_k={:.3} rho_v={:.3}  ->  m={} (K width {}), rv={}",
            scores.k[l], scores.v[l], rk[l], rv[l], m[l], 2 * m[l], rvv[l]
        );
    }
    let achieved = rap::rap::budget::achieved_kv_ratio(&cfg, &m, &rvv);
    println!(
        "achieved KV retention: {:.1}% (target {:.1}%)",
        achieved * 100.0,
        (1.0 - rho) * 100.0
    );
    println!(
        "break-even rho at H=1: SVD {:.0}%, PaLU {:.0}%, RAP 0%",
        100.0 * rap::cost::break_even_rho(Method::Svd, 1),
        100.0 * rap::cost::break_even_rho(Method::Palu, 1),
    );
    Ok(())
}

fn cmd_experiments(args: &Args) -> Result<()> {
    let ctx = ExpContext::new(args.flag("quick"))?;
    if args.flag("all") || args.positionals.is_empty() {
        experiments::run_all(&ctx)?;
    } else {
        for name in &args.positionals {
            experiments::run(&ctx, name).with_context(|| format!("experiment {name}"))?;
        }
    }
    Ok(())
}
