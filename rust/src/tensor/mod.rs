//! Row-major f32 tensor substrate for the pure-Rust inference engine,
//! baselines and pruning planner.
//!
//! Deliberately small: dense row-major storage, shape checked ops, a
//! cache-blocked matmul with an optional transposed-B fast path, the
//! neural-net primitives the engine needs (softmax, RMS-norm, SiLU), and
//! numerical linear algebra (one-sided Jacobi SVD, Cholesky) for the Rust
//! implementations of the SVD/PaLU baselines.

pub mod linalg;
pub mod ops;
pub mod simd;

pub use linalg::{cholesky, solve_lower_triangular, svd_thin};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![v; n],
        }
    }

    pub fn randn(shape: Vec<usize>, scale: f32, rng: &mut crate::util::rng::Rng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, scale);
        t
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Rows/cols of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.rank(), 2, "expected 2-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let (_, c) = self.dims2();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let (_, c) = self.dims2();
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        let (_, c) = self.dims2();
        self.data[i * c + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        let (_, c) = self.dims2();
        self.data[i * c + j] = v;
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    pub fn transpose2(&self) -> Tensor {
        let (r, c) = self.dims2();
        let mut out = Tensor::zeros(vec![c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    /// Gather columns of a 2-D tensor into a new tensor (used by the Rust
    /// RAP planner's A/B construction).
    pub fn gather_cols(&self, cols: &[usize]) -> Tensor {
        let (r, c) = self.dims2();
        let mut out = Tensor::zeros(vec![r, cols.len()]);
        for i in 0..r {
            let src = &self.data[i * c..(i + 1) * c];
            let dst = &mut out.data[i * cols.len()..(i + 1) * cols.len()];
            for (k, &j) in cols.iter().enumerate() {
                debug_assert!(j < c);
                dst[k] = src[j];
            }
        }
        out
    }

    /// Slice rows [lo, hi) of a 2-D tensor.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        let (_, c) = self.dims2();
        Tensor::new(vec![hi - lo, c], self.data[lo * c..hi * c].to_vec())
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.dims2(), (2, 3));
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(0);
        let t = Tensor::randn(vec![5, 7], 1.0, &mut rng);
        assert_eq!(t.transpose2().transpose2(), t);
    }

    #[test]
    fn gather_cols_selects() {
        let t = Tensor::new(vec![2, 4], vec![0., 1., 2., 3., 10., 11., 12., 13.]);
        let g = t.gather_cols(&[3, 1]);
        assert_eq!(g.data, vec![3., 1., 13., 11.]);
    }

    #[test]
    fn slice_rows_works() {
        let t = Tensor::new(vec![3, 2], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.slice_rows(1, 3).data, vec![2., 3., 4., 5.]);
    }
}
