//! Tensor kernels: blocked matmul, fused attention primitives, norms.
//!
//! The matmul microkernel is the L3 hot path for the pure-Rust engine
//! (`model::engine`): row-major A times row-major B with a K-blocked
//! accumulate over B rows (streaming B rows keeps the inner loop fully
//! vectorizable without materialising B^T), parallelised over A-row chunks
//! via `scoped_chunks`.

use std::sync::OnceLock;

use super::Tensor;
use crate::util::threadpool::scoped_chunks;

/// Number of threads for data-parallel kernels (1 on this testbed;
/// overridable via RAP_THREADS).  The environment is consulted exactly once
/// per process — this sits on the per-token decode path, so re-parsing an
/// env var per matmul call would be both slow and racy.  Tests that need a
/// specific thread count use the explicit `*_with_threads` entry points
/// instead of mutating the process environment.
pub fn kernel_threads() -> usize {
    static KERNEL_THREADS: OnceLock<usize> = OnceLock::new();
    *KERNEL_THREADS.get_or_init(|| {
        std::env::var("RAP_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|v| v.get())
                    .unwrap_or(1)
            })
    })
}

/// C[M,N] = A[M,K] @ B[K,N].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_with_threads(a, b, if a.dims2().0 >= 4 { kernel_threads() } else { 1 })
}

/// `matmul` with an explicit worker count (tests pin this instead of
/// mutating the process-global RAP_THREADS, which races under the parallel
/// test harness).
pub fn matmul_with_threads(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    let (m, k) = a.dims2();
    let (k2, n) = b.dims2();
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = Tensor::zeros(vec![m, n]);
    matmul_into_threads(&a.data, &b.data, &mut out.data, m, k, n, threads);
    out
}

/// Accumulating inner routine on raw slices (reused by the engine to avoid
/// intermediate allocations on the decode hot path).
pub fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    let threads = if m >= 4 { kernel_threads() } else { 1 };
    matmul_into_threads(a, b, out, m, k, n, threads);
}

/// `matmul_into` with an explicit worker count.
pub fn matmul_into_threads(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    // SAFETY-free parallelism: split output rows across scoped workers.
    let out_ptr = OutPtr(out.as_mut_ptr());
    scoped_chunks(m, threads, |rows| {
        let out_ptr = &out_ptr;
        for i in rows {
            // Row i of C accumulates row-i-of-A-weighted rows of B.
            let ai = &a[i * k..(i + 1) * k];
            let ci = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n)
            };
            for (p, &aip) in ai.iter().enumerate() {
                if aip == 0.0 {
                    continue;
                }
                let bp = &b[p * n..(p + 1) * n];
                for (c, &bv) in ci.iter_mut().zip(bp.iter()) {
                    *c += aip * bv;
                }
            }
        }
    });
}

struct OutPtr(*mut f32);
// Disjoint row ranges per worker make this sound.
unsafe impl Sync for OutPtr {}

/// C[M,N] = A[M,K] @ B[K,N] where row i of C is computed with *exactly*
/// the accumulation order of `vecmat_into(&a[i*k..], b, row_i)` — the
/// chunked-prefill GEMM.  One call projects a whole token chunk; rows fan
/// out across `threads` scoped workers, and because each output row runs
/// the same 4-row K-blocked kernel the token loop runs per token, the
/// blocked prefill stays bit-identical to token-by-token prefill.
pub fn matmul_rows_into(a: &[f32], b: &Tensor, out: &mut [f32], threads: usize) {
    let (k, n) = b.dims2();
    debug_assert_eq!(a.len() % k, 0);
    let m = a.len() / k;
    debug_assert_eq!(out.len(), m * n);
    let out_ptr = OutPtr(out.as_mut_ptr());
    scoped_chunks(m, threads, |rows| {
        let out_ptr = &out_ptr;
        for i in rows {
            // SAFETY: workers own disjoint row ranges of `out`.
            let yi = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
            vecmat_into(&a[i * k..(i + 1) * k], b, yi);
        }
    });
}

/// y[N] = x[K] @ B[K,N] — single-row fast path (decode step projections).
///
/// 4-row blocking over the K axis: each pass reads four B rows and writes y
/// once, quartering the y load/store traffic vs the naive axpy loop (§Perf:
/// ~1.6x on the engine's projection shapes).
pub fn vecmat(x: &[f32], b: &Tensor) -> Vec<f32> {
    let n = b.dims2().1;
    let mut y = vec![0.0f32; n];
    vecmat_into(x, b, &mut y);
    y
}

/// `vecmat` writing into caller-owned storage — the allocation-free decode
/// hot path (`DecodeWorkspace` owns `y`).
pub fn vecmat_into(x: &[f32], b: &Tensor, y: &mut [f32]) {
    let (k, n) = b.dims2();
    assert_eq!(x.len(), k);
    assert_eq!(y.len(), n);
    y.fill(0.0);
    let blocks = k / 4;
    for blk in 0..blocks {
        let p = blk * 4;
        let (x0, x1, x2, x3) = (x[p], x[p + 1], x[p + 2], x[p + 3]);
        let b0 = &b.data[p * n..(p + 1) * n];
        let b1 = &b.data[(p + 1) * n..(p + 2) * n];
        let b2 = &b.data[(p + 2) * n..(p + 3) * n];
        let b3 = &b.data[(p + 3) * n..(p + 4) * n];
        for j in 0..n {
            y[j] += x0 * b0[j] + x1 * b1[j] + x2 * b2[j] + x3 * b3[j];
        }
    }
    for p in blocks * 4..k {
        let xv = x[p];
        if xv == 0.0 {
            continue;
        }
        let bp = &b.data[p * n..(p + 1) * n];
        for (yo, &bv) in y.iter_mut().zip(bp.iter()) {
            *yo += xv * bv;
        }
    }
}

/// Attention score kernel over one contiguous block of cached rows:
/// `out[i] = scale * (q · rows[i*w .. (i+1)*w])` for each of the
/// `rows.len()/w` rows.  Rows are processed in pairs so `q` streams through
/// the registers once per pair instead of once per row.
///
/// Per-row accumulation (four partial sums + scalar tail, reduced as
/// `acc + s0 + s1 + s2 + s3`) mirrors `dot` exactly, so scores computed
/// block-by-block over the paged KV store are bit-identical to a dense
/// sweep — the batched-vs-sequential identity tests rely on this.
pub fn dot_rows_scaled(q: &[f32], rows: &[f32], w: usize, scale: f32, out: &mut [f32]) {
    debug_assert_eq!(q.len(), w);
    debug_assert_eq!(rows.len() % w, 0);
    let n = rows.len() / w;
    debug_assert!(out.len() >= n);
    let chunks = w / 4;
    let mut r = 0;
    while r + 2 <= n {
        let row0 = &rows[r * w..(r + 1) * w];
        let row1 = &rows[(r + 1) * w..(r + 2) * w];
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0, 0.0, 0.0);
        let (mut b0, mut b1, mut b2, mut b3) = (0.0f32, 0.0, 0.0, 0.0);
        for c in 0..chunks {
            let i = c * 4;
            let (q0, q1, q2, q3) = (q[i], q[i + 1], q[i + 2], q[i + 3]);
            a0 += q0 * row0[i];
            a1 += q1 * row0[i + 1];
            a2 += q2 * row0[i + 2];
            a3 += q3 * row0[i + 3];
            b0 += q0 * row1[i];
            b1 += q1 * row1[i + 1];
            b2 += q2 * row1[i + 2];
            b3 += q3 * row1[i + 3];
        }
        let (mut ta, mut tb) = (0.0f32, 0.0f32);
        for i in chunks * 4..w {
            ta += q[i] * row0[i];
            tb += q[i] * row1[i];
        }
        out[r] = (ta + a0 + a1 + a2 + a3) * scale;
        out[r + 1] = (tb + b0 + b1 + b2 + b3) * scale;
        r += 2;
    }
    if r < n {
        out[r] = dot(q, &rows[r * w..(r + 1) * w]) * scale;
    }
}

/// Weighted row accumulation over one contiguous block of cached rows:
/// `ctx[j] += Σ_i weights[i] * rows[i*w + j]`.
///
/// Rows are folded strictly in ascending order with one add per element per
/// row (`(ctx + w0·r0) + w1·r1`), so accumulating block-by-block over the
/// paged store matches a single dense sweep bitwise.
pub fn axpy_rows(weights: &[f32], rows: &[f32], w: usize, ctx: &mut [f32]) {
    debug_assert_eq!(rows.len() % w, 0);
    debug_assert_eq!(weights.len(), rows.len() / w);
    debug_assert_eq!(ctx.len(), w);
    let n = weights.len();
    let mut r = 0;
    while r + 2 <= n {
        let (w0, w1) = (weights[r], weights[r + 1]);
        let row0 = &rows[r * w..(r + 1) * w];
        let row1 = &rows[(r + 1) * w..(r + 2) * w];
        for j in 0..w {
            ctx[j] = (ctx[j] + w0 * row0[j]) + w1 * row1[j];
        }
        r += 2;
    }
    if r < n {
        axpy(weights[r], &rows[r * w..(r + 1) * w], ctx);
    }
}

/// dot(x, y).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f32;
    // 4-way unroll helps the scalar backend; LLVM vectorizes this cleanly.
    let chunks = x.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
    }
    for i in chunks * 4..x.len() {
        acc += x[i] * y[i];
    }
    acc + s0 + s1 + s2 + s3
}

/// axpy: y += a * x.
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yo, &xv) in y.iter_mut().zip(x.iter()) {
        *yo += a * xv;
    }
}

/// In-place numerically-stable softmax over a slice.
pub fn softmax_inplace(x: &mut [f32]) {
    let max = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// RMS-norm: out = x / rms(x) * w.
pub fn rms_norm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), w.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((o, &xv), &wv) in out.iter_mut().zip(x.iter()).zip(w.iter()) {
        *o = xv * inv * wv;
    }
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// out += residual (elementwise).
pub fn add_inplace(out: &mut [f32], x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    for (o, &v) in out.iter_mut().zip(x.iter()) {
        *o += v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall_res;
    use crate::util::rng::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (_, n) = b.dims2();
        let mut out = Tensor::zeros(vec![m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a.at2(i, p) * b.at2(p, j);
                }
                out.set2(i, j, acc);
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (8, 16, 8), (17, 31, 13)] {
            let a = Tensor::randn(vec![m, k], 1.0, &mut rng);
            let b = Tensor::randn(vec![k, n], 1.0, &mut rng);
            let c = matmul(&a, &b);
            let expect = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&expect) < 1e-4, "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_parallel_matches_serial() {
        // Explicit thread counts: no RAP_THREADS env mutation (which would
        // race with concurrently running tests in this binary).
        let mut rng = Rng::new(2);
        let a = Tensor::randn(vec![32, 24], 1.0, &mut rng);
        let b = Tensor::randn(vec![24, 16], 1.0, &mut rng);
        let ser = matmul_with_threads(&a, &b, 1);
        for threads in [2, 4, 7] {
            let par = matmul_with_threads(&a, &b, threads);
            assert!(par.max_abs_diff(&ser) < 1e-6, "{threads} threads");
        }
    }

    #[test]
    fn vecmat_matches_matmul() {
        let mut rng = Rng::new(3);
        let b = Tensor::randn(vec![9, 5], 1.0, &mut rng);
        let x = Tensor::randn(vec![1, 9], 1.0, &mut rng);
        let full = matmul(&x, &b);
        let fast = vecmat(&x.data, &b);
        for (a, b) in full.data.iter().zip(&fast) {
            assert!((a - b).abs() < 1e-5);
        }
        // The _into form reuses (and fully overwrites) its output buffer.
        let mut y = vec![7.0f32; 5];
        vecmat_into(&x.data, &b, &mut y);
        assert_eq!(y, fast);
    }

    #[test]
    fn matmul_rows_is_bitwise_per_row_vecmat() {
        let mut rng = Rng::new(21);
        for (m, k, n) in [(1usize, 8usize, 5usize), (3, 32, 24), (17, 9, 13), (64, 32, 48)] {
            let a = Tensor::randn(vec![m, k], 1.0, &mut rng);
            let b = Tensor::randn(vec![k, n], 1.0, &mut rng);
            for threads in [1usize, 2, 4] {
                let mut out = vec![0.0f32; m * n];
                matmul_rows_into(&a.data, &b, &mut out, threads);
                for i in 0..m {
                    let row = vecmat(&a.data[i * k..(i + 1) * k], &b);
                    assert_eq!(
                        &out[i * n..(i + 1) * n],
                        row.as_slice(),
                        "row {i} of ({m},{k},{n}) with {threads} threads"
                    );
                }
            }
        }
    }

    #[test]
    fn dot_rows_scaled_is_bitwise_per_row_dot() {
        let mut rng = Rng::new(11);
        for (n, w) in [(1usize, 7usize), (2, 8), (5, 12), (16, 24), (21, 16)] {
            let q: Vec<f32> = (0..w).map(|_| rng.normal_f32()).collect();
            let rows: Vec<f32> = (0..n * w).map(|_| rng.normal_f32()).collect();
            let scale = 0.37f32;
            let mut out = vec![0.0f32; n];
            dot_rows_scaled(&q, &rows, w, scale, &mut out);
            for t in 0..n {
                let expect = dot(&q, &rows[t * w..(t + 1) * w]) * scale;
                assert_eq!(out[t], expect, "row {t} of ({n},{w})");
            }
        }
    }

    #[test]
    fn axpy_rows_matches_sequential_axpy_bitwise() {
        let mut rng = Rng::new(12);
        for (n, w) in [(1usize, 5usize), (2, 8), (7, 16), (16, 9), (33, 16)] {
            let weights: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let rows: Vec<f32> = (0..n * w).map(|_| rng.normal_f32()).collect();
            let mut blocked = vec![0.5f32; w];
            let mut serial = vec![0.5f32; w];
            axpy_rows(&weights, &rows, w, &mut blocked);
            for t in 0..n {
                axpy(weights[t], &rows[t * w..(t + 1) * w], &mut serial);
            }
            assert_eq!(blocked, serial, "({n},{w})");
        }
    }

    #[test]
    fn blocked_kernels_agree_across_run_partitions() {
        // Accumulating block-by-block (the paged layout) must equal one
        // dense sweep — the batched decode identity depends on it.
        let mut rng = Rng::new(13);
        let (n, w) = (37usize, 12usize);
        let q: Vec<f32> = (0..w).map(|_| rng.normal_f32()).collect();
        let weights: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let rows: Vec<f32> = (0..n * w).map(|_| rng.normal_f32()).collect();
        let mut dense_scores = vec![0.0f32; n];
        dot_rows_scaled(&q, &rows, w, 1.3, &mut dense_scores);
        let mut dense_ctx = vec![0.0f32; w];
        axpy_rows(&weights, &rows, w, &mut dense_ctx);
        for block in [1usize, 4, 16] {
            let mut scores = vec![0.0f32; n];
            let mut ctx = vec![0.0f32; w];
            let mut t0 = 0;
            while t0 < n {
                let t1 = (t0 + block).min(n);
                dot_rows_scaled(&q, &rows[t0 * w..t1 * w], w, 1.3, &mut scores[t0..t1]);
                axpy_rows(&weights[t0..t1], &rows[t0 * w..t1 * w], w, &mut ctx);
                t0 = t1;
            }
            assert_eq!(scores, dense_scores, "block {block}");
            assert_eq!(ctx, dense_ctx, "block {block}");
        }
    }

    #[test]
    fn softmax_properties() {
        forall_res(
            4,
            50,
            |r| {
                let n = r.range(1, 40);
                (0..n).map(|_| r.normal_f32() * 10.0).collect::<Vec<f32>>()
            },
            |xs| {
                let mut v = xs.clone();
                softmax_inplace(&mut v);
                let sum: f32 = v.iter().sum();
                if (sum - 1.0).abs() > 1e-4 {
                    return Err(format!("sum {sum}"));
                }
                if v.iter().any(|&x| !(0.0..=1.0).contains(&x)) {
                    return Err("out of range".into());
                }
                // order preserved
                for i in 0..xs.len() {
                    for j in 0..xs.len() {
                        if xs[i] > xs[j] && v[i] < v[j] - 1e-6 {
                            return Err("order broken".into());
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn softmax_handles_extremes() {
        let mut v = vec![-1e30f32, 0.0, 1e3];
        softmax_inplace(&mut v);
        assert!(v.iter().all(|x| x.is_finite()));
        assert!((v.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn rms_norm_unit_scale() {
        let x = vec![3.0f32, 4.0];
        let w = vec![1.0f32, 1.0];
        let mut out = vec![0.0f32; 2];
        rms_norm(&x, &w, 0.0, &mut out);
        let rms = ((9.0 + 16.0) / 2.0f32).sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-6);
        assert!((out[1] - 4.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn silu_known_values() {
        assert!((silu(0.0) - 0.0).abs() < 1e-7);
        assert!((silu(10.0) - 10.0).abs() < 1e-3);
        assert!(silu(-10.0).abs() < 1e-3);
    }

    #[test]
    fn dot_matches_sum() {
        let x: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..13).map(|i| (i * 2) as f32).collect();
        let expect: f32 = (0..13).map(|i| (i * i * 2) as f32).sum();
        assert!((dot(&x, &y) - expect).abs() < 1e-3);
    }
}
