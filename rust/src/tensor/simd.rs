//! Selectable kernel paths: 8-lane f32 wide variants of the hot engine
//! kernels (`dot`, `axpy`, `dot_rows_scaled`, `axpy_rows`, `vecmat_into`,
//! `matmul_rows_into`), runtime-dispatched to AVX2/FMA on x86_64 with a
//! portable 8-accumulator fallback.
//!
//! Contract (see ROADMAP "Bit-identity discipline"): the scalar kernels in
//! `tensor::ops` remain the preserved bit-identity oracle.  The wide paths
//! change accumulation order (8 partial sums + a fixed pairwise horizontal
//! reduction; FMA fuses the multiply-add rounding on AVX2), so they are
//! covered by an explicit error-bound oracle instead (`tests/kernels.rs`:
//! per-logit abs/rel tolerance vs the scalar path plus temperature-0
//! argmax agreement).  Within one process the dispatch decision is fixed
//! (`OnceLock`), so a given path is self-consistent: per-row wide dots are
//! bitwise equal to the wide single-vector dot, which the engine's
//! ref-vs-blocked propchecks rely on when a wide path is forced via
//! `RAP_KERNEL_PATH`.

use std::sync::OnceLock;

use crate::tensor::ops;
use crate::tensor::Tensor;
use crate::util::threadpool::scoped_chunks;

/// Which kernel implementations the engine routes through.
///
/// `Scalar` is the preserved seed oracle; `Wide` uses the f32x8 kernels in
/// this module; `FusedInt4` uses the same wide f32 kernels *and* (when the
/// cache is built with `KvStorageMode::PackedInt4`) reads nibble-packed KV
/// rows directly via `kvcache::quant::{dot_rows_scaled_q4, axpy_rows_q4}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelPath {
    #[default]
    Scalar,
    Wide,
    FusedInt4,
}

impl KernelPath {
    pub fn name(self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Wide => "wide",
            KernelPath::FusedInt4 => "fused-int4",
        }
    }

    /// Parse a path name (`RAP_KERNEL_PATH` values); `None` for unknown.
    pub fn parse(s: &str) -> Option<KernelPath> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelPath::Scalar),
            "wide" => Some(KernelPath::Wide),
            "fused-int4" | "fused_int4" | "fusedint4" => Some(KernelPath::FusedInt4),
            _ => None,
        }
    }

    /// Process-wide default from `RAP_KERNEL_PATH` (read once; unset or
    /// unrecognized values fall back to `Scalar`).
    pub fn from_env() -> KernelPath {
        static PATH: OnceLock<KernelPath> = OnceLock::new();
        *PATH.get_or_init(|| {
            std::env::var("RAP_KERNEL_PATH")
                .ok()
                .and_then(|v| KernelPath::parse(&v))
                .unwrap_or_default()
        })
    }

    /// Does this path read packed-int4 KV rows in-register?
    pub fn fuses_int4(self) -> bool {
        self == KernelPath::FusedInt4
    }
}

/// Is the AVX2+FMA fast path available on this machine?  Decided once per
/// process so every wide call in a run takes the same arm.
pub fn avx2_available() -> bool {
    static AVX2: OnceLock<bool> = OnceLock::new();
    *AVX2.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

const LANES: usize = 8;

/// Portable 8-accumulator dot: one partial sum per lane, fixed pairwise
/// reduction.  Mirrors the AVX2 horizontal-sum tree so both arms agree in
/// reduction *shape* (not bitwise — FMA differs), keeping the error bound
/// uniform.
fn dot_wide_portable(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / LANES;
    let mut lanes = [0.0f32; LANES];
    for c in 0..chunks {
        let i = c * LANES;
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane += x[i + l] * y[i + l];
        }
    }
    let mut acc = 0.0f32;
    for i in chunks * LANES..n {
        acc += x[i] * y[i];
    }
    acc + (((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7])))
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_avx2(x: &[f32], y: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = x.len();
    let chunks = n / LANES;
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let i = c * LANES;
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let yv = _mm256_loadu_ps(y.as_ptr().add(i));
        acc = _mm256_fmadd_ps(xv, yv, acc);
    }
    // Pairwise horizontal sum: (lo+hi) -> 4 lanes -> 2 -> 1.
    let lo = _mm256_castps256_ps128(acc);
    let hi = _mm256_extractf128_ps(acc, 1);
    let s4 = _mm_add_ps(lo, hi);
    let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
    let s1 = _mm_add_ss(s2, _mm_shuffle_ps(s2, s2, 0b01));
    let mut out = _mm_cvtss_f32(s1);
    for i in chunks * LANES..n {
        out += x[i] * y[i];
    }
    out
}

/// Wide dot product (AVX2/FMA when available, portable 8-lane otherwise).
pub fn dot_wide(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: avx2_available() checked avx2+fma at runtime.
        return unsafe { dot_avx2(x, y) };
    }
    dot_wide_portable(x, y)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy_avx2(a: f32, x: &[f32], y: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = x.len();
    let chunks = n / LANES;
    let av = _mm256_set1_ps(a);
    for c in 0..chunks {
        let i = c * LANES;
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let yv = _mm256_loadu_ps(y.as_ptr().add(i));
        _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_fmadd_ps(av, xv, yv));
    }
    for i in chunks * LANES..n {
        y[i] += a * x[i];
    }
}

/// Wide `y += a * x`.  Element-wise, so the portable arm is bitwise equal
/// to `ops::axpy`; the AVX2 arm fuses the multiply-add rounding.
pub fn axpy_wide(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: avx2_available() checked avx2+fma at runtime.
        unsafe { axpy_avx2(a, x, y) };
        return;
    }
    for (yo, &xv) in y.iter_mut().zip(x.iter()) {
        *yo += a * xv;
    }
}

/// Wide `dot_rows_scaled`: per row bitwise equal to `dot_wide(q, row) *
/// scale`, which the paged-vs-reference propchecks rely on when this path
/// is forced.
pub fn dot_rows_scaled_wide(q: &[f32], rows: &[f32], w: usize, scale: f32, out: &mut [f32]) {
    debug_assert_eq!(q.len(), w);
    debug_assert_eq!(rows.len() % w, 0);
    debug_assert_eq!(out.len(), rows.len() / w);
    for (r, o) in out.iter_mut().enumerate() {
        *o = dot_wide(q, &rows[r * w..(r + 1) * w]) * scale;
    }
}

/// Wide `axpy_rows`: sequential per-row `axpy_wide`, so a blocked call is
/// bitwise equal to row-at-a-time accumulation on the same path.
pub fn axpy_rows_wide(weights: &[f32], rows: &[f32], w: usize, ctx: &mut [f32]) {
    debug_assert_eq!(rows.len() % w, 0);
    debug_assert_eq!(weights.len(), rows.len() / w);
    debug_assert_eq!(ctx.len(), w);
    for (r, &wt) in weights.iter().enumerate() {
        axpy_wide(wt, &rows[r * w..(r + 1) * w], ctx);
    }
}

/// Wide `y = x * B` (B row-major `k x n`): row-axpy accumulation so each
/// output element is touched by the 8-lane kernels; zero coefficients are
/// skipped exactly like the scalar tail loop.
pub fn vecmat_into_wide(x: &[f32], b: &Tensor, y: &mut [f32]) {
    let (k, n) = b.dims2();
    assert_eq!(x.len(), k);
    assert_eq!(y.len(), n);
    y.fill(0.0);
    for (i, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        axpy_wide(xv, &b.data[i * n..(i + 1) * n], y);
    }
}

/// Wide allocating vecmat (reference-path convenience).
pub fn vecmat_wide(x: &[f32], b: &Tensor) -> Vec<f32> {
    let n = b.dims2().1;
    let mut y = vec![0.0f32; n];
    vecmat_into_wide(x, b, &mut y);
    y
}

struct OutPtr(*mut f32);
unsafe impl Sync for OutPtr {}

/// Wide row-blocked GEMM: `out[r] = a_row[r] * B`, rows fanned across the
/// scoped pool exactly like `ops::matmul_rows_into` (disjoint row ranges
/// per worker).
pub fn matmul_rows_into_wide(a: &[f32], b: &Tensor, out: &mut [f32], threads: usize) {
    let (k, n) = b.dims2();
    debug_assert_eq!(a.len() % k, 0);
    let m = a.len() / k;
    debug_assert_eq!(out.len(), m * n);
    let out_ptr = OutPtr(out.as_mut_ptr());
    scoped_chunks(m, threads, |range| {
        for r in range {
            // SAFETY: workers receive disjoint row ranges of `out`.
            let row_out =
                unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(r * n), n) };
            vecmat_into_wide(&a[r * k..(r + 1) * k], b, row_out);
        }
    });
}

// ---- dispatch wrappers -------------------------------------------------
//
// Every engine call site routes through these with the engine's configured
// `KernelPath`, hot paths and preserved reference oracles alike — so a
// forced non-default path moves *both* sides of every existing bitwise
// propcheck onto the same kernels.  `FusedInt4` uses the wide f32 kernels
// here; its packed-row reads live in `kvcache::quant`.

#[inline]
pub fn dot_path(path: KernelPath, x: &[f32], y: &[f32]) -> f32 {
    match path {
        KernelPath::Scalar => ops::dot(x, y),
        _ => dot_wide(x, y),
    }
}

#[inline]
pub fn axpy_path(path: KernelPath, a: f32, x: &[f32], y: &mut [f32]) {
    match path {
        KernelPath::Scalar => ops::axpy(a, x, y),
        _ => axpy_wide(a, x, y),
    }
}

#[inline]
pub fn dot_rows_scaled_path(
    path: KernelPath,
    q: &[f32],
    rows: &[f32],
    w: usize,
    scale: f32,
    out: &mut [f32],
) {
    match path {
        KernelPath::Scalar => ops::dot_rows_scaled(q, rows, w, scale, out),
        _ => dot_rows_scaled_wide(q, rows, w, scale, out),
    }
}

#[inline]
pub fn axpy_rows_path(path: KernelPath, weights: &[f32], rows: &[f32], w: usize, ctx: &mut [f32]) {
    match path {
        KernelPath::Scalar => ops::axpy_rows(weights, rows, w, ctx),
        _ => axpy_rows_wide(weights, rows, w, ctx),
    }
}

#[inline]
pub fn vecmat_into_path(path: KernelPath, x: &[f32], b: &Tensor, y: &mut [f32]) {
    match path {
        KernelPath::Scalar => ops::vecmat_into(x, b, y),
        _ => vecmat_into_wide(x, b, y),
    }
}

#[inline]
pub fn vecmat_path(path: KernelPath, x: &[f32], b: &Tensor) -> Vec<f32> {
    match path {
        KernelPath::Scalar => ops::vecmat(x, b),
        _ => vecmat_wide(x, b),
    }
}

#[inline]
pub fn matmul_rows_into_path(
    path: KernelPath,
    a: &[f32],
    b: &Tensor,
    out: &mut [f32],
    threads: usize,
) {
    match path {
        KernelPath::Scalar => ops::matmul_rows_into(a, b, out, threads),
        _ => matmul_rows_into_wide(a, b, out, threads),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn close(a: f32, b: f32, n: usize) -> bool {
        let tol = 1e-5 * (n as f32).sqrt() * (1.0 + a.abs().max(b.abs()));
        (a - b).abs() <= tol
    }

    #[test]
    fn kernel_path_parses() {
        assert_eq!(KernelPath::parse("scalar"), Some(KernelPath::Scalar));
        assert_eq!(KernelPath::parse("Wide"), Some(KernelPath::Wide));
        assert_eq!(KernelPath::parse("fused-int4"), Some(KernelPath::FusedInt4));
        assert_eq!(KernelPath::parse("fused_int4"), Some(KernelPath::FusedInt4));
        assert_eq!(KernelPath::parse("avx512"), None);
        assert_eq!(KernelPath::default(), KernelPath::Scalar);
    }

    #[test]
    fn wide_dot_matches_scalar_within_tolerance() {
        let mut rng = Rng::new(11);
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 64, 100, 192, 257] {
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let y: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let s = ops::dot(&x, &y);
            let w = dot_wide(&x, &y);
            assert!(close(s, w, n.max(1)), "n={n}: scalar {s} wide {w}");
        }
    }

    #[test]
    fn wide_rows_kernels_are_per_row_consistent() {
        // Blocked wide calls must equal row-at-a-time wide calls bitwise:
        // the engine's ref-vs-blocked identity under a forced wide path
        // stands on exactly this.
        let mut rng = Rng::new(12);
        for (n_rows, w) in [(1usize, 6usize), (3, 8), (5, 16), (7, 33), (4, 64)] {
            let q: Vec<f32> = (0..w).map(|_| rng.normal_f32()).collect();
            let rows: Vec<f32> = (0..n_rows * w).map(|_| rng.normal_f32()).collect();
            let weights: Vec<f32> = (0..n_rows).map(|_| rng.normal_f32()).collect();
            let scale = 0.37f32;

            let mut blocked = vec![0.0f32; n_rows];
            dot_rows_scaled_wide(&q, &rows, w, scale, &mut blocked);
            for r in 0..n_rows {
                let one = dot_wide(&q, &rows[r * w..(r + 1) * w]) * scale;
                assert_eq!(blocked[r].to_bits(), one.to_bits(), "row {r} w={w}");
            }

            let mut ctx_blocked = vec![0.0f32; w];
            axpy_rows_wide(&weights, &rows, w, &mut ctx_blocked);
            let mut ctx_seq = vec![0.0f32; w];
            for r in 0..n_rows {
                axpy_wide(weights[r], &rows[r * w..(r + 1) * w], &mut ctx_seq);
            }
            assert_eq!(ctx_blocked, ctx_seq, "axpy_rows w={w}");
        }
    }

    #[test]
    fn wide_vecmat_and_gemm_match_scalar_within_tolerance() {
        let mut rng = Rng::new(13);
        for (m, k, n) in [(1usize, 5usize, 9usize), (4, 32, 48), (3, 33, 17)] {
            let b = Tensor::randn(vec![k, n], 1.0, &mut rng);
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
            let mut scalar = vec![0.0f32; m * n];
            ops::matmul_rows_into(&a, &b, &mut scalar, 1);
            let mut wide = vec![0.0f32; m * n];
            matmul_rows_into_wide(&a, &b, &mut wide, 1);
            for i in 0..m * n {
                assert!(close(scalar[i], wide[i], k), "({m},{k},{n})[{i}]");
            }
            let y = vecmat_wide(&a[..k], &b);
            assert_eq!(y.len(), n);
            for j in 0..n {
                assert_eq!(y[j].to_bits(), wide[j].to_bits(), "vecmat row 0 col {j}");
            }
        }
    }

    #[test]
    fn scalar_dispatch_is_bitwise_scalar() {
        let mut rng = Rng::new(14);
        let x: Vec<f32> = (0..100).map(|_| rng.normal_f32()).collect();
        let y: Vec<f32> = (0..100).map(|_| rng.normal_f32()).collect();
        assert_eq!(
            dot_path(KernelPath::Scalar, &x, &y).to_bits(),
            ops::dot(&x, &y).to_bits()
        );
    }
}
