//! Numerical linear algebra: one-sided Jacobi SVD and Cholesky.
//!
//! Used by the Rust implementations of the SVD/PaLU baselines
//! (`baselines::svd`, `baselines::palu`) so the entire pruning pipeline can
//! also be executed natively — an independent cross-check of the Python
//! plan and the substrate for the `plan` CLI subcommand.
//!
//! Matrices here are small (at most d_model × head_dim), so an O(n^3)
//! Jacobi sweep with f64 accumulation is both adequate and very accurate.

use super::Tensor;

/// Thin SVD of an [m, n] matrix with m >= n: A = U diag(s) V^T where
/// U is [m, n] with orthonormal columns, s descending, V is [n, n].
///
/// One-sided Jacobi: orthogonalise the columns of a working copy of A by
/// plane rotations; the resulting column norms are the singular values and
/// the accumulated rotations give V.
pub fn svd_thin(a: &Tensor) -> (Tensor, Vec<f32>, Tensor) {
    let (m, n) = a.dims2();
    assert!(m >= n, "svd_thin expects m >= n, got {m}x{n}");
    // Work in f64 column-major for accuracy.
    let mut u: Vec<f64> = vec![0.0; m * n]; // column-major [m, n]
    for i in 0..m {
        for j in 0..n {
            u[j * m + i] = a.data[i * n + j] as f64;
        }
    }
    let mut v: Vec<f64> = vec![0.0; n * n]; // column-major identity
    for j in 0..n {
        v[j * n + j] = 1.0;
    }

    let max_sweeps = 60;
    let eps = 1e-14;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let up = u[p * m + i];
                    let uq = u[q * m + i];
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) inner product.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u[p * m + i];
                    let uq = u[q * m + i];
                    u[p * m + i] = c * up - s * uq;
                    u[q * m + i] = s * up + c * uq;
                }
                for i in 0..n {
                    let vp = v[p * n + i];
                    let vq = v[q * n + i];
                    v[p * n + i] = c * vp - s * vq;
                    v[q * n + i] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-12 {
            break;
        }
    }

    // Column norms -> singular values; normalise U columns.
    let mut svals: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm = (0..m).map(|i| u[j * m + i] * u[j * m + i]).sum::<f64>().sqrt();
            (norm, j)
        })
        .collect();
    svals.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut u_out = Tensor::zeros(vec![m, n]);
    let mut v_out = Tensor::zeros(vec![n, n]);
    let mut s_out = vec![0.0f32; n];
    for (rank, &(norm, j)) in svals.iter().enumerate() {
        s_out[rank] = norm as f32;
        let inv = if norm > 1e-300 { 1.0 / norm } else { 0.0 };
        for i in 0..m {
            u_out.data[i * n + rank] = (u[j * m + i] * inv) as f32;
        }
        for i in 0..n {
            v_out.data[i * n + rank] = v[j * n + i] as f32;
        }
    }
    (u_out, s_out, v_out)
}

/// Cholesky factorization of a symmetric positive-definite [n, n] matrix:
/// A = L L^T with L lower-triangular.  Panics on non-PD input beyond
/// a small damping tolerance.
pub fn cholesky(a: &Tensor) -> Tensor {
    let (n, n2) = a.dims2();
    assert_eq!(n, n2);
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.data[i * n + j] as f64;
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                assert!(sum > 0.0, "matrix not positive definite at row {i} (sum={sum})");
                l[i * n + j] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Tensor::new(vec![n, n], l.iter().map(|&x| x as f32).collect())
}

/// Solve L x = b for lower-triangular L (forward substitution), column-wise
/// over the columns of B: returns X with L X = B.
pub fn solve_lower_triangular(l: &Tensor, b: &Tensor) -> Tensor {
    let (n, _) = l.dims2();
    let (n2, cols) = b.dims2();
    assert_eq!(n, n2);
    let mut x = vec![0.0f64; n * cols];
    for c in 0..cols {
        for i in 0..n {
            let mut sum = b.data[i * cols + c] as f64;
            for k in 0..i {
                sum -= l.data[i * n + k] as f64 * x[k * cols + c];
            }
            x[i * cols + c] = sum / l.data[i * n + i] as f64;
        }
    }
    Tensor::new(vec![n, cols], x.iter().map(|&v| v as f32).collect())
}

/// Solve L^T x = b for lower-triangular L (back substitution over columns).
pub fn solve_upper_from_lower(l: &Tensor, b: &Tensor) -> Tensor {
    let (n, _) = l.dims2();
    let (n2, cols) = b.dims2();
    assert_eq!(n, n2);
    let mut x = vec![0.0f64; n * cols];
    for c in 0..cols {
        for i in (0..n).rev() {
            let mut sum = b.data[i * cols + c] as f64;
            for k in (i + 1)..n {
                // (L^T)[i,k] = L[k,i]
                sum -= l.data[k * n + i] as f64 * x[k * cols + c];
            }
            x[i * cols + c] = sum / l.data[i * n + i] as f64;
        }
    }
    Tensor::new(vec![n, cols], x.iter().map(|&v| v as f32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul;
    use crate::util::rng::Rng;

    fn reconstruct(u: &Tensor, s: &[f32], v: &Tensor, rank: usize) -> Tensor {
        let (m, n) = u.dims2();
        let (nv, _) = v.dims2();
        let mut out = Tensor::zeros(vec![m, nv]);
        for r in 0..rank.min(n) {
            for i in 0..m {
                let f = u.data[i * n + r] * s[r];
                for j in 0..nv {
                    out.data[i * nv + j] += f * v.data[j * nv + r];
                }
            }
        }
        out
    }

    #[test]
    fn svd_reconstructs_exactly_at_full_rank() {
        let mut rng = Rng::new(1);
        for (m, n) in [(4, 4), (10, 6), (32, 8)] {
            let a = Tensor::randn(vec![m, n], 1.0, &mut rng);
            let (u, s, v) = svd_thin(&a);
            let rec = reconstruct(&u, &s, &v, n);
            assert!(a.max_abs_diff(&rec) < 1e-4, "{m}x{n}: {}", a.max_abs_diff(&rec));
        }
    }

    #[test]
    fn svd_singular_values_descend_and_nonneg() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(vec![12, 7], 1.0, &mut rng);
        let (_, s, _) = svd_thin(&a);
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn svd_u_v_orthonormal() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(vec![9, 5], 1.0, &mut rng);
        let (u, _, v) = svd_thin(&a);
        let utu = matmul(&u.transpose2(), &u);
        let vtv = matmul(&v.transpose2(), &v);
        for i in 0..5 {
            for j in 0..5 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((utu.at2(i, j) - expect).abs() < 1e-4);
                assert!((vtv.at2(i, j) - expect).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn svd_truncation_is_best_approx_energy() {
        // Truncated reconstruction error equals the tail singular-value
        // energy (Eckart–Young).
        let mut rng = Rng::new(4);
        let a = Tensor::randn(vec![16, 8], 1.0, &mut rng);
        let (u, s, v) = svd_thin(&a);
        for rank in [1, 3, 5, 8] {
            let rec = reconstruct(&u, &s, &v, rank);
            let err2: f32 = a
                .data
                .iter()
                .zip(&rec.data)
                .map(|(x, y)| (x - y) * (x - y))
                .sum();
            let tail: f32 = s[rank..].iter().map(|x| x * x).sum();
            assert!((err2 - tail).abs() < 1e-2 * (1.0 + tail), "rank {rank}");
        }
    }

    #[test]
    fn svd_rank_deficient() {
        // Duplicate columns: true rank 2 of a 6x4 matrix.
        let mut rng = Rng::new(5);
        let base = Tensor::randn(vec![6, 2], 1.0, &mut rng);
        let mut a = Tensor::zeros(vec![6, 4]);
        for i in 0..6 {
            for j in 0..4 {
                a.data[i * 4 + j] = base.data[i * 2 + j % 2];
            }
        }
        let (_, s, _) = svd_thin(&a);
        assert!(s[2] < 1e-4 && s[3] < 1e-4, "tail {s:?}");
    }

    #[test]
    fn cholesky_roundtrip() {
        let mut rng = Rng::new(6);
        let b = Tensor::randn(vec![8, 8], 1.0, &mut rng);
        // SPD: B B^T + I
        let mut spd = matmul(&b, &b.transpose2());
        for i in 0..8 {
            spd.data[i * 8 + i] += 1.0;
        }
        let l = cholesky(&spd);
        let rec = matmul(&l, &l.transpose2());
        assert!(spd.max_abs_diff(&rec) < 1e-3);
        // lower triangular
        for i in 0..8 {
            for j in (i + 1)..8 {
                assert_eq!(l.at2(i, j), 0.0);
            }
        }
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Rng::new(7);
        let b = Tensor::randn(vec![6, 6], 1.0, &mut rng);
        let mut spd = matmul(&b, &b.transpose2());
        for i in 0..6 {
            spd.data[i * 6 + i] += 2.0;
        }
        let l = cholesky(&spd);
        let rhs = Tensor::randn(vec![6, 3], 1.0, &mut rng);
        let x = solve_lower_triangular(&l, &rhs);
        assert!(matmul(&l, &x).max_abs_diff(&rhs) < 1e-4);
        let y = solve_upper_from_lower(&l, &rhs);
        assert!(matmul(&l.transpose2(), &y).max_abs_diff(&rhs) < 1e-4);
    }
}
