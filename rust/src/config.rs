//! Model / variant configuration mirrored from `python/compile/config.py`.
//!
//! These are deserialized from `artifacts/manifest.json`, but can also be
//! constructed directly (the cost model and the Rust pruning planner use
//! synthetic configs, including the paper's H=32, D=128 architecture).

use crate::util::json::Value;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pairing {
    /// (j, j + D/2) — LLaMA/HF layout.
    Half,
    /// (2j, 2j + 1) — original RoFormer layout.
    Interleaved,
}

impl Pairing {
    pub fn from_str(s: &str) -> Pairing {
        match s {
            "half" => Pairing::Half,
            "interleaved" => Pairing::Interleaved,
            other => panic!("unknown pairing {other:?}"),
        }
    }

    /// Column indices (j, j') of pair `p` for a head dimension `d`.
    pub fn pair_cols(&self, p: usize, d: usize) -> (usize, usize) {
        match self {
            Pairing::Half => (p, p + d / 2),
            Pairing::Interleaved => (2 * p, 2 * p + 1),
        }
    }
}

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub mlp_hidden: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
    pub pairing: Pairing,
    pub norm_eps: f32,
}

impl ModelConfig {
    pub fn n_pairs(&self) -> usize {
        self.head_dim / 2
    }

    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    pub fn group_size(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn from_json(v: &Value) -> ModelConfig {
        ModelConfig {
            name: v.req("name").as_str().unwrap().to_string(),
            vocab: v.req("vocab").as_usize().unwrap(),
            d_model: v.req("d_model").as_usize().unwrap(),
            n_layers: v.req("n_layers").as_usize().unwrap(),
            n_heads: v.req("n_heads").as_usize().unwrap(),
            n_kv_heads: v.req("n_kv_heads").as_usize().unwrap(),
            head_dim: v.req("head_dim").as_usize().unwrap(),
            mlp_hidden: v.req("mlp_hidden").as_usize().unwrap(),
            max_seq: v.req("max_seq").as_usize().unwrap(),
            rope_theta: v.req("rope_theta").as_f64().unwrap(),
            pairing: Pairing::from_str(v.req("pairing").as_str().unwrap()),
            norm_eps: v.req("norm_eps").as_f64().unwrap() as f32,
        }
    }

    /// The paper's evaluated architecture (LLaMA-3-8B attention geometry:
    /// H=32 query heads, 8 KV heads, D=128) — used by the analytic cost
    /// model to regenerate Table 2 / 6 / 10 / 12 at paper scale.
    pub fn paper_llama() -> ModelConfig {
        ModelConfig {
            name: "llama3-8b".into(),
            vocab: 128_256,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            mlp_hidden: 14336,
            max_seq: 8192,
            rope_theta: 500_000.0,
            pairing: Pairing::Half,
            norm_eps: 1e-5,
        }
    }

    /// Single-head worst case used in the paper's §3 break-even analysis.
    pub fn single_head() -> ModelConfig {
        ModelConfig {
            name: "single-head".into(),
            vocab: 32_000,
            d_model: 128,
            n_layers: 1,
            n_heads: 1,
            n_kv_heads: 1,
            head_dim: 128,
            mlp_hidden: 512,
            max_seq: 4096,
            rope_theta: 10_000.0,
            pairing: Pairing::Half,
            norm_eps: 1e-5,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Baseline,
    Svd,
    Palu,
    Rap,
}

impl Method {
    pub fn from_str(s: &str) -> Method {
        match s {
            "baseline" => Method::Baseline,
            "svd" => Method::Svd,
            "palu" => Method::Palu,
            "rap" => Method::Rap,
            other => panic!("unknown method {other:?}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Baseline => "baseline",
            Method::Svd => "svd",
            Method::Palu => "palu",
            Method::Rap => "rap",
        }
    }

    /// Does serving this method require reconstructing K to full dimension?
    pub fn reconstructs_k(&self) -> bool {
        matches!(self, Method::Svd | Method::Palu)
    }

    /// Does it require reconstructing V?
    pub fn reconstructs_v(&self) -> bool {
        matches!(self, Method::Svd)
    }
}

/// A compressed variant: per-layer latent widths (+ retained pair indices
/// for RAP).  Mirrors `compile.config.VariantSpec`.
#[derive(Debug, Clone)]
pub struct VariantSpec {
    pub method: Method,
    pub ratio: f64,
    pub model: String,
    pub tag: String,
    pub key: String,
    /// Latent K width per KV head, per layer (2m for RAP, rank for SVD/PaLU,
    /// head_dim for baseline).
    pub k_rank: Vec<usize>,
    /// Latent V width per KV head, per layer.
    pub v_rank: Vec<usize>,
    /// RAP only: retained pair indices `[layer][kv_head][m]`.
    pub k_pairs: Vec<Vec<Vec<usize>>>,
}

impl VariantSpec {
    pub fn from_json(v: &Value) -> VariantSpec {
        let k_pairs = v
            .get("k_pairs")
            .and_then(|p| p.as_arr())
            .map(|layers| {
                layers
                    .iter()
                    .map(|heads| {
                        heads
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .map(|h| h.usize_arr())
                            .collect()
                    })
                    .collect()
            })
            .unwrap_or_default();
        VariantSpec {
            method: Method::from_str(v.req("method").as_str().unwrap()),
            ratio: v.req("ratio").as_f64().unwrap(),
            model: v.req("model").as_str().unwrap().to_string(),
            tag: v.get("tag").and_then(|t| t.as_str()).unwrap_or("").to_string(),
            key: v.req("key").as_str().unwrap().to_string(),
            k_rank: v.req("k_rank").usize_arr(),
            v_rank: v.req("v_rank").usize_arr(),
            k_pairs,
        }
    }

    pub fn baseline(cfg: &ModelConfig) -> VariantSpec {
        VariantSpec {
            method: Method::Baseline,
            ratio: 0.0,
            model: cfg.name.clone(),
            tag: String::new(),
            key: "baseline".into(),
            k_rank: vec![cfg.head_dim; cfg.n_layers],
            v_rank: vec![cfg.head_dim; cfg.n_layers],
            k_pairs: vec![vec![(0..cfg.n_pairs()).collect(); cfg.n_kv_heads]; cfg.n_layers],
        }
    }

    /// Mean fraction of the baseline KV cache retained by this variant.
    pub fn kv_retained(&self, cfg: &ModelConfig) -> f64 {
        let kept: usize = self.k_rank.iter().sum::<usize>() + self.v_rank.iter().sum::<usize>();
        kept as f64 / (2.0 * cfg.head_dim as f64 * cfg.n_layers as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn pairing_cols() {
        assert_eq!(Pairing::Half.pair_cols(2, 8), (2, 6));
        assert_eq!(Pairing::Interleaved.pair_cols(2, 8), (4, 5));
    }

    #[test]
    fn spec_from_json() {
        let v = json::parse(
            r#"{"method":"rap","ratio":0.3,"model":"m","tag":"","key":"rap_r30",
                "k_rank":[8,8],"v_rank":[12,10],
                "k_pairs":[[[0,1,2,3],[1,2,3,4]],[[0,2,4,6],[1,3,5,7]]]}"#,
        )
        .unwrap();
        let s = VariantSpec::from_json(&v);
        assert_eq!(s.method, Method::Rap);
        assert_eq!(s.k_rank, vec![8, 8]);
        assert_eq!(s.k_pairs[1][0], vec![0, 2, 4, 6]);
    }

    #[test]
    fn kv_retained_baseline_is_one() {
        let cfg = ModelConfig::paper_llama();
        let b = VariantSpec::baseline(&cfg);
        assert!((b.kv_retained(&cfg) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn method_reconstruction_flags() {
        assert!(Method::Svd.reconstructs_k() && Method::Svd.reconstructs_v());
        assert!(Method::Palu.reconstructs_k() && !Method::Palu.reconstructs_v());
        assert!(!Method::Rap.reconstructs_k() && !Method::Rap.reconstructs_v());
    }
}
